"""Fig. 5 reproduction: checkpoint/restart times and image sizes vs scale.

Paper: ckpt/restart times for Rodinia + HPGMG/HYPRE at 8-32 ranks; image
size per rank; buffer-cache effects. Here: one host scales state size
(the per-rank image in the paper shrinks as ranks grow — we sweep the
same per-host image sizes directly) and reports save / restore / verify.
"""
from __future__ import annotations

import tempfile
import time

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.common import row
from repro.checkpoint import ChunkStore
from repro.core import ForkedCheckpointer, RestoreManager


def run() -> None:
    for mb in (16, 64, 256):
        n = (mb << 20) // 4
        rng = np.random.default_rng(0)
        state = {
            "device": {"w": jnp.asarray(rng.standard_normal(n), jnp.float32)},
            "host": {"step": np.int64(1)},
        }
        jax.block_until_ready(state["device"]["w"])
        with tempfile.TemporaryDirectory() as d:
            ck = ForkedCheckpointer(
                ChunkStore(d), codec="zstd1", chunk_bytes=8 << 20,
                incremental=False, digest_on_device=False,
            )
            t0 = time.perf_counter()
            r = ck.save_async(1, state)
            blocking = time.perf_counter() - t0
            r.wait()
            total = blocking + r.persist_s
            ck.close()

            t1 = time.perf_counter()
            rm = RestoreManager(ChunkStore(d))
            restored, _ = rm.restore()
            restart = time.perf_counter() - t1

            t2 = time.perf_counter()
            rm.restore(verify=True)
            verify = time.perf_counter() - t2

        row(
            f"fig5_ckpt_restart_{mb}mb",
            total * 1e6,
            blocking_ms=round(blocking * 1e3, 1),
            persist_ms=round(r.persist_s * 1e3, 1),
            restart_ms=round(restart * 1e3, 1),
            verify_ms=round(verify * 1e3, 1),
            image_mb=round(r.bytes_written / 2**20, 1),
        )


if __name__ == "__main__":
    run()
