"""Fig. 5 / Table 2 reproduction: checkpoint/restart times vs scale, with a
``backend`` axis (thread writer-pool vs true-COW fork).

Paper: ckpt/restart times for Rodinia + HPGMG/HYPRE at 8-32 ranks; image
size per rank; buffer-cache effects; Table 2's headline is blocking time
under forked checkpointing vs the naive synchronous strategy. Here: one
host scales state size (the per-rank image in the paper shrinks as ranks
grow — we sweep the same per-host image sizes directly) and reports, per
persist backend, async blocking time vs the ``save_sync`` baseline for the
same state, plus restore / verify times.

    PYTHONPATH=src python benchmarks/ckpt_restart.py --backend fork
    PYTHONPATH=src python benchmarks/ckpt_restart.py            # both
"""
from __future__ import annotations

import argparse
import os
import tempfile
import time

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.common import row
from repro.checkpoint import ChunkStore, DEFAULT_CODEC
from repro.core import ForkedCheckpointer, RestoreManager, list_persist_backends


def _make_state(mb: int):
    n = (mb << 20) // 4
    rng = np.random.default_rng(0)
    state = {
        "device": {"w": jnp.asarray(rng.standard_normal(n), jnp.float32)},
        "host": {"step": np.int64(1)},
    }
    jax.block_until_ready(state["device"]["w"])
    return state


def _checkpointer(d: str, backend: str, codec: str) -> ForkedCheckpointer:
    return ForkedCheckpointer(
        ChunkStore(d), codec=codec, chunk_bytes=8 << 20,
        incremental=False, digest_on_device=False, backend=backend,
    )


def run(backends: tuple[str, ...] = ("thread", "fork"),
        sizes_mb: tuple[int, ...] = (16, 64, 256),
        codec: str = DEFAULT_CODEC) -> None:
    backends = tuple(
        b for b in backends
        if b != "fork" or hasattr(os, "fork")
    )
    for mb in sizes_mb:
        state = _make_state(mb)

        # naive synchronous baseline (same state, same codec): the
        # application blocks for the full compress+write
        with tempfile.TemporaryDirectory() as d:
            ck = _checkpointer(d, "thread", codec)
            sync_s = ck.save_sync(1, state).blocking_s
            ck.close()

        for backend in backends:
            with tempfile.TemporaryDirectory() as d:
                ck = _checkpointer(d, backend, codec)
                t0 = time.perf_counter()
                r = ck.save_async(1, state)
                blocking = time.perf_counter() - t0
                r.wait()
                total = blocking + r.persist_s
                ck.close()

                t1 = time.perf_counter()
                rm = RestoreManager(ChunkStore(d))
                restored, _ = rm.restore()
                restart = time.perf_counter() - t1

                t2 = time.perf_counter()
                rm.restore(verify=True)
                verify = time.perf_counter() - t2

            row(
                f"table2_ckpt_restart_{mb}mb_{backend}",
                total * 1e6,
                backend=backend,
                blocking_ms=round(blocking * 1e3, 1),
                persist_ms=round(r.persist_s * 1e3, 1),
                sync_baseline_ms=round(sync_s * 1e3, 1),
                speedup_vs_naive=round(sync_s / max(blocking, 1e-9), 1),
                blocking_below_sync=bool(blocking < sync_s),
                restart_ms=round(restart * 1e3, 1),
                verify_ms=round(verify * 1e3, 1),
                image_mb=round(r.bytes_written / 2**20, 1),
            )


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument(
        "--backend", choices=list_persist_backends(), default=None,
        help="run a single persist backend (default: thread and fork)",
    )
    ap.add_argument("--codec", default=DEFAULT_CODEC)
    ap.add_argument(
        "--sizes-mb", type=int, nargs="+", default=[16, 64, 256],
        metavar="MB",
    )
    args = ap.parse_args(argv)
    backends = (args.backend,) if args.backend else ("thread", "fork")
    run(backends=backends, sizes_mb=tuple(args.sizes_mb), codec=args.codec)
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
