"""Shared benchmark helpers. All benchmarks print CSV:

    name,us_per_call,derived

``derived`` carries the table-specific figure (overhead %, speedup x,
bytes, ...) as `key=value` pairs joined by ';'.
"""
from __future__ import annotations

import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.models import ModelConfig, build
from repro.optim import get_optimizer


#: every row() call lands here too, so harness runs can dump the whole
#: session as structured JSON (benchmarks.run --json FILE) — the CSV on
#: stdout stays byte-identical for eyeballs and existing tooling
ROWS: list[dict] = []


def row(name: str, us_per_call: float, **derived) -> str:
    d = ";".join(f"{k}={v}" for k, v in derived.items())
    line = f"{name},{us_per_call:.1f},{d}"
    ROWS.append({"name": name, "us_per_call": round(us_per_call, 1), **derived})
    print(line, flush=True)
    return line


def bench_cfg(n_layers=4, d_model=256, vocab=8192) -> ModelConfig:
    """~10M-param dense model: big enough to time, small enough for CPU."""
    return ModelConfig(
        name="bench", family="dense", num_layers=n_layers, d_model=d_model,
        vocab_size=vocab, num_heads=8, num_kv_heads=4, head_dim=d_model // 8,
        d_ff=4 * d_model, param_dtype="float32", compute_dtype="float32",
        ce_chunk_tokens=0,
    )


def make_train_setup(cfg, batch=8, seq=128, seed=0):
    model = build(cfg)
    opt = get_optimizer("adamw", 1e-3)
    params = model.init(jax.random.key(seed))

    @jax.jit
    def step_fn(dstate, batch):
        (l, _), g = jax.value_and_grad(model.loss, has_aux=True)(
            dstate["params"], batch
        )
        p2, o2 = opt.update(g, dstate["opt"], dstate["params"], dstate["step"])
        return {"params": p2, "opt": o2, "step": dstate["step"] + 1}, l

    state = {"params": params, "opt": opt.init(params), "step": jnp.zeros((), jnp.int32)}
    rng = np.random.default_rng(seed)
    b = {
        "inputs": jnp.asarray(rng.integers(0, cfg.vocab_size, (batch, seq)), jnp.int32),
        "targets": jnp.asarray(rng.integers(0, cfg.vocab_size, (batch, seq)), jnp.int32),
    }
    return model, step_fn, state, b


def timeit(fn, *, warmup=1, iters=5) -> float:
    """Median seconds per call."""
    for _ in range(warmup):
        fn()
    ts = []
    for _ in range(iters):
        t0 = time.perf_counter()
        fn()
        ts.append(time.perf_counter() - t0)
    return float(np.median(ts))
