"""Coordinated-commit benchmark: round + commit latency vs cluster size.

The costs that matter at cluster scale:

  commit_ms     phase-2 critical section on the coordinator (merge all
                hostmetas + fsync MANIFEST/COMMIT) — grows with host count
  round_ms      first READY -> commit decision: barrier skew + slowest
                host's persist + commit (what the training loop observes
                at a checkpoint boundary, aggregated across the cluster)
  straggler     one host acks late: round time absorbs it, commit time
                must not — and the StragglerPolicy must flag the host

    PYTHONPATH=src python benchmarks/coord_commit.py
    PYTHONPATH=src python benchmarks/coord_commit.py --hosts 2 4 --straggle-s 0.5
"""
from __future__ import annotations

import argparse
import statistics
import tempfile

from benchmarks.common import row
from repro.coord.supervisor import run_cluster


def _one(n_hosts: int, *, straggle_host=None, straggle_s=0.0,
         steps=6, ckpt_every=2, backend="thread"):
    with tempfile.TemporaryDirectory(prefix="crum-bench-coord-") as root:
        return run_cluster(
            root=root, n_hosts=n_hosts, total_steps=steps,
            ckpt_every=ckpt_every, backend=backend, loop="numpy",
            chunk_bytes=1 << 15, width=256,
            straggle_host=straggle_host, straggle_s=straggle_s,
            deadline_s=300.0,
        )


def run(hosts=(1, 2, 4), straggle_s: float = 0.5, backend: str = "thread") -> None:
    for n in hosts:
        report = _one(n, backend=backend)
        commits = report.committed
        if not commits:
            continue
        commit_ms = statistics.median(r.commit_s * 1e3 for r in commits)
        round_ms = statistics.median(r.round_s * 1e3 for r in commits)
        row(
            f"coord_commit_{n}hosts",
            round_ms * 1e3,  # us_per_call = round latency
            hosts=n,
            backend=backend,
            commit_ms=round(commit_ms, 2),
            round_ms=round(round_ms, 1),
            persist_max_ms=round(
                statistics.median(r.persist_s_max * 1e3 for r in commits), 1
            ),
            rounds=len(commits),
            bytes_per_round=commits[-1].bytes_written,
        )

    # straggler drill at the largest host count: the slow host inflates the
    # round, not the commit, and the policy names it
    n = max(hosts)
    if n >= 2 and straggle_s > 0:
        base = _one(n, backend=backend)
        slow = _one(n, straggle_host=n - 1, straggle_s=straggle_s,
                    backend=backend)
        if base.committed and slow.committed:
            base_round = statistics.median(r.round_s for r in base.committed)
            slow_round = statistics.median(r.round_s for r in slow.committed)
            flagged = sorted(
                {h for r in slow.committed for h in r.stragglers}
            )
            row(
                f"coord_commit_{n}hosts_straggler",
                slow_round * 1e6,
                hosts=n,
                backend=backend,
                straggle_s=straggle_s,
                round_ms=round(slow_round * 1e3, 1),
                round_inflation_x=round(slow_round / max(base_round, 1e-9), 1),
                commit_ms=round(statistics.median(
                    r.commit_s * 1e3 for r in slow.committed
                ), 2),
                stragglers_flagged=flagged,
            )


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--hosts", type=int, nargs="+", default=[1, 2, 4])
    ap.add_argument("--straggle-s", type=float, default=0.5)
    ap.add_argument("--backend", default="thread")
    args = ap.parse_args(argv)
    run(hosts=tuple(args.hosts), straggle_s=args.straggle_s,
        backend=args.backend)
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
