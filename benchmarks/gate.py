"""Overhead-envelope gate — the CI teeth for the hot-path pipeline.

Reads a ``benchmarks.run --json`` dump (or runs the proxy benchmark
itself) and FAILS when the pipelined proxy falls out of the paper's
overhead envelope or the pipeline refactor's wins regress:

  1. proxied overhead (kernel-ish regime, pipelined): within the paper's
     ~6% average envelope, times a tolerance factor for CI-runner jitter
     (default 2.0 -> 12%, the paper's own worst case).
  2. pipelined epoch-sync stall <= half the blocking barrier's stall
     (both regimes) — the overlap must actually overlap.
  3. fused digesting removes the boundary digest scan entirely.
  4. kill-replay (including with an epoch SYNC in flight) restores
     bit-identically.

    PYTHONPATH=src python -m benchmarks.gate --json BENCH_results.json
    PYTHONPATH=src python -m benchmarks.gate            # run + gate

``--baseline FILE`` additionally diffs the rows against a committed
baseline dump (``repro.obs.baseline``); its findings are gate
violations too — one gate for the envelope AND the trajectory.
"""
from __future__ import annotations

import argparse
import json
import sys

PAPER_ENVELOPE_PCT = 6.0
STALL_RATIO_MAX = 0.5
OBS_NOOP_MAX_US = 1.0


def _load_rows(path: str | None) -> list[dict]:
    if path is not None:
        with open(path) as f:
            doc = json.load(f)
        return doc["rows"] if isinstance(doc, dict) else doc
    from benchmarks import proxy_overhead
    from benchmarks.common import ROWS

    proxy_overhead.run()
    return ROWS


def _by_name(rows: list[dict]) -> dict[str, dict]:
    return {r["name"]: r for r in rows}


def check(rows: list[dict], *, tolerance: float = 2.0) -> list[str]:
    """Returns the list of violations (empty = gate passes)."""
    named = _by_name(rows)
    bad: list[str] = []

    def need(name: str) -> dict | None:
        r = named.get(name)
        if r is None:
            bad.append(f"missing benchmark row {name!r}")
        return r

    # 1. paper envelope, kernel-ish regime (the regime the paper measures:
    #    real kernels, not bare control-plane framing)
    r = need("fig4_proxy_overhead_pipelined_kernelish_2ms_step")
    if r is not None:
        limit = PAPER_ENVELOPE_PCT * tolerance
        if float(r["overhead_pct"]) > limit:
            bad.append(
                f"pipelined proxy overhead {r['overhead_pct']}% exceeds "
                f"the paper envelope {PAPER_ENVELOPE_PCT}% x{tolerance} = "
                f"{limit}%"
            )

    # 2. the overlap win: epoch sync stalls <= 50% of the blocking barrier
    for regime in ("stress_60us_step", "kernelish_2ms_step"):
        r = need(f"pipeline_sync_stall_epoch_{regime}")
        if r is not None and float(r["stall_ratio"]) > STALL_RATIO_MAX:
            bad.append(
                f"epoch sync stall ratio {r['stall_ratio']} ({regime}) "
                f"exceeds {STALL_RATIO_MAX} — the pipelined sync is not "
                f"overlapping"
            )

    # 3. fused digesting: no boundary scan left
    r = need("fused_digest_boundary_fused")
    if r is not None and not r.get("boundary_scan_gone"):
        bad.append(
            f"fused digest boundary still scans (digest_us="
            f"{r.get('digest_us')})"
        )

    # 4. recovery correctness is not a perf number — it is a hard gate
    r = need("proxy_kill_replay_recovery")
    if r is not None and not r.get("bit_identical"):
        bad.append("kill-replay recovery was not bit-identical")
    r = need("proxy_kill_replay_inflight_epoch")
    if r is not None and not r.get("boundary_bit_identical"):
        bad.append(
            "kill with an in-flight epoch sync lost the boundary image"
        )

    # 5. observability must stay free when off. Soft: only gated when the
    #    obs_overhead benchmark ran (older dumps predate the row).
    r = named.get("obs_noop_hook")
    if r is not None and float(r["us_per_call"]) > OBS_NOOP_MAX_US:
        bad.append(
            f"disabled-path obs hook costs {r['us_per_call']}us/call — "
            f"over {OBS_NOOP_MAX_US}us; the no-op guard is no longer free"
        )
    r = named.get("obs_ctx_propagation")
    if r is not None and float(r.get("ctx_off_us", 0.0)) > OBS_NOOP_MAX_US:
        bad.append(
            f"untraced frame-send path costs {r['ctx_off_us']}us/frame — "
            f"over {OBS_NOOP_MAX_US}us; causal-context propagation is no "
            f"longer free when off"
        )
    return bad


def soak_clean(doc: dict) -> list[str]:
    """Gate a ``crum-soak/1`` scorecard (``repro.obs.soak`` output):
    every hard boolean must hold — an unexplained alert, an unevidenced
    injection, a non-converged run or a leak trend all fail the gate."""
    bad: list[str] = []
    if doc.get("schema") != "crum-soak/1":
        return [f"not a crum-soak/1 scorecard (schema="
                f"{doc.get('schema')!r})"]
    checks = doc.get("checks") or {}
    if not checks:
        return ["scorecard has no checks"]
    for name, ok in checks.items():
        if not ok:
            bad.append(f"soak check {name} failed")
    if not doc.get("n_injections"):
        bad.append("soak ran zero injections — the drill tested nothing")
    return bad


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--json", metavar="FILE", default=None,
                    help="gate an existing benchmarks.run --json dump "
                         "instead of running the proxy benchmark")
    ap.add_argument("--tolerance", type=float, default=2.0,
                    help="multiplier on the paper's 6%% envelope "
                         "(default 2.0 -> 12%%, the paper's worst case)")
    ap.add_argument("--baseline", metavar="FILE", default=None,
                    help="ALSO diff the rows against this committed "
                         "baseline dump (repro.obs.baseline findings "
                         "become gate violations)")
    ap.add_argument("--soak", metavar="FILE", default=None,
                    help="gate ONLY a crum-soak/1 scorecard "
                         "(repro.obs.soak output) — the chaos-soak CI "
                         "job's teeth")
    args = ap.parse_args(argv)
    if args.soak:
        with open(args.soak) as f:
            violations = soak_clean(json.load(f))
        for v in violations:
            print(f"[gate] FAIL: {v}", file=sys.stderr)
        if not violations:
            print("[gate] soak scorecard: OK")
        return 1 if violations else 0
    rows = _load_rows(args.json)
    violations = check(rows, tolerance=args.tolerance)
    if args.baseline:
        from repro.obs import baseline

        _, base_rows = baseline.load_rows(args.baseline)
        violations += [
            f"baseline: {f['message']}"
            for f in baseline.compare(rows, base_rows, check_missing=False)
        ]
    for v in violations:
        print(f"[gate] FAIL: {v}", file=sys.stderr)
    if not violations:
        print("[gate] overhead envelope + pipeline wins: OK")
    return 1 if violations else 0


if __name__ == "__main__":
    sys.exit(main())
