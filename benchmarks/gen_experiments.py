"""Generate the §Dry-run and §Roofline tables of EXPERIMENTS.md from
dryrun_results.jsonl (last record per cell wins)."""
from __future__ import annotations

import json
import sys


def load(path="dryrun_results.jsonl"):
    best = {}
    for line in open(path):
        r = json.loads(line)
        best[(r["arch"], r["shape"], r["mesh"])] = r
    return best


def fmt_bytes(b):
    return f"{b/2**30:.2f}"


def dryrun_table(best) -> str:
    out = [
        "| arch | shape | mesh | status | GiB/dev | fits 16GiB | compile s |",
        "|---|---|---|---|---:|---|---:|",
    ]
    for (a, s, m), r in sorted(best.items()):
        if r["status"] == "skip":
            out.append(f"| {a} | {s} | {m} | SKIP (quadratic attn) | — | — | — |")
            continue
        if r["status"] != "ok":
            out.append(f"| {a} | {s} | {m} | FAIL | — | — | — |")
            continue
        out.append(
            f"| {a} | {s} | {m} | ok | {fmt_bytes(r.get('per_device_bytes') or 0)} "
            f"| {'yes' if r.get('fits_hbm') else 'no'} | {r.get('compile_s','')} |"
        )
    return "\n".join(out)


def roofline_table(best) -> str:
    out = [
        "| arch | shape | compute s | memory s | collective s | bottleneck | "
        "MODEL_FLOPS | HLO_FLOPS | useful | one-line: what moves the dominant term |",
        "|---|---|---:|---:|---:|---|---:|---:|---:|---|",
    ]
    notes = {
        "collective": "cut cross-shard traffic (dispatch layout, grad-sync cadence, a2a schedule)",
        "memory": "cut HBM traffic (remat policy, dtype of intermediates, fusion of cache updates)",
        "compute": "raise MXU utilization (bigger per-device tiles, fewer redundant recomputes)",
    }
    for (a, s, m), r in sorted(best.items()):
        if m != "single" or r["status"] != "ok":
            continue
        roof = r["roofline"]
        out.append(
            f"| {a} | {s} | {roof['compute_s']:.4f} | {roof['memory_s']:.4f} "
            f"| {roof['collective_s']:.4f} | {roof['bottleneck']} "
            f"| {roof['model_flops']:.3e} | {roof['flops']:.3e} "
            f"| {roof['useful_ratio']:.3f} | {notes[roof['bottleneck']]} |"
        )
    return "\n".join(out)


def summary(best) -> str:
    ok = sum(1 for r in best.values() if r["status"] == "ok")
    skip = sum(1 for r in best.values() if r["status"] == "skip")
    fail = sum(1 for r in best.values() if r["status"] not in ("ok", "skip"))
    return f"{ok} ok / {skip} skip / {fail} fail over {len(best)} (arch x shape x mesh) cells"


if __name__ == "__main__":
    best = load(sys.argv[1] if len(sys.argv) > 1 else "dryrun_results.jsonl")
    print("## summary\n", summary(best))
    print("\n## dryrun\n", dryrun_table(best))
    print("\n## roofline\n", roofline_table(best))
