"""Beyond-paper: incremental (digest-delta) checkpointing.

CRUM's shadow pages track dirtiness but every image is written in full.
With chunk digests the persist phase can skip clean chunks entirely — the
headline case is MoE: a top-k step touches a minority of experts, so most
expert chunks are digest-clean between adjacent checkpoints. (Also: any
setup with frozen layers / embeddings, LoRA, or serving KV snapshots.)
"""
from __future__ import annotations

import tempfile

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.common import row
from repro.checkpoint import ChunkStore
from repro.core import ForkedCheckpointer


def run() -> None:
    rng = np.random.default_rng(0)
    E, D, F = 32, 256, 512
    experts = jnp.asarray(rng.standard_normal((E, D, F)), jnp.float32)
    dense = jnp.asarray(rng.standard_normal((D, 4 * D)), jnp.float32)
    state = {"device": {"experts": experts, "dense": dense},
             "host": {"step": np.int64(1)}}

    for touched_frac, label in [(1.0, "all_experts"), (0.25, "quarter"), (0.06, "top2_of_32")]:
        with tempfile.TemporaryDirectory() as d:
            ck = ForkedCheckpointer(
                ChunkStore(d), chunk_bytes=D * F * 4,  # 1 expert/chunk, default codec
                incremental=True, digest_on_device=False,
            )
            ck.save_async(1, state).wait()
            # a "training step" that touches only some experts + the dense mat
            k = max(1, int(E * touched_frac))
            new_experts = experts.at[:k].add(0.01)
            state2 = {
                "device": {"experts": new_experts, "dense": dense + 0.01},
                "host": {"step": np.int64(2)},
            }
            r = ck.save_async(2, state2)
            r.wait()
            ck.close()
        total_chunks = r.chunks_written + r.chunks_reused
        row(
            f"incremental_moe_{label}",
            r.persist_s * 1e6,
            chunks_written=r.chunks_written,
            chunks_reused=r.chunks_reused,
            write_fraction=round(r.chunks_written / total_chunks, 3),
            bytes_written_mb=round(r.bytes_written / 2**20, 2),
        )


if __name__ == "__main__":
    run()
