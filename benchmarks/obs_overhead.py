"""Observability cost: the disabled no-op path and the enabled emit path.

The tracing contract (repro/obs/trace.py) is that hot sites pay one
module-global load plus one identity test when tracing is off. That is
only true while nobody "helpfully" turns the guard into a function call
or an allocation — so this benchmark pins it:

  * ``obs_noop_hook`` — the exact disabled-path pattern every
    instrumented hot site uses (``tr = trace.get()`` hoisted, then the
    per-event ``if tr is not None`` test). Gate: must stay under 1 us
    per call; in practice it is tens of *nano*seconds.
  * ``obs_enabled_span`` — the enabled path: one ``X`` event per call
    (dict build + json + single O_APPEND write). This is the price a
    traced run pays per event, for sizing how much instrumentation a
    hot loop can carry.

The no-op measurement temporarily stashes any live tracer rather than
calling ``disable()`` so a traced benchmark session (CRUM_OBS_DIR set)
keeps its shard open across this module.
"""
from __future__ import annotations

import os
import tempfile
import time

from benchmarks.common import row, timeit
from repro.obs import trace


def run() -> None:
    # -- disabled path: the hot-site guard, nothing else -------------------
    n = 200_000
    prev = trace.TRACER
    trace.TRACER = None
    try:
        def noop_loop():
            tr = trace.get()  # hoisted once per hot region, like real sites
            for _ in range(n):
                if tr is not None:
                    tr.instant("never")
        t_noop = timeit(noop_loop, warmup=1, iters=5) / n
    finally:
        trace.TRACER = prev
    row("obs_noop_hook", t_noop * 1e6,
        ns_per_call=round(t_noop * 1e9, 2), calls=n)

    # -- enabled path: one complete (X) event per call ---------------------
    m = 20_000
    with tempfile.TemporaryDirectory(prefix="crum-obs-bench-") as d:
        tr = trace.Tracer(d, "bench")  # private instance; global untouched

        def emit_loop():
            for _ in range(m):
                t0 = time.perf_counter()
                tr.complete("bench.evt", t0, step=1)
        t_emit = timeit(emit_loop, warmup=1, iters=3) / m
        shard_bytes = os.fstat(tr._fd).st_size
        os.close(tr._fd)
    row("obs_enabled_span", t_emit * 1e6,
        events=m, bytes_per_event=round(shard_bytes / (3 * m + m)))

    # -- causal-context propagation: per-frame child mint + attach ---------
    # Traced path: every outbound frame mints a fresh child span id and
    # attaches it to the frame dict. Untraced path: the exact hot-site
    # guard (`if self.trace_ctx is None`) — one attribute load + identity
    # test, which must stay inside the same no-op envelope as the tracer
    # guard (gate: ctx_off_us <= OBS_NOOP_MAX_US).
    p = 100_000
    root = trace.span_context(trace.round_trace_id(3))

    def ctx_on_loop():
        for _ in range(p):
            frame = {"step": 1}
            frame["ctx"] = trace.child_span(root)
    t_on = timeit(ctx_on_loop, warmup=1, iters=3) / p

    class _Site:
        trace_ctx = None
    site = _Site()

    def ctx_off_loop():
        for _ in range(p):
            if site.trace_ctx is None:
                frame = {"step": 1}  # noqa: F841 — the untraced frame
    t_off = timeit(ctx_off_loop, warmup=1, iters=3) / p
    row("obs_ctx_propagation", t_on * 1e6,
        ctx_off_us=round(t_off * 1e6, 4), frames=p)

    # -- heartbeat piggyback: the per-beat delta collect -------------------
    # This runs once per heartbeat interval on every worker, against a
    # realistically-populated registry. It must stay far below the beat
    # period (hundreds of ms) — microseconds, in practice.
    from repro.obs import metrics as obs_metrics
    from repro.obs.live import HeartbeatPiggyback

    reg = obs_metrics.Registry()
    for i in range(40):
        reg.inc(f"counter_{i}", i + 1)
        reg.set(f"gauge_{i}", float(i))
    pig = HeartbeatPiggyback(reg)
    k = 20_000

    def collect_loop():
        for j in range(k):
            reg.inc("proxy_syncs_total")  # keep a delta flowing every beat
            pig.collect()
    t_collect = timeit(collect_loop, warmup=1, iters=3) / k
    row("obs_piggyback_collect", t_collect * 1e6,
        registry_keys=80, beats=k)
