"""Fig. 4 reproduction: runtime overhead of running under CRUM.

Paper: 1-12% overhead across Rodinia/HPGMG/HYPRE, 6% average — the cost of
interposition + shadow-page machinery with NO checkpoints taken.

Here, two measurements:

  1. train-step throughput native vs under the CheckpointedTrainer with
     the shadow manager registered and the Algorithm-1 FSM ticking every
     step (mark_device_step), but no checkpoint I/O. The analogue holds if
     overhead stays in the paper's single-digit-% envelope.
  2. a ``backend`` axis: the same loop with a checkpoint taken mid-run per
     persist backend — the steady-state dilation the train loop pays while
     phase 2 runs concurrently. The fork backend moves compression into a
     child process (own GIL, own scheduler slice); the thread backend
     shares both with the train loop.
"""
from __future__ import annotations

import os
import tempfile

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.common import bench_cfg, make_train_setup, row, timeit
from repro.checkpoint import ChunkStore
from repro.core import ForkedCheckpointer, ShadowStateManager


def run() -> None:
    cfg = bench_cfg()
    model, step_fn, state, batch = make_train_setup(cfg)

    def native():
        s = state
        for _ in range(5):
            s, _ = step_fn(s, batch)
        jax.block_until_ready(s["params"])

    t_native = timeit(native, warmup=1, iters=5) / 5

    # under CRUM: shadow registered, FSM ticking (the paper's interposition)
    shadow = ShadowStateManager(chunk_bytes=1 << 20)
    shadow.register(state)
    shadow.sync(state)

    def under_crum():
        s = state
        for _ in range(5):
            s, _ = step_fn(s, batch)
            shadow.mark_device_step()  # Algorithm-1 event per device step
        jax.block_until_ready(s["params"])

    t_crum = timeit(under_crum, warmup=1, iters=5) / 5
    overhead = (t_crum - t_native) / t_native * 100.0
    row(
        "fig4_runtime_overhead",
        t_crum * 1e6,
        native_us=round(t_native * 1e6, 1),
        overhead_pct=round(overhead, 2),
        paper_claim="6% avg / 12% worst",
        within_paper_envelope=bool(overhead <= 12.0),
    )

    # -- backend axis: step-time dilation while phase 2 persists -----------
    backends = ["thread"] + (["fork"] if hasattr(os, "fork") else [])
    full = {"device": state, "host": {"step": np.int64(0)}}
    for backend in backends:
        with tempfile.TemporaryDirectory() as d:
            ck = ForkedCheckpointer(
                ChunkStore(d), chunk_bytes=1 << 20, incremental=False,
                digest_on_device=False, backend=backend,
            )

            def steps_with_persist_inflight():
                r = ck.save_async(1, full)  # phase 2 overlaps the loop below
                s = state
                for _ in range(5):
                    s, _ = step_fn(s, batch)
                jax.block_until_ready(s["params"])
                r.wait()

            t_overlap = timeit(steps_with_persist_inflight, warmup=1, iters=3) / 5
            ck.close()
        dilation = (t_overlap - t_native) / t_native * 100.0
        row(
            f"fig4_persist_overlap_{backend}",
            t_overlap * 1e6,
            backend=backend,
            native_us=round(t_native * 1e6, 1),
            dilation_pct=round(dilation, 2),
        )


if __name__ == "__main__":
    run()
