"""Fig. 4 for the proxy subsystem: runtime overhead of proxied execution,
plus the kill-replay recovery latency the CRAC follow-up hardens.

Paper: running every CUDA call through the proxy costs 1-12% (6% average)
across Rodinia/HPGMG/HYPRE. Here the same measurement for the device-proxy
runner: per-step wall time executing the step program

  - inline (in-process, the no-proxy baseline),
  - proxied with pipelined STEP calls + one SYNC per window (the shipped
    configuration — the app runs ahead of the proxy), and
  - proxied with a FLUSH barrier after every step (upper bound: what the
    pipeline is buying).

Second measurement: SIGKILL the proxy mid-training and time the supervised
recovery (respawn + API-log replay + segment re-push) until training has
caught back up to the kill point with a verified bit-identical digest.

Third measurement (the hot-path pipeline refactor): what the app actually
*stalls* at a sync boundary — the legacy blocking barrier (issue SYNC,
wait for SYNCED) vs the pipelined epoch sync (issue SYNC{epoch}, keep
stepping, collect the ack at the next boundary). The epoch path's stall
must be a fraction of the barrier's, because the boundary work overlaps
the next window's steps. Plus fused digesting: the step program emits
chunk digests as part of each step, so the boundary's digest scan
disappears (phase_us.digest -> 0). And the kill drill with an epoch SYNC
in flight: replay must re-issue it and the collected boundary image must
stay bit-identical.
"""
from __future__ import annotations

import time

from benchmarks.common import row, timeit
from repro.proxy import ProxyRunner, make_program
from repro.utils.tree import tree_digest

SPEC = {"name": "numpy_sgd", "rows": 128, "width": 256, "seed": 0}
WINDOW = 20  # steps per sync window (the checkpoint-cadence analogue)

# the paper measures proxy overhead against real (ms-scale) GPU kernels;
# step_time_s simulates that regime, while 0 is the control-plane stress
# case where every microsecond of framing shows
REGIMES = {"stress_60us_step": 0.0, "kernelish_2ms_step": 0.002}


def _inline_per_step(spec) -> float:
    prog = make_program(spec)
    state = prog.init_state()
    step = 0

    def win():
        nonlocal state, step
        for _ in range(WINDOW):
            step += 1
            state, _ = prog.step(state, step)

    return timeit(win, warmup=1, iters=3) / WINDOW


def _proxied_per_step(spec, *, flush_every_step: bool) -> float:
    r = ProxyRunner(spec, chunk_bytes=1 << 18)
    r.start()
    step = 0

    def win():
        nonlocal step
        for _ in range(WINDOW):
            step += 1
            r.step(step)
            if flush_every_step:
                r.drain()
        r.sync_state()

    t = timeit(win, warmup=1, iters=3) / WINDOW
    r.close()
    return t


def _sync_stall(spec, *, pipelined: bool, app_work_s: float) -> tuple[float, float]:
    """(median, mean) seconds the app is BLOCKED per sync boundary.

    The app is *paced*: it spends ``app_work_s`` of its own time per step
    (input pipeline, metrics, host-side bookkeeping — what a real train
    loop does between submits), so the proxy keeps pace instead of
    accumulating an unbounded backlog. Blocking mode then stalls for the
    boundary's drain+digest+fetch+ack; pipelined mode issues the epoch
    SYNC and pays only whatever of that work is left when the *next*
    boundary collects the ack — the overlap the refactor buys."""
    r = ProxyRunner(spec, chunk_bytes=1 << 18)
    r.start()
    step = 0
    stalls: list[float] = []
    windows = 6
    pending = None

    def app_window():
        nonlocal step, pending
        for _ in range(WINDOW):
            step += 1
            r.step(step)
            if app_work_s:
                time.sleep(app_work_s)
            if pending is not None:
                # opportunistic poll between steps — exactly what the
                # trainer's pipelined loop does; a landed ack costs 0 stall
                if r.sync_poll(pending) is not None:
                    pending = None

    # warmup window (first sync pays first-copy costs either way)
    app_window()
    r.sync_state()
    if pipelined:
        pending = r.sync_begin()  # every measured window collects an epoch
    for _ in range(windows):
        app_window()
        if pipelined:
            stall = 0.0
            if pending is not None:
                _, info = r.sync_collect(pending)
                stall = info["stall_us"] / 1e6
            stalls.append(stall)
            pending = r.sync_begin()
        else:
            t0 = time.perf_counter()
            r.sync_state()
            stalls.append(time.perf_counter() - t0)
    if pending is not None:
        r.sync_collect(pending)
    r.close()
    # median boundary stall: an occasional window where the ack lands at
    # the boundary itself (and the collect waits behind a queued step or
    # two) is real but not the typical cost a train loop pays — the mean
    # rides along so the spike tail stays visible
    stalls.sort()
    return stalls[len(stalls) // 2], sum(stalls) / len(stalls)


def run() -> None:
    for regime, step_time_s in REGIMES.items():
        spec = dict(SPEC, step_time_s=step_time_s)
        t_inline = _inline_per_step(spec)
        t_pipe = _proxied_per_step(spec, flush_every_step=False)
        t_flush = _proxied_per_step(spec, flush_every_step=True)
        for label, t in (("pipelined", t_pipe), ("flush_per_step", t_flush)):
            ov = (t - t_inline) / t_inline * 100.0
            row(
                f"fig4_proxy_overhead_{label}_{regime}",
                t * 1e6,
                inline_us=round(t_inline * 1e6, 1),
                overhead_pct=round(ov, 2),
                sync_window=WINDOW,
                within_paper_envelope=bool(ov <= 12.0),
                paper_claim="6% avg / 12% worst (proxied CUDA calls)",
            )

    # -- sync-boundary stall: blocking barrier vs pipelined epoch -----------
    for regime, step_time_s in REGIMES.items():
        spec = dict(SPEC, step_time_s=step_time_s)
        # the app's own per-step time: a hair over the proxy's, so the
        # pipeline stays drained and the boundary stall isolates sync work
        app_work_s = step_time_s + 300e-6
        blk_med, blk_mean = _sync_stall(
            spec, pipelined=False, app_work_s=app_work_s
        )
        ep_med, ep_mean = _sync_stall(
            spec, pipelined=True, app_work_s=app_work_s
        )
        ratio = ep_med / blk_med if blk_med > 0 else 0.0
        row(
            f"pipeline_sync_stall_blocking_{regime}",
            blk_med * 1e6,
            mean_us=round(blk_mean * 1e6, 1),
            sync_window=WINDOW,
        )
        row(
            f"pipeline_sync_stall_epoch_{regime}",
            ep_med * 1e6,
            mean_us=round(ep_mean * 1e6, 1),
            sync_window=WINDOW,
            stall_ratio=round(ratio, 3),
            overlap_win=bool(ratio <= 0.5),
        )

    # -- fused digesting: the boundary scan disappears ----------------------
    for fused in (False, True):
        spec = dict(SPEC, step_time_s=0.0)
        r = ProxyRunner(spec, chunk_bytes=1 << 18, fused_digests=fused)
        r.start()
        step = 0
        digest_us = sync_us = 0.0
        iters = 4
        for _ in range(iters):
            for _ in range(WINDOW):
                step += 1
                r.step(step)
            _, info = r.sync_state()
            phase = info.get("phase_us", {})
            digest_us += float(phase.get("digest", 0.0))
            sync_us += float(phase.get("sync", 0.0))
        r.close()
        row(
            f"fused_digest_boundary_{'fused' if fused else 'scan'}",
            sync_us / iters,
            digest_us=round(digest_us / iters, 1),
            boundary_scan_gone=bool(fused and digest_us == 0.0),
        )

    # -- kill-replay recovery latency ---------------------------------------
    prog = make_program(SPEC)
    ref = prog.init_state()
    kill_at, end = 30, 60
    for s in range(1, end + 1):
        ref, _ = prog.step(ref, s)
    ref_digest = tree_digest(ref)

    r = ProxyRunner(SPEC, chunk_bytes=1 << 18)
    r.start()
    for s in range(1, kill_at + 1):
        r.step(s)
    _, info = r.sync_state()
    r.kill()
    t0 = time.perf_counter()
    for s in range(kill_at + 1, end + 1):
        r.step(s)  # first call detects death -> respawn + replay
    _, info2 = r.sync_state()
    recovery = time.perf_counter() - t0
    rec = r.recoveries[-1] if r.recoveries else {}
    row(
        "proxy_kill_replay_recovery",
        recovery * 1e6,
        recovery_ms=round(recovery * 1e3, 1),
        respawn_replay_ms=round(rec.get("recovery_s", 0.0) * 1e3, 1),
        replayed_steps=rec.get("replayed_steps", 0),
        restarts=r.restarts,
        bit_identical=bool(info2["digest"] == ref_digest),
    )
    r.close()

    # -- kill with an epoch SYNC in flight ----------------------------------
    prog = make_program(SPEC)
    boundary_ref = prog.init_state()
    for s in range(1, kill_at + 1):
        boundary_ref, _ = prog.step(boundary_ref, s)
    boundary_digest = tree_digest(boundary_ref)

    r = ProxyRunner(SPEC, chunk_bytes=1 << 18)
    r.start()
    for s in range(1, kill_at + 1):
        r.step(s)
    epoch = r.sync_begin()
    r.kill()  # SIGKILL with the epoch SYNC un-acked
    t0 = time.perf_counter()
    for s in range(kill_at + 1, end + 1):
        r.step(s)  # death detected -> respawn + replay (re-issues the SYNC)
    _, einfo = r.sync_collect(epoch)
    recovery = time.perf_counter() - t0
    row(
        "proxy_kill_replay_inflight_epoch",
        recovery * 1e6,
        recovery_ms=round(recovery * 1e3, 1),
        restarts=r.restarts,
        boundary_step=einfo["step"],
        boundary_bit_identical=bool(einfo["digest"] == boundary_digest),
    )
    r.close()


if __name__ == "__main__":
    run()
