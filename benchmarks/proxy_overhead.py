"""Fig. 4 for the proxy subsystem: runtime overhead of proxied execution,
plus the kill-replay recovery latency the CRAC follow-up hardens.

Paper: running every CUDA call through the proxy costs 1-12% (6% average)
across Rodinia/HPGMG/HYPRE. Here the same measurement for the device-proxy
runner: per-step wall time executing the step program

  - inline (in-process, the no-proxy baseline),
  - proxied with pipelined STEP calls + one SYNC per window (the shipped
    configuration — the app runs ahead of the proxy), and
  - proxied with a FLUSH barrier after every step (upper bound: what the
    pipeline is buying).

Second measurement: SIGKILL the proxy mid-training and time the supervised
recovery (respawn + API-log replay + segment re-push) until training has
caught back up to the kill point with a verified bit-identical digest.
"""
from __future__ import annotations

import time

from benchmarks.common import row, timeit
from repro.proxy import ProxyRunner, make_program
from repro.utils.tree import tree_digest

SPEC = {"name": "numpy_sgd", "rows": 128, "width": 256, "seed": 0}
WINDOW = 20  # steps per sync window (the checkpoint-cadence analogue)

# the paper measures proxy overhead against real (ms-scale) GPU kernels;
# step_time_s simulates that regime, while 0 is the control-plane stress
# case where every microsecond of framing shows
REGIMES = {"stress_60us_step": 0.0, "kernelish_2ms_step": 0.002}


def _inline_per_step(spec) -> float:
    prog = make_program(spec)
    state = prog.init_state()
    step = 0

    def win():
        nonlocal state, step
        for _ in range(WINDOW):
            step += 1
            state, _ = prog.step(state, step)

    return timeit(win, warmup=1, iters=3) / WINDOW


def _proxied_per_step(spec, *, flush_every_step: bool) -> float:
    r = ProxyRunner(spec, chunk_bytes=1 << 18)
    r.start()
    step = 0

    def win():
        nonlocal step
        for _ in range(WINDOW):
            step += 1
            r.step(step)
            if flush_every_step:
                r.drain()
        r.sync_state()

    t = timeit(win, warmup=1, iters=3) / WINDOW
    r.close()
    return t


def run() -> None:
    for regime, step_time_s in REGIMES.items():
        spec = dict(SPEC, step_time_s=step_time_s)
        t_inline = _inline_per_step(spec)
        t_pipe = _proxied_per_step(spec, flush_every_step=False)
        t_flush = _proxied_per_step(spec, flush_every_step=True)
        for label, t in (("pipelined", t_pipe), ("flush_per_step", t_flush)):
            ov = (t - t_inline) / t_inline * 100.0
            row(
                f"fig4_proxy_overhead_{label}_{regime}",
                t * 1e6,
                inline_us=round(t_inline * 1e6, 1),
                overhead_pct=round(ov, 2),
                sync_window=WINDOW,
                within_paper_envelope=bool(ov <= 12.0),
                paper_claim="6% avg / 12% worst (proxied CUDA calls)",
            )

    # -- kill-replay recovery latency ---------------------------------------
    prog = make_program(SPEC)
    ref = prog.init_state()
    kill_at, end = 30, 60
    for s in range(1, end + 1):
        ref, _ = prog.step(ref, s)
    ref_digest = tree_digest(ref)

    r = ProxyRunner(SPEC, chunk_bytes=1 << 18)
    r.start()
    for s in range(1, kill_at + 1):
        r.step(s)
    _, info = r.sync_state()
    r.kill()
    t0 = time.perf_counter()
    for s in range(kill_at + 1, end + 1):
        r.step(s)  # first call detects death -> respawn + replay
    _, info2 = r.sync_state()
    recovery = time.perf_counter() - t0
    rec = r.recoveries[-1] if r.recoveries else {}
    row(
        "proxy_kill_replay_recovery",
        recovery * 1e6,
        recovery_ms=round(recovery * 1e3, 1),
        respawn_replay_ms=round(rec.get("recovery_s", 0.0) * 1e3, 1),
        replayed_steps=rec.get("replayed_steps", 0),
        restarts=r.restarts,
        bit_identical=bool(info2["digest"] == ref_digest),
    )
    r.close()


if __name__ == "__main__":
    run()
