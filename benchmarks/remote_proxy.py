"""Remote-proxy data plane: wire economy + reschedule latency.

Three measurements for the cross-host proxy transport
(``repro.remote``):

  1. **Wire bytes vs dirty chunks** — push a state with exactly k dirty
     chunks through the streamed transport: payload bytes on the TCP
     connection must scale with k, not with the state size (the chunk-
     delta machinery from the paged-UPLOAD work, now crossing a real
     wire). The bench *asserts* sub-linear behaviour vs full-state pushes.
  2. **Streamed vs segment step overhead** — per-step wall time of the
     pipelined runner over both transports; the stream pays its payload
     framing only at SYNC points, so steady-state STEP cost should match.
  3. **Reschedule-and-replay latency** — SIGKILL a proxy-host daemon
     mid-run and time until training is caught back up on the survivor
     with a bit-identical digest (CRAC's restart protocol across a host
     boundary).
"""
from __future__ import annotations

import time

from benchmarks.common import row, timeit
from repro.proxy import ProxyRunner, make_program
from repro.utils.tree import tree_digest

SPEC = {"name": "numpy_sgd", "rows": 256, "width": 256, "seed": 0}
CHUNK = 1 << 12  # 4 KiB chunks: plenty of chunks to dirty selectively
WINDOW = 20


def _wire_vs_dirty_chunks() -> None:
    import numpy as np

    r = ProxyRunner(SPEC, chunk_bytes=CHUNK, transport="stream")
    state = r.start()
    try:
        r.sync_state()  # settle: mirror == device
        total = r.transport.table.total_bytes()
        full_push_wire = None
        results = []
        for k in (1, 4, 16, 64):
            flat_w = np.asarray(state["w"])
            w = flat_w.copy().reshape(-1)
            # dirty exactly k chunks of 'w' (CHUNK bytes apart, 1 float each)
            stride = CHUNK // w.itemsize
            for i in range(k):
                w[i * stride] += 1.0
            state = dict(state, w=w.reshape(flat_w.shape))
            before = r.transport.wire_tx
            r.push(state)
            wire = r.transport.wire_tx - before
            results.append((k, wire))
            row(
                f"remote_wire_bytes_k{k}",
                0.0,
                dirty_chunks=k,
                wire_bytes=wire,
                state_bytes=total,
                bytes_per_chunk=round(wire / k, 1),
            )
        # full-state push for comparison: everything dirty
        rng = np.random.default_rng(7)
        state = {p: rng.standard_normal(np.asarray(v).shape).astype("float32")
                 for p, v in state.items()}
        before = r.transport.wire_tx
        r.push(state)
        full_push_wire = r.transport.wire_tx - before
        row(
            "remote_wire_bytes_full_push",
            0.0,
            wire_bytes=full_push_wire,
            state_bytes=total,
        )
        # the acceptance assertion: delta pushes are sub-linear vs full
        for k, wire in results:
            assert wire <= k * CHUNK * 1.5 + 4096, (
                f"k={k}: wire {wire}B not ~k*chunk ({k * CHUNK}B)"
            )
        assert results[0][1] * 8 < full_push_wire, (
            f"1-chunk push ({results[0][1]}B) not far below full-state "
            f"push ({full_push_wire}B)"
        )
    finally:
        r.close()


def _step_overhead() -> None:
    times = {}
    for kind in ("segment", "stream"):
        r = ProxyRunner(SPEC, chunk_bytes=1 << 16, transport=kind)
        r.start()
        step = 0

        def win():
            nonlocal step
            for _ in range(WINDOW):
                step += 1
                r.step(step)
            r.sync_state()

        times[kind] = timeit(win, warmup=1, iters=3) / WINDOW
        r.close()
    ratio = times["stream"] / times["segment"]
    for kind, t in times.items():
        row(
            f"remote_transport_step_{kind}",
            t * 1e6,
            sync_window=WINDOW,
            stream_vs_segment_x=round(ratio, 3),
        )


def _reschedule_latency() -> None:
    from repro.remote.host import ProxyHostHandle

    daemons = [ProxyHostHandle(f"bench-ph{i}").start() for i in range(2)]
    order = list(daemons)

    def provider(failed: bool = False):
        from repro.proxy.protocol import ProxyDiedError

        if failed and len(order) > 1:
            order.pop(0)  # the dead one; survivor takes over
        elif failed:
            # the survivor flaked too: surface as a budgeted retryable
            # failure, never an IndexError out of the recovery loop
            raise ProxyDiedError("no proxy hosts left in the bench pool")
        return order[0].addr

    prog = make_program(SPEC)
    ref = prog.init_state()
    kill_at, end = 30, 60
    for s in range(1, end + 1):
        ref, _ = prog.step(ref, s)
    ref_digest = tree_digest(ref)

    r = ProxyRunner(
        SPEC, chunk_bytes=1 << 16, transport="stream",
        endpoint_provider=provider,
    )
    r.start()
    try:
        for s in range(1, kill_at + 1):
            r.step(s)
        r.sync_state()
        daemons[0].kill()  # the remote host dies, not just the session
        t0 = time.perf_counter()
        for s in range(kill_at + 1, end + 1):
            r.step(s)  # death detected -> reschedule to survivor + replay
        _, info = r.sync_state()
        recovery = time.perf_counter() - t0
        rec = r.recoveries[-1] if r.recoveries else {}
        row(
            "remote_reschedule_replay",
            recovery * 1e6,
            recovery_ms=round(recovery * 1e3, 1),
            respawn_replay_ms=round(rec.get("recovery_s", 0.0) * 1e3, 1),
            replayed_steps=rec.get("replayed_steps", 0),
            restarts=r.restarts,
            bit_identical=bool(info["digest"] == ref_digest),
        )
        assert info["digest"] == ref_digest, "reschedule lost state"
    finally:
        r.close()
        for d in daemons:
            d.terminate()


def run() -> None:
    _wire_vs_dirty_chunks()
    _step_overhead()
    _reschedule_latency()


if __name__ == "__main__":
    run()
