"""Roofline table emitter: reads the dry-run JSONL and prints §Roofline rows.

Run ``python -m repro.launch.dryrun --all --mesh both --out
dryrun_results.jsonl`` first (hours of compiles); this benchmark only
formats. Falls back to a live single-cell dry-run if the file is missing.
"""
from __future__ import annotations

import json
import os

from benchmarks.common import row

RESULTS = os.path.join(os.path.dirname(os.path.dirname(os.path.abspath(__file__))),
                       "dryrun_results.jsonl")


def run() -> None:
    if not os.path.exists(RESULTS):
        print(f"# {RESULTS} missing — run the dry-run sweep first")
        return
    best = {}
    for line in open(RESULTS):
        r = json.loads(line)
        key = (r["arch"], r["shape"], r["mesh"])
        best[key] = r  # last occurrence wins (re-runs append)
    for (arch, shape, mesh), r in sorted(best.items()):
        if r["status"] != "ok":
            row(f"roofline_{arch}_{shape}_{mesh}", 0.0, status=r["status"])
            continue
        roof = r["roofline"]
        step_bound = max(roof["compute_s"], roof["memory_s"], roof["collective_s"])
        row(
            f"roofline_{arch}_{shape}_{mesh}",
            step_bound * 1e6,
            bottleneck=roof["bottleneck"],
            compute_s=round(roof["compute_s"], 5),
            memory_s=round(roof["memory_s"], 5),
            collective_s=round(roof["collective_s"], 5),
            useful_flops_ratio=round(roof["useful_ratio"], 3),
            fits_hbm=r.get("fits_hbm"),
            per_device_gib=round((r.get("per_device_bytes") or 0) / 2**30, 2),
        )


if __name__ == "__main__":
    run()
