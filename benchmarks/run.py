"""Benchmark harness — one module per paper table/figure.

    PYTHONPATH=src python -m benchmarks.run                 # all
    PYTHONPATH=src python -m benchmarks.run overhead        # one
    PYTHONPATH=src python -m benchmarks.run --json OUT.json # + structured dump

Output: ``name,us_per_call,derived`` CSV rows on stdout (see
benchmarks/common.py); ``--json`` additionally writes the same rows as a
JSON array (one object per row, derived pairs as real fields) so perf
trajectories can be tracked by machines, not just eyeballs — CI uploads
it as the ``BENCH_results.json`` artifact.

``--compare [BASELINE]`` diffs this run against the committed baseline
(``repro.obs.baseline``: hard correctness flips + us_per_call growth
beyond a jitter-tolerant ratio) and exits non-zero on regressions;
``--history FILE`` appends one trajectory line per comparison.
"""
from __future__ import annotations

import argparse
import datetime
import json
import os
import platform
import subprocess
import sys

from benchmarks import ckpt_restart, coord_commit, incremental, overhead, roofline
from benchmarks import obs_overhead, proxy_overhead
from benchmarks import strategies_real, strategies_synthetic
from benchmarks import remote_proxy, uvm_paging
from benchmarks.common import ROWS
from repro.obs import metrics as obs_metrics
from repro.obs import trace as obs_trace

ALL = {
    "overhead": overhead.run,                    # Fig. 4
    "proxy_overhead": proxy_overhead.run,        # Fig. 4 (proxy runner) + kill-replay
    "ckpt_restart": ckpt_restart.run,            # Fig. 5
    "strategies_synthetic": strategies_synthetic.run,  # Table 2
    "strategies_real": strategies_real.run,      # Table 3
    "incremental": incremental.run,              # beyond-paper
    "coord_commit": coord_commit.run,            # cluster 2-phase commit
    "uvm_paging": uvm_paging.run,                # UVM oversubscription + paged deltas
    "remote_proxy": remote_proxy.run,            # cross-host transport + reschedule
    "obs_overhead": obs_overhead.run,            # tracing no-op + emit cost
    "roofline": roofline.run,                    # §Roofline emitter
}


def _git_rev() -> str | None:
    """The commit the numbers belong to (None outside a git checkout)."""
    try:
        return subprocess.run(
            ["git", "rev-parse", "HEAD"],
            capture_output=True, text=True, check=True, timeout=10,
        ).stdout.strip()
    except Exception:
        return None


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("names", nargs="*",
                    help=f"benchmarks to run (default: all of {sorted(ALL)})")
    ap.add_argument("--json", metavar="FILE", default=None,
                    help="also write rows as structured JSON to FILE")
    ap.add_argument("--compare", metavar="BASELINE", nargs="?",
                    const="BENCH_results.json", default=None,
                    help="diff this run's rows against a committed "
                         "baseline dump (default BENCH_results.json); "
                         "exit 1 on hard flips or perf regressions")
    ap.add_argument("--compare-ratio", type=float, default=None,
                    help="us_per_call growth factor that counts as a "
                         "regression (default: obs.baseline's 3.0)")
    ap.add_argument("--history", metavar="FILE", default=None,
                    help="append one comparison line to this JSONL "
                         "(the in-repo perf trajectory)")
    args = ap.parse_args(argv)

    unknown = [n for n in args.names if n not in ALL]
    if unknown:
        ap.error(f"unknown benchmark(s) {unknown}; have {sorted(ALL)}")
    names = args.names or list(ALL)
    # gate-with-tracing-on: CRUM_OBS_DIR in the environment turns the full
    # observability fabric on for the session (proxies and fork children
    # inherit it), proving the perf envelope holds while instrumented
    tracer = obs_trace.enable_from_env("bench")
    print("name,us_per_call,derived")
    failures = []
    for n in names:
        try:
            ALL[n]()
        except Exception as e:  # one broken bench must not lose the others' rows
            failures.append(n)
            print(f"[bench] {n} FAILED: {type(e).__name__}: {e}",
                  file=sys.stderr, flush=True)
    doc = {
        "schema": "crum-bench-rows/1",
        "python": platform.python_version(),
        "platform": platform.platform(),
        "git_rev": _git_rev(),
        "timestamp": datetime.datetime.now(datetime.timezone.utc)
            .isoformat(timespec="seconds"),
        "benchmarks": names,
        "failed": failures,
        "rows": ROWS,
        "obs": {
            "enabled": tracer is not None,
            "obs_dir": tracer.obs_dir if tracer else None,
            "run_id": tracer.run_id if tracer else None,
            "counters": obs_metrics.REGISTRY.counters_snapshot(),
        },
    }
    if args.json:
        with open(args.json, "w") as f:
            json.dump(doc, f, indent=2)
        print(f"[bench] wrote {len(ROWS)} rows to {args.json}", flush=True)
    obs_metrics.dump_if_enabled("bench")

    regressed = False
    if args.compare:
        from repro.obs import baseline

        if not os.path.exists(args.compare):
            print(f"[bench] no baseline at {args.compare}; skipping "
                  f"comparison", file=sys.stderr)
        else:
            base_doc, base_rows = baseline.load_rows(args.compare)
            kw = {"ratio": args.compare_ratio} \
                if args.compare_ratio is not None else {}
            findings = baseline.compare(
                ROWS, base_rows,
                # a subset run would read every un-run baseline row as
                # missing — only require full coverage on full runs
                check_missing=not args.names,
                **kw,
            )
            for f in findings:
                print(f"[bench] REGRESSION: {f['message']}",
                      file=sys.stderr, flush=True)
            if args.history:
                baseline.append_history(
                    args.history, doc, findings,
                    baseline_rev=base_doc.get("git_rev"),
                )
            if not findings:
                print(f"[bench] baseline comparison vs {args.compare}: "
                      f"no regressions", flush=True)
            regressed = bool(findings)
    return 1 if failures or regressed else 0


if __name__ == "__main__":
    sys.exit(main())
