"""Benchmark harness — one module per paper table/figure.

    PYTHONPATH=src python -m benchmarks.run            # all
    PYTHONPATH=src python -m benchmarks.run overhead   # one

Output: ``name,us_per_call,derived`` CSV rows (see benchmarks/common.py).
"""
from __future__ import annotations

import sys

from benchmarks import ckpt_restart, coord_commit, incremental, overhead, roofline
from benchmarks import strategies_real, strategies_synthetic

ALL = {
    "overhead": overhead.run,                    # Fig. 4
    "ckpt_restart": ckpt_restart.run,            # Fig. 5
    "strategies_synthetic": strategies_synthetic.run,  # Table 2
    "strategies_real": strategies_real.run,      # Table 3
    "incremental": incremental.run,              # beyond-paper
    "coord_commit": coord_commit.run,            # cluster 2-phase commit
    "roofline": roofline.run,                    # §Roofline emitter
}


def main() -> None:
    names = sys.argv[1:] or list(ALL)
    print("name,us_per_call,derived")
    for n in names:
        ALL[n]()


if __name__ == "__main__":
    main()
