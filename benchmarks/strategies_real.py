"""Table 3 reproduction: strategies on real model state (normalized to naive).

Paper (32 ranks, HPGMG & HYPRE), normalized checkpoint times:
    HPGMG: gzip 0.78x | pgzip 0.60x | LZ4 0.30x | forked 0.025x
    HYPRE: gzip 2x    | pgzip 1x    | LZ4 1x    | forked 0.032x

Here the "real application" is a trained-ish transformer state (params +
Adam moments — realistic float entropy, compresses poorly like HYPRE's).
The pattern to reproduce: forked beats every compression strategy by an
order of magnitude on blocking time.
"""
from __future__ import annotations

import os
import tempfile
import time

import jax
import numpy as np

from benchmarks.common import bench_cfg, make_train_setup, row
from repro.checkpoint import ChunkStore, has_codec
from repro.core import ForkedCheckpointer


def run() -> None:
    cfg = bench_cfg(n_layers=8, d_model=512, vocab=32000)  # ~60M params
    model, step_fn, state, batch = make_train_setup(cfg)
    # take a few steps so moments are non-zero (realistic entropy)
    dstate = state
    for _ in range(3):
        dstate, _ = step_fn(dstate, batch)
    jax.block_until_ready(dstate["params"])
    full = {"device": dstate, "host": {"step": np.int64(3)}}

    results = {}
    fast = "zstd1" if has_codec("zstd1") else "pgzip"
    strategies = [
        ("none", False, "naive", "thread"),
        ("gzip", False, "gzip", "thread"),
        ("pgzip", False, "pgzip", "thread"),
        ("zstd1", False, "zstd1_lz4class", "thread"),
        (fast, True, "forked_ckpting_thread", "thread"),
        (fast, True, "forked_ckpting_fork", "fork"),
    ]
    for codec, forked, label, backend in strategies:
        if not has_codec(codec):
            continue  # optional codec not installed
        if backend == "fork" and not hasattr(os, "fork"):
            continue
        with tempfile.TemporaryDirectory() as d:
            ck = ForkedCheckpointer(
                ChunkStore(d), codec=codec, chunk_bytes=4 << 20,
                incremental=False, digest_on_device=False, backend=backend,
            )
            t0 = time.perf_counter()
            if forked:
                r = ck.save_async(1, full)
                blocking = time.perf_counter() - t0
                r.wait()
            else:
                r = ck.save_sync(1, full)
                blocking = r.blocking_s
            ck.close()
        results[label] = (blocking, r.bytes_written)

    naive = results["naive"][0]
    for label, (blocking, written) in results.items():
        row(
            f"table3_model_state_{label}",
            blocking * 1e6,
            normalized_to_naive=round(blocking / naive, 3),
            ckpt_mb=round(written / 2**20, 1),
            paper_forked="0.025x-0.032x",
        )


if __name__ == "__main__":
    run()
