"""Table 2 reproduction: checkpoint strategies on a synthetic vector job.

Paper (32 GB of floats, 100% vs 50% random):
    naive 45 s | gzip 1296 s | pgzip 86 s | LZ4 62 s | forked 4.1 s
    (50% random: gzip 749 s | pgzip 56 s | LZ4 45 s)

Scaled to container size (256 MB), same axes: the strategy is what the
application *blocks* on. 'forked' = CRUM's two-phase checkpoint: blocking
time is phase 1 only (drain + snapshot); the write happens in background.
``zstd1`` plays LZ4's role (fast low-ratio codec available offline);
``zstd9`` shows the high-ratio/high-CPU corner.
"""
from __future__ import annotations

import os
import time

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.common import row
from repro.checkpoint import ChunkStore, has_codec
from repro.core import ForkedCheckpointer

N_BYTES = 256 << 20  # 256 MB state (paper: 32 GB)


def _vector(kind: str) -> np.ndarray:
    rng = np.random.default_rng(0)
    n = N_BYTES // 4
    if kind == "random":
        return rng.standard_normal(n).astype(np.float32)
    # 50%-random variant: half constant (compressible), half random
    v = np.full(n, 1.2345, np.float32)
    v[: n // 2] = rng.standard_normal(n // 2).astype(np.float32)
    return v


def _bench_strategy(store_root, state, codec: str, forked: bool,
                    backend: str = "thread"):
    store = ChunkStore(store_root)
    ck = ForkedCheckpointer(
        store, codec=codec, chunk_bytes=8 << 20, incremental=False,
        digest_on_device=False, backend=backend,
    )
    t0 = time.perf_counter()
    if forked:
        r = ck.save_async(1, state)
        blocking = time.perf_counter() - t0
        r.wait()
    else:
        r = ck.save_sync(1, state)
        blocking = r.blocking_s
    total = time.perf_counter() - t0
    ck.close()
    return blocking, total, r.bytes_written, r.bytes_snapshot


def run() -> None:
    import tempfile

    for kind in ("random", "half_random"):
        vec = _vector(kind)
        state = {"device": {"v": jnp.asarray(vec)}, "host": {"step": np.int64(1)}}
        jax.block_until_ready(state["device"]["v"])
        naive_blocking = None
        fast = "zstd1" if has_codec("zstd1") else "pgzip"
        strategies = [
            ("none", False, "naive", "thread"),
            ("gzip", False, "gzip", "thread"),
            ("pgzip", False, "pgzip", "thread"),
            ("zstd1", False, "zstd1_lz4class", "thread"),
            ("zstd9", False, "zstd9", "thread"),
            (fast, True, "forked_ckpting_thread", "thread"),
            (fast, True, "forked_ckpting_fork", "fork"),
        ]
        for codec, forked, label, backend in strategies:
            if not has_codec(codec):
                continue  # optional codec not installed
            if backend == "fork" and not hasattr(os, "fork"):
                continue
            with tempfile.TemporaryDirectory() as d:
                blocking, total, written, migrated = _bench_strategy(
                    d, state, codec, forked, backend
                )
            if label == "naive":
                naive_blocking = blocking
            row(
                f"table2_{kind}_{label}",
                blocking * 1e6,
                total_s=round(total, 3),
                blocking_s=round(blocking, 3),
                ckpt_mb=round(written / 2**20, 1),
                migrate_mb=round(migrated / 2**20, 1),
                speedup_vs_naive=round(naive_blocking / max(blocking, 1e-9), 1),
            )


if __name__ == "__main__":
    run()
