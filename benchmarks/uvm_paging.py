"""UVM paging benchmark — oversubscription, eviction policy, paged deltas.

Three questions, mirroring the paper's UVM scenarios (and UVMBench's
oversubscription sweeps):

  1. What does paging cost per step as the working set exceeds the device
     budget? (oversubscription ratio x{1.0, 1.5, 2.0}, both eviction
     policies; ratio 1.0 is the no-oversubscription envelope row)
  2. Do the eviction policies differ where they should? (a hot/cold access
     pattern: LRU keeps the hot set, a cyclic scan is its worst case)
  3. Does a paged checkpoint's delta bill scale with PAGES DIRTIED, not
     state size? (k dirty pages -> chunks_synced/chunks_written ~ k while
     total chunks stay constant)

CSV rows land in benchmarks.common.ROWS like every other table, so
``benchmarks.run --json`` ships them in the CI artifact.
"""
from __future__ import annotations

import tempfile

import numpy as np

from benchmarks.common import row, timeit
from repro.checkpoint.store import ChunkStore
from repro.core.forked import ForkedCheckpointer
from repro.uvm import ManagedSpace

PAGE = 16 << 10          # 16 KiB pages: enough pages to make policies matter
LEAF_ELEMS = 192 * 1024  # 768 KiB f32 per leaf
N_LEAVES = 4             # ~3 MiB total state — CPU-friendly, still ~200 pages


def _state() -> dict:
    return {
        f"layer{i}": (np.arange(LEAF_ELEMS, dtype=np.float32) + i)
        for i in range(N_LEAVES)
    }


def _total_bytes(state: dict) -> int:
    return sum(v.nbytes for v in state.values())


def _managed(state: dict, ratio: float, policy: str) -> ManagedSpace:
    cap = max(PAGE, int(_total_bytes(state) / ratio))
    sp = ManagedSpace(cap, page_bytes=PAGE, eviction_policy=policy)
    sp.register(state)
    return sp


def bench_step_overhead() -> None:
    """Per-step cost vs oversubscription ratio, both policies."""
    state = _state()

    def raw_step() -> None:
        for k in state:
            state[k] = state[k] * 1.0001

    base_s = timeit(raw_step, warmup=1, iters=5)
    row("uvm_step_unmanaged", base_s * 1e6, total_mb=_total_bytes(state) >> 20)

    for policy in ("lru", "clock"):
        for ratio in (1.0, 1.5, 2.0):
            sp = _managed(_state(), ratio, policy)

            def paged_step() -> None:
                dev = sp.read_state()
                for k in dev:
                    dev[k] = dev[k] * 1.0001
                sp.write_state(dev)

            t = timeit(paged_step, warmup=1, iters=5)
            s = sp.stats
            steps = 6  # warmup + iters
            row(
                f"uvm_step_{policy}_x{ratio:g}",
                t * 1e6,
                overhead_pct=round(100.0 * (t - base_s) / base_s, 1),
                faults_per_step=round(s.faults / steps, 1),
                evictions_per_step=round(s.evictions / steps, 1),
                writebacks_per_step=round(s.writebacks / steps, 1),
                h2d_mb=round(s.h2d_bytes / 1e6, 2),
                d2h_mb=round(s.d2h_bytes / 1e6, 2),
            )


def bench_eviction_policy() -> None:
    """Hot/cold reuse: the pattern where policies separate.

    90% of accesses hit a hot 25% of pages; a good policy keeps the hot
    set resident (high hit rate), a bad fit re-faults it continually.
    """
    for policy in ("lru", "clock"):
        # budget = half of the ONE leaf being hammered: the hot quarter
        # fits, the cold tail forces evictions through it
        leaf = {"layer0": _state()["layer0"]}
        sp = ManagedSpace(
            max(PAGE, leaf["layer0"].nbytes // 2),
            page_bytes=PAGE,
            eviction_policy=policy,
        )
        sp.register(leaf)
        path = "layer0"
        n_pages = sp.table(path).n_pages
        hot = max(1, n_pages // 4)
        rng = np.random.default_rng(0)
        ones = np.ones(PAGE // 4, np.float32)

        def access_round() -> None:
            for _ in range(64):
                if rng.random() < 0.9:
                    p = int(rng.integers(0, hot))
                else:
                    p = int(rng.integers(hot, n_pages))
                sp.read_range(path, p * PAGE, min((p + 1) * PAGE, sp.table(path).nbytes))
                if rng.random() < 0.3:
                    sp.write_range(path, p * PAGE, ones[: sp.table(path).page_nbytes(p) // 4])

        t = timeit(access_round, warmup=1, iters=5)
        s = sp.stats
        total_accesses = s.hits + s.faults
        row(
            f"uvm_hotcold_{policy}",
            t * 1e6,
            hit_rate_pct=round(100.0 * s.hits / max(1, total_accesses), 1),
            faults=s.faults,
            evictions=s.evictions,
            writebacks=s.writebacks,
        )


def bench_ckpt_delta() -> None:
    """Paged-checkpoint economics: delta bytes scale with pages dirtied."""
    state = {"device": _state(), "host": {"step": np.int64(0)}}
    sp = ManagedSpace(_total_bytes(state["device"]), page_bytes=PAGE)
    sp.register(state["device"])
    chunk_bytes = 32 << 10
    with tempfile.TemporaryDirectory() as root:
        ck = ForkedCheckpointer(
            ChunkStore(root),
            chunk_bytes=chunk_bytes,
            incremental=True,
            dirty_source=sp.as_dirty_source("device/"),
        )
        state["device"] = sp.peek_state()
        ck.save_async(0, state).wait()  # the full base image
        patch = np.ones(16, np.float32)
        table = sp.table("layer0")
        # distinct pages only: wrapping modulo n_pages would overstate the
        # x-axis of the scaling claim
        ks = sorted({1, 8, min(64, table.n_pages)})
        for step, k_pages in enumerate(ks, start=1):
            for p in range(k_pages):
                sp.write_range("layer0", p * PAGE, patch)
            state["device"] = sp.peek_state()
            state["host"]["step"] = np.int64(step)
            r = ck.save_async(step, state).wait()
            row(
                f"uvm_ckpt_delta_k{k_pages}",
                r.blocking_s * 1e6,
                pages_dirtied=k_pages,
                chunks_synced=r.chunks_synced,
                chunks_clean=r.chunks_clean,
                chunks_written=r.chunks_written,
                chunks_reused=r.chunks_reused,
                bytes_written=r.bytes_written,
                bytes_skipped=r.bytes_skipped,
            )
        ck.close()


def bench_ckpt_blocking_envelope() -> None:
    """x1.0 (no oversubscription) paged checkpointing vs the plain path:
    the managed space must not cost blocking time when it is not paging."""
    plain = {"device": _state(), "host": {"step": np.int64(0)}}
    with tempfile.TemporaryDirectory() as root:
        ck = ForkedCheckpointer(ChunkStore(root), chunk_bytes=32 << 10)
        ck.save_async(0, plain).wait()
        r_plain = ck.save_async(1, plain).wait()  # steady-state: digest gate
        ck.close()

    managed = {"device": _state(), "host": {"step": np.int64(0)}}
    sp = ManagedSpace(_total_bytes(managed["device"]), page_bytes=PAGE)
    sp.register(managed["device"])
    with tempfile.TemporaryDirectory() as root:
        ck = ForkedCheckpointer(
            ChunkStore(root),
            chunk_bytes=32 << 10,
            dirty_source=sp.as_dirty_source("device/"),
        )
        managed["device"] = sp.peek_state()
        ck.save_async(0, managed).wait()
        managed["device"] = sp.peek_state()
        managed["host"]["step"] = np.int64(1)
        r_paged = ck.save_async(1, managed).wait()  # steady-state: page marks
        ck.close()
    row(
        "uvm_ckpt_blocking_x1",
        r_paged.blocking_s * 1e6,
        plain_us=round(r_plain.blocking_s * 1e6, 1),
        paged_chunks_synced=r_paged.chunks_synced,
        plain_chunks_synced=r_plain.chunks_synced,
    )


def bench_fused_digest_boundary() -> None:
    """Fused digests compose with paged dirty marks: a checkpoint boundary
    handed ``device_digests`` (the step's own fused final pass) must beat
    the same boundary running the separate digest scan — digest_us drops
    to 0 while the paged delta (chunks_synced ~ pages dirtied) stays
    identical."""
    from repro.kernels.ops import tree_chunk_digests

    chunk_bytes = 32 << 10
    patch = np.ones(16, np.float32)
    results = {}
    for fused in (False, True):
        state = {"device": _state(), "host": {"step": np.int64(0)}}
        sp = ManagedSpace(_total_bytes(state["device"]), page_bytes=PAGE)
        sp.register(state["device"])
        with tempfile.TemporaryDirectory() as root:
            ck = ForkedCheckpointer(
                ChunkStore(root),
                chunk_bytes=chunk_bytes,
                dirty_source=sp.as_dirty_source("device/"),
            )
            state["device"] = sp.peek_state()
            ck.save_async(0, state).wait()  # base image
            iters = 4
            sync_us = digest_us = 0.0
            chunks = 0
            for step in range(1, iters + 1):
                for p in range(8):
                    sp.write_range("layer0", p * PAGE, patch)
                state["device"] = sp.peek_state()
                state["host"]["step"] = np.int64(step)
                dd = (
                    tree_chunk_digests(state, chunk_bytes) if fused else None
                )
                r = ck.save_async(step, state, device_digests=dd).wait()
                sync_us += r.sync_us
                digest_us += r.digest_us
                chunks += r.chunks_synced
            ck.close()
        results[fused] = (sync_us / iters, digest_us / iters, chunks)
    for fused, (sync_us, digest_us, chunks) in results.items():
        row(
            f"uvm_fused_digest_{'fused' if fused else 'scan'}",
            sync_us,
            digest_us=round(digest_us, 1),
            chunks_synced=chunks,
            boundary_scan_gone=bool(fused and digest_us == 0.0),
        )


def run() -> None:
    bench_step_overhead()
    bench_eviction_policy()
    bench_ckpt_delta()
    bench_ckpt_blocking_envelope()
    bench_fused_digest_boundary()


if __name__ == "__main__":
    run()
