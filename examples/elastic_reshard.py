"""Elastic restart: checkpoint on a (4, 2) mesh, restore onto an (8,) mesh.

The CRUM principle (§3.1): no device state in the image means the same
checkpoint restores onto any topology — here demonstrated with 8 forced
host devices standing in for two different cluster shapes.

    PYTHONPATH=src python examples/elastic_reshard.py
"""
import os

os.environ.setdefault("XLA_FLAGS", "--xla_force_host_platform_device_count=8")

import tempfile

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.launch.mesh import make_mesh, use_mesh

from repro.checkpoint import ChunkStore
from repro.core import ForkedCheckpointer, RestoreManager
from repro.models import ModelConfig, build
from repro.optim import get_optimizer
from repro.runtime.sharding import ShardingRules
from repro.runtime.steps import make_train_step
from repro.utils.tree import flatten_with_paths

cfg = ModelConfig(
    name="elastic-demo", family="dense", num_layers=2, d_model=128,
    vocab_size=512, num_heads=8, num_kv_heads=8, head_dim=16, d_ff=256,
    param_dtype="float32", compute_dtype="float32",
)
model = build(cfg)
opt = get_optimizer("adamw", 1e-3)
rngb = np.random.default_rng(0)
batch = {
    "inputs": jnp.asarray(rngb.integers(0, 512, (8, 32)), jnp.int32),
    "targets": jnp.asarray(rngb.integers(0, 512, (8, 32)), jnp.int32),
}

# ---- phase 1: train 3 steps on mesh A = (data=4, model=2), checkpoint ----
mesh_a = make_mesh((4, 2), ("data", "model"))
with use_mesh(mesh_a):
    rules_a = ShardingRules(cfg=cfg, mesh=mesh_a)
    step_a, sh_a, _ = make_train_step(model, rules_a, opt, donate=False)
    params = model.init(jax.random.key(0))
    state = {"params": params, "opt": opt.init(params),
             "step": jnp.zeros((), jnp.int32)}
    state = jax.device_put(state, sh_a)
    for _ in range(3):
        state, m = step_a(state, batch)
    print(f"[mesh A 4x2] step 3 loss={float(m['loss']):.4f}")
    tmp = tempfile.mkdtemp()
    ck = ForkedCheckpointer(ChunkStore(tmp), chunk_bytes=1 << 18)
    ck.save_async(3, {"device": state}).wait()
    ck.close()

# ---- phase 2: restore onto mesh B = (data=8,) and continue ----
mesh_b = make_mesh((8,), ("data",))
with use_mesh(mesh_b):
    rules_b = ShardingRules(cfg=cfg, mesh=mesh_b)
    step_b, sh_b, _ = make_train_step(model, rules_b, opt, donate=False)
    flat_sh, _ = flatten_with_paths({"device": sh_b})

    restored, manifest = RestoreManager(ChunkStore(tmp)).restore(
        sharding_for=lambda path, shape: flat_sh.get(path), verify=True
    )
    state_b = restored["device"]
    for _ in range(2):
        state_b, m = step_b(state_b, batch)
    print(f"[mesh B 8x1] resumed from step {manifest.step}, "
          f"step 5 loss={float(m['loss']):.4f}")
    print("elastic reshard OK: same checkpoint, different topology")
