"""Quickstart: train a tiny LM with CRUM fault tolerance in ~40 lines.

    PYTHONPATH=src python examples/quickstart.py

Persistence runs on the ``fork`` backend where the OS supports it (the
paper's copy-on-write child), falling back to the in-process writer pool.
"""
import os

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import CheckpointedTrainer, CheckpointPolicy
from repro.data import SyntheticBatches
from repro.models import ModelConfig, build
from repro.optim import get_optimizer

cfg = ModelConfig(
    name="quickstart", family="dense", num_layers=2, d_model=128,
    vocab_size=512, num_heads=4, num_kv_heads=2, head_dim=32, d_ff=256,
    param_dtype="float32", compute_dtype="float32",
)
model = build(cfg)
opt = get_optimizer("adamw", 1e-3)


@jax.jit
def train_step(dstate, batch):
    (loss, _), grads = jax.value_and_grad(model.loss, has_aux=True)(
        dstate["params"], batch
    )
    p, o = opt.update(grads, dstate["opt"], dstate["params"], dstate["step"])
    return {"params": p, "opt": o, "step": dstate["step"] + 1}, {"loss": loss}


backend = "fork" if hasattr(os, "fork") else "thread"
trainer = CheckpointedTrainer(
    train_step,
    store_root="/tmp/quickstart-ckpt",
    policy=CheckpointPolicy(interval_steps=10, keep_last=2),
    chunk_bytes=1 << 20,
    backend=backend,
)


def init_state():
    params = model.init(jax.random.key(0))
    return {
        "device": {"params": params, "opt": opt.init(params),
                   "step": jnp.zeros((), jnp.int32)},
        "host": {"step": np.int64(0),
                 "data": SyntheticBatches(cfg, batch=8, seq_len=64).state()},
    }


state, start = trainer.resume_or(init_state)  # picks up where a crash left off
data = SyntheticBatches.from_state(cfg, batch=8, seq_len=64,
                                   state=state["host"]["data"])
print(f"starting from step {start}")
state = trainer.run(state, data, num_steps=30, start_step=start,
                    on_metrics=lambda s, m: s % 10 == 0 and print(
                        f"step {s}: loss={float(m['loss']):.3f}"))
for r in trainer.finish():
    print(f"checkpoint@{r.step} [{backend}]: blocked {r.blocking_s*1e3:.1f}ms, "
          f"persisted {r.persist_s*1e3:.1f}ms in background "
          f"({r.chunks_reused} chunks reused)")
