"""Serving restart with CRUM lazy restore (the paper's read-fault heuristic).

Saves a model checkpoint, then compares time-to-first-token for an eager
restore (everything up front) vs lazy restore with exponential read-ahead
(parameters materialize as layers touch them).

    PYTHONPATH=src python examples/serve_lazy_restore.py
"""
import tempfile
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.checkpoint import ChunkStore
from repro.core import ForkedCheckpointer, RestoreManager
from repro.models import ModelConfig, build
from repro.utils.tree import flatten_with_paths, unflatten_from_paths

cfg = ModelConfig(
    name="serve-demo", family="dense", num_layers=8, d_model=512,
    vocab_size=32000, num_heads=8, num_kv_heads=8, head_dim=64, d_ff=2048,
    param_dtype="float32", compute_dtype="float32",
)
model = build(cfg)
params = model.init(jax.random.key(0))

with tempfile.TemporaryDirectory() as d:
    ck = ForkedCheckpointer(ChunkStore(d), chunk_bytes=4 << 20)
    ck.save_async(1, {"params": params}).wait()
    ck.close()
    rm = RestoreManager(ChunkStore(d))

    # eager: restore everything, then serve
    t0 = time.perf_counter()
    state, _ = rm.restore()
    p_eager = jax.tree.map(jnp.asarray, state["params"])
    logits, cache = model.prefill(p_eager, {"inputs": jnp.ones((1, 16), jnp.int32)}, 32)
    jax.block_until_ready(logits)
    t_eager = time.perf_counter() - t0

    # lazy: leaves materialize on access; read-ahead window doubles
    t0 = time.perf_counter()
    lazy, _ = rm.restore(lazy=True)
    flat_shape, treedef = flatten_with_paths(
        jax.eval_shape(lambda: model.init(jax.random.key(0)))
    )
    p_lazy = unflatten_from_paths(
        treedef, {k: jnp.asarray(lazy[f"params/{k}"]) for k in flat_shape}
    )
    logits, cache = model.prefill(p_lazy, {"inputs": jnp.ones((1, 16), jnp.int32)}, 32)
    jax.block_until_ready(logits)
    t_lazy = time.perf_counter() - t0
    lazy.close()

print(f"eager restore -> first logits: {t_eager:.3f}s")
print(f"lazy  restore -> first logits: {t_lazy:.3f}s "
      f"(read-ahead overlapped restore with compilation)")
