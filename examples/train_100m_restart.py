"""End-to-end driver: train a ~100M-param LM for a few hundred steps with
forked checkpointing, kill it mid-run (SIGKILL — a real crash), restart,
and verify the restored run continues seamlessly.

    PYTHONPATH=src python examples/train_100m_restart.py [--steps 200]

This is the deliverable-(b) end-to-end driver; expect ~1 s/step on CPU.
"""
import argparse
import os
import signal
import subprocess
import sys
import time

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))

WORKER = """
import jax, jax.numpy as jnp, numpy as np, sys
from repro.core import CheckpointedTrainer, CheckpointPolicy
from repro.data import SyntheticBatches
from repro.models import ModelConfig, build
from repro.optim import get_optimizer, warmup_cosine

STEPS = int(sys.argv[1]); CKPT = sys.argv[2]

# ~100M params: 12L x 768d, 32k vocab (gpt2-small-class)
cfg = ModelConfig(
    name="lm-100m", family="dense", num_layers=12, d_model=768,
    vocab_size=32000, num_heads=12, num_kv_heads=12, head_dim=64, d_ff=3072,
    param_dtype="float32", compute_dtype="float32", remat="none",
)
model = build(cfg)
n = sum(int(np.prod(l.shape)) for l in jax.tree.leaves(
    jax.eval_shape(lambda: model.init(jax.random.key(0)))))
opt = get_optimizer("adamw", warmup_cosine(3e-4, 20, STEPS))

@jax.jit
def train_step(d, batch):
    (l, _), g = jax.value_and_grad(model.loss, has_aux=True)(d["params"], batch)
    p, o = opt.update(g, d["opt"], d["params"], d["step"])
    return {"params": p, "opt": o, "step": d["step"] + 1}, {"loss": l}

trainer = CheckpointedTrainer(
    train_step, store_root=CKPT,
    policy=CheckpointPolicy(interval_steps=25, keep_last=2),
    chunk_bytes=8 << 20,
)

def init_state():
    params = model.init(jax.random.key(0))
    return {"device": {"params": params, "opt": opt.init(params),
                       "step": jnp.zeros((), jnp.int32)},
            "host": {"step": np.int64(0),
                     "data": SyntheticBatches(cfg, batch=4, seq_len=128).state()}}

state, start = trainer.resume_or(init_state)
data = SyntheticBatches.from_state(cfg, batch=4, seq_len=128,
                                   state=state["host"]["data"])
print(f"[worker] {n/1e6:.0f}M params, starting at step {start}", flush=True)
step = start
import time as _t
t0 = _t.time()
for _ in range(STEPS - start):
    batch = jax.tree.map(jnp.asarray, next(data))
    state["device"], m = train_step(state["device"], batch)
    step += 1
    state["host"]["step"] = np.int64(step)
    state["host"]["data"] = data.state()
    if step % 10 == 0:
        print(f"[worker] step {step} loss {float(m['loss']):.4f} "
              f"({(_t.time()-t0)/max(step-start,1):.2f}s/step)", flush=True)
    if trainer.policy.should_checkpoint(step):
        r = trainer.checkpoint_now(step, state)
        print(f"[worker] ckpt@{step} blocked {r.blocking_s*1e3:.0f}ms", flush=True)
trainer.finish()
print(f"[worker] DONE step={step}", flush=True)
"""


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=200)
    ap.add_argument("--kill-after", type=float, default=None,
                    help="seconds before SIGKILL (default: 40%% of run)")
    args = ap.parse_args()

    ckpt = "/tmp/train100m-ckpt"
    subprocess.run(["rm", "-rf", ckpt])
    env = dict(os.environ, PYTHONPATH="src")

    def launch():
        return subprocess.Popen(
            [sys.executable, "-c", WORKER, str(args.steps), ckpt],
            env=env, cwd=REPO, stdout=subprocess.PIPE, text=True, bufsize=1,
        )

    print("=== phase 1: train until killed ===")
    p = launch()
    t0 = time.time()
    kill_after = args.kill_after
    for line in p.stdout:
        print(line, end="")
        if kill_after is None and "s/step" in line:
            per = float(line.rsplit("(", 1)[1].split("s/step")[0])
            kill_after = max(20.0, per * args.steps * 0.4)
            print(f"[driver] will SIGKILL after ~{kill_after:.0f}s")
        if kill_after and time.time() - t0 > kill_after:
            print("[driver] SIGKILL (simulated node failure)")
            p.kill()
            break
    p.wait()

    print("=== phase 2: restart and finish ===")
    p = launch()
    resumed_at = None
    for line in p.stdout:
        print(line, end="")
        if "starting at step" in line:
            resumed_at = int(line.rsplit("step", 1)[1])
    p.wait()
    assert p.returncode == 0, "restarted run failed"
    assert resumed_at and resumed_at > 0, "restart did not resume from a checkpoint"
    print(f"=== OK: resumed from step {resumed_at}, finished {args.steps} steps ===")


if __name__ == "__main__":
    main()
