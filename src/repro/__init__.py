"""repro — CRUM on TPU: checkpoint-restart for unified device/host state in JAX."""
__version__ = "0.1.0"
