"""Chaos observatory: journaled fault injection for soak runs.

Three layers (ROADMAP item 5's harness):

* :mod:`repro.chaos.faults` — cross-process fault *arming*: sentinel
  files under ``$CRUM_CHAOS_DIR`` that in-tree shims (store writer
  quota, heartbeat clock skew) poll. Zero-cost when the env var is
  unset — production code paths stay exactly as fast.
* :mod:`repro.chaos.injectors` — the injection engine: every injection
  is FIRST a versioned journal line (``crum-inject/1`` in
  INJECT_LOG.jsonl, carrying its expected-evidence spec) plus a trace
  instant, and only then the fault itself (SIGKILL, SIGSTOP window,
  torn frame, quota arm, skew arm).
* :mod:`repro.chaos.schedule` + :mod:`repro.chaos.soak` — a seeded,
  reproducible, timer-driven schedule and the driver
  (``python -m repro.chaos.soak``) that runs a cluster under it.

The closed loop is :mod:`repro.obs.soak`: it joins INJECT_LOG.jsonl
against the cluster journal, alerts, metric series and critpath, and
fails the run on any unexplained alert or unevidenced injection.
"""
from repro.chaos.faults import CHAOS_ENV, active, arm, disarm
from repro.chaos.injectors import (
    INJECT_SCHEMA,
    ClusterHandles,
    InjectionEngine,
)
from repro.chaos.schedule import PlannedInjection, build_schedule

__all__ = [
    "CHAOS_ENV",
    "arm",
    "disarm",
    "active",
    "INJECT_SCHEMA",
    "ClusterHandles",
    "InjectionEngine",
    "PlannedInjection",
    "build_schedule",
]
