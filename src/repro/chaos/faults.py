"""Cross-process fault arming — sentinel files under ``$CRUM_CHAOS_DIR``.

The injection engine runs in the launcher process, but several faults
must fire *inside* another process entirely: the disk-full quota lands
in a worker's (or its forked persist child's) store writer, the clock
skew in a worker's heartbeat thread. Those processes are ``spawn``
children that inherit the environment, so the handshake is:

* the soak driver exports ``CRUM_CHAOS_DIR=<run_dir>/chaos``,
* :func:`arm` atomically writes ``<dir>/<kind>.json`` describing the
  fault (target host, parameters, expiry),
* the in-tree shim calls :func:`active` at its natural cadence and
  applies the fault while the sentinel matches.

The shims guard on the environment variable first: when it is unset
(every production run, every tier-1 test) the whole check is one dict
lookup — no stat, no open, no import-time cost.

Sentinels are self-expiring (``until`` wall-clock seconds) so a fault
window closes even if the injecting process dies mid-window.
"""
from __future__ import annotations

import errno
import json
import os
import time

CHAOS_ENV = "CRUM_CHAOS_DIR"

__all__ = ["CHAOS_ENV", "arm", "disarm", "active", "chaos_dir",
           "check_disk_quota"]


def chaos_dir() -> str | None:
    """The armed-fault directory, or None (chaos disabled)."""
    return os.environ.get(CHAOS_ENV) or None


def _path(d: str, kind: str) -> str:
    return os.path.join(d, f"{kind}.json")


def arm(kind: str, *, duration_s: float | None = None,
        directory: str | None = None, **params) -> str:
    """Arm ``kind`` for ``duration_s`` seconds (None = until disarmed).

    Returns the sentinel path. The write is atomic (tmp + rename) so a
    shim polling mid-arm sees either the old fault or the new one,
    never a torn JSON document.
    """
    d = directory or chaos_dir()
    if not d:
        raise RuntimeError(f"{CHAOS_ENV} is not set and no directory given")
    os.makedirs(d, exist_ok=True)
    doc = {
        "kind": kind,
        "armed_at": time.time(),
        "until": (time.time() + duration_s) if duration_s else None,
        "params": params,
    }
    path = _path(d, kind)
    tmp = f"{path}.tmp.{os.getpid()}"
    with open(tmp, "w") as f:
        json.dump(doc, f)
    os.replace(tmp, path)
    return path


def disarm(kind: str, *, directory: str | None = None) -> None:
    d = directory or chaos_dir()
    if not d:
        return
    try:
        os.remove(_path(d, kind))
    except OSError:
        pass


def active(kind: str, *, host: int | None = None,
           directory: str | None = None) -> dict | None:
    """The armed parameters for ``kind``, or None.

    Zero-cost when chaos is disabled (one env lookup). ``host`` filters
    host-targeted faults: a sentinel whose params carry a ``host`` only
    matches that host; a sentinel without one matches everybody.
    """
    d = directory or chaos_dir()
    if not d:
        return None
    try:
        with open(_path(d, kind)) as f:
            doc = json.load(f)
    except (OSError, ValueError):
        return None
    until = doc.get("until")
    if until is not None and time.time() > until:
        return None  # self-expired: the window closed
    params = doc.get("params") or {}
    target = params.get("host")
    if host is not None and target is not None and int(target) != int(host):
        return None
    return params


def check_disk_quota(host: int, would_write: int, written: int) -> None:
    """The store-writer shim: raise ENOSPC when an armed ``disk_full``
    fault's byte quota would be exceeded by this append.

    ``written`` is the bytes this writer already wrote; the quota is
    per-file, which models a filesystem running out of space partway
    through a host's payload stream. One env lookup when disabled.
    """
    if not os.environ.get(CHAOS_ENV):
        return
    params = active("disk_full", host=host)
    if params is None:
        return
    quota = int(params.get("quota_bytes", 0))
    if written + would_write > quota:
        raise OSError(
            errno.ENOSPC,
            f"chaos disk_full: quota {quota}B exceeded "
            f"(written={written}B, appending {would_write}B)",
        )
