"""The injection engine — every fault is a journal line *first*.

The soak verdict (:mod:`repro.obs.soak`) can only demand that "every
alert explains itself" if the injections themselves are evidence:
:class:`InjectionEngine` writes a versioned ``crum-inject/1`` line to
``INJECT_LOG.jsonl`` — kind, target, wall-clock time, and the
*expected-evidence spec* — **before** the fault fires, plus a trace
instant so the injection is visible on the merged timeline. Then, and
only then, the fault itself: a SIGKILL, a SIGSTOP window, a torn control
frame, or an armed sentinel (:mod:`repro.chaos.faults`) for the faults
that must fire inside another process.

The expected-evidence spec is the contract the verdict engine enforces:

``any``
    evidence tokens of which at least one must appear within
    ``window_s`` of the injection (``alert:<kind>`` — an AlertLine;
    ``journal:<what>`` — a cluster-journal fact, see
    :func:`repro.obs.soak.match_token`),
``all``
    tokens that must *all* appear (the disk-full drill demands both the
    abort and the later commit: abort-not-corrupt),
``explains``
    alert kinds this injection accounts for inside its window — any
    alert not claimed by some injection's ``explains`` fails the run.
"""
from __future__ import annotations

import os
import signal
import socket
import struct
import threading
import time
from dataclasses import dataclass, field

from repro.chaos import faults
from repro.obs import trace as obs_trace
from repro.obs.journal import JournalWriter

INJECT_SCHEMA = "crum-inject/1"

#: alert kinds that any disruptive injection may plausibly ripple into:
#: a kill lands mid-round (round_abort), several kills in a row trip
#: abort_rate, and the recovery window shows up as stalls/stragglers
_RIPPLE = ("round_abort", "abort_rate", "stall_ratio", "straggler",
           "heartbeat_skew")

__all__ = ["INJECT_SCHEMA", "ClusterHandles", "InjectionEngine"]


@dataclass
class ClusterHandles:
    """Live handles ``run_cluster(chaos=...)`` passes to the hook."""

    coordinator: object          # repro.coord.coordinator.Coordinator
    supervisor: object           # repro.coord.supervisor.ClusterSupervisor
    daemons: list = field(default_factory=list)  # ProxyHostHandle per host
    root: str = ""


class InjectionEngine:
    """Journal-first fault injection against a live cluster."""

    def __init__(self, handles: ClusterHandles, journal_path: str,
                 *, chaos_dir: str | None = None):
        self.h = handles
        self.journal = JournalWriter(journal_path, schema=INJECT_SCHEMA)
        self.chaos_dir = chaos_dir or faults.chaos_dir()
        self.seq = 0
        self.injected: list[dict] = []
        self._lock = threading.Lock()
        self._timers: list[threading.Timer] = []
        self._stopped_daemons: set[int] = set()
        self._armed: set[str] = set()

    # -- the journal-first discipline --------------------------------------

    def _record(self, kind: str, target: str, *, until: float | None,
                params: dict, expect: dict) -> dict:
        with self._lock:
            self.seq += 1
            seq = self.seq
        doc = dict(kind=kind, target=target, seq=seq, until=until,
                   params=params, expect=expect)
        # the line lands before the fault: a SIGKILLed-to-death run still
        # holds the full intent record for every fault that ever fired
        self.journal.write("inject", **doc)
        tr = obs_trace.get()
        if tr is not None:
            tr.instant(f"chaos.{kind}", target=target, seq=seq)
        self.injected.append(doc)
        return doc

    # -- injectors ---------------------------------------------------------

    def kill_worker(self, host: int, *, window_s: float = 90.0) -> dict:
        """SIGKILL one worker process: the classic death drill."""
        host = int(host)
        doc = self._record(
            "kill_worker", f"host:{host}", until=None,
            params={"host": host},
            expect={
                "window_s": window_s,
                "host": host,
                "any": ["alert:worker_death", "journal:death"],
                "explains": ["worker_death", *_RIPPLE],
            },
        )
        p = self.h.supervisor.procs.get(host)
        if p is not None and p.is_alive():
            try:
                os.kill(p.pid, signal.SIGKILL)
            except OSError:
                pass  # lost the race with a natural death: still evidenced
        return doc

    def kill_proxy_host(self, index: int, *, window_s: float = 120.0) -> dict:
        """SIGKILL one proxy-host daemon: cross-host reschedule drill."""
        d = self.h.daemons[int(index)]
        doc = self._record(
            "kill_proxy_host", f"proxy_host:{d.name}", until=None,
            params={"index": int(index), "name": d.name},
            expect={
                "window_s": window_s,
                "any": ["journal:proxy_host_death",
                        "alert:proxy_host_death",
                        "journal:proxy_placement_rescheduled"],
                "explains": ["proxy_host_death", "worker_death", *_RIPPLE],
            },
        )
        d.kill()
        return doc

    def partition(self, index: int, window_s: float = 20.0,
                  *, evidence_window_s: float = 150.0) -> dict:
        """SIGSTOP a proxy-host daemon for ``window_s`` seconds.

        The network-partition stand-in: the daemon's sockets stay open
        but nothing answers, exactly what a coordinator↔proxy-host
        partition looks like from the worker side. The window must
        outlast the proxy client's op timeout or nothing detects it —
        the *worker* then declares the endpoint dead and is rescheduled
        onto a survivor; SIGCONT arrives too late to matter.
        """
        d = self.h.daemons[int(index)]
        until = time.time() + float(window_s)
        doc = self._record(
            "partition", f"proxy_host:{d.name}", until=until,
            params={"index": int(index), "name": d.name,
                    "window_s": float(window_s)},
            expect={
                "window_s": evidence_window_s,
                "any": ["journal:proxy_host_death",
                        "alert:proxy_host_death",
                        "journal:proxy_placement_rescheduled"],
                "explains": ["proxy_host_death", "worker_death", *_RIPPLE],
            },
        )
        try:
            os.kill(d.pid, signal.SIGSTOP)
            self._stopped_daemons.add(int(index))
        except OSError:
            return doc
        t = threading.Timer(float(window_s), self._heal_partition, (index,))
        t.daemon = True
        t.start()
        self._timers.append(t)
        return doc

    def _heal_partition(self, index: int) -> None:
        d = self.h.daemons[int(index)]
        try:
            os.kill(d.pid, signal.SIGCONT)
        except OSError:
            pass
        self._stopped_daemons.discard(int(index))

    def torn_frame(self, *, window_s: float = 120.0) -> dict:
        """Open a connection to the coordinator, send a valid length
        prefix plus a *partial* payload, and hang up.

        This is the protocol-robustness probe: EOF mid-frame must be
        treated as a dead peer (ignored — the connection never joined),
        not poison the event loop. Its evidence is *liveness*: a round
        commits after the torn frame, and it explains nothing — any
        alert near it must have another cause.
        """
        addr = self.h.coordinator.address
        doc = self._record(
            "torn_frame", "coordinator", until=None, params={},
            expect={
                "window_s": window_s,
                "any": ["journal:round_committed"],
                "explains": [],
            },
        )
        try:
            with socket.create_connection(addr, timeout=5.0) as s:
                # claim 64 payload bytes, deliver 10, vanish: the reader
                # is now mid-frame at EOF
                s.sendall(struct.pack("<I", 64) + b"\x00" * 10)
        except OSError:
            pass
        return doc

    def disk_full(self, host: int, *, quota_bytes: int = 1,
                  duration_s: float = 8.0,
                  window_s: float = 180.0) -> dict:
        """Arm the store-writer quota: the next persist on ``host`` hits
        ENOSPC mid-stream. Abort-not-corrupt: the expected evidence is
        the aborted round **and** a later committed one (after the
        sentinel self-expires, the retry overwrites the partial file).
        """
        host = int(host)
        until = time.time() + float(duration_s)
        doc = self._record(
            "disk_full", f"host:{host}", until=until,
            params={"host": host, "quota_bytes": int(quota_bytes),
                    "duration_s": float(duration_s)},
            expect={
                "window_s": window_s,
                "all": ["journal:round_aborted_persist",
                        "journal:round_committed"],
                "explains": ["round_abort", "abort_rate", "stall_ratio",
                             "straggler"],
            },
        )
        faults.arm("disk_full", duration_s=duration_s,
                   directory=self.chaos_dir, host=host,
                   quota_bytes=int(quota_bytes))
        self._armed.add("disk_full")
        return doc

    def clock_skew(self, host: int, *, skew_s: float = 120.0,
                   duration_s: float = 6.0,
                   window_s: float = 60.0) -> dict:
        """Arm the heartbeat wall-clock skew shim on one worker."""
        host = int(host)
        until = time.time() + float(duration_s)
        doc = self._record(
            "clock_skew", f"host:{host}", until=until,
            params={"host": host, "skew_s": float(skew_s),
                    "duration_s": float(duration_s)},
            expect={
                "window_s": window_s,
                "host": host,
                "any": ["alert:clock_skew"],
                "explains": ["clock_skew"],
            },
        )
        faults.arm("clock_skew", duration_s=duration_s,
                   directory=self.chaos_dir, host=host, skew_s=float(skew_s))
        self._armed.add("clock_skew")
        return doc

    # -- dispatch ----------------------------------------------------------

    KINDS = ("kill_worker", "kill_proxy_host", "partition", "torn_frame",
             "disk_full", "clock_skew")

    def inject(self, kind: str, **params) -> dict:
        if kind not in self.KINDS:
            raise ValueError(f"unknown injection kind {kind!r}")
        return getattr(self, kind)(**params)

    def stop(self) -> None:
        """Cancel pending windows and heal everything still broken."""
        for t in self._timers:
            t.cancel()
        for i in list(self._stopped_daemons):
            self._heal_partition(i)
        for kind in list(self._armed):
            faults.disarm(kind, directory=self.chaos_dir)
            self._armed.discard(kind)
        self.journal.close()
