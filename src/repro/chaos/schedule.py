"""Seeded, reproducible chaos schedules.

A soak run's fault sequence must be *replayable*: the same seed (and the
same cluster shape) produces exactly the same planned injections, at the
same offsets, with the same parameters — so a failing scorecard can be
re-run and the same faults land in the same order. Everything random
flows through one ``random.Random(seed)``; nothing reads the clock.

The builder enforces the structural safety limits the cluster needs to
*converge* under chaos (the soak's whole point is that it does):

* worker SIGKILLs per host stay within the restart budget,
* proxy-host kills always leave a survivor to reschedule onto,
* a SIGSTOPped (partitioned) daemon is never also killed,
* the tail of the run is fault-free so the final rounds commit and the
  bit-identical convergence check has something to check.
"""
from __future__ import annotations

import random
from dataclasses import dataclass, field

__all__ = ["PlannedInjection", "build_schedule"]

#: kinds that need no proxy-host daemons
_WORKER_KINDS = ("kill_worker", "torn_frame", "disk_full", "clock_skew")
_PROXY_KINDS = ("kill_proxy_host", "partition")


@dataclass(frozen=True)
class PlannedInjection:
    offset_s: float          # seconds after the cluster came up
    kind: str
    params: dict = field(default_factory=dict)

    def as_dict(self) -> dict:
        return {"offset_s": self.offset_s, "kind": self.kind,
                "params": dict(self.params)}


def build_schedule(
    *,
    seed: int,
    duration_s: float,
    n_hosts: int,
    n_proxy_hosts: int = 0,
    kinds: tuple | list | None = None,
    warmup_s: float = 8.0,
    spacing_s: float = 7.0,
    tail_s: float | None = None,
    max_worker_kills_per_host: int = 1,
    partition_window_s: float = 20.0,
) -> list[PlannedInjection]:
    """Plan a deterministic injection sequence for one soak run.

    ``kinds`` restricts the menu (default: everything the cluster shape
    supports — proxy-host faults need >= 2 daemons so a survivor
    exists). Offsets land on a jittered ``spacing_s`` grid between
    ``warmup_s`` and ``duration_s - tail_s``.
    """
    rng = random.Random(int(seed))
    duration_s = float(duration_s)
    if tail_s is None:
        # fault-free convergence window: a third of the run, at least
        # one full round of recovery
        tail_s = max(20.0, duration_s / 3.0)
    menu = list(kinds) if kinds else list(_WORKER_KINDS) + (
        list(_PROXY_KINDS) if n_proxy_hosts >= 2 else []
    )
    for k in menu:
        if k in _PROXY_KINDS and n_proxy_hosts < 2:
            raise ValueError(
                f"{k!r} needs >= 2 proxy hosts (a survivor to "
                f"reschedule onto); got {n_proxy_hosts}"
            )
    worker_kills = {h: 0 for h in range(n_hosts)}
    ph_killed: set[int] = set()
    plan: list[PlannedInjection] = []
    t = float(warmup_s)
    while t < duration_s - tail_s:
        offset = round(t + rng.uniform(0.0, spacing_s / 2.0), 3)
        for _ in range(8):  # bounded retries against exhausted caps
            kind = rng.choice(menu)
            if kind == "kill_worker":
                host = rng.randrange(n_hosts)
                if worker_kills[host] >= max_worker_kills_per_host:
                    continue
                worker_kills[host] += 1
                plan.append(PlannedInjection(offset, kind, {"host": host}))
            elif kind == "kill_proxy_host":
                alive = [i for i in range(n_proxy_hosts)
                         if i not in ph_killed]
                if len(alive) < 2:  # always leave a survivor
                    continue
                idx = rng.choice(alive)
                ph_killed.add(idx)
                plan.append(PlannedInjection(offset, kind, {"index": idx}))
            elif kind == "partition":
                alive = [i for i in range(n_proxy_hosts)
                         if i not in ph_killed]
                if len(alive) < 2:
                    continue
                idx = rng.choice(alive)
                plan.append(PlannedInjection(
                    offset, kind,
                    {"index": idx, "window_s": float(partition_window_s)},
                ))
            elif kind == "disk_full":
                host = rng.randrange(n_hosts)
                plan.append(PlannedInjection(
                    offset, kind,
                    {"host": host, "quota_bytes": 1,
                     "duration_s": round(rng.uniform(4.0, 8.0), 3)},
                ))
            elif kind == "clock_skew":
                host = rng.randrange(n_hosts)
                plan.append(PlannedInjection(
                    offset, kind,
                    {"host": host,
                     "skew_s": round(rng.uniform(60.0, 300.0), 3),
                     "duration_s": round(rng.uniform(4.0, 8.0), 3)},
                ))
            else:  # torn_frame
                plan.append(PlannedInjection(offset, kind, {}))
            break
        t += spacing_s
    return plan
