"""Soak driver — a model-zoo cluster under a seeded chaos schedule.

``python -m repro.chaos.soak --run-dir DIR --seconds 60 --hosts 2
--proxy-hosts 2`` brings up the full stack (coordinator + supervised
workers + proxy-host daemons, oversubscribed via ``--device-capacity``)
with the live telemetry plane, the SLO watchdog (recording mode:
``abort_on_critical`` off — a soak *collects* evidence, it does not
flinch) and leak-trend sampling all running, then fires a
:func:`repro.chaos.schedule.build_schedule` plan at it from a timer
thread while the run runs.

Everything the verdict needs lands in the run dir:

========================  ====================================================
``ckpt/``                 cluster root (CLUSTER_LOG.jsonl, checkpoints)
``obs/``                  trace shards + ``live_metrics.json``
``chaos/``                armed-fault sentinels (``$CRUM_CHAOS_DIR``)
``INJECT_LOG.jsonl``      the injection journal (``crum-inject/1``)
``soak_run.json``         driver summary: config, seed, plan, convergence
========================  ====================================================

The run is *judged* separately: ``python -m repro.obs.soak DIR --check``.
"""
from __future__ import annotations

import argparse
import json
import os
import sys
import threading
import time

from repro.chaos.faults import CHAOS_ENV
from repro.chaos.injectors import InjectionEngine
from repro.chaos.schedule import build_schedule

SOAK_RUN_SCHEMA = "crum-soak-run/1"

__all__ = ["SOAK_RUN_SCHEMA", "main"]


def _chaos_hook(run_dir: str, chaos_dir: str, plan):
    """The ``run_cluster(chaos=...)`` callable: schedule thread + engine."""

    def hook(handles):
        eng = InjectionEngine(
            handles,
            os.path.join(run_dir, "INJECT_LOG.jsonl"),
            chaos_dir=chaos_dir,
        )
        stop = threading.Event()

        def runner() -> None:
            t0 = time.monotonic()
            for pi in plan:
                delay = pi.offset_s - (time.monotonic() - t0)
                if delay > 0 and stop.wait(delay):
                    return
                if handles.coordinator.done.is_set():
                    return
                try:
                    eng.inject(pi.kind, **pi.params)
                except Exception as e:  # an injector must not kill the run
                    print(f"soak: injection {pi.kind} failed: {e}",
                          file=sys.stderr)

        th = threading.Thread(target=runner, name="chaos-schedule",
                              daemon=True)
        th.start()

        class _Ctl:
            def stop(self) -> None:
                stop.set()
                th.join(timeout=10)
                eng.stop()

        return _Ctl()

    return hook


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(
        prog="python -m repro.chaos.soak", description=__doc__,
        formatter_class=argparse.RawDescriptionHelpFormatter,
    )
    ap.add_argument("--run-dir", required=True)
    ap.add_argument("--seconds", type=float, default=60.0,
                    help="target soak duration (the step count is derived;"
                         " recovery work stretches the actual run)")
    ap.add_argument("--hosts", type=int, default=2)
    ap.add_argument("--proxy-hosts", type=int, default=0,
                    help=">= 2 enables the cross-host fault menu")
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--kinds", default=None,
                    help="comma list restricting the injection menu")
    ap.add_argument("--loop", default="numpy",
                    help='"numpy", "jax", or "arch:<name>" for a '
                         "repro.configs model-zoo architecture (smoke "
                         "shape)")
    ap.add_argument("--device-capacity", default=None,
                    help='proxy UVM budget: bytes or "50%%" of state '
                         "(oversubscription x2); needs a proxy runner")
    ap.add_argument("--backend", default="thread",
                    choices=("thread", "fork"))
    ap.add_argument("--ckpt-every", type=int, default=4)
    ap.add_argument("--step-time", type=float, default=0.15)
    ap.add_argument("--steps", type=int, default=None,
                    help="override the derived total step count")
    ap.add_argument("--width", type=int, default=64)
    ap.add_argument("--max-clock-skew-s", type=float, default=30.0)
    ap.add_argument("--persist-timeout-s", type=float, default=10.0,
                    help="also the proxy op timeout: bounds how long a "
                         "partitioned proxy host goes undetected")
    args = ap.parse_args(argv)

    from repro.coord.supervisor import run_cluster
    from repro.obs.watch import WatchConfig

    run_dir = os.path.abspath(args.run_dir)
    chaos_dir = os.path.join(run_dir, "chaos")
    os.makedirs(chaos_dir, exist_ok=True)
    # exported before any spawn: every worker (and its persist children)
    # inherits the chaos dir, so armed sentinels reach their shims
    os.environ[CHAOS_ENV] = chaos_dir

    kinds = tuple(k for k in (args.kinds or "").split(",") if k) or None
    plan = build_schedule(
        seed=args.seed, duration_s=args.seconds, n_hosts=args.hosts,
        n_proxy_hosts=args.proxy_hosts, kinds=kinds,
    )
    worker_kills: dict[int, int] = {}
    for pi in plan:
        if pi.kind == "kill_worker":
            h = pi.params["host"]
            worker_kills[h] = worker_kills.get(h, 0) + 1
    print(f"soak: {len(plan)} planned injections over ~{args.seconds:.0f}s "
          f"(seed {args.seed}): "
          + ", ".join(f"{p.offset_s:.0f}s {p.kind}" for p in plan))

    total_steps = args.steps or max(
        args.ckpt_every * 5, int(args.seconds * 0.6 / args.step_time)
    )
    proxied = args.proxy_hosts > 0 or args.device_capacity is not None
    t0 = time.time()
    report = run_cluster(
        root=os.path.join(run_dir, "ckpt"),
        n_hosts=args.hosts,
        total_steps=total_steps,
        ckpt_every=args.ckpt_every,
        backend=args.backend,
        loop=args.loop,
        device_runner="proxy" if proxied else "inline",
        width=args.width,
        step_time_s=args.step_time,
        proxy_hosts=args.proxy_hosts,
        deadline_s=max(300.0, args.seconds * 4),
        max_restarts=max(worker_kills.values(), default=0) + 2,
        persist_timeout_s=args.persist_timeout_s,
        device_capacity=args.device_capacity,
        obs_dir=os.path.join(run_dir, "obs"),
        watch_cfg=WatchConfig(max_clock_skew_s=args.max_clock_skew_s),
        abort_on_critical=False,  # recording mode: judge later, fully
        chaos=_chaos_hook(run_dir, chaos_dir, plan),
    )
    wall_s = time.time() - t0

    summary = {
        "schema": SOAK_RUN_SCHEMA,
        "seed": args.seed,
        "seconds": args.seconds,
        "wall_s": round(wall_s, 3),
        "hosts": args.hosts,
        "proxy_hosts": args.proxy_hosts,
        "loop": args.loop,
        "device_capacity": args.device_capacity,
        "total_steps": total_steps,
        "plan": [p.as_dict() for p in plan],
        "lockstep": report.lockstep(),
        "latest_committed": report.latest_committed,
        "final_digests": {str(h): d for h, d in
                          report.final_digests.items()},
        "restarts": {str(h): c for h, c in report.restarts.items()},
        "rounds_committed": len(report.committed),
        "rounds_aborted": len(report.aborted),
        "alerts": len(report.alerts),
        "proxy_placements": [[w, n] for w, n in report.proxy_placements],
    }
    path = os.path.join(run_dir, "soak_run.json")
    with open(path, "w") as f:
        json.dump(summary, f, indent=2)
    print(f"soak: done in {wall_s:.1f}s — "
          f"{summary['rounds_committed']} committed / "
          f"{summary['rounds_aborted']} aborted rounds, "
          f"{summary['alerts']} alerts, lockstep={summary['lockstep']}")
    print(f"soak: wrote {path}; judge with: "
          f"python -m repro.obs.soak {run_dir} --check")
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
