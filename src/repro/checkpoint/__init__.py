from repro.checkpoint.codecs import (
    Codec,
    DEFAULT_CODEC,
    get_codec,
    has_codec,
    list_codecs,
    register_codec,
    unregister_codec,
)
from repro.checkpoint.chunking import (
    ChunkKey,
    chunk_digest_np,
    iter_chunks,
    join_chunks,
    split_into_chunks,
    DEFAULT_CHUNK_BYTES,
)
from repro.checkpoint.manifest import (
    ChunkRecord,
    LeafRecord,
    Manifest,
    atomic_write,
    commit_manifest,
    latest_committed_step,
    load_manifest,
)
from repro.checkpoint.store import ChunkStore
from repro.checkpoint.sharded import (
    restore_pytree,
    restore_pytree_elastic,
    save_pytree,
)

__all__ = [
    "Codec",
    "DEFAULT_CODEC",
    "get_codec",
    "has_codec",
    "list_codecs",
    "register_codec",
    "unregister_codec",
    "ChunkKey",
    "chunk_digest_np",
    "iter_chunks",
    "join_chunks",
    "split_into_chunks",
    "DEFAULT_CHUNK_BYTES",
    "ChunkRecord",
    "LeafRecord",
    "Manifest",
    "atomic_write",
    "commit_manifest",
    "latest_committed_step",
    "load_manifest",
    "ChunkStore",
    "save_pytree",
    "restore_pytree",
    "restore_pytree_elastic",
]
