"""Chunking + digests — the TPU analogue of CRUM's UVM pages.

A leaf array's bytes are split into fixed-size chunks addressed by
``ChunkKey(path, index)`` with a global byte range. Chunks are the unit of

  - dirty tracking (digest diff — Algorithm 1's page-granularity, scaled to
    DMA-friendly sizes),
  - parallel compression (the pgzip / writer-pool unit),
  - sharded + elastic restore (chunks intersect shard index ranges).

The digest is a 64-bit FNV-1a-style rolling hash computed with numpy (host
side) or the ``chunk_digest`` Pallas kernel (device side); both produce the
same value for the same bytes, so device-computed digests can be compared
against manifest digests written by the host.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Iterator

import numpy as np

DEFAULT_CHUNK_BYTES = 4 << 20  # 4 MiB — bulk-DMA friendly; ~1000 pages worth

# Digest constants shared with kernels/chunk_digest.py: a blocked sum/xor
# mix over u32 words. Chosen to be exactly representable in 32-bit lanes on
# the VPU (no 64-bit multiply on TPU vector units).
_DIGEST_PRIME = np.uint32(16777619)
_DIGEST_SEED = np.uint32(2166136261)


@dataclass(frozen=True, order=True)
class ChunkKey:
    path: str
    index: int

    def render(self) -> str:
        return f"{self.path}#{self.index}"


def _as_u32_words(buf: np.ndarray) -> np.ndarray:
    """View arbitrary bytes as u32 words, zero-padding the tail."""
    b = np.ascontiguousarray(buf).reshape(-1).view(np.uint8)
    pad = (-len(b)) % 4
    if pad:
        b = np.concatenate([b, np.zeros(pad, np.uint8)])
    return b.view(np.uint32)


def chunk_digest_np(data: bytes | np.ndarray) -> int:
    """Reference digest for one chunk (matches the chunk_digest kernel).

    Two 32-bit mixes over u32 words, both expressible with wrapping u32
    adds/muls/xors (VPU-lane friendly; no 64-bit arithmetic on device):

        lo = sum_i  (w_i XOR (i * PRIME))          (wrapping add, i from 1)
        hi = xor_i  (w_i * ((i << 1) | 1))         (wrapping mul by odd)

    Zero-padding can be masked out exactly on device (a padded word with
    w=0 at masked position contributes nothing once masked), so host bytes
    and device padded-tile computations agree bit-for-bit. Order-sensitive
    (catches permutations) and cheap enough to run every sync.
    """
    if isinstance(data, (bytes, bytearray, memoryview)):
        arr = np.frombuffer(bytes(data), np.uint8)
    else:
        arr = np.asarray(data)
    words = _as_u32_words(arr)
    if words.size == 0:
        return 0
    idx = np.arange(1, words.size + 1, dtype=np.uint32)
    with np.errstate(over="ignore"):
        lo = np.uint64(
            int((words ^ (idx * _DIGEST_PRIME)).sum(dtype=np.uint64)) & 0xFFFFFFFF
        )
        hi = np.uint64(
            int(np.bitwise_xor.reduce(words * ((idx << np.uint32(1)) | np.uint32(1))))
            ^ int(_DIGEST_SEED)
        )
    return int((hi << np.uint64(32)) | lo)


def split_into_chunks(
    path: str, arr: np.ndarray, chunk_bytes: int = DEFAULT_CHUNK_BYTES
) -> list[tuple["ChunkKey", bytes]]:
    """Split a host array into (key, raw_bytes) chunks."""
    return list(iter_chunks(path, arr, chunk_bytes))


def iter_chunks(
    path: str, arr: np.ndarray, chunk_bytes: int = DEFAULT_CHUNK_BYTES
) -> Iterator[tuple["ChunkKey", bytes]]:
    raw = np.ascontiguousarray(arr).reshape(-1).view(np.uint8)
    n = max(1, int(np.ceil(raw.nbytes / chunk_bytes))) if raw.nbytes else 1
    for i in range(n):
        lo = i * chunk_bytes
        hi = min(raw.nbytes, lo + chunk_bytes)
        yield ChunkKey(path, i), raw[lo:hi].tobytes()


def num_chunks(nbytes: int, chunk_bytes: int = DEFAULT_CHUNK_BYTES) -> int:
    return max(1, int(np.ceil(nbytes / chunk_bytes))) if nbytes else 1


def join_chunks(
    chunks: list[bytes], shape: tuple[int, ...], dtype: np.dtype
) -> np.ndarray:
    """Reassemble raw chunk bytes into an array of the given shape/dtype."""
    buf = b"".join(chunks)
    expected = int(np.prod(shape, dtype=np.int64)) * np.dtype(dtype).itemsize
    if len(buf) != expected:
        raise ValueError(
            f"chunk bytes {len(buf)} != expected {expected} for {shape} {dtype}"
        )
    return np.frombuffer(buf, dtype=dtype).reshape(shape).copy()
