"""Checkpoint compression codecs.

Reproduces the paper's Table 2/3 strategy axis:

  - ``none``   — the naive strategy (raw bytes straight to disk).
  - ``gzip``   — zlib level 1 (the paper uses gzip -1).
  - ``pgzip``  — the same zlib stream, but chunk-parallel across a thread
                 pool (paper: "parallel gzip ... as many threads as cores").
  - ``zstd1``  — zstandard level 1: the LZ4-class fast codec available in
                 this environment (paper uses LZ4; zstd-1 occupies the same
                 design point: ~GB/s compression, modest ratio). Optional:
                 registered only when the ``zstandard`` package is installed.
  - ``zstd9``  — high-ratio point for the ratio/CPU trade-off curve
                 (optional, same dependency).

All codecs release the GIL inside compress/decompress, which is what makes
the forked-checkpointing writer pool overlap with the train loop.

``zstandard`` is an *optional* dependency (the ``[zstd]`` extra): when it is
absent the zstd codecs are simply not registered, and asking for one raises
an error naming the missing package instead of breaking import of this
module (and with it every consumer of the checkpoint substrate).
"""
from __future__ import annotations

import concurrent.futures as cf
import os
import struct
import zlib
from dataclasses import dataclass
from typing import Callable

try:
    import zstandard
except ImportError:  # optional dependency — zstd codecs not registered
    zstandard = None


@dataclass(frozen=True)
class Codec:
    name: str
    compress: Callable[[bytes], bytes]
    decompress: Callable[[bytes], bytes]


def _zstd_c(level: int) -> Callable[[bytes], bytes]:
    def fn(data: bytes) -> bytes:
        return zstandard.ZstdCompressor(level=level).compress(data)

    return fn


def _zstd_d(data: bytes) -> bytes:
    return zstandard.ZstdDecompressor().decompress(data)


_PGZIP_BLOCK = 1 << 20  # 1 MiB sub-blocks, one per worker task
_PGZIP_MAGIC = b"PGZ1"


def _pgzip_compress(data: bytes) -> bytes:
    """Chunk-parallel zlib: independent sub-blocks compressed concurrently.

    Framed as: MAGIC | n_blocks u32 | (raw_len u32, comp_len u32)* | blocks.
    """
    blocks = [data[i : i + _PGZIP_BLOCK] for i in range(0, len(data), _PGZIP_BLOCK)] or [b""]
    workers = min(len(blocks), os.cpu_count() or 1)
    if workers > 1:
        with cf.ThreadPoolExecutor(max_workers=workers) as pool:
            comp = list(pool.map(lambda b: zlib.compress(b, 1), blocks))
    else:
        comp = [zlib.compress(b, 1) for b in blocks]
    header = [_PGZIP_MAGIC, struct.pack("<I", len(blocks))]
    for raw, c in zip(blocks, comp):
        header.append(struct.pack("<II", len(raw), len(c)))
    return b"".join(header) + b"".join(comp)


def _pgzip_decompress(data: bytes) -> bytes:
    if data[:4] != _PGZIP_MAGIC:
        raise ValueError("not a pgzip frame")
    (n,) = struct.unpack_from("<I", data, 4)
    offs = 8
    sizes = []
    for _ in range(n):
        raw_len, comp_len = struct.unpack_from("<II", data, offs)
        sizes.append((raw_len, comp_len))
        offs += 8
    out, pos = [], offs
    blobs = []
    for raw_len, comp_len in sizes:
        blobs.append(data[pos : pos + comp_len])
        pos += comp_len
    workers = min(len(blobs), os.cpu_count() or 1)
    if workers > 1:
        with cf.ThreadPoolExecutor(max_workers=workers) as pool:
            out = list(pool.map(zlib.decompress, blobs))
    else:
        out = [zlib.decompress(b) for b in blobs]
    return b"".join(out)


DEFAULT_CODEC = "pgzip"  # fastest codec with no optional dependency

_CODECS: dict[str, Codec] = {
    "none": Codec("none", lambda b: b, lambda b: b),
    "gzip": Codec("gzip", lambda b: zlib.compress(b, 1), zlib.decompress),
    "pgzip": Codec("pgzip", _pgzip_compress, _pgzip_decompress),
}

# codec name -> (pip package, extra) for codecs whose dependency is missing
_MISSING: dict[str, tuple[str, str]] = {}

if zstandard is not None:
    _CODECS["zstd1"] = Codec("zstd1", _zstd_c(1), _zstd_d)
    _CODECS["zstd9"] = Codec("zstd9", _zstd_c(9), _zstd_d)
else:
    _MISSING["zstd1"] = ("zstandard", "zstd")
    _MISSING["zstd9"] = ("zstandard", "zstd")


def register_codec(codec: Codec, *, replace: bool = False) -> None:
    """Register a codec under ``codec.name`` (plugin point; used by tests)."""
    if codec.name in _CODECS and not replace:
        raise ValueError(f"codec {codec.name!r} already registered")
    _CODECS[codec.name] = codec


def unregister_codec(name: str) -> None:
    """Remove a codec registered via :func:`register_codec`."""
    _CODECS.pop(name, None)


def get_codec(name: str) -> Codec:
    try:
        return _CODECS[name]
    except KeyError:
        if name in _MISSING:
            pkg, extra = _MISSING[name]
            raise ModuleNotFoundError(
                f"codec {name!r} requires the optional dependency {pkg!r} "
                f"which is not installed (pip install {pkg!r}, or the "
                f"[{extra}] extra of this package)"
            ) from None
        raise KeyError(f"unknown codec {name!r}; have {sorted(_CODECS)}") from None


def has_codec(name: str) -> bool:
    return name in _CODECS


def list_codecs() -> list[str]:
    return sorted(_CODECS)
