"""Checkpoint manifests + atomic commit protocol.

Layout of a checkpoint directory (one per step)::

    <root>/step_00000042/
        data-h0000.bin            per-host chunk payload files
        hostmeta-h0000.msgpack    per-host leaf/chunk records
        MANIFEST.msgpack          merged manifest (written by coordinator)
        COMMIT                    commit marker (last thing written)

A checkpoint exists iff COMMIT exists; everything before that is invisible
to restore. This mirrors CRUM's requirement that a crash mid-checkpoint must
leave the previous image restorable (the forked child writing the image can
die without corrupting anything).

The manifest is *topology-independent*: leaves are keyed by path and chunk
data is keyed by global index ranges (shard domains), so restore can target
any mesh — the analogue of CRUM's "checkpoint on one CUDA version, restart
on another".

Delta (incremental) manifests: a chunk record may carry a ``file`` that
lives in an earlier step's directory. Restore chases these references, so an
incremental checkpoint only persists digest-dirty chunks.
"""
from __future__ import annotations

import os
import re
from dataclasses import dataclass, field, asdict
from typing import Any

import msgpack
import numpy as np

FORMAT_VERSION = 2
_STEP_RE = re.compile(r"^step_(\d{8})$")


@dataclass
class ChunkRecord:
    index: int          # chunk ordinal within its shard
    raw_len: int        # uncompressed byte length
    digest: int         # u64 content digest (chunking.chunk_digest_np)
    codec: str          # codec name used on disk
    file: str           # path relative to checkpoint ROOT (enables deltas)
    file_offset: int
    comp_len: int


@dataclass
class ShardRecord:
    start: list[int]    # global index-range start (per dim)
    stop: list[int]     # global index-range stop (per dim)
    chunks: list[ChunkRecord] = field(default_factory=list)


@dataclass
class LeafRecord:
    path: str
    shape: list[int]
    dtype: str
    shards: list[ShardRecord] = field(default_factory=list)


@dataclass
class Manifest:
    step: int
    format_version: int = FORMAT_VERSION
    leaves: dict[str, LeafRecord] = field(default_factory=dict)
    skeleton: Any = None       # nested dict/list/tuple structure w/ leaf paths
    meta: dict = field(default_factory=dict)  # free-form (mesh, config, ...)

    # -- (de)serialization -------------------------------------------------
    def to_bytes(self) -> bytes:
        payload = {
            "step": self.step,
            "format_version": self.format_version,
            "leaves": {k: asdict(v) for k, v in self.leaves.items()},
            "skeleton": _encode_skeleton(self.skeleton),
            "meta": self.meta,
        }
        return msgpack.packb(payload, use_bin_type=True)

    @staticmethod
    def from_bytes(data: bytes) -> "Manifest":
        p = msgpack.unpackb(data, raw=False, strict_map_key=False)
        if p["format_version"] > FORMAT_VERSION:
            raise ValueError(
                f"manifest format {p['format_version']} newer than supported "
                f"{FORMAT_VERSION}"
            )
        leaves = {}
        for k, lv in p["leaves"].items():
            shards = [
                ShardRecord(
                    start=s["start"],
                    stop=s["stop"],
                    chunks=[ChunkRecord(**c) for c in s["chunks"]],
                )
                for s in lv["shards"]
            ]
            leaves[k] = LeafRecord(lv["path"], lv["shape"], lv["dtype"], shards)
        return Manifest(
            step=p["step"],
            format_version=p["format_version"],
            leaves=leaves,
            skeleton=_decode_skeleton(p["skeleton"]),
            meta=p.get("meta", {}),
        )

    def total_bytes(self, *, compressed: bool = True) -> int:
        return sum(
            (c.comp_len if compressed else c.raw_len)
            for lv in self.leaves.values()
            for s in lv.shards
            for c in s.chunks
        )


# -- tree skeleton -----------------------------------------------------------
# Checkpointable state must be a pytree of dict / list / tuple containers
# with array leaves. The skeleton encodes the container structure with leaf
# paths at the leaf positions, so restore is pickle-free and version-robust.

def _encode_skeleton(node: Any) -> Any:
    if isinstance(node, dict):
        return {"t": "d", "k": list(node.keys()),
                "v": [_encode_skeleton(v) for v in node.values()]}
    if isinstance(node, tuple):
        return {"t": "t", "v": [_encode_skeleton(v) for v in node]}
    if isinstance(node, list):
        return {"t": "l", "v": [_encode_skeleton(v) for v in node]}
    if node is None:
        return {"t": "n"}
    if isinstance(node, str):  # leaf path reference
        return {"t": "p", "v": node}
    raise TypeError(f"unsupported skeleton node {type(node)}")


def _decode_skeleton(enc: Any) -> Any:
    if enc is None:
        return None
    t = enc["t"]
    if t == "d":
        return {k: _decode_skeleton(v) for k, v in zip(enc["k"], enc["v"])}
    if t == "t":
        return tuple(_decode_skeleton(v) for v in enc["v"])
    if t == "l":
        return [_decode_skeleton(v) for v in enc["v"]]
    if t == "n":
        return None
    if t == "p":
        return enc["v"]
    raise TypeError(f"bad skeleton tag {t}")


def build_skeleton(tree: Any, prefix: str = "") -> Any:
    """Replace every leaf of a dict/list/tuple pytree with its path string."""
    if isinstance(tree, dict):
        return {k: build_skeleton(v, f"{prefix}{k}/") for k, v in tree.items()}
    if isinstance(tree, (list, tuple)):
        seq = [build_skeleton(v, f"{prefix}{i}/") for i, v in enumerate(tree)]
        return tuple(seq) if isinstance(tree, tuple) else seq
    if tree is None:
        return None
    return prefix[:-1]  # strip trailing '/'


def skeleton_fill(skeleton: Any, leaves: dict[str, Any]) -> Any:
    """Rebuild the original pytree from a skeleton + {path: leaf} map."""
    if isinstance(skeleton, dict):
        return {k: skeleton_fill(v, leaves) for k, v in skeleton.items()}
    if isinstance(skeleton, tuple):
        return tuple(skeleton_fill(v, leaves) for v in skeleton)
    if isinstance(skeleton, list):
        return [skeleton_fill(v, leaves) for v in skeleton]
    if skeleton is None:
        return None
    return leaves[skeleton]


def skeleton_paths(skeleton: Any) -> list[str]:
    out: list[str] = []

    def rec(node: Any) -> None:
        if isinstance(node, dict):
            for v in node.values():
                rec(v)
        elif isinstance(node, (list, tuple)):
            for v in node:
                rec(v)
        elif isinstance(node, str):
            out.append(node)

    rec(skeleton)
    return out


# -- atomic filesystem protocol ----------------------------------------------

def atomic_write(path: str, data: bytes) -> None:
    """tmp + fsync + rename: the write is all-or-nothing."""
    tmp = f"{path}.tmp.{os.getpid()}"
    with open(tmp, "wb") as f:
        f.write(data)
        f.flush()
        os.fsync(f.fileno())
    os.replace(tmp, path)


def fsync_dir(path: str) -> None:
    """Flush a directory's entry table to stable storage.

    ``atomic_write`` fsyncs file *contents*; the rename that makes the file
    visible lives in the directory, which has its own cache. Without this, a
    power failure after commit can leave a COMMIT marker whose payload files
    were never durably linked — exactly the torn image the commit protocol
    exists to prevent.
    """
    try:
        fd = os.open(path, os.O_RDONLY | getattr(os, "O_DIRECTORY", 0))
    except OSError:  # platform without directory fds (or dir just GC'd)
        return
    try:
        os.fsync(fd)
    except OSError:  # some filesystems refuse fsync on directories
        pass
    finally:
        os.close(fd)


def step_dir(root: str, step: int) -> str:
    return os.path.join(root, f"step_{step:08d}")


def commit_manifest(root: str, manifest: Manifest, *, durable: bool = True) -> str:
    """Write MANIFEST then the COMMIT marker (the commit point).

    With ``durable`` (default) the step directory and the checkpoint root are
    fsynced after the marker lands, so the committed image survives power
    loss: payload files, hostmetas, MANIFEST and COMMIT are all durably
    linked before the commit is observable.
    """
    d = step_dir(root, manifest.step)
    os.makedirs(d, exist_ok=True)
    atomic_write(os.path.join(d, "MANIFEST.msgpack"), manifest.to_bytes())
    atomic_write(os.path.join(d, "COMMIT"), b"ok")
    if durable:
        fsync_dir(d)
        fsync_dir(root)
    return d


def is_committed(root: str, step: int) -> bool:
    return os.path.exists(os.path.join(step_dir(root, step), "COMMIT"))


def committed_steps(root: str) -> list[int]:
    """Committed step numbers under ``root``, tolerant of concurrent GC.

    A step directory may vanish between ``listdir`` and the COMMIT probe
    (GC on another thread/process); such steps are simply not reported.
    """
    try:
        names = os.listdir(root)
    except (FileNotFoundError, NotADirectoryError):
        return []
    steps = []
    for name in names:
        m = _STEP_RE.match(name)
        # os.path.exists returns False (never raises) for a dir GC'd
        # between the listdir and this probe
        if m and os.path.exists(os.path.join(root, name, "COMMIT")):
            steps.append(int(m.group(1)))
    return sorted(steps)


def latest_committed_step(root: str) -> int | None:
    steps = committed_steps(root)
    return steps[-1] if steps else None


_STEP_FILE_RE = re.compile(r"^step_(\d{8})/")


def referenced_steps(manifest: Manifest) -> set[int]:
    """Steps whose payload files this (possibly delta) manifest references.

    An incremental manifest's chunk records point into *earlier* steps'
    ``data-h*.bin`` files; collecting one of those steps strands the delta.
    GC planners (policy.gc_keep), the store-level GC safety net and the
    checkpointer's in-flight-base pinning all consume this.
    """
    out: set[int] = set()
    for lv in manifest.leaves.values():
        for s in lv.shards:
            for c in s.chunks:
                m = _STEP_FILE_RE.match(c.file.replace("\\", "/"))
                if m:
                    out.add(int(m.group(1)))
    return out


def load_manifest(root: str, step: int) -> Manifest:
    if not is_committed(root, step):
        raise FileNotFoundError(f"step {step} not committed under {root}")
    with open(os.path.join(step_dir(root, step), "MANIFEST.msgpack"), "rb") as f:
        return Manifest.from_bytes(f.read())


def load_manifest_if_committed(root: str, step: int) -> Manifest | None:
    """Like :func:`load_manifest` but returns None if the step is gone.

    The committed/read pair is not atomic against GC: a step can be listed
    as committed and then disappear before the manifest read. Callers that
    scan (GC planners, restore pickers) use this to tolerate the race.
    """
    try:
        return load_manifest(root, step)
    except (FileNotFoundError, NotADirectoryError):
        return None


# -- per-host metadata + coordinator merge ------------------------------------
# In the cluster protocol each host persists its own shards and writes a
# *hostmeta* — a Manifest holding only that host's ShardRecords — into the
# step directory. The coordinator merges all hostmetas into the single
# MANIFEST.msgpack and only then writes COMMIT (two-phase commit: hostmetas
# are the prepare records, COMMIT is the decision).

_HOSTMETA_RE = re.compile(r"^hostmeta-h(\d{4})\.msgpack$")


def hostmeta_path(root: str, step: int, host: int) -> str:
    return os.path.join(step_dir(root, step), f"hostmeta-h{host:04d}.msgpack")


def write_hostmeta(root: str, step: int, host: int, manifest: Manifest) -> str:
    """Atomically write one host's manifest fragment; returns its path."""
    d = step_dir(root, step)
    os.makedirs(d, exist_ok=True)
    path = hostmeta_path(root, step, host)
    atomic_write(path, manifest.to_bytes())
    return path


def list_hostmetas(root: str, step: int) -> dict[int, str]:
    """{host: hostmeta path} present in a step directory."""
    d = step_dir(root, step)
    try:
        names = os.listdir(d)
    except (FileNotFoundError, NotADirectoryError):
        return {}
    out = {}
    for name in names:
        m = _HOSTMETA_RE.match(name)
        if m:
            out[int(m.group(1))] = os.path.join(d, name)
    return out


def load_hostmeta(root: str, step: int, host: int) -> Manifest:
    with open(hostmeta_path(root, step, host), "rb") as f:
        return Manifest.from_bytes(f.read())


def merge_hostmetas(
    root: str, step: int, hosts: list[int] | None = None
) -> Manifest:
    """Merge per-host manifest fragments into the cluster manifest.

    Every host reports the same global leaf set (paths, shapes, dtypes,
    skeleton) but only its own ShardRecords; the merge unions the shard
    lists per leaf. Disagreement on shape/dtype/step is a protocol error —
    it means two hosts checkpointed different states, which must abort the
    round rather than commit a chimera.
    """
    if hosts is None:
        hosts = sorted(list_hostmetas(root, step))
    if not hosts:
        raise FileNotFoundError(f"no hostmetas for step {step} under {root}")
    merged: Manifest | None = None
    for h in sorted(hosts):
        hm = load_hostmeta(root, step, h)
        if hm.step != step:
            raise ValueError(
                f"hostmeta h{h} is for step {hm.step}, expected {step}"
            )
        if merged is None:
            # seed meta from the first host but drop its per-host fields —
            # the cluster manifest must not claim one host's identity or
            # report one host's chunk counters as cluster totals
            base_meta = {
                k: v for k, v in hm.meta.items()
                if k not in ("host", "chunks_written", "chunks_reused")
            }
            merged = Manifest(
                step=step,
                format_version=hm.format_version,
                skeleton=hm.skeleton,
                meta=base_meta,
            )
            merged.meta["hosts"] = {}
        for path, lv in hm.leaves.items():
            have = merged.leaves.get(path)
            if have is None:
                merged.leaves[path] = LeafRecord(
                    path=lv.path, shape=lv.shape, dtype=lv.dtype,
                    shards=list(lv.shards),
                )
            else:
                if list(have.shape) != list(lv.shape) or have.dtype != lv.dtype:
                    raise ValueError(
                        f"hostmeta h{h} disagrees on leaf {path!r}: "
                        f"{lv.shape}/{lv.dtype} vs {have.shape}/{have.dtype}"
                    )
                have.shards.extend(lv.shards)
        merged.meta["hosts"][h] = {
            "chunks_written": hm.meta.get("chunks_written", 0),
            "chunks_reused": hm.meta.get("chunks_reused", 0),
        }
    merged.meta["chunks_written"] = sum(
        v["chunks_written"] for v in merged.meta["hosts"].values()
    )
    merged.meta["chunks_reused"] = sum(
        v["chunks_reused"] for v in merged.meta["hosts"].values()
    )
    # deterministic shard order: by global start range
    for lv in merged.leaves.values():
        lv.shards.sort(key=lambda s: (tuple(s.start), tuple(s.stop)))
    return merged
