"""Checkpoint manifests + atomic commit protocol.

Layout of a checkpoint directory (one per step)::

    <root>/step_00000042/
        data-h0000.bin            per-host chunk payload files
        hostmeta-h0000.msgpack    per-host leaf/chunk records
        MANIFEST.msgpack          merged manifest (written by coordinator)
        COMMIT                    commit marker (last thing written)

A checkpoint exists iff COMMIT exists; everything before that is invisible
to restore. This mirrors CRUM's requirement that a crash mid-checkpoint must
leave the previous image restorable (the forked child writing the image can
die without corrupting anything).

The manifest is *topology-independent*: leaves are keyed by path and chunk
data is keyed by global index ranges (shard domains), so restore can target
any mesh — the analogue of CRUM's "checkpoint on one CUDA version, restart
on another".

Delta (incremental) manifests: a chunk record may carry a ``file`` that
lives in an earlier step's directory. Restore chases these references, so an
incremental checkpoint only persists digest-dirty chunks.
"""
from __future__ import annotations

import os
import re
from dataclasses import dataclass, field, asdict
from typing import Any

import msgpack
import numpy as np

FORMAT_VERSION = 2
_STEP_RE = re.compile(r"^step_(\d{8})$")


@dataclass
class ChunkRecord:
    index: int          # chunk ordinal within its shard
    raw_len: int        # uncompressed byte length
    digest: int         # u64 content digest (chunking.chunk_digest_np)
    codec: str          # codec name used on disk
    file: str           # path relative to checkpoint ROOT (enables deltas)
    file_offset: int
    comp_len: int


@dataclass
class ShardRecord:
    start: list[int]    # global index-range start (per dim)
    stop: list[int]     # global index-range stop (per dim)
    chunks: list[ChunkRecord] = field(default_factory=list)


@dataclass
class LeafRecord:
    path: str
    shape: list[int]
    dtype: str
    shards: list[ShardRecord] = field(default_factory=list)


@dataclass
class Manifest:
    step: int
    format_version: int = FORMAT_VERSION
    leaves: dict[str, LeafRecord] = field(default_factory=dict)
    skeleton: Any = None       # nested dict/list/tuple structure w/ leaf paths
    meta: dict = field(default_factory=dict)  # free-form (mesh, config, ...)

    # -- (de)serialization -------------------------------------------------
    def to_bytes(self) -> bytes:
        payload = {
            "step": self.step,
            "format_version": self.format_version,
            "leaves": {k: asdict(v) for k, v in self.leaves.items()},
            "skeleton": _encode_skeleton(self.skeleton),
            "meta": self.meta,
        }
        return msgpack.packb(payload, use_bin_type=True)

    @staticmethod
    def from_bytes(data: bytes) -> "Manifest":
        p = msgpack.unpackb(data, raw=False, strict_map_key=False)
        if p["format_version"] > FORMAT_VERSION:
            raise ValueError(
                f"manifest format {p['format_version']} newer than supported "
                f"{FORMAT_VERSION}"
            )
        leaves = {}
        for k, lv in p["leaves"].items():
            shards = [
                ShardRecord(
                    start=s["start"],
                    stop=s["stop"],
                    chunks=[ChunkRecord(**c) for c in s["chunks"]],
                )
                for s in lv["shards"]
            ]
            leaves[k] = LeafRecord(lv["path"], lv["shape"], lv["dtype"], shards)
        return Manifest(
            step=p["step"],
            format_version=p["format_version"],
            leaves=leaves,
            skeleton=_decode_skeleton(p["skeleton"]),
            meta=p.get("meta", {}),
        )

    def total_bytes(self, *, compressed: bool = True) -> int:
        return sum(
            (c.comp_len if compressed else c.raw_len)
            for lv in self.leaves.values()
            for s in lv.shards
            for c in s.chunks
        )


# -- tree skeleton -----------------------------------------------------------
# Checkpointable state must be a pytree of dict / list / tuple containers
# with array leaves. The skeleton encodes the container structure with leaf
# paths at the leaf positions, so restore is pickle-free and version-robust.

def _encode_skeleton(node: Any) -> Any:
    if isinstance(node, dict):
        return {"t": "d", "k": list(node.keys()),
                "v": [_encode_skeleton(v) for v in node.values()]}
    if isinstance(node, tuple):
        return {"t": "t", "v": [_encode_skeleton(v) for v in node]}
    if isinstance(node, list):
        return {"t": "l", "v": [_encode_skeleton(v) for v in node]}
    if node is None:
        return {"t": "n"}
    if isinstance(node, str):  # leaf path reference
        return {"t": "p", "v": node}
    raise TypeError(f"unsupported skeleton node {type(node)}")


def _decode_skeleton(enc: Any) -> Any:
    if enc is None:
        return None
    t = enc["t"]
    if t == "d":
        return {k: _decode_skeleton(v) for k, v in zip(enc["k"], enc["v"])}
    if t == "t":
        return tuple(_decode_skeleton(v) for v in enc["v"])
    if t == "l":
        return [_decode_skeleton(v) for v in enc["v"]]
    if t == "n":
        return None
    if t == "p":
        return enc["v"]
    raise TypeError(f"bad skeleton tag {t}")


def build_skeleton(tree: Any, prefix: str = "") -> Any:
    """Replace every leaf of a dict/list/tuple pytree with its path string."""
    if isinstance(tree, dict):
        return {k: build_skeleton(v, f"{prefix}{k}/") for k, v in tree.items()}
    if isinstance(tree, (list, tuple)):
        seq = [build_skeleton(v, f"{prefix}{i}/") for i, v in enumerate(tree)]
        return tuple(seq) if isinstance(tree, tuple) else seq
    if tree is None:
        return None
    return prefix[:-1]  # strip trailing '/'


def skeleton_fill(skeleton: Any, leaves: dict[str, Any]) -> Any:
    """Rebuild the original pytree from a skeleton + {path: leaf} map."""
    if isinstance(skeleton, dict):
        return {k: skeleton_fill(v, leaves) for k, v in skeleton.items()}
    if isinstance(skeleton, tuple):
        return tuple(skeleton_fill(v, leaves) for v in skeleton)
    if isinstance(skeleton, list):
        return [skeleton_fill(v, leaves) for v in skeleton]
    if skeleton is None:
        return None
    return leaves[skeleton]


def skeleton_paths(skeleton: Any) -> list[str]:
    out: list[str] = []

    def rec(node: Any) -> None:
        if isinstance(node, dict):
            for v in node.values():
                rec(v)
        elif isinstance(node, (list, tuple)):
            for v in node:
                rec(v)
        elif isinstance(node, str):
            out.append(node)

    rec(skeleton)
    return out


# -- atomic filesystem protocol ----------------------------------------------

def atomic_write(path: str, data: bytes) -> None:
    """tmp + fsync + rename: the write is all-or-nothing."""
    tmp = f"{path}.tmp.{os.getpid()}"
    with open(tmp, "wb") as f:
        f.write(data)
        f.flush()
        os.fsync(f.fileno())
    os.replace(tmp, path)


def step_dir(root: str, step: int) -> str:
    return os.path.join(root, f"step_{step:08d}")


def commit_manifest(root: str, manifest: Manifest) -> str:
    """Write MANIFEST then the COMMIT marker (the commit point)."""
    d = step_dir(root, manifest.step)
    os.makedirs(d, exist_ok=True)
    atomic_write(os.path.join(d, "MANIFEST.msgpack"), manifest.to_bytes())
    atomic_write(os.path.join(d, "COMMIT"), b"ok")
    return d


def is_committed(root: str, step: int) -> bool:
    return os.path.exists(os.path.join(step_dir(root, step), "COMMIT"))


def committed_steps(root: str) -> list[int]:
    if not os.path.isdir(root):
        return []
    steps = []
    for name in os.listdir(root):
        m = _STEP_RE.match(name)
        if m and os.path.exists(os.path.join(root, name, "COMMIT")):
            steps.append(int(m.group(1)))
    return sorted(steps)


def latest_committed_step(root: str) -> int | None:
    steps = committed_steps(root)
    return steps[-1] if steps else None


def load_manifest(root: str, step: int) -> Manifest:
    if not is_committed(root, step):
        raise FileNotFoundError(f"step {step} not committed under {root}")
    with open(os.path.join(step_dir(root, step), "MANIFEST.msgpack"), "rb") as f:
        return Manifest.from_bytes(f.read())
