"""Sharded, topology-independent save/restore of JAX pytrees.

The CRUM principle applied to SPMD: the checkpoint image must contain *no
device state*. Leaves are stored as global logical arrays; every host writes
only the shards it owns (``addressable_shards`` with ``replica_id == 0``),
keyed by their global index ranges. Restore targets **any** mesh: each
target shard is assembled from whichever stored shards overlap its index
domain — the elastic-restart analogue of "checkpoint on one CUDA/GPU
version, restart on another" (§3.1 of the paper).
"""
from __future__ import annotations

import math
import zlib
from typing import Any, Callable

import jax
import numpy as np

import ml_dtypes  # noqa: F401  (registers bfloat16 & friends with numpy)

from repro.checkpoint.codecs import DEFAULT_CODEC
from repro.checkpoint.chunking import (
    DEFAULT_CHUNK_BYTES,
    chunk_digest_np,
    iter_chunks,
)
from repro.checkpoint.manifest import (
    LeafRecord,
    Manifest,
    ShardRecord,
    build_skeleton,
    commit_manifest,
    load_manifest,
    skeleton_fill,
)
from repro.checkpoint.store import ChunkStore
from repro.utils.tree import flatten_with_paths


def _np_dtype(name: str) -> np.dtype:
    return np.dtype(name)  # ml_dtypes registers bfloat16 etc.


def host_slice_plan(
    path: str, shape: tuple[int, ...], host: int, n_hosts: int
) -> tuple[list[int], list[int]] | None:
    """The global [start, stop) window ``host`` of ``n_hosts`` owns.

    THE ownership rule of the simulated cluster, defined once so persist
    (``coord.worker.shard_tree_for_host``) and elastic restore
    (``RestoreManager.restore_elastic``) can never drift apart:

      - a leaf whose leading dimension is >= n_hosts splits contiguously
        along dim 0, ``(host * n0) // n_hosts`` style — non-divisible
        splits give some hosts one extra row, never gaps or overlaps;
      - smaller leaves and scalars are whole-owned by a stable hash of
        their path (exactly one host persists each byte);
      - returns None when this host owns nothing of the leaf.
    """
    shape = tuple(int(d) for d in shape)
    if n_hosts <= 0:
        raise ValueError(f"n_hosts must be positive, got {n_hosts}")
    if not 0 <= host < n_hosts:
        raise ValueError(f"host {host} outside [0, {n_hosts})")
    if len(shape) >= 1 and shape[0] >= n_hosts:
        n0 = shape[0]
        lo = (host * n0) // n_hosts
        hi = ((host + 1) * n0) // n_hosts
        return [lo] + [0] * (len(shape) - 1), [hi] + list(shape[1:])
    if zlib.crc32(path.encode()) % n_hosts == host:
        return [0] * len(shape), list(shape)
    return None


def _shard_index_to_ranges(index: tuple, shape: tuple[int, ...]) -> tuple[list, list]:
    start, stop = [], []
    for sl, dim in zip(index, shape):
        start.append(0 if sl.start is None else int(sl.start))
        stop.append(dim if sl.stop is None else int(sl.stop))
    return start, stop


def _owned_shards(arr: jax.Array) -> list[tuple[list, list, np.ndarray]]:
    """(start, stop, data) for shards this host is responsible for writing."""
    out = []
    for sh in arr.addressable_shards:
        if sh.replica_id != 0:
            continue  # replicas: exactly one device owns each index domain
        start, stop = _shard_index_to_ranges(sh.index, arr.shape)
        out.append((start, stop, np.asarray(sh.data)))
    return out


def _leaf_shards(leaf: Any) -> tuple[tuple[int, ...], np.dtype, list]:
    if isinstance(leaf, jax.Array):
        return tuple(leaf.shape), np.dtype(leaf.dtype), _owned_shards(leaf)
    arr = np.asarray(leaf)
    start = [0] * arr.ndim
    stop = list(arr.shape)
    return tuple(arr.shape), arr.dtype, [(start, stop, arr)]


def _prev_digest_map(prev: Manifest | None) -> dict[tuple, "object"]:
    """(path, start, stop, chunk_idx) -> ChunkRecord from a prior manifest."""
    if prev is None:
        return {}
    out = {}
    for path, lv in prev.leaves.items():
        for s in lv.shards:
            for c in s.chunks:
                out[(path, tuple(s.start), tuple(s.stop), c.index)] = c
    return out


def save_pytree(
    state: Any,
    store: ChunkStore,
    step: int,
    *,
    codec: str = DEFAULT_CODEC,
    chunk_bytes: int = DEFAULT_CHUNK_BYTES,
    host: int = 0,
    prev_manifest: Manifest | None = None,
    meta: dict | None = None,
    commit: bool = True,
    fsync: bool = False,
) -> Manifest:
    """Write this host's shards of ``state``; commit the manifest.

    ``prev_manifest`` enables incremental checkpoints: chunks whose digest
    matches the previous image are *referenced*, not rewritten.
    """
    flat, _ = flatten_with_paths(state)
    skeleton = build_skeleton(state)
    prev = _prev_digest_map(prev_manifest)

    manifest = Manifest(step=step, skeleton=skeleton, meta=meta or {})
    writer = store.writer(step, host)
    reused = written = 0
    try:
        for path, leaf in flat.items():
            shape, dtype, shards = _leaf_shards(leaf)
            lrec = LeafRecord(path=path, shape=list(shape), dtype=dtype.name)
            for start, stop, data in shards:
                srec = ShardRecord(start=start, stop=stop)
                for key, raw in iter_chunks(path, data, chunk_bytes):
                    digest = chunk_digest_np(raw)
                    old = prev.get((path, tuple(start), tuple(stop), key.index))
                    if old is not None and old.digest == digest and old.raw_len == len(raw):
                        srec.chunks.append(old)  # delta reference
                        reused += 1
                    else:
                        srec.chunks.append(
                            writer.append(raw, codec, index=key.index, digest=digest)
                        )
                        written += 1
                lrec.shards.append(srec)
            manifest.leaves[path] = lrec
    finally:
        writer.close(fsync=fsync)
    manifest.meta.setdefault("chunks_written", written)
    manifest.meta.setdefault("chunks_reused", reused)
    if commit:
        # directory durability tracks the payload fsync knob (see manifest
        # .fsync_dir): dir fsyncs without payload fsyncs buy nothing
        commit_manifest(store.root, manifest, durable=fsync)
    return manifest


# --------------------------------------------------------------------------
# Restore
# --------------------------------------------------------------------------

class _LeafAssembler:
    """Assembles arbitrary index-windows of one stored leaf."""

    def __init__(self, store: ChunkStore, lrec: LeafRecord):
        self.store = store
        self.lrec = lrec
        self.shape = tuple(lrec.shape)
        self.dtype = _np_dtype(lrec.dtype)
        self._shard_cache: dict[int, np.ndarray] = {}

    def _shard_array(self, i: int) -> np.ndarray:
        if i not in self._shard_cache:
            s = self.lrec.shards[i]
            raw = b"".join(self.store.read_chunk(c) for c in s.chunks)
            shp = tuple(b - a for a, b in zip(s.start, s.stop))
            n = int(np.prod(shp, dtype=np.int64)) if shp else 1
            arr = np.frombuffer(raw, dtype=self.dtype, count=n).reshape(shp)
            self._shard_cache[i] = arr
        return self._shard_cache[i]

    def window(self, start: list[int], stop: list[int]) -> np.ndarray:
        """Assemble the [start, stop) window from overlapping stored shards."""
        out_shape = tuple(b - a for a, b in zip(start, stop))
        if not out_shape:  # 0-d leaf
            return self._shard_array(0).copy()
        out = np.empty(out_shape, dtype=self.dtype)
        filled = 0
        for i, s in enumerate(self.lrec.shards):
            lo = [max(a, sa) for a, sa in zip(start, s.start)]
            hi = [min(b, sb) for b, sb in zip(stop, s.stop)]
            if any(l >= h for l, h in zip(lo, hi)):
                continue
            src = self._shard_array(i)[
                tuple(slice(l - sa, h - sa) for l, h, sa in zip(lo, hi, s.start))
            ]
            out[tuple(slice(l - a, h - a) for l, h, a in zip(lo, hi, start))] = src
            filled += src.size
        if filled < int(np.prod(out_shape, dtype=np.int64)):
            raise ValueError(
                f"stored shards do not cover window {start}:{stop} of "
                f"{self.lrec.path} (covered {filled})"
            )
        return out

    def full(self) -> np.ndarray:
        return self.window([0] * len(self.shape), list(self.shape))


def _normalize_index(index: tuple, shape: tuple[int, ...]) -> tuple[list, list]:
    start, stop = [], []
    for sl, dim in zip(index, shape):
        start.append(0 if sl.start is None else int(sl.start))
        stop.append(dim if sl.stop is None else int(sl.stop))
    return start, stop


def restore_leaf(
    store: ChunkStore,
    lrec: LeafRecord,
    sharding: jax.sharding.Sharding | None,
) -> Any:
    """Restore one leaf, optionally placing it with the given sharding."""
    asm = _LeafAssembler(store, lrec)
    if sharding is None:
        return asm.full()
    shape = asm.shape

    def cb(index: tuple) -> np.ndarray:
        if not shape:
            return asm.window([], [])
        start, stop = _normalize_index(index, shape)
        return asm.window(start, stop)

    return jax.make_array_from_callback(shape, sharding, cb)


def restore_pytree(
    store: ChunkStore,
    step: int,
    shardings: Any = None,
    *,
    verify_digests: bool = False,
) -> tuple[Any, Manifest]:
    """Restore the full pytree saved at ``step``.

    ``shardings`` is either None (host numpy arrays), a single Sharding
    applied to all leaves, or a pytree matching the saved structure whose
    leaves are Shardings/None.
    """
    manifest = load_manifest(store.root, step)
    if verify_digests:
        verify_manifest(store, manifest)

    flat_sh: dict[str, Any] = {}
    if shardings is not None and not isinstance(shardings, jax.sharding.Sharding):
        flat_sh, _ = flatten_with_paths(shardings)

    def sh_for(path: str):
        if shardings is None:
            return None
        if isinstance(shardings, jax.sharding.Sharding):
            return shardings
        return flat_sh.get(path)

    leaves = {
        path: restore_leaf(store, lrec, sh_for(path))
        for path, lrec in manifest.leaves.items()
    }
    return skeleton_fill(manifest.skeleton, leaves), manifest


def restore_pytree_elastic(
    store: ChunkStore,
    step: int,
    make_sharding: Callable[[str, tuple[int, ...]], jax.sharding.Sharding | None],
) -> tuple[Any, Manifest]:
    """Elastic restore: target shardings chosen per-(path, shape) callback."""
    manifest = load_manifest(store.root, step)
    leaves = {
        path: restore_leaf(store, lrec, make_sharding(path, tuple(lrec.shape)))
        for path, lrec in manifest.leaves.items()
    }
    return skeleton_fill(manifest.skeleton, leaves), manifest


def verify_manifest(store: ChunkStore, manifest: Manifest) -> None:
    """Integrity pass: re-digest every chunk on disk (paper's 'verified mode')."""
    for lv in manifest.leaves.values():
        for s in lv.shards:
            for c in s.chunks:
                raw = store.read_chunk(c)
                d = chunk_digest_np(raw)
                if d != c.digest:
                    raise IOError(
                        f"digest mismatch for {lv.path} shard {s.start}:{s.stop} "
                        f"chunk {c.index}: {d:#x} != {c.digest:#x}"
                    )
