"""ChunkStore — per-host chunk payload files + read path + GC.

Each host appends its compressed chunks to a single ``data-h<host>.bin``
per checkpoint step (one sequential stream per host: the I/O pattern the
paper's forked child produces). Reads are random-access by (file, offset,
comp_len) from the manifest, with a small decompression cache so elastic
restore does not decompress a chunk once per overlapping target shard.
"""
from __future__ import annotations

import os
import threading
from collections import OrderedDict

from repro.checkpoint.codecs import get_codec
from repro.checkpoint.manifest import ChunkRecord, step_dir


def host_data_file(step: int, host: int) -> str:
    """Path of a host's payload file, relative to the checkpoint root."""
    return os.path.join(f"step_{step:08d}", f"data-h{host:04d}.bin")


class ChunkStore:
    def __init__(self, root: str, *, cache_chunks: int = 256):
        self.root = root
        os.makedirs(root, exist_ok=True)
        self._cache: OrderedDict[tuple, bytes] = OrderedDict()
        self._cache_max = cache_chunks
        self._lock = threading.Lock()
        self.bytes_read = 0
        self.chunks_read = 0

    # -- write path ---------------------------------------------------------
    class Writer:
        """Sequential appender for one host's payload file.

        With ``lazy=True`` construction records only the target path and the
        file descriptor is opened on first ``append``. This is the child-safe
        handoff for the fork persist backend: the parent builds the Writer
        (cheap, no fd) before ``os.fork()`` and only the child ever opens the
        file, so parent and child never share an fd offset.
        """

        def __init__(self, store: "ChunkStore", step: int, host: int,
                     *, lazy: bool = False):
            self.host = int(host)
            self.relpath = host_data_file(step, host)
            self._abspath = os.path.join(store.root, self.relpath)
            self._f = None
            self._off = 0
            if not lazy:
                self._open()

        def _open(self) -> None:
            os.makedirs(os.path.dirname(self._abspath), exist_ok=True)
            self._f = open(self._abspath, "wb")

        def append(self, raw: bytes, codec_name: str, *, index: int,
                   digest: int) -> ChunkRecord:
            if self._f is None:
                self._open()
            comp = get_codec(codec_name).compress(raw)
            if os.environ.get("CRUM_CHAOS_DIR"):
                # chaos shim (soak drills): an armed disk_full fault turns
                # this append into ENOSPC mid-persist. One env lookup on
                # every production run — the import never happens.
                from repro.chaos.faults import check_disk_quota

                check_disk_quota(self.host, len(comp), self._off)
            rec = ChunkRecord(
                index=index, raw_len=len(raw), digest=digest,
                codec=codec_name, file=self.relpath,
                file_offset=self._off, comp_len=len(comp),
            )
            self._f.write(comp)
            self._off += len(comp)
            return rec

        def close(self, *, fsync: bool = True) -> None:
            if self._f is None:  # lazy writer that never wrote
                return
            self._f.flush()
            if fsync:
                os.fsync(self._f.fileno())
            self._f.close()
            self._f = None

    def writer(self, step: int, host: int = 0, *, lazy: bool = False
               ) -> "ChunkStore.Writer":
        return ChunkStore.Writer(self, step, host, lazy=lazy)

    # -- read path ------------------------------------------------------------
    def read_chunk(self, rec: ChunkRecord) -> bytes:
        key = (rec.file, rec.file_offset, rec.comp_len)
        with self._lock:
            if key in self._cache:
                self._cache.move_to_end(key)
                return self._cache[key]
        with open(os.path.join(self.root, rec.file), "rb") as f:
            f.seek(rec.file_offset)
            comp = f.read(rec.comp_len)
        if len(comp) != rec.comp_len:
            raise IOError(
                f"short read for {rec.file}@{rec.file_offset}: "
                f"{len(comp)} < {rec.comp_len}"
            )
        raw = get_codec(rec.codec).decompress(comp)
        if len(raw) != rec.raw_len:
            raise IOError(f"decompressed length mismatch for {rec.file}")
        with self._lock:
            self.bytes_read += len(raw)
            self.chunks_read += 1
            self._cache[key] = raw
            while len(self._cache) > self._cache_max:
                self._cache.popitem(last=False)
        return raw

    # -- garbage collection ----------------------------------------------------
    def gc(self, keep_steps: list[int], *, pin_referenced: bool = True) -> list[int]:
        """Delete committed step dirs not in ``keep_steps``.

        Never deletes a step that a surviving delta manifest references.
        Policy callers already pass the transitive closure (see
        policy.gc_keep), but the store re-derives it itself
        (``pin_referenced``) as a safety net: a caller with a naive keep
        list — or a manifest committed between the caller's plan and this
        collection — must not strand an incremental chain. Safe against a
        concurrent collector on the same root (two trainers, or trainer +
        cluster coordinator): a step another GC got to first is simply
        skipped.
        """
        from repro.checkpoint.manifest import (
            committed_steps,
            load_manifest_if_committed,
            referenced_steps,
        )
        removed = []
        keep = set(keep_steps)
        committed = committed_steps(self.root)
        if pin_referenced:
            # closure over the manifests that will survive: anything they
            # reference survives too (and transitively its own references)
            frontier = [s for s in committed if s in keep]
            while frontier:
                m = load_manifest_if_committed(self.root, frontier.pop())
                if m is None:
                    continue
                for ref in referenced_steps(m):
                    if ref not in keep:
                        keep.add(ref)
                        frontier.append(ref)
        for s in committed:
            if s in keep:
                continue
            d = step_dir(self.root, s)
            try:
                # remove COMMIT first so a crash mid-GC leaves an uncommitted
                # (hence invisible) directory rather than a corrupt one.
                os.remove(os.path.join(d, "COMMIT"))
            except FileNotFoundError:
                continue  # a racing collector owns this step now
            try:
                for name in os.listdir(d):
                    try:
                        os.remove(os.path.join(d, name))
                    except FileNotFoundError:
                        pass
                os.rmdir(d)
            except (FileNotFoundError, NotADirectoryError):
                pass
            removed.append(s)
        return removed
