"""Architecture registry: ``--arch <id>`` ids exactly as assigned."""
from __future__ import annotations

import importlib

from repro.models.config import ModelConfig, reduced_for_smoke

_MODULES = {
    "qwen2-0.5b": "repro.configs.qwen2_0_5b",
    "command-r-plus-104b": "repro.configs.command_r_plus_104b",
    "granite-8b": "repro.configs.granite_8b",
    "gemma-2b": "repro.configs.gemma_2b",
    "paligemma-3b": "repro.configs.paligemma_3b",
    "musicgen-medium": "repro.configs.musicgen_medium",
    "arctic-480b": "repro.configs.arctic_480b",
    "moonshot-v1-16b-a3b": "repro.configs.moonshot_v1_16b_a3b",
    "mamba2-130m": "repro.configs.mamba2_130m",
    "zamba2-1.2b": "repro.configs.zamba2_1_2b",
}


def list_archs() -> list[str]:
    return list(_MODULES)


def get_config(name: str, *, smoke: bool = False) -> ModelConfig:
    if name not in _MODULES:
        raise KeyError(f"unknown arch {name!r}; have {list_archs()}")
    cfg = importlib.import_module(_MODULES[name]).ARCH
    return reduced_for_smoke(cfg) if smoke else cfg


__all__ = ["get_config", "list_archs", "ModelConfig", "reduced_for_smoke"]
