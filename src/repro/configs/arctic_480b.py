"""arctic-480b [moe] — 128 experts top-2 with a dense residual branch
(Snowflake's dense-MoE hybrid). Adafactor keeps optimizer state within a
16 GiB/chip pod (DESIGN §6). [hf:Snowflake/snowflake-arctic-base; hf]"""
from repro.models.config import ModelConfig

ARCH = ModelConfig(
    name="arctic-480b",
    family="moe",
    num_layers=35,
    d_model=7168,
    num_heads=56,
    num_kv_heads=8,
    head_dim=128,
    d_ff=4864,              # per-expert FFN width
    vocab_size=32000,
    mlp_type="swiglu",
    qkv_bias=False,
    tie_embeddings=True,
    moe_experts=128,
    moe_top_k=2,
    moe_dense_ff=4864,      # dense residual branch
    moe_capacity_factor=1.25,
    # attn_over_model=True was REFUTED (see EXPERIMENTS §Perf): the per-layer
    # batch reshard bounces against FSDP-sharded weights (collective-permute
    # storm); attention stays replicated over model (heads !% 16)
    accum_dtype="bfloat16",
    optimizer="adafactor",
    remat="full",
    microbatches=16,  # bounds live activations at 480B
)
