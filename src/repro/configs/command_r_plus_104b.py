"""command-r-plus-104b [dense] — GQA, no biases, cohere parallel blocks,
tied embeddings. [hf:CohereForAI/c4ai-command-r-v01; unverified]"""
from repro.models.config import ModelConfig

ARCH = ModelConfig(
    name="command-r-plus-104b",
    family="dense",
    num_layers=64,
    d_model=12288,
    num_heads=96,
    num_kv_heads=8,
    head_dim=128,
    d_ff=33792,
    vocab_size=256000,
    mlp_type="swiglu",
    qkv_bias=False,
    parallel_block=True,
    tie_embeddings=True,
    rope_theta=75e6,
    optimizer="adamw",
    remat="full",
    microbatches=8,   # bounds live activations at 104B scale
)
