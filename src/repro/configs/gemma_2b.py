"""gemma-2b [dense] — GeGLU, head_dim=256, MQA (kv=1), sqrt(D) embed scale.
[arXiv:2403.08295; hf]"""
from repro.models.config import ModelConfig

ARCH = ModelConfig(
    name="gemma-2b",
    family="dense",
    num_layers=18,
    d_model=2048,
    num_heads=8,
    num_kv_heads=1,
    head_dim=256,
    d_ff=16384,
    vocab_size=256000,
    mlp_type="geglu",
    qkv_bias=False,
    tie_embeddings=True,
    embed_scale=True,
    rope_theta=10000.0,
    tensor_parallel=False,  # 8 heads don't divide model=16; 2.5B -> pure DP+FSDP
    optimizer="adamw",
    remat="dots",
    microbatches=1,
)
