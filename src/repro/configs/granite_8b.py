"""granite-8b [dense] — llama-architecture code model. [arXiv:2405.04324; hf]"""
from repro.models.config import ModelConfig

ARCH = ModelConfig(
    name="granite-8b",
    family="dense",
    num_layers=36,
    d_model=4096,
    num_heads=32,
    num_kv_heads=8,
    head_dim=128,
    d_ff=14336,
    vocab_size=49152,
    mlp_type="swiglu",
    qkv_bias=False,
    tie_embeddings=True,
    rope_theta=1e7,
    optimizer="adamw",
    remat="dots",
    microbatches=2,
)
