"""mamba2-130m [ssm] — attention-free SSD (state-space duality).
Sub-quadratic: runs the long_500k cell. [arXiv:2405.21060; unverified]"""
from repro.models.config import ModelConfig

ARCH = ModelConfig(
    name="mamba2-130m",
    family="ssm",
    num_layers=24,
    d_model=768,
    vocab_size=50280,
    ssm_state=128,
    ssm_conv=4,
    ssm_expand=2,           # d_inner = 1536 -> 24 SSD heads @ head_dim 64
    ssm_head_dim=64,
    ssm_chunk=256,
    subquadratic=True,
    tensor_parallel=False,  # 24 SSD heads don't divide model=16; 130M -> pure DP
    optimizer="adamw",
    remat="dots",
    microbatches=1,
)
