"""moonshot-v1-16b-a3b [moe] — kimi/moonlight-style, 64 experts top-6.
[hf:moonshotai/Moonlight-16B-A3B; hf]"""
from repro.models.config import ModelConfig

ARCH = ModelConfig(
    name="moonshot-v1-16b-a3b",
    family="moe",
    num_layers=48,
    d_model=2048,
    num_heads=16,
    num_kv_heads=16,
    head_dim=128,
    d_ff=1408,              # per-expert FFN width
    vocab_size=163840,
    mlp_type="swiglu",
    qkv_bias=False,
    tie_embeddings=True,
    moe_experts=64,
    moe_top_k=6,
    moe_capacity_factor=1.25,
    optimizer="adamw",
    remat="dots",
    microbatches=2,
)
