"""musicgen-medium [audio] — decoder-only LM over 4 EnCodec codebook
streams (stub frontend); GELU MLP, MHA. RoPE replaces the original
sinusoidal embedding (deviation noted in DESIGN §6). [arXiv:2306.05284; hf]"""
from repro.models.config import ModelConfig

ARCH = ModelConfig(
    name="musicgen-medium",
    family="dense",
    frontend="audio",
    audio_codebooks=4,
    num_layers=48,
    d_model=1536,
    num_heads=24,
    num_kv_heads=24,
    head_dim=64,
    d_ff=6144,
    vocab_size=2048,
    mlp_type="gelu",
    qkv_bias=False,
    tie_embeddings=False,   # separate codebook embed/head tables
    tensor_parallel=False,  # 24 heads don't divide model=16; 1.4B -> pure DP+FSDP
    optimizer="adamw",
    remat="dots",
    microbatches=1,
)
