"""paligemma-3b [vlm] — SigLIP stub frontend + gemma backbone; image tokens
form a bidirectional prefix. [arXiv:2407.07726; hf]"""
from repro.models.config import ModelConfig

ARCH = ModelConfig(
    name="paligemma-3b",
    family="dense",
    frontend="vision",
    num_patches=256,        # precomputed patch embeddings from input_specs()
    num_layers=18,
    d_model=2048,
    num_heads=8,
    num_kv_heads=1,
    head_dim=256,
    d_ff=16384,
    vocab_size=257216,
    mlp_type="geglu",
    qkv_bias=False,
    tie_embeddings=True,
    embed_scale=True,
    rope_theta=10000.0,
    tensor_parallel=False,  # gemma backbone: 8 heads; pure DP+FSDP
    optimizer="adamw",
    remat="dots",
    microbatches=1,
)
