"""zamba2-1.2b [hybrid] — Mamba2 backbone with a single *shared* attention
block applied every 6th layer. Sub-quadratic backbone: runs long_500k.
[arXiv:2411.15242; hf]"""
from repro.models.config import ModelConfig

ARCH = ModelConfig(
    name="zamba2-1.2b",
    family="hybrid",
    num_layers=38,
    d_model=2048,
    num_heads=32,
    num_kv_heads=32,
    head_dim=64,
    d_ff=8192,              # shared block MLP width
    vocab_size=32000,
    mlp_type="swiglu",
    ssm_state=64,
    ssm_conv=4,
    ssm_expand=2,           # d_inner = 4096 -> 64 SSD heads @ head_dim 64
    ssm_head_dim=64,
    ssm_chunk=256,
    attn_every=6,           # 6 shared-block applications over 38 layers
    subquadratic=True,
    optimizer="adamw",
    remat="dots",
    microbatches=4,
)
