"""Cluster coordination — CRUM's DMTCP-coordinator layer for this system.

CRUM checkpoints a *cluster*: a central coordinator quiesces every rank,
each rank's proxy/forked child persists its share of the image, and a
single commit makes the checkpoint visible atomically across all ranks.
This package is that layer, with simulated hosts as real OS processes:

  protocol.py     length-prefixed msgpack frames + message vocabulary
                  (JOIN/HEARTBEAT/READY/DRAIN/PERSIST_DONE/COMMIT/ABORT/…)
  coordinator.py  the coordinator process: membership, heartbeat-gated
                  two-phase commit (hostmetas are prepare records, the
                  merged MANIFEST + COMMIT marker is the decision), abort
                  on death/stall, round log
  worker.py       the per-host worker loop: train, barrier at checkpoint
                  boundaries, persist own shards via ForkedCheckpointer in
                  external-commit mode, failure injection for drills
  supervisor.py   restart supervision: spawn N workers, reap deaths
                  (process sentinels — the portable SIGCHLD), respawn with
                  restore-from-latest-committed so the cluster converges
                  back to lockstep

Entry point: ``python -m repro.launch.cluster --hosts 4 ...``.
"""
from repro.coord.protocol import (
    MSG_ABORT,
    MSG_COMMIT,
    MSG_DRAIN,
    MSG_FINISHED,
    MSG_HEARTBEAT,
    MSG_JOIN,
    MSG_PERSIST_DONE,
    MSG_PERSIST_FAIL,
    MSG_READY,
    MSG_SHUTDOWN,
    MSG_WELCOME,
    Connection,
    recv_frame,
    send_frame,
)
from repro.coord.coordinator import Coordinator, RoundRecord
from repro.coord.worker import WorkerConfig, worker_entry
from repro.coord.supervisor import ClusterReport, ClusterSupervisor, run_cluster

__all__ = [
    "Connection", "send_frame", "recv_frame",
    "MSG_JOIN", "MSG_WELCOME", "MSG_HEARTBEAT", "MSG_READY", "MSG_DRAIN",
    "MSG_PERSIST_DONE", "MSG_PERSIST_FAIL", "MSG_COMMIT", "MSG_ABORT",
    "MSG_FINISHED", "MSG_SHUTDOWN",
    "Coordinator", "RoundRecord",
    "WorkerConfig", "worker_entry",
    "ClusterSupervisor", "ClusterReport", "run_cluster",
]
