"""The cluster coordinator: heartbeat-gated two-phase checkpoint commit.

DMTCP-style: one coordinator process owns cluster state; workers (CRUM's
per-rank proxies) connect, heartbeat, and block at checkpoint boundaries.
A checkpoint round is a two-phase commit over the shared checkpoint root:

  phase 1 (prepare)  every worker READY at step S -> coordinator sends
                     DRAIN -> each worker persists *its own shards* via its
                     local ForkedCheckpointer in external-commit mode
                     (data-h*.bin + hostmeta-h*.msgpack) and acks
                     PERSIST_DONE.
  phase 2 (decide)   only when every live participant has acked *and* the
                     HeartbeatMonitor sees the full membership alive does
                     the coordinator merge the hostmetas into
                     MANIFEST.msgpack and write the COMMIT marker (fsynced
                     with the step directory). Any death, stall or persist
                     failure mid-round ABORTs: no MANIFEST, no COMMIT, the
                     previous committed image stays the restore target.

Rounds, joins, deaths and commits are journaled to CLUSTER_LOG.jsonl under
the checkpoint root (the auditable "manifest chain" of the cluster).
"""
from __future__ import annotations

import os
import queue
import socket
import threading
import time
from dataclasses import dataclass, field, asdict

from repro.checkpoint.manifest import (
    commit_manifest,
    latest_committed_step,
    merge_hostmetas,
)
from repro.checkpoint.store import ChunkStore
from repro.core.failure import HeartbeatMonitor, StragglerPolicy
from repro.core.policy import CheckpointPolicy
from repro.coord.protocol import (
    MSG_ABORT,
    MSG_COMMIT,
    MSG_DRAIN,
    MSG_FINISHED,
    MSG_HEARTBEAT,
    MSG_JOIN,
    MSG_METRICS,
    MSG_PERSIST_DONE,
    MSG_PERSIST_FAIL,
    MSG_PROXY_ENDPOINT,
    MSG_READY,
    MSG_SHUTDOWN,
    MSG_WELCOME,
    Connection,
)
from repro.obs import metrics as obs_metrics
from repro.obs import trace as obs_trace
from repro.obs.journal import JournalWriter
from repro.obs.live import LiveAggregator
from repro.obs.watch import SEV_CRITICAL, Alert, WatchConfig, Watchdog

# NOTE: repro.remote.placement is imported lazily in __init__ — that module
# (and the rest of repro.remote) builds on the proxy package, whose import
# chain passes back through repro.coord.protocol.


@dataclass
class RoundRecord:
    """One checkpoint round attempt (committed or aborted)."""

    step: int
    status: str = "open"          # open -> committed | aborted
    reason: str = ""              # abort cause
    participants: list[int] = field(default_factory=list)
    acked: list[int] = field(default_factory=list)
    stragglers: list[int] = field(default_factory=list)
    commit_s: float = 0.0         # merge + fsync + COMMIT marker
    round_s: float = 0.0          # first READY -> decision
    persist_s_max: float = 0.0    # slowest host's persist time
    bytes_written: int = 0
    # incremental sync economy, summed over participants: how much of the
    # cluster state the digest gate / page dirty bits proved unchanged
    chunks_synced: int = 0        # chunks fetched device->host this round
    chunks_clean: int = 0         # chunks proven (or known) unchanged
    bytes_skipped: int = 0        # bytes the clean chunks did not move
    # phase-1 breakdown summed over participants (microseconds): how the
    # blocking window split between shadow sync, digesting (0 when fused
    # digests covered the boundary), fetching, and pipelined-sync stall
    sync_us: float = 0.0
    digest_us: float = 0.0
    fetch_us: float = 0.0
    stall_us: float = 0.0


@dataclass
class _Round:
    step: int
    opened_at: float
    drained_at: float | None = None
    acks: dict[int, dict] = field(default_factory=dict)
    record: RoundRecord | None = None
    # causal root context for the round's trace (None when tracing is off);
    # DRAIN/COMMIT/ABORT broadcasts carry it so receivers parent to the root
    ctx: dict | None = None


class Coordinator:
    """Owns membership, the round state machine, and the commit decision."""

    def __init__(
        self,
        root: str,
        *,
        n_hosts: int,
        heartbeat_timeout_s: float = 15.0,
        round_timeout_s: float = 120.0,
        keep_last: int = 0,
        tick_s: float = 0.25,
        watch_cfg: WatchConfig | None = None,
        abort_on_critical: bool = False,
        live_snapshot_every_s: float = 5.0,
        obs_dir: str | None = None,
    ):
        self.root = root
        os.makedirs(root, exist_ok=True)
        self.n_hosts = int(n_hosts)
        self.round_timeout_s = round_timeout_s
        self.tick_s = tick_s
        self.keep_last = int(keep_last)
        self.monitor = HeartbeatMonitor([], timeout_s=heartbeat_timeout_s)
        self.stragglers = StragglerPolicy()
        self.rounds: list[RoundRecord] = []
        self.done = threading.Event()
        self.latest_committed: int | None = latest_committed_step(root)
        self._inbox: "queue.Queue[tuple[str, Connection, dict | None]]" = queue.Queue()
        self._conns: dict[int, Connection] = {}       # host -> connection
        self._conn_host: dict[Connection, int] = {}
        self._finished: dict[int, str] = {}           # host -> state digest
        self._restored_from: dict[int, int | None] = {}
        self._round: _Round | None = None
        self._listener: socket.socket | None = None
        self._journal = JournalWriter(
            os.path.join(root, "CLUSTER_LOG.jsonl")
        )
        # live telemetry plane: HEARTBEAT-piggybacked registry deltas land
        # in a bounded time-series store, snapshotted to the obs/run dir
        # (falling back to the checkpoint root) and served over this same
        # listener (METRICS frames -> obs.top)
        self.live = LiveAggregator(
            snapshot_path=os.path.join(obs_dir or root, "live_metrics.json"),
            snapshot_every_s=live_snapshot_every_s,
        )
        # SLO watchdog: rules over every signal the event loop already
        # sees; alerts fan out to journal + trace + metrics via _on_alert
        self.abort_on_critical = bool(abort_on_critical)
        self.watchdog = Watchdog(watch_cfg, on_alert=self._on_alert)
        # proxy placement (remote device proxies): endpoint registry +
        # worker assignments, mutated only on the event-loop thread
        from repro.remote.placement import PlacementMap

        self.placement = PlacementMap()

    # -- lifecycle -----------------------------------------------------------
    @property
    def address(self) -> tuple[str, int]:
        assert self._listener is not None, "call start() first"
        return self._listener.getsockname()[:2]

    def start(self) -> "Coordinator":
        self._listener = socket.socket(socket.AF_INET, socket.SOCK_STREAM)
        self._listener.setsockopt(socket.SOL_SOCKET, socket.SO_REUSEADDR, 1)
        self._listener.bind(("127.0.0.1", 0))
        self._listener.listen(self.n_hosts * 2)
        threading.Thread(
            target=self._accept_loop, name="coord-accept", daemon=True
        ).start()
        return self

    def _accept_loop(self) -> None:
        while True:
            try:
                sock, _ = self._listener.accept()
            except OSError:  # listener closed at shutdown
                return
            sock.setsockopt(socket.IPPROTO_TCP, socket.TCP_NODELAY, 1)
            conn = Connection(sock)
            # daemon readers die with their connection's EOF; holding on to
            # them would leak one Thread per worker incarnation forever
            threading.Thread(
                target=self._reader_loop, args=(conn,),
                name="coord-reader", daemon=True,
            ).start()

    def _reader_loop(self, conn: Connection) -> None:
        try:
            while True:
                frame = conn.recv()
                if frame is None:
                    break
                self._inbox.put(("msg", conn, frame))
        except (OSError, ValueError):
            pass
        self._inbox.put(("eof", conn, None))

    def close(self) -> None:
        self.live.write_snapshot()  # final state for post-run obs.top
        if self._listener is not None:
            try:
                self._listener.close()
            except OSError:
                pass
        for c in list(self._conns.values()):
            c.close()
        self._conns.clear()
        self._conn_host.clear()
        self._journal.close()

    # -- journal ---------------------------------------------------------------
    def _log(self, event: str, **fields) -> None:
        self._journal.write(event, **fields)

    # -- alerts (SLO watchdog fan-out) ----------------------------------------
    def _on_alert(self, alert: Alert) -> None:
        """Every alert crosses every observability channel at once: the
        versioned journal line, a trace instant, the metrics registry —
        and, under the abort-on-critical policy, the open round."""
        self._log("alert", **alert.as_dict())
        obs_trace.instant(f"watch.{alert.kind}", severity=alert.severity,
                          host=alert.host, step=alert.step)
        obs_metrics.REGISTRY.inc("watch_alerts_total")
        obs_metrics.REGISTRY.inc(f"watch_alerts_{alert.severity}")
        self.live.observe(-1, f"alert_{alert.kind}", 1.0)
        if self.abort_on_critical and alert.severity == SEV_CRITICAL:
            self._abort_round(
                f"critical alert: {alert.kind} ({alert.message})"
            )

    @property
    def alerts(self) -> list[Alert]:
        return list(self.watchdog.alerts)

    # -- the event loop --------------------------------------------------------
    def run(self, *, deadline_s: float = 600.0) -> list[RoundRecord]:
        """Drive rounds until every host reports FINISHED (or deadline)."""
        deadline = time.monotonic() + deadline_s
        try:
            while True:
                if len(self._finished) == self.n_hosts:
                    self._broadcast(MSG_SHUTDOWN)
                    self._log("shutdown", finished=sorted(self._finished))
                    return self.rounds
                if time.monotonic() > deadline:
                    self._abort_round("coordinator deadline exceeded")
                    self._broadcast(MSG_SHUTDOWN)
                    raise TimeoutError(
                        f"cluster did not finish within {deadline_s}s "
                        f"(finished={sorted(self._finished)}, "
                        f"members={sorted(self._conns)})"
                    )
                try:
                    kind, conn, frame = self._inbox.get(timeout=self.tick_s)
                except queue.Empty:
                    self._check_liveness()
                    continue
                if kind == "eof":
                    self._on_eof(conn)
                else:
                    self._dispatch(conn, frame)
                self._check_liveness()
        finally:
            self.done.set()
            self.close()

    # -- message handling -------------------------------------------------------
    def _dispatch(self, conn: Connection, msg: dict) -> None:
        mtype = msg.get("type")
        host = msg.get("host")
        if mtype == MSG_JOIN:
            self._on_join(conn, msg)
            return
        if mtype == MSG_PROXY_ENDPOINT:
            # side channel: daemons/launchers register, workers acquire —
            # these connections never JOIN, so handle before the host gate
            self._on_proxy_endpoint(conn, msg)
            return
        if mtype == MSG_METRICS:
            # live-telemetry readout (obs.top): any connection, no JOIN
            self._on_metrics(conn, msg)
            return
        if self._conn_host.get(conn) != host:
            return  # frame from a connection we already kicked
        self.monitor.beat(host)
        if mtype == MSG_HEARTBEAT:
            self._on_heartbeat(host, msg)
            return
        if mtype == MSG_READY:
            self._on_ready(host, int(msg["step"]))
        elif mtype == MSG_PERSIST_DONE:
            self._on_persist_done(host, msg)
        elif mtype == MSG_PERSIST_FAIL:
            self._abort_round(
                f"host {host} persist failed: {msg.get('error', '?')}"
            )
        elif mtype == MSG_FINISHED:
            self._finished[host] = msg.get("digest", "")
            self._log("finished", host=host, step=msg.get("step"),
                      digest=msg.get("digest", ""))

    def _on_heartbeat(self, host: int, msg: dict) -> None:
        step = int(msg.get("step") or 0)
        wt = msg.get("wt")
        self.watchdog.on_heartbeat(
            host, step, wt=float(wt) if wt is not None else None
        )
        if self.live.ingest(host, msg.get("metrics")):
            # feed the spike rules exactly the points that just landed
            now = time.time()
            for metric in self.watchdog.cfg.fault_metrics:
                v = self.live.store.latest(host, metric)
                if v is not None:
                    self.watchdog.on_metric_point(host, metric, now, v)

    def _on_metrics(self, conn: Connection, msg: dict) -> None:
        try:
            conn.send(
                MSG_METRICS,
                snapshot=self.live.snapshot(),
                alerts=[a.as_dict() for a in self.watchdog.alerts[-100:]],
                latest_committed=self.latest_committed,
                n_hosts=self.n_hosts,
            )
        except OSError:
            pass  # readout peer vanished: nothing to unwind

    def _on_join(self, conn: Connection, msg: dict) -> None:
        host = int(msg["host"])
        self.live.reset_host(host)  # fresh incarnation: seq restarts at 1
        old = self._conns.pop(host, None)
        if old is not None and old is not conn:
            # stale connection from a previous incarnation of this host
            # (a re-JOIN on the *same* connection just updates metadata)
            self._conn_host.pop(old, None)
            old.close()
        self._conns[host] = conn
        self._conn_host[conn] = host
        self.monitor.add_host(host)
        self._restored_from[host] = msg.get("restored_from")
        self._log(
            "join", host=host, pid=msg.get("pid"),
            restored_from=msg.get("restored_from"),
            latest_committed=self.latest_committed,
        )
        obs_trace.instant("coord.join", host=host,
                          restored_from=msg.get("restored_from"))
        conn.send(
            MSG_WELCOME, host=host, n_hosts=self.n_hosts,
            latest_committed=self.latest_committed,
        )

    # -- proxy placement (remote device proxies) --------------------------------
    def register_proxy_endpoint(self, name: str, addr: str, port: int) -> None:
        """Launcher-side registration (same-process convenience); daemons
        on other machines use the PROXY_ENDPOINT register frame instead."""
        self.placement.register(name, addr, port)
        self._log("proxy_endpoint", name=name, addr=addr, port=int(port))

    def _on_proxy_endpoint(self, conn: Connection, msg: dict) -> None:
        # the side channel is open to any un-JOINed connection: a
        # malformed frame must be answered with an error, never allowed to
        # crash the event loop (and with it the whole cluster)
        try:
            op = msg.get("op")
            if op == "register":
                self.placement.register(msg["name"], msg["addr"], msg["port"])
                self._log("proxy_endpoint", name=msg["name"],
                          addr=msg["addr"], port=int(msg["port"]))
                conn.send(MSG_PROXY_ENDPOINT, op="registered",
                          name=msg["name"])
                return
            if op == "acquire":
                worker = int(msg["worker"])
                failed = msg.get("failed")
                if failed:
                    self.placement.report_dead(failed)
                    self._log("proxy_host_death", name=failed, worker=worker)
                    # alert *before* the reassignment answer goes out — the
                    # journal must show the death ahead of any round that
                    # commits on the rescheduled proxy
                    self.watchdog.on_proxy_host_death(failed, worker)
                ep = self.placement.assign(
                    worker, exclude=tuple(msg.get("exclude") or ())
                )
                if ep is None:
                    conn.send(MSG_PROXY_ENDPOINT,
                              error="no live proxy endpoints")
                    return
                self._log("proxy_placement", worker=worker, name=ep.name,
                          rescheduled=bool(failed))
                conn.send(MSG_PROXY_ENDPOINT, name=ep.name, addr=ep.addr,
                          port=ep.port)
                return
            conn.send(MSG_PROXY_ENDPOINT, error=f"unknown op {op!r}")
        except OSError:
            pass  # side-channel peer vanished mid-reply: nothing to unwind
        except Exception as e:
            try:
                conn.send(MSG_PROXY_ENDPOINT,
                          error=f"bad frame: {type(e).__name__}: {e}")
            except OSError:
                pass

    def _on_ready(self, host: int, step: int) -> None:
        if self.latest_committed is not None and step <= self.latest_committed:
            return  # stale barrier from before a restore
        r = self._round
        if r is None:
            r = self._round = _Round(step=step, opened_at=time.monotonic())
            r.record = RoundRecord(step=step)
            self.rounds.append(r.record)
            tr = obs_trace.get()
            if tr is not None:
                # the round root span: its id is derived from the trace id
                # alone (root_span_id), so workers that reached the boundary
                # before this READY arrived already parented to it
                trace_id = obs_trace.round_trace_id(step)
                r.ctx = obs_trace.span_context(
                    trace_id, span=obs_trace.root_span_id(trace_id)
                )
                tr.begin("coord.round", step=step, **obs_trace.ctx_args(r.ctx))
        if step != r.step:
            # a worker at a different boundary than the open round means the
            # cluster lost lockstep — abort, then re-open at the incoming
            # boundary (survivors re-READY on ABORT, so the barrier re-forms)
            self._abort_round(
                f"host {host} ready at step {step} during round {r.step}"
            )
            self._on_ready(host, step)
            return
        if host not in r.record.participants:
            r.record.participants.append(host)
        if (
            len(self._conns) == self.n_hosts
            and all(h in r.record.participants for h in range(self.n_hosts))
            and r.drained_at is None
        ):
            r.drained_at = time.monotonic()
            # ctx rides only when tracing: the off-path frame is byte-identical
            extra = {"ctx": r.ctx} if r.ctx is not None else {}
            self._broadcast(MSG_DRAIN, step=step, **extra)

    def _on_persist_done(self, host: int, msg: dict) -> None:
        r = self._round
        if r is None or int(msg["step"]) != r.step or r.drained_at is None:
            return  # late ack for an aborted round
        r.acks[host] = msg
        r.record.acked = sorted(r.acks)
        # cross-worker divergence rule: every acking host must hold the
        # same lockstep state at this boundary (digest rides the ack);
        # per-chunk digests, when they flowed, let a divergence alert name
        # the exact chunk and the host whose copy forked
        self.watchdog.on_persist_done(
            host, r.step, msg.get("state_digest"),
            chunk_digests=msg.get("chunk_digests"),
        )
        tr = obs_trace.get()
        if tr is not None:
            # quorum instant: child of the worker's round span (the ack
            # frame echoes the worker's ctx), so commit-quorum spread is
            # attributable per host in the causal tree
            tr.instant(
                "coord.ack", host=host, step=r.step,
                **obs_trace.ctx_args(obs_trace.child_span(msg.get("ctx"))),
            )
        # straggler accounting uses the duration the *coordinator* observed
        # (DRAIN -> ack), not the worker's self-reported persist time: a
        # host whose storage or network stalls the ack is exactly the host
        # that stalls the commit, whatever its local clock claims.
        self.stragglers.record(host, time.monotonic() - r.drained_at)
        if len(r.acks) < self.n_hosts:
            return
        # phase 2: the decision. Gate on liveness — an ack from a host that
        # died right after sending it must not produce a commit no one can
        # heartbeat for.
        dead = set(self.monitor.dead_hosts()) & set(self._conns)
        if dead or len(self._conns) < self.n_hosts:
            self._abort_round(f"dead hosts at commit gate: {sorted(dead)}")
            return
        self._commit_round()

    # -- round transitions --------------------------------------------------------
    def _commit_round(self) -> None:
        r = self._round
        t0 = time.perf_counter()
        try:
            manifest = merge_hostmetas(self.root, r.step, hosts=sorted(r.acks))
            manifest.meta["coordinator"] = {
                "participants": sorted(r.acks),
                "previous_committed": self.latest_committed,
            }
            commit_manifest(self.root, manifest, durable=True)
        except Exception as e:
            self._abort_round(f"commit failed: {type(e).__name__}: {e}")
            return
        rec = r.record
        rec.commit_s = time.perf_counter() - t0
        rec.round_s = time.monotonic() - r.opened_at
        rec.persist_s_max = max(
            (float(m.get("persist_s", 0.0)) for m in r.acks.values()), default=0.0
        )
        rec.bytes_written = sum(
            int(m.get("bytes_written", 0)) for m in r.acks.values()
        )
        rec.chunks_synced = sum(
            int(m.get("chunks_synced", 0)) for m in r.acks.values()
        )
        rec.chunks_clean = sum(
            int(m.get("chunks_clean", 0)) for m in r.acks.values()
        )
        rec.bytes_skipped = sum(
            int(m.get("bytes_skipped", 0)) for m in r.acks.values()
        )
        for phase in ("sync_us", "digest_us", "fetch_us", "stall_us"):
            setattr(rec, phase, round(sum(
                float(m.get(phase, 0.0)) for m in r.acks.values()
            ), 1))
        rec.stragglers = self.stragglers.stragglers()
        rec.status = "committed"
        self.latest_committed = r.step
        rctx = r.ctx
        self._round = None
        tr = obs_trace.get()
        if tr is not None:
            # the decision phase as a real span (merge + fsync + marker),
            # child of the round root — critpath's commit bucket. The
            # round root closes HERE, at the decision, so its extent
            # matches the journaled round_s (first READY -> decision) and
            # critpath --check can hold the two within tolerance; the
            # broadcast/journal/watchdog work below is post-round.
            tr.complete("coord.commit", t0, step=rec.step,
                        bytes_written=rec.bytes_written,
                        **obs_trace.ctx_args(obs_trace.child_span(rctx)))
            tr.end("coord.round")
        extra = {"ctx": rctx} if rctx is not None else {}
        self._broadcast(MSG_COMMIT, step=rec.step, **extra)
        self._log("round", **asdict(rec))
        obs_metrics.absorb_round(asdict(rec))
        self.watchdog.on_round(asdict(rec))
        self.live.observe(-1, "round_s", rec.round_s)
        self.live.observe(-1, "commit_s", rec.commit_s)
        self._gc()

    def _abort_round(self, reason: str) -> None:
        r = self._round
        if r is None:
            return
        rec = r.record
        rec.status = "aborted"
        rec.reason = reason
        rec.round_s = time.monotonic() - r.opened_at
        rctx = r.ctx
        self._round = None
        tr = obs_trace.get()
        if tr is not None:
            tr.instant("coord.abort", step=rec.step, reason=reason)
            tr.end("coord.round")
        extra = {"ctx": rctx} if rctx is not None else {}
        self._broadcast(MSG_ABORT, step=rec.step, reason=reason, **extra)
        self._log("round", **asdict(rec))
        obs_metrics.absorb_round(asdict(rec))
        # safe even when an abort_rate alert goes critical here: _round is
        # already None, so a nested abort-on-critical _abort_round no-ops
        self.watchdog.on_round(asdict(rec))
        # Partial files (data-h*/hostmeta-h*) stay in the uncommitted step
        # dir — invisible to restore, truncated/overwritten by the retry.
        # Deleting here would race a straggler still writing into the dir.

    def _gc(self) -> None:
        if self.keep_last <= 0:
            return
        CheckpointPolicy(keep_last=self.keep_last).run_gc(ChunkStore(self.root))

    # -- liveness ------------------------------------------------------------------
    def _on_eof(self, conn: Connection) -> None:
        host = self._conn_host.pop(conn, None)
        conn.close()
        if host is None or self._conns.get(host) is not conn:
            return  # already replaced by a rejoin
        self._kick(host, "connection lost (worker death)")

    def _check_liveness(self) -> None:
        s = self.watchdog.tick()      # leak-trend sampling (rate-limited)
        if s and s.get("supported"):
            # publish the raw counts as coordinator-local series (-1):
            # the soak verdict's leaks_flat check reads these, so a flat
            # trend is provable from live_metrics.json, not just from
            # the absence of a leak alert
            self.live.observe(-1, "coord_fd", float(s["fd"]))
            self.live.observe(-1, "coord_shm", float(s["shm"]))
        self.live.maybe_snapshot()    # run-dir live_metrics.json refresh
        for host in set(self.monitor.dead_hosts()) & set(self._conns):
            self._kick(host, "heartbeat timeout (worker stalled)")
        r = self._round
        if (
            r is not None
            and r.drained_at is not None
            and time.monotonic() - r.drained_at > self.round_timeout_s
        ):
            missing = sorted(set(range(self.n_hosts)) - set(r.acks))
            self._abort_round(f"round timeout; missing acks from {missing}")
            for host in missing:
                self._kick(host, "no persist ack within round timeout")

    def _kick(self, host: int, reason: str) -> None:
        conn = self._conns.pop(host, None)
        if conn is not None:
            self._conn_host.pop(conn, None)
            conn.close()
        self.monitor.remove_host(host)
        self._finished.pop(host, None)
        self._log("death", host=host, reason=reason,
                  latest_committed=self.latest_committed)
        obs_trace.instant("coord.death", host=host, reason=reason)
        self.watchdog.on_death(host, reason)
        r = self._round
        if r is not None and host in r.record.participants:
            self._abort_round(f"host {host} lost mid-round: {reason}")

    def _broadcast(self, msg_type: str, **fields) -> None:
        for host, conn in list(self._conns.items()):
            try:
                conn.send(msg_type, **fields)
            except OSError:
                self._inbox.put(("eof", conn, None))

    # -- introspection --------------------------------------------------------------
    @property
    def final_digests(self) -> dict[int, str]:
        """{host: state digest at FINISHED} — lockstep-convergence evidence."""
        return dict(self._finished)

    @property
    def log_path(self) -> str:
        return self._journal.path

    def aborted_rounds(self) -> list[RoundRecord]:
        return [r for r in self.rounds if r.status == "aborted"]

    def committed_rounds(self) -> list[RoundRecord]:
        return [r for r in self.rounds if r.status == "committed"]

    def sweep_uncommitted(self) -> list[int]:
        """Remove uncommitted (aborted/partial) step dirs. Only safe once all
        workers have exited — a live straggler may still be writing."""
        removed = []
        try:
            names = os.listdir(self.root)
        except FileNotFoundError:
            return removed
        for name in names:
            d = os.path.join(self.root, name)
            if not (name.startswith("step_") and os.path.isdir(d)):
                continue
            if os.path.exists(os.path.join(d, "COMMIT")):
                continue
            for f in os.listdir(d):
                os.remove(os.path.join(d, f))
            os.rmdir(d)
            removed.append(name)
        return removed
