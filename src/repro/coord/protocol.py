"""Coordinator wire protocol: u32-length-prefixed msgpack frames over TCP.

The coordinator listens on loopback; workers connect and speak a small
message vocabulary. Payloads are primitive-only msgpack maps — bulk data
(chunk payloads, hostmetas) never crosses the socket, it goes through the
shared checkpoint root exactly as CRUM routes image data through stable
storage rather than through the DMTCP coordinator.

When tracing is enabled every frame below may additionally carry a
``ctx`` field — ``{"trace", "span", "parent"}``, the causal trace
context (repro.obs.trace) naming the span the receiver emits, which
links per-round spans across processes into one causal tree
(repro.obs.critpath). The field is *absent* when tracing is off: the
untraced wire format is byte-identical. PERSIST_DONE may also carry
``chunk_digests`` ({path: [int, ...]}, the fused per-chunk digest
table) so the watchdog's divergence alert can name the first forked
chunk.

Worker -> coordinator::

    JOIN          {host, pid, restored_from}   first frame on a connection
    HEARTBEAT     {host, step, wt?, metrics?}  periodic liveness; ``wt`` is
                                               the sender's wall clock —
                                               the watchdog's clock_skew
                                               rule compares it against
                                               the coordinator's (0 =
                                               rule off); ``metrics``
                                               optionally piggybacks the
                                               worker's registry delta
                                               ({seq, counters, gauges} —
                                               repro.obs.live) in the SAME
                                               frame: zero extra syscalls
    READY         {host, step}                 at a checkpoint boundary
    PERSIST_DONE  {host, step, hostmeta, persist_s, blocking_s,
                   bytes_written, chunks_written, chunks_reused,
                   state_digest?}              state_digest feeds the SLO
                                               watchdog's cross-worker
                                               divergence rule
    PERSIST_FAIL  {host, step, error}
    FINISHED      {host, step, digest}         training loop complete

Side channel (proxy placement — any connection, no JOIN required)::

    PROXY_ENDPOINT {op: "register", name, addr, port}   daemon announces
    PROXY_ENDPOINT {op: "acquire", worker, failed?, exclude?}
                                                worker asks "where is my
                                                proxy?"; ``failed`` names
                                                an endpoint it watched die
    PROXY_ENDPOINT {name, addr, port} | {error} the coordinator's answer

    METRICS        {op: "snapshot"}             live telemetry readout (any
                                                connection, no JOIN): the
                                                coordinator answers with
                                                {snapshot, alerts} — the
                                                repro.obs.top data source

Coordinator -> worker::

    WELCOME       {host, n_hosts, latest_committed}
    DRAIN         {step}      all participants ready: persist now
    COMMIT        {step}      merged MANIFEST durable; image visible
    ABORT         {step, reason}   round void; previous image stands
    SHUTDOWN      {}
"""
from __future__ import annotations

import socket
import struct
import threading
from typing import Any

import msgpack

MSG_JOIN = "JOIN"
MSG_WELCOME = "WELCOME"
MSG_HEARTBEAT = "HEARTBEAT"
MSG_READY = "READY"
MSG_DRAIN = "DRAIN"
MSG_PERSIST_DONE = "PERSIST_DONE"
MSG_PERSIST_FAIL = "PERSIST_FAIL"
MSG_COMMIT = "COMMIT"
MSG_ABORT = "ABORT"
MSG_FINISHED = "FINISHED"
MSG_SHUTDOWN = "SHUTDOWN"
MSG_PROXY_ENDPOINT = "PROXY_ENDPOINT"
MSG_METRICS = "METRICS"

_LEN = struct.Struct("<I")
MAX_FRAME = 16 << 20  # a control frame this large is a protocol bug


def send_frame(sock: socket.socket, msg: dict[str, Any]) -> None:
    data = msgpack.packb(msg, use_bin_type=True)
    if len(data) > MAX_FRAME:
        raise ValueError(f"frame too large ({len(data)} bytes)")
    sock.sendall(_LEN.pack(len(data)) + data)


def _recv_exact(sock: socket.socket, n: int) -> bytes | None:
    buf = bytearray()
    while len(buf) < n:
        piece = sock.recv(n - len(buf))
        if not piece:  # peer closed (or died): clean EOF signal
            return None
        buf.extend(piece)
    return bytes(buf)


def recv_frame(sock: socket.socket) -> dict[str, Any] | None:
    """One frame, or None on EOF. socket timeouts propagate to the caller."""
    hdr = _recv_exact(sock, _LEN.size)
    if hdr is None:
        return None
    (n,) = _LEN.unpack(hdr)
    if n > MAX_FRAME:
        raise ValueError(f"corrupt frame header ({n} bytes)")
    data = _recv_exact(sock, n)
    if data is None:
        return None
    return msgpack.unpackb(data, raw=False, strict_map_key=False)


class Connection:
    """A framed, send-locked socket (heartbeat + main threads both send).

    ``recv`` keeps partial-frame progress across socket timeouts: workers
    poll with a short timeout (to interleave deadline checks), and a frame
    whose bytes straddle a timeout must not be torn — losing a half-read
    header would desync the stream and misparse payload bytes as lengths.
    """

    def __init__(self, sock: socket.socket):
        self.sock = sock
        self._send_lock = threading.Lock()
        self._rbuf = bytearray()
        self._need: int | None = None  # pending frame's payload length

    def send(self, msg_type: str, **fields: Any) -> None:
        frame = {"type": msg_type, **fields}
        with self._send_lock:
            send_frame(self.sock, frame)

    def _read_exact(self, n: int) -> bytes | None:
        """n buffered bytes, None on EOF; socket.timeout leaves progress
        in the buffer so the next call resumes mid-frame."""
        while len(self._rbuf) < n:
            piece = self.sock.recv(65536)
            if not piece:
                return None
            self._rbuf.extend(piece)
        out = bytes(self._rbuf[:n])
        del self._rbuf[:n]
        return out

    def recv(self) -> dict[str, Any] | None:
        if self._need is None:
            hdr = self._read_exact(_LEN.size)
            if hdr is None:
                return None
            (n,) = _LEN.unpack(hdr)
            if n > MAX_FRAME:
                raise ValueError(f"corrupt frame header ({n} bytes)")
            self._need = n
        data = self._read_exact(self._need)
        if data is None:
            return None
        self._need = None
        return msgpack.unpackb(data, raw=False, strict_map_key=False)

    def settimeout(self, t: float | None) -> None:
        self.sock.settimeout(t)

    def close(self) -> None:
        try:
            self.sock.shutdown(socket.SHUT_RDWR)
        except OSError:
            pass
        self.sock.close()


def connect(addr: tuple[str, int], *, timeout: float = 10.0) -> Connection:
    sock = socket.create_connection(addr, timeout=timeout)
    sock.setsockopt(socket.IPPROTO_TCP, socket.TCP_NODELAY, 1)
    return Connection(sock)
