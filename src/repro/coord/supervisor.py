"""Restart supervision: spawn workers, reap deaths, respawn into restore.

The supervisor is the process-level half of fault tolerance (the
coordinator is the protocol-level half). It spawns one OS process per
simulated host (``multiprocessing`` *spawn* context — safe with an
already-initialized JAX in the parent), then blocks on the process
sentinels (``multiprocessing.connection.wait`` — the portable SIGCHLD).
A worker exiting non-zero is a death: the supervisor respawns it with
``restored=True`` and every failure injection cleared, and the new
incarnation restores from ``latest_committed_step`` via the coordinator's
WELCOME — driving the cluster back to lockstep. A zero exit is a worker
that finished; it is never respawned.
"""
from __future__ import annotations

import dataclasses
import multiprocessing as mp
import threading
import time
from dataclasses import dataclass, field
from multiprocessing.connection import wait as sentinel_wait

from repro.coord.coordinator import Coordinator, RoundRecord
from repro.coord.worker import WorkerConfig, worker_entry
from repro.core.failure import RestartBudget
from repro.obs import metrics as obs_metrics
from repro.obs import trace as obs_trace


@dataclass
class ClusterReport:
    """What a cluster run produced — the CLI and tests assert on this."""

    n_hosts: int
    rounds: list[RoundRecord]
    restarts: dict[int, int]                # host -> respawn count
    final_digests: dict[int, str]           # host -> state digest at FINISHED
    latest_committed: int | None
    log_path: str
    swept_dirs: list[str] = field(default_factory=list)
    # remote proxies: every worker->endpoint assignment in order (repeats
    # for a worker = it was rescheduled onto a survivor)
    proxy_placements: list[tuple[int, str]] = field(default_factory=list)
    killed_proxy_hosts: list[str] = field(default_factory=list)
    # SLO watchdog output (Alert.as_dict() shapes, in emission order) —
    # drills assert on the kinds, launch.cluster prints/serializes them
    alerts: list[dict] = field(default_factory=list)

    def alert_kinds(self) -> set[str]:
        return {a.get("kind", "") for a in self.alerts}

    @property
    def committed(self) -> list[RoundRecord]:
        return [r for r in self.rounds if r.status == "committed"]

    @property
    def aborted(self) -> list[RoundRecord]:
        return [r for r in self.rounds if r.status == "aborted"]

    def lockstep(self) -> bool:
        """All hosts finished with bit-identical state."""
        return (
            len(self.final_digests) == self.n_hosts
            and len(set(self.final_digests.values())) == 1
        )


class ClusterSupervisor:
    def __init__(
        self,
        cfgs: list[WorkerConfig],
        *,
        max_restarts: int = 3,
        mp_context: str = "spawn",
    ):
        self.cfgs = {c.host: c for c in cfgs}
        self.max_restarts = max_restarts
        self.ctx = mp.get_context(mp_context)
        self.procs: dict[int, mp.Process] = {}
        self.budgets: dict[int, RestartBudget] = {
            h: RestartBudget(max_restarts, what=f"host {h}") for h in self.cfgs
        }
        self.exited_clean: set[int] = set()

    @property
    def restarts(self) -> dict[int, int]:
        return {h: b.count for h, b in self.budgets.items()}

    def _spawn(self, cfg: WorkerConfig) -> None:
        p = self.ctx.Process(
            target=worker_entry, args=(cfg,), name=f"crum-worker-{cfg.host}"
        )
        p.start()
        self.procs[cfg.host] = p

    def start(self) -> None:
        for cfg in self.cfgs.values():
            self._spawn(cfg)

    @staticmethod
    def respawn_cfg(cfg: WorkerConfig) -> WorkerConfig:
        """The next incarnation: restore-on-join, no replayed injections."""
        return dataclasses.replace(
            cfg,
            restored=True,
            kill_at_step=None,
            die_after_persist_step=None,
            stall_at_step=None,
            corrupt_at_step=None,
        )

    def watch(self, done: threading.Event, *, deadline_s: float = 600.0) -> None:
        """Reap deaths and respawn until ``done`` (coordinator finished).

        Every pass reaps ANY dead-and-unprocessed process, however the
        death was first noticed. Gating the reap on "its sentinel was in
        this pass's ``sentinel_wait`` result" is a liveness race: a death
        landing between passes is reaped by the next ``is_alive()`` call
        (``waitpid``), which then excludes the process from the waited
        set — it would never be respawned and the cluster would hang at
        the barrier until the coordinator deadline.
        """
        deadline = time.monotonic() + deadline_s
        while not done.is_set():
            if time.monotonic() > deadline:
                raise TimeoutError("supervisor deadline exceeded")
            for host, p in list(self.procs.items()):
                if host in self.exited_clean or p.is_alive():
                    continue
                p.join()
                if p.exitcode == 0:
                    self.exited_clean.add(host)
                    continue
                self.budgets[host].spend(f"last exit code {p.exitcode}")
                cfg = self.respawn_cfg(self.cfgs[host])
                self.cfgs[host] = cfg
                self._spawn(cfg)
            live = [
                p.sentinel for h, p in self.procs.items()
                if h not in self.exited_clean and p.is_alive()
            ]
            if live:
                # nap until a sentinel fires (portable SIGCHLD) or 0.25s
                sentinel_wait(live, timeout=0.25)
            else:
                # every worker exited; wait on the coordinator to notice
                done.wait(timeout=0.25)

    def terminate(self) -> None:
        for p in self.procs.values():
            if p.is_alive():
                p.terminate()
        for p in self.procs.values():
            p.join(timeout=10)


def run_cluster(
    *,
    root: str,
    n_hosts: int,
    total_steps: int,
    ckpt_every: int,
    backend: str = "thread",
    loop: str = "numpy",
    device_runner: str = "inline",
    codec: str | None = None,
    chunk_bytes: int = 1 << 16,
    width: int = 64,
    rows: int | None = None,
    step_time_s: float = 0.0,
    keep_last: int = 0,
    heartbeat_timeout_s: float = 10.0,
    round_timeout_s: float = 120.0,
    deadline_s: float = 600.0,
    max_restarts: int = 3,
    kill_host: int | None = None,
    kill_at_step: int | None = None,
    die_after_persist_host: int | None = None,
    die_after_persist_step: int | None = None,
    straggle_host: int | None = None,
    straggle_s: float = 0.0,
    stall_host: int | None = None,
    stall_s: float = 0.0,
    stall_at_step: int | None = None,
    corrupt_host: int | None = None,
    corrupt_at_step: int | None = None,
    proxy_hosts: int = 0,
    proxy_transport: str = "stream",
    kill_proxy_host: int | None = None,
    kill_proxy_after_commits: int = 1,
    sweep: bool = True,
    obs_dir: str | None = None,
    watch_cfg=None,
    abort_on_critical: bool = False,
    device_capacity: str | None = None,
    persist_timeout_s: float | None = None,
    chaos=None,
) -> ClusterReport:
    """One coordinated run: coordinator + N supervised worker processes.

    With ``proxy_hosts > 0`` (requires ``device_runner="proxy"``) the
    launcher additionally spawns that many proxy-host daemons
    (``repro.remote.host``), registers their endpoints with the
    coordinator, and every worker's device proxy is *placed* on one of
    them over the streamed transport instead of being spawned locally.
    ``kill_proxy_host`` SIGKILLs daemon #i once ``kill_proxy_after_commits``
    rounds have committed — the cross-host failure drill: affected workers
    are rescheduled onto a survivor and their API logs replayed there.

    Blocks until every host reports FINISHED (workers killed by injections
    are respawned and restored along the way) and returns the report.
    """
    if proxy_hosts and device_runner != "proxy":
        raise ValueError("proxy_hosts needs device_runner='proxy'")
    if kill_proxy_host is not None and not (
        0 <= kill_proxy_host < proxy_hosts
    ):
        raise ValueError(
            f"kill_proxy_host {kill_proxy_host} outside [0, {proxy_hosts})"
        )
    if kill_proxy_host is not None and proxy_hosts < 2:
        raise ValueError("the proxy-host kill drill needs a survivor (>= 2)")
    if obs_dir:
        # the launcher hosts the coordinator thread; workers and proxy-host
        # daemons inherit the obs dir through the exported environment
        obs_trace.enable(obs_dir, "launcher")

    coord = Coordinator(
        root,
        n_hosts=n_hosts,
        heartbeat_timeout_s=heartbeat_timeout_s,
        round_timeout_s=round_timeout_s,
        keep_last=keep_last,
        watch_cfg=watch_cfg,
        abort_on_critical=abort_on_critical,
        obs_dir=obs_dir,
    ).start()
    host_addr, port = coord.address

    daemons: list = []
    if proxy_hosts:
        from repro.remote.host import ProxyHostHandle

        for i in range(proxy_hosts):
            d = ProxyHostHandle(f"ph{i}").start()
            coord.register_proxy_endpoint(d.name, *d.addr)
            daemons.append(d)

    def cfg_for(h: int) -> WorkerConfig:
        kw = dict(
            host=h, n_hosts=n_hosts, coord_host=host_addr, coord_port=port,
            root=root, total_steps=total_steps, ckpt_every=ckpt_every,
            backend=backend, loop=loop, device_runner=device_runner,
            chunk_bytes=chunk_bytes, width=width, rows=rows,
            step_time_s=step_time_s, deadline_s=deadline_s,
        )
        if proxy_hosts:
            kw.update(proxy_placement="coord", proxy_transport=proxy_transport)
        if codec is not None:
            kw["codec"] = codec
        if device_capacity is not None:
            kw["device_capacity"] = device_capacity
        if persist_timeout_s is not None:
            kw["persist_timeout_s"] = persist_timeout_s
        if h == kill_host and kill_at_step is not None:
            kw["kill_at_step"] = kill_at_step
        if h == die_after_persist_host and die_after_persist_step is not None:
            kw["die_after_persist_step"] = die_after_persist_step
        if h == straggle_host and straggle_s:
            kw["straggle_s"] = straggle_s
        if h == stall_host and stall_s:
            kw.update(stall_s=stall_s, stall_at_step=stall_at_step)
        if h == corrupt_host and corrupt_at_step is not None:
            kw["corrupt_at_step"] = corrupt_at_step
        return WorkerConfig(**kw)

    sup = ClusterSupervisor(
        [cfg_for(h) for h in range(n_hosts)], max_restarts=max_restarts
    )

    coord_result: dict = {}
    killed_proxy_hosts: list[str] = []

    def drive() -> None:
        try:
            coord.run(deadline_s=deadline_s)
        except Exception as e:  # surfaced after the watch loop unblocks
            coord_result["error"] = e

    def proxy_killer() -> None:
        # the cross-host drill: wait for real progress (committed rounds
        # prove proxies are serving traffic), then SIGKILL one daemon
        while not coord.done.is_set():
            if len(coord.committed_rounds()) >= kill_proxy_after_commits:
                d = daemons[kill_proxy_host]
                d.kill()
                killed_proxy_hosts.append(d.name)
                return
            time.sleep(0.05)

    driver = threading.Thread(target=drive, name="coordinator", daemon=True)
    driver.start()
    if kill_proxy_host is not None:
        threading.Thread(
            target=proxy_killer, name="proxy-killer", daemon=True
        ).start()
    sup.start()
    chaos_ctl = None
    if chaos is not None:
        # chaos hook (repro.chaos.soak): hand the caller live handles to
        # every process in the cluster so a schedule thread can inject
        # faults while the run runs; stopped before teardown so a fault
        # window never outlives the cluster it targeted
        from repro.chaos.injectors import ClusterHandles

        chaos_ctl = chaos(ClusterHandles(
            coordinator=coord, supervisor=sup, daemons=daemons, root=root,
        ))
    try:
        sup.watch(coord.done, deadline_s=deadline_s)
    finally:
        if chaos_ctl is not None:
            try:
                chaos_ctl.stop()
            except Exception:
                pass
        sup.terminate()
        for d in daemons:
            d.terminate()
    driver.join(timeout=30)
    if "error" in coord_result:
        raise coord_result["error"]

    swept = coord.sweep_uncommitted() if sweep else []
    obs_metrics.dump_if_enabled("launcher")
    return ClusterReport(
        n_hosts=n_hosts,
        rounds=coord.rounds,
        restarts=dict(sup.restarts),
        final_digests=coord.final_digests,
        latest_committed=coord.latest_committed,
        log_path=coord.log_path,
        swept_dirs=swept,
        proxy_placements=list(coord.placement.history),
        killed_proxy_hosts=killed_proxy_hosts,
        alerts=[a.as_dict() for a in coord.alerts],
    )
