"""The per-host worker: train, barrier, persist own shards, obey commits.

One worker process simulates one CRUM rank+proxy pair. It holds the full
replicated training state (data-parallel lockstep: every host computes the
same deterministic updates) but **persists only its assigned global index
range** of each leaf, wrapped in :class:`HostShardView` — the simulated
analogue of a real multi-host jax.Array's ``addressable_shards``. The
local ForkedCheckpointer runs in *external-commit* mode: either persist
backend (thread pool or true-COW fork child) writes ``data-h*.bin`` +
``hostmeta-h*.msgpack``, and the *coordinator* — never the worker — writes
MANIFEST + COMMIT.

Failure injection (for drills, tests and benchmarks):

  kill_at_step            exit hard at that train step (after READY when
                          the step is a checkpoint boundary, so the death
                          lands mid-round and aborts it)
  die_after_persist_step  the crash-mid-commit drill: hostmeta is on disk,
                          PERSIST_DONE never sent
  straggle_s[/at_step]    sleep before acking (slow storage)
  stall_at_step/stall_s   stop heartbeating and freeze (hung host)
  corrupt_at_step         divergence drill: flip one byte of the device
                          state after that step, so the watchdog's
                          digest_divergence rule fires at the next
                          boundary and its alert names the forked chunk
"""
from __future__ import annotations

import os
import socket
import time
import threading
from dataclasses import dataclass

import numpy as np

from repro.checkpoint.codecs import DEFAULT_CODEC
from repro.checkpoint.store import ChunkStore
from repro.core.forked import ForkedCheckpointer
from repro.core.restore import RestoreManager
from repro.core.shadow import HostShardView
from repro.obs import metrics as obs_metrics
from repro.obs import trace as obs_trace
from repro.obs.live import HeartbeatPiggyback
from repro.coord.protocol import (
    MSG_ABORT,
    MSG_COMMIT,
    MSG_DRAIN,
    MSG_FINISHED,
    MSG_HEARTBEAT,
    MSG_JOIN,
    MSG_PERSIST_DONE,
    MSG_PERSIST_FAIL,
    MSG_READY,
    MSG_SHUTDOWN,
    MSG_WELCOME,
    Connection,
    connect,
)
from repro.utils.tree import flatten_with_paths, tree_digest, unflatten_from_paths

EXIT_KILLED = 9          # kill_at_step drill
EXIT_MID_COMMIT = 23     # die_after_persist_step drill
EXIT_WATCHDOG = 3        # local persist hung past persist_timeout_s


@dataclass
class WorkerConfig:
    host: int
    n_hosts: int
    coord_host: str
    coord_port: int
    root: str
    total_steps: int
    ckpt_every: int
    backend: str = "thread"
    codec: str = DEFAULT_CODEC
    chunk_bytes: int = 1 << 16
    incremental: bool = True
    loop: str = "numpy"            # "numpy" (fast, tests) | "jax" (real model)
    device_runner: str = "inline"  # "inline" | "proxy" (per-host proxy process)
    proxy_transport: str = "segment"   # "segment" (shared) | "stream" (remote)
    proxy_placement: str = "local"     # "local" spawn | "coord" (PROXY_ENDPOINT)
    width: int = 64                # numpy state width / jax d_model
    rows: int | None = None        # numpy state rows; None = n_hosts-derived
    #                                (pin it for elastic restarts: the state
    #                                shape must not change with host count)
    step_time_s: float = 0.0       # simulated compute per train step
    # proxy UVM budget: bytes ("1048576") or a percentage of the program
    # state ("50%" = oversubscription x2); None = unmanaged (soak runs
    # exercise the paging path by setting this under 100%)
    device_capacity: str | None = None
    heartbeat_s: float = 0.5
    sock_timeout_s: float = 1.0
    deadline_s: float = 600.0
    persist_timeout_s: float = 120.0
    seed: int = 0
    restored: bool = False         # this incarnation is a supervisor respawn
    kill_at_step: int | None = None
    die_after_persist_step: int | None = None
    straggle_s: float = 0.0
    straggle_at_step: int | None = None
    stall_at_step: int | None = None
    stall_s: float = 0.0
    corrupt_at_step: int | None = None  # divergence drill (inline loop)
    # attach per-chunk digests of the full replicated state to PERSIST_DONE
    # so a digest_divergence alert can name the first forked chunk. Free in
    # proxy mode (the fused table rides SYNC info); the inline loop scans
    # the state, so disable for perf-sensitive inline runs.
    chunk_provenance: bool = True


# -- shard ownership -----------------------------------------------------------

def shard_tree_for_host(state, host: int, n_hosts: int):
    """Wrap every leaf in the HostShardView this host persists.

    Ownership is :func:`repro.checkpoint.sharded.host_slice_plan` — ONE
    definition shared with ``RestoreManager.restore_elastic``, so a
    committed image's shards re-slice bit-identically onto any other host
    count: leaves with a leading dimension >= n_hosts split contiguously
    along dim 0 (global index ranges recorded in the manifest); smaller
    leaves and scalars are whole-owned by a stable hash of their path, so
    exactly one hostmeta carries each byte and the merged manifest covers
    everything.
    """
    from repro.checkpoint.sharded import host_slice_plan

    flat, treedef = flatten_with_paths(state)
    out = {}
    for path, leaf in flat.items():
        arr = np.asarray(leaf)
        plan = host_slice_plan(path, arr.shape, host, n_hosts)
        if plan is None:
            out[path] = HostShardView(
                None, global_shape=arr.shape, dtype=arr.dtype
            )
        else:
            start, stop = plan
            window = tuple(slice(a, b) for a, b in zip(start, stop))
            out[path] = HostShardView(
                arr[window] if arr.ndim else arr,
                start=start,
                stop=stop,
                global_shape=arr.shape,
                dtype=arr.dtype,
            )
    return unflatten_from_paths(treedef, out)


def state_digest(state) -> str:
    """Order-stable content hash for lockstep-convergence assertions."""
    return tree_digest(state)


def _corrupt_state(state) -> None:
    """Divergence drill: flip one byte of the first device leaf, in place.

    A silent-corruption stand-in (bad DIMM, miscompiled kernel): the host
    keeps training on the perturbed weights, so every later digest forks
    too — the watchdog must name *this* chunk at the first boundary, not
    just "hosts disagree". Inline (numpy) state only: the leaves are the
    live arrays, so the flip lands in the math.
    """
    flat, _ = flatten_with_paths(state["device"])
    for path in sorted(flat):
        arr = np.asarray(flat[path])
        if arr.nbytes and arr.flags.c_contiguous and arr.flags.writeable:
            arr.reshape(-1).view(np.uint8)[0] ^= 0xFF
            return


# -- training loops ------------------------------------------------------------
#
# Device math lives in repro.proxy.programs (one definition of "a step",
# shared by inline workers, proxied workers and the proxy benchmarks); the
# loop classes only adapt a program to the worker's {"device", "host"}
# state layout and its restore/materialize hooks.

def _program_spec(cfg: WorkerConfig) -> dict:
    if cfg.loop == "numpy":
        return {
            "name": "numpy_sgd",
            "rows": cfg.rows or max(cfg.n_hosts, 2) * 8,
            "width": cfg.width,
            "seed": cfg.seed,
            "step_time_s": cfg.step_time_s,
        }
    if cfg.loop == "jax":
        return {"name": "jax_tiny", "width": cfg.width, "seed": cfg.seed}
    if cfg.loop.startswith("arch:"):
        # model-zoo worker (soak runs): a real repro.configs architecture
        # in smoke shape — the same program launch/train.py ships
        return {
            "name": "train_arch",
            "arch": cfg.loop.split(":", 1)[1],
            "smoke": True,
            "seed": cfg.seed,
        }
    raise ValueError(f"unknown worker loop {cfg.loop!r}")


def _resolve_capacity(spec: str, spec_dict: dict) -> int:
    """``"50%"`` of the program's state bytes, or absolute bytes."""
    s = spec.strip()
    if s.endswith("%"):
        from repro.proxy.programs import make_program

        nbytes = make_program(spec_dict).state_nbytes()
        return max(1, int(nbytes * float(s[:-1]) / 100.0))
    return int(s)


class _InlineLoop:
    """Run the step program in-process (the pre-proxy execution model)."""

    def __init__(self, cfg: WorkerConfig):
        from repro.proxy.programs import make_program

        self.cfg = cfg
        self.program = make_program(_program_spec(cfg))

    def init(self):
        return {
            "device": self.program.init_state(),
            "host": {"step": np.int64(0)},
        }

    def step(self, state, step: int):
        state["device"], _ = self.program.step(state["device"], step)
        return state

    def on_restore(self, state):
        state["device"] = self.program.on_restore(state["device"])
        return state

    def materialize(self, state):
        """Inline state is always current; nothing to pull."""
        return state

    def digest(self, state) -> str:
        return state_digest(state["device"])

    def set_ctx(self, ctx: dict | None) -> None:
        self.ctx = ctx  # inline steps emit no spans; kept for symmetry

    def chunk_digests(self, state) -> dict[str, list[int]] | None:
        """Full-state per-chunk digests for divergence provenance."""
        if not self.cfg.chunk_provenance:
            return None
        from repro.kernels.ops import tree_chunk_digests

        return tree_chunk_digests(state["device"], self.cfg.chunk_bytes)

    def close(self):
        pass


class _ProxyLoop:
    """Host the step program in a supervised device-proxy process.

    The worker stays device-clean: ``state["device"]`` is a host mirror
    refreshed by ``materialize()`` at persist boundaries and FINISHED; the
    proxy is respawned + replayed transparently if it dies mid-round.
    """

    def __init__(self, cfg: WorkerConfig):
        from repro.proxy import ProxyRunner

        self.cfg = cfg
        self.spec = _program_spec(cfg)
        self.last_digest: str | None = None
        self.last_chunk_digests: dict[str, list[int]] | None = None
        # segments/API log live under the cluster root, not /dev/shm: a
        # drill that hard-exits this worker (os._exit) skips close(), and
        # files under the root are reclaimed with it — a respawned
        # incarnation reuses the same directory instead of leaking
        # RAM-backed segments
        workdir = os.path.join(cfg.root, f"proxy-h{cfg.host:04d}")
        os.makedirs(workdir, exist_ok=True)
        provider = None
        if cfg.proxy_placement == "coord":
            # remote proxies: the coordinator assigns a proxy host (and a
            # survivor after a proxy-host death) via the PROXY_ENDPOINT
            # side channel — never this worker's barrier connection
            from repro.remote.placement import CoordEndpointProvider

            provider = CoordEndpointProvider(
                (cfg.coord_host, cfg.coord_port), cfg.host,
                timeout_s=cfg.deadline_s,
            )
        elif cfg.proxy_placement != "local":
            raise ValueError(
                f"unknown proxy_placement {cfg.proxy_placement!r}"
            )
        extra = {}
        if cfg.device_capacity is not None:
            # oversubscribed soak runs: cap the proxy's device budget so
            # the UVM pager is on the hot path while chaos fires
            extra["device_capacity_bytes"] = _resolve_capacity(
                cfg.device_capacity, self.spec
            )
        self.runner = ProxyRunner(
            self.spec,
            workdir=workdir,
            chunk_bytes=cfg.chunk_bytes,
            sync_timeout_s=cfg.persist_timeout_s,
            # a partitioned (SIGSTOPped) proxy host must be detected well
            # inside the round timeout, not after the default 120s
            op_timeout_s=cfg.persist_timeout_s,
            transport=cfg.proxy_transport,
            endpoint_provider=provider,
            **extra,
        )

    def init(self):
        dstate = self.runner.start()
        return {"device": dstate, "host": {"step": np.int64(0)}}

    def step(self, state, step: int):
        self.runner.step(step)
        return state  # mirror is stale until the next materialize()

    def on_restore(self, state):
        self.runner.start(
            device_state=state["device"],
            base_step=int(np.asarray(state["host"]["step"])),
        )
        return state

    def materialize(self, state):
        state["device"], info = self.runner.sync_state()
        # the proxy already digested the state during sync — keep it so
        # the persist ack's divergence check costs nothing extra here
        self.last_digest = info.get("digest") if isinstance(info, dict) \
            else None
        self.last_chunk_digests = (
            info.get("chunk_digests") if isinstance(info, dict) else None
        )
        return state

    def digest(self, state) -> str:
        return self.last_digest or state_digest(state["device"])

    def set_ctx(self, ctx: dict | None) -> None:
        # the runner mints a child context per STEP/SYNC/UPLOAD frame under
        # whatever is installed here (None = frames ride bare)
        self.runner.trace_ctx = ctx

    def chunk_digests(self, state) -> dict[str, list[int]] | None:
        """Per-chunk digests the proxy's SYNC already produced (free)."""
        if not self.cfg.chunk_provenance:
            return None
        return self.last_chunk_digests

    def close(self):
        self.runner.close()


def _make_loop(cfg: WorkerConfig):
    if cfg.device_runner == "proxy":
        return _ProxyLoop(cfg)
    if cfg.device_runner != "inline":
        raise ValueError(f"unknown device_runner {cfg.device_runner!r}")
    return _InlineLoop(cfg)


# -- the worker process --------------------------------------------------------

class _Heartbeat(threading.Thread):
    def __init__(self, conn: Connection, cfg: WorkerConfig):
        super().__init__(name=f"worker-{cfg.host}-heartbeat", daemon=True)
        self.conn, self.cfg = conn, cfg
        self.step = 0
        self.paused = threading.Event()
        self.stop = threading.Event()
        # causal context of the checkpoint window in flight (main thread
        # writes, this thread reads — a torn read just rides the next beat)
        self.ctx: dict | None = None
        # live telemetry: the registry delta since the last beat rides
        # inside the same framed sendall — zero extra syscalls per beat
        self.piggyback = HeartbeatPiggyback()

    def run(self) -> None:
        while not self.stop.wait(self.cfg.heartbeat_s):
            if self.paused.is_set():
                continue
            payload = self.piggyback.collect()
            extra = {}
            if payload is not None:
                extra["metrics"] = payload
            if self.ctx is not None:
                extra["ctx"] = self.ctx
            # wall-clock witness for the watchdog's clock_skew rule; the
            # chaos shim (soak drills) skews it while a sentinel is armed
            wt = time.time()
            if os.environ.get("CRUM_CHAOS_DIR"):
                from repro.chaos.faults import active as _chaos_active

                skew = _chaos_active("clock_skew", host=self.cfg.host)
                if skew is not None:
                    wt += float(skew.get("skew_s", 0.0))
            try:
                self.conn.send(MSG_HEARTBEAT, host=self.cfg.host,
                               step=self.step, wt=wt, **extra)
            except OSError:
                # coordinator kicked us (or died): this incarnation is over
                os._exit(1)


def _recv(conn: Connection, deadline: float) -> dict:
    while True:
        if time.monotonic() > deadline:
            raise TimeoutError("worker gave up waiting for the coordinator")
        try:
            msg = conn.recv()
        except (socket.timeout, TimeoutError):
            continue
        if msg is None:
            raise ConnectionError("coordinator closed the connection")
        return msg


def worker_entry(cfg: WorkerConfig) -> int:
    """Process entry point (multiprocessing spawn target)."""
    os.environ.setdefault("JAX_PLATFORMS", "cpu")  # simulated hosts are CPU
    obs_trace.enable_from_env(f"worker{cfg.host}")
    deadline = time.monotonic() + cfg.deadline_s
    conn = connect((cfg.coord_host, cfg.coord_port), timeout=cfg.deadline_s)
    conn.settimeout(cfg.sock_timeout_s)

    loop = _make_loop(cfg)
    store = ChunkStore(cfg.root)
    restorer = RestoreManager(store)
    ck = ForkedCheckpointer(
        store,
        codec=cfg.codec,
        chunk_bytes=cfg.chunk_bytes,
        incremental=cfg.incremental,
        digest_on_device=False,
        host=cfg.host,
        backend=cfg.backend,
        external_commit=True,
        # PERSIST_DONE is this host's promise that its payload bytes are on
        # stable storage; the coordinator's durable commit is meaningless if
        # data-h*.bin still lives in the page cache
        fsync=True,
    )

    # -- join + restore ------------------------------------------------------
    conn.send(MSG_JOIN, host=cfg.host, pid=os.getpid(), restored_from=None)
    welcome = _recv(conn, deadline)
    assert welcome["type"] == MSG_WELCOME, welcome
    # heartbeats must start *before* restore: a respawned worker restoring
    # a large image for longer than the heartbeat timeout would otherwise
    # be kicked as dead and crash-loop through its restart budget
    hb = _Heartbeat(conn, cfg)
    hb.start()
    latest = welcome.get("latest_committed")
    if latest is not None:
        state, _ = restorer.restore(step=latest)
        state = loop.on_restore(state)
        start = int(np.asarray(state["host"]["step"]))
        # tell the coordinator (and the round log) where we came back from
        conn.send(MSG_JOIN, host=cfg.host, pid=os.getpid(),
                  restored_from=latest)
        _recv(conn, deadline)  # the re-JOIN's WELCOME
    else:
        state = loop.init()
        start = int(np.asarray(state["host"]["step"]))
    hb.step = start

    step = start
    tr = obs_trace.get()
    window_ctx: dict | None = None
    try:
        while step < cfg.total_steps:
            step += 1
            if tr is not None and cfg.ckpt_every > 0:
                # the boundary this step marches toward names the round
                # trace; install its window context *before* the step so
                # proxy STEP frames issued mid-window join the round tree.
                # The parent is the deterministic round root — the
                # coordinator has not opened the round yet, but it will
                # derive the same root id from the same trace id.
                b = -(-step // cfg.ckpt_every) * cfg.ckpt_every
                trace_id = obs_trace.round_trace_id(b)
                if window_ctx is None or window_ctx["trace"] != trace_id:
                    window_ctx = obs_trace.span_context(
                        trace_id, parent=obs_trace.root_span_id(trace_id)
                    )
                    loop.set_ctx(window_ctx)
                    hb.ctx = window_ctx
            state = loop.step(state, step)
            state["host"]["step"] = np.int64(step)
            hb.step = step
            if cfg.corrupt_at_step == step and not cfg.restored:
                _corrupt_state(state)
            boundary = cfg.ckpt_every > 0 and step % cfg.ckpt_every == 0

            if cfg.stall_at_step == step and not cfg.restored:
                hb.paused.set()          # heartbeat miss -> coordinator kicks
                time.sleep(cfg.stall_s)
                hb.paused.clear()
            if cfg.kill_at_step == step and not cfg.restored:
                if boundary:
                    conn.send(MSG_READY, host=cfg.host, step=step)
                    time.sleep(0.05)     # let READY land: death is mid-round
                os._exit(EXIT_KILLED)

            if boundary:
                # proxy runner: pull the device mirror current before the
                # barrier — the persisted shards must reflect this step
                state = loop.materialize(state)
                _checkpoint_round(conn, cfg, ck, state, step, deadline,
                                  digest=loop.digest(state),
                                  chunk_digests=loop.chunk_digests(state),
                                  ctx=window_ctx)

        loop.set_ctx(None)  # the final sync belongs to no round
        hb.ctx = None
        state = loop.materialize(state)
        digest = state_digest(state["device"])
        conn.send(MSG_FINISHED, host=cfg.host, step=step, digest=digest)
        while True:
            msg = _recv(conn, deadline)
            if msg["type"] == MSG_SHUTDOWN:
                break
    finally:
        hb.stop.set()
        ck.close()
        loop.close()
        conn.close()
        obs_metrics.dump_if_enabled(f"worker{cfg.host}")
    return 0


def _checkpoint_round(
    conn: Connection,
    cfg: WorkerConfig,
    ck: ForkedCheckpointer,
    state,
    step: int,
    deadline: float,
    digest: str | None = None,
    chunk_digests: dict | None = None,
    ctx: dict | None = None,
) -> None:
    """Barrier at a boundary; persist on DRAIN; retry the round on ABORT."""
    tr = obs_trace.get()
    if tr is not None:
        # the span *is* the window context: mid-window proxy frames already
        # parented to ctx["span"], and this B/E (covering every retry of
        # the round) resolves them to the deterministic round root
        tr.begin("worker.round", step=step, host=cfg.host,
                 **obs_trace.ctx_args(ctx))
    try:
        _checkpoint_round_inner(conn, cfg, ck, state, step, deadline,
                                digest, chunk_digests, ctx)
    finally:
        if tr is not None:
            tr.end("worker.round")


def _checkpoint_round_inner(
    conn: Connection,
    cfg: WorkerConfig,
    ck: ForkedCheckpointer,
    state,
    step: int,
    deadline: float,
    digest: str | None = None,
    chunk_digests: dict | None = None,
    ctx: dict | None = None,
) -> None:
    conn.send(MSG_READY, host=cfg.host, step=step)
    while True:
        msg = _recv(conn, deadline)
        mtype, mstep = msg["type"], int(msg.get("step", -1))
        if mstep != step and mtype != MSG_SHUTDOWN:
            continue  # stale frame from a previous (aborted) round
        if mtype == MSG_DRAIN:
            _persist_shards(conn, cfg, ck, state, step, digest,
                            chunk_digests, ctx)
        elif mtype == MSG_COMMIT:
            ck.commit_confirmed(step)
            return
        elif mtype == MSG_ABORT:
            ck.commit_aborted(step)
            conn.send(MSG_READY, host=cfg.host, step=step)
        elif mtype == MSG_SHUTDOWN:
            # coordinator is tearing the cluster down mid-round
            raise SystemExit(0)


def _persist_shards(conn, cfg: WorkerConfig, ck, state, step: int,
                    digest: str | None = None,
                    chunk_digests: dict | None = None,
                    ctx: dict | None = None) -> None:
    shard = shard_tree_for_host(state, cfg.host, cfg.n_hosts)
    try:
        r = ck.save_async(
            step, shard, meta={"host": cfg.host, "n_hosts": cfg.n_hosts},
            trace_ctx=ctx,
        )
        try:
            r.wait(cfg.persist_timeout_s)
        except TimeoutError:
            # hung persist: die loudly, get respawned. Kill any forked
            # persist child first — an orphan holding an fd on data-h*.bin
            # could otherwise interleave writes with the respawned
            # incarnation's retry of the same file.
            ck.backend.kill_pending()
            os._exit(EXIT_WATCHDOG)
    except Exception as e:
        conn.send(MSG_PERSIST_FAIL, host=cfg.host, step=step, error=str(e))
        return
    if cfg.die_after_persist_step == step and not cfg.restored:
        os._exit(EXIT_MID_COMMIT)  # hostmeta is durable, ack never sent
    if cfg.straggle_s and cfg.straggle_at_step in (None, step):
        time.sleep(cfg.straggle_s)  # heartbeats continue: slow, not dead
    extra = {}
    if ctx is not None:
        # echo the round context so the coordinator's quorum instant
        # (coord.ack) parents under this worker's round span
        extra["ctx"] = ctx
    if chunk_digests and sum(map(len, chunk_digests.values())) <= 65536:
        # divergence provenance: per-chunk digests of the full replicated
        # state (size-capped — a pathological chunk count must not blow
        # the 16 MiB control-frame limit)
        extra["chunk_digests"] = chunk_digests
    conn.send(
        MSG_PERSIST_DONE,
        host=cfg.host,
        step=step,
        **extra,
        hostmeta=f"hostmeta-h{cfg.host:04d}.msgpack",
        persist_s=r.persist_s,
        blocking_s=r.blocking_s,
        bytes_written=r.bytes_written,
        chunks_written=r.chunks_written,
        chunks_reused=r.chunks_reused,
        # incremental sync economy: what the digest gate (or page dirty
        # bits) spared this host in phase 1 — the coordinator aggregates
        # these into the round record so CLUSTER_LOG.jsonl shows per-round
        # delta efficiency, not just bytes that did move
        chunks_synced=r.chunks_synced,
        chunks_clean=r.chunks_clean,
        bytes_skipped=r.bytes_skipped,
        # phase-1 breakdown (hot-path observability): where the blocking
        # microseconds went on this host, and how long it stalled on a
        # pipelined sync ack (0 when the sync path is the inline barrier)
        sync_us=r.sync_us,
        digest_us=r.digest_us,
        fetch_us=r.fetch_us,
        stall_us=r.stall_us,
        # lockstep witness for the watchdog's divergence rule: every host
        # acking this round must hold the same replicated state
        state_digest=digest,
    )
