"""CRUM core — the paper's contribution, adapted to TPU/JAX (see DESIGN.md)."""
from repro.core.shadow import (
    ShadowStateManager,
    ChunkState,
    SyncStats,
    UploadStats,
    HostShardView,
)
from repro.core.forked import (
    CheckpointResult,
    ForkedCheckpointer,
    ForkPersistBackend,
    PersistBackend,
    PersistJob,
    ThreadPersistBackend,
    list_persist_backends,
    register_persist_backend,
)
from repro.core.restore import RestoreManager, LazyLeaves
from repro.core.drain import drain
from repro.core.policy import CheckpointPolicy, referenced_steps
from repro.core.failure import (
    HeartbeatMonitor,
    RestartBudget,
    StragglerPolicy,
    PreemptionHandler,
)
from repro.core.trainer import CheckpointedTrainer

__all__ = [
    "ShadowStateManager", "ChunkState", "SyncStats", "UploadStats",
    "HostShardView",
    "ForkedCheckpointer", "CheckpointResult",
    "PersistBackend", "PersistJob",
    "ThreadPersistBackend", "ForkPersistBackend",
    "list_persist_backends", "register_persist_backend",
    "RestoreManager", "LazyLeaves", "drain",
    "CheckpointPolicy", "referenced_steps",
    "HeartbeatMonitor", "RestartBudget", "StragglerPolicy",
    "PreemptionHandler",
    "CheckpointedTrainer",
]
