"""CRUM core — the paper's contribution, adapted to TPU/JAX (see DESIGN.md)."""
from repro.core.shadow import ShadowStateManager, ChunkState, SyncStats
from repro.core.forked import ForkedCheckpointer, CheckpointResult
from repro.core.restore import RestoreManager, LazyLeaves
from repro.core.drain import drain
from repro.core.policy import CheckpointPolicy, referenced_steps
from repro.core.failure import HeartbeatMonitor, StragglerPolicy, PreemptionHandler
from repro.core.trainer import CheckpointedTrainer

__all__ = [
    "ShadowStateManager", "ChunkState", "SyncStats",
    "ForkedCheckpointer", "CheckpointResult",
    "RestoreManager", "LazyLeaves", "drain",
    "CheckpointPolicy", "referenced_steps",
    "HeartbeatMonitor", "StragglerPolicy", "PreemptionHandler",
    "CheckpointedTrainer",
]
