"""Quiesce the device before a snapshot (paper §3.4: cudaDeviceSynchronize +
MPI network drain).

JAX's dispatch is asynchronous — the Python train loop runs ahead of the
device exactly like CRUM's pipelined proxy calls run ahead of the GPU.
``drain`` is the pipeline flush: block until every in-flight computation
contributing to ``state`` has landed, then (multi-host) barrier so no host
snapshots while a peer still has collectives in flight.
"""
from __future__ import annotations

from typing import Any

import jax

from repro.utils.timing import Timer


def drain(state: Any, *, barrier: bool = True) -> float:
    """Returns seconds spent draining.

    The device-proxy runner has its own pipeline of the same shape —
    forwarded STEP calls the app issued ahead of the proxy — and its own
    flush (``repro.proxy.ProxyRunner.drain`` / the SYNC barrier), which
    the trainer runs *before* handing the host mirror to this path: the
    ordering CRUM imposes on pipelined proxy calls before
    cudaDeviceSynchronize.
    """
    with Timer() as t:
        jax.block_until_ready(state)
        if barrier and jax.process_count() > 1:  # pragma: no cover (multi-host)
            from jax.experimental import multihost_utils

            multihost_utils.sync_global_devices("crum-drain")
    return t.elapsed
