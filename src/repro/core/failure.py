"""Failure detection + straggler mitigation for checkpoint I/O at scale.

At thousands of nodes the paper's failure model (GPU DUEs) is joined by
host-level failure modes: a host that stops heartbeating mid-checkpoint,
and stragglers whose storage writes stall the commit. Mechanisms:

  - HeartbeatMonitor: per-host liveness with a miss threshold; the
    coordinator refuses to commit a manifest while a participating host is
    dead (restart picks the previous committed step — correctness comes
    from the commit protocol, not from luck).
  - StragglerPolicy: per-host persist durations; hosts beyond
    ``multiplier`` x median are flagged, and their shard assignments can be
    rebalanced to buddy hosts for the *next* checkpoint (write paths are
    content-addressed, so any host may persist any chunk it holds a replica
    of — replicated leaves give natural buddies).
  - PreemptionHandler: SIGTERM -> policy.request_preempt_checkpoint().

In this container everything runs single-host; the classes are exercised
by simulation in tests (multi-host wiring is jax.process_index()-keyed).
"""
from __future__ import annotations

import signal
import statistics
import threading
import time
from dataclasses import dataclass, field


class RestartBudget:
    """How many times one supervised process may die before we give up.

    Shared by the cluster supervisor (per-host worker respawns) and the
    device-proxy supervisor (proxy respawn + API-log replay): both convert
    "died again" into either a respawn or a loud, attributable failure.
    """

    def __init__(self, max_restarts: int = 3, *, what: str = "process"):
        self.max_restarts = int(max_restarts)
        self.what = what
        self.count = 0

    def spend(self, detail: str = "") -> int:
        """Record one death; raises once the budget is exhausted."""
        self.count += 1
        if self.count > self.max_restarts:
            suffix = f" ({detail})" if detail else ""
            raise RuntimeError(
                f"{self.what} died {self.count} times{suffix}; giving up"
            )
        return self.count

    @property
    def remaining(self) -> int:
        return max(0, self.max_restarts - self.count)


class HeartbeatMonitor:
    def __init__(self, hosts: list[int], *, timeout_s: float = 30.0):
        self.timeout_s = timeout_s
        self._last: dict[int, float] = {h: time.monotonic() for h in hosts}
        self._lock = threading.Lock()

    def beat(self, host: int, at: float | None = None) -> None:
        with self._lock:
            self._last[host] = time.monotonic() if at is None else at

    # dynamic membership: the cluster coordinator adds a host at JOIN and
    # removes it when its connection drops (it re-adds on rejoin), so a
    # dead host stops counting against liveness once it has been kicked.
    def add_host(self, host: int) -> None:
        self.beat(host)

    def remove_host(self, host: int) -> None:
        with self._lock:
            self._last.pop(host, None)

    def dead_hosts(self, now: float | None = None) -> list[int]:
        now = time.monotonic() if now is None else now
        with self._lock:
            return sorted(
                h for h, t in self._last.items() if now - t > self.timeout_s
            )

    def all_alive(self, now: float | None = None) -> bool:
        return not self.dead_hosts(now)


@dataclass
class StragglerPolicy:
    """Flag hosts whose checkpoint-persist durations are outliers."""

    multiplier: float = 3.0
    min_samples: int = 3
    history: dict[int, list[float]] = field(default_factory=dict)

    def record(self, host: int, persist_s: float) -> None:
        self.history.setdefault(host, []).append(persist_s)

    def _latest(self) -> dict[int, float]:
        return {h: v[-1] for h, v in self.history.items() if v}

    def stragglers(self) -> list[int]:
        latest = self._latest()
        if len(latest) < self.min_samples:
            return []
        med = statistics.median(latest.values())
        if med <= 0:
            return []
        return sorted(h for h, v in latest.items() if v > self.multiplier * med)

    def rebalance(self, assignments: dict[int, list], buddies: dict[int, int]) -> dict[int, list]:
        """Move a straggler's shard list onto its buddy for the next round."""
        out = {h: list(v) for h, v in assignments.items()}
        for s in self.stragglers():
            b = buddies.get(s)
            if b is None or b == s or b not in out:
                continue
            out[b].extend(out[s])
            out[s] = []
        return out


class PreemptionHandler:
    """SIGTERM -> checkpoint-now; the paper's 'checkpoint before the failure'."""

    def __init__(self, policy):
        self.policy = policy
        self.received = threading.Event()
        self._prev = None

    def install(self) -> "PreemptionHandler":
        def _handler(signum, frame):
            self.received.set()
            self.policy.request_preempt_checkpoint()

        self._prev = signal.signal(signal.SIGTERM, _handler)
        return self

    def uninstall(self) -> None:
        if self._prev is not None:
            signal.signal(signal.SIGTERM, self._prev)
            self._prev = None
