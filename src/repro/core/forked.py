"""ForkedCheckpointer — the paper's §3.3 forked checkpointing model.

CRUM's two phases, TPU-native:

  phase 1  "drain the device"  : block the train loop only for
           (a) flushing the async dispatch queue (drain), and
           (b) syncing the shadow snapshot (digest-gated device->host
               transfer of dirty chunks only).
  phase 2  "forked child writes": the persist backend compresses and writes
           the immutable snapshot to stable storage *while training
           continues*.

Phase 2 is pluggable (``backend=``):

  ``thread``  a writer-pool thread persists the snapshot. The snapshot
              buffers are plain host memory the train loop never touches, so
              immutability is structural — but compression shares the
              parent's GIL and memory bandwidth, so a heavy persist can
              still steal cycles from the train loop.
  ``fork``    the paper's actual mechanism: ``os.fork()`` a child per
              checkpoint. Shadow buffers live in anonymous MAP_SHARED mmap
              segments (see ShadowStateManager), so the child sees the
              snapshot at zero copy cost; it compresses, writes chunks to
              the ChunkStore, commits the manifest, and streams
              CheckpointResult fields (bytes written, chunks reused, errors)
              back over a pipe. A supervisor thread per child reaps it and
              converts a non-zero exit into ``CheckpointResult.error``.
              ``max_pending`` bounds *live children* — the paper's
              one-forked-child-at-a-time discipline at N=1.

Double buffering (max_pending+1 ShadowStateManagers) lets checkpoint N+1's
phase 1 begin while checkpoint N's phase 2 is still writing — after which
phase 1 blocks.

Blocking time (what the application observes) is accounted separately from
total persist time: the 40x headline of Table 2 is precisely
``blocking_time / naive_synchronous_time``.
"""
from __future__ import annotations

import concurrent.futures as cf
import os
import pickle
import struct
import threading
import time
import warnings
from dataclasses import dataclass, field
from typing import Any, BinaryIO, Callable

import numpy as np

from repro.checkpoint.chunking import DEFAULT_CHUNK_BYTES, chunk_digest_np, iter_chunks
from repro.checkpoint.codecs import DEFAULT_CODEC
from repro.checkpoint.manifest import (
    LeafRecord,
    Manifest,
    ShardRecord,
    build_skeleton,
    commit_manifest,
    write_hostmeta,
)
from repro.checkpoint.store import ChunkStore
from repro.core.drain import drain
from repro.core.shadow import ShadowStateManager
from repro.obs import metrics as obs_metrics
from repro.obs import trace as obs_trace
from repro.utils.timing import Timings
from repro.utils.tree import flatten_with_paths


@dataclass
class CheckpointResult:
    step: int
    blocking_s: float          # what the train loop paid (phase 1)
    persist_s: float = 0.0     # background write time (phase 2)
    bytes_snapshot: int = 0    # bytes moved device->host
    bytes_written: int = 0     # bytes written to storage (compressed)
    chunks_written: int = 0
    chunks_reused: int = 0     # delta references (incremental mode)
    # phase-1 sync economy (what the digest gate / page dirty bits saved):
    chunks_synced: int = 0     # chunks actually fetched device->host
    chunks_clean: int = 0      # chunks the sync proved (or knew) unchanged
    bytes_skipped: int = 0     # bytes the clean chunks did NOT move
    # phase-1 breakdown (microseconds): where the blocking time went —
    # digesting (0 when fused digests pre-hashed the boundary), fetching
    # dirty chunks, the whole shadow sync, and — proxy mode — how long the
    # train loop actually stalled waiting for the pipelined SYNCED ack
    sync_us: float = 0.0
    digest_us: float = 0.0
    fetch_us: float = 0.0
    stall_us: float = 0.0
    error: str | None = None
    done: threading.Event = field(default_factory=threading.Event, repr=False)

    def wait(self, timeout: float | None = None) -> "CheckpointResult":
        if not self.done.wait(timeout):
            raise TimeoutError(f"checkpoint step {self.step} still pending")
        if self.error:
            raise RuntimeError(f"checkpoint step {self.step} failed: {self.error}")
        return self


@dataclass
class PersistJob:
    """Everything phase 2 needs, captured at the end of phase 1."""

    result: CheckpointResult
    buf_index: int
    shadow: ShadowStateManager
    snapshot: dict[tuple[str, int], dict]
    skeleton: Any
    shapes_dtypes: dict[str, tuple[list, str]]
    prev: Manifest | None
    meta: dict
    shadow_gen: int = 0        # buffer generation the snapshot belongs to
    # causal context the ckpt.persist span is emitted with (child of the
    # phase-1 span); the fork child echoes it on the result pipe's final
    # record, so even a persist whose parent worker was SIGKILL'd leaves
    # an attributable span in the round tree
    trace_ctx: dict | None = None


def _persist_image(
    store: ChunkStore,
    *,
    step: int,
    host: int,
    codec: str,
    chunk_bytes: int,
    fsync: bool,
    snapshot: dict[tuple[str, int], dict],
    skeleton: Any,
    shapes_dtypes: dict,
    prev: Manifest | None,
    meta: dict,
    counters: CheckpointResult,
    writer: "ChunkStore.Writer | None" = None,
    progress: Callable[[], None] | None = None,
    external_commit: bool = False,
) -> tuple[Manifest, dict[tuple[str, int], list[int]]]:
    """Compress + write one snapshot and commit (or stage) its manifest.

    Backend-agnostic phase 2: runs on a writer-pool thread (thread backend)
    or inside a forked child (fork backend). Mutates ``counters``
    (chunks/bytes written, chunks reused) as it goes and returns the
    committed manifest plus the per-stream chunk digests for shadow
    backfill. ``progress`` (if given) is called after each leaf so callers
    can stream counters while the image is still being written.

    With ``external_commit`` the image is *staged*, not committed: the
    host's manifest fragment lands as ``hostmeta-h*.msgpack`` and writing
    MANIFEST + COMMIT belongs to the cluster coordinator once every
    participant has acked (two-phase commit; see repro.coord).
    """
    prev_map: dict[tuple, Any] = {}
    if prev is not None:
        for path, lv in prev.leaves.items():
            for s in lv.shards:
                for c in s.chunks:
                    prev_map[(path, tuple(s.start), tuple(s.stop), c.index)] = c

    manifest = Manifest(step=step, skeleton=skeleton, meta=dict(meta))
    digests_out: dict[tuple[str, int], list[int]] = {}
    if writer is None:
        writer = store.writer(step, host)
    try:
        by_path: dict[str, list] = {}
        for (path, ordinal), shard in sorted(snapshot.items()):
            shard = dict(shard)
            shard["ordinal"] = ordinal
            by_path.setdefault(path, []).append(shard)
        for path, (shape, dtype) in shapes_dtypes.items():
            lrec = LeafRecord(path=path, shape=shape, dtype=dtype)
            for shard in by_path.get(path, []):
                srec = ShardRecord(start=shard["start"], stop=shard["stop"])
                shard_digests: list[int] = []
                # digests the shadow already knows (maintained by sync and
                # upload) need not be re-hashed; negative entries are the
                # "unknown / backfill pending" sentinels and are recomputed
                known = shard.get("digests")
                for key, raw in iter_chunks(path, shard["data"], chunk_bytes):
                    if (
                        known is not None
                        and key.index < len(known)
                        and known[key.index] >= 0
                    ):
                        digest = known[key.index]
                    else:
                        digest = chunk_digest_np(raw)
                    shard_digests.append(digest)
                    old = prev_map.get(
                        (path, tuple(srec.start), tuple(srec.stop), key.index)
                    )
                    if (
                        old is not None
                        and old.digest == digest
                        and old.raw_len == len(raw)
                    ):
                        srec.chunks.append(old)
                        counters.chunks_reused += 1
                    else:
                        rec = writer.append(
                            raw, codec, index=key.index, digest=digest
                        )
                        srec.chunks.append(rec)
                        counters.chunks_written += 1
                        counters.bytes_written += rec.comp_len
                lrec.shards.append(srec)
                digests_out[(path, shard["ordinal"])] = shard_digests
            manifest.leaves[path] = lrec
            if progress is not None:
                progress()
    finally:
        writer.close(fsync=fsync)
    manifest.meta.update(
        chunks_written=counters.chunks_written,
        chunks_reused=counters.chunks_reused,
    )
    if external_commit:
        write_hostmeta(store.root, step, host, manifest)
    else:
        # directory durability tracks the payload fsync knob: without the
        # payload bytes being fsynced, fsyncing directory entries buys
        # nothing, and with them it completes the power-failure story
        commit_manifest(store.root, manifest, durable=fsync)
    return manifest, digests_out


# --------------------------------------------------------------------------
# Persist backends (phase 2 strategies)
# --------------------------------------------------------------------------

class PersistBackend:
    """Phase-2 strategy: how a finished snapshot reaches stable storage."""

    name: str = "?"
    # True: the backend reads snapshots from another process, so shadow
    # buffers must live in MAP_SHARED mmap segments that survive os.fork()
    # without COW page duplication (any forking plugin backend wants this)
    wants_shared_buffers: bool = False

    def __init__(self, checkpointer: "ForkedCheckpointer"):
        self.ck = checkpointer

    def submit(self, job: PersistJob) -> None:
        raise NotImplementedError

    def close(self) -> None:
        """Wait for in-flight persists and release backend resources."""

    def kill_pending(self) -> None:
        """Forcibly stop in-flight persists (no-op unless the backend owns
        other processes). A worker about to hard-exit on a hung persist
        calls this so no orphan keeps an fd on files a respawned
        incarnation will truncate and rewrite."""


class ThreadPersistBackend(PersistBackend):
    """Writer-pool threads in-process (the pre-fork emulation).

    Codecs release the GIL inside compress, so phase 2 overlaps the train
    loop — but it still shares the parent's scheduler and memory bandwidth.
    """

    name = "thread"

    def __init__(self, checkpointer: "ForkedCheckpointer"):
        super().__init__(checkpointer)
        workers = checkpointer.io_workers or min(8, (os.cpu_count() or 2))
        self._pool = cf.ThreadPoolExecutor(
            max_workers=workers, thread_name_prefix="crum-writer"
        )

    def submit(self, job: PersistJob) -> None:
        self._pool.submit(self._run, job)

    def _run(self, job: PersistJob) -> None:
        ck, result = self.ck, job.result
        t0 = time.perf_counter()
        try:
            manifest, digests = _persist_image(
                ck.store,
                step=result.step,
                host=ck.host,
                codec=ck.codec,
                chunk_bytes=ck.chunk_bytes,
                fsync=ck.fsync,
                snapshot=job.snapshot,
                skeleton=job.skeleton,
                shapes_dtypes=job.shapes_dtypes,
                prev=job.prev,
                meta=job.meta,
                counters=result,
                external_commit=ck.external_commit,
            )
            for key, d in digests.items():
                job.shadow.set_digests(key, d, generation=job.shadow_gen)
            ck._note_manifest(manifest)
        except Exception as e:  # surfaced at wait()
            result.error = f"{type(e).__name__}: {e}"
        finally:
            result.persist_s = time.perf_counter() - t0
            tr = obs_trace.get()
            if tr is not None:
                tr.complete("ckpt.persist", t0, step=result.step,
                            backend="thread",
                            bytes_written=result.bytes_written,
                            **obs_trace.ctx_args(job.trace_ctx))
            ck._finish_job(job)

    def close(self) -> None:
        self._pool.shutdown(wait=True)


# ---- fork backend pipe protocol: u32-length-prefixed pickles --------------

def _send_msg(f: BinaryIO, obj: Any) -> None:
    data = pickle.dumps(obj, protocol=pickle.HIGHEST_PROTOCOL)
    f.write(struct.pack("<I", len(data)))
    f.write(data)
    f.flush()


def _recv_msg(f: BinaryIO) -> Any | None:
    hdr = f.read(4)
    if len(hdr) < 4:
        return None  # EOF: child exited (or died) after its last message
    (n,) = struct.unpack("<I", hdr)
    data = f.read(n)
    if len(data) < n:
        return None  # truncated: child died mid-message
    return pickle.loads(data)


class ForkPersistBackend(PersistBackend):
    """True copy-on-write persistence: one ``os.fork()`` child per image.

    The paper's mechanism. The snapshot lives in MAP_SHARED mmap segments,
    so the fork costs no copy and the parent's ongoing training never
    triggers COW page duplication of the image. The child owns the whole
    compress+write+commit path (its own GIL, its own scheduler slice) and
    streams counters and the committed manifest back over a pipe; a
    supervisor thread reaps it and surfaces any failure — including a raw
    non-zero exit — as ``CheckpointResult.error``.
    """

    name = "fork"
    wants_shared_buffers = True

    def __init__(self, checkpointer: "ForkedCheckpointer"):
        if not hasattr(os, "fork"):
            raise RuntimeError(
                "persist backend 'fork' requires os.fork (POSIX); "
                "use backend='thread' on this platform"
            )
        super().__init__(checkpointer)
        self._cond = threading.Condition()
        self._live: dict[int, threading.Thread] = {}  # pid -> supervisor
        self._closed = False

    def submit(self, job: PersistJob) -> None:
        ck = self.ck
        # One continuous hold of _cond covers gate-check, pipe, fork and
        # registration, so (a) two concurrent submits can't both pass an
        # empty _live and overshoot max_pending — the paper's at-most-N
        # live children discipline — and (b) no sibling fork can run while
        # our write fd is open and leak it into an unrelated child, which
        # would rob the supervisor of EOF if our child dies silently.
        with self._cond:
            while len(self._live) >= ck.max_pending:
                self._cond.wait()
            if self._closed:
                raise RuntimeError("persist backend is closed")
            # built pre-fork, opened post-fork: child-safe writer handoff
            writer = ck.store.writer(job.result.step, ck.host, lazy=True)
            rfd, wfd = os.pipe()
            with warnings.catch_warnings():
                # jax warns that fork + its internal threads can deadlock;
                # the child never calls back into jax/XLA — it only
                # compresses host memory and writes files — so none of
                # those locks are taken.
                warnings.filterwarnings(
                    "ignore", message="os.fork", category=RuntimeWarning
                )
                pid = os.fork()
            if pid == 0:  # ---- child: persist and report, then _exit ------
                code = 0
                try:
                    os.close(rfd)
                    self._child_main(job, writer, wfd)
                except BaseException:
                    code = 1
                finally:
                    os._exit(code)
            # ---- parent ----------------------------------------------------
            os.close(wfd)
            t = threading.Thread(
                target=self._supervise, args=(pid, rfd, job),
                name=f"crum-fork-supervisor-{job.result.step}", daemon=True,
            )
            self._live[pid] = t
        t.start()

    def _child_main(self, job: PersistJob, writer, wfd: int) -> None:
        ck = self.ck
        counters = job.result  # the child's private copy of the result
        out = os.fdopen(wfd, "wb")
        t0 = time.perf_counter()
        err: str | None = None
        manifest = digests = None
        # the child inherits the parent's registry at fork: snapshot now so
        # only what THIS persist adds ships back over the result pipe
        reg_base = obs_metrics.REGISTRY.counters_snapshot()

        def stream_counters() -> None:
            _send_msg(out, {
                "kind": "progress",
                "chunks_written": counters.chunks_written,
                "chunks_reused": counters.chunks_reused,
                "bytes_written": counters.bytes_written,
            })

        try:
            manifest, digests = _persist_image(
                ck.store,
                step=counters.step,
                host=ck.host,
                codec=ck.codec,
                chunk_bytes=ck.chunk_bytes,
                fsync=ck.fsync,
                snapshot=job.snapshot,
                skeleton=job.skeleton,
                shapes_dtypes=job.shapes_dtypes,
                prev=job.prev,
                meta=job.meta,
                counters=counters,
                writer=writer,
                progress=stream_counters,
                external_commit=ck.external_commit,
            )
        except Exception as e:
            err = f"{type(e).__name__}: {e}"
        tr = obs_trace.get()
        if tr is not None:
            # emitted in the forked child: the tracer notices the pid
            # change and writes a shard of its own — the merged timeline
            # shows the COW persist running beside the training steps
            tr.complete("ckpt.persist", t0, step=counters.step,
                        backend="fork", error=err,
                        bytes_written=counters.bytes_written,
                        **obs_trace.ctx_args(job.trace_ctx))
        obs_metrics.REGISTRY.inc("ckpt_fork_persists_total")
        obs_metrics.REGISTRY.inc("ckpt_fork_bytes_written",
                                 counters.bytes_written)
        obs_metrics.REGISTRY.inc("ckpt_fork_chunks_written",
                                 counters.chunks_written)
        final: dict[str, Any] = {
            "kind": "final",
            "error": err,
            "persist_s": time.perf_counter() - t0,
            "chunks_written": counters.chunks_written,
            "chunks_reused": counters.chunks_reused,
            "bytes_written": counters.bytes_written,
            "registry_delta": obs_metrics.counter_delta(
                reg_base, obs_metrics.REGISTRY.counters_snapshot()
            ),
            # causal context of the persist span, echoed back over the
            # result pipe: the supervising parent (or a post-mortem reader
            # of a torn pipe) can attribute this child's work even though
            # the span itself lives in the child's own shard
            "ctx": job.trace_ctx,
        }
        if err is None:
            final["manifest"] = manifest.to_bytes()
            final["digests"] = digests
        _send_msg(out, final)
        out.close()

    def _supervise(self, pid: int, rfd: int, job: PersistJob) -> None:
        ck, result = self.ck, job.result
        t0 = time.perf_counter()
        final: dict | None = None
        try:
            with os.fdopen(rfd, "rb") as pipe:
                while True:
                    msg = _recv_msg(pipe)
                    if msg is None:
                        break
                    if msg["kind"] == "progress":
                        result.chunks_written = msg["chunks_written"]
                        result.chunks_reused = msg["chunks_reused"]
                        result.bytes_written = msg["bytes_written"]
                    elif msg["kind"] == "final":
                        final = msg
        except Exception as e:
            result.error = f"persist pipe error: {type(e).__name__}: {e}"
        _, status = os.waitpid(pid, 0)
        exit_code = os.waitstatus_to_exitcode(status)
        try:
            if final is not None:
                result.chunks_written = final["chunks_written"]
                result.chunks_reused = final["chunks_reused"]
                result.bytes_written = final["bytes_written"]
                result.persist_s = final["persist_s"]
                # fold the child's counter delta into this process's
                # registry — child metrics ride the pipe they always rode
                obs_metrics.REGISTRY.merge_counters(
                    final.get("registry_delta") or {}
                )
                if final["error"]:
                    result.error = final["error"]
                else:
                    for key, d in final["digests"].items():
                        job.shadow.set_digests(key, d, generation=job.shadow_gen)
                    ck._note_manifest(Manifest.from_bytes(final["manifest"]))
            if result.error is None and final is None:
                result.error = (
                    f"persist child (pid {pid}) died before reporting "
                    f"(exit code {exit_code})"
                )
            elif result.error is None and exit_code != 0:
                result.error = (
                    f"persist child (pid {pid}) exited with code {exit_code}"
                )
        finally:
            if result.persist_s == 0.0:
                result.persist_s = time.perf_counter() - t0
            with self._cond:
                self._live.pop(pid, None)
                self._cond.notify_all()
            ck._finish_job(job)

    def close(self) -> None:
        with self._cond:
            self._closed = True
            threads = list(self._live.values())
        for t in threads:
            t.join()

    def kill_pending(self) -> None:
        import signal

        with self._cond:
            pids = list(self._live)
        for pid in pids:
            try:
                os.kill(pid, signal.SIGKILL)
            except ProcessLookupError:
                pass


_PERSIST_BACKENDS: dict[str, Callable[["ForkedCheckpointer"], PersistBackend]] = {
    ThreadPersistBackend.name: ThreadPersistBackend,
    ForkPersistBackend.name: ForkPersistBackend,
}


def register_persist_backend(
    name: str, factory: Callable[["ForkedCheckpointer"], PersistBackend],
    *, replace: bool = False,
) -> None:
    """Plugin point: later scaling work (multi-host persist, remote object
    stores, incremental GC offload) registers here."""
    if name in _PERSIST_BACKENDS and not replace:
        raise ValueError(f"persist backend {name!r} already registered")
    _PERSIST_BACKENDS[name] = factory


def list_persist_backends() -> list[str]:
    return sorted(_PERSIST_BACKENDS)


def make_persist_backend(name: str, checkpointer: "ForkedCheckpointer") -> PersistBackend:
    try:
        factory = _PERSIST_BACKENDS[name]
    except KeyError:
        raise KeyError(
            f"unknown persist backend {name!r}; have {sorted(_PERSIST_BACKENDS)}"
        ) from None
    return factory(checkpointer)


# --------------------------------------------------------------------------
# The checkpointer
# --------------------------------------------------------------------------

class ForkedCheckpointer:
    def __init__(
        self,
        store: ChunkStore,
        *,
        codec: str = DEFAULT_CODEC,
        chunk_bytes: int = DEFAULT_CHUNK_BYTES,
        io_workers: int | None = None,
        max_pending: int = 1,
        incremental: bool = True,
        digest_on_device: bool = True,
        host: int = 0,
        fsync: bool = False,
        backend: str = "thread",
        external_commit: bool = False,
        dirty_source: Any = None,
        timings: Timings | None = None,
    ):
        self.store = store
        self.codec = codec
        self.chunk_bytes = int(chunk_bytes)
        self.incremental = incremental
        self.host = host
        self.fsync = fsync
        self.io_workers = io_workers
        self.max_pending = max(1, int(max_pending))
        # external_commit: persist writes hostmeta-h*.msgpack only; the
        # cluster coordinator merges hostmetas and owns MANIFEST + COMMIT.
        # Incremental deltas must then base on *cluster-committed* images
        # only: a staged manifest becomes the delta base via
        # commit_confirmed(), never implicitly (an aborted round's chunks
        # may be overwritten by the retry).
        self.external_commit = external_commit
        # dirty_source: page-granular dirty history (a ManagedSpace adapter:
        # tick() + dirty_chunk_marks_since(tick, chunk_bytes)). When set,
        # phase 1 marks exactly the chunks written since THIS buffer's last
        # sync — page-delta sync instead of whole-leaf digest scans.
        self.dirty_source = dirty_source
        self.timings = timings or Timings()
        self._pending: list[CheckpointResult] = []
        self._prev_manifest: Manifest | None = None
        self._staged: dict[int, Manifest] = {}  # step -> unconfirmed manifest
        self._lock = threading.Lock()
        self.backend = make_persist_backend(backend, self)
        self._buffers = [
            ShadowStateManager(
                chunk_bytes=chunk_bytes,
                digest_on_device=digest_on_device,
                defer_first_digests=True,  # persist backfills via set_digests
                shared_buffers=self.backend.wants_shared_buffers,
                timings=self.timings,
            )
            for _ in range(self.max_pending + 1)
        ]
        # one condition variable guards buffer ownership: acquisition is a
        # claim-under-lock, not the old busy-event scan that let two waiters
        # race for the buffer freed by the oldest pending checkpoint
        self._buf_cond = threading.Condition()
        self._buf_busy = [False] * len(self._buffers)
        # per-buffer dirty-source watermark: buffer i's shadow content is
        # current as of tick _buf_tick[i]; each buffer diffs against its OWN
        # last sync (double buffering means buffers alternate checkpoints)
        self._buf_tick = [-1] * len(self._buffers)
        # steps whose payload an in-flight (uncommitted) delta persist still
        # references — GC must not collect them out from under the child
        self._inflight_bases: dict[int, set[int]] = {}

    # -- the checkpoint entry point ------------------------------------------
    def save_async(
        self,
        step: int,
        state: Any,
        *,
        meta: dict | None = None,
        device_digests: dict[str, list[int]] | None = None,
        trace_ctx: dict | None = None,
    ) -> CheckpointResult:
        """Phase 1 inline (blocking, fast); phase 2 on the persist backend.

        ``device_digests`` are per-chunk digests the step already computed
        as a fused final pass (``kernels.ops.tree_chunk_digests``): the
        boundary sync compares them instead of re-scanning the state, so
        ``digest_us`` drops to zero for covered leaves. Composes with
        ``dirty_source`` page marks (the intersection is fetched).

        ``trace_ctx`` is an optional causal context from the caller's round
        span: phase 1 records a child span of it, and the persist job (even
        across a fork) records a grandchild, so checkpoint latency shows up
        on the round's causal tree."""
        result = CheckpointResult(step=step, blocking_s=0.0)
        with self.timings.measure("ckpt/blocking") as _:
            t0 = time.perf_counter()
            # pick a free snapshot buffer (waits if all are persisting)
            buf_i = self._acquire_buffer()
            shadow = self._buffers[buf_i]
            marks = None
            now_tick = None
            if self.dirty_source is not None:
                # capture the tick BEFORE reading state: a write racing the
                # capture lands after it and stays dirty for the next sync
                now_tick = self.dirty_source.tick()
                marks = self.dirty_source.dirty_chunk_marks_since(
                    self._buf_tick[buf_i], self.chunk_bytes
                )
            with self.timings.measure("ckpt/drain"):
                drain(state)
            with self.timings.measure("ckpt/snapshot"):
                shadow.mark_device_step(marks)
                t_sync = time.perf_counter()
                stats = shadow.sync(state, device_digests=device_digests)
                result.sync_us = (time.perf_counter() - t_sync) * 1e6
            result.digest_us = stats.digest_us
            result.fetch_us = stats.fetch_us
            if now_tick is not None:
                self._buf_tick[buf_i] = now_tick
            skeleton = build_skeleton(state)
            shapes_dtypes = {
                p: (list(np.shape(l)), np.dtype(
                    l.dtype if hasattr(l, "dtype") else np.asarray(l).dtype
                ).name)
                for p, l in flatten_with_paths(state)[0].items()
            }
            result.bytes_snapshot = stats.bytes_fetched
            result.chunks_synced = stats.chunks_fetched
            result.chunks_clean = stats.chunks_total - stats.chunks_fetched
            result.bytes_skipped = stats.bytes_total - stats.bytes_fetched
            result.blocking_s = time.perf_counter() - t0
            pctx = obs_trace.child_span(trace_ctx)
            tr = obs_trace.get()
            if tr is not None:
                tr.complete("ckpt.phase1", t0, step=step,
                            chunks_synced=result.chunks_synced,
                            bytes_snapshot=result.bytes_snapshot,
                            **obs_trace.ctx_args(pctx))

        job = PersistJob(
            result=result,
            buf_index=buf_i,
            shadow=shadow,
            snapshot=shadow.snapshot(),
            skeleton=skeleton,
            shapes_dtypes=shapes_dtypes,
            prev=self._prev_manifest if self.incremental else None,
            meta=meta or {},
            shadow_gen=shadow.generation,
            trace_ctx=obs_trace.child_span(pctx),
        )
        # phase 2 (possibly a fork child) reads this buffer generation: a
        # re-registration must retire, not release, it until the job is done
        shadow.pin()
        self._reap()
        with self._lock:
            self._pending.append(result)
            if job.prev is not None:
                # the delta being written references the base image's chunk
                # payloads: GC must keep them until this persist resolves
                from repro.checkpoint.manifest import referenced_steps

                self._inflight_bases[id(job)] = (
                    {job.prev.step} | referenced_steps(job.prev)
                )
        try:
            self.backend.submit(job)
        except BaseException as e:
            # never strand the claimed buffer or leave a result that can't
            # complete (close()/wait_all() would hang on it)
            result.error = f"persist submit failed: {type(e).__name__}: {e}"
            with self._lock:
                self._inflight_bases.pop(id(job), None)
            shadow.unpin()
            self._release_buffer(buf_i)
            result.done.set()
            raise
        return result

    # -- buffer ownership ------------------------------------------------------
    def _acquire_buffer(self) -> int:
        with self._buf_cond:
            while True:
                for i, busy in enumerate(self._buf_busy):
                    if not busy:
                        self._buf_busy[i] = True
                        return i
                # all buffers persisting: wait for a release (bounded pipeline)
                self._buf_cond.wait()

    def _release_buffer(self, i: int) -> None:
        with self._buf_cond:
            self._buf_busy[i] = False
            self._buf_cond.notify_all()

    def _reap(self) -> None:
        with self._lock:
            self._pending = [r for r in self._pending if not r.done.is_set()]

    # -- backend callbacks -------------------------------------------------------
    def _note_manifest(self, manifest: Manifest) -> None:
        with self._lock:
            if self.external_commit:
                self._staged[manifest.step] = manifest
                return
            if self._prev_manifest is None or manifest.step >= self._prev_manifest.step:
                self._prev_manifest = manifest

    # -- external (coordinator-driven) commit ------------------------------------
    def commit_confirmed(self, step: int) -> None:
        """Coordinator committed ``step``: promote it to the delta base."""
        with self._lock:
            m = self._staged.pop(step, None)
            if m is not None and (
                self._prev_manifest is None or m.step >= self._prev_manifest.step
            ):
                self._prev_manifest = m

    def commit_aborted(self, step: int) -> None:
        """Coordinator aborted ``step``: its staged image is never a base."""
        with self._lock:
            self._staged.pop(step, None)

    def _finish_job(self, job: PersistJob) -> None:
        """Common phase-2 epilogue: timing, buffer release, completion."""
        self.timings.add("ckpt/persist", job.result.persist_s)
        obs_metrics.absorb_checkpoint_result(job.result)
        with self._lock:
            self._inflight_bases.pop(id(job), None)
        job.shadow.unpin()
        self._release_buffer(job.buf_index)
        job.result.done.set()

    def inflight_delta_bases(self) -> set[int]:
        """Steps an uncommitted in-flight delta persist still reads from.

        ``trainer._gc`` passes these to the policy as extra pins: without
        them a GC planned between submit and commit could collect a base
        image whose chunks the pending manifest will reference.
        """
        with self._lock:
            out: set[int] = set()
            for bases in self._inflight_bases.values():
                out |= bases
            return out

    # -- lifecycle ---------------------------------------------------------------
    def wait_all(self, timeout: float | None = None) -> list[CheckpointResult]:
        with self._lock:
            pending = list(self._pending)
        return [r.wait(timeout) for r in pending]

    def pending(self) -> int:
        self._reap()
        with self._lock:
            return len(self._pending)

    def close(self) -> None:
        """Drain in-flight persists (without raising on failed ones) and
        release backend resources."""
        with self._lock:
            pending = list(self._pending)
        for r in pending:
            r.done.wait()
        self.backend.close()

    # -- synchronous baseline (the paper's "naive" strategy) -----------------------
    def save_sync(self, step: int, state: Any, *, meta: dict | None = None) -> CheckpointResult:
        """Naive strategy: the application blocks for the full write."""
        r = self.save_async(step, state, meta=meta)
        r.wait()
        r.blocking_s += r.persist_s
        return r
