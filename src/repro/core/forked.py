"""ForkedCheckpointer — the paper's §3.3 forked checkpointing model.

CRUM's two phases, TPU-native:

  phase 1  "drain the device"  : block the train loop only for
           (a) flushing the async dispatch queue (drain), and
           (b) syncing the shadow snapshot (digest-gated device->host
               transfer of dirty chunks only).
  phase 2  "forked child writes": a writer pool compresses and persists the
           immutable snapshot to stable storage *while training continues*.

The paper forks a child to get a COW view of the image; here the snapshot
buffers are plain host memory that the train loop never touches, so
immutability is structural. Double buffering (two ShadowStateManagers)
lets checkpoint N+1's phase 1 begin while checkpoint N's phase 2 is still
writing — at most ``max_pending`` images are in flight, after which phase 1
blocks (the paper's implicit "one forked child at a time").

Blocking time (what the application observes) is accounted separately from
total persist time: the 40x headline of Table 2 is precisely
``blocking_time / naive_synchronous_time``.
"""
from __future__ import annotations

import concurrent.futures as cf
import os
import threading
from dataclasses import dataclass, field
from typing import Any, Callable

import jax
import numpy as np

from repro.checkpoint.chunking import DEFAULT_CHUNK_BYTES, chunk_digest_np, iter_chunks
from repro.checkpoint.manifest import (
    LeafRecord,
    Manifest,
    ShardRecord,
    build_skeleton,
    commit_manifest,
)
from repro.checkpoint.store import ChunkStore
from repro.core.drain import drain
from repro.core.shadow import ShadowStateManager
from repro.utils.timing import Timings
from repro.utils.tree import flatten_with_paths


@dataclass
class CheckpointResult:
    step: int
    blocking_s: float          # what the train loop paid (phase 1)
    persist_s: float = 0.0     # background write time (phase 2)
    bytes_snapshot: int = 0    # bytes moved device->host
    bytes_written: int = 0     # bytes written to storage (compressed)
    chunks_written: int = 0
    chunks_reused: int = 0     # delta references (incremental mode)
    error: str | None = None
    done: threading.Event = field(default_factory=threading.Event, repr=False)

    def wait(self, timeout: float | None = None) -> "CheckpointResult":
        if not self.done.wait(timeout):
            raise TimeoutError(f"checkpoint step {self.step} still pending")
        if self.error:
            raise RuntimeError(f"checkpoint step {self.step} failed: {self.error}")
        return self


class ForkedCheckpointer:
    def __init__(
        self,
        store: ChunkStore,
        *,
        codec: str = "zstd1",
        chunk_bytes: int = DEFAULT_CHUNK_BYTES,
        io_workers: int | None = None,
        max_pending: int = 1,
        incremental: bool = True,
        digest_on_device: bool = True,
        host: int = 0,
        fsync: bool = False,
        timings: Timings | None = None,
    ):
        self.store = store
        self.codec = codec
        self.chunk_bytes = int(chunk_bytes)
        self.incremental = incremental
        self.host = host
        self.fsync = fsync
        self.timings = timings or Timings()
        workers = io_workers or min(8, (os.cpu_count() or 2))
        self._pool = cf.ThreadPoolExecutor(
            max_workers=workers, thread_name_prefix="crum-writer"
        )
        self._buffers = [
            ShadowStateManager(
                chunk_bytes=chunk_bytes,
                digest_on_device=digest_on_device,
                defer_first_digests=True,  # persist backfills via set_digests
                timings=self.timings,
            )
            for _ in range(max_pending + 1)
        ]
        self._buf_busy = [threading.Event() for _ in self._buffers]
        self._pending: list[CheckpointResult] = []
        self._prev_manifest: Manifest | None = None
        self._lock = threading.Lock()

    # -- the checkpoint entry point ------------------------------------------
    def save_async(
        self, step: int, state: Any, *, meta: dict | None = None
    ) -> CheckpointResult:
        """Phase 1 inline (blocking, fast); phase 2 on the writer pool."""
        result = CheckpointResult(step=step, blocking_s=0.0)
        with self.timings.measure("ckpt/blocking") as _:
            import time

            t0 = time.perf_counter()
            # pick a free snapshot buffer (waits if all are persisting)
            buf_i = self._acquire_buffer()
            shadow = self._buffers[buf_i]
            with self.timings.measure("ckpt/drain"):
                drain(state)
            with self.timings.measure("ckpt/snapshot"):
                shadow.mark_device_step()
                stats = shadow.sync(state)
            skeleton = build_skeleton(state)
            shapes_dtypes = {
                p: (list(np.shape(l)), np.dtype(
                    l.dtype if hasattr(l, "dtype") else np.asarray(l).dtype
                ).name)
                for p, l in flatten_with_paths(state)[0].items()
            }
            result.bytes_snapshot = stats.bytes_fetched
            result.blocking_s = time.perf_counter() - t0

        snapshot = shadow.snapshot()
        prev = self._prev_manifest if self.incremental else None
        self._pool.submit(
            self._persist, result, buf_i, shadow, snapshot, skeleton,
            shapes_dtypes, prev, meta or {},
        )
        with self._lock:
            self._pending.append(result)
        return result

    def _acquire_buffer(self) -> int:
        while True:
            for i, busy in enumerate(self._buf_busy):
                if not busy.is_set():
                    busy.set()
                    return i
            # all buffers persisting: wait for the oldest (bounded pipeline)
            oldest = None
            with self._lock:
                if self._pending:
                    oldest = self._pending[0]
            if oldest is not None:
                oldest.done.wait()
            self._reap()

    def _reap(self) -> None:
        with self._lock:
            self._pending = [r for r in self._pending if not r.done.is_set()]

    # -- phase 2 ---------------------------------------------------------------
    def _persist(
        self,
        result: CheckpointResult,
        buf_i: int,
        shadow: ShadowStateManager,
        snapshot: dict,
        skeleton: Any,
        shapes_dtypes: dict,
        prev: Manifest | None,
        meta: dict,
    ) -> None:
        import time

        t0 = time.perf_counter()
        try:
            prev_map: dict[tuple, Any] = {}
            if prev is not None:
                for path, lv in prev.leaves.items():
                    for s in lv.shards:
                        for c in s.chunks:
                            prev_map[(path, tuple(s.start), tuple(s.stop), c.index)] = c

            manifest = Manifest(step=result.step, skeleton=skeleton, meta=meta)
            writer = self.store.writer(result.step, self.host)
            try:
                by_path: dict[str, list] = {}
                for (path, ordinal), shard in sorted(snapshot.items()):
                    shard = dict(shard)
                    shard["ordinal"] = ordinal
                    by_path.setdefault(path, []).append(shard)
                for path, (shape, dtype) in shapes_dtypes.items():
                    lrec = LeafRecord(path=path, shape=shape, dtype=dtype)
                    for shard in by_path.get(path, []):
                        srec = ShardRecord(start=shard["start"], stop=shard["stop"])
                        shard_digests: list[int] = []
                        for key, raw in iter_chunks(path, shard["data"], self.chunk_bytes):
                            digest = chunk_digest_np(raw)
                            shard_digests.append(digest)
                            old = prev_map.get(
                                (path, tuple(srec.start), tuple(srec.stop), key.index)
                            )
                            if (
                                old is not None
                                and old.digest == digest
                                and old.raw_len == len(raw)
                            ):
                                srec.chunks.append(old)
                                result.chunks_reused += 1
                            else:
                                rec = writer.append(
                                    raw, self.codec, index=key.index, digest=digest
                                )
                                srec.chunks.append(rec)
                                result.chunks_written += 1
                                result.bytes_written += rec.comp_len
                        lrec.shards.append(srec)
                        # backfill shadow digests (phase 1 skipped them)
                        shadow.set_digests((path, shard["ordinal"]), shard_digests)
                    manifest.leaves[path] = lrec
            finally:
                writer.close(fsync=self.fsync)
            manifest.meta.update(
                chunks_written=result.chunks_written,
                chunks_reused=result.chunks_reused,
            )
            commit_manifest(self.store.root, manifest)
            with self._lock:
                if self._prev_manifest is None or result.step >= self._prev_manifest.step:
                    self._prev_manifest = manifest
        except Exception as e:  # surfaced at wait()
            result.error = f"{type(e).__name__}: {e}"
        finally:
            result.persist_s = time.perf_counter() - t0
            self.timings.add("ckpt/persist", result.persist_s)
            self._buf_busy[buf_i].clear()
            result.done.set()

    # -- lifecycle ---------------------------------------------------------------
    def wait_all(self, timeout: float | None = None) -> list[CheckpointResult]:
        with self._lock:
            pending = list(self._pending)
        return [r.wait(timeout) for r in pending]

    def pending(self) -> int:
        self._reap()
        with self._lock:
            return len(self._pending)

    def close(self) -> None:
        self.wait_all()
        self._pool.shutdown(wait=True)

    # -- synchronous baseline (the paper's "naive" strategy) -----------------------
    def save_sync(self, step: int, state: Any, *, meta: dict | None = None) -> CheckpointResult:
        """Naive strategy: the application blocks for the full write."""
        r = self.save_async(step, state, meta=meta)
        r.wait()
        r.blocking_s += r.persist_s
        return r
