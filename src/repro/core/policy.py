"""Checkpoint cadence + retention policy.

The paper motivates cadence from DUE rates (§2.2): more failures => more
frequent checkpoints => blocking time matters more. The policy layer decides
*when* (steps / wall-clock / preemption signal) and *what to keep*
(keep_last N, keep_every K), including the transitive closure of delta
references so GC never strands an incremental checkpoint's base chunks.
"""
from __future__ import annotations

import time
from dataclasses import dataclass, field

from repro.checkpoint.manifest import (  # noqa: F401  (re-exported; it lives
    Manifest,                            # with the manifest format now)
    referenced_steps,
)


@dataclass
class CheckpointPolicy:
    interval_steps: int = 0          # 0 = disabled
    interval_secs: float = 0.0       # 0 = disabled
    keep_last: int = 2
    keep_every: int = 0              # additionally keep every K-th step
    _last_time: float = field(default_factory=time.monotonic)
    _preempt: bool = False

    def should_checkpoint(self, step: int) -> bool:
        if self._preempt:
            return True
        if self.interval_steps and step > 0 and step % self.interval_steps == 0:
            return True
        if self.interval_secs and (time.monotonic() - self._last_time) >= self.interval_secs:
            return True
        return False

    def notify_checkpointed(self, step: int) -> None:
        self._last_time = time.monotonic()
        self._preempt = False

    def request_preempt_checkpoint(self) -> None:
        """Hook for SIGTERM/preemption notice: checkpoint at the next step."""
        self._preempt = True

    def run_gc(self, store, *, extra_keep=()) -> list[int]:
        """Scan, plan and collect under this policy; returns removed steps.

        Tolerates a concurrent collector on the same root end to end: steps
        that vanish between the scan and the manifest read are treated as
        already collected (see load_manifest_if_committed), and the
        store-side deletion skips steps a racing GC got to first.

        ``extra_keep`` pins additional steps (and their delta closure) —
        the trainer passes the bases of in-flight incremental persists,
        whose manifests are not on disk yet and so invisible to the scan.
        """
        from repro.checkpoint.manifest import (
            committed_steps,
            load_manifest_if_committed,
        )

        committed = committed_steps(store.root)
        manifests = {
            s: m
            for s in committed
            if (m := load_manifest_if_committed(store.root, s)) is not None
        }
        if not manifests:
            return []
        keep = self.gc_keep(sorted(manifests), manifests, extra_keep=extra_keep)
        if set(keep) == set(manifests):
            return []
        return store.gc(keep)

    def gc_keep(
        self,
        committed: list[int],
        manifests: dict[int, Manifest],
        *,
        extra_keep=(),
    ) -> list[int]:
        """Which steps to keep: keep_last + keep_every + delta closure."""
        keep: set[int] = set()
        for s in sorted(committed)[-self.keep_last :] if self.keep_last else []:
            keep.add(s)
        if self.keep_every:
            keep.update(s for s in committed if s % self.keep_every == 0)
        keep.update(s for s in extra_keep if s in committed)
        # transitive closure over delta references
        frontier = list(keep)
        while frontier:
            s = frontier.pop()
            m = manifests.get(s)
            if m is None:
                continue
            for ref in referenced_steps(m):
                if ref not in keep and ref in committed:
                    keep.add(ref)
                    frontier.append(ref)
        return sorted(keep)
