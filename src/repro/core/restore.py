"""RestoreManager — restart protocol (paper §3.4) + lazy restore (§4.2).

Eager mode re-creates the full state: read manifest, assemble each leaf's
global array from stored shards (any source topology -> any target
topology), place with the target sharding. This is the paper's "replay the
allocations, transfer the data back through the proxy".

Lazy mode returns a mapping that materializes leaves on first access and
prefetches ahead in manifest order with an exponentially growing window —
the paper's read-fault heuristic: the first fault reads one page, each
subsequent fault on the same region doubles the read-ahead. Serving
restarts benefit: embedding tables materialize on demand rather than
stalling the whole restore.
"""
from __future__ import annotations

import concurrent.futures as cf
import threading
from typing import Any, Callable

import jax

from repro.checkpoint.manifest import Manifest, latest_committed_step, load_manifest
from repro.checkpoint.sharded import _LeafAssembler, restore_leaf
from repro.checkpoint.store import ChunkStore
from repro.checkpoint.manifest import skeleton_fill, skeleton_paths
from repro.utils.timing import Timings

ShardingFor = Callable[[str, tuple[int, ...]], jax.sharding.Sharding | None]


class LazyLeaves:
    """Dict-like view over a manifest; leaves materialize on first read.

    Exponential read-ahead: after ``k`` consecutive accesses that hit the
    prefetch frontier, the window grows as 1, 2, 4, ... up to
    ``max_readahead`` leaves submitted to a background reader.
    """

    def __init__(
        self,
        store: ChunkStore,
        manifest: Manifest,
        sharding_for: ShardingFor | None,
        *,
        max_readahead: int = 32,
        timings: Timings | None = None,
    ):
        self._store = store
        self._manifest = manifest
        self._sharding_for = sharding_for or (lambda p, s: None)
        self._order = list(manifest.leaves.keys())
        self._pos = {p: i for i, p in enumerate(self._order)}
        self._cache: dict[str, Any] = {}
        self._futures: dict[str, cf.Future] = {}
        self._window = 1
        self._max_window = max_readahead
        self._frontier = 0
        self._last_idx = -1
        self._lock = threading.Lock()
        self._pool = cf.ThreadPoolExecutor(max_workers=4, thread_name_prefix="crum-read")
        self.timings = timings or Timings()
        self.loads = 0

    def keys(self) -> list[str]:
        return list(self._order)

    def _materialize(self, path: str) -> Any:
        lrec = self._manifest.leaves[path]
        with self.timings.measure("restore/leaf"):
            leaf = restore_leaf(
                self._store, lrec, self._sharding_for(path, tuple(lrec.shape))
            )
        return leaf

    def __getitem__(self, path: str) -> Any:
        # claim-under-lock: concurrent first accesses to the same leaf must
        # materialize it exactly once. The first claimant registers a future
        # (so peers wait on it) and runs the read itself; peers — and reads
        # already prefetched by the pool — block on fut.result().
        owner = False
        with self._lock:
            if path in self._cache:
                return self._cache[path]
            fut = self._futures.get(path)
            if fut is None:
                fut = cf.Future()
                self._futures[path] = fut
                owner = True
                self.loads += 1
        if owner:
            try:
                fut.set_result(self._materialize(path))
            except BaseException as e:
                fut.set_exception(e)
        try:
            leaf = fut.result()
        except BaseException:
            # a failed read (owner or pool prefetch) must not poison the
            # leaf: drop the future so the next access retries materialize
            with self._lock:
                if self._futures.get(path) is fut:
                    self._futures.pop(path)
            raise
        with self._lock:
            self._cache[path] = leaf
            self._futures.pop(path, None)
        self._read_ahead(path)
        return leaf

    def _read_ahead(self, touched: str) -> None:
        """Grow and schedule the prefetch window past the touched leaf."""
        with self._lock:
            i = self._pos[touched]
            if i >= self._last_idx:
                # forward progress: double the window (paper's heuristic)
                self._window = min(self._window * 2, self._max_window)
            else:  # backward jump: new region, reset the stride
                self._window = 1
                self._frontier = 0
            self._last_idx = i
            lo = max(self._frontier, i + 1)
            hi = min(len(self._order), i + 1 + self._window)
            to_fetch = [
                p
                for p in self._order[lo:hi]
                if p not in self._cache and p not in self._futures
            ]
            for p in to_fetch:
                self._futures[p] = self._pool.submit(self._materialize, p)
                self.loads += 1
            self._frontier = max(self._frontier, hi)

    def as_tree(self) -> Any:
        """Force everything and return the full pytree."""
        leaves = {p: self[p] for p in self._order}
        return skeleton_fill(self._manifest.skeleton, leaves)

    def close(self) -> None:
        self._pool.shutdown(wait=False, cancel_futures=True)


class RestoreManager:
    def __init__(self, store: ChunkStore, *, timings: Timings | None = None):
        self.store = store
        self.timings = timings or Timings()

    def available_steps(self) -> list[int]:
        from repro.checkpoint.manifest import committed_steps

        return committed_steps(self.store.root)

    def _pick_manifest(self, step: int | None) -> Manifest:
        """Load the requested (or newest committed) manifest.

        The pick/load pair races with GC: the step chosen as newest can be
        collected before its manifest read. Re-scan on miss instead of
        surfacing a spurious FileNotFoundError to the caller.
        """
        if step is not None:
            return load_manifest(self.store.root, step)
        for _ in range(8):
            step = latest_committed_step(self.store.root)
            if step is None:
                raise FileNotFoundError(
                    f"no committed checkpoint under {self.store.root}"
                )
            try:
                return load_manifest(self.store.root, step)
            except (FileNotFoundError, NotADirectoryError):
                continue
        raise FileNotFoundError(
            f"committed checkpoints under {self.store.root} kept "
            "vanishing mid-read (GC racing restore)"
        )

    def restore(
        self,
        *,
        step: int | None = None,
        sharding_for: ShardingFor | None = None,
        lazy: bool = False,
        verify: bool = False,
    ) -> tuple[Any, Manifest]:
        """Restore the newest (or given) committed checkpoint.

        Returns (state, manifest); in lazy mode state is a LazyLeaves whose
        ``as_tree()`` gives the pytree.
        """
        manifest = self._pick_manifest(step)
        if verify:
            from repro.checkpoint.sharded import verify_manifest

            with self.timings.measure("restore/verify"):
                verify_manifest(self.store, manifest)
        if lazy:
            return (
                LazyLeaves(
                    self.store, manifest, sharding_for, timings=self.timings
                ),
                manifest,
            )
        with self.timings.measure("restore/eager"):
            leaves = {
                path: restore_leaf(
                    self.store,
                    lrec,
                    (sharding_for or (lambda p, s: None))(path, tuple(lrec.shape)),
                )
                for path, lrec in manifest.leaves.items()
            }
            state = skeleton_fill(manifest.skeleton, leaves)
        return state, manifest

    # -- proxy restart (paper §3.4: replay allocations, push data back) ---------
    def restore_into_proxy(
        self,
        runner,
        *,
        step: int | None = None,
        sharding_for: ShardingFor | None = None,
        verify: bool = False,
    ) -> tuple[Any, Manifest]:
        """Restore a committed image and re-create device state in a proxy.

        The paper's restart protocol for the proxy architecture: read the
        image, then replay the logged allocations into a fresh proxy process
        and transfer the data back through it. ``runner`` is a
        ``repro.proxy.ProxyRunner``; a fresh runner is started with the
        restored device state (program + register + upload replayed from
        scratch), a running one gets the state pushed over its segments.
        Returns (state, manifest) exactly like :meth:`restore`.
        """
        state, manifest = self.restore(
            step=step, sharding_for=sharding_for, verify=verify
        )
        with self.timings.measure("restore/proxy_push"):
            if getattr(runner, "started", False):
                runner.push(state["device"])
            else:
                runner.start(
                    device_state=state["device"], base_step=int(manifest.step)
                )
        return state, manifest

    # -- elastic reshard (restore onto a different host count) ------------------
    def restore_elastic(
        self,
        *,
        n_hosts: int,
        host: int | None = None,
        step: int | None = None,
        verify: bool = False,
    ) -> tuple[Any, Manifest]:
        """Re-slice a committed image across a different worker count.

        The manifest is topology-independent (leaves are global arrays,
        shards are index ranges), so a checkpoint written by N hosts
        restores onto M: with ``host=None`` the full global state is
        assembled (what each simulated worker holds); with ``host=h`` only
        the windows host ``h`` of ``n_hosts`` *owns* are read — each
        window assembled from whichever stored shards overlap it, wrapped
        in :class:`~repro.core.shadow.HostShardView` exactly as
        ``shard_tree_for_host`` would produce it live. Non-divisible
        splits (4 -> 3, 3 -> 5, N -> 1) need no special casing: ownership
        comes from the same ``host_slice_plan`` rule the writers use.

        Returns (state, manifest); in per-host mode the state's leaves are
        HostShardViews ready to be persisted under the new topology.
        """
        from repro.checkpoint.sharded import host_slice_plan
        from repro.core.shadow import HostShardView

        manifest = self._pick_manifest(step)
        if verify:
            from repro.checkpoint.sharded import verify_manifest

            with self.timings.measure("restore/verify"):
                verify_manifest(self.store, manifest)
        if host is None:
            leaves = {
                path: restore_leaf(self.store, lrec, None)
                for path, lrec in manifest.leaves.items()
            }
            return skeleton_fill(manifest.skeleton, leaves), manifest
        import numpy as np

        with self.timings.measure("restore/elastic"):
            leaves = {}
            for path, lrec in manifest.leaves.items():
                shape = tuple(lrec.shape)
                dtype = np.dtype(lrec.dtype)
                plan = host_slice_plan(path, shape, host, n_hosts)
                if plan is None:
                    leaves[path] = HostShardView(
                        None, global_shape=shape, dtype=dtype
                    )
                    continue
                start, stop = plan
                data = _LeafAssembler(self.store, lrec).window(start, stop)
                leaves[path] = HostShardView(
                    data, start=start, stop=stop,
                    global_shape=shape, dtype=dtype,
                )
        return skeleton_fill(manifest.skeleton, leaves), manifest
