"""ShadowStateManager — Algorithm 1 adapted to TPU/JAX.

CRUM's shadow UVM pages keep an application-side copy of device memory in
sync lazily, driven by page faults. On TPU there are no page faults to hook,
but the structure of the algorithm survives intact once "page" becomes
"chunk" and "fault" becomes "digest mismatch at a sync point":

    paper (Algorithm 1)                 here
    -----------------------------       ------------------------------------
    CUDA kernel launch marks pages      train step marks all chunks
    writable-by-device                  DEVICE_DIRTY (conservative)
    read fault on a shadow page ->      sync(): device-side digest compare;
    ReadDataFromRealPage()              only mismatching chunks are fetched
    write fault -> MarkPageAsDirty()    host mutation marks HOST_DIRTY
    CUDA call -> SendDataToRealPages()  upload(): HOST_DIRTY chunks pushed
                                        back to device (restore path)

The digest compare runs *on device* (Pallas ``chunk_digest`` kernel on TPU,
jnp fallback elsewhere): only the (n_chunks, 2)-u32 digest tensor crosses
the wire before any data does, so clean chunks cost nothing to skip — the
same economy CRUM gets from not faulting untouched pages.
"""
from __future__ import annotations

import enum
import mmap
import threading
import time
from dataclasses import dataclass, field
from typing import Any, Callable

import jax
import numpy as np

from repro.checkpoint.chunking import (
    DEFAULT_CHUNK_BYTES,
    chunk_digest_np,
    num_chunks,
)
from repro.obs import trace as obs_trace
from repro.utils.timing import Timings
from repro.utils.tree import flatten_with_paths, unflatten_from_paths


class ChunkState(enum.Enum):
    CLEAN = "clean"              # shadow == device
    DEVICE_DIRTY = "device_dirty"  # device may have advanced; shadow stale
    HOST_DIRTY = "host_dirty"    # shadow mutated on host; device stale


@dataclass
class _ShardStream:
    """One owned shard of one leaf, viewed as a byte stream of chunks."""

    path: str
    shard_ordinal: int
    start: list[int]
    stop: list[int]
    nbytes: int
    n_chunks: int
    states: list[ChunkState]
    digests: list[int]                    # digest of current *shadow* content
    buffer: np.ndarray | None = None      # host shadow bytes (u8), lazily alloc'd
    # True: the current DEVICE_DIRTY marks are page-granular truth (a
    # ManagedSpace's write_tick history), so sync may fetch exactly those
    # chunks and skip the digest compare entirely. Reset by every sync.
    precise: bool = False


@dataclass
class SyncStats:
    chunks_total: int = 0
    chunks_fetched: int = 0
    bytes_total: int = 0
    bytes_fetched: int = 0
    leaves: int = 0
    # exactly which chunks this sync materialized, keyed (path, ordinal) —
    # the streamed proxy transport forwards precisely these chunk payloads
    # to the application, so wire bytes track what actually changed
    changed: dict[tuple[str, int], list[int]] = field(default_factory=dict)
    # which sync epoch produced this image (-1: unepoched / legacy barrier)
    epoch: int = -1
    # phase breakdown: time spent hashing device chunks vs moving bytes —
    # fused digesting (digests computed inside the step) drives digest_us
    # toward zero, which is what the pipeline benchmarks assert
    digest_us: float = 0.0
    fetch_us: float = 0.0
    # chunks whose digest the step already supplied (no boundary scan)
    chunks_prehashed: int = 0

    def merge(self, other: "SyncStats") -> None:
        self.chunks_total += other.chunks_total
        self.chunks_fetched += other.chunks_fetched
        self.bytes_total += other.bytes_total
        self.bytes_fetched += other.bytes_fetched
        self.leaves += other.leaves
        self.changed.update(other.changed)
        self.digest_us += other.digest_us
        self.fetch_us += other.fetch_us
        self.chunks_prehashed += other.chunks_prehashed


@dataclass
class UploadStats:
    """What ``upload()`` pushed host->device (paper: SendDataToRealPages)."""

    chunks_uploaded: int = 0
    bytes_uploaded: int = 0
    leaves_touched: int = 0
    # per-stream bytes pushed, keyed (path, shard_ordinal) — the proxy
    # replay path reports these so recovery cost is attributable per leaf
    per_stream: dict[tuple[str, int], int] = field(default_factory=dict)


class HostShardView:
    """A host-owned slice of a globally-sharded leaf (simulated multi-host).

    In the cluster protocol every worker process holds the full replicated
    state but *persists* only its assigned global index range — the same
    ownership split ``addressable_shards``/``replica_id`` gives a real
    multi-host jax.Array. ``shape``/``dtype`` describe the **global** leaf
    (what the merged manifest records); ``data`` is this host's slice, or
    None when the host owns nothing of the leaf (the owner's hostmeta
    supplies it at merge time).
    """

    __slots__ = ("data", "start", "stop", "_shape", "_dtype")

    def __init__(self, data, *, start=None, stop=None,
                 global_shape=None, dtype=None):
        self.data = None if data is None else np.ascontiguousarray(data)
        self.start = list(start) if start is not None else None
        self.stop = list(stop) if stop is not None else None
        if global_shape is None:
            if self.data is None:
                raise ValueError("unowned HostShardView needs global_shape")
            global_shape = self.data.shape
        self._shape = tuple(int(d) for d in global_shape)
        self._dtype = np.dtype(dtype if dtype is not None else self.data.dtype)

    @property
    def shape(self) -> tuple[int, ...]:
        return self._shape

    @property
    def dtype(self) -> np.dtype:
        return self._dtype


def _owned_host_shards(leaf: Any):
    """(ordinal, start, stop, np_data) for shards this host owns."""
    if isinstance(leaf, HostShardView):
        if leaf.data is None:
            return []
        start = leaf.start if leaf.start is not None else [0] * leaf.data.ndim
        stop = leaf.stop if leaf.stop is not None else list(leaf.data.shape)
        return [(0, list(start), list(stop), leaf.data)]
    if isinstance(leaf, jax.Array):
        out = []
        ordinal = 0
        for sh in leaf.addressable_shards:
            if sh.replica_id != 0:
                continue
            start, stop = [], []
            for sl, dim in zip(sh.index, leaf.shape):
                start.append(0 if sl.start is None else int(sl.start))
                stop.append(dim if sl.stop is None else int(sl.stop))
            out.append((ordinal, start, stop, sh.data))
            ordinal += 1
        return out
    arr = np.asarray(leaf)
    return [(0, [0] * arr.ndim, list(arr.shape), arr)]


class ShadowStateManager:
    """Maintains a host shadow of an on-device state pytree.

    One manager owns one shadow buffer set. The forked checkpointer holds
    two managers (double buffering) so persisting snapshot A never blocks
    filling snapshot B.
    """

    def __init__(
        self,
        *,
        chunk_bytes: int = DEFAULT_CHUNK_BYTES,
        digest_on_device: bool = True,
        defer_first_digests: bool = False,
        shared_buffers: bool = False,
        segment_factory: Callable[[tuple[str, int], int], np.ndarray] | None = None,
        timings: Timings | None = None,
    ):
        self.chunk_bytes = int(chunk_bytes)
        self.digest_on_device = digest_on_device
        # True: first sync skips the digest pass (a persist phase will
        # backfill via set_digests) — used by ForkedCheckpointer
        self.defer_first_digests = defer_first_digests
        # True: shadow buffers live in anonymous MAP_SHARED mmap segments.
        # Across an os.fork() the pages are *shared*, not COW-duplicated, so
        # a persist child reads the snapshot at zero copy cost and the
        # parent's later writes to *other* buffers never trigger page
        # copies — the paper's fork-and-persist economics. The caller must
        # not mutate a buffer while a child is persisting it (the forked
        # checkpointer's busy-buffer discipline guarantees this).
        self.shared_buffers = shared_buffers
        # Pluggable buffer allocation: (key, nbytes) -> u8 array. The device
        # proxy passes a factory that maps file-backed MAP_SHARED segments,
        # making the shadow buffers themselves the cross-process data plane
        # (step inputs/outputs never pickle through the control pipe).
        self.segment_factory = segment_factory
        self.timings = timings or Timings()
        self._streams: dict[tuple[str, int], _ShardStream] = {}
        self._mmaps: list[mmap.mmap] = []
        self._registered = False
        # pin/retire: a persisting fork child may still be reading the
        # MAP_SHARED pages of a buffer generation that register() replaces;
        # retired generations are released only once the pin count drops to 0
        self._pin_lock = threading.Lock()
        self._pins = 0
        self._retired: list[tuple[dict, list]] = []
        # buffer generation: bumped by register() so a digest backfill from
        # a persist of the *previous* generation can be recognized and
        # dropped instead of installing stale digests into fresh streams
        self.generation = 0
        # sync epochs: each begin_sync_epoch() names one step-boundary
        # image. The epoch is carried through SyncStats (and, in the proxy,
        # through SYNCED frames) so a caller that pipelines SYNC behind the
        # next STEP can match images to boundaries asynchronously instead
        # of treating every sync as a barrier.
        self.sync_epoch = 0

    def _alloc_buffer(self, nbytes: int, key: tuple[str, int] | None = None) -> np.ndarray:
        if self.segment_factory is not None and key is not None:
            return self.segment_factory(key, nbytes)
        if self.shared_buffers and nbytes > 0:
            mm = mmap.mmap(-1, nbytes)  # anonymous + MAP_SHARED on POSIX
            self._mmaps.append(mm)
            return np.frombuffer(mm, dtype=np.uint8, count=nbytes)
        return np.empty(nbytes, np.uint8)

    # -- buffer generation pinning ------------------------------------------------
    def pin(self) -> None:
        """A consumer (e.g. a forked persist child's parent-side job) still
        reads the current buffer generation: re-registration must not release
        it. Balanced by :meth:`unpin`."""
        with self._pin_lock:
            self._pins += 1

    def unpin(self) -> None:
        with self._pin_lock:
            self._pins = max(0, self._pins - 1)
            if self._pins == 0 and self._retired:
                retired, self._retired = self._retired, []
            else:
                retired = []
        for streams, mmaps in retired:
            self._drop_generation(streams, mmaps)

    @staticmethod
    def _drop_generation(streams: dict, mmaps: list) -> None:
        """Release one buffer generation: sever the stream->buffer views so
        the mmaps can actually close (a view held elsewhere — e.g. a
        persist job's snapshot dict — downgrades close to GC-time)."""
        for s in streams.values():
            s.buffer = None
        for mm in mmaps:
            try:
                mm.close()
            except (BufferError, ValueError):  # a view still alive: GC frees
                pass

    # -- registration ---------------------------------------------------------
    def register(self, state: Any) -> None:
        """Learn the chunk layout of ``state``; all chunks start DEVICE_DIRTY.

        Re-registration retires (rather than releases) the previous buffer
        generation while any consumer holds a pin — a persisting fork child
        may still be reading those MAP_SHARED pages.
        """
        flat, _ = flatten_with_paths(state)
        with self._pin_lock:
            old_streams, old_mmaps = self._streams, self._mmaps
            retire = self._pins > 0 and bool(old_streams or old_mmaps)
            if retire:
                self._retired.append((old_streams, old_mmaps))
            self._streams = {}
            self._mmaps = []
        if not retire:
            self._drop_generation(old_streams, old_mmaps)
        for path, leaf in flat.items():
            for ordinal, start, stop, data in _owned_host_shards(leaf):
                nbytes = int(np.asarray(data).nbytes) if not isinstance(
                    data, jax.Array
                ) else int(np.prod(data.shape, dtype=np.int64)) * data.dtype.itemsize
                nc = num_chunks(nbytes, self.chunk_bytes)
                self._streams[(path, ordinal)] = _ShardStream(
                    path=path,
                    shard_ordinal=ordinal,
                    start=start,
                    stop=stop,
                    nbytes=nbytes,
                    n_chunks=nc,
                    states=[ChunkState.DEVICE_DIRTY] * nc,
                    digests=[-1] * nc,
                )
        self.generation += 1
        self._registered = True

    # -- Algorithm-1 events -----------------------------------------------------
    def mark_device_step(self, marks: dict[str, list[int]] | None = None) -> None:
        """Paper: a CUDA call may mutate real pages -> mark shadows stale.

        Without ``marks`` every CLEAN chunk becomes DEVICE_DIRTY (the
        conservative pre-UVM behaviour: any step may have touched any
        byte). With ``marks`` — ``{path: chunk indices}`` from a managed
        space's page-granular write history — a path present in the dict
        gets *exactly* those chunks marked, flagged ``precise`` so the next
        sync fetches them without a digest scan; paths absent from the dict
        (e.g. host-side leaves outside the managed space) stay
        conservative. Precision only applies to single-stream (whole-leaf,
        ordinal-0) paths; sharded leaves fall back to the digest path,
        whose chunk indexing is per-shard, not per-leaf.
        """
        if marks is not None:
            per_path: dict[str, int] = {}
            for p, _ in self._streams:
                per_path[p] = per_path.get(p, 0) + 1
        for (path, ordinal), s in self._streams.items():
            idx = marks.get(path) if marks is not None else None
            if idx is not None and ordinal == 0 and per_path.get(path) == 1:
                for i in idx:
                    if 0 <= i < s.n_chunks and s.states[i] is ChunkState.CLEAN:
                        s.states[i] = ChunkState.DEVICE_DIRTY
                s.precise = True
            else:
                for i, st in enumerate(s.states):
                    if st is ChunkState.CLEAN:
                        s.states[i] = ChunkState.DEVICE_DIRTY
                s.precise = False

    def mark_host_write(self, path: str) -> None:
        """Paper: write fault on a shadow page -> HOST_DIRTY."""
        for (p, _), s in self._streams.items():
            if p == path:
                s.states = [ChunkState.HOST_DIRTY] * s.n_chunks

    def mark_host_chunks(self, path: str, indices: list[int], *, ordinal: int = 0) -> None:
        """Chunk-granular host-write marks (the proxy's delta-UPLOAD path):
        only the listed chunks will be pushed by the next ``upload()``."""
        s = self._streams.get((path, ordinal))
        if s is None:
            raise KeyError(f"no stream for {(path, ordinal)}")
        for i in indices:
            if 0 <= i < s.n_chunks:
                s.states[i] = ChunkState.HOST_DIRTY

    # -- sync (the read-fault path, batched) ------------------------------------
    def begin_sync_epoch(self) -> int:
        """Open a new sync epoch and return its number.

        An epoch names one step-boundary image: the caller issues
        ``begin_sync_epoch()`` at the boundary, keeps stepping, and runs
        ``sync(state, epoch=...)`` against the boundary state while the
        *next* step mutates the live buffers — the double-buffered overlap
        the proxy's pipelined SYNC{epoch} is built on.
        """
        self.sync_epoch += 1
        return self.sync_epoch

    def sync(
        self,
        state: Any,
        *,
        epoch: int | None = None,
        device_digests: dict[str, list[int]] | None = None,
    ) -> SyncStats:
        """Bring the shadow up to date with the device; returns transfer stats.

        Only chunks whose device digest differs from the shadow digest are
        materialized on host — CRUM's read-fault economy at chunk scale.

        ``device_digests`` ({path: per-chunk u64 digests}) are digests the
        step program already computed as a fused final pass: a listed path
        skips the boundary digest scan entirely and compares the supplied
        digests against the shadow's. They compose with page-granular
        ``precise`` marks (the intersection is fetched) instead of racing
        them. Like precise marks, they apply only to single-stream
        (whole-leaf, ordinal-0) paths; sharded leaves fall back to the
        scan, whose chunk indexing is per-shard.
        """
        tr = obs_trace.get()
        t0 = time.perf_counter() if tr is not None else 0.0
        if not self._registered:
            self.register(state)
        flat, _ = flatten_with_paths(state)
        per_path: dict[str, int] = {}
        if device_digests:
            for p, _o in self._streams:
                per_path[p] = per_path.get(p, 0) + 1
        stats = SyncStats(epoch=epoch if epoch is not None else self.sync_epoch)
        for path, leaf in flat.items():
            for ordinal, start, stop, data in _owned_host_shards(leaf):
                stream = self._streams.get((path, ordinal))
                if stream is None:  # new leaf appeared: register on the fly
                    self.register(state)
                    stream = self._streams[(path, ordinal)]
                known = None
                if (
                    device_digests
                    and ordinal == 0
                    and per_path.get(path) == 1
                ):
                    k = device_digests.get(path)
                    if k is not None and len(k) == stream.n_chunks:
                        known = [int(d) for d in k]
                st = self._sync_stream(stream, data, known=known)
                stats.merge(st)
            stats.leaves += 1
        if tr is not None:
            tr.complete("shadow.sync", t0, epoch=stats.epoch,
                        chunks_fetched=stats.chunks_fetched,
                        bytes_fetched=stats.bytes_fetched,
                        prehashed=stats.chunks_prehashed)
        return stats

    def _sync_stream(
        self, stream: _ShardStream, data: Any, known: list[int] | None = None
    ) -> SyncStats:
        stats = SyncStats(
            chunks_total=stream.n_chunks, bytes_total=stream.nbytes
        )
        if stream.buffer is None:
            # first sync: everything must move regardless — bulk copy; the
            # digest pass is skipped when a persist phase will backfill it
            stream.precise = False
            t0 = time.perf_counter()
            with self.timings.measure("shadow/fetch"):
                stream.buffer = self._alloc_buffer(
                    stream.nbytes, (stream.path, stream.shard_ordinal)
                )
                host = np.ascontiguousarray(data).reshape(-1).view(np.uint8)
                np.copyto(stream.buffer, host)
                stream.states = [ChunkState.CLEAN] * stream.n_chunks
                stats.chunks_fetched = stream.n_chunks
                stats.bytes_fetched = stream.nbytes
                stats.changed[(stream.path, stream.shard_ordinal)] = list(
                    range(stream.n_chunks)
                )
            stats.fetch_us += (time.perf_counter() - t0) * 1e6
            if known is not None:
                stream.digests = list(known)
                stats.chunks_prehashed += stream.n_chunks
            elif self.defer_first_digests:
                stream.digests = [-2] * stream.n_chunks  # pending backfill
            else:
                t0 = time.perf_counter()
                with self.timings.measure("shadow/digest"):
                    stream.digests = self._device_digests(data, stream)
                stats.digest_us += (time.perf_counter() - t0) * 1e6
            return stats
        dirty = [
            i for i, st in enumerate(stream.states)
            if st is ChunkState.DEVICE_DIRTY
        ]
        precise, stream.precise = stream.precise, False
        if not dirty:
            return stats

        if known is not None:
            # fused digests: the step already hashed the chunks, so the
            # boundary compare is pure bookkeeping (not counted as digest
            # time — no hash runs here) — and it *composes* with
            # page-granular marks: only chunks that are both marked dirty
            # AND hash-changed are fetched (shadow digests still unknown
            # from a deferred first sync count as changed)
            keep = {
                i for i in dirty
                if stream.digests[i] < 0 or known[i] != stream.digests[i]
            }
            changed = sorted(keep)
            for i in dirty:
                if i not in keep:
                    stream.states[i] = ChunkState.CLEAN
            dev_digests = known
            stats.chunks_prehashed += len(dirty)
        elif precise:
            # page-granular marks are authoritative: fetch exactly them, no
            # digest scan over the (mostly clean) rest of the leaf — the
            # whole point of the UVM dirty-bit integration
            dev_digests = None
            changed = dirty
        else:
            t0 = time.perf_counter()
            with self.timings.measure("shadow/digest"):
                dev_digests = self._device_digests(data, stream)
            stats.digest_us += (time.perf_counter() - t0) * 1e6

            changed = [
                i for i in dirty if dev_digests[i] != stream.digests[i]
            ]
            # unchanged-but-marked chunks are clean after the compare
            for i in dirty:
                if i not in changed:
                    stream.states[i] = ChunkState.CLEAN

        if not changed:
            return stats
        stats.changed[(stream.path, stream.shard_ordinal)] = sorted(changed)

        t_fetch = time.perf_counter()
        with self.timings.measure("shadow/fetch"):
            if stream.buffer is None:
                stream.buffer = self._alloc_buffer(
                    stream.nbytes, (stream.path, stream.shard_ordinal)
                )
            cb = self.chunk_bytes
            if len(changed) == stream.n_chunks:
                # everything dirty (first sync / full update): one bulk copy
                host = np.ascontiguousarray(data).reshape(-1).view(np.uint8)
                np.copyto(stream.buffer, host)
                if dev_digests is not None:
                    stream.digests = list(dev_digests)
                else:
                    stream.digests = [
                        chunk_digest_np(
                            stream.buffer[i * cb : min(stream.nbytes, (i + 1) * cb)]
                        )
                        for i in range(stream.n_chunks)
                    ]
                stream.states = [ChunkState.CLEAN] * stream.n_chunks
                stats.chunks_fetched = stream.n_chunks
                stats.bytes_fetched = stream.nbytes
                stats.fetch_us += (time.perf_counter() - t_fetch) * 1e6
                return stats
            fetch = self._make_chunk_fetcher(data, stream, changed)
            for i in changed:
                lo, hi = i * cb, min(stream.nbytes, (i + 1) * cb)
                stream.buffer[lo:hi] = fetch(i, lo, hi)
                stream.digests[i] = (
                    dev_digests[i] if dev_digests is not None
                    else chunk_digest_np(stream.buffer[lo:hi])
                )
                stream.states[i] = ChunkState.CLEAN
                stats.chunks_fetched += 1
                stats.bytes_fetched += hi - lo
        stats.fetch_us += (time.perf_counter() - t_fetch) * 1e6
        return stats

    def _make_chunk_fetcher(self, data: Any, stream: _ShardStream, changed: list[int]):
        """Per-chunk device->host fetch: only dirty bytes cross the wire.

        When most chunks changed a single bulk fetch is cheaper than many
        small DMAs (the paper's exponential read-ahead argument, degenerated
        to its endpoint); below that threshold, chunks are fetched
        individually via on-device slices.
        """
        if (
            isinstance(data, jax.Array)
            and stream.n_chunks > 1
            and len(changed) <= stream.n_chunks // 2
        ):
            itemsize = np.dtype(data.dtype).itemsize
            flat = data.reshape(-1)

            def fetch(i: int, lo: int, hi: int) -> np.ndarray:
                piece = jax.device_get(flat[lo // itemsize : -(-hi // itemsize)])
                return piece.reshape(-1).view(np.uint8)[: hi - lo]

            return fetch
        host = np.ascontiguousarray(data).reshape(-1).view(np.uint8)
        return lambda i, lo, hi: host[lo:hi]

    def _device_digests(self, data: Any, stream: _ShardStream) -> list[int]:
        if self.digest_on_device and isinstance(data, jax.Array):
            from repro.kernels.ops import chunk_digests

            d = np.asarray(chunk_digests(data, self.chunk_bytes))
            return [int((np.uint64(h) << np.uint64(32)) | np.uint64(l))
                    for h, l in zip(d[:, 0], d[:, 1])]
        host = np.ascontiguousarray(data).reshape(-1).view(np.uint8)
        cb = self.chunk_bytes
        return [
            chunk_digest_np(host[i * cb : min(stream.nbytes, (i + 1) * cb)])
            for i in range(stream.n_chunks)
        ]

    # -- upload (the write-back path: SendDataToRealPages) ---------------------
    def upload(self, state: Any) -> tuple[Any, UploadStats]:
        """Push HOST_DIRTY chunks back to the device; returns (state', stats).

        The paper's ``SendDataToRealPages()``: shadow content that the host
        mutated is written back before the device computes again. Only
        HOST_DIRTY chunk byte-ranges move; untouched chunks cost nothing.
        Returns a new state pytree (jax arrays are immutable, so patched
        leaves are rebuilt and re-placed with their original sharding) plus
        per-stream bytes-uploaded stats. This is also the device proxy's
        replay data-push primitive: after a proxy respawn, the last synced
        snapshot lives in the (shared-segment) shadow buffers and is pushed
        into the fresh proxy's device state through this path.
        """
        if not self._registered:
            raise RuntimeError("upload() before register()")
        flat, treedef = flatten_with_paths(state)
        stats = UploadStats()
        new_flat = dict(flat)
        cb = self.chunk_bytes
        for path, leaf in flat.items():
            shards = _owned_host_shards(leaf)
            dirty_streams = []
            for ordinal, start, stop, _data in shards:
                stream = self._streams.get((path, ordinal))
                if stream is None:
                    continue
                dirty = [
                    i for i, st in enumerate(stream.states)
                    if st is ChunkState.HOST_DIRTY
                ]
                if dirty:
                    dirty_streams.append((stream, start, stop, dirty))
            if not dirty_streams:
                continue
            stats.leaves_touched += 1
            with self.timings.measure("shadow/upload"):
                new_flat[path] = self._upload_leaf(
                    path, leaf, dirty_streams, cb, stats
                )
        return unflatten_from_paths(treedef, new_flat), stats

    def _upload_leaf(
        self, path: str, leaf: Any, dirty_streams: list, cb: int, stats: UploadStats
    ) -> Any:
        dtype = np.dtype(
            leaf.dtype if hasattr(leaf, "dtype") else np.asarray(leaf).dtype
        )
        shape = tuple(
            leaf.shape if hasattr(leaf, "shape") else np.asarray(leaf).shape
        )
        is_jax = isinstance(leaf, jax.Array)
        if isinstance(leaf, HostShardView):
            # host-owned slice: patch the bytes in place, no rebuild needed
            for stream, _start, _stop, dirty in dirty_streams:
                buf = self._stream_buffer(stream)
                target = np.ascontiguousarray(leaf.data).reshape(-1).view(np.uint8)
                self._patch_chunks(stream, buf, target, dirty, cb, stats)
                leaf.data[...] = target.view(leaf.data.dtype).reshape(leaf.data.shape)
            return leaf

        full = (
            len(dirty_streams) == 1
            and list(dirty_streams[0][1]) == [0] * len(shape)
            and list(dirty_streams[0][2]) == list(shape)
            and len(dirty_streams[0][3]) == dirty_streams[0][0].n_chunks
        )
        if full:
            # everything dirty over the whole leaf: rebuild straight from
            # the shadow buffer, never fetching the stale device content
            stream, _s, _e, dirty = dirty_streams[0]
            buf = self._stream_buffer(stream)
            arr = buf.view(dtype).reshape(shape).copy()
            self._finish_upload(stream, buf, dirty, cb, stats)
        else:
            arr = np.array(np.asarray(leaf))  # host copy of the global leaf
            for stream, start, stop, dirty in dirty_streams:
                buf = self._stream_buffer(stream)
                idx = tuple(slice(a, b) for a, b in zip(start, stop))
                region = np.ascontiguousarray(arr[idx])
                target = region.reshape(-1).view(np.uint8)
                self._patch_chunks(stream, buf, target, dirty, cb, stats)
                arr[idx] = target.view(dtype).reshape(region.shape)
        if is_jax:
            try:
                return jax.device_put(arr, leaf.sharding)
            except Exception:
                return jax.numpy.asarray(arr)
        return arr

    def _stream_buffer(self, stream: _ShardStream) -> np.ndarray:
        if stream.buffer is None:
            # never synced: only meaningful when a segment factory can
            # attach existing shared content (the proxy replay path)
            if self.segment_factory is None:
                raise RuntimeError(
                    f"stream {(stream.path, stream.shard_ordinal)} has no "
                    "shadow content to upload"
                )
            stream.buffer = self._alloc_buffer(
                stream.nbytes, (stream.path, stream.shard_ordinal)
            )
        return stream.buffer

    def _patch_chunks(
        self,
        stream: _ShardStream,
        buf: np.ndarray,
        target: np.ndarray,
        dirty: list[int],
        cb: int,
        stats: UploadStats,
    ) -> None:
        for i in dirty:
            lo, hi = i * cb, min(stream.nbytes, (i + 1) * cb)
            target[lo:hi] = buf[lo:hi]
        self._finish_upload(stream, buf, dirty, cb, stats)

    def _finish_upload(
        self,
        stream: _ShardStream,
        buf: np.ndarray,
        dirty: list[int],
        cb: int,
        stats: UploadStats,
    ) -> None:
        pushed = 0
        for i in dirty:
            lo, hi = i * cb, min(stream.nbytes, (i + 1) * cb)
            stream.digests[i] = chunk_digest_np(buf[lo:hi])
            stream.states[i] = ChunkState.CLEAN
            pushed += hi - lo
        key = (stream.path, stream.shard_ordinal)
        stats.chunks_uploaded += len(dirty)
        stats.bytes_uploaded += pushed
        stats.per_stream[key] = stats.per_stream.get(key, 0) + pushed

    # -- snapshot access ----------------------------------------------------------
    def snapshot(self) -> dict[tuple[str, int], dict]:
        """The current shadow: {(path, ordinal): {start, stop, bytes}}.

        ``digests`` carries the per-chunk shadow digests where known
        (negative entries are the -1 "never computed" / -2 "backfill
        pending" sentinels): the persist path uses a known digest instead
        of re-hashing the chunk, so a page-delta sync is followed by a
        page-delta digest bill, not a full-state rescan.
        """
        out = {}
        for key, s in self._streams.items():
            if s.buffer is None:
                raise RuntimeError(f"stream {key} never synced")
            out[key] = {
                "start": s.start, "stop": s.stop, "data": s.buffer,
                "digests": list(s.digests),
            }
        return out

    def chunk_states(self) -> dict[tuple[str, int], list[ChunkState]]:
        return {k: list(s.states) for k, s in self._streams.items()}

    def digest_table(self) -> dict[str, list[int]] | None:
        """Full-state per-chunk digest view: {path: [u64 digests]}.

        Only meaningful when every stream is a whole leaf (ordinal 0 —
        the proxy-service registration shape) and every digest is known:
        returns None if any stream is a shard slice or still holds a
        negative sentinel, so callers never ship a partial table. Used
        for divergence provenance — these digests are comparable across
        hosts (same replicated state, same chunking).
        """
        out: dict[str, list[int]] = {}
        for (path, ordinal), s in self._streams.items():
            if ordinal != 0 or any(d < 0 for d in s.digests):
                return None
            out[path] = [int(d) for d in s.digests]
        return out or None

    def set_digests(
        self,
        key: tuple[str, int],
        digests: list[int],
        *,
        generation: int | None = None,
    ) -> None:
        """Backfill digests computed during persist (phase 2).

        ``generation`` (when given) must match the buffer generation the
        persist snapshotted: a backfill racing a re-registration would
        otherwise install the *old* generation's digests into fresh
        streams, and a later delta persist would silently reuse chunks
        against the wrong baseline.
        """
        if generation is not None and generation != self.generation:
            return
        s = self._streams.get(key)
        if s is not None and len(digests) == s.n_chunks:
            s.digests = list(digests)

    def invalidate(self) -> None:
        """Drop all shadow content (e.g., after restoring different weights)."""
        for s in self._streams.values():
            s.states = [ChunkState.DEVICE_DIRTY] * s.n_chunks
            s.digests = [-1] * s.n_chunks
