"""CheckpointedTrainer — the paper's technique as a first-class feature.

Wraps any jitted ``train_step(device_state, batch) -> (device_state,
metrics)`` with CRUM-style fault tolerance:

  - forked (two-phase async) checkpointing on a cadence policy,
  - incremental persistence (digest-delta against the previous image),
  - restart: newest committed image -> device state re-placed on the
    current mesh (elastic), data iterator + RNG replayed,
  - preemption-triggered checkpoint, straggler accounting hooks.

State layout (a plain dict pytree; everything checkpointable):

    {"device": {...jax arrays...},        # params / opt state / rng-key-data
     "host":   {"step": np.int64, "data": {...iterator state...}}}

Device-runner axis (``device_runner=``): ``inline`` executes the step
function in-process (the default, above); ``proxy`` is the paper's actual
architecture — compute runs in a separate restartable proxy process
(``repro.proxy.ProxyRunner``) built from a replayable ``program`` spec,
the app holds only the host mirror, and ``state["device"]`` is refreshed
from the proxy at every sync/checkpoint boundary. A killed proxy is
respawned and its API log replayed transparently mid-``run()``.

Managed-memory axis (``device_capacity_bytes=``): when set, the device
state lives in a ``repro.uvm.ManagedSpace`` — a paged managed address
space with a hard device budget — so training states *larger than device
memory* work: each step faults its working set in (evicting/writing back
under pressure) and the checkpointer consumes the space's page-granular
dirty history (page-delta sync instead of whole-leaf digest scans). In
proxy mode the budget applies inside the proxy process instead.
"""
from __future__ import annotations

import time
from typing import Any, Callable, Iterator

import jax
import numpy as np

from repro.checkpoint.codecs import DEFAULT_CODEC
from repro.checkpoint.store import ChunkStore
from repro.core.forked import CheckpointResult, ForkedCheckpointer
from repro.core.policy import CheckpointPolicy
from repro.core.restore import RestoreManager
from repro.obs import metrics as obs_metrics
from repro.obs import trace as obs_trace
from repro.utils.timing import Timings

DEVICE_RUNNERS = ("inline", "proxy")


class CheckpointedTrainer:
    def __init__(
        self,
        train_step: Callable[[Any, Any], tuple[Any, Any]] | None,
        *,
        store_root: str,
        policy: CheckpointPolicy | None = None,
        codec: str = DEFAULT_CODEC,
        chunk_bytes: int = 4 << 20,
        incremental: bool = True,
        io_workers: int | None = None,
        host: int = 0,
        backend: str = "thread",
        device_runner: str = "inline",
        program: dict | None = None,
        proxy_opts: dict | None = None,
        device_capacity_bytes: int | None = None,
        page_bytes: int | None = None,
        eviction_policy: str = "lru",
        promote_threshold: int = 0,
        promote_window: int = 0,
        timings: Timings | None = None,
    ):
        if device_runner not in DEVICE_RUNNERS:
            raise ValueError(
                f"unknown device_runner {device_runner!r}; have {DEVICE_RUNNERS}"
            )
        self.train_step = train_step
        self.device_runner = device_runner
        self.store = ChunkStore(store_root)
        self.policy = policy or CheckpointPolicy(interval_steps=100)
        self.timings = timings or Timings()
        self.device_capacity_bytes = (
            int(device_capacity_bytes) if device_capacity_bytes else None
        )
        self.page_bytes = page_bytes
        self.eviction_policy = eviction_policy
        self.promote_threshold = int(promote_threshold)
        self.promote_window = int(promote_window)
        self.space = None  # ManagedSpace, created on first run() when capped
        self.checkpointer = ForkedCheckpointer(
            self.store,
            codec=codec,
            chunk_bytes=chunk_bytes,
            incremental=incremental,
            io_workers=io_workers,
            host=host,
            backend=backend,
            timings=self.timings,
        )
        self.restorer = RestoreManager(self.store, timings=self.timings)
        self.results: list[CheckpointResult] = []
        self.runner = None
        if device_runner == "proxy":
            if program is None:
                raise ValueError("device_runner='proxy' needs a program spec")
            from repro.proxy import ProxyRunner

            popts = dict(proxy_opts or {})
            if self.device_capacity_bytes is not None:
                # the budget applies where the device state lives: inside
                # the proxy process
                popts.setdefault(
                    "device_capacity_bytes", self.device_capacity_bytes
                )
                if page_bytes is not None:
                    popts.setdefault("page_bytes", int(page_bytes))
                popts.setdefault("eviction_policy", eviction_policy)
                popts.setdefault("promote_threshold", self.promote_threshold)
                popts.setdefault("promote_window", self.promote_window)
            self.runner = ProxyRunner(
                program, chunk_bytes=chunk_bytes, **popts
            )

    # -- managed memory -----------------------------------------------------------
    def _ensure_space(self, device_state: Any) -> None:
        """Back ``device_state`` with a ManagedSpace (inline managed mode)
        and hand its dirty history to the checkpointer."""
        from repro.uvm import DEFAULT_PAGE_BYTES, ManagedSpace

        if self.space is None:
            self.space = ManagedSpace(
                self.device_capacity_bytes,
                page_bytes=self.page_bytes or DEFAULT_PAGE_BYTES,
                eviction_policy=self.eviction_policy,
                promote_threshold=self.promote_threshold,
                promote_window=self.promote_window,
            )
        self.space.register(device_state)
        # state["device"] leaves appear under the "device/" prefix in the
        # checkpointed pytree; marks must use those paths
        self.checkpointer.dirty_source = self.space.as_dirty_source("device/")

    # -- restart ----------------------------------------------------------------
    def resume_or(
        self,
        init_fn: Callable[[], Any],
        *,
        sharding_for=None,
        verify: bool = False,
    ) -> tuple[Any, int]:
        """Restore the newest committed state or build a fresh one.

        In proxy mode the (restored or fresh) device state is also pushed
        into a freshly-started proxy — the paper's restart protocol of
        replaying allocations and transferring data back through the proxy.

        Returns (state, start_step).
        """
        steps = self.restorer.available_steps()
        if not steps:
            state = init_fn()
            start = int(np.asarray(_get(state, "host", "step", default=0)))
            if self.runner is not None:
                state["device"] = self.runner.start(
                    device_state=state.get("device"), base_step=start
                )
            return state, start
        if self.runner is not None:
            state, _manifest = self.restorer.restore_into_proxy(
                self.runner,
                step=steps[-1],
                sharding_for=sharding_for,
                verify=verify,
            )
        else:
            state, _manifest = self.restorer.restore(
                step=steps[-1], sharding_for=sharding_for, verify=verify
            )
        start = int(np.asarray(state["host"]["step"]))
        return state, start

    # -- the train loop -----------------------------------------------------------
    def run(
        self,
        state: Any,
        batches: Iterator[Any] | None = None,
        *,
        num_steps: int,
        start_step: int = 0,
        on_metrics: Callable[[int, Any], None] | None = None,
        stop: Callable[[], bool] | None = None,
    ) -> Any:
        """``stop`` (checked after each step's checkpoint decision) ends
        the loop early — the preemption hook for callers that delegate
        their loop here instead of hand-rolling one."""
        if self.runner is not None:
            if batches is not None:
                raise ValueError(
                    "device_runner='proxy' derives batches inside the step "
                    "program (deterministic in the step number — that is "
                    "what makes replay bit-identical); a batches iterator "
                    "here would be silently ignored"
                )
            return self._run_proxied(
                state, num_steps=num_steps, start_step=start_step,
                on_metrics=on_metrics, stop=stop,
            )
        if batches is None:
            raise ValueError("inline device runner needs a batches iterator")
        managed = self.device_capacity_bytes is not None
        if managed:
            self._ensure_space(state["device"])
        step = start_step
        tr = obs_trace.get()
        for _ in range(num_steps):
            batch = next(batches)
            t0 = time.perf_counter() if tr is not None else 0.0
            with self.timings.measure("train/step"):
                if managed:
                    # device access: fault the working set in under the
                    # budget, compute, write-allocate the results back
                    with self.timings.measure("train/page_in"):
                        dev = self.space.read_state()
                    dev, metrics = self.train_step(dev, batch)
                    with self.timings.measure("train/page_out"):
                        self.space.write_state(dev)
                else:
                    state["device"], metrics = self.train_step(
                        state["device"], batch
                    )
            step += 1
            if tr is not None:
                tr.complete("app.step", t0, step=step)
            state["host"]["step"] = np.int64(step)
            if on_metrics is not None:
                on_metrics(step, metrics)
            if self.policy.should_checkpoint(step):
                if managed:
                    # coherent host view, no migrations: the sync source
                    state["device"] = self.space.peek_state()
                self.checkpoint_now(step, state)
            if stop is not None and stop():
                break
        if managed:
            state["device"] = self.space.peek_state()
        return state

    def _run_proxied(
        self,
        state: Any,
        *,
        num_steps: int,
        start_step: int,
        on_metrics: Callable[[int, Any], None] | None,
        stop: Callable[[], bool] | None = None,
    ) -> Any:
        """Proxy mode: forward pipelined STEP calls; checkpoint boundaries
        issue a pipelined epoch SYNC and keep stepping — the SYNCED ack is
        polled opportunistically each iteration and only *collected*
        (blocking) when the next boundary needs the data plane, so the
        boundary stall overlaps with the following steps' compute. Batches
        are program-internal (deterministic in the step number) — that
        determinism is what makes kill-replay bit-identical."""
        step = start_step
        synced_at = start_step - 1
        pending: tuple[int, int] | None = None  # (epoch, boundary step)
        tr = obs_trace.get()
        for _ in range(num_steps):
            step += 1
            t0 = time.perf_counter() if tr is not None else 0.0
            with self.timings.measure("train/step"):
                self.runner.step(step)
            if tr is not None:
                tr.complete("app.step", t0, step=step)
            state["host"]["step"] = np.int64(step)
            if pending is not None:
                res = self.runner.sync_poll(pending[0])
                if res is not None:
                    synced_at = self._commit_boundary(
                        state, pending[1], res, on_metrics
                    )
                    pending = None
            if self.policy.should_checkpoint(step):
                if pending is not None:
                    # one epoch in flight at a time: the data plane must be
                    # mirrored before the next SYNC rewrites it
                    synced_at = self._collect_boundary(
                        state, pending, on_metrics
                    )
                with self.timings.measure("train/proxy_sync_begin"):
                    pending = (self.runner.sync_begin(), step)
                if tr is not None:
                    tr.instant("app.sync_begin", epoch=pending[0], step=step)
            if stop is not None and stop():
                break
        if pending is not None:
            synced_at = self._collect_boundary(state, pending, on_metrics)
        if synced_at != step:
            with self.timings.measure("train/proxy_sync"):
                state["device"], info = self.runner.sync_state()
            if on_metrics is not None:
                on_metrics(step, info.get("metrics", {}))
        return state

    def _collect_boundary(
        self,
        state: Any,
        pending: tuple[int, int],
        on_metrics: Callable[[int, Any], None] | None,
    ) -> int:
        with self.timings.measure("train/proxy_sync"):
            res = self.runner.sync_collect(pending[0])
        return self._commit_boundary(state, pending[1], res, on_metrics)

    def _commit_boundary(
        self,
        state: Any,
        boundary: int,
        res: tuple[Any, dict],
        on_metrics: Callable[[int, Any], None] | None,
    ) -> int:
        """SYNCED{epoch} for a checkpoint boundary arrived: checkpoint the
        boundary image under the boundary's step number (the loop may have
        run ahead of it — the whole point of the overlap)."""
        device, info = res
        state["device"] = device
        ck_state = dict(state)
        ck_state["host"] = dict(state["host"])
        ck_state["host"]["step"] = np.int64(boundary)
        if on_metrics is not None:
            on_metrics(boundary, info.get("metrics", {}))
        r = self.checkpoint_now(boundary, ck_state)
        r.stall_us = float(info.get("stall_us", 0.0))
        return boundary

    def materialize(self, state: Any) -> Any:
        """Refresh ``state["device"]`` from the managed space (no-op when
        unmanaged). Callers outside :meth:`run` — preemption handlers, the
        launch CLI — use this before ``checkpoint_now``."""
        if self.space is not None:
            state["device"] = self.space.peek_state()
        return state

    def paging_stats(self) -> dict | None:
        """The managed space's fault/eviction/migration counters."""
        return self.space.stats_dict() if self.space is not None else None

    def checkpoint_now(self, step: int, state: Any) -> CheckpointResult:
        r = self.checkpointer.save_async(step, state, meta={"wall": time.time()})
        self.results.append(r)
        self.policy.notify_checkpointed(step)
        self._gc()
        return r

    def _gc(self) -> None:
        # pin the bases of in-flight incremental persists: their manifests
        # are not on disk yet, so the policy's scan alone cannot see that
        # an older step's chunks are still referenced
        self.policy.run_gc(
            self.store, extra_keep=self.checkpointer.inflight_delta_bases()
        )

    # -- teardown ---------------------------------------------------------------
    def finish(self) -> list[CheckpointResult]:
        # wait on THIS trainer's results, not the checkpointer's pending
        # list: a persist that completed before the next save_async's reap
        # has already left that list, and wait_all() alone would silently
        # return fewer results than checkpoints taken
        self.checkpointer.wait_all()
        for r in self.results:
            r.done.wait()
        self.checkpointer.close()
        if self.runner is not None:
            self.runner.close()
        self._gc()  # in-flight persists have committed by now
        if self.space is not None:
            obs_metrics.absorb_paging(self.space.stats_dict())
        obs_metrics.dump_if_enabled("app")
        return list(self.results)


def _get(tree: Any, *keys: str, default=None) -> Any:
    node = tree
    for k in keys:
        if not isinstance(node, dict) or k not in node:
            return default
        node = node[k]
    return node
