"""CheckpointedTrainer — the paper's technique as a first-class feature.

Wraps any jitted ``train_step(device_state, batch) -> (device_state,
metrics)`` with CRUM-style fault tolerance:

  - forked (two-phase async) checkpointing on a cadence policy,
  - incremental persistence (digest-delta against the previous image),
  - restart: newest committed image -> device state re-placed on the
    current mesh (elastic), data iterator + RNG replayed,
  - preemption-triggered checkpoint, straggler accounting hooks.

State layout (a plain dict pytree; everything checkpointable):

    {"device": {...jax arrays...},        # params / opt state / rng-key-data
     "host":   {"step": np.int64, "data": {...iterator state...}}}
"""
from __future__ import annotations

import time
from typing import Any, Callable, Iterator

import jax
import numpy as np

from repro.checkpoint.codecs import DEFAULT_CODEC
from repro.checkpoint.store import ChunkStore
from repro.core.forked import CheckpointResult, ForkedCheckpointer
from repro.core.policy import CheckpointPolicy
from repro.core.restore import RestoreManager
from repro.utils.timing import Timings


class CheckpointedTrainer:
    def __init__(
        self,
        train_step: Callable[[Any, Any], tuple[Any, Any]],
        *,
        store_root: str,
        policy: CheckpointPolicy | None = None,
        codec: str = DEFAULT_CODEC,
        chunk_bytes: int = 4 << 20,
        incremental: bool = True,
        io_workers: int | None = None,
        host: int = 0,
        backend: str = "thread",
        timings: Timings | None = None,
    ):
        self.train_step = train_step
        self.store = ChunkStore(store_root)
        self.policy = policy or CheckpointPolicy(interval_steps=100)
        self.timings = timings or Timings()
        self.checkpointer = ForkedCheckpointer(
            self.store,
            codec=codec,
            chunk_bytes=chunk_bytes,
            incremental=incremental,
            io_workers=io_workers,
            host=host,
            backend=backend,
            timings=self.timings,
        )
        self.restorer = RestoreManager(self.store, timings=self.timings)
        self.results: list[CheckpointResult] = []

    # -- restart ----------------------------------------------------------------
    def resume_or(
        self,
        init_fn: Callable[[], Any],
        *,
        sharding_for=None,
        verify: bool = False,
    ) -> tuple[Any, int]:
        """Restore the newest committed state or build a fresh one.

        Returns (state, start_step).
        """
        steps = self.restorer.available_steps()
        if not steps:
            state = init_fn()
            return state, int(np.asarray(_get(state, "host", "step", default=0)))
        state, manifest = self.restorer.restore(
            step=steps[-1], sharding_for=sharding_for, verify=verify
        )
        start = int(np.asarray(state["host"]["step"]))
        return state, start

    # -- the train loop -----------------------------------------------------------
    def run(
        self,
        state: Any,
        batches: Iterator[Any],
        *,
        num_steps: int,
        start_step: int = 0,
        on_metrics: Callable[[int, Any], None] | None = None,
    ) -> Any:
        step = start_step
        for _ in range(num_steps):
            batch = next(batches)
            with self.timings.measure("train/step"):
                state["device"], metrics = self.train_step(state["device"], batch)
            step += 1
            state["host"]["step"] = np.int64(step)
            if on_metrics is not None:
                on_metrics(step, metrics)
            if self.policy.should_checkpoint(step):
                self.checkpoint_now(step, state)
        return state

    def checkpoint_now(self, step: int, state: Any) -> CheckpointResult:
        r = self.checkpointer.save_async(step, state, meta={"wall": time.time()})
        self.results.append(r)
        self.policy.notify_checkpointed(step)
        self._gc()
        return r

    def _gc(self) -> None:
        self.policy.run_gc(self.store)

    # -- teardown ---------------------------------------------------------------
    def finish(self) -> list[CheckpointResult]:
        done = self.checkpointer.wait_all()
        self.checkpointer.close()
        self._gc()  # in-flight persists have committed by now
        return done


def _get(tree: Any, *keys: str, default=None) -> Any:
    node = tree
    for k in keys:
        if not isinstance(node, dict) or k not in node:
            return default
        node = node[k]
    return node
