from repro.data.synthetic import SyntheticBatches

__all__ = ["SyntheticBatches"]
