"""Deterministic, checkpointable synthetic data pipeline.

Restart correctness (paper §3.4: "resume the application threads" with no
data loss/duplication) requires the input pipeline's cursor to live inside
the checkpoint. Batches here are a pure function of (seed, step): the
pipeline state is two integers, the restore path replays neither data nor
RNG, and a restored run is bitwise-identical to an uninterrupted one
(asserted by tests/integration/test_restart.py).

Token streams follow a Zipfian-ish distribution (more realistic compression
behaviour for the Table-2/3 benchmarks than uniform noise).
"""
from __future__ import annotations

from typing import Iterator

import numpy as np

from repro.models.config import ModelConfig


class SyntheticBatches:
    def __init__(
        self,
        cfg: ModelConfig,
        *,
        batch: int,
        seq_len: int,
        seed: int = 0,
        start_step: int = 0,
    ):
        self.cfg = cfg
        self.batch = batch
        self.seq_len = seq_len
        self.seed = seed
        self.step = start_step

    # -- checkpointable state ----------------------------------------------------
    def state(self) -> dict:
        return {"seed": np.int64(self.seed), "step": np.int64(self.step)}

    @classmethod
    def from_state(cls, cfg: ModelConfig, *, batch: int, seq_len: int, state: dict):
        return cls(
            cfg, batch=batch, seq_len=seq_len,
            seed=int(np.asarray(state["seed"])),
            start_step=int(np.asarray(state["step"])),
        )

    # -- generation ---------------------------------------------------------------
    def _tokens(self, rng: np.random.Generator, shape) -> np.ndarray:
        v = self.cfg.vocab_size
        z = rng.zipf(1.3, size=shape).astype(np.int64)
        return ((z - 1) % v).astype(np.int32)

    def _batch_at(self, step: int) -> dict:
        rng = np.random.default_rng(
            np.random.PCG64(np.random.SeedSequence([self.seed, step]))
        )
        cfg = self.cfg
        B, S = self.batch, self.seq_len
        if cfg.frontend == "audio":
            toks = self._tokens(rng, (B, S + 1, cfg.audio_codebooks))
            return {"inputs": toks[:, :-1], "targets": toks[:, 1:]}
        out = {}
        toks = self._tokens(rng, (B, S + 1))
        out["inputs"], out["targets"] = toks[:, :-1], toks[:, 1:]
        if cfg.frontend == "vision":
            out["patches"] = rng.standard_normal(
                (B, cfg.num_patches, cfg.d_model), dtype=np.float32
            ).astype(np.float32)
        return out

    def __iter__(self) -> Iterator[dict]:
        return self

    def __next__(self) -> dict:
        b = self._batch_at(self.step)
        self.step += 1
        return b
