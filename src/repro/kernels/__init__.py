from repro.kernels.ops import chunk_digests, digests_to_u64, flash_attention

__all__ = ["chunk_digests", "digests_to_u64", "flash_attention"]
