"""Pallas TPU kernel: per-chunk content digests.

The checkpoint hot path of this framework (DESIGN §2): dirty-chunk
detection runs *on device*, so only a (n_chunks, 2) u32 digest tensor —
not the data — crosses HBM->host before a sync. This kernel is the TPU
adaptation of CRUM's page-fault tracking: the VPU scans HBM-resident state
at memory bandwidth and emits one digest per 4 MiB chunk.

Layout: the caller reshapes the leaf's byte stream to u32 words padded to
(n_chunks, chunk_words). Grid = (n_chunks, n_sub); the sub-block axis is
the innermost (sequential on TPU) axis, accumulating partial mixes into the
(1, 2) output block, which Pallas keeps resident in VMEM across the
sequential axis because its index map ignores ``j``.

Both mixes are associative, so sub-block partials combine exactly:
    lo = wrapping-sum of (w ^ (idx * PRIME))
    hi = xor of (w * ((idx << 1) | 1)), finally xored with SEED
Padding words are masked by comparing idx to the chunk's real word count
(computed from static sizes), so device digests equal host digests
bit-for-bit.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
import numpy as np
from jax.experimental import pallas as pl

from repro.kernels.ref import DIGEST_PRIME, DIGEST_SEED

# 64K words = 256 KiB per sub-block: 8 lanes * 128 sublanes tiles cleanly
# and leaves VMEM headroom for double buffering of the input stream.
SUB_WORDS = 64 * 1024


def _digest_kernel(x_ref, o_ref, *, chunk_words: int, sub_words: int, total_words: int):
    i = pl.program_id(0)  # chunk ordinal
    j = pl.program_id(1)  # sub-block ordinal within the chunk

    w = x_ref[0, :]  # (sub_words,) u32
    base = j * sub_words
    # word index within the chunk, 1-based (u32; sizes < 2**32 words)
    idx = (jax.lax.broadcasted_iota(jnp.uint32, (1, sub_words), 1)[0]
           + jnp.uint32(base) + jnp.uint32(1))
    # real (unpadded) words in this chunk, from static sizes. i32 is safe:
    # a single shard stream is < 2**31 words (8 GiB) on 16 GiB-HBM parts.
    real = jnp.clip(
        jnp.int32(total_words) - i * jnp.int32(chunk_words), 0, chunk_words
    ).astype(jnp.uint32)
    mask = idx <= real

    lo_terms = jnp.where(mask, w ^ (idx * jnp.uint32(DIGEST_PRIME)), jnp.uint32(0))
    lo_part = lo_terms.sum(dtype=jnp.uint32)
    hi_terms = jnp.where(
        mask, w * ((idx << jnp.uint32(1)) | jnp.uint32(1)), jnp.uint32(0)
    )
    hi_part = jax.lax.reduce(
        hi_terms, np.uint32(0), lambda a, b: jax.lax.bitwise_xor(a, b), (0,)
    )

    @pl.when(j == 0)
    def _init():
        o_ref[0, 0] = hi_part ^ jnp.uint32(DIGEST_SEED)
        o_ref[0, 1] = lo_part

    @pl.when(j != 0)
    def _accum():
        o_ref[0, 0] = o_ref[0, 0] ^ hi_part
        o_ref[0, 1] = o_ref[0, 1] + lo_part


@functools.partial(jax.jit, static_argnames=("chunk_words", "total_words", "interpret"))
def digest_words(
    words2d: jax.Array,
    *,
    chunk_words: int,
    total_words: int,
    interpret: bool = False,
) -> jax.Array:
    """Digest a (n_chunks, chunk_words_padded) u32 array -> (n_chunks, 2) u32.

    ``chunk_words`` is the *logical* chunk length; the padded row length
    must be a multiple of SUB_WORDS (or equal to a single smaller tile).
    """
    n_chunks, row = words2d.shape
    sub = min(SUB_WORDS, row)
    if row % sub:
        raise ValueError(f"padded row {row} not a multiple of sub-block {sub}")
    n_sub = row // sub
    kernel = functools.partial(
        _digest_kernel,
        chunk_words=chunk_words,
        sub_words=sub,
        total_words=total_words,
    )
    return pl.pallas_call(
        kernel,
        grid=(n_chunks, n_sub),
        in_specs=[pl.BlockSpec((1, sub), lambda i, j: (i, j))],
        out_specs=pl.BlockSpec((1, 2), lambda i, j: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((n_chunks, 2), jnp.uint32),
        interpret=interpret,
    )(words2d)
