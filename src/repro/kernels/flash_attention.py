"""Pallas TPU kernel: block-wise causal FlashAttention with GQA.

Used by the prefill path (32k-sequence cells) where attention is the
compute hot spot. TPU-native adaptation choices:

  - Block shapes are MXU-aligned: (bq, D) x (bk, D) tiles with D the head
    dim (128-multiples preferred) so the systolic array runs dense.
  - The KV axis is the innermost grid axis -> sequential on TPU; online
    softmax statistics (m, l) and the accumulator live in VMEM scratch and
    persist across that axis (no HBM round-trips per block).
  - GQA is expressed in the BlockSpec index map (kv head = q head // group),
    so grouped heads share KV tiles without materializing repeats.

Numerics: dots in f32 (preferred_element_type), masked logits use -1e30
(not -inf) so fully-masked tiles cannot produce NaNs; output cast back to
the query dtype.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
import numpy as np
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

DEFAULT_BLOCK_Q = 128
DEFAULT_BLOCK_K = 128
_NEG_INF = -1e30


def _flash_kernel(
    q_ref, k_ref, v_ref, o_ref, acc_ref, m_ref, l_ref,
    *, scale: float, causal: bool, block_q: int, block_k: int,
    seq_q: int, seq_k: int,
):
    iq = pl.program_id(2)
    ik = pl.program_id(3)
    nk = pl.num_programs(3)

    @pl.when(ik == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)
        m_ref[...] = jnp.full_like(m_ref, _NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)

    q = q_ref[0, 0, :, :]  # (bq, D)
    k = k_ref[0, 0, :, :]  # (bk, D)
    v = v_ref[0, 0, :, :]  # (bk, D)

    s = jax.lax.dot_general(
        q, k, (((1,), (1,)), ((), ())), preferred_element_type=jnp.float32
    ) * scale  # (bq, bk)

    if causal:
        # decode/cache alignment: query row r attends keys <= r + (Sk - Sq)
        offset = seq_k - seq_q
        rows = iq * block_q + jax.lax.broadcasted_iota(jnp.int32, (block_q, block_k), 0)
        cols = ik * block_k + jax.lax.broadcasted_iota(jnp.int32, (block_q, block_k), 1)
        s = jnp.where(cols <= rows + offset, s, _NEG_INF)

    m_prev = m_ref[...]                      # (bq, 1)
    m_new = jnp.maximum(m_prev, s.max(axis=1, keepdims=True))
    p = jnp.exp(s - m_new)                   # (bq, bk)
    alpha = jnp.exp(m_prev - m_new)          # (bq, 1)
    l_ref[...] = l_ref[...] * alpha + p.sum(axis=1, keepdims=True)
    acc_ref[...] = acc_ref[...] * alpha + jax.lax.dot_general(
        p, v, (((1,), (0,)), ((), ())), preferred_element_type=jnp.float32
    )
    m_ref[...] = m_new

    @pl.when(ik == nk - 1)
    def _finalize():
        l = l_ref[...]
        l = jnp.where(l == 0.0, 1.0, l)  # fully-masked rows -> zeros, not NaNs
        o_ref[0, 0, :, :] = (acc_ref[...] / l).astype(o_ref.dtype)


@functools.partial(
    jax.jit,
    static_argnames=("causal", "scale", "block_q", "block_k", "interpret"),
)
def flash_attention_pallas(
    q: jax.Array,
    k: jax.Array,
    v: jax.Array,
    *,
    causal: bool = True,
    scale: float | None = None,
    block_q: int = DEFAULT_BLOCK_Q,
    block_k: int = DEFAULT_BLOCK_K,
    interpret: bool = False,
) -> jax.Array:
    """q: (B, Hq, Sq, D); k, v: (B, Hkv, Sk, D). Returns (B, Hq, Sq, D)."""
    B, Hq, Sq, D = q.shape
    _, Hkv, Sk, _ = k.shape
    if Hq % Hkv:
        raise ValueError(f"Hq={Hq} must be a multiple of Hkv={Hkv}")
    group = Hq // Hkv
    bq = min(block_q, Sq)
    bk = min(block_k, Sk)
    if Sq % bq or Sk % bk:
        raise ValueError(f"seq lengths ({Sq},{Sk}) not divisible by blocks ({bq},{bk})")
    scale_f = float(scale if scale is not None else 1.0 / np.sqrt(D))

    kernel = functools.partial(
        _flash_kernel,
        scale=scale_f, causal=causal,
        block_q=bq, block_k=bk, seq_q=Sq, seq_k=Sk,
    )
    grid = (B, Hq, Sq // bq, Sk // bk)
    return pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, 1, bq, D), lambda b, h, iq, ik: (b, h, iq, 0)),
            pl.BlockSpec((1, 1, bk, D), lambda b, h, iq, ik, g=group: (b, h // g, ik, 0)),
            pl.BlockSpec((1, 1, bk, D), lambda b, h, iq, ik, g=group: (b, h // g, ik, 0)),
        ],
        out_specs=pl.BlockSpec((1, 1, bq, D), lambda b, h, iq, ik: (b, h, iq, 0)),
        out_shape=jax.ShapeDtypeStruct(q.shape, q.dtype),
        scratch_shapes=[
            pltpu.VMEM((bq, D), jnp.float32),
            pltpu.VMEM((bq, 1), jnp.float32),
            pltpu.VMEM((bq, 1), jnp.float32),
        ],
        interpret=interpret,
    )(q, k, v)
