"""Public jit'd wrappers for the kernels package.

Dispatch policy (``use_pallas``):
  - ``"auto"``  — Pallas on TPU backends, jnp reference elsewhere (this
                  container is CPU-only, so auto == reference here; the
                  dry-run/roofline path intentionally lowers the jnp path).
  - ``"interpret"`` — Pallas kernel body executed by the interpreter (CPU
                  correctness validation; used by tests/kernels/).
  - ``"pallas"`` / ``"ref"`` — forced.
"""
from __future__ import annotations

import functools
from typing import Literal

import jax
import jax.numpy as jnp
import numpy as np

from repro.checkpoint.chunking import num_chunks
from repro.kernels import ref as _ref
from repro.kernels.chunk_digest import SUB_WORDS, digest_words
from repro.kernels.flash_attention import flash_attention_pallas

Dispatch = Literal["auto", "interpret", "pallas", "ref"]


def _on_tpu() -> bool:
    return jax.default_backend() == "tpu"


def _resolve(use_pallas: Dispatch) -> str:
    if use_pallas == "auto":
        return "pallas" if _on_tpu() else "ref"
    return use_pallas


# ---------------------------------------------------------------------------
# chunk digests
# ---------------------------------------------------------------------------

@functools.partial(jax.jit, static_argnames=("chunk_bytes", "mode"))
def _chunk_digests_jit(x: jax.Array, chunk_bytes: int, mode: str) -> jax.Array:
    if mode == "ref":
        return _ref.chunk_digests_jnp(x, chunk_bytes)
    words = _ref.to_u32_words(x)
    total_words = words.shape[0]
    cw = chunk_bytes // 4
    n = num_chunks(total_words * 4, chunk_bytes)
    sub = min(SUB_WORDS, cw)
    row = -(-cw // sub) * sub  # pad row length to sub-block multiple
    padded = n * row
    if padded != total_words:
        words = jnp.concatenate(
            [words, jnp.zeros((padded - total_words,), jnp.uint32)]
        )
    words2d = words.reshape(n, row)
    return digest_words(
        words2d,
        chunk_words=cw,
        total_words=total_words,
        interpret=(mode == "interpret"),
    )


def chunk_digests(
    x: jax.Array, chunk_bytes: int, *, use_pallas: Dispatch = "auto"
) -> jax.Array:
    """Per-chunk digests of an array's byte stream -> (n_chunks, 2) u32 [hi, lo].

    Bit-identical to ``checkpoint.chunking.chunk_digest_np`` over the same
    chunk bytes (the shadow manager compares them directly).
    """
    if chunk_bytes % 4:
        raise ValueError("chunk_bytes must be a multiple of 4")
    mode = _resolve(use_pallas)
    if mode == "ref":
        return _chunk_digests_jit(x, chunk_bytes, "ref")
    return _chunk_digests_jit(x, chunk_bytes, mode)


def digests_to_u64(d: jax.Array | np.ndarray) -> np.ndarray:
    """(n, 2) u32 [hi, lo] -> (n,) python-int-compatible u64 digests."""
    d = np.asarray(d)
    return (d[:, 0].astype(np.uint64) << np.uint64(32)) | d[:, 1].astype(np.uint64)


def tree_chunk_digests(
    state, chunk_bytes: int, *, use_pallas: Dispatch = "auto"
) -> dict[str, list[int]]:
    """Per-chunk u64 digests of every leaf: {path: [digest, ...]}.

    The fused-digest primitive: a step program calls this as its final
    pass so the sync boundary receives ready-made digests instead of
    re-scanning the state (``ShadowStateManager.sync(device_digests=...)``).
    jax leaves go through the :func:`chunk_digests` kernel dispatch
    (Pallas on TPU, jnp reference elsewhere); host leaves hash with the
    bit-identical numpy reference.
    """
    from repro.checkpoint.chunking import chunk_digest_np
    from repro.utils.tree import flatten_with_paths

    flat, _ = flatten_with_paths(state)
    out: dict[str, list[int]] = {}
    for path, leaf in flat.items():
        if isinstance(leaf, jax.Array):
            d = digests_to_u64(
                chunk_digests(leaf, chunk_bytes, use_pallas=use_pallas)
            )
            out[path] = [int(x) for x in d]
            continue
        raw = np.ascontiguousarray(np.asarray(leaf)).reshape(-1).view(np.uint8)
        cb = int(chunk_bytes)
        out[path] = [
            chunk_digest_np(raw[i * cb : min(raw.nbytes, (i + 1) * cb)])
            for i in range(num_chunks(raw.nbytes, cb))
        ]
    return out


# ---------------------------------------------------------------------------
# flash attention
# ---------------------------------------------------------------------------

def flash_attention(
    q: jax.Array,
    k: jax.Array,
    v: jax.Array,
    *,
    causal: bool = True,
    scale: float | None = None,
    block_q: int = 128,
    block_k: int = 128,
    use_pallas: Dispatch = "auto",
) -> jax.Array:
    """Causal GQA attention. q: (B,Hq,Sq,D); k,v: (B,Hkv,Sk,D)."""
    mode = _resolve(use_pallas)
    if mode == "ref":
        return _ref.mha_reference(q, k, v, causal=causal, scale=scale)
    return flash_attention_pallas(
        q, k, v,
        causal=causal, scale=scale,
        block_q=block_q, block_k=block_k,
        interpret=(mode == "interpret"),
    )
