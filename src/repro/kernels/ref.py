"""Pure-jnp / numpy oracles for every Pallas kernel.

Each kernel in this package must agree with its oracle bit-for-bit
(digests) or to numerical tolerance (attention) across the shape/dtype
sweeps in tests/kernels/.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from repro.checkpoint.chunking import chunk_digest_np, num_chunks

DIGEST_PRIME = np.uint32(16777619)
DIGEST_SEED = np.uint32(2166136261)


# ---------------------------------------------------------------------------
# chunk_digest
# ---------------------------------------------------------------------------

def chunk_digests_np(arr: np.ndarray, chunk_bytes: int) -> np.ndarray:
    """Host oracle: (n_chunks, 2) u32 [hi, lo] digests of the byte stream."""
    raw = np.ascontiguousarray(arr).view(np.uint8).reshape(-1)
    n = num_chunks(raw.nbytes, chunk_bytes)
    out = np.zeros((n, 2), np.uint32)
    for i in range(n):
        d = chunk_digest_np(raw[i * chunk_bytes : min(raw.nbytes, (i + 1) * chunk_bytes)])
        out[i, 0] = np.uint32(d >> 32)
        out[i, 1] = np.uint32(d & 0xFFFFFFFF)
    return out


def to_u32_words(x: jax.Array) -> jax.Array:
    """Bit-reinterpret any array as a flat little-endian u32 word stream.

    Matches numpy's ``.view(np.uint8)`` + zero-pad + ``.view(np.uint32)``.
    """
    flat = x.reshape(-1)
    if flat.dtype.itemsize == 4:
        return jax.lax.bitcast_convert_type(flat, jnp.uint32)
    b = jax.lax.bitcast_convert_type(flat, jnp.uint8)  # (n, itemsize) or (n,)
    b = b.reshape(-1)
    pad = (-b.shape[0]) % 4
    if pad:
        b = jnp.concatenate([b, jnp.zeros((pad,), jnp.uint8)])
    return jax.lax.bitcast_convert_type(b.reshape(-1, 4), jnp.uint32)


def chunk_digests_jnp(x: jax.Array, chunk_bytes: int) -> jax.Array:
    """jit-friendly oracle: same math as :func:`chunk_digest_np`, batched."""
    if chunk_bytes % 4:
        raise ValueError("chunk_bytes must be a multiple of 4")
    words = to_u32_words(x)
    nbytes = int(np.prod(x.shape, dtype=np.int64)) * x.dtype.itemsize
    total_words = words.shape[0]
    cw = chunk_bytes // 4
    n = num_chunks(nbytes, chunk_bytes)
    padded = n * cw
    if padded != total_words:
        words = jnp.concatenate(
            [words, jnp.zeros((padded - total_words,), jnp.uint32)]
        )
    w = words.reshape(n, cw)
    idx = jax.lax.broadcasted_iota(jnp.uint32, (n, cw), 1) + jnp.uint32(1)
    # real word counts are static (shapes known at trace time)
    real = jnp.asarray(
        np.minimum(
            cw, np.maximum(total_words - np.arange(n, dtype=np.int64) * cw, 0)
        ).astype(np.uint32)
    )
    mask = idx <= real[:, None]
    lo_terms = jnp.where(mask, w ^ (idx * jnp.uint32(DIGEST_PRIME)), jnp.uint32(0))
    lo = lo_terms.sum(axis=1, dtype=jnp.uint32)
    hi_terms = jnp.where(
        mask, w * ((idx << jnp.uint32(1)) | jnp.uint32(1)), jnp.uint32(0)
    )
    hi = jax.lax.reduce(
        hi_terms, np.uint32(0), lambda a, b: jax.lax.bitwise_xor(a, b), (1,)
    ) ^ jnp.uint32(DIGEST_SEED)
    return jnp.stack([hi, lo], axis=1)


# ---------------------------------------------------------------------------
# flash_attention
# ---------------------------------------------------------------------------

def mha_reference(
    q: jax.Array,
    k: jax.Array,
    v: jax.Array,
    *,
    causal: bool = True,
    scale: float | None = None,
) -> jax.Array:
    """Dense softmax attention oracle.

    q: (B, Hq, Sq, D); k, v: (B, Hkv, Sk, D) with Hq % Hkv == 0 (GQA).
    Returns (B, Hq, Sq, D) in q's dtype; softmax in f32.
    """
    B, Hq, Sq, D = q.shape
    Hkv = k.shape[1]
    if Hq % Hkv:
        raise ValueError(f"Hq={Hq} not a multiple of Hkv={Hkv}")
    group = Hq // Hkv
    scale = float(scale if scale is not None else 1.0 / np.sqrt(D))
    kq = jnp.repeat(k, group, axis=1)
    vq = jnp.repeat(v, group, axis=1)
    s = jnp.einsum("bhqd,bhkd->bhqk", q.astype(jnp.float32), kq.astype(jnp.float32))
    s = s * scale
    if causal:
        Sk = k.shape[2]
        qpos = jnp.arange(Sq)[:, None] + (Sk - Sq)  # right-aligned (cache decode)
        kpos = jnp.arange(Sk)[None, :]
        s = jnp.where(kpos <= qpos, s, -jnp.inf)
    p = jax.nn.softmax(s, axis=-1)
    out = jnp.einsum("bhqk,bhkd->bhqd", p, vq.astype(jnp.float32))
    return out.astype(q.dtype)
