"""Coordinated multi-process cluster driver with failure drills.

Runs N simulated hosts as real OS processes under the CRUM coordinator:
every host trains in lockstep, persists its shard of each checkpoint via
its local forked checkpointer, and the coordinator two-phase-commits the
merged image. Failure injections exercise the recovery paths end to end:

    # 4 hosts; host 2 is killed at step 6, respawned, restored, and the
    # cluster converges back to lockstep
    PYTHONPATH=src python -m repro.launch.cluster \\
        --hosts 4 --kill-host 2 --kill-at-step 6

    # crash-mid-commit drill: host 1 dies after its hostmeta is written
    # but before acking — the round aborts, the previous image stands
    PYTHONPATH=src python -m repro.launch.cluster \\
        --hosts 3 --die-after-persist-host 1 --die-after-persist-step 6

    # a straggling host slows the round but never blocks correctness
    PYTHONPATH=src python -m repro.launch.cluster \\
        --hosts 4 --straggle-host 3 --straggle-s 1.0

    # divergence-provenance drill: one byte of host 1's state is flipped
    # after step 4 — the watchdog's digest_divergence alert must name the
    # first divergent chunk and the culprit host
    PYTHONPATH=src python -m repro.launch.cluster \\
        --hosts 2 --steps 6 --corrupt-host 1 --corrupt-at-step 4

    # REMOTE proxies: every worker's device proxy is placed on one of 2
    # proxy-host daemons (streamed chunk transport); daemon 0 is
    # SIGKILLed after the first commit — affected workers are rescheduled
    # onto the survivor and their API logs replayed there
    PYTHONPATH=src python -m repro.launch.cluster \\
        --hosts 2 --device-runner proxy --proxy-hosts 2 --kill-proxy-host 0

    # ELASTIC restart: run 4 hosts to step 4, then restore the committed
    # 4-host image onto 6 hosts and continue to step 8 (the manifest is
    # topology-independent; shards re-slice onto any count)
    PYTHONPATH=src python -m repro.launch.cluster \\
        --hosts 4 --steps 8 --ckpt-every 2 \\
        --restart-at-step 4 --hosts-after-restart 6

Exits non-zero if the cluster fails to converge (hosts finish with
different state digests) or no checkpoint ever commits.
"""
from __future__ import annotations

import argparse
import json
import sys
import tempfile

from repro.checkpoint.codecs import DEFAULT_CODEC
from repro.coord.supervisor import run_cluster
from repro.core.forked import list_persist_backends


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(
        description=__doc__, formatter_class=argparse.RawDescriptionHelpFormatter
    )
    ap.add_argument("--hosts", type=int, default=4)
    ap.add_argument("--steps", type=int, default=9)
    ap.add_argument("--ckpt-every", type=int, default=3)
    ap.add_argument("--ckpt-dir", default=None,
                    help="checkpoint root (default: fresh temp dir)")
    ap.add_argument("--backend", choices=list_persist_backends(),
                    default="thread")
    ap.add_argument("--loop", choices=["numpy", "jax"], default="numpy",
                    help="worker train loop: numpy (fast) or jax (real model)")
    ap.add_argument("--device-runner", choices=["inline", "proxy"],
                    default="inline",
                    help="inline: step in the worker process; proxy: each "
                         "worker hosts a restartable device-proxy process")
    ap.add_argument("--codec", default=DEFAULT_CODEC)
    ap.add_argument("--width", type=int, default=64)
    ap.add_argument("--chunk-bytes", type=int, default=1 << 16)
    ap.add_argument("--keep-last", type=int, default=0,
                    help="coordinator GC: keep last K committed steps (0=all)")
    ap.add_argument("--step-time-s", type=float, default=0.0)
    ap.add_argument("--heartbeat-timeout-s", type=float, default=10.0)
    ap.add_argument("--round-timeout-s", type=float, default=120.0)
    ap.add_argument("--deadline-s", type=float, default=600.0)
    # failure drills
    ap.add_argument("--kill-host", type=int, default=None)
    ap.add_argument("--kill-at-step", type=int, default=None)
    ap.add_argument("--die-after-persist-host", type=int, default=None)
    ap.add_argument("--die-after-persist-step", type=int, default=None)
    ap.add_argument("--straggle-host", type=int, default=None)
    ap.add_argument("--straggle-s", type=float, default=0.0)
    ap.add_argument("--stall-host", type=int, default=None)
    ap.add_argument("--stall-s", type=float, default=0.0)
    ap.add_argument("--stall-at-step", type=int, default=None)
    ap.add_argument("--corrupt-host", type=int, default=None,
                    help="flip one byte of this host's state after the "
                         "given step (divergence-provenance drill: the "
                         "watchdog must name the first forked chunk)")
    ap.add_argument("--corrupt-at-step", type=int, default=None)
    # remote proxies
    ap.add_argument("--proxy-hosts", type=int, default=0,
                    help="place worker proxies on this many proxy-host "
                         "daemons via the coordinator (needs --device-runner "
                         "proxy); 0 = spawn proxies locally")
    ap.add_argument("--proxy-transport", choices=["segment", "stream"],
                    default="stream",
                    help="data plane for placed proxies: stream = chunk "
                         "frames over TCP (cross-host); segment = shared "
                         "files (same machine only)")
    ap.add_argument("--kill-proxy-host", type=int, default=None,
                    help="SIGKILL proxy-host daemon #i mid-run (reschedule "
                         "drill; needs --proxy-hosts >= 2)")
    ap.add_argument("--kill-proxy-after-commits", type=int, default=1)
    # elastic restart
    ap.add_argument("--hosts-after-restart", type=int, default=None,
                    help="after --restart-at-step, restore the committed "
                         "image onto THIS many hosts and continue to --steps")
    ap.add_argument("--restart-at-step", type=int, default=None,
                    help="end phase 1 at this step (should be a checkpoint "
                         "boundary so a committed image exists)")
    ap.add_argument("--no-sweep", action="store_true",
                    help="keep aborted/partial step dirs for inspection")
    ap.add_argument("--obs-dir", default=None, metavar="DIR",
                    help="enable observability: per-process trace shards and "
                         "metrics snapshots land here (merge with "
                         "`python -m repro.obs.report DIR`)")
    # SLO watchdog policy
    ap.add_argument("--abort-on-critical", action="store_true",
                    help="a critical watchdog alert aborts the open "
                         "checkpoint round (the previous image stands)")
    ap.add_argument("--expect-no-alerts", action="store_true",
                    help="exit non-zero if the watchdog raised ANY alert — "
                         "the happy-path CI gate")
    args = ap.parse_args(argv)

    root = args.ckpt_dir or tempfile.mkdtemp(prefix="crum-cluster-")
    print(f"[cluster] hosts={args.hosts} steps={args.steps} "
          f"ckpt_every={args.ckpt_every} backend={args.backend} "
          f"loop={args.loop} device_runner={args.device_runner} "
          f"root={root}", flush=True)

    common = dict(
        root=root,
        ckpt_every=args.ckpt_every,
        backend=args.backend,
        loop=args.loop,
        device_runner=args.device_runner,
        codec=args.codec,
        chunk_bytes=args.chunk_bytes,
        width=args.width,
        step_time_s=args.step_time_s,
        keep_last=args.keep_last,
        heartbeat_timeout_s=args.heartbeat_timeout_s,
        round_timeout_s=args.round_timeout_s,
        deadline_s=args.deadline_s,
        proxy_hosts=args.proxy_hosts,
        proxy_transport=args.proxy_transport,
        sweep=not args.no_sweep,
        obs_dir=args.obs_dir,
        abort_on_critical=args.abort_on_critical,
    )

    if args.restart_at_step is not None and args.hosts_after_restart is None:
        ap.error("--restart-at-step needs --hosts-after-restart")
    if args.hosts_after_restart is not None:
        if args.restart_at_step is None:
            ap.error("--hosts-after-restart needs --restart-at-step")
        if args.ckpt_every <= 0 or args.restart_at_step % args.ckpt_every:
            ap.error("--restart-at-step must be a checkpoint boundary")
        drills = [
            args.kill_host, args.kill_at_step, args.die_after_persist_host,
            args.die_after_persist_step, args.straggle_host, args.stall_host,
            args.kill_proxy_host, args.corrupt_host,
        ]
        if any(d is not None for d in drills) or args.straggle_s or args.stall_s:
            # refusing beats silently running both phases without the
            # drill and reporting a "passed" run that never exercised it
            ap.error("failure drills cannot be combined with an elastic "
                     "restart run; drill each phase separately")
        # the numpy state's shape must not change with the host count —
        # pin rows to the larger phase so both slicings cover one image
        common["rows"] = max(args.hosts, args.hosts_after_restart, 2) * 8
        print(f"[cluster] phase 1: {args.hosts} hosts to step "
              f"{args.restart_at_step}", flush=True)
        phase1 = run_cluster(
            n_hosts=args.hosts, total_steps=args.restart_at_step, **common
        )
        if phase1.latest_committed != args.restart_at_step:
            print(f"[cluster] FAIL: phase 1 never committed step "
                  f"{args.restart_at_step}", file=sys.stderr)
            return 1
        print(f"[cluster] phase 2 (elastic): {args.hosts_after_restart} "
              f"hosts restore step {phase1.latest_committed} and continue "
              f"to {args.steps}", flush=True)
        report = run_cluster(
            n_hosts=args.hosts_after_restart, total_steps=args.steps,
            **common,
        )
        n_hosts_final = args.hosts_after_restart
    else:
        report = run_cluster(
            n_hosts=args.hosts,
            total_steps=args.steps,
            kill_host=args.kill_host,
            kill_at_step=args.kill_at_step,
            die_after_persist_host=args.die_after_persist_host,
            die_after_persist_step=args.die_after_persist_step,
            straggle_host=args.straggle_host,
            straggle_s=args.straggle_s,
            stall_host=args.stall_host,
            stall_s=args.stall_s,
            stall_at_step=args.stall_at_step,
            corrupt_host=args.corrupt_host,
            corrupt_at_step=args.corrupt_at_step,
            kill_proxy_host=args.kill_proxy_host,
            kill_proxy_after_commits=args.kill_proxy_after_commits,
            **common,
        )
        n_hosts_final = args.hosts

    for r in report.rounds:
        line = (f"[round] step={r.step} {r.status} "
                f"participants={r.participants} acked={r.acked}")
        if r.status == "committed":
            line += (f" commit={r.commit_s*1e3:.1f}ms "
                     f"round={r.round_s*1e3:.0f}ms "
                     f"persist_max={r.persist_s_max*1e3:.0f}ms "
                     f"bytes={r.bytes_written}")
            if r.stragglers:
                line += f" stragglers={r.stragglers}"
        else:
            line += f" reason={r.reason!r}"
        print(line, flush=True)

    for a in report.alerts:
        print(f"[alert] {a.get('severity', '?')}: {a.get('kind', '?')} "
              f"host={a.get('host')} step={a.get('step')} "
              f"{a.get('message', '')}", flush=True)

    # every injected failure has an alert signature; a drill whose
    # signature never fired means the watchdog is blind to that failure
    expected_kinds: set[str] = set()
    if args.kill_host is not None and args.kill_at_step is not None:
        expected_kinds.add("worker_death")
    if args.die_after_persist_host is not None \
            and args.die_after_persist_step is not None:
        expected_kinds.add("worker_death")
    if args.stall_host is not None and args.stall_s:
        expected_kinds.add("worker_death")
    if args.straggle_host is not None and args.straggle_s:
        expected_kinds.add("straggler")
    if args.kill_proxy_host is not None:
        expected_kinds.add("proxy_host_death")
    corrupt_drill = (args.corrupt_host is not None
                     and args.corrupt_at_step is not None)
    if corrupt_drill:
        expected_kinds.add("digest_divergence")

    lockstep = report.lockstep()
    summary = {
        "hosts": n_hosts_final,
        "latest_committed": report.latest_committed,
        "rounds_committed": len(report.committed),
        "rounds_aborted": len(report.aborted),
        "restarts": report.restarts,
        "lockstep_converged": lockstep,
        "final_digest": next(iter(report.final_digests.values()), None),
        "log": report.log_path,
        "alerts": report.alerts,
        "alert_kinds": sorted(report.alert_kinds()),
    }
    if args.proxy_hosts:
        summary["proxy_placements"] = [
            [w, n] for w, n in report.proxy_placements
        ]
        summary["killed_proxy_hosts"] = report.killed_proxy_hosts
    print(json.dumps(summary, indent=2))

    if corrupt_drill:
        # the injection *makes* the hosts diverge — converging would mean
        # it never took; what must hold instead is that the watchdog's
        # divergence alert carries provenance: the first forked chunk
        if lockstep:
            print("[cluster] FAIL: corrupt drill ran but hosts still "
                  "converged (injection never took)", file=sys.stderr)
            return 1
        named = [a for a in report.alerts
                 if a.get("kind") == "digest_divergence"
                 and a.get("chunk") is not None]
        if not named:
            print("[cluster] FAIL: divergence alert fired but named no "
                  "chunk (provenance lost)", file=sys.stderr)
            return 1
        a = named[0]
        print(f"[cluster] divergence provenance OK: chunk={a['chunk']!r} "
              f"index={a.get('chunk_index')} host={a.get('host')}",
              flush=True)
    elif not lockstep:
        print("[cluster] FAIL: hosts finished with diverged state",
              file=sys.stderr)
        return 1
    if report.latest_committed is None and args.steps >= args.ckpt_every > 0:
        print("[cluster] FAIL: no checkpoint round ever committed",
              file=sys.stderr)
        return 1
    if args.expect_no_alerts and report.alerts:
        print(f"[cluster] FAIL: watchdog raised "
              f"{sorted(report.alert_kinds())} on a run expected to be "
              f"alert-free", file=sys.stderr)
        return 1
    missing = expected_kinds - report.alert_kinds()
    if missing:
        print(f"[cluster] FAIL: drill ran but watchdog never raised "
              f"{sorted(missing)} (got {sorted(report.alert_kinds())})",
              file=sys.stderr)
        return 1
    return 0


if __name__ == "__main__":
    sys.exit(main())
