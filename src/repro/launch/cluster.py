"""Coordinated multi-process cluster driver with failure drills.

Runs N simulated hosts as real OS processes under the CRUM coordinator:
every host trains in lockstep, persists its shard of each checkpoint via
its local forked checkpointer, and the coordinator two-phase-commits the
merged image. Failure injections exercise the recovery paths end to end:

    # 4 hosts; host 2 is killed at step 6, respawned, restored, and the
    # cluster converges back to lockstep
    PYTHONPATH=src python -m repro.launch.cluster \\
        --hosts 4 --kill-host 2 --kill-at-step 6

    # crash-mid-commit drill: host 1 dies after its hostmeta is written
    # but before acking — the round aborts, the previous image stands
    PYTHONPATH=src python -m repro.launch.cluster \\
        --hosts 3 --die-after-persist-host 1 --die-after-persist-step 6

    # a straggling host slows the round but never blocks correctness
    PYTHONPATH=src python -m repro.launch.cluster \\
        --hosts 4 --straggle-host 3 --straggle-s 1.0

Exits non-zero if the cluster fails to converge (hosts finish with
different state digests) or no checkpoint ever commits.
"""
from __future__ import annotations

import argparse
import json
import sys
import tempfile

from repro.checkpoint.codecs import DEFAULT_CODEC
from repro.coord.supervisor import run_cluster
from repro.core.forked import list_persist_backends


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(
        description=__doc__, formatter_class=argparse.RawDescriptionHelpFormatter
    )
    ap.add_argument("--hosts", type=int, default=4)
    ap.add_argument("--steps", type=int, default=9)
    ap.add_argument("--ckpt-every", type=int, default=3)
    ap.add_argument("--ckpt-dir", default=None,
                    help="checkpoint root (default: fresh temp dir)")
    ap.add_argument("--backend", choices=list_persist_backends(),
                    default="thread")
    ap.add_argument("--loop", choices=["numpy", "jax"], default="numpy",
                    help="worker train loop: numpy (fast) or jax (real model)")
    ap.add_argument("--device-runner", choices=["inline", "proxy"],
                    default="inline",
                    help="inline: step in the worker process; proxy: each "
                         "worker hosts a restartable device-proxy process")
    ap.add_argument("--codec", default=DEFAULT_CODEC)
    ap.add_argument("--width", type=int, default=64)
    ap.add_argument("--chunk-bytes", type=int, default=1 << 16)
    ap.add_argument("--keep-last", type=int, default=0,
                    help="coordinator GC: keep last K committed steps (0=all)")
    ap.add_argument("--step-time-s", type=float, default=0.0)
    ap.add_argument("--heartbeat-timeout-s", type=float, default=10.0)
    ap.add_argument("--round-timeout-s", type=float, default=120.0)
    ap.add_argument("--deadline-s", type=float, default=600.0)
    # failure drills
    ap.add_argument("--kill-host", type=int, default=None)
    ap.add_argument("--kill-at-step", type=int, default=None)
    ap.add_argument("--die-after-persist-host", type=int, default=None)
    ap.add_argument("--die-after-persist-step", type=int, default=None)
    ap.add_argument("--straggle-host", type=int, default=None)
    ap.add_argument("--straggle-s", type=float, default=0.0)
    ap.add_argument("--stall-host", type=int, default=None)
    ap.add_argument("--stall-s", type=float, default=0.0)
    ap.add_argument("--stall-at-step", type=int, default=None)
    ap.add_argument("--no-sweep", action="store_true",
                    help="keep aborted/partial step dirs for inspection")
    args = ap.parse_args(argv)

    root = args.ckpt_dir or tempfile.mkdtemp(prefix="crum-cluster-")
    print(f"[cluster] hosts={args.hosts} steps={args.steps} "
          f"ckpt_every={args.ckpt_every} backend={args.backend} "
          f"loop={args.loop} device_runner={args.device_runner} "
          f"root={root}", flush=True)

    report = run_cluster(
        root=root,
        n_hosts=args.hosts,
        total_steps=args.steps,
        ckpt_every=args.ckpt_every,
        backend=args.backend,
        loop=args.loop,
        device_runner=args.device_runner,
        codec=args.codec,
        chunk_bytes=args.chunk_bytes,
        width=args.width,
        step_time_s=args.step_time_s,
        keep_last=args.keep_last,
        heartbeat_timeout_s=args.heartbeat_timeout_s,
        round_timeout_s=args.round_timeout_s,
        deadline_s=args.deadline_s,
        kill_host=args.kill_host,
        kill_at_step=args.kill_at_step,
        die_after_persist_host=args.die_after_persist_host,
        die_after_persist_step=args.die_after_persist_step,
        straggle_host=args.straggle_host,
        straggle_s=args.straggle_s,
        stall_host=args.stall_host,
        stall_s=args.stall_s,
        stall_at_step=args.stall_at_step,
        sweep=not args.no_sweep,
    )

    for r in report.rounds:
        line = (f"[round] step={r.step} {r.status} "
                f"participants={r.participants} acked={r.acked}")
        if r.status == "committed":
            line += (f" commit={r.commit_s*1e3:.1f}ms "
                     f"round={r.round_s*1e3:.0f}ms "
                     f"persist_max={r.persist_s_max*1e3:.0f}ms "
                     f"bytes={r.bytes_written}")
            if r.stragglers:
                line += f" stragglers={r.stragglers}"
        else:
            line += f" reason={r.reason!r}"
        print(line, flush=True)

    lockstep = report.lockstep()
    summary = {
        "hosts": args.hosts,
        "latest_committed": report.latest_committed,
        "rounds_committed": len(report.committed),
        "rounds_aborted": len(report.aborted),
        "restarts": report.restarts,
        "lockstep_converged": lockstep,
        "final_digest": next(iter(report.final_digests.values()), None),
        "log": report.log_path,
    }
    print(json.dumps(summary, indent=2))

    if not lockstep:
        print("[cluster] FAIL: hosts finished with diverged state",
              file=sys.stderr)
        return 1
    if report.latest_committed is None and args.steps >= args.ckpt_every > 0:
        print("[cluster] FAIL: no checkpoint round ever committed",
              file=sys.stderr)
        return 1
    return 0


if __name__ == "__main__":
    sys.exit(main())
