import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

"""Multi-pod dry-run: prove every (arch x shape x mesh) lowers + compiles.

The two lines above MUST run before any jax import (jax locks the device
count at first init); 512 placeholder host devices back the production
meshes. Usage:

    PYTHONPATH=src python -m repro.launch.dryrun --arch qwen2-0.5b \
        --shape train_4k --mesh single
    PYTHONPATH=src python -m repro.launch.dryrun --all --out dryrun.jsonl

Per cell it records compiled.memory_analysis() (fits-on-chip proof),
cost_analysis() FLOPs/bytes, the parsed collective schedule, and the three
roofline terms (runtime/hlo.py).
"""
import argparse
import json
import sys
import time
import traceback

import jax
import numpy as np

from repro.configs import get_config, list_archs
from repro.launch.mesh import make_production_mesh, use_mesh
from repro.models import SHAPES, build, shape_applicable
from repro.optim import get_optimizer
from repro.runtime import hlo
from repro.runtime.sharding import ShardingRules
from repro.runtime.steps import make_decode_step, make_prefill_step, make_train_step

HBM_PER_CHIP = 16 << 30  # v5e: 16 GiB


def _tokens_of(cfg, shape_name: str) -> int:
    info = SHAPES[shape_name]
    if info["kind"] == "train" or info["kind"] == "prefill":
        return info["seq_len"] * info["global_batch"]
    return info["global_batch"]  # decode: one token per sequence


def run_cell(
    arch: str,
    shape_name: str,
    mesh_kind: str,
    *,
    fsdp: bool = True,
    overrides: dict | None = None,
) -> dict:
    cfg = get_config(arch)
    if overrides:
        cfg = cfg.with_overrides(**overrides)
    ok, why = shape_applicable(cfg, shape_name)
    rec = {
        "arch": arch, "shape": shape_name, "mesh": mesh_kind,
        "family": cfg.family, "status": "", "detail": "",
    }
    if not ok:
        rec.update(status="skip", detail=why)
        return rec

    mesh = make_production_mesh(multi_pod=(mesh_kind == "multi"))
    chips = int(np.prod(list(mesh.shape.values())))
    model = build(cfg)
    rules = ShardingRules(cfg=cfg, mesh=mesh, fsdp=fsdp)
    info = SHAPES[shape_name]
    kind = info["kind"]

    def _with_sh(abs_tree, sh_tree):
        # attach shardings to ShapeDtypeStructs so lowering sees the real
        # data layout (otherwise XLA replicates the batch => 256x the work)
        return jax.tree.map(
            lambda s, sh: jax.ShapeDtypeStruct(s.shape, s.dtype, sharding=sh),
            abs_tree, sh_tree,
        )

    t0 = time.perf_counter()
    with use_mesh(mesh):
        if kind == "train":
            jitted, state_sh, batch_sh_fn = make_train_step(
                model, rules, get_optimizer(cfg.optimizer, 1e-4)
            )
            specs = model.input_specs(shape_name)
            specs = _with_sh(specs, batch_sh_fn(specs))
            params_shape = jax.eval_shape(lambda: model.init(jax.random.key(0)))
            opt_shape = jax.eval_shape(
                lambda: get_optimizer(cfg.optimizer, 1e-4).init(params_shape)
            )
            state_abs = {
                "params": params_shape,
                "opt": opt_shape,
                "step": jax.ShapeDtypeStruct((), np.int32),
            }
            lowered = jitted.lower(state_abs, specs)
            n_flops = hlo.model_flops_train(
                cfg.active_params_per_token(), _tokens_of(cfg, shape_name)
            )
        elif kind == "prefill":
            jitted, p_sh = make_prefill_step(model, rules, info["seq_len"])
            specs = model.input_specs(shape_name)
            specs = _with_sh(
                specs,
                jax.tree.map(
                    lambda l: rules.batch_sharding_for(tuple(l.shape)), specs
                ),
            )
            params_shape = jax.eval_shape(lambda: model.init(jax.random.key(0)))
            lowered = jitted.lower(params_shape, specs)
            n_flops = hlo.model_flops_forward(
                cfg.active_params_per_token(), _tokens_of(cfg, shape_name)
            )
        else:  # decode
            jitted, p_sh, cache_sh_fn, tok_sh = make_decode_step(model, rules)
            specs = model.input_specs(shape_name)
            cache_abs = _with_sh(specs["cache"], cache_sh_fn(specs["cache"]))
            tok_abs = _with_sh(specs["tokens"], tok_sh(specs["tokens"]))
            params_shape = jax.eval_shape(lambda: model.init(jax.random.key(0)))
            lowered = jitted.lower(params_shape, cache_abs, tok_abs)
            n_flops = hlo.model_flops_forward(
                cfg.active_params_per_token(), _tokens_of(cfg, shape_name)
            )

        compiled = lowered.compile()

    mem = hlo.memory_summary(compiled)
    text = compiled.as_text()
    # loop-aware HLO cost: trip-count-multiplied dots/collectives/bytes
    # (cost_analysis() counts while bodies once — useless for scanned layers)
    from repro.runtime.hlo_counter import loop_aware_cost

    cost = loop_aware_cost(text)
    roof = hlo.Roofline(
        flops=cost.flops * chips,
        hbm_bytes=cost.hbm_bytes * chips,
        collective_bytes=cost.collective_bytes * chips,
        chips=chips,
        model_flops=n_flops,
    ).finalize()
    raw = hlo.cost_of(compiled)
    rec.update(
        status="ok",
        compile_s=round(time.perf_counter() - t0, 1),
        chips=chips,
        n_params=cfg.n_params(),
        active_params=cfg.active_params_per_token(),
        tokens=_tokens_of(cfg, shape_name),
        memory=mem,
        per_device_bytes=mem.get("total_bytes"),
        fits_hbm=(mem.get("total_bytes", 0) <= HBM_PER_CHIP) if mem else None,
        roofline=roof.as_dict(),
        collectives={k: v * chips for k, v in cost.coll_by_kind.items()},
        collective_counts=cost.coll_counts,
        unknown_trip_loops=cost.unknown_trip_loops,
        raw_cost_analysis={
            "flops": raw.get("flops"), "bytes_accessed": raw.get("bytes accessed")
        },
    )
    return rec


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--arch", choices=list_archs())
    ap.add_argument("--shape", choices=list(SHAPES))
    ap.add_argument("--mesh", choices=["single", "multi", "both"], default="single")
    ap.add_argument("--all", action="store_true", help="run every cell")
    ap.add_argument("--no-fsdp", action="store_true")
    ap.add_argument("--out", help="append JSONL records here")
    ap.add_argument("--override", action="append", default=[],
                    help="cfg override k=v (e.g. microbatches=4)")
    args = ap.parse_args(argv)

    overrides = {}
    for kv in args.override:
        k, v = kv.split("=", 1)
        try:
            v = json.loads(v)
        except json.JSONDecodeError:
            pass
        overrides[k] = v

    cells = []
    meshes = ["single", "multi"] if args.mesh == "both" else [args.mesh]
    if args.all:
        for a in list_archs():
            for s in SHAPES:
                for mk in meshes:
                    cells.append((a, s, mk))
    else:
        if not (args.arch and args.shape):
            ap.error("--arch and --shape required unless --all")
        cells = [(args.arch, args.shape, mk) for mk in meshes]

    failures = 0
    for arch, shape, mk in cells:
        try:
            rec = run_cell(arch, shape, mk, fsdp=not args.no_fsdp,
                           overrides=overrides or None)
        except Exception as e:
            rec = {
                "arch": arch, "shape": shape, "mesh": mk, "status": "fail",
                "detail": f"{type(e).__name__}: {e}",
                "trace": traceback.format_exc()[-2000:],
            }
            failures += 1
        line = json.dumps(rec)
        print(line, flush=True)
        if args.out:
            with open(args.out, "a") as f:
                f.write(line + "\n")
    return 1 if failures else 0


if __name__ == "__main__":
    sys.exit(main())
