"""Production mesh construction.

Defined as functions (never module-level constants) so importing this
module never touches jax device state — required for the dry-run's
XLA_FLAGS ordering and for tests that run on 1 CPU device.

``AxisType`` / the ``axis_types=`` kwarg only exist in newer jax releases;
the helpers below degrade gracefully so the same code runs on any jax
that has ``jax.make_mesh``.
"""
from __future__ import annotations

import jax

try:
    from jax.sharding import AxisType
except ImportError:  # older jax: axes are implicitly Auto
    AxisType = None


def make_mesh(shape: tuple[int, ...], axes: tuple[str, ...]):
    """jax.make_mesh with Auto axis types where the API supports them."""
    if AxisType is not None:
        return jax.make_mesh(shape, axes, axis_types=(AxisType.Auto,) * len(axes))
    return jax.make_mesh(shape, axes)


def make_abstract_mesh(shape: tuple[int, ...], axes: tuple[str, ...]):
    """AbstractMesh: spec logic needs only shape+names, not real devices."""
    if AxisType is not None:
        return jax.sharding.AbstractMesh(
            shape, axes, axis_types=(AxisType.Auto,) * len(axes)
        )
    return jax.sharding.AbstractMesh(tuple(zip(axes, shape)))


def use_mesh(mesh):
    """Context manager entering ``mesh``: ``jax.sharding.set_mesh`` on new
    jax, the classic ``with mesh:`` global-mesh context on older releases."""
    if hasattr(jax.sharding, "set_mesh"):
        return jax.sharding.set_mesh(mesh)
    return mesh  # Mesh is itself a context manager


def make_production_mesh(*, multi_pod: bool = False):
    """Single pod: (data=16, model=16) = 256 chips.
    Multi-pod:  (pod=2, data=16, model=16) = 512 chips; the pod axis is
    data-parallel over DCN."""
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return make_mesh(shape, axes)


def make_host_mesh(shape: tuple[int, ...] = (1,), axes: tuple[str, ...] = ("data",)):
    """Small mesh over whatever devices exist (tests / smoke runs)."""
    return make_mesh(shape, axes)
