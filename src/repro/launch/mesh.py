"""Production mesh construction.

Defined as functions (never module-level constants) so importing this
module never touches jax device state — required for the dry-run's
XLA_FLAGS ordering and for tests that run on 1 CPU device.
"""
from __future__ import annotations

import jax
from jax.sharding import AxisType


def make_production_mesh(*, multi_pod: bool = False):
    """Single pod: (data=16, model=16) = 256 chips.
    Multi-pod:  (pod=2, data=16, model=16) = 512 chips; the pod axis is
    data-parallel over DCN."""
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes, axis_types=(AxisType.Auto,) * len(axes))


def make_host_mesh(shape: tuple[int, ...] = (1,), axes: tuple[str, ...] = ("data",)):
    """Small mesh over whatever devices exist (tests / smoke runs)."""
    return jax.make_mesh(shape, axes, axis_types=(AxisType.Auto,) * len(axes))
