"""Serving driver: prefill + batched decode with CRUM lazy restore.

Demonstrates the paper's read-fault economics on the restore path: with
``--lazy``, parameters materialize on first use with exponential
read-ahead, so time-to-first-token beats a full eager restore.

    PYTHONPATH=src python -m repro.launch.serve --arch gemma-2b --smoke \
        --ckpt-dir /tmp/ckpt --prompt-len 32 --gen 16

With ``--device-runner proxy`` decode executes in a device-proxy process
via the ``decode_arch`` step program — and with ``--proxy-endpoint`` that
proxy is a *remote* one, served by a ``repro.remote.host`` daemon over the
streamed chunk transport: the restored params ride the wire once (lazy
restore feeds the push leaf by leaf), then every SYNC moves only the
chunks decode dirtied (cache/toks), never the clean params.

    PYTHONPATH=src python -m repro.remote.host --port 7070   # machine B
    PYTHONPATH=src python -m repro.launch.serve --arch gemma-2b --smoke \
        --ckpt-dir /tmp/ckpt --lazy --device-runner proxy \
        --proxy-endpoint 127.0.0.1:7070                      # machine A
"""
from __future__ import annotations

import argparse
import sys
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_config, list_archs
from repro.core import RestoreManager
from repro.checkpoint import ChunkStore
from repro.launch.mesh import make_host_mesh, use_mesh
from repro.models import build
from repro.utils.tree import flatten_with_paths


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--arch", choices=list_archs(), required=True)
    ap.add_argument("--smoke", action="store_true")
    ap.add_argument("--ckpt-dir", default=None, help="restore params from here")
    ap.add_argument("--lazy", action="store_true", help="lazy restore w/ read-ahead")
    ap.add_argument("--batch", type=int, default=2)
    ap.add_argument("--prompt-len", type=int, default=32)
    ap.add_argument("--gen", type=int, default=16)
    ap.add_argument("--device-runner", choices=["inline", "proxy"],
                    default="inline",
                    help="proxy: decode in a device-proxy process "
                         "(decode_arch step program)")
    ap.add_argument("--proxy-endpoint", default=None, metavar="HOST:PORT",
                    help="connect to a remote proxy-host daemon instead of "
                         "spawning a local proxy (implies the streamed "
                         "transport)")
    ap.add_argument("--transport", choices=["segment", "stream"], default=None,
                    help="proxy data plane (default: stream when "
                         "--proxy-endpoint is given, else segment)")
    args = ap.parse_args(argv)

    if args.device_runner == "proxy":
        return _serve_proxy(args)

    cfg = get_config(args.arch, smoke=args.smoke)
    model = build(cfg)
    mesh = make_host_mesh((jax.device_count(),), ("data",))

    with use_mesh(mesh):
        t0 = time.perf_counter()
        if args.ckpt_dir:
            rm = RestoreManager(ChunkStore(args.ckpt_dir))
            if args.lazy:
                lazy, manifest = rm.restore(lazy=True)
                # materialize exactly the params subtree, leaf by leaf
                flat = {
                    p[len("device/params/"):]: lazy[p]
                    for p in lazy.keys()
                    if p.startswith("device/params/")
                }
                params_shape = jax.eval_shape(lambda: model.init(jax.random.key(0)))
                flat_shape, treedef = flatten_with_paths(params_shape)
                from repro.utils.tree import unflatten_from_paths

                params = unflatten_from_paths(
                    treedef, {k: jnp.asarray(v) for k, v in flat.items()}
                )
                lazy.close()
            else:
                state, manifest = rm.restore()
                params = jax.tree.map(jnp.asarray, state["device"]["params"])
            print(f"[serve] restored step {manifest.step} in "
                  f"{time.perf_counter()-t0:.3f}s (lazy={args.lazy})")
        else:
            params = model.init(jax.random.key(0))
            print(f"[serve] fresh init in {time.perf_counter()-t0:.3f}s")

        B, P, G = args.batch, args.prompt_len, args.gen
        cache_len = P + G
        rng = np.random.default_rng(0)
        if cfg.frontend == "audio":
            prompt = jnp.asarray(
                rng.integers(0, cfg.vocab_size, (B, P, cfg.audio_codebooks)), jnp.int32
            )
            batch = {"inputs": prompt}
        elif cfg.frontend == "vision":
            batch = {
                "patches": jnp.asarray(
                    rng.standard_normal((B, cfg.num_patches, cfg.d_model)),
                    jnp.bfloat16,
                ),
                "inputs": jnp.asarray(
                    rng.integers(0, cfg.vocab_size, (B, P)), jnp.int32
                ),
            }
        else:
            batch = {
                "inputs": jnp.asarray(
                    rng.integers(0, cfg.vocab_size, (B, P)), jnp.int32
                )
            }

        t1 = time.perf_counter()
        if model.prefill is not None:
            logits, cache = model.prefill(params, batch, cache_len)
        else:
            # SSM/hybrid: prefill by decoding the prompt token-by-token
            cache = model.init_cache(B, cache_len)
            for t in range(P):
                tok = batch["inputs"][:, t]
                logits, cache = model.decode(params, cache, tok)
        jax.block_until_ready(logits)
        ttft = time.perf_counter() - t1
        print(f"[serve] prefill({P} tokens) -> first logits in {ttft:.3f}s")

        def sample(lg):
            return jnp.argmax(lg, axis=-1).astype(jnp.int32)

        toks = sample(logits if logits.ndim == 2 else logits[:, -1])
        t2 = time.perf_counter()
        out = [toks]
        for _ in range(G - 1):
            logits, cache = model.decode(params, cache, toks)
            toks = sample(logits)
            out.append(toks)
        jax.block_until_ready(toks)
        dt = time.perf_counter() - t2
        print(f"[serve] generated {G-1} steps in {dt:.3f}s "
              f"({(G-1)*B/max(dt,1e-9):.1f} tok/s)")
        first = np.asarray(out[0]).reshape(B, -1)[:, 0]
        print(f"[serve] sample tokens: {first.tolist()}")
    return 0


def _restored_params(args):
    """Restore the params subtree (eagerly, or leaf-by-lazy-leaf)."""
    rm = RestoreManager(ChunkStore(args.ckpt_dir))
    t0 = time.perf_counter()
    if args.lazy:
        lazy, manifest = rm.restore(lazy=True)
        flat = {
            p[len("device/params/"):]: np.asarray(lazy[p])
            for p in lazy.keys()
            if p.startswith("device/params/")
        }
        lazy.close()
    else:
        state, manifest = rm.restore()
        flat, _ = flatten_with_paths(state["device"]["params"])
        flat = {p: np.asarray(v) for p, v in flat.items()}
    print(f"[serve] restored step {manifest.step} in "
          f"{time.perf_counter()-t0:.3f}s (lazy={args.lazy})")
    return flat


def _serve_proxy(args) -> int:
    """Decode through a (possibly remote) device proxy."""
    from repro.proxy import ProxyRunner, make_program
    from repro.remote.transport import endpoint_arg
    from repro.utils.tree import unflatten_from_paths

    spec = {
        "name": "decode_arch", "arch": args.arch, "smoke": bool(args.smoke),
        "batch": args.batch, "prompt_len": args.prompt_len, "gen": args.gen,
    }
    provider = None
    if args.proxy_endpoint:
        ep = endpoint_arg(args.proxy_endpoint)
        provider = lambda failed=False: ep  # noqa: E731 — static placement
    transport = args.transport or ("stream" if args.proxy_endpoint else "segment")
    prog = make_program(spec)
    init = prog.init_state()
    if args.ckpt_dir:
        flat_params = _restored_params(args)
        have, treedef = flatten_with_paths(init["params"])
        missing = set(have) - set(flat_params)
        if missing:
            raise SystemExit(
                f"checkpoint lacks params for {sorted(missing)[:3]}..."
            )
        init["params"] = unflatten_from_paths(
            treedef, {p: flat_params[p] for p in have}
        )

    runner = ProxyRunner(
        spec, transport=transport, endpoint_provider=provider,
        chunk_bytes=1 << 20,
    )
    t0 = time.perf_counter()
    runner.start(device_state=init)
    push_s = time.perf_counter() - t0
    where = args.proxy_endpoint or "local"
    print(f"[serve] proxy={where} transport={transport} "
          f"state pushed in {push_s:.3f}s", flush=True)
    try:
        total = args.prompt_len + args.gen
        t1 = time.perf_counter()
        for n in range(1, total):
            runner.step(n)
        state, info = runner.sync_state()
        dt = time.perf_counter() - t1
        toks = np.asarray(state["toks"])[:, args.prompt_len:]
        print(f"[serve] decoded {total - 1} steps in {dt:.3f}s "
              f"({(total - 1) * args.batch / max(dt, 1e-9):.1f} tok/s, "
              f"restarts={runner.restarts})")
        tstats = info.get("transport", {})
        print(f"[serve] sync wire: chunks={info.get('chunks_synced')} "
              f"bytes={info.get('bytes_synced')} "
              f"wire_rx={tstats.get('wire_rx')} (params stay clean)")
        print(f"[serve] sample tokens: {toks[:, 0].tolist()}")
    finally:
        runner.close()
    return 0


if __name__ == "__main__":
    sys.exit(main())
