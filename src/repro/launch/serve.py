"""Serving driver: prefill + batched decode with CRUM lazy restore.

Demonstrates the paper's read-fault economics on the restore path: with
``--lazy``, parameters materialize on first use with exponential
read-ahead, so time-to-first-token beats a full eager restore.

    PYTHONPATH=src python -m repro.launch.serve --arch gemma-2b --smoke \
        --ckpt-dir /tmp/ckpt --prompt-len 32 --gen 16
"""
from __future__ import annotations

import argparse
import sys
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_config, list_archs
from repro.core import RestoreManager
from repro.checkpoint import ChunkStore
from repro.launch.mesh import make_host_mesh, use_mesh
from repro.models import build
from repro.utils.tree import flatten_with_paths


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--arch", choices=list_archs(), required=True)
    ap.add_argument("--smoke", action="store_true")
    ap.add_argument("--ckpt-dir", default=None, help="restore params from here")
    ap.add_argument("--lazy", action="store_true", help="lazy restore w/ read-ahead")
    ap.add_argument("--batch", type=int, default=2)
    ap.add_argument("--prompt-len", type=int, default=32)
    ap.add_argument("--gen", type=int, default=16)
    args = ap.parse_args(argv)

    cfg = get_config(args.arch, smoke=args.smoke)
    model = build(cfg)
    mesh = make_host_mesh((jax.device_count(),), ("data",))

    with use_mesh(mesh):
        t0 = time.perf_counter()
        if args.ckpt_dir:
            rm = RestoreManager(ChunkStore(args.ckpt_dir))
            if args.lazy:
                lazy, manifest = rm.restore(lazy=True)
                # materialize exactly the params subtree, leaf by leaf
                flat = {
                    p[len("device/params/"):]: lazy[p]
                    for p in lazy.keys()
                    if p.startswith("device/params/")
                }
                params_shape = jax.eval_shape(lambda: model.init(jax.random.key(0)))
                flat_shape, treedef = flatten_with_paths(params_shape)
                from repro.utils.tree import unflatten_from_paths

                params = unflatten_from_paths(
                    treedef, {k: jnp.asarray(v) for k, v in flat.items()}
                )
                lazy.close()
            else:
                state, manifest = rm.restore()
                params = jax.tree.map(jnp.asarray, state["device"]["params"])
            print(f"[serve] restored step {manifest.step} in "
                  f"{time.perf_counter()-t0:.3f}s (lazy={args.lazy})")
        else:
            params = model.init(jax.random.key(0))
            print(f"[serve] fresh init in {time.perf_counter()-t0:.3f}s")

        B, P, G = args.batch, args.prompt_len, args.gen
        cache_len = P + G
        rng = np.random.default_rng(0)
        if cfg.frontend == "audio":
            prompt = jnp.asarray(
                rng.integers(0, cfg.vocab_size, (B, P, cfg.audio_codebooks)), jnp.int32
            )
            batch = {"inputs": prompt}
        elif cfg.frontend == "vision":
            batch = {
                "patches": jnp.asarray(
                    rng.standard_normal((B, cfg.num_patches, cfg.d_model)),
                    jnp.bfloat16,
                ),
                "inputs": jnp.asarray(
                    rng.integers(0, cfg.vocab_size, (B, P)), jnp.int32
                ),
            }
        else:
            batch = {
                "inputs": jnp.asarray(
                    rng.integers(0, cfg.vocab_size, (B, P)), jnp.int32
                )
            }

        t1 = time.perf_counter()
        if model.prefill is not None:
            logits, cache = model.prefill(params, batch, cache_len)
        else:
            # SSM/hybrid: prefill by decoding the prompt token-by-token
            cache = model.init_cache(B, cache_len)
            for t in range(P):
                tok = batch["inputs"][:, t]
                logits, cache = model.decode(params, cache, tok)
        jax.block_until_ready(logits)
        ttft = time.perf_counter() - t1
        print(f"[serve] prefill({P} tokens) -> first logits in {ttft:.3f}s")

        def sample(lg):
            return jnp.argmax(lg, axis=-1).astype(jnp.int32)

        toks = sample(logits if logits.ndim == 2 else logits[:, -1])
        t2 = time.perf_counter()
        out = [toks]
        for _ in range(G - 1):
            logits, cache = model.decode(params, cache, toks)
            toks = sample(logits)
            out.append(toks)
        jax.block_until_ready(toks)
        dt = time.perf_counter() - t2
        print(f"[serve] generated {G-1} steps in {dt:.3f}s "
              f"({(G-1)*B/max(dt,1e-9):.1f} tok/s)")
        first = np.asarray(out[0]).reshape(B, -1)[:, 0]
        print(f"[serve] sample tokens: {first.tolist()}")
    return 0


if __name__ == "__main__":
    sys.exit(main())
