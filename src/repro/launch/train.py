"""End-to-end training driver with CRUM fault tolerance.

Runs on anything from 1 CPU device (--smoke) to the production mesh; the
CheckpointedTrainer provides forked checkpointing, incremental persistence
and restart (examples/train_restart.py kills and resumes this loop).

    PYTHONPATH=src python -m repro.launch.train --arch qwen2-0.5b --smoke \
        --steps 20 --batch 8 --seq 128 --ckpt-dir /tmp/ckpt
"""
from __future__ import annotations

import argparse
import json
import sys
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.checkpoint import DEFAULT_CODEC
from repro.configs import get_config, list_archs
from repro.core import (
    CheckpointedTrainer,
    CheckpointPolicy,
    PreemptionHandler,
    list_persist_backends,
)
from repro.data import SyntheticBatches
from repro.launch.mesh import make_host_mesh, make_production_mesh, use_mesh
from repro.models import build
from repro.obs import trace as obs_trace
from repro.optim import get_optimizer, warmup_cosine
from repro.runtime.sharding import ShardingRules
from repro.runtime.steps import make_train_step
from repro.utils.tree import flatten_with_paths, unflatten_from_paths


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--arch", choices=list_archs(), required=True)
    ap.add_argument("--smoke", action="store_true", help="reduced config (CPU)")
    ap.add_argument("--steps", type=int, default=100)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--lr", type=float, default=3e-4)
    ap.add_argument("--ckpt-dir", default="/tmp/repro-ckpt")
    ap.add_argument("--ckpt-every", type=int, default=20)
    ap.add_argument("--codec", default=DEFAULT_CODEC)
    ap.add_argument(
        "--backend", choices=list_persist_backends(), default="thread",
        help="persist backend: 'fork' = paper's COW child, 'thread' = pool",
    )
    ap.add_argument(
        "--device-runner", choices=["inline", "proxy"], default="inline",
        help="inline: step fn runs in-process; proxy: the paper's "
             "architecture — compute in a restartable proxy process with "
             "API log-and-replay recovery",
    )
    ap.add_argument(
        "--device-capacity", default=None, metavar="BYTES|PCT%",
        help="managed-memory (UVM) mode: hard device budget for the model "
             "state, either absolute bytes or a percentage of the state "
             "size (e.g. '50%%' = oversubscription ratio 2x). Pages "
             "migrate on fault; the checkpointer syncs page deltas",
    )
    ap.add_argument("--page-bytes", type=int, default=None,
                    help="managed-memory page size (default 64 KiB)")
    ap.add_argument("--eviction-policy", choices=["lru", "clock"],
                    default="lru", help="managed-memory eviction policy")
    ap.add_argument("--promote-threshold", type=int, default=0,
                    help="Volta-style access-counter promotion: a HOST page "
                         "read this many times within --promote-window is "
                         "migrated to device; colder reads are served "
                         "remotely without a migration (0/1 = migrate on "
                         "first touch)")
    ap.add_argument("--promote-window", type=int, default=0,
                    help="promotion counting window in ticks (0 = unbounded)")
    ap.add_argument("--no-incremental", action="store_true")
    ap.add_argument("--production-mesh", action="store_true")
    ap.add_argument("--log-every", type=int, default=10)
    ap.add_argument("--obs-dir", default=None, metavar="DIR",
                    help="enable observability: trace shards and metrics "
                         "snapshots land here (the proxy process inherits "
                         "the setting; merge with "
                         "`python -m repro.obs.report DIR`)")
    args = ap.parse_args(argv)

    if args.obs_dir:
        obs_trace.enable(args.obs_dir, "app")

    if args.device_runner == "proxy":
        return _main_proxy(args)

    cfg = get_config(args.arch, smoke=args.smoke)
    model = build(cfg)
    mesh = (
        make_production_mesh()
        if args.production_mesh
        else make_host_mesh((jax.device_count(),), ("data",))
    )
    rules = ShardingRules(cfg=cfg, mesh=mesh)
    optimizer = get_optimizer(
        cfg.optimizer, warmup_cosine(args.lr, 10, args.steps)
    )

    trainer = CheckpointedTrainer(
        None,  # set below (needs the mesh context)
        store_root=args.ckpt_dir,
        policy=CheckpointPolicy(interval_steps=args.ckpt_every, keep_last=2),
        codec=args.codec,
        incremental=not args.no_incremental,
        chunk_bytes=1 << 20,
        backend=args.backend,
        page_bytes=args.page_bytes,
        eviction_policy=args.eviction_policy,
        promote_threshold=args.promote_threshold,
        promote_window=args.promote_window,
    )
    preempt = PreemptionHandler(trainer.policy).install()

    with use_mesh(mesh):
        step_fn, state_shardings, batch_sh = make_train_step(
            model, rules, optimizer, donate=False
        )
        trainer.train_step = step_fn

        def init_state():
            params = model.init(jax.random.key(0))
            return {
                "device": {
                    "params": params,
                    "opt": optimizer.init(params),
                    "step": jnp.zeros((), jnp.int32),
                },
                "host": {
                    "step": np.int64(0),
                    "data": SyntheticBatches(
                        cfg, batch=args.batch, seq_len=args.seq
                    ).state(),
                },
            }

        def sharding_for(path, shape):
            flat_sh, _ = flatten_with_paths(
                {"device": state_shardings, "host": None}
            )
            return flat_sh.get(path)

        state, start = trainer.resume_or(init_state, sharding_for=sharding_for)
        data = SyntheticBatches.from_state(
            cfg, batch=args.batch, seq_len=args.seq, state=state["host"]["data"]
        )
        print(f"[train] arch={cfg.name} start_step={start} mesh={dict(mesh.shape)}")

        if args.device_capacity is not None:
            return _run_managed(args, trainer, state, start, data, preempt)

        tr = obs_trace.get()
        step = start
        for _ in range(args.steps - start):
            t0 = time.perf_counter() if tr is not None else 0.0
            batch = jax.tree.map(jnp.asarray, next(data))
            state["device"], metrics = step_fn(state["device"], batch)
            step += 1
            if tr is not None:
                tr.complete("app.step", t0, step=step)
            state["host"]["step"] = np.int64(step)
            state["host"]["data"] = data.state()
            if step % args.log_every == 0 or step == args.steps:
                print(
                    f"[train] step={step} loss={float(metrics['loss']):.4f} "
                    f"grad_norm={float(metrics['grad_norm']):.3f}",
                    flush=True,
                )
            if trainer.policy.should_checkpoint(step):
                r = trainer.checkpoint_now(step, state)
                print(
                    f"[ckpt] step={step} blocking={r.blocking_s*1e3:.1f}ms "
                    f"(persist continues in background)",
                    flush=True,
                )
            if preempt.received.is_set():
                print("[train] preemption: checkpointing and exiting")
                if _needs_preempt_ckpt(trainer, step):
                    trainer.checkpoint_now(step, state)
                break

        done = trainer.finish()
        for r in done:
            print(
                f"[ckpt-done] step={r.step} blocking={r.blocking_s*1e3:.1f}ms "
                f"persist={r.persist_s*1e3:.1f}ms written={r.chunks_written} "
                f"reused={r.chunks_reused}"
            )
    preempt.uninstall()
    print(json.dumps({"final_step": step, "timings": trainer.timings.summary()}, indent=2))
    return 0


def _tree_nbytes(tree) -> int:
    flat, _ = flatten_with_paths(tree)
    return sum(int(np.asarray(l).nbytes) for l in flat.values())


def _needs_preempt_ckpt(trainer, step: int) -> bool:
    """SIGTERM sets the policy's preempt flag too, so the train loop may
    already have checkpointed this very step before exiting — saving it
    again would run two concurrent persists of the same step directory."""
    return not trainer.results or trainer.results[-1].step != step


def _resolve_capacity(spec: str, state_nbytes: int) -> int:
    """'BYTES' or 'PCT%' (of the device state size) -> absolute bytes."""
    s = str(spec).strip()
    if s.endswith("%"):
        return max(1, int(state_nbytes * float(s[:-1]) / 100.0))
    return int(s)


def _run_managed(args, trainer, state, start, data, preempt) -> int:
    """Inline training through a ManagedSpace (the UVM oversubscription
    path): the device budget is hard, pages migrate on fault, and the
    checkpointer syncs page deltas instead of digest-scanning every leaf."""
    state_nbytes = _tree_nbytes(state["device"])
    cap = _resolve_capacity(args.device_capacity, state_nbytes)
    trainer.device_capacity_bytes = cap
    print(f"[uvm] device_capacity={cap}B state={state_nbytes}B "
          f"oversubscription=x{state_nbytes / cap:.2f} "
          f"policy={args.eviction_policy}", flush=True)

    def batches():
        while True:
            yield jax.tree.map(jnp.asarray, next(data))

    def on_metrics(step, metrics):
        state["host"]["data"] = data.state()
        if step % args.log_every == 0 or step == args.steps:
            print(f"[train] step={step} loss={float(metrics['loss']):.4f}",
                  flush=True)

    state = trainer.run(
        state, batches(), num_steps=args.steps - start, start_step=start,
        on_metrics=on_metrics, stop=preempt.received.is_set,
    )
    step = int(np.asarray(state["host"]["step"]))
    if preempt.received.is_set() and _needs_preempt_ckpt(trainer, step):
        print("[train] preemption: checkpointing and exiting", flush=True)
        trainer.checkpoint_now(step, trainer.materialize(state))
    done = trainer.finish()
    for r in done:
        print(
            f"[ckpt-done] step={r.step} blocking={r.blocking_s*1e3:.1f}ms "
            f"synced={r.chunks_synced} clean={r.chunks_clean} "
            f"written={r.chunks_written} reused={r.chunks_reused}"
        )
    preempt.uninstall()
    print(json.dumps({
        "final_step": step,
        "paging": trainer.paging_stats(),
        "timings": trainer.timings.summary(),
    }, indent=2))
    return 0


def _main_proxy(args) -> int:
    """The paper's architecture: this process never runs the step function.

    A ``train_arch`` step program (rebuilt from the CLI config inside the
    proxy — programs are replayable specs, not closures) executes in a
    supervised proxy process; this process forwards pipelined STEP calls,
    syncs the host mirror at checkpoint boundaries, and persists it with
    the same forked checkpointer. Batches are deterministic in the step
    number, which is what makes kill-replay recovery bit-identical.
    """
    program = {
        "name": "train_arch",
        "arch": args.arch,
        "smoke": bool(args.smoke),
        "batch": args.batch,
        "seq": args.seq,
        "lr": args.lr,
        "total_steps": args.steps,
    }
    capacity = None
    if args.device_capacity is not None:
        spec = str(args.device_capacity).strip()
        if spec.endswith("%"):
            # percentage of the program's device state, sized abstractly
            # (eval_shape): the app must never materialize the state it is
            # keeping out of its own process
            from repro.proxy.programs import make_program

            nbytes = make_program(program).state_nbytes()
            capacity = _resolve_capacity(spec, nbytes)
            print(f"[uvm] proxy device_capacity={capacity}B "
                  f"state={nbytes}B", flush=True)
        else:
            capacity = int(spec)
    trainer = CheckpointedTrainer(
        None,
        store_root=args.ckpt_dir,
        policy=CheckpointPolicy(interval_steps=args.ckpt_every, keep_last=2),
        codec=args.codec,
        incremental=not args.no_incremental,
        chunk_bytes=1 << 20,
        backend=args.backend,
        device_runner="proxy",
        program=program,
        device_capacity_bytes=capacity,
        page_bytes=args.page_bytes,
        eviction_policy=args.eviction_policy,
        promote_threshold=args.promote_threshold,
        promote_window=args.promote_window,
    )
    preempt = PreemptionHandler(trainer.policy).install()

    def init_state():
        # device side is None: resume_or lets the runner ask the program
        # for a deterministic init inside this process (shared registry)
        return {"device": None, "host": {"step": np.int64(0)}}

    state, start = trainer.resume_or(init_state)
    print(f"[train] arch={args.arch} device_runner=proxy start_step={start} "
          f"proxy_pid={trainer.runner.proxy.pid}", flush=True)

    def on_metrics(step, metrics):
        loss = metrics.get("loss")
        loss_s = f"{loss:.4f}" if loss is not None else "n/a"
        print(f"[train] step={step} loss={loss_s} "
              f"proxy_restarts={trainer.runner.restarts}", flush=True)

    state = trainer.run(
        state, num_steps=args.steps - start, start_step=start,
        on_metrics=on_metrics, stop=preempt.received.is_set,
    )
    step = int(np.asarray(state["host"]["step"]))
    if preempt.received.is_set() and _needs_preempt_ckpt(trainer, step):
        print("[train] preemption: checkpointing and exiting", flush=True)
        trainer.checkpoint_now(step, state)
    done = trainer.finish()
    for r in done:
        print(
            f"[ckpt-done] step={r.step} blocking={r.blocking_s*1e3:.1f}ms "
            f"persist={r.persist_s*1e3:.1f}ms written={r.chunks_written} "
            f"reused={r.chunks_reused}"
        )
    preempt.uninstall()
    print(json.dumps({"final_step": step, "timings": trainer.timings.summary()},
                     indent=2))
    return 0


if __name__ == "__main__":
    sys.exit(main())
