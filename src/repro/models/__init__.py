from repro.models.config import ModelConfig, reduced_for_smoke
from repro.models.zoo import Model, SHAPES, build, shape_applicable, softmax_xent

__all__ = ["ModelConfig", "reduced_for_smoke", "Model", "SHAPES", "build", "shape_applicable", "softmax_xent"]
