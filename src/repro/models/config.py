"""Architecture configuration.

One frozen dataclass describes every supported architecture family
(dense / moe / ssm / hybrid, with optional multimodal stub frontends).
The 10 assigned architectures instantiate this in ``repro/configs/``.
"""
from __future__ import annotations

from dataclasses import dataclass, field, replace


@dataclass(frozen=True)
class ModelConfig:
    name: str
    family: str                       # dense | moe | ssm | hybrid
    num_layers: int
    d_model: int
    vocab_size: int
    # attention (0 heads => attention-free)
    num_heads: int = 0
    num_kv_heads: int = 0
    head_dim: int = 0
    d_ff: int = 0
    mlp_type: str = "swiglu"          # swiglu | geglu | gelu
    qkv_bias: bool = False
    parallel_block: bool = False      # cohere-style parallel attn+ffn
    tie_embeddings: bool = True
    embed_scale: bool = False         # gemma-style sqrt(d_model) embed scaling
    rope_theta: float = 10000.0
    norm_eps: float = 1e-6
    # MoE
    moe_experts: int = 0
    moe_top_k: int = 0
    moe_dense_ff: int = 0             # arctic: dense residual FFN width
    moe_capacity_factor: float = 1.25
    router_z_weight: float = 1e-3
    # dispatch groups: tokens are routed within groups aligned to the data
    # axis so the dispatch scatter stays shard-local (GSPMD-friendly MoE);
    # the effective group count is gcd(moe_groups, tokens)
    moe_groups: int = 16
    # SSM (mamba2 / SSD)
    ssm_state: int = 0
    ssm_conv: int = 4
    ssm_expand: int = 2
    ssm_head_dim: int = 64
    ssm_chunk: int = 256
    # hybrid (zamba2): shared attention block cadence
    attn_every: int = 0
    # multimodal stub frontends
    frontend: str = "none"            # none | vision | audio
    num_patches: int = 0              # vision: precomputed patch embeddings
    audio_codebooks: int = 0
    # parallelism role of the mesh "model" axis for this arch:
    #   True  -> tensor parallelism (heads/ffn/experts sharded over "model")
    #   False -> "model" joins the batch axes (pure DP+FSDP; right choice for
    #            small archs or head counts that don't divide the axis)
    tensor_parallel: bool = True
    # attention-over-model: when TP is on but head counts don't divide the
    # model axis (arctic: 56 heads vs 16), run attention batch-parallel over
    # "model" (two activation reshards per layer) instead of letting GSPMD
    # all-gather the global batch (observed 1.5e15 B/step on arctic)
    attn_over_model: bool = False
    # gradient-accumulation dtype for the microbatch loop (bfloat16 halves
    # the accumulator: 480B params = 7.5 GiB/device in f32 vs 3.75 in bf16)
    accum_dtype: str = "float32"
    # chunked cross-entropy: bound the live (tokens, vocab) logits buffer by
    # computing CE in sequence chunks of this many tokens (0 = disabled)
    ce_chunk_tokens: int = 1024
    # numerics
    param_dtype: str = "bfloat16"
    compute_dtype: str = "bfloat16"
    # training
    optimizer: str = "adamw"          # adamw | adafactor | q8adam
    remat: str = "full"               # none | dots | full
    microbatches: int = 1             # grad-accumulation splits of the batch
    # attention lowering for long sequences (pure-JAX flash-style blocks)
    attn_block_q: int = 512
    attn_block_k: int = 1024
    attn_chunked_threshold: int = 4096   # use blocked attention at/above this S
    # sub-quadratic? (controls long_500k applicability)
    subquadratic: bool = False

    # -- derived -------------------------------------------------------------
    @property
    def batch_axes(self) -> tuple[str, ...]:
        """Mesh axes the batch shards over (constrain() drops absent ones)."""
        return ("pod", "data") if self.tensor_parallel else ("pod", "data", "model")

    @property
    def q_dim(self) -> int:
        return self.num_heads * self.head_dim

    @property
    def kv_dim(self) -> int:
        return self.num_kv_heads * self.head_dim

    @property
    def ssm_d_inner(self) -> int:
        return self.ssm_expand * self.d_model

    @property
    def ssm_heads(self) -> int:
        return self.ssm_d_inner // self.ssm_head_dim if self.ssm_head_dim else 0

    def n_params(self) -> int:
        """Analytic parameter count (embeddings included once if tied)."""
        D, F, V, L = self.d_model, self.d_ff, self.vocab_size, self.num_layers
        n = 0
        if self.frontend == "audio" and self.audio_codebooks:
            n += self.audio_codebooks * V * D          # codebook embeds
            n += self.audio_codebooks * V * D          # per-codebook heads
        else:
            n += V * D
            if not self.tie_embeddings:
                n += V * D
        if self.frontend == "vision":
            n += self.d_model * self.d_model           # patch projection stub
        per_layer = 0
        if self.family in ("dense", "moe"):
            per_layer += D * self.q_dim + 2 * D * self.kv_dim + self.q_dim * D
            if self.qkv_bias:
                per_layer += self.q_dim + 2 * self.kv_dim
            per_layer += 2 * D                          # norms
            gate = 2 if self.mlp_type in ("swiglu", "geglu") else 1
            if self.family == "dense":
                per_layer += (gate + 1) * D * F
            else:
                if self.moe_dense_ff:
                    per_layer += (gate + 1) * D * self.moe_dense_ff
                per_layer += self.moe_experts * (gate + 1) * D * F
                per_layer += D * self.moe_experts       # router
        elif self.family == "ssm":
            per_layer += self._mamba_block_params()
        elif self.family == "hybrid":
            per_layer += self._mamba_block_params()
        n += L * per_layer
        if self.family == "hybrid" and self.attn_every:
            # one shared attention+mlp block
            n += D * self.q_dim + 2 * D * self.kv_dim + self.q_dim * D
            n += 3 * D * self.d_ff + 2 * D
        n += D                                          # final norm
        return n

    def _mamba_block_params(self) -> int:
        D, DI, N = self.d_model, self.ssm_d_inner, self.ssm_state
        H = self.ssm_heads
        n = D * (2 * DI + 2 * N * (DI // self.ssm_head_dim and 1 or 1))  # placeholder
        # in_proj: D -> (z, x, B, C, dt) = 2*DI + 2*N*n_groups(=1) + H
        n = D * (2 * DI + 2 * N + H)
        n += self.ssm_conv * (DI + 2 * N)               # depthwise conv over x,B,C
        n += H * 2                                      # A_log, D per head
        n += DI                                         # pre-out norm (gated rmsnorm)
        n += DI * D                                     # out_proj
        n += D                                          # block norm
        return n

    def active_params_per_token(self) -> int:
        """MoE: params touched per token (top-k experts); dense: n_params."""
        if self.family != "moe":
            return self.n_params()
        D, F, V, L = self.d_model, self.d_ff, self.vocab_size, self.num_layers
        gate = 2 if self.mlp_type in ("swiglu", "geglu") else 1
        n = V * D
        per_layer = D * self.q_dim + 2 * D * self.kv_dim + self.q_dim * D + 2 * D
        if self.moe_dense_ff:
            per_layer += (gate + 1) * D * self.moe_dense_ff
        per_layer += self.moe_top_k * (gate + 1) * D * F
        per_layer += D * self.moe_experts
        return n + L * per_layer

    def with_overrides(self, **kw) -> "ModelConfig":
        return replace(self, **kw)


def reduced_for_smoke(cfg: ModelConfig) -> ModelConfig:
    """A tiny same-family config for CPU smoke tests."""
    kw: dict = dict(
        num_layers=min(cfg.num_layers, 2),
        d_model=128,
        vocab_size=256,
        microbatches=1,
    )
    if cfg.num_heads:
        kw.update(num_heads=4, num_kv_heads=min(cfg.num_kv_heads, 4) or 1,
                  head_dim=32, d_ff=256)
    if cfg.moe_experts:
        kw.update(moe_experts=4, moe_top_k=min(cfg.moe_top_k, 2),
                  moe_capacity_factor=4.0)  # no token drops -> decode == forward
        if cfg.moe_dense_ff:
            kw.update(moe_dense_ff=128)
        kw.update(d_ff=128)
    if cfg.ssm_state:
        kw.update(ssm_state=16, ssm_head_dim=32, ssm_chunk=32)
    if cfg.attn_every:
        kw.update(attn_every=2)
    if cfg.num_patches:
        kw.update(num_patches=16)
    kw.update(attn_chunked_threshold=64, attn_block_q=32, attn_block_k=32)
    kw.update(param_dtype="float32", compute_dtype="float32")
    return cfg.with_overrides(**kw)
