"""Zamba2-style hybrid: Mamba2 backbone + one *shared* attention block.

The shared transformer block (single parameter set) is applied after every
``attn_every``-th mamba layer — expressed as a ``lax.cond`` inside the
layer scan, so the HLO holds exactly one mamba block + one attention block
regardless of depth, and arbitrary (L, attn_every) combinations work.

Decode carries: per-layer SSM states (stacked L) + a KV cache per shared-
block *application* (n_apps = L // attn_every), indexed by an application
counter that only advances inside the cond's true branch.

Deviation noted in DESIGN §6: Zamba2 concatenates the block input with the
original embeddings before the shared block and applies per-invocation
LoRA deltas; we apply the shared block to the residual stream directly.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from repro.models.config import ModelConfig
from repro.models.layers import (
    apply_rope,
    logits_from_embed,
    attention_init,
    decode_attention,
    dtype_of,
    embed_init,
    mlp_apply,
    mlp_init,
    multihead_attention,
    rmsnorm,
)
from repro.models.mamba2 import (
    init_ssm_state,
    mamba_init,
    ssd_forward,
    ssm_decode_step,
)
from repro.models.transformer import _qkv


def n_shared_apps(cfg: ModelConfig) -> int:
    if not cfg.attn_every:
        return 0
    return sum(1 for i in range(cfg.num_layers) if (i + 1) % cfg.attn_every == 0)


def init_params(cfg: ModelConfig, key: jax.Array) -> dict:
    """attn_every == 0 gives the pure-SSM LM (mamba2 family)."""
    dtype = dtype_of(cfg.param_dtype)
    k_embed, k_blocks, k_shared_a, k_shared_m = jax.random.split(key, 4)
    layer_keys = jax.random.split(k_blocks, cfg.num_layers)
    blocks = jax.vmap(
        lambda k: {
            "ln": jnp.zeros((cfg.d_model,), dtype),
            "ssm": mamba_init(k, cfg, dtype),
        }
    )(layer_keys)
    params = {
        "embed": embed_init(k_embed, cfg.vocab_size, cfg.d_model, dtype),
        "blocks": blocks,
        "final_norm": jnp.zeros((cfg.d_model,), dtype),
    }
    if cfg.attn_every:
        params["shared"] = {
            "ln1": jnp.zeros((cfg.d_model,), dtype),
            "attn": attention_init(k_shared_a, cfg, dtype),
            "ln2": jnp.zeros((cfg.d_model,), dtype),
            "mlp": mlp_init(k_shared_m, cfg.d_model, cfg.d_ff, cfg.mlp_type, dtype),
        }
    return params


def _shared_block(cfg: ModelConfig, shared: dict, x: jax.Array, positions):
    from repro.runtime.sharding import constrain

    h = rmsnorm(x, shared["ln1"], cfg.norm_eps)
    q, k, v = _qkv(cfg, shared["attn"], h)
    # pin head sharding: propagation through the reshape chose replication
    fm = "model" if cfg.tensor_parallel else None
    q = constrain(q, (cfg.batch_axes, fm, None, None))
    k = constrain(k, (cfg.batch_axes, fm, None, None))
    v = constrain(v, (cfg.batch_axes, fm, None, None))
    q = apply_rope(q, positions, cfg.rope_theta)
    k = apply_rope(k, positions, cfg.rope_theta)
    a = multihead_attention(
        q, k, v, causal=True,
        chunked_threshold=cfg.attn_chunked_threshold,
        block_q=cfg.attn_block_q, block_k=cfg.attn_block_k,
    )
    B, S = x.shape[0], x.shape[1]
    a = a.transpose(0, 2, 1, 3).reshape(B, S, cfg.q_dim) @ shared["attn"]["wo"]
    x = x + a
    h2 = rmsnorm(x, shared["ln2"], cfg.norm_eps)
    return x + mlp_apply(shared["mlp"], h2, cfg.mlp_type)


def hidden_forward(cfg: ModelConfig, params: dict, tokens: jax.Array):
    """tokens (B, S) -> (hidden (B, S, D), aux=0).

    Structured as a python loop over shared-block applications with a
    lax.scan over the mamba span in between (static bounds) — NOT a
    lax.cond inside one scan: GSPMD's sharding propagation into
    conditional branches replicated the shared attention over the model
    axis (16x redundant compute, §Perf zamba2 hillclimb), and cost
    attribution through conditionals is max-branch (inexact). HLO size is
    one mamba block + n_apps attention blocks.
    """
    from repro.runtime.sharding import constrain

    B, S = tokens.shape
    x = jnp.take(params["embed"], tokens, axis=0)
    positions = jnp.arange(S)
    shared = params.get("shared")
    apps = _app_layers(cfg) if shared is not None else []

    def mamba_span(x, lo, hi):
        span = jax.tree.map(lambda p: p[lo:hi], params["blocks"])

        def body(x, block):
            x = constrain(x, (cfg.batch_axes, None, None))
            h = rmsnorm(x, block["ln"], cfg.norm_eps)
            y, _ = ssd_forward(cfg, block["ssm"], h)
            return x + y, None

        if cfg.remat != "none":
            body = jax.checkpoint(body)
        x, _ = jax.lax.scan(body, x, span)
        return x

    shared_fn = lambda xx: _shared_block(cfg, shared, xx, positions)
    if cfg.remat != "none" and shared is not None:
        shared_fn = jax.checkpoint(shared_fn)

    prev = 0
    for a in apps:
        x = mamba_span(x, prev, a + 1)
        x = shared_fn(x)
        prev = a + 1
    if prev < cfg.num_layers:
        x = mamba_span(x, prev, cfg.num_layers)
    x = rmsnorm(x, params["final_norm"], cfg.norm_eps)
    return x, jnp.zeros((), jnp.float32)


def lm_forward(cfg: ModelConfig, params: dict, tokens: jax.Array):
    """tokens (B, S) -> (logits (B, S, V) f32, aux=0)."""
    h, aux = hidden_forward(cfg, params, tokens)
    return logits_from_embed(params["embed"], h), aux


# ---------------------------------------------------------------------------
# serving
# ---------------------------------------------------------------------------

def _app_layers(cfg: ModelConfig) -> list[int]:
    """Layer indices after which the shared block applies."""
    if not cfg.attn_every:
        return []
    return [i for i in range(cfg.num_layers) if (i + 1) % cfg.attn_every == 0]


def prefill(cfg: ModelConfig, params: dict, tokens: jax.Array, cache_len: int):
    """Run the prompt, building SSM states + shared-attn KV caches.

    Structured as a python loop over shared-block *applications* with a
    lax.scan over the mamba layers in between (static group bounds), so the
    per-application KV cache is produced only where the block actually runs.
    Returns (last-token logits (B, 1, V), cache).
    """
    B, S = tokens.shape
    x = jnp.take(params["embed"], tokens, axis=0)
    positions = jnp.arange(S)
    shared = params.get("shared")
    apps = _app_layers(cfg)
    dtype = x.dtype

    def mamba_span(x, lo, hi):
        span = jax.tree.map(lambda p: p[lo:hi], params["blocks"])

        def body(x, block):
            h = rmsnorm(x, block["ln"], cfg.norm_eps)
            y, st = ssd_forward(cfg, block["ssm"], h)
            return x + y, st

        return jax.lax.scan(body, x, span)

    k_caches, v_caches, ssm_states = [], [], []
    prev = 0
    for a in apps:
        x, st = mamba_span(x, prev, a + 1)
        ssm_states.append(st)
        h = rmsnorm(x, shared["ln1"], cfg.norm_eps)
        q, k, v = _qkv(cfg, shared["attn"], h)
        q = apply_rope(q, positions, cfg.rope_theta)
        k = apply_rope(k, positions, cfg.rope_theta)
        att = multihead_attention(
            q, k, v, causal=True,
            chunked_threshold=cfg.attn_chunked_threshold,
            block_q=cfg.attn_block_q, block_k=cfg.attn_block_k,
        )
        att = att.transpose(0, 2, 1, 3).reshape(B, S, cfg.q_dim) @ shared["attn"]["wo"]
        x = x + att
        h2 = rmsnorm(x, shared["ln2"], cfg.norm_eps)
        x = x + mlp_apply(shared["mlp"], h2, cfg.mlp_type)
        pad = [(0, 0), (0, 0), (0, cache_len - S), (0, 0)]
        k_caches.append(jnp.pad(k.astype(dtype), pad))
        v_caches.append(jnp.pad(v.astype(dtype), pad))
        prev = a + 1
    if prev < cfg.num_layers:
        x, st = mamba_span(x, prev, cfg.num_layers)
        ssm_states.append(st)

    ssm = jax.tree.map(lambda *xs: jnp.concatenate(xs, axis=0), *ssm_states)
    x = rmsnorm(x[:, -1:, :], params["final_norm"], cfg.norm_eps)
    logits = logits_from_embed(params["embed"], x)
    cache = {"ssm": ssm, "pos": jnp.asarray(S, jnp.int32)}
    if apps:
        cache["k"] = jnp.stack(k_caches)
        cache["v"] = jnp.stack(v_caches)
    return logits, cache


def init_cache(cfg: ModelConfig, batch: int, cache_len: int, dtype=None) -> dict:
    dtype = dtype or dtype_of(cfg.param_dtype)
    L = cfg.num_layers
    H, P, N = cfg.ssm_heads, cfg.ssm_head_dim, cfg.ssm_state
    ch = cfg.ssm_d_inner + 2 * N
    cache = {
        "ssm": {
            "h": jnp.zeros((L, batch, H, P, N), jnp.float32),
            "conv": jnp.zeros((L, batch, cfg.ssm_conv - 1, ch), jnp.float32),
        },
        "pos": jnp.asarray(0, jnp.int32),
    }
    apps = n_shared_apps(cfg)
    if apps:
        kv_shape = (apps, batch, cfg.num_kv_heads, cache_len, cfg.head_dim)
        cache["k"] = jnp.zeros(kv_shape, dtype)
        cache["v"] = jnp.zeros(kv_shape, dtype)
    return cache


def decode_step(cfg: ModelConfig, params: dict, cache: dict, tokens: jax.Array):
    """tokens (B,) -> (logits (B, V) f32, new cache)."""
    B = tokens.shape[0]
    pos = cache["pos"]
    x = jnp.take(params["embed"], tokens, axis=0)[:, None, :]  # (B, 1, D)
    positions = pos[None]
    shared = params.get("shared")
    every = cfg.attn_every

    has_attn = shared is not None and n_shared_apps(cfg) > 0
    kc = cache.get("k", jnp.zeros((1, B, 1, 1, 1), x.dtype))
    vc = cache.get("v", jnp.zeros((1, B, 1, 1, 1), x.dtype))

    def shared_branch(args):
        x, app_idx, kc, vc = args
        h = rmsnorm(x, shared["ln1"], cfg.norm_eps)
        q, k, v = _qkv(cfg, shared["attn"], h)
        q = apply_rope(q, positions, cfg.rope_theta)
        k = apply_rope(k, positions, cfg.rope_theta)
        k_app = jax.lax.dynamic_index_in_dim(kc, app_idx, 0, keepdims=False)
        v_app = jax.lax.dynamic_index_in_dim(vc, app_idx, 0, keepdims=False)
        k_app = jax.lax.dynamic_update_slice(k_app, k.astype(k_app.dtype), (0, 0, pos, 0))
        v_app = jax.lax.dynamic_update_slice(v_app, v.astype(v_app.dtype), (0, 0, pos, 0))
        kc = jax.lax.dynamic_update_index_in_dim(kc, k_app, app_idx, 0)
        vc = jax.lax.dynamic_update_index_in_dim(vc, v_app, app_idx, 0)
        a = decode_attention(q, k_app, v_app, pos)
        a = a.transpose(0, 2, 1, 3).reshape(B, 1, cfg.q_dim) @ shared["attn"]["wo"]
        x = x + a
        h2 = rmsnorm(x, shared["ln2"], cfg.norm_eps)
        x = x + mlp_apply(shared["mlp"], h2, cfg.mlp_type)
        return x, app_idx + 1, kc, vc

    def body(carry, scanned):
        x, app_idx, kc, vc = carry
        block, ssm_state, idx = scanned
        h = rmsnorm(x, block["ln"], cfg.norm_eps)
        y, ssm_new = ssm_decode_step(cfg, block["ssm"], ssm_state, h)
        x = x + y
        if has_attn:
            x, app_idx, kc, vc = jax.lax.cond(
                (idx + 1) % every == 0,
                shared_branch,
                lambda args: args,
                (x, app_idx, kc, vc),
            )
        return (x, app_idx, kc, vc), ssm_new

    (x, _, kc, vc), ssm_states = jax.lax.scan(
        body,
        (x, jnp.asarray(0, jnp.int32), kc, vc),
        (params["blocks"], cache["ssm"], jnp.arange(cfg.num_layers)),
    )
    x = rmsnorm(x, params["final_norm"], cfg.norm_eps)
    logits = logits_from_embed(params["embed"], x)[:, 0]
    new_cache = {"ssm": ssm_states, "pos": pos + 1}
    if has_attn:
        new_cache["k"], new_cache["v"] = kc, vc
    return logits, new_cache
