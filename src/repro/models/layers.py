"""Shared neural-net layers (pure functions over param pytrees).

Conventions:
  - activations (B, S, D); attention tensors (B, H, S, Dh);
  - params bf16 (config.param_dtype), accumulation/normalization in f32;
  - attention has three lowerings: dense (short S), chunked flash-style
    (long S: online softmax over KV blocks inside lax.scan — bounded
    memory, the pure-JAX analogue of kernels/flash_attention.py), and
    decode (one query token against a cache).
"""
from __future__ import annotations

import functools
from typing import Any

import jax
import jax.numpy as jnp
import numpy as np


def dtype_of(name: str):
    return jnp.dtype(name)


# ---------------------------------------------------------------------------
# norms
# ---------------------------------------------------------------------------

def rmsnorm(x: jax.Array, w: jax.Array, eps: float = 1e-6) -> jax.Array:
    xf = x.astype(jnp.float32)
    var = jnp.mean(xf * xf, axis=-1, keepdims=True)
    out = xf * jax.lax.rsqrt(var + eps)
    return (out * (1.0 + w.astype(jnp.float32))).astype(x.dtype)


def gated_rmsnorm(x: jax.Array, z: jax.Array, w: jax.Array, eps: float = 1e-6) -> jax.Array:
    """Mamba2's norm: RMSNorm(x * silu(z))."""
    return rmsnorm(x * jax.nn.silu(z.astype(jnp.float32)).astype(x.dtype), w, eps)


# ---------------------------------------------------------------------------
# rotary embeddings (half-split convention)
# ---------------------------------------------------------------------------

def rope_freqs(head_dim: int, theta: float) -> np.ndarray:
    return 1.0 / (theta ** (np.arange(0, head_dim // 2, dtype=np.float32) * 2 / head_dim))


def apply_rope(x: jax.Array, positions: jax.Array, theta: float) -> jax.Array:
    """x: (B, H, S, Dh); positions: (S,) or scalar broadcast over S."""
    dh = x.shape[-1]
    freqs = jnp.asarray(rope_freqs(dh, theta))               # (Dh/2,)
    angles = positions.astype(jnp.float32)[..., None] * freqs  # (S, Dh/2)
    cos, sin = jnp.cos(angles), jnp.sin(angles)
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    rx1 = x1 * cos - x2 * sin
    rx2 = x2 * cos + x1 * sin
    return jnp.concatenate([rx1, rx2], axis=-1).astype(x.dtype)


# ---------------------------------------------------------------------------
# MLPs
# ---------------------------------------------------------------------------

def mlp_apply(params: dict, x: jax.Array, mlp_type: str) -> jax.Array:
    if mlp_type in ("swiglu", "geglu"):
        act = jax.nn.silu if mlp_type == "swiglu" else functools.partial(
            jax.nn.gelu, approximate=True
        )
        h = act(x @ params["wg"]) * (x @ params["wi"])
        return h @ params["wo"]
    h = jax.nn.gelu(x @ params["wi"], approximate=True)
    return h @ params["wo"]


def mlp_init(key, d_model: int, d_ff: int, mlp_type: str, dtype) -> dict:
    k1, k2, k3 = jax.random.split(key, 3)
    scale_in = 1.0 / np.sqrt(d_model)
    scale_out = 1.0 / np.sqrt(d_ff)
    p = {
        "wi": (jax.random.normal(k1, (d_model, d_ff)) * scale_in).astype(dtype),
        "wo": (jax.random.normal(k3, (d_ff, d_model)) * scale_out).astype(dtype),
    }
    if mlp_type in ("swiglu", "geglu"):
        p["wg"] = (jax.random.normal(k2, (d_model, d_ff)) * scale_in).astype(dtype)
    return p


# ---------------------------------------------------------------------------
# attention
# ---------------------------------------------------------------------------

def attention_init(key, cfg, dtype) -> dict:
    D, Q, KV = cfg.d_model, cfg.q_dim, cfg.kv_dim
    ks = jax.random.split(key, 4)
    s = 1.0 / np.sqrt(D)
    p = {
        "wq": (jax.random.normal(ks[0], (D, Q)) * s).astype(dtype),
        "wk": (jax.random.normal(ks[1], (D, KV)) * s).astype(dtype),
        "wv": (jax.random.normal(ks[2], (D, KV)) * s).astype(dtype),
        "wo": (jax.random.normal(ks[3], (Q, D)) / np.sqrt(Q)).astype(dtype),
    }
    if cfg.qkv_bias:
        p["bq"] = jnp.zeros((Q,), dtype)
        p["bk"] = jnp.zeros((KV,), dtype)
        p["bv"] = jnp.zeros((KV,), dtype)
    return p


def _dense_attention(
    q: jax.Array, k: jax.Array, v: jax.Array, *,
    causal: bool, prefix_len: int | jax.Array, scale: float,
) -> jax.Array:
    """Materialized-scores path for short sequences. GQA without kv repeat."""
    B, Hq, Sq, Dh = q.shape
    Hkv, Sk = k.shape[1], k.shape[2]
    g = Hq // Hkv
    qg = q.reshape(B, Hkv, g, Sq, Dh)
    s = jnp.einsum("bhgqd,bhkd->bhgqk", qg.astype(jnp.float32),
                   k.astype(jnp.float32)) * scale
    if causal:
        offset = Sk - Sq
        rows = jnp.arange(Sq)[:, None] + offset
        cols = jnp.arange(Sk)[None, :]
        ok = cols <= rows
        if prefix_len is not None:
            ok = ok | (cols < prefix_len)
        s = jnp.where(ok[None, None, None], s, -1e30)
    p = jax.nn.softmax(s, axis=-1)
    o = jnp.einsum("bhgqk,bhkd->bhgqd", p, v.astype(jnp.float32))
    return o.reshape(B, Hq, Sq, Dh).astype(q.dtype)


def _chunked_attention(
    q: jax.Array, k: jax.Array, v: jax.Array, *,
    causal: bool, prefix_len: int | jax.Array, scale: float,
    block_q: int, block_k: int,
) -> jax.Array:
    """Flash-style blocked attention in pure JAX (bounded memory).

    Online-softmax over KV blocks inside a lax.scan; a second scan walks
    query blocks. Peak live logits: (B, Hkv, G, bq, bk) — independent of S.
    """
    B, Hq, Sq, Dh = q.shape
    Hkv, Sk = k.shape[1], k.shape[2]
    g = Hq // Hkv
    bq, bk = min(block_q, Sq), min(block_k, Sk)
    if Sq % bq or Sk % bk:
        raise ValueError(f"S ({Sq},{Sk}) must divide blocks ({bq},{bk})")
    nq, nk = Sq // bq, Sk // bk
    offset = Sk - Sq
    qg = q.reshape(B, Hkv, g, nq, bq, Dh)
    kb = k.reshape(B, Hkv, nk, bk, Dh)
    vb = v.reshape(B, Hkv, nk, bk, Dh)

    def q_block(iq):
        qi = qg[:, :, :, iq].astype(jnp.float32)  # (B,Hkv,G,bq,Dh)

        def kv_step(carry, ik):
            acc, m, l = carry
            kj = kb[:, :, ik].astype(jnp.float32)   # (B,Hkv,bk,Dh)
            vj = vb[:, :, ik].astype(jnp.float32)
            s = jnp.einsum("bhgqd,bhkd->bhgqk", qi, kj) * scale
            if causal:
                rows = iq * bq + jnp.arange(bq)[:, None] + offset
                cols = ik * bk + jnp.arange(bk)[None, :]
                ok = cols <= rows
                if prefix_len is not None:
                    ok = ok | (cols < prefix_len)
                s = jnp.where(ok[None, None, None], s, -1e30)
            m_new = jnp.maximum(m, s.max(axis=-1, keepdims=True))
            p = jnp.exp(s - m_new)
            alpha = jnp.exp(m - m_new)
            l_new = l * alpha + p.sum(axis=-1, keepdims=True)
            acc_new = acc * alpha + jnp.einsum("bhgqk,bhkd->bhgqd", p, vj)
            return (acc_new, m_new, l_new), None

        acc0 = jnp.zeros((B, Hkv, g, bq, Dh), jnp.float32)
        m0 = jnp.full((B, Hkv, g, bq, 1), -1e30, jnp.float32)
        l0 = jnp.zeros((B, Hkv, g, bq, 1), jnp.float32)
        # remat the kv step: without it the backward saves the (bq, bk)
        # score tile per (iq, ik) pair — 32 GiB/device at 4k seq (§Perf)
        (acc, m, l), _ = jax.lax.scan(
            jax.checkpoint(kv_step), (acc0, m0, l0), jnp.arange(nk)
        )
        l = jnp.where(l == 0.0, 1.0, l)
        return (acc / l).astype(q.dtype)  # (B,Hkv,G,bq,Dh)

    _, blocks = jax.lax.scan(
        lambda _, iq: (None, q_block(iq)), None, jnp.arange(nq)
    )  # (nq, B, Hkv, G, bq, Dh)
    out = jnp.moveaxis(blocks, 0, 3).reshape(B, Hkv, g, Sq, Dh)
    return out.reshape(B, Hq, Sq, Dh)


def multihead_attention(
    q: jax.Array, k: jax.Array, v: jax.Array, *,
    causal: bool = True,
    prefix_len: int | jax.Array | None = None,
    scale: float | None = None,
    chunked_threshold: int = 4096,
    block_q: int = 512,
    block_k: int = 1024,
) -> jax.Array:
    scale = float(scale if scale is not None else 1.0 / np.sqrt(q.shape[-1]))
    if q.shape[2] >= chunked_threshold and q.shape[2] % min(block_q, q.shape[2]) == 0 \
            and k.shape[2] % min(block_k, k.shape[2]) == 0:
        return _chunked_attention(
            q, k, v, causal=causal, prefix_len=prefix_len, scale=scale,
            block_q=block_q, block_k=block_k,
        )
    return _dense_attention(q, k, v, causal=causal, prefix_len=prefix_len, scale=scale)


def decode_attention(
    q: jax.Array, k_cache: jax.Array, v_cache: jax.Array, pos: jax.Array, *,
    scale: float | None = None,
) -> jax.Array:
    """One-token attention against a cache.

    q: (B, Hq, 1, Dh); caches: (B, Hkv, S, Dh); pos: scalar i32 — the index
    of the token being generated (attends to cache[: pos+1]).
    """
    B, Hq, _, Dh = q.shape
    Hkv, S = k_cache.shape[1], k_cache.shape[2]
    g = Hq // Hkv
    scale = float(scale if scale is not None else 1.0 / np.sqrt(Dh))
    qg = q.reshape(B, Hkv, g, Dh)
    s = jnp.einsum("bhgd,bhkd->bhgk", qg.astype(jnp.float32),
                   k_cache.astype(jnp.float32)) * scale
    ok = jnp.arange(S)[None, None, None, :] <= pos
    s = jnp.where(ok, s, -1e30)
    p = jax.nn.softmax(s, axis=-1)
    o = jnp.einsum("bhgk,bhkd->bhgd", p, v_cache.astype(jnp.float32))
    return o.reshape(B, Hq, 1, Dh).astype(q.dtype)


# ---------------------------------------------------------------------------
# embeddings
# ---------------------------------------------------------------------------

def embed_init(key, vocab: int, d_model: int, dtype) -> jax.Array:
    return (jax.random.normal(key, (vocab, d_model)) * 0.02).astype(dtype)


def embed_lookup(table: jax.Array, ids: jax.Array) -> jax.Array:
    return jnp.take(table, ids, axis=0)


def logits_from_embed(table: jax.Array, x: jax.Array) -> jax.Array:
    from repro.runtime.sharding import constrain

    out = jnp.einsum("bsd,vd->bsv", x, table).astype(jnp.float32)
    # anchor the vocab dim to the model axis: the CE loss reduces over it
    # locally (one-hot contraction), so the full logits never re-replicate
    return constrain(out, (("pod", "data"), None, "model"))
