"""Mamba2 — SSD (state-space duality) blocks, arXiv:2405.21060.

Training/prefill uses the chunked SSD algorithm: within a chunk of length
Q the computation is a (masked, decay-weighted) quadratic form — MXU
friendly; across chunks a small recurrent state (B, H, P, N) carries via a
sequential scan. Decode is the pure SSM recurrence (state update per
token). All SSD math runs in f32.

Sharding note (§Perf zamba2 hillclimb): the projections are stored as
*separate* matrices (w_z/w_x/w_B/w_C/w_dt) and the depthwise conv as three
per-segment kernels rather than one fused (D, 2*DI+2*N+H) block. A fused
layout mixes segments whose natural shard boundaries (DI, N, H) don't
align with the column shards, so GSPMD fell back to replicating the whole
block over the model axis — 16x redundant compute (useful-FLOPs ratio
0.061). Per-segment weights shard cleanly: DI and H divide the model axis
on zamba2 (d_inner 4096, 64 heads / 16).

Shapes: d_inner = expand * d_model; H = d_inner / head_dim (P = head_dim);
N = ssm_state; single B/C group shared across heads (ngroups=1).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from repro.models.config import ModelConfig
from repro.models.layers import gated_rmsnorm, rmsnorm
from repro.runtime.sharding import constrain


def mamba_init(key, cfg: ModelConfig, dtype) -> dict:
    D, DI, N, H, W = (
        cfg.d_model,
        cfg.ssm_d_inner,
        cfg.ssm_state,
        cfg.ssm_heads,
        cfg.ssm_conv,
    )
    ks = jax.random.split(key, 6)
    s = 1.0 / np.sqrt(D)
    return {
        "w_z": (jax.random.normal(ks[0], (D, DI)) * s).astype(dtype),
        "w_x": (jax.random.normal(ks[1], (D, DI)) * s).astype(dtype),
        "w_B": (jax.random.normal(ks[2], (D, N)) * s).astype(dtype),
        "w_C": (jax.random.normal(ks[3], (D, N)) * s).astype(dtype),
        "w_dt": (jax.random.normal(ks[4], (D, H)) * s).astype(dtype),
        "conv_x": (jax.random.normal(ks[5], (W, DI)) / np.sqrt(W)).astype(dtype),
        "conv_xb": jnp.zeros((DI,), dtype),
        "conv_B": (jax.random.normal(ks[5], (W, N)) / np.sqrt(W)).astype(dtype),
        "conv_Bb": jnp.zeros((N,), dtype),
        "conv_C": (jax.random.normal(ks[5], (W, N)) / np.sqrt(W)).astype(dtype),
        "conv_Cb": jnp.zeros((N,), dtype),
        "A_log": jnp.log(
            jnp.linspace(1.0, 16.0, H, dtype=jnp.float32)
        ),  # A in [-16, -1]
        "D": jnp.ones((H,), jnp.float32),
        "dt_bias": jnp.log(jnp.expm1(jnp.full((H,), 0.01, jnp.float32))),
        "norm_w": jnp.zeros((DI,), dtype),
        "w_out": (jax.random.normal(ks[2], (DI, D)) / np.sqrt(DI)).astype(dtype),
    }


def _causal_conv(u: jax.Array, w: jax.Array, b: jax.Array) -> jax.Array:
    """Depthwise causal conv over time + silu: u (B, S, Ch), w (W, Ch)."""
    W = w.shape[0]
    out = u * w[-1]
    for i in range(1, W):
        shifted = jnp.pad(u, ((0, 0), (i, 0), (0, 0)))[:, : u.shape[1]]
        out = out + shifted * w[-1 - i]
    return jax.nn.silu((out + b).astype(jnp.float32)).astype(u.dtype)


def _feature_model_axis(cfg: ModelConfig):
    """The model axis for feature dims — None when "model" carries batch."""
    return "model" if cfg.tensor_parallel else None


def _project(cfg: ModelConfig, p: dict, x_in: jax.Array):
    """x (B,S,D) -> z (B,S,DI), xr, B_, C_, dt — each shard-aligned."""
    ba = cfg.batch_axes
    fm = _feature_model_axis(cfg)
    z = constrain(x_in @ p["w_z"], (ba, None, fm))
    xr = constrain(x_in @ p["w_x"], (ba, None, fm))
    B_ = x_in @ p["w_B"]
    C_ = x_in @ p["w_C"]
    dt = constrain(x_in @ p["w_dt"], (ba, None, fm))
    return z, xr, B_, C_, dt


def ssd_forward(cfg: ModelConfig, p: dict, x_in: jax.Array):
    """Full-sequence SSD.

    x_in: (B, S, D) -> (y: (B, S, D), state {"h": (B,H,P,N), "conv":
    (B, W-1, Ch)}) — the state continues generation exactly where the
    sequence ended (asserted by tests/models/test_mamba2_ssd.py).
    """
    B, S, D = x_in.shape
    DI, N, H, P = cfg.ssm_d_inner, cfg.ssm_state, cfg.ssm_heads, cfg.ssm_head_dim
    Q = min(cfg.ssm_chunk, S)
    if S % Q:
        raise ValueError(f"S={S} not divisible by ssm_chunk={Q}")
    nc = S // Q
    W = cfg.ssm_conv
    ba = cfg.batch_axes

    z, xr, B_, C_, dt = _project(cfg, p, x_in)
    # conv state: the last W-1 *pre-conv* rows per segment (decode continues)
    conv_tail = jnp.concatenate(
        [
            jnp.pad(t, ((0, 0), (max(W - 1 - S, 0), 0), (0, 0)))[:, -(W - 1):]
            for t in (xr, B_, C_)
        ],
        axis=-1,
    ).astype(jnp.float32)
    xr = _causal_conv(xr, p["conv_x"], p["conv_xb"])
    B_ = _causal_conv(B_, p["conv_B"], p["conv_Bb"])
    C_ = _causal_conv(C_, p["conv_C"], p["conv_Cb"])

    # f32 SSD quantities; heads shard over model (H % model == 0 on zamba2)
    fm = _feature_model_axis(cfg)
    xh = xr.reshape(B, S, H, P).astype(jnp.float32)
    xh = constrain(xh, (ba, None, fm, None))
    Bf = B_.astype(jnp.float32)                      # (B, S, N)
    Cf = C_.astype(jnp.float32)
    dtf = jax.nn.softplus(dt.astype(jnp.float32) + p["dt_bias"])  # (B, S, H)
    dtf = constrain(dtf, (ba, None, fm))
    A = -jnp.exp(p["A_log"])                          # (H,) negative
    dA = dtf * A                                      # (B, S, H) log-decay

    # chunked views
    xc = xh.reshape(B, nc, Q, H, P)
    Bc = Bf.reshape(B, nc, Q, N)
    Cc = Cf.reshape(B, nc, Q, N)
    dAc = dA.reshape(B, nc, Q, H)
    dtc = dtf.reshape(B, nc, Q, H)

    seg = jnp.cumsum(dAc, axis=2)                     # (B, nc, Q, H)
    total = seg[:, :, -1]                             # (B, nc, H)

    # intra-chunk (quadratic, masked decay kernel)
    #   G[t, s] = (C_t . B_s) * exp(seg_t - seg_s) * dt_s   for s <= t
    CB = jnp.einsum("bcin,bcjn->bcij", Cc, Bc)        # (B, nc, Q, Q)
    decay = jnp.exp(seg[:, :, :, None, :] - seg[:, :, None, :, :])  # (B,nc,Q,Q,H)
    mask = jnp.tril(jnp.ones((Q, Q), bool))
    G = CB[..., None] * decay * dtc[:, :, None, :, :]
    G = jnp.where(mask[None, None, :, :, None], G, 0.0)
    G = constrain(G, (ba, None, None, None, fm))
    y_intra = jnp.einsum("bcijh,bcjhp->bcihp", G, xc)

    # chunk states: S_c = sum_t exp(total - seg_t) * dt_t * B_t x_t^T
    w_state = jnp.exp(total[:, :, None, :] - seg) * dtc        # (B, nc, Q, H)
    S_c = jnp.einsum("bcqh,bcqn,bcqhp->bchpn", w_state, Bc, xc)

    # inter-chunk recurrence over nc (sequential, tiny state)
    def step(h, inputs):
        S_ci, total_i = inputs                        # (B,H,P,N), (B,H)
        h_new = h * jnp.exp(total_i)[:, :, None, None] + S_ci
        return h_new, h                                # emit state *before* chunk

    h0 = jnp.zeros((B, H, P, N), jnp.float32)
    h_final, h_prevs = jax.lax.scan(
        step,
        h0,
        (jnp.moveaxis(S_c, 1, 0), jnp.moveaxis(total, 1, 0)),
    )
    h_prevs = jnp.moveaxis(h_prevs, 0, 1)             # (B, nc, H, P, N)

    # inter-chunk contribution: y_t += C_t . (exp(seg_t) * h_prev)
    y_inter = jnp.einsum(
        "bcqn,bcqh,bchpn->bcqhp", Cc, jnp.exp(seg), h_prevs
    )

    y = (y_intra + y_inter).reshape(B, S, H, P)
    y = y + xh * p["D"][None, None, :, None]
    y = y.reshape(B, S, DI).astype(x_in.dtype)
    y = constrain(y, (ba, None, fm))
    y = gated_rmsnorm(y, z, p["norm_w"], cfg.norm_eps)
    return y @ p["w_out"], {"h": h_final, "conv": conv_tail}


def ssm_decode_step(cfg: ModelConfig, p: dict, state: dict, x_tok: jax.Array):
    """One-token recurrence. x_tok: (B, 1, D); state: {"h": (B,H,P,N),
    "conv": (B, W-1, Ch)} -> (y (B, 1, D), new state)."""
    B = x_tok.shape[0]
    DI, N, H, P = cfg.ssm_d_inner, cfg.ssm_state, cfg.ssm_heads, cfg.ssm_head_dim
    W = cfg.ssm_conv
    z, xr, B_, C_, dt = _project(cfg, p, x_tok)
    xBC = jnp.concatenate([xr, B_, C_], axis=-1)[:, 0]          # (B, Ch)

    conv_hist = state["conv"]                                    # (B, W-1, Ch)
    window = jnp.concatenate([conv_hist, xBC[:, None].astype(jnp.float32)], axis=1)
    conv_w = jnp.concatenate(
        [p["conv_x"], p["conv_B"], p["conv_C"]], axis=-1
    ).astype(jnp.float32)
    conv_b = jnp.concatenate(
        [p["conv_xb"], p["conv_Bb"], p["conv_Cb"]]
    ).astype(jnp.float32)
    conv_out = jnp.einsum("bwc,wc->bc", window, conv_w) + conv_b
    conv_out = jax.nn.silu(conv_out)
    new_conv = window[:, 1:]

    xr = conv_out[:, :DI].reshape(B, H, P)
    Bf = conv_out[:, DI : DI + N]
    Cf = conv_out[:, DI + N :]
    dtf = jax.nn.softplus(dt[:, 0].astype(jnp.float32) + p["dt_bias"])  # (B, H)
    A = -jnp.exp(p["A_log"])
    decay = jnp.exp(dtf * A)                                     # (B, H)

    h = state["h"] * decay[:, :, None, None] + jnp.einsum(
        "bh,bn,bhp->bhpn", dtf, Bf, xr
    )
    y = jnp.einsum("bn,bhpn->bhp", Cf, h) + xr * p["D"][None, :, None]
    y = y.reshape(B, 1, DI).astype(x_tok.dtype)
    y = gated_rmsnorm(y, z, p["norm_w"], cfg.norm_eps)
    return y @ p["w_out"], {"h": h, "conv": new_conv}


def init_ssm_state(cfg: ModelConfig, batch: int) -> dict:
    H, P, N = cfg.ssm_heads, cfg.ssm_head_dim, cfg.ssm_state
    ch = cfg.ssm_d_inner + 2 * N
    return {
        "h": jnp.zeros((batch, H, P, N), jnp.float32),
        "conv": jnp.zeros((batch, cfg.ssm_conv - 1, ch), jnp.float32),
    }


# ---------------------------------------------------------------------------
# naive O(S) recurrence oracle (tests only)
# ---------------------------------------------------------------------------

def ssd_reference(cfg: ModelConfig, p: dict, x_in: jax.Array) -> jax.Array:
    """Sequential recurrence — must match ssd_forward to f32 tolerance."""
    B, S, D = x_in.shape
    state = init_ssm_state(cfg, B)
    ys = []
    for t in range(S):
        y, state = ssm_decode_step(cfg, p, state, x_in[:, t : t + 1])
        ys.append(y)
    return jnp.concatenate(ys, axis=1)
