"""Mixture-of-Experts MLP with group-local capacity dispatch.

TPU/GSPMD-native formulation, two design points visible in EXPERIMENTS.md
§Perf (arctic-480b hillclimb):

  v1 (baseline, kept for reference in git history): one global scatter into
  (E, C, D). GSPMD cannot keep a scatter local when the operand is sharded
  over `model` and tokens over `data` — every layer moved the full
  (E, C, D) dispatch buffer over ICI (~750 s/step of collectives at 480B).

  v2 (this file): tokens are grouped along the data axis; each group routes
  and scatters *locally* into expert_in (G, E, Cg, D) sharded
  (data, model, -, -). The expert FFN einsum contracts locally; the
  combine gathers only the device-local expert slice and partial-sums over
  `model` (one (T, D)-sized all-reduce per layer — the unavoidable MoE
  combine volume).

Capacity semantics are per-group (Switch-style): Cg = Tg*k/E * factor;
overflow drops. The router aux (load-balance + z) is computed globally.

Arctic's "dense residual": a small dense FFN runs in parallel with the MoE
branch and the two outputs add.
"""
from __future__ import annotations

import math

import jax
import jax.numpy as jnp
import numpy as np

from repro.models.config import ModelConfig
from repro.models.layers import mlp_apply, mlp_init
from repro.runtime.sharding import constrain


def moe_init(key, cfg: ModelConfig, dtype) -> dict:
    E, D, F = cfg.moe_experts, cfg.d_model, cfg.d_ff
    ks = jax.random.split(key, 5)
    s_in, s_out = 1.0 / np.sqrt(D), 1.0 / np.sqrt(F)
    p = {
        "router": (jax.random.normal(ks[0], (D, E)) * s_in).astype(jnp.float32),
        "wi": (jax.random.normal(ks[1], (E, D, F)) * s_in).astype(dtype),
        "wo": (jax.random.normal(ks[2], (E, F, D)) * s_out).astype(dtype),
    }
    if cfg.mlp_type in ("swiglu", "geglu"):
        p["wg"] = (jax.random.normal(ks[3], (E, D, F)) * s_in).astype(dtype)
    if cfg.moe_dense_ff:
        p["dense"] = mlp_init(ks[4], D, cfg.moe_dense_ff, cfg.mlp_type, dtype)
    return p


def _mesh_info():
    try:
        m = jax.sharding.get_abstract_mesh()
        if m is not None and not m.empty:
            return m
    except Exception:
        pass
    try:
        from jax._src.mesh import thread_resources

        pm = thread_resources.env.physical_mesh
        if not pm.empty:
            return pm
    except Exception:
        pass
    return None


def moe_apply(cfg: ModelConfig, params: dict, x: jax.Array):
    """x: (B, S, D) -> (out (B, S, D), aux_loss scalar f32).

    Dispatch selection: explicit expert-parallel shard_map when the mesh
    has a model axis and shapes divide (the production path — see §Perf:
    GSPMD's scatter/gather partitioning moved ~2.2e15 collective bytes per
    step on arctic; the explicit all_to_all formulation moves the
    information-theoretic minimum); otherwise the GSPMD group-local
    formulation below (single-device tests, ragged decode batches).
    """
    B, S, D = x.shape
    E, K = cfg.moe_experts, cfg.moe_top_k
    T = B * S
    mesh = _mesh_info()
    if mesh is not None and "model" in mesh.axis_names:
        M = mesh.shape["model"]
        dp = tuple(a for a in ("pod", "data") if a in mesh.axis_names)
        n_dp = int(np.prod([mesh.shape[a] for a in dp], dtype=np.int64))
        if M > 1 and E % M == 0 and T % (n_dp * M) == 0:
            return _moe_expert_parallel(cfg, params, x, mesh, dp, M)
    return _moe_gspmd(cfg, params, x)


def _moe_gspmd(cfg: ModelConfig, params: dict, x: jax.Array):
    """GSPMD group-local formulation (fallback path)."""
    B, S, D = x.shape
    E, K = cfg.moe_experts, cfg.moe_top_k
    T = B * S
    G = math.gcd(cfg.moe_groups, T)
    Tg = T // G
    xt = x.reshape(G, Tg, D)
    xt = constrain(xt, ("data", None, None))

    logits = xt.astype(jnp.float32) @ params["router"]          # (G, Tg, E)
    probs = jax.nn.softmax(logits, axis=-1)
    gate, ids = jax.lax.top_k(probs, K)                          # (G, Tg, K)
    gate = gate / jnp.clip(gate.sum(-1, keepdims=True), 1e-9)

    # aux losses: Switch load-balance + router z-loss (global statistics)
    f = jnp.zeros((E,), jnp.float32).at[ids.reshape(-1)].add(1.0) / (T * K)
    p_mean = probs.reshape(-1, E).mean(axis=0)
    balance = E * jnp.sum(f * p_mean)
    z = jnp.mean(jax.scipy.special.logsumexp(logits, axis=-1) ** 2)
    aux = balance + cfg.router_z_weight * z

    # group-local slot bookkeeping ((token, k) pairs, token-major)
    ids_flat = ids.reshape(G, Tg * K)                            # (G, TgK)
    gate_flat = gate.reshape(G, Tg * K)
    token_idx = jnp.arange(Tg * K, dtype=jnp.int32) // K         # (TgK,)
    onehot = jax.nn.one_hot(ids_flat, E, dtype=jnp.int32)        # (G, TgK, E)
    onehot = constrain(onehot, ("data", None, None))
    pos_all = jnp.cumsum(onehot, axis=1) - onehot                # pos before self
    pos = jnp.take_along_axis(pos_all, ids_flat[..., None], axis=2)[..., 0]
    Cg = int(np.ceil(Tg * K / E * cfg.moe_capacity_factor))
    keep = (pos < Cg).astype(x.dtype)                            # (G, TgK)
    pos_c = jnp.minimum(pos, Cg - 1)

    x_slot = jnp.take(xt, token_idx, axis=1)                     # (G, TgK, D)
    g_idx = jnp.broadcast_to(
        jnp.arange(G, dtype=jnp.int32)[:, None], (G, Tg * K)
    )
    expert_in = jnp.zeros((G, E, Cg, D), x.dtype).at[g_idx, ids_flat, pos_c].add(
        x_slot * keep[..., None]
    )
    expert_in = constrain(expert_in, ("data", "model", None, None))

    if "wg" in params:
        act = jax.nn.silu if cfg.mlp_type == "swiglu" else jax.nn.gelu
        h = act(jnp.einsum("gecd,edf->gecf", expert_in, params["wg"])) * jnp.einsum(
            "gecd,edf->gecf", expert_in, params["wi"]
        )
    else:
        h = jax.nn.gelu(jnp.einsum("gecd,edf->gecf", expert_in, params["wi"]))
    y = jnp.einsum("gecf,efd->gecd", h, params["wo"])
    y = constrain(y, ("data", "model", None, None))

    # combine: gather each slot's expert output (partial over the model-
    # sharded E dim -> one (G, Tg, D) all-reduce, the MoE combine volume)
    y_slot = y[g_idx, ids_flat, pos_c]                           # (G, TgK, D)
    y_slot = y_slot * (gate_flat.astype(x.dtype) * keep)[..., None]
    out = jnp.zeros((G, Tg, D), x.dtype).at[g_idx, token_idx[None, :]].add(y_slot)
    out = constrain(out, ("data", None, None))
    out = out.reshape(B, S, D)

    if "dense" in params:
        out = out + mlp_apply(params["dense"], x, cfg.mlp_type)
    return out, aux


def _moe_expert_parallel(cfg: ModelConfig, params: dict, x: jax.Array,
                         mesh, dp: tuple, M: int):
    """Explicit EP: shard_map with all_to_all over the model axis.

    Per device: route the local token slice, pack per-(peer, local-expert)
    capacity buffers, all_to_all over "model", run the local experts
    (weights ZeRO-gathered over the data axes inside — transpose is a
    reduce-scatter, so grads shard back automatically), all_to_all the
    outputs home, combine locally. Collective volume per layer:
    2 x T*k*cf*D (the dispatch round-trips) + the weight gathers — the
    information-theoretic MoE minimum, vs GSPMD's emergent all-gathers of
    the full (E, C, D) buffer (~30x more on arctic-480b).

    Capacity is per (source device, expert): C_loc = T_loc*k/E * factor.
    """
    from jax.experimental.shard_map import shard_map
    from jax.sharding import PartitionSpec as P

    B, S, D = x.shape
    E, K = cfg.moe_experts, cfg.moe_top_k
    T = B * S
    E_loc = E // M
    n_dp = int(np.prod([mesh.shape[a] for a in dp], dtype=np.int64))
    T_loc = T // (n_dp * M)
    C_loc = max(1, int(np.ceil(T_loc * K / E * cfg.moe_capacity_factor)))
    dp_group = dp if len(dp) > 1 else dp[0]
    gated = "wg" in params

    xt = x.reshape(T, D)

    # aux losses from a replicated router pass (cheap; identical decisions)
    logits_g = xt.astype(jnp.float32) @ params["router"]
    probs_g = jax.nn.softmax(logits_g, axis=-1)
    _, ids_g = jax.lax.top_k(probs_g, K)
    f = jnp.zeros((E,), jnp.float32).at[ids_g.reshape(-1)].add(1.0) / (T * K)
    balance = E * jnp.sum(f * probs_g.mean(axis=0))
    z = jnp.mean(jax.scipy.special.logsumexp(logits_g, axis=-1) ** 2)
    aux = balance + cfg.router_z_weight * z

    def local_fn(x_loc, router, wi_s, wg_s, wo_s):
        # x_loc: (T_loc, D); w*_s: (E_loc, D or F slice, ...) fsdp-sharded
        wi = jax.lax.all_gather(wi_s, dp_group, axis=1, tiled=True)
        wo = jax.lax.all_gather(wo_s, dp_group, axis=1, tiled=True)
        wg = (jax.lax.all_gather(wg_s, dp_group, axis=1, tiled=True)
              if gated else None)

        probs = jax.nn.softmax(x_loc.astype(jnp.float32) @ router, axis=-1)
        gate, ids = jax.lax.top_k(probs, K)            # (T_loc, K)
        gate = gate / jnp.clip(gate.sum(-1, keepdims=True), 1e-9)
        s = T_loc * K
        ids_f = ids.reshape(s)
        gate_f = gate.reshape(s)
        token_idx = jnp.arange(s, dtype=jnp.int32) // K
        peer = ids_f // E_loc                           # destination model rank
        exp = ids_f % E_loc                             # expert on that rank
        onehot = jax.nn.one_hot(ids_f, E, dtype=jnp.int32)
        pos = (jnp.cumsum(onehot, axis=0) - onehot)[jnp.arange(s), ids_f]
        keep = (pos < C_loc).astype(x_loc.dtype)
        pos_c = jnp.minimum(pos, C_loc - 1)

        send = jnp.zeros((M, E_loc, C_loc, D), x_loc.dtype).at[
            peer, exp, pos_c
        ].add(x_loc[token_idx] * keep[:, None])
        recv = jax.lax.all_to_all(send, "model", 0, 0, tiled=True)
        # recv[i]: what peer i sent to my experts (tiled a2a keeps the shape)

        h_in = jnp.einsum("mecd,edf->mecf", recv, wi)
        if gated:
            act = jax.nn.silu if cfg.mlp_type == "swiglu" else jax.nn.gelu
            h = act(jnp.einsum("mecd,edf->mecf", recv, wg)) * h_in
        else:
            h = jax.nn.gelu(h_in)
        y = jnp.einsum("mecf,efd->mecd", h, wo)

        back = jax.lax.all_to_all(y, "model", 0, 0, tiled=True)
        y_slot = back[peer, exp, pos_c] * (gate_f.astype(x_loc.dtype) * keep)[:, None]
        return jnp.zeros((T_loc, D), x_loc.dtype).at[token_idx].add(y_slot)

    tok_axes = dp + ("model",)
    out_flat = shard_map(
        local_fn,
        mesh=mesh,
        in_specs=(
            P(tok_axes, None),                  # tokens over all axes
            P(None, None),                      # router replicated
            P("model", dp_group, None),         # experts over model, fsdp data
            P("model", dp_group, None) if gated else P(None),
            P("model", dp_group, None),
        ),
        out_specs=P(tok_axes, None),
        check_rep=False,
    )(
        xt,
        params["router"],
        params["wi"],
        params["wg"] if gated else jnp.zeros((1,), x.dtype),
        params["wo"],
    )
    out = out_flat.reshape(B, S, D)
    if "dense" in params:
        out = out + mlp_apply(params["dense"], x, cfg.mlp_type)
    return out, aux
