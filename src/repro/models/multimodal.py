"""Multimodal backbones with stub frontends (per assignment rules).

paligemma-3b [vlm]: the SigLIP tower is a stub — ``input_specs()`` provides
precomputed patch embeddings (B, P, D_vis=d_model); a learned projection
maps them into the gemma backbone's residual stream; image tokens form a
bidirectional *prefix* (PaliGemma's prefix-LM attention), text is causal.

musicgen-medium [audio]: EnCodec is a stub — the backbone consumes K=4
codebook token streams (B, S, K), embeds them with K tables (summed), and
predicts K vocab-2048 heads per position. The delay-pattern interleaving is
data preparation, out of scope.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from repro.models.config import ModelConfig
from repro.models import transformer as tfm
from repro.models.layers import dtype_of, rmsnorm


# ---------------------------------------------------------------------------
# vision-language (paligemma)
# ---------------------------------------------------------------------------

def vlm_init(cfg: ModelConfig, key: jax.Array) -> dict:
    k1, k2 = jax.random.split(key)
    params = tfm.init_params(cfg, k1)
    params["vision_proj"] = (
        jax.random.normal(k2, (cfg.d_model, cfg.d_model)) / np.sqrt(cfg.d_model)
    ).astype(dtype_of(cfg.param_dtype))
    return params


def vlm_hidden(cfg: ModelConfig, params: dict, patches: jax.Array, tokens: jax.Array):
    """patches: (B, P, D) stub embeddings; tokens: (B, S_text).

    Returns (text hidden (B, S_text, D), aux)."""
    x_img = patches.astype(dtype_of(cfg.param_dtype)) @ params["vision_proj"]
    x_txt = tfm.embed_tokens(cfg, params, tokens)
    x = jnp.concatenate([x_img, x_txt], axis=1)
    P = patches.shape[1]
    h, _, aux = tfm.forward(cfg, params, x, prefix_len=P)
    return h[:, P:, :], aux


def vlm_forward(cfg: ModelConfig, params: dict, patches: jax.Array, tokens: jax.Array):
    """Returns (text logits (B, S_text, V) f32, aux)."""
    h, aux = vlm_hidden(cfg, params, patches, tokens)
    return tfm.lm_logits(cfg, params, h), aux


def vlm_prefill(cfg: ModelConfig, params: dict, patches: jax.Array,
                tokens: jax.Array, cache_len: int):
    x_img = patches.astype(dtype_of(cfg.param_dtype)) @ params["vision_proj"]
    x_txt = tfm.embed_tokens(cfg, params, tokens)
    x = jnp.concatenate([x_img, x_txt], axis=1)
    P = patches.shape[1]
    S = x.shape[1]
    h, cache, _ = tfm.forward(cfg, params, x, prefix_len=P, return_cache=True)
    k, v = cache["k"], cache["v"]
    if cache_len > S:
        pad = [(0, 0), (0, 0), (0, 0), (0, cache_len - S), (0, 0)]
        k, v = jnp.pad(k, pad), jnp.pad(v, pad)
    logits = tfm.lm_logits(cfg, params, h[:, -1:, :])
    return logits, {"k": k, "v": v, "pos": jnp.asarray(S, jnp.int32)}


# vlm decode == transformer decode (image lives in the cache prefix)
vlm_decode_step = tfm.decode_step


# ---------------------------------------------------------------------------
# audio LM over codebooks (musicgen)
# ---------------------------------------------------------------------------

def audio_init(cfg: ModelConfig, key: jax.Array) -> dict:
    K = cfg.audio_codebooks
    k1, k2, k3 = jax.random.split(key, 3)
    params = tfm.init_params(cfg.with_overrides(tie_embeddings=True), k1)
    del params["embed"]  # replaced by per-codebook tables
    dt = dtype_of(cfg.param_dtype)
    params["codebook_embed"] = (
        jax.random.normal(k2, (K, cfg.vocab_size, cfg.d_model)) * 0.02
    ).astype(dt)
    params["codebook_head"] = (
        jax.random.normal(k3, (K, cfg.vocab_size, cfg.d_model)) * 0.02
    ).astype(dt)
    return params


def _audio_embed(cfg: ModelConfig, params: dict, tokens: jax.Array) -> jax.Array:
    """tokens (B, S, K) -> summed codebook embeddings (B, S, D)."""
    # one_hot-free gather per codebook, summed
    embeds = params["codebook_embed"]  # (K, V, D)
    xs = [jnp.take(embeds[k], tokens[..., k], axis=0) for k in range(cfg.audio_codebooks)]
    return sum(xs)


def _audio_logits(cfg: ModelConfig, params: dict, h: jax.Array) -> jax.Array:
    """h (B, S, D) -> (B, S, K, V) f32 (vocab anchored to the model axis)."""
    from repro.runtime.sharding import constrain

    out = jnp.einsum("bsd,kvd->bskv", h, params["codebook_head"]).astype(jnp.float32)
    return constrain(out, (("pod", "data"), None, None, "model"))


def audio_hidden(cfg: ModelConfig, params: dict, tokens: jax.Array):
    """tokens (B, S, K) -> (hidden (B, S, D), aux)."""
    x = _audio_embed(cfg, params, tokens)
    h, _, aux = tfm.forward(cfg, params, x)
    return h, aux


def audio_forward(cfg: ModelConfig, params: dict, tokens: jax.Array):
    """tokens (B, S, K) -> (logits (B, S, K, V) f32, aux)."""
    h, aux = audio_hidden(cfg, params, tokens)
    return _audio_logits(cfg, params, h), aux


def audio_prefill(cfg: ModelConfig, params: dict, tokens: jax.Array, cache_len: int):
    B, S, _ = tokens.shape
    x = _audio_embed(cfg, params, tokens)
    h, cache, _ = tfm.forward(cfg, params, x, return_cache=True)
    k, v = cache["k"], cache["v"]
    if cache_len > S:
        pad = [(0, 0), (0, 0), (0, 0), (0, cache_len - S), (0, 0)]
        k, v = jnp.pad(k, pad), jnp.pad(v, pad)
    logits = _audio_logits(cfg, params, h[:, -1:, :])
    return logits, {"k": k, "v": v, "pos": jnp.asarray(S, jnp.int32)}


def audio_decode_step(cfg: ModelConfig, params: dict, cache: dict, tokens: jax.Array):
    """tokens (B, K) one frame -> (logits (B, K, V) f32, cache)."""
    B = tokens.shape[0]
    pos = cache["pos"]
    x = _audio_embed(cfg, params, tokens[:, None, :])  # (B, 1, D)
    positions = pos[None]
    # same per-layer cache scan as transformer.decode_step, but with the
    # codebook embedding/head instead of a single tied table
    logits_h, new_cache = _audio_decode_core(cfg, params, cache, x, positions)
    logits = _audio_logits(cfg, params, logits_h)[:, 0]
    return logits, new_cache


def _audio_decode_core(cfg, params, cache, x, positions):
    from repro.models.transformer import _qkv
    from repro.models.layers import apply_rope, decode_attention, mlp_apply

    B = x.shape[0]
    pos = cache["pos"]

    def body(x, scanned):
        block, k_c, v_c = scanned
        h = rmsnorm(x, block["ln1"], cfg.norm_eps)
        q, k, v = _qkv(cfg, block["attn"], h)
        q = apply_rope(q, positions, cfg.rope_theta)
        k = apply_rope(k, positions, cfg.rope_theta)
        k_c = jax.lax.dynamic_update_slice(k_c, k.astype(k_c.dtype), (0, 0, pos, 0))
        v_c = jax.lax.dynamic_update_slice(v_c, v.astype(v_c.dtype), (0, 0, pos, 0))
        a = decode_attention(q, k_c, v_c, pos)
        a = a.transpose(0, 2, 1, 3).reshape(B, 1, cfg.q_dim) @ block["attn"]["wo"]
        x = x + a
        h2 = rmsnorm(x, block["ln2"], cfg.norm_eps)
        x = x + mlp_apply(block["mlp"], h2, cfg.mlp_type)
        return x, (k_c, v_c)

    x, (k_new, v_new) = jax.lax.scan(
        body, x, (params["blocks"], cache["k"], cache["v"])
    )
    h = rmsnorm(x, params["final_norm"], cfg.norm_eps)
    return h, {"k": k_new, "v": v_new, "pos": pos + 1}
