"""Dense decoder-only transformer (the LM backbone for 8 of 10 archs).

Layer params are stacked along a leading L axis and the forward pass is a
``lax.scan`` over layers: HLO stays one-layer-sized (fast compile at 512
devices), checkpoint chunk keys are stable, and the remat policy wraps the
scan body. MoE archs swap the MLP for models/moe.py inside the same block.
"""
from __future__ import annotations

import functools
from typing import Any, Callable

import jax
import jax.numpy as jnp
import numpy as np

from repro.models.config import ModelConfig
from repro.models.layers import (
    apply_rope,
    attention_init,
    decode_attention,
    dtype_of,
    embed_init,
    embed_lookup,
    logits_from_embed,
    mlp_apply,
    mlp_init,
    multihead_attention,
    rmsnorm,
)


# ---------------------------------------------------------------------------
# init
# ---------------------------------------------------------------------------

def _block_init(key, cfg: ModelConfig, dtype) -> dict:
    k_attn, k_mlp = jax.random.split(key)
    block = {
        "ln1": jnp.zeros((cfg.d_model,), dtype),
        "attn": attention_init(k_attn, cfg, dtype),
    }
    if cfg.family == "moe":
        from repro.models.moe import moe_init

        block["moe"] = moe_init(k_mlp, cfg, dtype)
        if not cfg.parallel_block:
            block["ln2"] = jnp.zeros((cfg.d_model,), dtype)
    else:
        block["mlp"] = mlp_init(k_mlp, cfg.d_model, cfg.d_ff, cfg.mlp_type, dtype)
        if not cfg.parallel_block:
            block["ln2"] = jnp.zeros((cfg.d_model,), dtype)
    return block


def init_params(cfg: ModelConfig, key: jax.Array) -> dict:
    dtype = dtype_of(cfg.param_dtype)
    k_embed, k_blocks, k_head = jax.random.split(key, 3)
    layer_keys = jax.random.split(k_blocks, cfg.num_layers)
    blocks = jax.vmap(lambda k: _block_init(k, cfg, dtype))(layer_keys)
    params = {
        "embed": embed_init(k_embed, cfg.vocab_size, cfg.d_model, dtype),
        "blocks": blocks,
        "final_norm": jnp.zeros((cfg.d_model,), dtype),
    }
    if not cfg.tie_embeddings:
        params["lm_head"] = embed_init(k_head, cfg.vocab_size, cfg.d_model, dtype)
    return params


# ---------------------------------------------------------------------------
# blocks
# ---------------------------------------------------------------------------

def _qkv(cfg: ModelConfig, attn: dict, h: jax.Array):
    B, S, _ = h.shape
    q = h @ attn["wq"]
    k = h @ attn["wk"]
    v = h @ attn["wv"]
    if cfg.qkv_bias:
        q, k, v = q + attn["bq"], k + attn["bk"], v + attn["bv"]
    q = q.reshape(B, S, cfg.num_heads, cfg.head_dim).transpose(0, 2, 1, 3)
    k = k.reshape(B, S, cfg.num_kv_heads, cfg.head_dim).transpose(0, 2, 1, 3)
    v = v.reshape(B, S, cfg.num_kv_heads, cfg.head_dim).transpose(0, 2, 1, 3)
    return q, k, v


def _block_apply(
    cfg: ModelConfig,
    block: dict,
    x: jax.Array,
    positions: jax.Array,
    prefix_len: int | jax.Array | None,
):
    """Returns (x_out, (k, v), aux_loss)."""
    from repro.runtime.sharding import constrain

    B, S, _ = x.shape
    # pin the carry's batch sharding: GSPMD otherwise may replicate the
    # scan carry and all-gather the global batch inside every layer
    x = constrain(x, (cfg.batch_axes, None, None))
    h = rmsnorm(x, block["ln1"], cfg.norm_eps)
    if cfg.attn_over_model:
        # heads don't divide the model axis: reshard the batch over the
        # FULL mesh for the attention region (one all-to-all in, one out)
        h = constrain(h, (("pod", "data", "model"), None, None))
    q, k, v = _qkv(cfg, block["attn"], h)
    q = apply_rope(q, positions, cfg.rope_theta)
    k = apply_rope(k, positions, cfg.rope_theta)
    attn_out = multihead_attention(
        q, k, v,
        causal=True, prefix_len=prefix_len,
        chunked_threshold=cfg.attn_chunked_threshold,
        block_q=cfg.attn_block_q, block_k=cfg.attn_block_k,
    )
    attn_out = attn_out.transpose(0, 2, 1, 3).reshape(B, S, cfg.q_dim)
    attn_out = attn_out @ block["attn"]["wo"]
    if cfg.attn_over_model:
        attn_out = constrain(attn_out, (cfg.batch_axes, None, None))

    aux = jnp.zeros((), jnp.float32)
    if cfg.parallel_block:
        if cfg.family == "moe":
            from repro.models.moe import moe_apply

            mlp_out, aux = moe_apply(cfg, block["moe"], h)
        else:
            mlp_out = mlp_apply(block["mlp"], h, cfg.mlp_type)
        x = x + attn_out + mlp_out
    else:
        x = x + attn_out
        h2 = rmsnorm(x, block["ln2"], cfg.norm_eps)
        if cfg.family == "moe":
            from repro.models.moe import moe_apply

            mlp_out, aux = moe_apply(cfg, block["moe"], h2)
        else:
            mlp_out = mlp_apply(block["mlp"], h2, cfg.mlp_type)
        x = x + mlp_out
    return x, (k, v), aux


def _remat(cfg: ModelConfig, fn: Callable) -> Callable:
    if cfg.remat == "none":
        return fn
    if cfg.remat == "dots":
        return jax.checkpoint(
            fn, policy=jax.checkpoint_policies.dots_with_no_batch_dims_saveable
        )
    return jax.checkpoint(fn)


# ---------------------------------------------------------------------------
# forward passes
# ---------------------------------------------------------------------------

def forward(
    cfg: ModelConfig,
    params: dict,
    x: jax.Array,
    *,
    positions: jax.Array | None = None,
    prefix_len: int | jax.Array | None = None,
    return_cache: bool = False,
):
    """x: (B, S, D) embedded inputs -> (hidden, cache?, aux_loss)."""
    B, S, _ = x.shape
    if positions is None:
        positions = jnp.arange(S)

    def body(carry, block):
        xc, aux = carry
        x_new, kv, a = _block_apply(cfg, block, xc, positions, prefix_len)
        ys = kv if return_cache else None
        return (x_new, aux + a), ys

    body = _remat(cfg, body)
    (x, aux), kvs = jax.lax.scan(body, (x, jnp.zeros((), jnp.float32)), params["blocks"])
    x = rmsnorm(x, params["final_norm"], cfg.norm_eps)
    cache = None
    if return_cache:
        cache = {"k": kvs[0], "v": kvs[1]}  # (L, B, Hkv, S, Dh)
    return x, cache, aux


def embed_tokens(cfg: ModelConfig, params: dict, tokens: jax.Array) -> jax.Array:
    x = embed_lookup(params["embed"], tokens)
    if cfg.embed_scale:
        x = x * jnp.asarray(np.sqrt(cfg.d_model), x.dtype)
    return x


def lm_logits(cfg: ModelConfig, params: dict, hidden: jax.Array) -> jax.Array:
    table = params["embed"] if cfg.tie_embeddings else params["lm_head"]
    return logits_from_embed(table, hidden)


def lm_forward(cfg: ModelConfig, params: dict, tokens: jax.Array, **kw):
    """tokens (B, S) -> (logits (B, S, V) f32, aux)."""
    x = embed_tokens(cfg, params, tokens)
    h, _, aux = forward(cfg, params, x, **kw)
    return lm_logits(cfg, params, h), aux


def prefill(
    cfg: ModelConfig, params: dict, tokens: jax.Array, cache_len: int | None = None,
    prefix_len: int | jax.Array | None = None,
):
    """Build a KV cache of size ``cache_len`` (>= S); returns (logits, cache)."""
    B, S = tokens.shape
    cache_len = cache_len or S
    x = embed_tokens(cfg, params, tokens)
    h, cache, _ = forward(
        cfg, params, x, prefix_len=prefix_len, return_cache=True
    )
    k, v = cache["k"], cache["v"]
    if cache_len > S:
        pad = [(0, 0), (0, 0), (0, 0), (0, cache_len - S), (0, 0)]
        k, v = jnp.pad(k, pad), jnp.pad(v, pad)
    logits = lm_logits(cfg, params, h[:, -1:, :])
    return logits, {"k": k, "v": v, "pos": jnp.asarray(S, jnp.int32)}


def decode_step(
    cfg: ModelConfig, params: dict, cache: dict, tokens: jax.Array
):
    """One decode step. tokens: (B,) int32; cache k/v: (L, B, Hkv, Smax, Dh).

    Returns (logits (B, V) f32, new cache).
    """
    B = tokens.shape[0]
    pos = cache["pos"]  # scalar i32: index where the new token is written
    x = embed_tokens(cfg, params, tokens[:, None])  # (B, 1, D)
    positions = pos[None]

    def body(x, scanned):
        block, k_c, v_c = scanned
        h = rmsnorm(x, block["ln1"], cfg.norm_eps)
        q, k, v = _qkv(cfg, block["attn"], h)          # (B, H, 1, Dh)
        q = apply_rope(q, positions, cfg.rope_theta)
        k = apply_rope(k, positions, cfg.rope_theta)
        k_c = jax.lax.dynamic_update_slice(k_c, k.astype(k_c.dtype), (0, 0, pos, 0))
        v_c = jax.lax.dynamic_update_slice(v_c, v.astype(v_c.dtype), (0, 0, pos, 0))
        attn_out = decode_attention(q, k_c, v_c, pos)
        attn_out = attn_out.transpose(0, 2, 1, 3).reshape(B, 1, cfg.q_dim)
        attn_out = attn_out @ block["attn"]["wo"]
        if cfg.parallel_block:
            if cfg.family == "moe":
                from repro.models.moe import moe_apply

                mlp_out, _ = moe_apply(cfg, block["moe"], h)
            else:
                mlp_out = mlp_apply(block["mlp"], h, cfg.mlp_type)
            x = x + attn_out + mlp_out
        else:
            x = x + attn_out
            h2 = rmsnorm(x, block["ln2"], cfg.norm_eps)
            if cfg.family == "moe":
                from repro.models.moe import moe_apply

                mlp_out, _ = moe_apply(cfg, block["moe"], h2)
            else:
                mlp_out = mlp_apply(block["mlp"], h2, cfg.mlp_type)
            x = x + mlp_out
        return x, (k_c, v_c)

    x, (k_new, v_new) = jax.lax.scan(
        body, x, (params["blocks"], cache["k"], cache["v"])
    )
    h = rmsnorm(x, params["final_norm"], cfg.norm_eps)
    logits = lm_logits(cfg, params, h)[:, 0, :]
    return logits, {"k": k_new, "v": v_new, "pos": pos + 1}


def init_cache(
    cfg: ModelConfig, batch: int, cache_len: int, dtype=None
) -> dict:
    dtype = dtype or dtype_of(cfg.param_dtype)
    shape = (cfg.num_layers, batch, cfg.num_kv_heads, cache_len, cfg.head_dim)
    return {
        "k": jnp.zeros(shape, dtype),
        "v": jnp.zeros(shape, dtype),
        "pos": jnp.asarray(0, jnp.int32),
    }
