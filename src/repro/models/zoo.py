"""Model zoo: one API over all families.

``build(cfg)`` returns a ``Model`` whose functions close over the config:

    init(key)                      -> params
    loss(params, batch)            -> (scalar f32 loss, metrics dict)
    forward(params, batch)         -> logits
    prefill(params, batch, cache_len) -> (logits, cache)
    decode(params, cache, tokens)  -> (logits, cache)
    init_cache(batch, cache_len)   -> cache
    input_specs(shape)             -> ShapeDtypeStructs for jit lowering
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Any, Callable

import jax
import jax.numpy as jnp
import numpy as np

from repro.models.config import ModelConfig
from repro.models import hybrid as hyb
from repro.models import multimodal as mm
from repro.models import transformer as tfm


# ---------------------------------------------------------------------------
# losses
# ---------------------------------------------------------------------------

def softmax_xent(logits: jax.Array, targets: jax.Array, *, z_weight: float = 1e-4):
    """logits (..., V) f32; targets (...) i32 -> mean CE (+ z-loss).

    The gold logit is extracted with a one-hot contraction rather than
    take_along_axis: with the vocab dim sharded over `model`, the one-hot
    product reduces locally and all-reduces a scalar per token, whereas a
    gather along a sharded axis forces GSPMD to all-gather the logits
    (observed: ~400 GB/device of temp at 151k vocab).
    """
    logits = logits.astype(jnp.float32)
    lse = jax.scipy.special.logsumexp(logits, axis=-1)
    onehot = jax.nn.one_hot(targets, logits.shape[-1], dtype=jnp.float32)
    gold = jnp.sum(logits * onehot, axis=-1)
    ce = jnp.mean(lse - gold)
    z = jnp.mean(lse**2) * z_weight
    return ce + z, ce


def chunked_lm_xent(
    hidden: jax.Array,
    table: jax.Array,
    targets: jax.Array,
    *,
    chunk_tokens: int,
    batch_axes: tuple = ("pod", "data"),
    z_weight: float = 1e-4,
):
    """CE without materializing the full (tokens, vocab) logits.

    hidden (B, S, D), table (V, D), targets (B, S). A remat'd lax.scan over
    sequence chunks keeps at most (B*chunk, V) logits live — at 150k+ vocab
    this is the difference between ~40 GB and ~300 MB of activations (the
    full-logits buffer was the dominant temp in the baseline dry-run).
    """
    B, S, D = hidden.shape
    V = table.shape[0]
    # chunk along S only: chunk_tokens is a per-sequence window, so the
    # loop count stays small (each iteration all-reduces the table grad —
    # 1000s of tiny chunks would multiply that collective 1000-fold)
    per_b = max(1, min(S, chunk_tokens))
    while S % per_b:
        per_b -= 1
    n = S // per_b
    hs = hidden.reshape(B, n, per_b, D).transpose(1, 0, 2, 3)
    ts = targets.reshape(B, n, per_b).transpose(1, 0, 2)

    def body(carry, xs):
        from repro.runtime.sharding import constrain

        ce_sum, z_sum = carry
        h, t = xs
        h = constrain(h, (batch_axes, None, None))  # (B, chunk, D)
        logits = jnp.einsum("bsd,vd->bsv", h, table).astype(jnp.float32)
        lse = jax.scipy.special.logsumexp(logits, axis=-1)
        onehot = jax.nn.one_hot(t, V, dtype=jnp.float32)
        gold = jnp.sum(logits * onehot, axis=-1)
        return (ce_sum + jnp.sum(lse - gold), z_sum + jnp.sum(lse**2)), None

    (ce_sum, z_sum), _ = jax.lax.scan(
        jax.checkpoint(body), (jnp.zeros((), jnp.float32),) * 2, (hs, ts)
    )
    ntok = B * S
    ce = ce_sum / ntok
    return ce + z_weight * z_sum / ntok, ce


# ---------------------------------------------------------------------------
# input shape sets (per assignment)
# ---------------------------------------------------------------------------

SHAPES = {
    "train_4k": dict(kind="train", seq_len=4096, global_batch=256),
    "prefill_32k": dict(kind="prefill", seq_len=32768, global_batch=32),
    "decode_32k": dict(kind="decode", seq_len=32768, global_batch=128),
    "long_500k": dict(kind="decode", seq_len=524288, global_batch=1),
}


def shape_applicable(cfg: ModelConfig, shape: str) -> tuple[bool, str]:
    """long_500k only runs for sub-quadratic archs (DESIGN §6)."""
    if shape == "long_500k" and not cfg.subquadratic:
        return False, (
            "full quadratic attention: a 500k-token context needs "
            "sub-quadratic attention (skip noted in DESIGN.md §6)"
        )
    return True, ""


@dataclass
class Model:
    cfg: ModelConfig
    init: Callable
    loss: Callable
    forward: Callable
    prefill: Callable | None
    decode: Callable | None
    init_cache: Callable | None

    # -- shape-set plumbing ----------------------------------------------------
    def input_specs(self, shape: str, *, batch_override: int | None = None) -> dict:
        """ShapeDtypeStruct stand-ins for jit lowering (no allocation)."""
        cfg = self.cfg
        info = SHAPES[shape]
        B = batch_override or info["global_batch"]
        S = info["seq_len"]
        i32 = jnp.int32
        if info["kind"] == "train":
            if cfg.frontend == "vision":
                return {
                    "patches": jax.ShapeDtypeStruct(
                        (B, cfg.num_patches, cfg.d_model), jnp.bfloat16
                    ),
                    "inputs": jax.ShapeDtypeStruct((B, S), i32),
                    "targets": jax.ShapeDtypeStruct((B, S), i32),
                }
            if cfg.frontend == "audio":
                K = cfg.audio_codebooks
                return {
                    "inputs": jax.ShapeDtypeStruct((B, S, K), i32),
                    "targets": jax.ShapeDtypeStruct((B, S, K), i32),
                }
            return {
                "inputs": jax.ShapeDtypeStruct((B, S), i32),
                "targets": jax.ShapeDtypeStruct((B, S), i32),
            }
        if info["kind"] == "prefill":
            if cfg.frontend == "vision":
                return {
                    "patches": jax.ShapeDtypeStruct(
                        (B, cfg.num_patches, cfg.d_model), jnp.bfloat16
                    ),
                    "inputs": jax.ShapeDtypeStruct((B, S - cfg.num_patches), i32),
                }
            if cfg.frontend == "audio":
                return {
                    "inputs": jax.ShapeDtypeStruct((B, S, cfg.audio_codebooks), i32)
                }
            return {"inputs": jax.ShapeDtypeStruct((B, S), i32)}
        # decode: one new token against a cache of size S
        if cfg.frontend == "audio":
            tok = jax.ShapeDtypeStruct((B, cfg.audio_codebooks), i32)
        else:
            tok = jax.ShapeDtypeStruct((B,), i32)
        cache = jax.eval_shape(lambda: self.init_cache(B, S))
        return {"tokens": tok, "cache": cache}


# ---------------------------------------------------------------------------
# family builders
# ---------------------------------------------------------------------------

def _lm_table(cfg: ModelConfig, params: dict) -> jax.Array:
    return params["embed"] if cfg.tie_embeddings else params["lm_head"]


def _hidden_xent(cfg: ModelConfig, params, hidden, targets):
    if cfg.ce_chunk_tokens:
        return chunked_lm_xent(
            hidden, _lm_table(cfg, params), targets,
            chunk_tokens=cfg.ce_chunk_tokens, batch_axes=cfg.batch_axes,
        )
    logits = tfm.lm_logits(cfg, params, hidden)
    return softmax_xent(logits, targets)


def _build_dense_or_moe(cfg: ModelConfig) -> Model:
    def loss(params, batch):
        x = tfm.embed_tokens(cfg, params, batch["inputs"])
        h, _, aux = tfm.forward(cfg, params, x)
        l, ce = _hidden_xent(cfg, params, h, batch["targets"])
        return l + aux, {"loss": l, "ce": ce, "aux": aux}

    return Model(
        cfg=cfg,
        init=lambda key: tfm.init_params(cfg, key),
        loss=loss,
        forward=lambda params, batch: tfm.lm_forward(cfg, params, batch["inputs"])[0],
        prefill=lambda params, batch, cache_len: tfm.prefill(
            cfg, params, batch["inputs"], cache_len
        ),
        decode=lambda params, cache, tokens: tfm.decode_step(cfg, params, cache, tokens),
        init_cache=lambda batch, cache_len: tfm.init_cache(cfg, batch, cache_len),
    )


def _build_ssm_or_hybrid(cfg: ModelConfig) -> Model:
    def loss(params, batch):
        h, aux = hyb.hidden_forward(cfg, params, batch["inputs"])
        if cfg.ce_chunk_tokens:
            l, ce = chunked_lm_xent(
                h, params["embed"], batch["targets"],
                chunk_tokens=cfg.ce_chunk_tokens, batch_axes=cfg.batch_axes,
            )
        else:
            from repro.models.layers import logits_from_embed

            l, ce = softmax_xent(
                logits_from_embed(params["embed"], h), batch["targets"]
            )
        return l + aux, {"loss": l, "ce": ce, "aux": aux}

    return Model(
        cfg=cfg,
        init=lambda key: hyb.init_params(cfg, key),
        loss=loss,
        forward=lambda params, batch: hyb.lm_forward(cfg, params, batch["inputs"])[0],
        prefill=lambda params, batch, cache_len: hyb.prefill(
            cfg, params, batch["inputs"], cache_len
        ),
        decode=lambda params, cache, tokens: hyb.decode_step(cfg, params, cache, tokens),
        init_cache=lambda batch, cache_len: hyb.init_cache(cfg, batch, cache_len),
    )


def _build_vlm(cfg: ModelConfig) -> Model:
    def loss(params, batch):
        h, aux = mm.vlm_hidden(cfg, params, batch["patches"], batch["inputs"])
        l, ce = _hidden_xent(cfg, params, h, batch["targets"])
        return l + aux, {"loss": l, "ce": ce, "aux": aux}

    return Model(
        cfg=cfg,
        init=lambda key: mm.vlm_init(cfg, key),
        loss=loss,
        forward=lambda params, batch: mm.vlm_forward(
            cfg, params, batch["patches"], batch["inputs"]
        )[0],
        prefill=lambda params, batch, cache_len: mm.vlm_prefill(
            cfg, params, batch["patches"], batch["inputs"], cache_len
        ),
        decode=lambda params, cache, tokens: mm.vlm_decode_step(
            cfg, params, cache, tokens
        ),
        init_cache=lambda batch, cache_len: tfm.init_cache(cfg, batch, cache_len),
    )


def _build_audio(cfg: ModelConfig) -> Model:
    def loss(params, batch):
        h, aux = mm.audio_hidden(cfg, params, batch["inputs"])
        if cfg.ce_chunk_tokens:
            K = cfg.audio_codebooks
            ls, ces = [], []
            for k in range(K):
                lk, cek = chunked_lm_xent(
                    h, params["codebook_head"][k], batch["targets"][..., k],
                    chunk_tokens=cfg.ce_chunk_tokens, batch_axes=cfg.batch_axes,
                )
                ls.append(lk)
                ces.append(cek)
            l, ce = sum(ls) / K, sum(ces) / K
        else:
            logits = mm._audio_logits(cfg, params, h)
            l, ce = softmax_xent(logits, batch["targets"])
        return l + aux, {"loss": l, "ce": ce, "aux": aux}

    return Model(
        cfg=cfg,
        init=lambda key: mm.audio_init(cfg, key),
        loss=loss,
        forward=lambda params, batch: mm.audio_forward(cfg, params, batch["inputs"])[0],
        prefill=lambda params, batch, cache_len: mm.audio_prefill(
            cfg, params, batch["inputs"], cache_len
        ),
        decode=lambda params, cache, tokens: mm.audio_decode_step(
            cfg, params, cache, tokens
        ),
        init_cache=lambda batch, cache_len: tfm.init_cache(cfg, batch, cache_len),
    )


def build(cfg: ModelConfig) -> Model:
    if cfg.frontend == "vision":
        return _build_vlm(cfg)
    if cfg.frontend == "audio":
        return _build_audio(cfg)
    if cfg.family in ("ssm", "hybrid"):
        return _build_ssm_or_hybrid(cfg)
    if cfg.family in ("dense", "moe"):
        return _build_dense_or_moe(cfg)
    raise ValueError(f"unknown family {cfg.family}")
