"""Unified observability fabric — tracing, metrics, journal, leak audit.

One subsystem spanning every process in the CRUM stack:

* :mod:`repro.obs.trace` — per-process Chrome ``trace_event`` shards
  with correlation IDs (run, step, epoch, incarnation); disabled by
  default with a zero-allocation no-op path.
* :mod:`repro.obs.metrics` — one registry absorbing the scattered layer
  stats (PagingStats, transport wire counters, checkpoint phases,
  restart budgets) under one snake_case naming scheme.
* :mod:`repro.obs.journal` — the versioned, typed CLUSTER_LOG.jsonl
  schema.
* :mod:`repro.obs.leakcheck` — fd + /dev/shm growth audit for soak runs.
* :mod:`repro.obs.report` — ``python -m repro.obs.report <run_dir>``
  merges everything into one Perfetto-loadable trace + summary table.

Enable with ``--obs-dir`` on ``launch/train`` / ``launch/cluster`` (or
``CRUM_OBS_DIR`` in the environment, which is how child processes
inherit it).
"""
from repro.obs import trace
from repro.obs.metrics import REGISTRY

__all__ = ["trace", "REGISTRY"]
