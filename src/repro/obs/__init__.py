"""Unified observability fabric — tracing, metrics, journal, leak audit.

One subsystem spanning every process in the CRUM stack:

* :mod:`repro.obs.trace` — per-process Chrome ``trace_event`` shards
  with correlation IDs (run, step, epoch, incarnation); disabled by
  default with a zero-allocation no-op path.
* :mod:`repro.obs.metrics` — one registry absorbing the scattered layer
  stats (PagingStats, transport wire counters, checkpoint phases,
  restart budgets) under one snake_case naming scheme.
* :mod:`repro.obs.journal` — the versioned, typed CLUSTER_LOG.jsonl
  schema.
* :mod:`repro.obs.leakcheck` — fd + /dev/shm growth audit for soak runs,
  plus the light periodic :func:`~repro.obs.leakcheck.sample` /
  :class:`~repro.obs.leakcheck.PeriodicAudit` the live watchdog uses.
* :mod:`repro.obs.report` — ``python -m repro.obs.report <run_dir>``
  merges everything into one Perfetto-loadable trace + summary table.

The *live* half (streaming, while the run runs):

* :mod:`repro.obs.live` — worker registry deltas piggybacked on
  HEARTBEAT frames; the coordinator aggregates them into a bounded
  in-memory time-series store served over its TCP listener and
  snapshotted to ``live_metrics.json``.
* :mod:`repro.obs.watch` — the SLO watchdog: rules per heartbeat/round
  (stall ratio, skew, abort rate, stragglers, leak trends, digest
  divergence) emitting versioned ``alert`` journal records.
* :mod:`repro.obs.top` — ``python -m repro.obs.top`` terminal dashboard
  over a live coordinator endpoint or a finished run dir.
* :mod:`repro.obs.baseline` — diff fresh bench rows against the
  committed ``BENCH_results.json`` (``benchmarks.run --compare``);
  ``BENCH_history.jsonl`` keeps the trajectory in-repo.

Enable with ``--obs-dir`` on ``launch/train`` / ``launch/cluster`` (or
``CRUM_OBS_DIR`` in the environment, which is how child processes
inherit it).
"""
from repro.obs import trace
from repro.obs.metrics import REGISTRY

__all__ = ["trace", "REGISTRY"]
