"""Perf baselines in-repo: diff fresh bench rows against committed ones.

``BENCH_results.json`` at the repo root is the committed baseline — the
numbers the current code is *supposed* to produce. This module turns it
into a regression oracle:

* :func:`compare` diffs a fresh ``benchmarks.run --json`` row set
  against the baseline row set and returns findings in two classes:

  - **hard** — a correctness boolean the baseline had True came back
    False (or vanished): ``within_paper_envelope``, ``bit_identical``,
    ``boundary_scan_gone``, ``boundary_bit_identical``,
    ``blocking_below_sync``. These are never jitter.
  - **perf** — ``us_per_call`` grew beyond ``ratio``× the baseline
    (default 3× — wide enough that a CI runner vs the baseline machine
    never false-positives, tight enough that a real 4× regression is
    caught deterministically). Sub-``min_us`` rows are skipped: a 0.2µs
    hook timing is all noise.

* :func:`append_history` keeps ``BENCH_history.jsonl`` — one line per
  compared run, so the perf trajectory across commits is a file in the
  repo, not a dashboard somewhere else.

Wired into ``benchmarks.run --compare`` (fresh run vs baseline, exit 1
on findings) and ``benchmarks.gate --baseline`` (envelope checks *plus*
baseline diff in one gate).
"""
from __future__ import annotations

import argparse
import json
import os
import sys

BASELINE_SCHEMA = "crum-bench-compare/1"

#: booleans where baseline True -> fresh False/missing is a hard failure
HARD_BOOL_KEYS = (
    "within_paper_envelope",
    "bit_identical",
    "boundary_scan_gone",
    "boundary_bit_identical",
    "blocking_below_sync",
)

DEFAULT_RATIO = 3.0
DEFAULT_MIN_US = 5.0

__all__ = [
    "BASELINE_SCHEMA",
    "HARD_BOOL_KEYS",
    "DEFAULT_RATIO",
    "load_rows",
    "compare",
    "append_history",
]


def load_rows(path: str) -> tuple[dict, list[dict]]:
    """A ``crum-bench-rows/1`` dump (or bare row list) -> (doc, rows)."""
    with open(path) as f:
        doc = json.load(f)
    if isinstance(doc, list):
        return {"rows": doc}, doc
    rows = doc.get("rows")
    if not isinstance(rows, list):
        raise ValueError(f"{path}: no 'rows' array")
    return doc, [r for r in rows if isinstance(r, dict) and "name" in r]


def _by_name(rows: list[dict]) -> dict[str, dict]:
    return {str(r["name"]): r for r in rows if "name" in r}


def compare(
    fresh_rows: list[dict],
    base_rows: list[dict],
    *,
    ratio: float = DEFAULT_RATIO,
    min_us: float = DEFAULT_MIN_US,
    check_missing: bool = True,
) -> list[dict]:
    """Findings (empty = fresh run is no worse than the baseline).

    Each finding: ``{kind, name, message}`` plus kind-specific fields.
    ``check_missing=False`` skips the missing-row class — for partial
    runs that only exercised a subset of the benchmarks.
    """
    fresh = _by_name(fresh_rows)
    base = _by_name(base_rows)
    findings: list[dict] = []

    if check_missing:
        for name in sorted(set(base) - set(fresh)):
            findings.append({
                "kind": "missing_row", "name": name,
                "message": f"baseline row {name!r} absent from fresh run",
            })

    for name, b in sorted(base.items()):
        f = fresh.get(name)
        if f is None:
            continue
        for key in HARD_BOOL_KEYS:
            if b.get(key) is True and not f.get(key):
                findings.append({
                    "kind": "hard_flip", "name": name, "key": key,
                    "message": f"{name}: {key} flipped True -> "
                               f"{f.get(key)!r}",
                })
        bu, fu = b.get("us_per_call"), f.get("us_per_call")
        if (
            isinstance(bu, (int, float)) and isinstance(fu, (int, float))
            and max(bu, fu) >= min_us and bu > 0 and fu > bu * ratio
        ):
            findings.append({
                "kind": "perf_regression", "name": name,
                "base_us": bu, "fresh_us": fu,
                "ratio": round(fu / bu, 2), "limit": ratio,
                "message": f"{name}: us_per_call {fu} is "
                           f"{fu / bu:.1f}x the baseline {bu} "
                           f"(limit {ratio}x)",
            })
    return findings


def append_history(
    path: str,
    fresh_doc: dict,
    findings: list[dict],
    *,
    baseline_rev: str | None = None,
) -> None:
    """One JSONL line per compared run — the in-repo perf trajectory."""
    line = {
        "schema": BASELINE_SCHEMA,
        "timestamp": fresh_doc.get("timestamp"),
        "git_rev": fresh_doc.get("git_rev"),
        "baseline_rev": baseline_rev,
        "n_rows": len(fresh_doc.get("rows") or []),
        "failed_benchmarks": fresh_doc.get("failed") or [],
        "n_findings": len(findings),
        "finding_kinds": sorted({f.get("kind", "") for f in findings}),
        "findings": findings,
        # the headline numbers worth a trend line at a glance
        "headline": {
            r["name"]: r.get("us_per_call")
            for r in (fresh_doc.get("rows") or [])
            if isinstance(r, dict) and r.get("name") in (
                "fig4_proxy_overhead_pipelined_kernelish_2ms_step",
                "fig4_runtime_overhead",
                "obs_noop_hook",
            )
        },
    }
    with open(path, "a") as f:
        f.write(json.dumps(line, default=str) + "\n")


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(
        description=__doc__,
        formatter_class=argparse.RawDescriptionHelpFormatter,
    )
    ap.add_argument("fresh", help="fresh benchmarks.run --json dump")
    ap.add_argument("--baseline", default="BENCH_results.json",
                    help="committed baseline dump (default: "
                         "BENCH_results.json)")
    ap.add_argument("--ratio", type=float, default=DEFAULT_RATIO)
    ap.add_argument("--history", metavar="FILE", default=None,
                    help="append one trajectory line to this JSONL")
    ap.add_argument("--allow-missing", action="store_true",
                    help="partial run: skip the missing-row findings")
    args = ap.parse_args(argv)

    if not os.path.exists(args.baseline):
        print(f"[baseline] no baseline at {args.baseline}; nothing to "
              f"compare", file=sys.stderr)
        return 0
    fresh_doc, fresh_rows = load_rows(args.fresh)
    base_doc, base_rows = load_rows(args.baseline)
    findings = compare(
        fresh_rows, base_rows, ratio=args.ratio,
        check_missing=not args.allow_missing,
    )
    for f in findings:
        print(f"[baseline] FAIL: {f['message']}", file=sys.stderr)
    if args.history:
        append_history(args.history, fresh_doc, findings,
                       baseline_rev=base_doc.get("git_rev"))
    if not findings:
        print(f"[baseline] {len(fresh_rows)} rows vs "
              f"{len(base_rows)} baseline rows: no regressions")
    return 1 if findings else 0


if __name__ == "__main__":
    sys.exit(main())
