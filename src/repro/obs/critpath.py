"""Per-round causal trees, critical path, and latency attribution.

    PYTHONPATH=src python -m repro.obs.critpath <run_dir>
    PYTHONPATH=src python -m repro.obs.critpath <run_dir> --check   # CI

Spans traced with a causal context (``trace``/``span``/``parent`` args —
see :mod:`repro.obs.trace`) are stitched here into one tree per
checkpoint round: the coordinator's ``coord.round`` span is the
deterministic root (``root_span_id("round:<step>")``), every worker's
``worker.round`` hangs off it, and proxy/persist/commit spans hang off
those. Over each *committed* round this module computes:

* the **critical path** — from the round root, repeatedly descend into
  the child subtree that finishes last; the spans on that walk are what
  actually bounded the round's latency, and ``critical_host`` names the
  host that held the round open,
* a **phase decomposition** — the round window is swept into pinned
  buckets (step compute, sync, sync stall, wire/codec, phase-1
  snapshot, persist, commit quorum) plus a ``wait`` residual, both as a
  union across hosts (sums to the round span by construction) and per
  host; ``--check`` asserts the round span agrees with the journaled
  ``round_s`` within 5 %,
* **orphan subtrees** — spans whose parent chain dead-ends in a missing
  id. A SIGKILLed process leaves exactly this signature (its children's
  frames landed, its own span never closed), so orphans are reported,
  and fail ``--check`` only when the journal recorded no deaths.

The JSON report (``--json FILE``) is versioned ``crum-critpath/1``.
:func:`flow_events` additionally renders every resolved parent→child
edge as Perfetto flow events (``s``/``f``); ``repro.obs.report``
stitches them into the merged trace so the causal arrows show up in the
Perfetto UI.
"""
from __future__ import annotations

import argparse
import json
import os
import sys

from repro.obs.journal import read_journal
from repro.obs.report import find_journal, load_shards
from repro.obs.trace import root_span_id, round_trace_id

CRITPATH_SCHEMA = "crum-critpath/1"

# round-latency buckets, most-specific first: when intervals overlap
# (ckpt.persist runs inside worker.round, proxy.wire inside proxy.sync)
# the sweep charges the sub-interval to the lowest-ranked active bucket
_PHASE_RANK: list[tuple[str, tuple[str, ...]]] = [
    ("commit", ("coord.commit",)),
    ("persist", ("ckpt.persist",)),
    ("phase1", ("ckpt.phase1",)),
    ("wire_codec", ("proxy.wire",)),
    ("sync_stall", ("app.sync_stall",)),
    ("sync", ("proxy.sync",)),
    ("step_compute", ("proxy.step", "app.step")),
]
_BUCKET_OF = {name: i for i, (_, names) in enumerate(_PHASE_RANK)
              for name in names}

# tolerance for the span-vs-journal agreement check: 5 % relative, with
# a 2 ms absolute floor so sub-millisecond rounds don't flap on jitter
CHECK_REL = 0.05
CHECK_ABS_S = 0.002

__all__ = [
    "CRITPATH_SCHEMA",
    "build_spans",
    "flow_events",
    "analyze",
    "main",
]


# -- span reconstruction ----------------------------------------------------


def build_spans(events: list[dict]) -> list[dict]:
    """Events → span dicts with causal identity.

    X events and matched B/E pairs become closed spans; an unclosed B
    (SIGKILL mid-span) becomes an open-ended span marked
    ``incomplete``; instants that carry a causal context become
    zero-duration nodes so acks/registrations appear in the tree.
    """
    spans: list[dict] = []
    open_b: dict[tuple, list[dict]] = {}

    def mk(ev: dict, end, args: dict, incomplete: bool = False) -> dict:
        args = args if isinstance(args, dict) else {}
        ts = float(ev.get("ts", 0))
        return {
            "name": ev.get("name", "?"),
            "pid": ev.get("pid"),
            "tid": ev.get("tid"),
            "shard": ev.get("_shard"),
            "ts": ts,
            "end": float(end) if end is not None else None,
            "args": args,
            "trace": args.get("trace"),
            "span": args.get("span"),
            "parent": args.get("parent"),
            "incomplete": incomplete,
        }

    for ev in sorted(events, key=lambda e: e.get("ts", 0)):
        ph = ev.get("ph")
        key = (ev.get("pid"), ev.get("tid"))
        if ph == "X":
            spans.append(mk(ev, float(ev.get("ts", 0)) +
                            float(ev.get("dur", 0)), ev.get("args") or {}))
        elif ph == "B":
            open_b.setdefault(key, []).append(ev)
        elif ph == "E":
            stack = open_b.get(key)
            if stack:
                b = stack.pop()
                args = {**(b.get("args") or {}), **(ev.get("args") or {})}
                spans.append(mk(b, ev.get("ts", 0), args))
        elif ph in ("i", "I"):
            args = ev.get("args") or {}
            if isinstance(args, dict) and args.get("span") is not None:
                spans.append(mk(ev, ev.get("ts", 0), args))
    for stack in open_b.values():
        for b in stack:  # process died inside the span: open-ended
            spans.append(mk(b, None, b.get("args") or {}, incomplete=True))
    return spans


def _traces(spans: list[dict]) -> dict[str, list[dict]]:
    out: dict[str, list[dict]] = {}
    for s in spans:
        if s["trace"] is not None and s["span"] is not None:
            out.setdefault(s["trace"], []).append(s)
    return out


def _resolves(span: dict, parent_of: dict, ids: set) -> bool:
    """Does the parent chain reach a root without a missing link/cycle?"""
    cur, seen = span.get("parent"), set()
    while cur is not None:
        if cur in seen or cur not in ids:
            return False
        seen.add(cur)
        cur = parent_of.get(cur)
    return True


def _host_of(span: dict, by_id: dict) -> str:
    """Host attribution: coordinator spans are "coord"; everything else
    inherits the ``host`` arg from the nearest ancestor that has one
    (``worker.round`` carries it), falling back to the source shard."""
    if str(span["name"]).startswith("coord."):
        return "coord"
    cur, seen = span, set()
    while cur is not None:
        h = cur["args"].get("host")
        if h is not None:
            return str(h)
        p = cur.get("parent")
        if p is None or p in seen:
            break
        seen.add(p)
        cur = by_id.get(p)
    return str(span.get("shard") or "?")


# -- phase decomposition ----------------------------------------------------


def _sweep(intervals: list[tuple[int, float, float]],
           t0: float, t1: float) -> dict[str, float]:
    """Charge every sub-interval of [t0, t1] to the lowest-ranked active
    bucket (``wait`` when none is active). Sums to t1−t0 exactly."""
    pts = {t0, t1}
    clipped = []
    for rank, s, e in intervals:
        s, e = max(s, t0), min(e, t1)
        if e > s:
            clipped.append((rank, s, e))
            pts.update((s, e))
    order = sorted(pts)
    out = {name: 0.0 for name, _ in _PHASE_RANK}
    out["wait"] = 0.0
    for a, b in zip(order, order[1:]):
        active = [r for r, s, e in clipped if s <= a and e >= b]
        out[_PHASE_RANK[min(active)][0] if active else "wait"] += b - a
    return out


def _phase_intervals(spans: list[dict]) -> list[tuple[int, float, float, str]]:
    out = []
    for s in spans:
        rank = _BUCKET_OF.get(s["name"])
        if rank is None or s["end"] is None:
            continue
        out.append((rank, s["ts"], s["end"], s.get("_host", "?")))
    return out


def _critical_path(root: dict, children: dict, by_id: dict) -> list[dict]:
    """Greedy descent into the child that finishes last."""
    path: list[dict] = []
    cur, seen = root, set()
    while cur is not None and id(cur) not in seen:
        seen.add(id(cur))
        end = cur["end"] if cur["end"] is not None else cur["ts"]
        path.append({
            "name": cur["name"],
            "host": cur.get("_host", "?"),
            "ts_us": round(cur["ts"], 1),
            "dur_us": round(end - cur["ts"], 1),
            "incomplete": cur["incomplete"],
        })
        kids = children.get(cur["span"]) or []
        kids = [k for k in kids if id(k) not in seen]
        cur = max(
            kids,
            key=lambda k: k["end"] if k["end"] is not None else k["ts"],
            default=None,
        )
    return path


# -- the report -------------------------------------------------------------


def analyze(run_dir: str, journal: str | None = None) -> dict:
    """The full ``crum-critpath/1`` document for a run dir."""
    events, _ = load_shards(run_dir)
    spans = build_spans(events)
    traces = _traces(spans)
    jpath = find_journal(run_dir, journal)
    round_lines = []
    deaths = 0
    if jpath:
        for rec in read_journal(jpath):
            if rec.event == "round":
                round_lines.append(rec)
            elif rec.event == "death":
                deaths += 1

    rounds: list[dict] = []
    claimed: set[str] = set()
    for rl in round_lines:
        trace_id = round_trace_id(rl.step)
        claimed.add(trace_id)
        if rl.status != "committed":
            rounds.append({"step": rl.step, "status": rl.status,
                           "trace": trace_id})
            continue
        tspans = traces.get(trace_id, [])
        ids = {s["span"] for s in tspans}
        parent_of = {s["span"]: s.get("parent") for s in tspans}
        by_id: dict = {}
        for s in tspans:
            by_id.setdefault(s["span"], s)
        for s in tspans:
            s["_host"] = _host_of(s, by_id)
        root_id = root_span_id(trace_id)
        # a retried round opens one coord.round per attempt, all with the
        # same deterministic root id: the committed attempt is the one
        # whose window contains the journal line's commit timestamp
        t_us = rl.t * 1e6
        attempts = [s for s in tspans
                    if s["name"] == "coord.round" and s["span"] == root_id]
        attempt = None
        containing = [a for a in attempts if a["end"] is not None
                      and a["ts"] <= t_us <= a["end"]]
        if containing:
            attempt = containing[0]
        elif attempts:
            attempt = min(
                attempts,
                key=lambda a: abs((a["end"] if a["end"] is not None
                                   else a["ts"]) - t_us),
            )
        orphans = [s for s in tspans if not _resolves(s, parent_of, ids)]
        entry: dict = {
            "step": rl.step,
            "status": "committed",
            "trace": trace_id,
            "rooted": attempt is not None,
            "n_spans": len(tspans),
            "orphan_spans": len(orphans),
            "round_s": rl.round_s,
        }
        if attempt is not None and attempt["end"] is not None:
            t0, t1 = attempt["ts"], attempt["end"]
            entry["span_s"] = round((t1 - t0) / 1e6, 6)
            ivals = _phase_intervals(tspans)
            entry["phases_us"] = {
                k: round(v, 1)
                for k, v in _sweep([(r, s, e) for r, s, e, _ in ivals],
                                   t0, t1).items()
            }
            hosts = sorted({h for _, _, _, h in ivals})
            entry["per_host_us"] = {
                h: {k: round(v, 1)
                    for k, v in _sweep(
                        [(r, s, e) for r, s, e, hh in ivals if hh == h],
                        t0, t1).items() if k != "wait" and v > 0}
                for h in hosts
            }
            children: dict = {}
            for s in tspans:
                if s.get("parent") is not None:
                    children.setdefault(s["parent"], []).append(s)
            cp = _critical_path(attempt, children, by_id)
            entry["critical_path"] = cp
            entry["critical_host"] = cp[-1]["host"] if cp else None
        rounds.append(entry)

    # traces the journal never claimed: trailing windows (steps past the
    # last boundary) and rounds a killed coordinator never journaled
    stray = []
    for trace_id in sorted(set(traces) - claimed):
        tspans = traces[trace_id]
        ids = {s["span"] for s in tspans}
        parent_of = {s["span"]: s.get("parent") for s in tspans}
        n_orphans = sum(1 for s in tspans
                        if not _resolves(s, parent_of, ids))
        stray.append({"trace": trace_id, "n_spans": len(tspans),
                      "orphan_spans": n_orphans})

    return {
        "schema": CRITPATH_SCHEMA,
        "run_dir": run_dir,
        "journal": jpath,
        "deaths": deaths,
        "rounds": rounds,
        "orphans": stray,
    }


def check(doc: dict) -> list[str]:
    """--check rules; empty list = green."""
    problems: list[str] = []
    committed = [r for r in doc["rounds"] if r["status"] == "committed"]
    for r in committed:
        step = r["step"]
        if not r.get("rooted"):
            problems.append(
                f"round {step}: committed but no coord.round root span"
            )
            continue
        span_s, round_s = r.get("span_s"), r.get("round_s")
        if span_s is None:
            problems.append(f"round {step}: root span never closed")
        elif round_s and abs(span_s - round_s) > max(
            CHECK_REL * round_s, CHECK_ABS_S
        ):
            problems.append(
                f"round {step}: span {span_s:.4f}s vs journal "
                f"{round_s:.4f}s (> {CHECK_REL:.0%} apart)"
            )
        if r.get("orphan_spans") and not doc.get("deaths"):
            # orphans are the expected residue of kill drills; with no
            # journaled deaths they mean the propagation chain broke
            problems.append(
                f"round {step}: {r['orphan_spans']} orphan span(s) with "
                f"no journaled deaths"
            )
    return problems


# -- Perfetto flow stitching ------------------------------------------------


def flow_events(events: list[dict]) -> list[dict]:
    """Every resolved parent→child edge as an ``s``/``f`` flow pair, so
    the merged trace draws the causal arrows across processes."""
    spans = build_spans(events)
    by_id: dict = {}
    for s in spans:
        if s["span"] is not None:
            by_id.setdefault(s["span"], s)
    out: list[dict] = []
    for s in spans:
        p = s.get("parent")
        if s["span"] is None or p is None:
            continue
        parent = by_id.get(p)
        if parent is None or parent["pid"] is None or s["pid"] is None:
            continue  # orphan edge: nothing to draw to
        fid = format(int(s["span"]), "x")
        out.append({"name": "causal", "cat": "causal", "ph": "s",
                    "id": fid, "pid": parent["pid"],
                    "tid": parent["tid"], "ts": parent["ts"]})
        out.append({"name": "causal", "cat": "causal", "ph": "f",
                    "bp": "e", "id": fid, "pid": s["pid"],
                    "tid": s["tid"], "ts": s["ts"]})
    return out


# -- entry point ------------------------------------------------------------


def _fmt_round(r: dict) -> str:
    if r["status"] != "committed":
        return f"  round {r['step']:<6} {r['status']}"
    if "span_s" not in r:
        return (f"  round {r['step']:<6} committed  UNROOTED "
                f"({r.get('n_spans', 0)} spans)")
    phases = r.get("phases_us", {})
    top = sorted(phases.items(), key=lambda kv: -kv[1])[:3]
    top_s = " ".join(f"{k}={v / 1e3:.1f}ms" for k, v in top if v > 0)
    return (
        f"  round {r['step']:<6} committed  span={r['span_s']:.3f}s "
        f"journal={r['round_s']:.3f}s  orphans={r['orphan_spans']}  "
        f"critical={r.get('critical_host')}  {top_s}"
    )


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(
        prog="python -m repro.obs.critpath", description=__doc__,
        formatter_class=argparse.RawDescriptionHelpFormatter,
    )
    ap.add_argument("run_dir", help="obs dir holding trace-*.jsonl shards")
    ap.add_argument("--journal", default=None,
                    help="explicit CLUSTER_LOG.jsonl path")
    ap.add_argument("--json", metavar="FILE", default=None,
                    help="write the crum-critpath/1 report as JSON")
    ap.add_argument("--check", action="store_true",
                    help="assert every committed round is rooted and its "
                         "phase sum agrees with the journal within 5%%")
    args = ap.parse_args(argv)

    if not os.path.isdir(args.run_dir):
        print(f"[critpath] no such run dir: {args.run_dir}",
              file=sys.stderr)
        return 2
    doc = analyze(args.run_dir, args.journal)
    committed = [r for r in doc["rounds"] if r["status"] == "committed"]
    print(f"[critpath] {len(doc['rounds'])} journaled round(s), "
          f"{len(committed)} committed, {len(doc['orphans'])} stray "
          f"trace(s), {doc['deaths']} death(s)")
    for r in doc["rounds"]:
        print(_fmt_round(r))
    for o in doc["orphans"]:
        print(f"  stray {o['trace']:<12} {o['n_spans']} span(s), "
              f"{o['orphan_spans']} orphaned")
    if args.json:
        with open(args.json, "w") as f:
            json.dump(doc, f, indent=2, default=str)
        print(f"[critpath] wrote {args.json}")
    if args.check:
        problems = check(doc)
        if problems:
            for p in problems:
                print(f"[critpath] FAILED: {p}", file=sys.stderr)
            return 1
        print(f"[critpath] check OK ({len(committed)} committed round(s))")
    return 0


if __name__ == "__main__":
    sys.exit(main())
