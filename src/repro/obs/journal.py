"""Versioned, typed CLUSTER_LOG.jsonl — the coordinator's journal schema.

The coordinator's append-only journal used to be raw ``json.dumps``
lines with ad-hoc shapes; consumers (restore, reschedule, tests,
post-mortems) each re-parsed them by hand. This module formalizes it:

* every line carries ``schema: "crum-cluster-log/1"`` plus ``event`` and
  ``t`` (wall-clock seconds),
* :class:`JournalWriter` is the single write path (thread-safe, one
  flushed line per record — same torn-tail tolerance as before),
* :func:`read_journal` parses lines back into typed records, one
  dataclass per event kind, tolerating torn tails and unknown kinds
  (forward compatibility: new fields land in ``extra``).

Legacy schema-less lines parse fine — ``schema`` defaults to the v1
label, since v1 *is* the formalization of the legacy shape.
"""
from __future__ import annotations

import json
import os
import threading
import time
from dataclasses import dataclass, field, fields

JOURNAL_SCHEMA = "crum-cluster-log/1"

__all__ = [
    "JOURNAL_SCHEMA",
    "JournalWriter",
    "read_journal",
    "parse_record",
    "JournalRecord",
    "RoundLine",
    "JoinLine",
    "DeathLine",
    "FinishedLine",
    "ShutdownLine",
    "ProxyEndpointLine",
    "ProxyPlacementLine",
    "ProxyHostDeathLine",
    "AlertLine",
    "InjectLine",
    "alerts",
]


class JournalWriter:
    """Append-only journal writer; one ``os.write`` per line (atomic on
    O_APPEND), so concurrent writers never interleave and a SIGKILL tears
    at most the final line."""

    def __init__(self, path: str, *, schema: str = JOURNAL_SCHEMA):
        self.path = path
        self.schema = schema
        self._lock = threading.Lock()
        os.makedirs(os.path.dirname(path) or ".", exist_ok=True)
        self._fd = os.open(
            path, os.O_WRONLY | os.O_CREAT | os.O_APPEND, 0o644
        )

    def write(self, event: str, **fields) -> None:
        line = {
            "schema": self.schema,
            "event": event,
            "t": time.time(),
            **fields,
        }
        data = (json.dumps(line, default=str) + "\n").encode("utf-8")
        with self._lock:
            try:
                os.write(self._fd, data)
            except OSError:
                pass  # journaling must never take the coordinator down

    def close(self) -> None:
        with self._lock:
            fd, self._fd = self._fd, -1  # -1: EBADF on late writes, never
            try:                         # a reused fd belonging to someone else
                os.close(fd)
            except OSError:
                pass


# -- typed records ----------------------------------------------------------


@dataclass
class JournalRecord:
    event: str = ""
    t: float = 0.0
    schema: str = JOURNAL_SCHEMA
    extra: dict = field(default_factory=dict)


@dataclass
class RoundLine(JournalRecord):
    """One checkpoint round attempt — committed or aborted."""

    step: int = -1
    status: str = ""
    reason: str = ""
    participants: list = field(default_factory=list)
    acked: list = field(default_factory=list)
    stragglers: list = field(default_factory=list)
    commit_s: float = 0.0
    round_s: float = 0.0
    persist_s_max: float = 0.0
    bytes_written: int = 0
    chunks_synced: int = 0
    chunks_clean: int = 0
    bytes_skipped: int = 0
    sync_us: float = 0.0
    digest_us: float = 0.0
    fetch_us: float = 0.0
    stall_us: float = 0.0

    @property
    def committed(self) -> bool:
        return self.status == "committed"


@dataclass
class JoinLine(JournalRecord):
    host: int = -1
    pid: int | None = None
    restored_from: int | None = None
    latest_committed: int | None = None


@dataclass
class DeathLine(JournalRecord):
    host: int = -1
    reason: str = ""
    latest_committed: int | None = None


@dataclass
class FinishedLine(JournalRecord):
    host: int = -1
    step: int | None = None
    digest: str = ""


@dataclass
class ShutdownLine(JournalRecord):
    finished: list = field(default_factory=list)


@dataclass
class ProxyEndpointLine(JournalRecord):
    name: str = ""
    addr: str = ""
    port: int = 0


@dataclass
class ProxyPlacementLine(JournalRecord):
    worker: int = -1
    name: str = ""
    rescheduled: bool = False


@dataclass
class ProxyHostDeathLine(JournalRecord):
    name: str = ""
    worker: int = -1


@dataclass
class InjectLine(JournalRecord):
    """One planned fault injection (``crum-inject/1``, INJECT_LOG.jsonl).

    Written *before* the fault fires — the injection journal is the
    ground truth the soak verdict engine joins against the cluster
    journal: every injection must produce its expected evidence
    (``expect``), and every alert must be explained by some injection.
    """

    schema: str = "crum-inject/1"
    kind: str = ""
    target: str = ""
    seq: int = -1
    until: float | None = None
    params: dict = field(default_factory=dict)
    expect: dict = field(default_factory=dict)


@dataclass
class AlertLine(JournalRecord):
    """One SLO-watchdog rule violation (``repro.obs.watch.Alert``)."""

    kind: str = ""
    severity: str = ""
    host: int | None = None
    step: int | None = None
    value: float | None = None
    limit: float | None = None
    message: str = ""
    chunk: str | None = None
    chunk_index: int | None = None
    alert_schema: str = ""


RECORD_TYPES: dict[str, type[JournalRecord]] = {
    "round": RoundLine,
    "join": JoinLine,
    "death": DeathLine,
    "finished": FinishedLine,
    "shutdown": ShutdownLine,
    "proxy_endpoint": ProxyEndpointLine,
    "proxy_placement": ProxyPlacementLine,
    "proxy_host_death": ProxyHostDeathLine,
    "alert": AlertLine,
    "inject": InjectLine,
}


def parse_record(doc: dict) -> JournalRecord:
    """One journal line (already JSON-decoded) → typed record.

    Unknown event kinds fall back to the generic :class:`JournalRecord`;
    unknown fields of known kinds land in ``extra`` — readers of v1
    survive writers of v1.1.
    """
    cls = RECORD_TYPES.get(doc.get("event", ""), JournalRecord)
    known = {f.name for f in fields(cls)} - {"extra"}
    kw = {k: v for k, v in doc.items() if k in known}
    rec = cls(**kw)
    rec.extra = {k: v for k, v in doc.items() if k not in known}
    return rec


def read_journal(path: str) -> list[JournalRecord]:
    """Parse a CLUSTER_LOG.jsonl; skips torn/corrupt lines (SIGKILL tail)."""
    out: list[JournalRecord] = []
    try:
        f = open(path, encoding="utf-8", errors="replace")
    except OSError:
        return out
    with f:
        for line in f:
            line = line.strip()
            if not line:
                continue
            try:
                doc = json.loads(line)
            except ValueError:
                continue
            if isinstance(doc, dict):
                out.append(parse_record(doc))
    return out


def rounds(path: str) -> list[RoundLine]:
    return [r for r in read_journal(path) if isinstance(r, RoundLine)]


def alerts(path: str) -> list[AlertLine]:
    return [r for r in read_journal(path) if isinstance(r, AlertLine)]
