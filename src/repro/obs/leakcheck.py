"""fd / shared-memory-segment leak audit — the soak-run exit criterion.

The stack opens a lot of kernel objects per proxy incarnation: sockets,
MAP_SHARED segment fds, ``/dev/shm`` arenas, API-log fds, trace shards.
ROADMAP item 5's soak harness exits on "zero fd/segment leaks after an
N-minute run"; this helper is that check, reusable from any drill:

    with LeakCheck(tolerance=2) as lc:
        ... 20 kill/respawn cycles ...
    # raises AssertionError naming the leaked fds / segments

Snapshots are taken from ``/proc/self/fd`` (symlink targets, so the
report names *what* leaked, not just how many) and the ``/dev/shm``
listing. On platforms without ``/proc`` the check degrades to a no-op
rather than a false failure.

For *live* monitoring the before/after context manager is the wrong
shape — a watchdog wants a cheap point-in-time count plus a trend over a
window. :func:`sample` is that light snapshot (counts only, no symlink
resolution) and :class:`PeriodicAudit` the rate-limited window over it;
the SLO watchdog's leak-trend rule and long-running drills share them.
"""
from __future__ import annotations

import os
import time
from collections import deque
from typing import Callable

__all__ = ["ResourceSnapshot", "LeakCheck", "sample", "watchdog_sample",
           "PeriodicAudit"]

_FD_DIR = "/proc/self/fd"
_SHM_DIR = "/dev/shm"

# fd targets the observability stack itself owns (trace shards, metric
# snapshots, the cluster journal, live-metrics stream, merged report):
# the watchdog's leak-trend rule must not count these, or enabling obs
# on a long run trips the very alert it is there to power
_OBS_FD_BASENAMES = ("CLUSTER_LOG.jsonl", "merged.trace.json")
_OBS_FD_PREFIXES = ("trace-", "metrics-", "live_metrics.json")


def _is_obs_fd(target: str) -> bool:
    base = os.path.basename(target.split(" ", 1)[0])
    if base in _OBS_FD_BASENAMES:
        return True
    return any(base.startswith(p) for p in _OBS_FD_PREFIXES)


def sample(*, exclude_obs: bool = False) -> dict:
    """Point-in-time resource counts: ``{supported, fd, shm}``.

    Cheaper than :meth:`ResourceSnapshot.capture` (two listdirs, no
    readlink per fd) — safe to call on a periodic tick. ``supported`` is
    False on platforms without ``/proc`` (counts are then 0, and any
    consumer should treat the audit as a no-op rather than a leak).

    ``exclude_obs=True`` resolves each fd's symlink and drops the ones
    the observability plane itself holds open (trace shards, the
    journal, live-metrics files), reporting them separately as
    ``fd_obs``; the watchdog's fd-leak trend uses this so tracing a run
    does not read as a leak.
    """
    try:
        entries = os.listdir(_FD_DIR)
    except OSError:
        return {"supported": False, "fd": 0, "shm": 0}
    fd = len(entries)
    fd_obs = 0
    if exclude_obs:
        for entry in entries:
            try:
                target = os.readlink(f"{_FD_DIR}/{entry}")
            except OSError:
                continue  # the listdir fd itself / raced closes
            if _is_obs_fd(target):
                fd_obs += 1
        fd -= fd_obs
    try:
        shm = len(os.listdir(_SHM_DIR))
    except OSError:
        shm = 0
    out = {"supported": True, "fd": fd, "shm": shm}
    if exclude_obs:
        out["fd_obs"] = fd_obs
    return out


def watchdog_sample() -> dict:
    """The SLO watchdog's default sampler: obs-owned fds excluded."""
    return sample(exclude_obs=True)


class PeriodicAudit:
    """Rate-limited :func:`sample` window with a growth-trend readout.

    ``maybe_sample()`` takes at most one sample per ``interval_s`` and
    keeps the last ``window`` of them; ``trend(key)`` reports growth
    across the full window *only when it is monotonically non-shrinking*
    — a transient burst that is reclaimed reads as no trend, a steady
    climb (the actual leak signature) reads as its total growth.
    """

    def __init__(self, interval_s: float = 2.0, window: int = 5,
                 sampler: Callable[[], dict] | None = None):
        self.interval_s = float(interval_s)
        self.window = int(window)
        self.sampler = sampler or sample
        self.samples: deque = deque(maxlen=self.window)
        self._last_t: float | None = None

    def maybe_sample(self, now: float | None = None) -> dict | None:
        """One sample if the interval elapsed, else None."""
        now = time.monotonic() if now is None else now
        if self._last_t is not None and now - self._last_t < self.interval_s:
            return None
        self._last_t = now
        s = self.sampler()
        if s.get("supported"):
            self.samples.append(s)
        return s

    def trend(self, key: str) -> int | None:
        """Monotonic growth of ``key`` over the window; None until the
        window is full, 0 when any sample shrank (not a steady leak)."""
        if len(self.samples) < self.window:
            return None
        vals = [int(s.get(key, 0)) for s in self.samples]
        if any(b < a for a, b in zip(vals, vals[1:])):
            return 0
        return vals[-1] - vals[0]


class ResourceSnapshot:
    def __init__(self, fds: dict[int, str] | None, shm: set[str] | None):
        self.fds = fds
        self.shm = shm

    @classmethod
    def capture(cls) -> "ResourceSnapshot":
        fds: dict[int, str] | None = None
        try:
            fds = {}
            for entry in os.listdir(_FD_DIR):
                try:
                    fds[int(entry)] = os.readlink(f"{_FD_DIR}/{entry}")
                except OSError:
                    pass  # the listdir fd itself / raced closes
        except OSError:
            fds = None
        shm: set[str] | None = None
        try:
            shm = set(os.listdir(_SHM_DIR))
        except OSError:
            shm = None
        return cls(fds, shm)

    @property
    def supported(self) -> bool:
        return self.fds is not None


class LeakCheck:
    """Before/after resource audit; assert no growth at exit."""

    def __init__(self, tolerance: int = 0, shm_tolerance: int = 0):
        self.tolerance = tolerance
        self.shm_tolerance = shm_tolerance
        self.before: ResourceSnapshot | None = None
        self.after: ResourceSnapshot | None = None

    def start(self) -> "LeakCheck":
        self.before = ResourceSnapshot.capture()
        return self

    def stop(self) -> "LeakCheck":
        self.after = ResourceSnapshot.capture()
        return self

    def diff(self) -> dict:
        assert self.before is not None, "call start() first"
        if self.after is None:
            self.stop()
        b, a = self.before, self.after
        if not (b.supported and a.supported):
            return {"supported": False, "fd_growth": 0, "new_fds": [],
                    "shm_growth": 0, "new_shm": []}
        new_fds = sorted(
            f"{n} -> {tgt}"
            for n, tgt in a.fds.items()
            if n not in b.fds
        )
        new_shm = sorted((a.shm or set()) - (b.shm or set()))
        return {
            "supported": True,
            "fd_growth": len(a.fds) - len(b.fds),
            "new_fds": new_fds,
            "shm_growth": len(a.shm or ()) - len(b.shm or ()),
            "new_shm": new_shm,
        }

    def assert_no_growth(self, note: str = "") -> None:
        d = self.diff()
        if not d["supported"]:
            return
        prefix = f"[leakcheck{': ' + note if note else ''}] "
        assert d["fd_growth"] <= self.tolerance, (
            prefix + f"fd count grew by {d['fd_growth']} "
            f"(> tolerance {self.tolerance}); new fds: {d['new_fds']}"
        )
        assert d["shm_growth"] <= self.shm_tolerance, (
            prefix + f"/dev/shm grew by {d['shm_growth']} "
            f"(> tolerance {self.shm_tolerance}); new: {d['new_shm']}"
        )

    def __enter__(self) -> "LeakCheck":
        return self.start()

    def __exit__(self, exc_type, exc, tb) -> bool:
        self.stop()
        if exc_type is None:
            self.assert_no_growth()
        return False
