"""fd / shared-memory-segment leak audit — the soak-run exit criterion.

The stack opens a lot of kernel objects per proxy incarnation: sockets,
MAP_SHARED segment fds, ``/dev/shm`` arenas, API-log fds, trace shards.
ROADMAP item 5's soak harness exits on "zero fd/segment leaks after an
N-minute run"; this helper is that check, reusable from any drill:

    with LeakCheck(tolerance=2) as lc:
        ... 20 kill/respawn cycles ...
    # raises AssertionError naming the leaked fds / segments

Snapshots are taken from ``/proc/self/fd`` (symlink targets, so the
report names *what* leaked, not just how many) and the ``/dev/shm``
listing. On platforms without ``/proc`` the check degrades to a no-op
rather than a false failure.
"""
from __future__ import annotations

import os

__all__ = ["ResourceSnapshot", "LeakCheck"]

_FD_DIR = "/proc/self/fd"
_SHM_DIR = "/dev/shm"


class ResourceSnapshot:
    def __init__(self, fds: dict[int, str] | None, shm: set[str] | None):
        self.fds = fds
        self.shm = shm

    @classmethod
    def capture(cls) -> "ResourceSnapshot":
        fds: dict[int, str] | None = None
        try:
            fds = {}
            for entry in os.listdir(_FD_DIR):
                try:
                    fds[int(entry)] = os.readlink(f"{_FD_DIR}/{entry}")
                except OSError:
                    pass  # the listdir fd itself / raced closes
        except OSError:
            fds = None
        shm: set[str] | None = None
        try:
            shm = set(os.listdir(_SHM_DIR))
        except OSError:
            shm = None
        return cls(fds, shm)

    @property
    def supported(self) -> bool:
        return self.fds is not None


class LeakCheck:
    """Before/after resource audit; assert no growth at exit."""

    def __init__(self, tolerance: int = 0, shm_tolerance: int = 0):
        self.tolerance = tolerance
        self.shm_tolerance = shm_tolerance
        self.before: ResourceSnapshot | None = None
        self.after: ResourceSnapshot | None = None

    def start(self) -> "LeakCheck":
        self.before = ResourceSnapshot.capture()
        return self

    def stop(self) -> "LeakCheck":
        self.after = ResourceSnapshot.capture()
        return self

    def diff(self) -> dict:
        assert self.before is not None, "call start() first"
        if self.after is None:
            self.stop()
        b, a = self.before, self.after
        if not (b.supported and a.supported):
            return {"supported": False, "fd_growth": 0, "new_fds": [],
                    "shm_growth": 0, "new_shm": []}
        new_fds = sorted(
            f"{n} -> {tgt}"
            for n, tgt in a.fds.items()
            if n not in b.fds
        )
        new_shm = sorted((a.shm or set()) - (b.shm or set()))
        return {
            "supported": True,
            "fd_growth": len(a.fds) - len(b.fds),
            "new_fds": new_fds,
            "shm_growth": len(a.shm or ()) - len(b.shm or ()),
            "new_shm": new_shm,
        }

    def assert_no_growth(self, note: str = "") -> None:
        d = self.diff()
        if not d["supported"]:
            return
        prefix = f"[leakcheck{': ' + note if note else ''}] "
        assert d["fd_growth"] <= self.tolerance, (
            prefix + f"fd count grew by {d['fd_growth']} "
            f"(> tolerance {self.tolerance}); new fds: {d['new_fds']}"
        )
        assert d["shm_growth"] <= self.shm_tolerance, (
            prefix + f"/dev/shm grew by {d['shm_growth']} "
            f"(> tolerance {self.shm_tolerance}); new: {d['new_shm']}"
        )

    def __enter__(self) -> "LeakCheck":
        return self.start()

    def __exit__(self, exc_type, exc, tb) -> bool:
        self.stop()
        if exc_type is None:
            self.assert_no_growth()
        return False
