"""Live telemetry plane — streaming cluster metrics while the run runs.

PR 7's observability is post-hoc: trace shards and ``metrics-*.json``
dumps are only merged by ``repro.obs.report`` after the run ends. This
module is the *live* half:

* **Worker side** — :class:`HeartbeatPiggyback` computes the per-process
  :class:`~repro.obs.metrics.Registry` counter delta since the last
  heartbeat and rides it on the HEARTBEAT frame the worker already
  sends. Zero extra syscalls: the payload travels inside the same
  framed ``sendall`` as the heartbeat itself (``benchmarks/obs_overhead``
  pins the collect cost; a unit test pins the one-frame property).
* **Coordinator side** — :class:`LiveAggregator` folds those deltas into
  a bounded in-memory time-series store (:class:`SeriesStore`, one ring
  buffer per ``(host, metric)``), deduplicated by per-host sequence
  number so a re-delivered delta (heartbeat retry, re-JOIN replay) is
  idempotent. The aggregator snapshots periodically to the run dir
  (``live_metrics.json``) and is served over the coordinator's existing
  TCP listener (``METRICS`` side-channel frame) — ``repro.obs.top``
  renders either source.

Malformed payloads (a worker SIGKILLed mid-send tears the *frame*, which
the length-prefixed protocol already rejects; a buggy or hostile worker
could still send garbage *values*) must never poison the store or the
coordinator event loop: ``ingest`` validates every key and value and
drops what it cannot use.
"""
from __future__ import annotations

import json
import os
import threading
import time
from collections import deque

from repro.obs import metrics as obs_metrics

LIVE_SCHEMA = "crum-live-metrics/1"

#: hard caps keeping one misbehaving worker from ballooning coordinator
#: memory: metrics tracked per host, points kept per (host, metric)
MAX_METRICS_PER_HOST = 256
DEFAULT_RING = 240

#: tiered downsampling: every raw append also folds into one open bucket
#: per tier (seconds); completed buckets land in their own ring. The raw
#: ring covers the last ~2 minutes at heartbeat cadence; the 10s tier
#: covers ~40 minutes and the 60s tier ~4 hours — a soak run's whole
#: history stays in memory at bounded cost, and trend consumers
#: (``repro.obs.top``, the soak verdict's leak check) read the rollups
#: instead of a raw ring that has long since wrapped.
ROLLUP_TIERS = (10.0, 60.0)

#: piggyback payload budget — a HEARTBEAT frame stays a control frame.
#: Deltas beyond the key budget are *deferred*, not dropped: an uncounted
#: key stays out of the baseline snapshot, so its whole value rides the
#: next heartbeat's delta.
MAX_PIGGYBACK_KEYS = 96

__all__ = [
    "LIVE_SCHEMA",
    "SeriesStore",
    "HeartbeatPiggyback",
    "LiveAggregator",
]


def _is_num(v) -> bool:
    return isinstance(v, (int, float)) and not isinstance(v, bool)


class SeriesStore:
    """Bounded time-series: one ring buffer of (t, value) per (host, metric).

    Appends are O(1) and memory is hard-bounded: ``ring`` points per
    series, ``MAX_METRICS_PER_HOST`` series per host. All methods are
    thread-safe (the coordinator event loop appends while the METRICS
    side channel snapshots).
    """

    def __init__(self, ring: int = DEFAULT_RING,
                 rollups: tuple = ROLLUP_TIERS,
                 rollup_ring: int = DEFAULT_RING):
        self.ring = int(ring)
        self.rollups = tuple(float(r) for r in rollups)
        self.rollup_ring = int(rollup_ring)
        self._lock = threading.Lock()
        self._series: dict[tuple[int, str], deque] = {}
        # completed buckets per (host, metric, tier); each point is
        # [bucket_t, last, min, max, n] — last-value downsampling with a
        # min/max envelope, so a spike inside a bucket stays visible
        self._rolled: dict[tuple[int, str, float], deque] = {}
        # the in-progress bucket per (host, metric, tier):
        # [bucket_t, last, min, max, n]
        self._open: dict[tuple[int, str, float], list] = {}

    def append(self, host: int, metric: str, t: float, value: float) -> bool:
        key = (int(host), str(metric))
        t, value = float(t), float(value)
        with self._lock:
            q = self._series.get(key)
            if q is None:
                if sum(1 for h, _ in self._series if h == key[0]) \
                        >= MAX_METRICS_PER_HOST:
                    return False  # per-host series budget exhausted
                q = self._series[key] = deque(maxlen=self.ring)
            q.append((t, value))
            for tier in self.rollups:
                rkey = (key[0], key[1], tier)
                bucket = (t // tier) * tier
                cur = self._open.get(rkey)
                if cur is None or cur[0] != bucket:
                    if cur is not None:
                        rq = self._rolled.get(rkey)
                        if rq is None:
                            rq = self._rolled[rkey] = deque(
                                maxlen=self.rollup_ring
                            )
                        rq.append(cur)
                    self._open[rkey] = [bucket, value, value, value, 1]
                else:
                    cur[1] = value
                    cur[2] = min(cur[2], value)
                    cur[3] = max(cur[3], value)
                    cur[4] += 1
        return True

    def rollup(self, host: int, metric: str, tier: float
               ) -> list[list[float]]:
        """Completed buckets plus the provisional open one, oldest first.

        Each point is ``[bucket_t, last, min, max, n]``. The open bucket
        rides along so short runs (shorter than one tier) still expose a
        point — it is provisional: its values may still move until the
        bucket closes.
        """
        rkey = (int(host), str(metric), float(tier))
        with self._lock:
            out = [list(p) for p in self._rolled.get(rkey, ())]
            cur = self._open.get(rkey)
            if cur is not None:
                out.append(list(cur))
        return out

    def series(self, host: int, metric: str) -> list[tuple[float, float]]:
        with self._lock:
            return list(self._series.get((int(host), metric), ()))

    def latest(self, host: int, metric: str) -> float | None:
        with self._lock:
            q = self._series.get((int(host), metric))
            return q[-1][1] if q else None

    def hosts(self) -> list[int]:
        with self._lock:
            return sorted({h for h, _ in self._series})

    def metrics(self, host: int | None = None) -> list[str]:
        with self._lock:
            return sorted({
                m for h, m in self._series if host is None or h == host
            })

    def snapshot(self) -> dict:
        """The whole store as a JSON-ready dict (host keys stringified)."""
        with self._lock:
            out: dict[str, dict[str, list]] = {}
            for (h, m), q in self._series.items():
                out.setdefault(str(h), {})[m] = [
                    [round(t, 3), v] for t, v in q
                ]
        return out

    def rollup_snapshot(self) -> dict:
        """All rollup tiers as a JSON-ready dict:
        ``{tier: {host: {metric: [[t, last, min, max, n], ...]}}}``."""
        with self._lock:
            out: dict[str, dict[str, dict[str, list]]] = {}
            for (h, m, tier), q in self._rolled.items():
                pts = [list(p) for p in q]
                cur = self._open.get((h, m, tier))
                if cur is not None:
                    pts.append(list(cur))
                out.setdefault(f"{tier:g}", {}) \
                   .setdefault(str(h), {})[m] = pts
            for (h, m, tier), cur in self._open.items():
                tiers = out.setdefault(f"{tier:g}", {})
                metrics = tiers.setdefault(str(h), {})
                if m not in metrics:  # open bucket with no completed ones
                    metrics[m] = [list(cur)]
        return out

    def drop_host(self, host: int) -> None:
        with self._lock:
            for key in [k for k in self._series if k[0] == int(host)]:
                del self._series[key]
            for key in [k for k in self._rolled if k[0] == int(host)]:
                del self._rolled[key]
            for key in [k for k in self._open if k[0] == int(host)]:
                del self._open[key]


class HeartbeatPiggyback:
    """Worker-side delta collector for the HEARTBEAT metrics field.

    Each ``collect()`` returns ``{"seq", "counters", "gauges"}`` where
    ``counters`` is the registry delta since the previous collect and
    ``gauges`` the current gauge values. ``seq`` increases by one per
    collect; the aggregator discards any payload whose seq it has
    already applied, which makes redelivery idempotent.
    """

    def __init__(self, reg: obs_metrics.Registry | None = None,
                 max_keys: int = MAX_PIGGYBACK_KEYS):
        self.reg = reg or obs_metrics.REGISTRY
        self.max_keys = int(max_keys)
        self.seq = 0
        self._last: dict[str, float] = {}

    def collect(self) -> dict | None:
        snap = self.reg.counters_snapshot()
        delta = obs_metrics.counter_delta(self._last, snap)
        gauges = self.reg.gauges_snapshot()
        if len(delta) > self.max_keys:
            # defer the overflow: keys beyond the budget are left out of
            # the baseline too, so their full delta rides the next beat
            kept = dict(sorted(delta.items())[: self.max_keys])
            snap = dict(self._last)
            for k, v in kept.items():
                snap[k] = snap.get(k, 0) + v
            delta = kept
        if len(gauges) > self.max_keys:
            gauges = dict(sorted(gauges.items())[: self.max_keys])
        if not delta and not gauges and self.seq:
            return None  # nothing new: the heartbeat rides bare
        self.seq += 1
        self._last = snap
        return {"seq": self.seq, "counters": delta, "gauges": gauges}


class LiveAggregator:
    """Coordinator-side sink: HEARTBEAT piggybacks -> bounded series.

    Counters accumulate (the series records the running per-host total);
    gauges record the latest value. ``ingest`` is defensive end to end —
    whatever arrives in the frame, the event loop survives it.
    """

    def __init__(self, ring: int = DEFAULT_RING,
                 snapshot_path: str | None = None,
                 snapshot_every_s: float = 5.0):
        self.store = SeriesStore(ring=ring)
        self.snapshot_path = snapshot_path
        self.snapshot_every_s = float(snapshot_every_s)
        self._last_seq: dict[int, int] = {}
        self._totals: dict[tuple[int, str], float] = {}
        self._last_snapshot: float | None = None
        self.ingested = 0
        self.dropped = 0

    def reset_host(self, host: int) -> None:
        """A (re)JOIN starts a fresh incarnation: its seq counter restarts
        and its counter totals start over from the new process's zero."""
        self._last_seq.pop(int(host), None)
        for key in [k for k in self._totals if k[0] == int(host)]:
            del self._totals[key]

    def ingest(self, host: int, payload, t: float | None = None) -> bool:
        """Apply one piggyback payload; returns False when dropped
        (duplicate seq, malformed shape, or no payload at all)."""
        if not isinstance(payload, dict):
            if payload is not None:
                self.dropped += 1
            return False
        try:
            host = int(host)
            seq = payload.get("seq")
            if not isinstance(seq, int) or seq <= 0:
                self.dropped += 1
                return False
            if seq <= self._last_seq.get(host, 0):
                self.dropped += 1  # redelivery: already applied
                return False
            t = time.time() if t is None else float(t)
            counters = payload.get("counters")
            if isinstance(counters, dict):
                for k, v in counters.items():
                    if isinstance(k, str) and _is_num(v):
                        key = (host, k)
                        total = self._totals.get(key, 0.0) + float(v)
                        self._totals[key] = total
                        self.store.append(host, k, t, total)
            gauges = payload.get("gauges")
            if isinstance(gauges, dict):
                for k, v in gauges.items():
                    if isinstance(k, str) and _is_num(v):
                        self.store.append(host, k, t, float(v))
            self._last_seq[host] = seq
            self.ingested += 1
            return True
        except Exception:
            # live telemetry must never take the coordinator down
            self.dropped += 1
            return False

    def observe(self, host: int, metric: str, value: float,
                t: float | None = None) -> None:
        """Coordinator-local series (round durations, alert counts) share
        the same bounded store as the piggybacked worker metrics."""
        if _is_num(value):
            self.store.append(
                host, metric, time.time() if t is None else t, float(value)
            )

    # -- serving -----------------------------------------------------------

    def snapshot(self) -> dict:
        return {
            "schema": LIVE_SCHEMA,
            "t": time.time(),
            "hosts": self.store.hosts(),
            "series": self.store.snapshot(),
            "rollups": self.store.rollup_snapshot(),
            "ingested": self.ingested,
            "dropped": self.dropped,
        }

    def write_snapshot(self, path: str | None = None) -> str | None:
        path = path or self.snapshot_path
        if not path:
            return None
        tmp = f"{path}.tmp.{os.getpid()}"
        try:
            with open(tmp, "w") as f:
                json.dump(self.snapshot(), f, default=str)
            os.replace(tmp, path)
        except OSError:
            return None
        return path

    def maybe_snapshot(self, now: float | None = None) -> str | None:
        """Periodic run-dir snapshot (called from the coordinator tick)."""
        if not self.snapshot_path:
            return None
        now = time.monotonic() if now is None else now
        if (
            self._last_snapshot is not None
            and now - self._last_snapshot < self.snapshot_every_s
        ):
            return None
        self._last_snapshot = now
        return self.write_snapshot()


def read_snapshot(path: str) -> dict | None:
    """Load a ``live_metrics.json`` (tolerates a torn mid-replace write)."""
    try:
        with open(path) as f:
            doc = json.load(f)
    except (OSError, ValueError):
        return None
    return doc if isinstance(doc, dict) else None
