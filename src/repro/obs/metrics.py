"""One metrics registry for the whole stack — counters/gauges/histograms.

Before this module, every layer kept its own stats in its own shape:
``PagingStats.as_dict()`` (uvm), ``ChunkTransport.stats()`` (remote),
``CheckpointResult`` fields (core), SYNCED ``info`` dicts (proxy),
``RoundRecord`` (coord). The registry absorbs them all under one
snake_case naming scheme:

    <layer>_<metric>     e.g. uvm_faults_read, transport_wire_tx,
                              ckpt_bytes_written, proxy_restarts,
                              coord_rounds_committed

Absorption rides the channels the data already crosses: SYNCED info
frames (proxy → app), the fork-child result pipe (child counter deltas →
supervisor), PERSIST_DONE (worker → coordinator). No new wire traffic.

Always-on and allocation-light: incrementing a counter is a dict add
under a lock. Per-process snapshots are dumped to
``metrics-<process>-<pid>.json`` in the obs dir when tracing is enabled;
``repro.obs.report`` merges them per run.
"""
from __future__ import annotations

import json
import os
import threading

from repro.obs import trace

METRICS_SCHEMA = "crum-metrics/1"

# ---------------------------------------------------------------------------
# Pinned public key sets. These names are consumed across layer boundaries —
# by benchmarks/gate.py rows, RoundRecord, SYNCED info consumers and the
# canonical registry mapping below. tests/obs/test_naming.py pins them;
# changing a producer without updating the pin (and every consumer) is a
# cross-layer break, which is exactly what the test is for.
# ---------------------------------------------------------------------------

PAGING_STAT_KEYS = frozenset(
    {
        "faults_read",
        "faults_write",
        "hits",
        "prefetches",
        "evictions",
        "writebacks",
        "invalidations",
        "h2d_bytes",
        "d2h_bytes",
        "resident_high_water",
        "remote_reads",
        "remote_read_bytes",
        "promotions",
        "faults",
    }
)

TRANSPORT_STAT_KEYS = frozenset(
    {
        "transport",
        "wire_tx",
        "wire_rx",
        "raw_tx",
        "raw_rx",
        "frames_tx",
        "frames_rx",
        "chunks_tx",
        "chunks_rx",
        "data_plane_bytes",
    }
)

# SYNCED / ProxyRunner.sync_state() info dict — the proxy data-plane summary.
SYNC_INFO_KEYS = frozenset(
    {
        "step",
        "digest",
        "metrics",
        "chunks_synced",
        "bytes_synced",
        "restarts",
        "transport",
        "epoch",
        "stall_us",
        "wire_bytes",
        "raw_bytes",
        "paging",
        "phase_us",
    }
)

# Per-round coordinator journal record (RoundRecord.as_dict()).
ROUND_RECORD_KEYS = frozenset(
    {
        "step",
        "status",
        "reason",
        "participants",
        "acked",
        "stragglers",
        "commit_s",
        "round_s",
        "persist_s_max",
        "bytes_written",
        "chunks_synced",
        "chunks_clean",
        "bytes_skipped",
        "sync_us",
        "digest_us",
        "fetch_us",
        "stall_us",
    }
)

# Row fields benchmarks/gate.py reads from BENCH_results.json.
GATE_ROW_KEYS = frozenset(
    {
        "overhead_pct",
        "stall_ratio",
        "boundary_scan_gone",
        "bit_identical",
        "boundary_bit_identical",
        "us_per_call",
    }
)

_HIST_CAP = 8192


def _percentile(sorted_vals: list[float], q: float) -> float:
    if not sorted_vals:
        return 0.0
    idx = min(len(sorted_vals) - 1, int(q * (len(sorted_vals) - 1) + 0.5))
    return sorted_vals[idx]


class Registry:
    """Counters (monotonic adds), gauges (latest wins), histograms."""

    def __init__(self) -> None:
        self._lock = threading.Lock()
        self.counters: dict[str, float] = {}
        self.gauges: dict[str, float] = {}
        self._hists: dict[str, list[float]] = {}

    def inc(self, name: str, value: float = 1) -> None:
        with self._lock:
            self.counters[name] = self.counters.get(name, 0) + value

    def set(self, name: str, value: float) -> None:
        with self._lock:
            self.gauges[name] = value

    def observe(self, name: str, value: float) -> None:
        with self._lock:
            h = self._hists.setdefault(name, [])
            h.append(float(value))
            if len(h) >= _HIST_CAP:  # decimate: halve, keep the spread
                del h[::2]

    # -- snapshots ---------------------------------------------------------

    def counters_snapshot(self) -> dict[str, float]:
        with self._lock:
            return dict(self.counters)

    def gauges_snapshot(self) -> dict[str, float]:
        with self._lock:
            return dict(self.gauges)

    def hist_summary(self, name: str) -> dict[str, float]:
        with self._lock:
            vals = sorted(self._hists.get(name, []))
        return {
            "count": len(vals),
            "sum": sum(vals),
            "p50": _percentile(vals, 0.50),
            "p99": _percentile(vals, 0.99),
            "max": vals[-1] if vals else 0.0,
        }

    def snapshot(self) -> dict:
        with self._lock:
            hist_names = list(self._hists)
            doc = {
                "schema": METRICS_SCHEMA,
                "counters": dict(self.counters),
                "gauges": dict(self.gauges),
            }
        doc["hists"] = {n: self.hist_summary(n) for n in hist_names}
        return doc

    def merge_counters(self, delta: dict[str, float]) -> None:
        """Fold a child process's counter delta in (fork-pipe shipping)."""
        for k, v in delta.items():
            if isinstance(v, (int, float)):
                self.inc(k, v)

    def dump(self, path: str, *, process: str | None = None) -> None:
        doc = self.snapshot()
        doc["process"] = process
        doc["pid"] = os.getpid()
        tmp = f"{path}.tmp.{os.getpid()}"
        with open(tmp, "w") as f:
            json.dump(doc, f, indent=2, default=str)
        os.replace(tmp, path)

    def reset(self) -> None:
        with self._lock:
            self.counters.clear()
            self.gauges.clear()
            self._hists.clear()


REGISTRY = Registry()


def counter_delta(
    before: dict[str, float], after: dict[str, float]
) -> dict[str, float]:
    """What a child process added between two counter snapshots."""
    out: dict[str, float] = {}
    for k, v in after.items():
        d = v - before.get(k, 0)
        if d:
            out[k] = d
    return out


def dump_if_enabled(process: str, reg: Registry | None = None) -> str | None:
    """Write this process's snapshot into the obs dir (if tracing is on)."""
    tr = trace.get()
    if tr is None:
        return None
    path = os.path.join(
        tr.obs_dir, f"metrics-{process}-{os.getpid()}.json"
    )
    try:
        (reg or REGISTRY).dump(path, process=process)
    except OSError:
        return None
    return path


# ---------------------------------------------------------------------------
# Canonical absorption — scattered per-layer stat dicts map into the one
# registry under the one naming scheme. Producers keep their local shapes
# (as_dict()/stats() are public API); the registry is the merge point.
# ---------------------------------------------------------------------------


def absorb_paging(stats: dict, reg: Registry | None = None) -> None:
    """uvm ``PagingStats.as_dict()`` / ``ManagedSpace.stats_dict()``.

    Paging counters are cumulative per space, so they land as gauges
    (latest wins) — re-absorbing every SYNC boundary is idempotent.
    """
    reg = reg or REGISTRY
    for k, v in stats.items():
        if isinstance(v, (int, float)) and not isinstance(v, bool):
            reg.set(f"uvm_{k}", v)


def absorb_transport(stats: dict, reg: Registry | None = None) -> None:
    """remote ``ChunkTransport.stats()`` — cumulative wire counters."""
    reg = reg or REGISTRY
    for k, v in stats.items():
        if isinstance(v, (int, float)) and not isinstance(v, bool):
            reg.set(f"transport_{k}", v)


def absorb_sync_info(info: dict, reg: Registry | None = None) -> None:
    """Proxy SYNCED / ``sync_state()`` info dict, app side."""
    reg = reg or REGISTRY
    reg.inc("proxy_syncs_total")
    reg.inc("proxy_chunks_synced", info.get("chunks_synced") or 0)
    reg.inc("proxy_bytes_synced", info.get("bytes_synced") or 0)
    if info.get("stall_us") is not None:
        reg.observe("proxy_sync_stall_us", info["stall_us"])
    if info.get("wire_bytes") is not None:
        reg.set("proxy_wire_bytes", info["wire_bytes"])
    if info.get("raw_bytes") is not None:
        reg.set("proxy_raw_bytes", info["raw_bytes"])
    phase = info.get("phase_us") or {}
    for k, v in phase.items():
        if isinstance(v, (int, float)) and not isinstance(v, bool):
            reg.observe(f"proxy_phase_{k}_us", v)
    paging = info.get("paging")
    if isinstance(paging, dict):
        absorb_paging(paging, reg)
    transport = info.get("transport")
    if isinstance(transport, dict):
        absorb_transport(transport, reg)


def absorb_checkpoint_result(res, reg: Registry | None = None) -> None:
    """``core.forked.CheckpointResult`` — per-checkpoint phase stats."""
    reg = reg or REGISTRY
    reg.inc("ckpt_checkpoints_total")
    if getattr(res, "error", None):
        reg.inc("ckpt_errors_total")
    for field in (
        "bytes_written",
        "chunks_written",
        "chunks_reused",
        "chunks_synced",
        "chunks_clean",
        "bytes_skipped",
    ):
        v = getattr(res, field, None)
        if isinstance(v, (int, float)):
            reg.inc(f"ckpt_{field}", v)
    for field in ("blocking_s", "persist_s"):
        v = getattr(res, field, None)
        if isinstance(v, (int, float)):
            reg.observe(f"ckpt_{field}", v)
    for field in ("sync_us", "digest_us", "fetch_us", "stall_us"):
        v = getattr(res, field, None)
        if isinstance(v, (int, float)):
            reg.observe(f"ckpt_{field}", v)


def absorb_round(rec: dict, reg: Registry | None = None) -> None:
    """Coordinator journal ``round`` record (RoundRecord shape)."""
    reg = reg or REGISTRY
    reg.inc("coord_rounds_total")
    status = rec.get("status")
    if status == "committed":
        reg.inc("coord_rounds_committed")
    elif status:
        reg.inc("coord_rounds_aborted")
    for field in ("commit_s", "round_s", "persist_s_max"):
        v = rec.get(field)
        if isinstance(v, (int, float)):
            reg.observe(f"coord_{field}", v)
    for field in ("bytes_written", "chunks_synced", "chunks_clean",
                  "bytes_skipped"):
        v = rec.get(field)
        if isinstance(v, (int, float)):
            reg.inc(f"coord_{field}", v)
