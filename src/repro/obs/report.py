"""Run-dir reporter — merge shards into one Perfetto trace + summary.

    PYTHONPATH=src python -m repro.obs.report <run_dir>
    PYTHONPATH=src python -m repro.obs.report <run_dir> --check   # CI

Inputs found under ``<run_dir>`` (the ``--obs-dir`` of a run):

* ``trace-<process>-<pid>.jsonl`` — per-process trace_event shards,
* ``metrics-<process>-<pid>.json`` — per-process registry snapshots,
* ``CLUSTER_LOG.jsonl`` — coordinator journal (also looked up one level
  up, where ``launch/cluster`` keeps it) — journal records become
  instants on a synthetic "cluster-journal" track so commits/deaths line
  up against the process timelines.

Outputs: ``<run_dir>/merged.trace.json`` (open in https://ui.perfetto.dev
or chrome://tracing) and a text summary — per-span p50/p99, stall ratio,
fault/eviction rates, wire vs dirty bytes.

``--check`` additionally validates the merged trace against the
trace_event schema (required keys per phase, balanced ``B``/``E``
nesting per (pid, tid) in every shard) and exits non-zero on violation —
the CI teeth for satellite "trace correctness".
"""
from __future__ import annotations

import argparse
import glob
import json
import os
import sys

from repro.obs.journal import read_journal

# Synthetic pid for the journal track — far outside real pid ranges.
JOURNAL_PID = 99999999

_REQUIRED = ("name", "ph", "ts")
_PHASES = {"B", "E", "X", "i", "I", "C", "M"}


def load_shards(run_dir: str) -> tuple[list[dict], list[str]]:
    """All events from every trace-*.jsonl shard; skips torn lines."""
    events: list[dict] = []
    shards = sorted(glob.glob(os.path.join(run_dir, "trace-*.jsonl")))
    for path in shards:
        with open(path, encoding="utf-8", errors="replace") as f:
            for line in f:
                line = line.strip()
                if not line:
                    continue
                try:
                    ev = json.loads(line)
                except ValueError:
                    continue  # torn tail (SIGKILL mid-write)
                if isinstance(ev, dict):
                    ev["_shard"] = os.path.basename(path)
                    events.append(ev)
    return events, shards


def find_journal(run_dir: str, explicit: str | None = None) -> str | None:
    for cand in (
        explicit,
        os.path.join(run_dir, "CLUSTER_LOG.jsonl"),
        os.path.join(os.path.dirname(os.path.abspath(run_dir)),
                     "CLUSTER_LOG.jsonl"),
    ):
        if cand and os.path.exists(cand):
            return cand
    return None


def journal_events(journal_path: str) -> list[dict]:
    """Coordinator journal records → instants on a synthetic track."""
    out: list[dict] = [
        {
            "name": "process_name",
            "ph": "M",
            "pid": JOURNAL_PID,
            "tid": 0,
            "ts": 0,
            "args": {"name": "cluster-journal"},
        }
    ]
    for rec in read_journal(journal_path):
        args = {k: v for k, v in vars(rec).items()
                if k not in ("extra", "schema") and v not in (None, [], "")}
        args.update(rec.extra)
        out.append(
            {
                "name": f"journal.{rec.event}",
                "ph": "i",
                "s": "p",
                "pid": JOURNAL_PID,
                "tid": 0,
                "ts": int(rec.t * 1e6),
                "args": args,
            }
        )
    return out


def merge_metrics(run_dir: str) -> dict:
    """Sum per-process registry snapshots into one run-level view."""
    counters: dict[str, float] = {}
    gauges: dict[str, float] = {}
    processes: list[str] = []
    for path in sorted(glob.glob(os.path.join(run_dir, "metrics-*.json"))):
        try:
            with open(path) as f:
                doc = json.load(f)
        except (OSError, ValueError):
            continue
        processes.append(str(doc.get("process") or
                             os.path.basename(path)))
        for k, v in (doc.get("counters") or {}).items():
            if isinstance(v, (int, float)):
                counters[k] = counters.get(k, 0) + v
        for k, v in (doc.get("gauges") or {}).items():
            if isinstance(v, (int, float)):
                # gauges are per-process cumulative values: sum across
                # processes gives the run total (e.g. uvm_faults per space)
                gauges[k] = gauges.get(k, 0) + v
    return {"counters": counters, "gauges": gauges, "processes": processes}


# -- validation -------------------------------------------------------------


def validate_events(events: list[dict]) -> list[str]:
    """trace_event schema + nesting problems (empty list = valid)."""
    problems: list[str] = []
    stacks: dict[tuple, list[str]] = {}
    for i, ev in enumerate(events):
        where = f"event {i} ({ev.get('_shard', '?')})"
        for k in _REQUIRED:
            if k not in ev:
                problems.append(f"{where}: missing {k!r}")
        ph = ev.get("ph")
        if ph not in _PHASES:
            problems.append(f"{where}: unknown phase {ph!r}")
            continue
        if ph != "M" and ("pid" not in ev or "tid" not in ev):
            problems.append(f"{where}: missing pid/tid")
            continue
        if ph == "X" and not isinstance(ev.get("dur"), (int, float)):
            problems.append(f"{where}: X event without numeric dur")
        key = (ev.get("pid"), ev.get("tid"))
        if ph == "B":
            stacks.setdefault(key, []).append(ev.get("name", ""))
        elif ph == "E":
            stack = stacks.setdefault(key, [])
            if not stack:
                problems.append(
                    f"{where}: orphaned E {ev.get('name')!r} on {key}"
                )
            else:
                stack.pop()
    for key, stack in stacks.items():
        if stack:
            problems.append(f"unclosed B events on {key}: {stack}")
    return problems


# -- summary ----------------------------------------------------------------


def _pct(sorted_vals: list[float], q: float) -> float:
    if not sorted_vals:
        return 0.0
    idx = min(len(sorted_vals) - 1, int(q * (len(sorted_vals) - 1) + 0.5))
    return sorted_vals[idx]


def span_durations(events: list[dict]) -> dict[str, list[float]]:
    """Per-name duration samples (µs) from X events and matched B/E pairs."""
    durs: dict[str, list[float]] = {}
    open_b: dict[tuple, list[dict]] = {}
    for ev in sorted(events, key=lambda e: e.get("ts", 0)):
        ph = ev.get("ph")
        if ph == "X":
            durs.setdefault(ev.get("name", "?"), []).append(
                float(ev.get("dur", 0))
            )
        elif ph == "B":
            open_b.setdefault((ev.get("pid"), ev.get("tid")), []).append(ev)
        elif ph == "E":
            stack = open_b.get((ev.get("pid"), ev.get("tid")))
            if stack:
                b = stack.pop()
                durs.setdefault(b.get("name", "?"), []).append(
                    float(ev.get("ts", 0)) - float(b.get("ts", 0))
                )
    return durs


def summarize(events: list[dict], metrics: dict) -> str:
    durs = span_durations(events)
    lines: list[str] = []
    lines.append(f"{'span':<28}{'count':>8}{'p50_us':>12}{'p99_us':>12}"
                 f"{'total_ms':>12}")
    for name in sorted(durs):
        vals = sorted(durs[name])
        lines.append(
            f"{name:<28}{len(vals):>8}{_pct(vals, 0.5):>12.0f}"
            f"{_pct(vals, 0.99):>12.0f}{sum(vals) / 1e3:>12.1f}"
        )

    c = metrics.get("counters", {})
    g = metrics.get("gauges", {})
    step_total = sum(durs.get("app.step", [])) or sum(
        durs.get("proxy.step", [])
    )
    stall_total = sum(durs.get("app.sync_stall", []))
    lines.append("")
    lines.append("derived:")
    if step_total:
        lines.append(
            f"  stall_ratio            {stall_total / step_total:.4f}  "
            f"(sync stall / step time)"
        )
    steps = len(durs.get("proxy.step", [])) or len(durs.get("app.step", []))
    faults = g.get("uvm_faults", 0)
    evictions = g.get("uvm_evictions", 0)
    if steps:
        lines.append(f"  uvm_faults_per_step    {faults / steps:.2f}")
        lines.append(f"  uvm_evictions_per_step {evictions / steps:.2f}")
    wire = g.get("transport_wire_tx", 0) + g.get("transport_wire_rx", 0)
    dirty = c.get("proxy_bytes_synced", 0) or c.get("ckpt_bytes_written", 0)
    if wire or dirty:
        ratio = f"  ({wire / dirty:.3f}x)" if dirty else ""
        lines.append(
            f"  wire_bytes vs dirty    {int(wire)} / {int(dirty)}{ratio}"
        )
    restarts = c.get("proxy_restarts", 0)
    if restarts:
        lines.append(f"  proxy_restarts         {int(restarts)}")
    rounds = c.get("coord_rounds_total", 0)
    if rounds:
        lines.append(
            f"  coord_rounds           {int(rounds)} "
            f"({int(c.get('coord_rounds_committed', 0))} committed)"
        )
    if metrics.get("processes"):
        lines.append(
            f"  metric sources         {', '.join(metrics['processes'])}"
        )
    return "\n".join(lines)


# -- entry point ------------------------------------------------------------


def merge(run_dir: str, journal: str | None = None,
          out: str | None = None) -> tuple[str, list[dict], dict]:
    events, shards = load_shards(run_dir)
    jpath = find_journal(run_dir, journal)
    if jpath:
        events.extend(journal_events(jpath))
    events.sort(key=lambda e: e.get("ts", 0))
    metrics = merge_metrics(run_dir)
    out = out or os.path.join(run_dir, "merged.trace.json")
    doc = {
        "traceEvents": [
            {k: v for k, v in ev.items() if k != "_shard"} for ev in events
        ],
        "displayTimeUnit": "ms",
        "otherData": {
            "schema": "crum-trace/1",
            "shards": [os.path.basename(s) for s in shards],
            "journal": jpath,
            "metrics": metrics,
        },
    }
    with open(out, "w") as f:
        json.dump(doc, f, default=str)
    return out, events, metrics


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(
        prog="python -m repro.obs.report", description=__doc__,
        formatter_class=argparse.RawDescriptionHelpFormatter,
    )
    ap.add_argument("run_dir", help="obs dir holding trace-*.jsonl shards")
    ap.add_argument("--journal", default=None,
                    help="explicit CLUSTER_LOG.jsonl path")
    ap.add_argument("--out", default=None,
                    help="merged trace path (default <run_dir>/merged.trace.json)")
    ap.add_argument("--check", action="store_true",
                    help="validate trace_event schema + span nesting; "
                         "exit non-zero on violation")
    args = ap.parse_args(argv)

    if not os.path.isdir(args.run_dir):
        print(f"[obs] no such run dir: {args.run_dir}", file=sys.stderr)
        return 2
    out, events, metrics = merge(args.run_dir, args.journal, args.out)
    n_shard_events = sum(1 for e in events if "_shard" in e)
    print(f"[obs] merged {n_shard_events} events -> {out}")
    print(summarize(events, metrics))
    if args.check:
        problems = validate_events(events)
        if problems:
            for p in problems[:50]:
                print(f"[obs] INVALID: {p}", file=sys.stderr)
            print(f"[obs] trace validation FAILED "
                  f"({len(problems)} problem(s))", file=sys.stderr)
            return 1
        print(f"[obs] trace validation OK ({n_shard_events} events, "
              f"{len(metrics.get('processes', []))} metric shards)")
    return 0


if __name__ == "__main__":
    sys.exit(main())
