"""Run-dir reporter — merge shards into one Perfetto trace + summary.

    PYTHONPATH=src python -m repro.obs.report <run_dir>
    PYTHONPATH=src python -m repro.obs.report <run_dir> --check   # CI

Inputs found under ``<run_dir>`` (the ``--obs-dir`` of a run):

* ``trace-<process>-<pid>.jsonl`` — per-process trace_event shards,
* ``metrics-<process>-<pid>.json`` — per-process registry snapshots,
* ``CLUSTER_LOG.jsonl`` — coordinator journal (also looked up one level
  up, where ``launch/cluster`` keeps it) — journal records become
  instants on a synthetic "cluster-journal" track so commits/deaths line
  up against the process timelines.

Outputs: ``<run_dir>/merged.trace.json`` (open in https://ui.perfetto.dev
or chrome://tracing) and a text summary — per-span p50/p99, stall ratio,
fault/eviction rates, wire vs dirty bytes.

``--check`` additionally validates the merged trace against the
trace_event schema (required keys per phase, balanced ``B``/``E``
nesting per (pid, tid) in every shard) and exits non-zero on violation —
the CI teeth for satellite "trace correctness".

Kill drills SIGKILL processes mid-run, so the reporter tolerates the
gaps they leave — a traced process with no metrics dump, a dump torn
mid-replace — and *names* them (``missing_metrics``/``corrupt_metrics``
in the summary) instead of failing. ``--summary-json FILE`` writes the
whole summary as machine-readable JSON (the CI artifact).
"""
from __future__ import annotations

import argparse
import glob
import json
import os
import sys

from repro.obs.journal import read_journal

# Synthetic pid for the journal track — far outside real pid ranges.
JOURNAL_PID = 99999999

_REQUIRED = ("name", "ph", "ts")
_PHASES = {"B", "E", "X", "i", "I", "C", "M", "s", "t", "f"}


def load_shards(run_dir: str) -> tuple[list[dict], list[str]]:
    """All events from every trace-*.jsonl shard; skips torn lines."""
    events: list[dict] = []
    shards = sorted(glob.glob(os.path.join(run_dir, "trace-*.jsonl")))
    for path in shards:
        with open(path, encoding="utf-8", errors="replace") as f:
            for line in f:
                line = line.strip()
                if not line:
                    continue
                try:
                    ev = json.loads(line)
                except ValueError:
                    continue  # torn tail (SIGKILL mid-write)
                if isinstance(ev, dict):
                    ev["_shard"] = os.path.basename(path)
                    events.append(ev)
    return events, shards


def find_journal(run_dir: str, explicit: str | None = None) -> str | None:
    for cand in (
        explicit,
        os.path.join(run_dir, "CLUSTER_LOG.jsonl"),
        os.path.join(os.path.dirname(os.path.abspath(run_dir)),
                     "CLUSTER_LOG.jsonl"),
    ):
        if cand and os.path.exists(cand):
            return cand
    return None


def journal_events(journal_path: str) -> list[dict]:
    """Coordinator journal records → instants on a synthetic track."""
    out: list[dict] = [
        {
            "name": "process_name",
            "ph": "M",
            "pid": JOURNAL_PID,
            "tid": 0,
            "ts": 0,
            "args": {"name": "cluster-journal"},
        }
    ]
    for rec in read_journal(journal_path):
        args = {k: v for k, v in vars(rec).items()
                if k not in ("extra", "schema") and v not in (None, [], "")}
        args.update(rec.extra)
        out.append(
            {
                "name": f"journal.{rec.event}",
                "ph": "i",
                "s": "p",
                "pid": JOURNAL_PID,
                "tid": 0,
                "ts": int(rec.t * 1e6),
                "args": args,
            }
        )
    return out


def _shard_id(path: str, prefix: str, suffix: str) -> str | None:
    """``<prefix><process>-<pid><suffix>`` -> ``<process>-<pid>``."""
    name = os.path.basename(path)
    if not (name.startswith(prefix) and name.endswith(suffix)):
        return None
    return name[len(prefix):len(name) - len(suffix)]


def merge_metrics(run_dir: str) -> dict:
    """Sum per-process registry snapshots into one run-level view.

    Kill drills leave gaps: a SIGKILLed process traced events but never
    reached its atexit metrics dump, and a dump torn mid-replace is
    unparseable. Both are *expected* in failure drills, so the merge
    proceeds over what exists — but the gaps are named in the result
    (``missing_metrics`` / ``corrupt_metrics``) so a report over a run
    that should have been clean can be gated on them.
    """
    counters: dict[str, float] = {}
    gauges: dict[str, float] = {}
    processes: list[str] = []
    corrupt: list[str] = []
    seen: set[str] = set()
    for path in sorted(glob.glob(os.path.join(run_dir, "metrics-*.json"))):
        sid = _shard_id(path, "metrics-", ".json")
        if sid is not None:
            seen.add(sid)
        try:
            with open(path) as f:
                doc = json.load(f)
        except (OSError, ValueError):
            corrupt.append(os.path.basename(path))
            continue
        processes.append(str(doc.get("process") or
                             os.path.basename(path)))
        for k, v in (doc.get("counters") or {}).items():
            if isinstance(v, (int, float)):
                counters[k] = counters.get(k, 0) + v
        for k, v in (doc.get("gauges") or {}).items():
            if isinstance(v, (int, float)):
                # gauges are per-process cumulative values: sum across
                # processes gives the run total (e.g. uvm_faults per space)
                gauges[k] = gauges.get(k, 0) + v
    # a trace shard with no metrics twin = that process died before its
    # final dump (SIGKILL drill, crash) — a gap, not a reporter error
    missing = sorted(
        sid
        for path in glob.glob(os.path.join(run_dir, "trace-*.jsonl"))
        if (sid := _shard_id(path, "trace-", ".jsonl")) is not None
        and sid not in seen
    )
    return {
        "counters": counters, "gauges": gauges, "processes": processes,
        "missing_metrics": missing, "corrupt_metrics": corrupt,
    }


# -- validation -------------------------------------------------------------


def validate_events(events: list[dict]) -> list[str]:
    """trace_event schema + nesting problems (empty list = valid)."""
    problems: list[str] = []
    stacks: dict[tuple, list[str]] = {}
    for i, ev in enumerate(events):
        where = f"event {i} ({ev.get('_shard', '?')})"
        for k in _REQUIRED:
            if k not in ev:
                problems.append(f"{where}: missing {k!r}")
        ph = ev.get("ph")
        if ph not in _PHASES:
            problems.append(f"{where}: unknown phase {ph!r}")
            continue
        if ph != "M" and ("pid" not in ev or "tid" not in ev):
            problems.append(f"{where}: missing pid/tid")
            continue
        if ph == "X" and not isinstance(ev.get("dur"), (int, float)):
            problems.append(f"{where}: X event without numeric dur")
        key = (ev.get("pid"), ev.get("tid"))
        if ph == "B":
            stacks.setdefault(key, []).append(ev.get("name", ""))
        elif ph == "E":
            stack = stacks.setdefault(key, [])
            if not stack:
                problems.append(
                    f"{where}: orphaned E {ev.get('name')!r} on {key}"
                )
            else:
                stack.pop()
    for key, stack in stacks.items():
        if stack:
            problems.append(f"unclosed B events on {key}: {stack}")
    return problems


# -- summary ----------------------------------------------------------------


def _pct(sorted_vals: list[float], q: float) -> float:
    if not sorted_vals:
        return 0.0
    idx = min(len(sorted_vals) - 1, int(q * (len(sorted_vals) - 1) + 0.5))
    return sorted_vals[idx]


def span_durations(events: list[dict]) -> dict[str, list[float]]:
    """Per-name duration samples (µs) from X events and matched B/E pairs."""
    durs: dict[str, list[float]] = {}
    open_b: dict[tuple, list[dict]] = {}
    for ev in sorted(events, key=lambda e: e.get("ts", 0)):
        ph = ev.get("ph")
        if ph == "X":
            durs.setdefault(ev.get("name", "?"), []).append(
                float(ev.get("dur", 0))
            )
        elif ph == "B":
            open_b.setdefault((ev.get("pid"), ev.get("tid")), []).append(ev)
        elif ph == "E":
            stack = open_b.get((ev.get("pid"), ev.get("tid")))
            if stack:
                b = stack.pop()
                durs.setdefault(b.get("name", "?"), []).append(
                    float(ev.get("ts", 0)) - float(b.get("ts", 0))
                )
    return durs


def summary_dict(events: list[dict], metrics: dict) -> dict:
    """The run summary as data — one source for text AND --summary-json."""
    durs = span_durations(events)
    spans = {}
    for name in sorted(durs):
        vals = sorted(durs[name])
        spans[name] = {
            "count": len(vals),
            "p50_us": round(_pct(vals, 0.5), 1),
            "p99_us": round(_pct(vals, 0.99), 1),
            "total_ms": round(sum(vals) / 1e3, 3),
        }

    c = metrics.get("counters", {})
    g = metrics.get("gauges", {})
    derived: dict = {}
    step_total = sum(durs.get("app.step", [])) or sum(
        durs.get("proxy.step", [])
    )
    stall_total = sum(durs.get("app.sync_stall", []))
    if step_total:
        derived["stall_ratio"] = round(stall_total / step_total, 4)
    steps = len(durs.get("proxy.step", [])) or len(durs.get("app.step", []))
    if steps:
        derived["uvm_faults_per_step"] = round(
            g.get("uvm_faults", 0) / steps, 2)
        derived["uvm_evictions_per_step"] = round(
            g.get("uvm_evictions", 0) / steps, 2)
    wire = g.get("transport_wire_tx", 0) + g.get("transport_wire_rx", 0)
    dirty = c.get("proxy_bytes_synced", 0) or c.get("ckpt_bytes_written", 0)
    if wire or dirty:
        derived["wire_bytes"] = int(wire)
        derived["dirty_bytes"] = int(dirty)
        if dirty:
            derived["wire_vs_dirty_x"] = round(wire / dirty, 3)
    if c.get("proxy_restarts", 0):
        derived["proxy_restarts"] = int(c["proxy_restarts"])
    if c.get("coord_rounds_total", 0):
        derived["coord_rounds"] = int(c["coord_rounds_total"])
        derived["coord_rounds_committed"] = int(
            c.get("coord_rounds_committed", 0))
    if c.get("watch_alerts_total", 0):
        derived["watch_alerts"] = int(c["watch_alerts_total"])
    return {
        "schema": "crum-obs-summary/1",
        "spans": spans,
        "derived": derived,
        "counters": c,
        "gauges": g,
        "processes": metrics.get("processes", []),
        "missing_metrics": metrics.get("missing_metrics", []),
        "corrupt_metrics": metrics.get("corrupt_metrics", []),
    }


def summarize(events: list[dict], metrics: dict) -> str:
    doc = summary_dict(events, metrics)
    lines: list[str] = []
    lines.append(f"{'span':<28}{'count':>8}{'p50_us':>12}{'p99_us':>12}"
                 f"{'total_ms':>12}")
    for name, s in doc["spans"].items():
        lines.append(
            f"{name:<28}{s['count']:>8}{s['p50_us']:>12.0f}"
            f"{s['p99_us']:>12.0f}{s['total_ms']:>12.1f}"
        )
    d = doc["derived"]
    lines.append("")
    lines.append("derived:")
    if "stall_ratio" in d:
        lines.append(
            f"  stall_ratio            {d['stall_ratio']:.4f}  "
            f"(sync stall / step time)"
        )
    if "uvm_faults_per_step" in d:
        lines.append(f"  uvm_faults_per_step    "
                     f"{d['uvm_faults_per_step']:.2f}")
        lines.append(f"  uvm_evictions_per_step "
                     f"{d['uvm_evictions_per_step']:.2f}")
    if "wire_bytes" in d:
        ratio = (f"  ({d['wire_vs_dirty_x']:.3f}x)"
                 if "wire_vs_dirty_x" in d else "")
        lines.append(
            f"  wire_bytes vs dirty    {d['wire_bytes']} / "
            f"{d.get('dirty_bytes', 0)}{ratio}"
        )
    if "proxy_restarts" in d:
        lines.append(f"  proxy_restarts         {d['proxy_restarts']}")
    if "coord_rounds" in d:
        lines.append(
            f"  coord_rounds           {d['coord_rounds']} "
            f"({d['coord_rounds_committed']} committed)"
        )
    if "watch_alerts" in d:
        lines.append(f"  watch_alerts           {d['watch_alerts']}")
    if doc["processes"]:
        lines.append(
            f"  metric sources         {', '.join(doc['processes'])}"
        )
    if doc["missing_metrics"]:
        lines.append(
            f"  MISSING metric shards  {', '.join(doc['missing_metrics'])} "
            f"(process died before its final dump)"
        )
    if doc["corrupt_metrics"]:
        lines.append(
            f"  CORRUPT metric shards  {', '.join(doc['corrupt_metrics'])}"
        )
    return "\n".join(lines)


# -- entry point ------------------------------------------------------------


def merge(run_dir: str, journal: str | None = None,
          out: str | None = None) -> tuple[str, list[dict], dict]:
    events, shards = load_shards(run_dir)
    try:
        # causal-context spans become Perfetto flow arrows; lazy import —
        # critpath imports this module for shard loading
        from repro.obs.critpath import flow_events

        events.extend(flow_events(events))
    except Exception:
        pass  # a malformed ctx must not take the whole report down
    jpath = find_journal(run_dir, journal)
    if jpath:
        events.extend(journal_events(jpath))
    events.sort(key=lambda e: e.get("ts", 0))
    metrics = merge_metrics(run_dir)
    out = out or os.path.join(run_dir, "merged.trace.json")
    doc = {
        "traceEvents": [
            {k: v for k, v in ev.items() if k != "_shard"} for ev in events
        ],
        "displayTimeUnit": "ms",
        "otherData": {
            "schema": "crum-trace/1",
            "shards": [os.path.basename(s) for s in shards],
            "journal": jpath,
            "metrics": metrics,
        },
    }
    with open(out, "w") as f:
        json.dump(doc, f, default=str)
    return out, events, metrics


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(
        prog="python -m repro.obs.report", description=__doc__,
        formatter_class=argparse.RawDescriptionHelpFormatter,
    )
    ap.add_argument("run_dir", help="obs dir holding trace-*.jsonl shards")
    ap.add_argument("--journal", default=None,
                    help="explicit CLUSTER_LOG.jsonl path")
    ap.add_argument("--out", default=None,
                    help="merged trace path (default <run_dir>/merged.trace.json)")
    ap.add_argument("--check", action="store_true",
                    help="validate trace_event schema + span nesting; "
                         "exit non-zero on violation")
    ap.add_argument("--summary-json", metavar="FILE", default=None,
                    help="also write the summary (spans + derived + "
                         "merged metrics + shard gaps) as JSON")
    args = ap.parse_args(argv)

    if not os.path.isdir(args.run_dir):
        print(f"[obs] no such run dir: {args.run_dir}", file=sys.stderr)
        return 2
    out, events, metrics = merge(args.run_dir, args.journal, args.out)
    n_shard_events = sum(1 for e in events if "_shard" in e)
    print(f"[obs] merged {n_shard_events} events -> {out}")
    print(summarize(events, metrics))
    if args.summary_json:
        with open(args.summary_json, "w") as f:
            json.dump(summary_dict(events, metrics), f, indent=2,
                      default=str)
        print(f"[obs] wrote summary to {args.summary_json}")
    if args.check:
        problems = validate_events(events)
        if problems:
            for p in problems[:50]:
                print(f"[obs] INVALID: {p}", file=sys.stderr)
            print(f"[obs] trace validation FAILED "
                  f"({len(problems)} problem(s))", file=sys.stderr)
            return 1
        print(f"[obs] trace validation OK ({n_shard_events} events, "
              f"{len(metrics.get('processes', []))} metric shards)")
    return 0


if __name__ == "__main__":
    sys.exit(main())
