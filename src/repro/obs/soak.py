"""Soak verdict engine — every alert must explain itself.

``python -m repro.obs.soak RUN_DIR --check`` joins the injection journal
(``INJECT_LOG.jsonl``, ``crum-inject/1``) against everything the run
recorded — cluster-journal lines, watchdog AlertLines, live metric
series (leak trends), the critical-path report and the driver summary —
and renders a versioned scorecard (``crum-soak/1``, ``soak.json``) of
hard booleans:

``all_injections_evidenced``
    every injection produced its expected evidence inside its window
    (an injection that left no trace means detection is broken),
``no_unexplained_alerts``
    every alert is claimed by some injection's ``explains`` list within
    that injection's window (an unexplained alert is either a false
    positive or a real, un-injected fault — both are failures),
``converged``
    the cluster finished in bit-identical lockstep with a committed
    checkpoint,
``leaks_flat``
    the coordinator's fd and /dev/shm series did not grow beyond the
    allowance across the whole run,
``critpath_ok``
    the merged trace passes ``repro.obs.critpath.check`` (orphan
    subtrees only where deaths are journaled),
``envelope_ok``
    no committed round exceeded the duration envelope.

``pass`` is the conjunction. Exit status follows it under ``--check``.
"""
from __future__ import annotations

import argparse
import json
import os

from repro.obs.journal import (
    AlertLine,
    DeathLine,
    InjectLine,
    JoinLine,
    ProxyHostDeathLine,
    ProxyPlacementLine,
    RoundLine,
    read_journal,
)

SOAK_SCHEMA = "crum-soak/1"

__all__ = ["SOAK_SCHEMA", "match_token", "evidence_for", "explain_alerts",
           "verdict", "main"]


def _in_window(t: float, inj: InjectLine) -> bool:
    w = float(inj.expect.get("window_s", 120.0))
    return inj.t <= t <= inj.t + w


def _host_ok(inj: InjectLine, host) -> bool:
    want = inj.expect.get("host")
    if want is None or host is None:
        return True
    return int(host) == int(want)


def match_token(token: str, inj: InjectLine, records: list) -> list[str]:
    """Evidence descriptors for one token of one injection's spec.

    Tokens: ``alert:<kind>`` matches an AlertLine; ``journal:<what>``
    matches a cluster-journal fact — ``death``, ``join_restored``,
    ``proxy_host_death``, ``proxy_placement_rescheduled``,
    ``round_committed`` (a commit after the injection: liveness),
    ``round_aborted_persist`` (an abort whose reason names persist).
    All matches are time-boxed to the injection's window and, when the
    spec pins a ``host``, host-filtered.
    """
    out: list[str] = []
    for r in records:
        if not _in_window(r.t, inj):
            continue
        if token.startswith("alert:"):
            kind = token.split(":", 1)[1]
            if (isinstance(r, AlertLine) and r.kind == kind
                    and _host_ok(inj, r.host)):
                out.append(f"alert:{kind}@{r.t:.3f}")
        elif token == "journal:death":
            if isinstance(r, DeathLine) and _host_ok(inj, r.host):
                out.append(f"death:host{r.host}@{r.t:.3f}")
        elif token == "journal:join_restored":
            if (isinstance(r, JoinLine) and r.restored_from is not None
                    and _host_ok(inj, r.host)):
                out.append(f"join_restored:host{r.host}@{r.t:.3f}")
        elif token == "journal:proxy_host_death":
            if isinstance(r, ProxyHostDeathLine):
                out.append(f"proxy_host_death:{r.name}@{r.t:.3f}")
        elif token == "journal:proxy_placement_rescheduled":
            if isinstance(r, ProxyPlacementLine) and r.rescheduled:
                out.append(f"rescheduled:worker{r.worker}@{r.t:.3f}")
        elif token == "journal:round_committed":
            if isinstance(r, RoundLine) and r.committed:
                out.append(f"round_committed:step{r.step}@{r.t:.3f}")
        elif token == "journal:round_aborted_persist":
            if (isinstance(r, RoundLine) and r.status == "aborted"
                    and "persist" in (r.reason or "")):
                out.append(f"round_aborted_persist:step{r.step}@{r.t:.3f}")
    return out


def evidence_for(inj: InjectLine, records: list) -> dict:
    """Judge one injection: ``{"evidenced": bool, "matched": {...}}``."""
    matched: dict[str, list[str]] = {}
    any_tokens = list(inj.expect.get("any") or [])
    all_tokens = list(inj.expect.get("all") or [])
    for tok in any_tokens + all_tokens:
        matched[tok] = match_token(tok, inj, records)
    ok = True
    if any_tokens:
        ok = any(matched[t] for t in any_tokens)
    if ok and all_tokens:
        ok = all(matched[t] for t in all_tokens)
    return {"evidenced": ok, "matched": matched}


def explain_alerts(injections: list[InjectLine],
                   alerts: list[AlertLine]) -> list[dict]:
    """Attribute every alert to the injection that claims it (or None).

    An alert is explained when its kind appears in some injection's
    ``explains`` list and it fired inside that injection's window —
    kind + time matching, deliberately not host-strict: a worker kill's
    abort ripples to rounds, not hosts.
    """
    out = []
    for a in alerts:
        by = None
        for inj in injections:
            if a.kind in (inj.expect.get("explains") or ()) \
                    and _in_window(a.t, inj):
                by = inj.seq
                break
        out.append({
            "kind": a.kind, "severity": a.severity, "host": a.host,
            "step": a.step, "t": a.t, "message": a.message,
            "explained_by": by,
        })
    return out


# -- run-dir plumbing --------------------------------------------------------


def load_inject_log(run_dir: str) -> list[InjectLine]:
    path = os.path.join(run_dir, "INJECT_LOG.jsonl")
    return [r for r in read_journal(path) if isinstance(r, InjectLine)]


def find_cluster_journal(run_dir: str) -> str | None:
    from repro.obs.report import find_journal

    for cand in (
        os.path.join(run_dir, "ckpt", "CLUSTER_LOG.jsonl"),
        os.path.join(run_dir, "CLUSTER_LOG.jsonl"),
    ):
        if os.path.exists(cand):
            return cand
    return find_journal(run_dir)


def _leak_trend(snap: dict | None, metric: str) -> float | None:
    """Net growth of a coordinator-local series over the whole run.

    Prefers the 10s rollup tier (the raw ring wraps on long soaks);
    falls back to the raw series. None = the series never appeared
    (leakcheck unsupported on this platform)."""
    if not snap:
        return None
    for tier in ("10", "60"):
        pts = ((snap.get("rollups") or {}).get(tier) or {}) \
            .get("-1", {}).get(metric)
        if pts:
            return float(pts[-1][1]) - float(pts[0][1])
    raw = (snap.get("series") or {}).get("-1", {}).get(metric)
    if raw:
        return float(raw[-1][1]) - float(raw[0][1])
    return None


def verdict(run_dir: str, *, round_envelope_s: float = 30.0,
            fd_allowance: int = 8, shm_allowance: int = 4) -> dict:
    """The full ``crum-soak/1`` scorecard for one soak run dir."""
    from repro.obs import critpath as obs_critpath
    from repro.obs import live as obs_live

    run_dir = os.path.abspath(run_dir)
    injections = load_inject_log(run_dir)
    jpath = find_cluster_journal(run_dir)
    records = read_journal(jpath) if jpath else []
    alerts = [r for r in records if isinstance(r, AlertLine)]
    rounds = [r for r in records if isinstance(r, RoundLine)]

    inj_rows = []
    for inj in injections:
        row = {"seq": inj.seq, "kind": inj.kind, "target": inj.target,
               "t": inj.t, "params": inj.params}
        row.update(evidence_for(inj, records))
        inj_rows.append(row)
    alert_rows = explain_alerts(injections, alerts)

    # convergence: the driver summary when present, else the journal
    summary = None
    try:
        with open(os.path.join(run_dir, "soak_run.json")) as f:
            summary = json.load(f)
    except (OSError, ValueError):
        pass
    if summary is not None:
        converged = bool(summary.get("lockstep")) \
            and summary.get("latest_committed") is not None
    else:
        committed = [r for r in rounds if r.committed]
        converged = bool(committed)

    obs_dir = os.path.join(run_dir, "obs")
    snap = obs_live.read_snapshot(
        os.path.join(obs_dir, "live_metrics.json")
    ) or obs_live.read_snapshot(
        os.path.join(run_dir, "ckpt", "live_metrics.json")
    )
    fd_growth = _leak_trend(snap, "coord_fd")
    shm_growth = _leak_trend(snap, "coord_shm")
    # an absent series is not a leak — leakcheck may be unsupported
    leaks_flat = (fd_growth is None or fd_growth <= fd_allowance) and \
                 (shm_growth is None or shm_growth <= shm_allowance)

    critpath_problems: list[str] = []
    critpath_ok = True
    if os.path.isdir(obs_dir) and jpath:
        try:
            doc = obs_critpath.analyze(obs_dir, journal=jpath)
            critpath_problems = obs_critpath.check(doc)
            critpath_ok = not critpath_problems
        except Exception as e:
            critpath_problems = [f"critpath analysis failed: {e}"]
            critpath_ok = False

    slow = [r for r in rounds
            if r.committed and r.round_s > round_envelope_s]

    checks = {
        "all_injections_evidenced": all(r["evidenced"] for r in inj_rows),
        "no_unexplained_alerts": all(
            a["explained_by"] is not None for a in alert_rows
        ),
        "converged": converged,
        "leaks_flat": leaks_flat,
        "critpath_ok": critpath_ok,
        "envelope_ok": not slow,
    }
    return {
        "schema": SOAK_SCHEMA,
        "run_dir": run_dir,
        "n_injections": len(inj_rows),
        "n_alerts": len(alert_rows),
        "injections": inj_rows,
        "alerts": alert_rows,
        "leak_growth": {"coord_fd": fd_growth, "coord_shm": shm_growth},
        "critpath_problems": critpath_problems,
        "slow_rounds": [{"step": r.step, "round_s": r.round_s}
                        for r in slow],
        "checks": checks,
        "pass": all(checks.values()),
    }


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(
        prog="python -m repro.obs.soak", description=__doc__,
        formatter_class=argparse.RawDescriptionHelpFormatter,
    )
    ap.add_argument("run_dir")
    ap.add_argument("--check", action="store_true",
                    help="exit 1 unless every check passes")
    ap.add_argument("--out", default=None,
                    help="scorecard path (default RUN_DIR/soak.json)")
    ap.add_argument("--round-envelope-s", type=float, default=30.0)
    ap.add_argument("--fd-allowance", type=int, default=8)
    ap.add_argument("--shm-allowance", type=int, default=4)
    args = ap.parse_args(argv)

    doc = verdict(
        args.run_dir,
        round_envelope_s=args.round_envelope_s,
        fd_allowance=args.fd_allowance,
        shm_allowance=args.shm_allowance,
    )
    out = args.out or os.path.join(os.path.abspath(args.run_dir),
                                   "soak.json")
    with open(out, "w") as f:
        json.dump(doc, f, indent=2)

    for row in doc["injections"]:
        tick = "ok " if row["evidenced"] else "FAIL"
        hits = sum(len(v) for v in row["matched"].values())
        print(f"  [{tick}] #{row['seq']} {row['kind']} -> {row['target']} "
              f"({hits} evidence line(s))")
    unexplained = [a for a in doc["alerts"] if a["explained_by"] is None]
    for a in unexplained:
        print(f"  [FAIL] unexplained alert {a['kind']} "
              f"(host={a['host']}, t={a['t']:.3f}): {a['message']}")
    for name, ok in doc["checks"].items():
        print(f"  [{'ok ' if ok else 'FAIL'}] {name}")
    print(f"soak verdict: {'PASS' if doc['pass'] else 'FAIL'} "
          f"({doc['n_injections']} injections, {doc['n_alerts']} alerts) "
          f"-> {out}")
    if args.check and not doc["pass"]:
        return 1
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
