"""``python -m repro.obs.top`` — the terminal live view of a cluster run.

Two data sources, same rendering:

* ``--endpoint HOST:PORT`` — ask a *running* coordinator over its own
  TCP listener (the ``METRICS`` side-channel frame; no JOIN, so the view
  never participates in membership or rounds), refreshing every
  ``--interval`` seconds.
* ``--run-dir DIR`` — read the ``live_metrics.json`` snapshot the
  coordinator drops into the checkpoint root (plus ``alert`` lines from
  CLUSTER_LOG.jsonl), which also works after the run has ended.

``--once`` renders a single frame and exits — what CI and tests use;
without it the view loops until interrupted.

Rendering is pure (:func:`render` takes the snapshot + alerts and
returns a string), so tests never need a terminal or a socket.
"""
from __future__ import annotations

import argparse
import os
import sys
import time

from repro.obs import live as obs_live

#: metrics promoted to the per-host table when present (everything else
#: is summarized in the "other series" count)
KEY_COLUMNS = (
    "proxy_syncs_total",
    "proxy_chunks_synced",
    "proxy_bytes_synced",
    "ckpt_checkpoints_total",
    "ckpt_bytes_written",
    "uvm_faults",
    "uvm_evictions",
)


def _fmt(v: float) -> str:
    if v != v:  # NaN
        return "?"
    if abs(v) >= 1e9:
        return f"{v / 1e9:.1f}G"
    if abs(v) >= 1e6:
        return f"{v / 1e6:.1f}M"
    if abs(v) >= 1e4:
        return f"{v / 1e3:.1f}k"
    if v == int(v):
        return str(int(v))
    return f"{v:.2f}"


def _rate(points: list) -> float | None:
    """Per-second rate over the tail of a cumulative series."""
    if len(points) < 2:
        return None
    (t0, v0), (t1, v1) = points[-2], points[-1]
    if t1 <= t0:
        return None
    return (v1 - v0) / (t1 - t0)


def _explained_by(alert: dict, injections: list) -> int | None:
    """The seq of the injection whose ``explains`` claims this alert
    inside its evidence window, or None (same rule as the soak verdict:
    kind + time, not host-strict)."""
    t = alert.get("t")
    if not isinstance(t, (int, float)):
        return None
    for inj in injections:
        expect = inj.expect or {}
        if alert.get("kind") in (expect.get("explains") or ()):
            w = float(expect.get("window_s", 120.0))
            if inj.t <= t <= inj.t + w:
                return inj.seq
    return None


def render(snapshot: dict | None, alerts: list[dict],
           *, injections: list | None = None, width: int = 100) -> str:
    """One frame of the dashboard as a plain string."""
    lines: list[str] = []
    injections = injections or []
    if not snapshot:
        lines.append("crum top — no live snapshot yet "
                     "(coordinator not started, or telemetry disabled)")
    else:
        t = snapshot.get("t")
        age = f" ({time.time() - t:.0f}s ago)" if isinstance(
            t, (int, float)) else ""
        lines.append(
            f"crum top — hosts={snapshot.get('hosts', [])} "
            f"ingested={snapshot.get('ingested', 0)} "
            f"dropped={snapshot.get('dropped', 0)}{age}"
        )
        series = snapshot.get("series") or {}
        shown = [c for c in KEY_COLUMNS if any(
            c in (m or {}) for m in series.values())]
        if shown:
            hdr = "host".ljust(6) + "".join(
                c.replace("proxy_", "p.").replace("ckpt_", "c.")
                 .replace("uvm_", "u.")[:14].rjust(15) for c in shown)
            lines.append(hdr[:width])
            for host_key in sorted(series, key=lambda h: (len(h), h)):
                metrics = series[host_key] or {}
                label = "coord" if host_key == "-1" else f"h{host_key}"
                row = label.ljust(6)
                for c in shown:
                    pts = metrics.get(c) or []
                    cell = _fmt(pts[-1][1]) if pts else "-"
                    r = _rate(pts)
                    if r is not None and r > 0:
                        cell += f"/{_fmt(r)}s"
                    row += cell.rjust(15)
                lines.append(row[:width])
        n_other = sum(
            1 for m in series.values() for k in (m or {}) if k not in shown
        )
        if n_other:
            lines.append(f"  … plus {n_other} more series "
                         f"(full dump: live_metrics.json)")
    if injections:
        now = time.time()
        active = [i for i in injections
                  if i.until is not None and i.until > now]
        lines.append(f"chaos: {len(injections)} injection(s), "
                     f"{len(active)} active")
        for i in injections[-8:]:
            state = "ACTIVE" if (i.until is not None and i.until > now) \
                else "fired"
            lines.append(
                f"  [{state:6s}] #{i.seq} {i.kind} -> {i.target}"[:width]
            )
    if alerts:
        lines.append(f"alerts ({len(alerts)}):")
        for a in alerts[-10:]:
            note = ""
            if injections:
                by = _explained_by(a, injections)
                note = (f" <- chaos #{by}" if by is not None
                        else " [UNEXPLAINED]")
            body = (f"  [{a.get('severity', '?'):8s}] {a.get('kind', '?')}"
                    f" host={a.get('host', '-')} step={a.get('step', '-')}"
                    f" {a.get('message', '')}")
            # the chaos annotation is the point: clip the message, not it
            lines.append(body[:width - len(note)] + note if note
                         else body[:width])
    else:
        lines.append("alerts: none")
    return "\n".join(lines)


# -- data sources ------------------------------------------------------------

def fetch_endpoint(host: str, port: int,
                   timeout: float = 5.0) -> tuple[dict | None, list[dict]]:
    """One METRICS round-trip against a live coordinator."""
    from repro.coord import protocol

    conn = protocol.connect((host, port), timeout=timeout)
    try:
        conn.settimeout(timeout)
        conn.send(protocol.MSG_METRICS, op="snapshot")
        reply = conn.recv()
    finally:
        conn.close()
    if not isinstance(reply, dict):
        return None, []
    alerts = reply.get("alerts")
    return (
        reply.get("snapshot"),
        alerts if isinstance(alerts, list) else [],
    )


def load_injections(run_dir: str) -> list:
    """InjectLines from the run dir's (or its parent's) INJECT_LOG.jsonl
    — present when the run was a chaos soak, empty otherwise."""
    from repro.obs import journal

    for cand in (
        os.path.join(run_dir, "INJECT_LOG.jsonl"),
        os.path.join(os.path.dirname(os.path.abspath(run_dir)),
                     "INJECT_LOG.jsonl"),
    ):
        if os.path.exists(cand):
            return [r for r in journal.read_journal(cand)
                    if isinstance(r, journal.InjectLine)]
    return []


def fetch_run_dir(run_dir: str) -> tuple[dict | None, list[dict], list]:
    """Snapshot + journaled alerts (+ injections) from a run dir."""
    from repro.obs import journal
    from repro.obs.report import find_journal

    snap = obs_live.read_snapshot(os.path.join(run_dir, "live_metrics.json"))
    if snap is None:  # soak layout: the snapshot lives under obs/
        snap = obs_live.read_snapshot(
            os.path.join(run_dir, "obs", "live_metrics.json"))
    jpath = find_journal(run_dir)
    if jpath is None:
        cand = os.path.join(run_dir, "ckpt", "CLUSTER_LOG.jsonl")
        jpath = cand if os.path.exists(cand) else None
    alert_lines = journal.alerts(jpath) if jpath else []
    alerts = [
        {"kind": a.kind, "severity": a.severity, "host": a.host,
         "step": a.step, "t": a.t, "message": a.message}
        for a in alert_lines
    ]
    return snap, alerts, load_injections(run_dir)


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(
        description=__doc__,
        formatter_class=argparse.RawDescriptionHelpFormatter,
    )
    src = ap.add_mutually_exclusive_group(required=True)
    src.add_argument("--endpoint", metavar="HOST:PORT",
                     help="poll a running coordinator's METRICS channel")
    src.add_argument("--run-dir", metavar="DIR",
                     help="read live_metrics.json + CLUSTER_LOG.jsonl "
                          "from a checkpoint root")
    ap.add_argument("--interval", type=float, default=2.0)
    ap.add_argument("--once", action="store_true",
                    help="render one frame and exit (CI mode)")
    args = ap.parse_args(argv)

    if args.endpoint:
        host, _, port = args.endpoint.rpartition(":")
        if not host or not port.isdigit():
            ap.error("--endpoint must be HOST:PORT")

        def fetch():
            snap, alerts = fetch_endpoint(host, int(port))
            return snap, alerts, []
    else:
        def fetch():
            return fetch_run_dir(args.run_dir)

    while True:
        try:
            snapshot, alerts, injections = fetch()
        except (OSError, ValueError) as e:
            snapshot, alerts, injections = None, [], []
            print(f"[top] fetch failed: {e}", file=sys.stderr)
        frame = render(snapshot, alerts, injections=injections)
        if not args.once:
            print("\x1b[2J\x1b[H", end="")  # clear + home
        print(frame, flush=True)
        if args.once:
            return 0 if snapshot is not None else 1
        try:
            time.sleep(args.interval)
        except KeyboardInterrupt:
            return 0


if __name__ == "__main__":
    sys.exit(main())
