"""Cross-process tracing — Chrome ``trace_event`` JSONL shards.

Every process in the stack (app, proxy, proxy-host daemon, cluster
worker, coordinator, fork-persist child) appends events to its own
``trace-<process>-<pid>.jsonl`` shard inside one shared *obs dir*.
``repro.obs.report`` later merges the shards into a single
Perfetto-loadable ``.trace.json``.

Design constraints, in order:

1. **Disabled is free.** The module-global ``TRACER`` is ``None`` until
   :func:`enable` runs. Hot paths hoist ``tr = trace.get()`` and guard
   with ``if tr is not None`` — the disabled path is one global load and
   one identity test, no allocation, no call. ``benchmarks/obs_overhead``
   pins this.
2. **SIGKILL-tolerant.** Each event is one line written with a single
   ``os.write`` on an ``O_APPEND`` fd: lines from concurrent writers
   never interleave, and a kill mid-write tears at most the final line
   (the reader skips lines that fail to parse).
3. **Fork-safe.** The fork-persist child inherits the tracer; the first
   emit in the child notices the pid change and reopens a shard of its
   own, so every shard stays single-writer.
4. **One clock.** ``ts`` is ``time.time_ns() // 1000`` — the shared wall
   clock in microseconds — so shards from different processes (and
   different hosts sharing NTP) line up on one Perfetto timeline.
   Durations are measured with ``perf_counter`` and back-dated onto the
   wall clock (``X`` events), keeping span widths monotonic-accurate.

Correlation IDs ride as event ``args``: ``step`` (training step),
``epoch`` (SYNC epoch), ``inc`` (proxy incarnation = restarts spent),
``run`` (run id). They are threaded through the existing control frames
(REGISTER ``obs`` field), never through new side channels.

**Causal contexts.** On top of the correlation args sits a causal trace
context — a small dict ``{"trace": str, "span": int, "parent": int}``
(``parent`` omitted at the root) that rides the existing msgpack frames
as an optional ``ctx`` field and lands in span ``args`` via
:func:`ctx_args`. ``trace`` names the causal tree (one per checkpoint
round: ``round:<step>``, see :func:`round_trace_id`); ``span`` is a
64-bit id minted with :func:`new_span_id`; ``parent`` points at the
emitting site's causal parent, which may live in *another process's*
shard. The convention for frames: the **sender** mints a fresh child id
per frame (:func:`child_span`) and the **receiver** emits its span with
exactly that context — one frame, one receiver span, and a SIGKILL'd
sender simply leaves its receivers' subtree orphaned (the reporter marks
it, never drops it). :func:`root_span_id` derives the round root's span
id deterministically from the trace id so every process agrees on the
root without any exchange. ``repro.obs.critpath`` rebuilds the per-round
trees from the merged shards.
"""
from __future__ import annotations

import hashlib
import json
import os
import random
import threading
import time

ENV_DIR = "CRUM_OBS_DIR"
ENV_RUN = "CRUM_OBS_RUN"

__all__ = [
    "Tracer",
    "enable",
    "enable_from_env",
    "disable",
    "get",
    "new_span_id",
    "round_trace_id",
    "root_span_id",
    "span_context",
    "child_span",
    "ctx_args",
    "ENV_DIR",
    "ENV_RUN",
]


# -- causal trace contexts -------------------------------------------------


def new_span_id() -> int:
    """A fresh 63-bit span id (non-zero, msgpack/JSON-safe positive int)."""
    return random.getrandbits(63) | 1


def round_trace_id(step: int) -> str:
    """The trace id naming checkpoint round ``step``'s causal tree."""
    return f"round:{int(step)}"


def root_span_id(trace_id: str) -> int:
    """Deterministic root span id for a trace.

    Workers reach a round boundary (and their proxies STEP toward it)
    *before* the coordinator opens the round, so the root id cannot be
    handed out over the wire — instead every process derives the same
    63-bit id from the trace id alone and parents its top-level spans to
    it with zero coordination.
    """
    h = hashlib.blake2s(trace_id.encode("utf-8"), digest_size=8).digest()
    return (int.from_bytes(h, "big") & ((1 << 63) - 1)) | 1


def span_context(
    trace_id: str, *, parent: int | None = None, span: int | None = None
) -> dict:
    """Build a context naming span ``span`` (fresh id if None) in a trace."""
    ctx: dict = {
        "trace": trace_id,
        "span": int(span) if span is not None else new_span_id(),
    }
    if parent is not None:
        ctx["parent"] = int(parent)
    return ctx


def child_span(ctx: dict | None) -> dict | None:
    """A fresh child context under ``ctx`` (None stays None — no-op path)."""
    if not ctx:
        return None
    return {"trace": ctx["trace"], "span": new_span_id(), "parent": ctx["span"]}


def ctx_args(ctx: dict | None) -> dict:
    """Flatten a context into span ``args`` keys ({} when no context)."""
    if not ctx or "span" not in ctx:
        return {}
    out = {"trace": ctx.get("trace"), "span": ctx["span"]}
    if ctx.get("parent") is not None:
        out["parent"] = ctx["parent"]
    return out


class _Span:
    """B/E pair as a context manager — for structural (non-hot) spans."""

    __slots__ = ("_tr", "_name", "_args")

    def __init__(self, tr: "Tracer", name: str, args: dict):
        self._tr = tr
        self._name = name
        self._args = args

    def __enter__(self) -> "_Span":
        self._tr.begin(self._name, **self._args)
        return self

    def __exit__(self, *exc) -> bool:
        self._tr.end(self._name)
        return False


class Tracer:
    def __init__(self, obs_dir: str, process: str, run_id: str | None = None):
        self.obs_dir = os.path.abspath(obs_dir)
        self.process = process
        self.run_id = run_id
        self._reopen_lock = threading.Lock()
        self._fd = -1
        self._pid = -1
        self._open_shard()

    # -- shard management --------------------------------------------------

    def _open_shard(self) -> None:
        os.makedirs(self.obs_dir, exist_ok=True)
        pid = os.getpid()
        self.path = os.path.join(
            self.obs_dir, f"trace-{self.process}-{pid}.jsonl"
        )
        self._fd = os.open(
            self.path, os.O_WRONLY | os.O_CREAT | os.O_APPEND, 0o644
        )
        self._pid = pid
        # Perfetto process label; run id rides along for the reporter.
        self._write(
            {
                "name": "process_name",
                "ph": "M",
                "pid": pid,
                "tid": 0,
                "ts": 0,
                "args": {"name": f"{self.process}:{pid}", "run": self.run_id},
            }
        )

    def _write(self, ev: dict) -> None:
        line = json.dumps(ev, separators=(",", ":"), default=str) + "\n"
        try:
            os.write(self._fd, line.encode("utf-8"))
        except OSError:
            pass  # tracing must never take the traced process down

    def _emit(self, ev: dict) -> None:
        if ev["pid"] != self._pid:
            # Forked child: inherited fd points at the parent's shard and
            # the inherited lock state is garbage — rebuild both. Only the
            # (single) surviving thread runs here, so this is race-free.
            self._reopen_lock = threading.Lock()
            try:
                os.close(self._fd)
            except OSError:
                pass
            self._open_shard()
        self._write(ev)

    # -- event API ---------------------------------------------------------

    def instant(self, name: str, **args) -> None:
        self._emit(
            {
                "name": name,
                "ph": "i",
                "s": "p",
                "pid": os.getpid(),
                "tid": threading.get_native_id(),
                "ts": time.time_ns() // 1000,
                "args": args,
            }
        )

    def complete(self, name: str, t0: float, **args) -> None:
        """``X`` event ending now; ``t0`` is a ``perf_counter()`` at start.

        Built for hot paths that already measured ``t0`` for their own
        stats — the span costs one dict + one write, no extra clock reads
        at the start of the measured region.
        """
        dur = int((time.perf_counter() - t0) * 1e6)
        self._emit(
            {
                "name": name,
                "ph": "X",
                "pid": os.getpid(),
                "tid": threading.get_native_id(),
                "ts": time.time_ns() // 1000 - dur,
                "dur": dur,
                "args": args,
            }
        )

    def begin(self, name: str, **args) -> None:
        self._emit(
            {
                "name": name,
                "ph": "B",
                "pid": os.getpid(),
                "tid": threading.get_native_id(),
                "ts": time.time_ns() // 1000,
                "args": args,
            }
        )

    def end(self, name: str, **args) -> None:
        ev = {
            "name": name,
            "ph": "E",
            "pid": os.getpid(),
            "tid": threading.get_native_id(),
            "ts": time.time_ns() // 1000,
        }
        if args:
            ev["args"] = args
        self._emit(ev)

    def span(self, name: str, **args) -> _Span:
        return _Span(self, name, args)

    def counter(self, name: str, **values) -> None:
        self._emit(
            {
                "name": name,
                "ph": "C",
                "pid": os.getpid(),
                "tid": threading.get_native_id(),
                "ts": time.time_ns() // 1000,
                "args": values,
            }
        )


# -- module-global switch --------------------------------------------------

TRACER: Tracer | None = None


def get() -> Tracer | None:
    """The enabled tracer, or None. Hot paths hoist this and null-check."""
    return TRACER


def enable(
    obs_dir: str,
    process: str,
    run_id: str | None = None,
    *,
    set_env: bool = True,
) -> Tracer:
    """Turn tracing on for this process (idempotent; first enable wins).

    With ``set_env`` (the default for launcher processes), exports
    ``CRUM_OBS_DIR``/``CRUM_OBS_RUN`` so spawned children — workers,
    proxies, proxy-host daemons — pick the same obs dir up via
    :func:`enable_from_env`.
    """
    global TRACER
    if TRACER is not None:
        return TRACER
    run_id = (
        run_id
        or os.environ.get(ENV_RUN)
        or f"run-{os.getpid()}-{time.time_ns() // 1_000_000_000}"
    )
    TRACER = Tracer(obs_dir, process, run_id)
    if set_env:
        os.environ[ENV_DIR] = TRACER.obs_dir
        os.environ[ENV_RUN] = run_id
    return TRACER


def enable_from_env(process: str) -> Tracer | None:
    """Child-process hook: enable iff the launcher exported an obs dir."""
    d = os.environ.get(ENV_DIR)
    if d and TRACER is None:
        return enable(
            d, process, run_id=os.environ.get(ENV_RUN), set_env=False
        )
    return TRACER


def disable() -> None:
    """Turn tracing off (tests); drops the env propagation too."""
    global TRACER
    t, TRACER = TRACER, None
    if t is not None:
        try:
            os.close(t._fd)
        except OSError:
            pass
    os.environ.pop(ENV_DIR, None)
    os.environ.pop(ENV_RUN, None)


def instant(name: str, **args) -> None:
    t = TRACER
    if t is not None:
        t.instant(name, **args)


def counter(name: str, **values) -> None:
    t = TRACER
    if t is not None:
        t.counter(name, **values)
