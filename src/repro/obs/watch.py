"""SLO watchdog — live health rules evaluated per heartbeat / per round.

CRUM's value proposition is that checkpointing overhead stays inside a
small envelope *while the run is under way*; this module is the rule
engine that notices, live, when it does not. The coordinator feeds it
every signal it already has (heartbeats + piggybacked metric deltas,
round records, deaths, persist acks) plus a periodic
:func:`repro.obs.leakcheck.sample`, and each rule emits a versioned
:class:`Alert` record:

    ======================  ==========  ==================================
    kind                    severity    fires when
    ======================  ==========  ==================================
    stall_ratio             warning     round stall_us over the ceiling
                                        relative to the round duration
    heartbeat_skew          warning     a host's reported step lags the
                                        front-runner by > max_step_skew
    clock_skew              warning     a host's heartbeat wall clock is
                                        > max_clock_skew_s off the
                                        coordinator's (re-arms when the
                                        clock recovers)
    round_abort             warning     a checkpoint round aborted
    abort_rate              critical    >= abort_rate_window aborts with
                                        no commit in between
    straggler               warning     the straggler policy flagged hosts
                                        at a committed round
    worker_death            warning     a worker was kicked (EOF/timeout)
    proxy_host_death        warning     a worker reported its proxy
                                        endpoint dead (reschedule path)
    fault_rate              warning     uvm fault counter rate spiked
                                        above fault_rate_max per second
    fd_leak_trend           warning     fd count grew monotonically over
                                        the sampled window
    shm_leak_trend          warning     /dev/shm entries grew over window
    digest_divergence       critical    two hosts acked the same round
                                        with different state digests;
                                        when per-chunk digests flowed,
                                        the alert names the first chunk
                                        that forked and the culprit host
    ======================  ==========  ==================================

Alerts flow through every observability channel at once: the journal
(``alert`` lines in CLUSTER_LOG.jsonl, typed as
:class:`repro.obs.journal.AlertLine`), a trace instant, the metrics
registry (``watch_alerts_total``), and an optional ``on_alert`` callback
— the coordinator uses the callback for the abort-on-critical policy.

The watchdog is pure bookkeeping over numbers already in hand: no I/O of
its own beyond the (rate-limited) leakcheck sample, so it is safe to run
on the coordinator event-loop thread every tick.
"""
from __future__ import annotations

import time
from dataclasses import dataclass, field, asdict
from typing import Callable

from repro.obs import leakcheck

ALERT_SCHEMA = "crum-alert/1"

SEV_INFO = "info"
SEV_WARNING = "warning"
SEV_CRITICAL = "critical"

__all__ = [
    "ALERT_SCHEMA",
    "Alert",
    "WatchConfig",
    "Watchdog",
]


@dataclass
class Alert:
    """One rule violation — the versioned record every channel carries."""

    kind: str
    severity: str = SEV_WARNING
    host: int | None = None
    step: int | None = None
    value: float | None = None
    limit: float | None = None
    message: str = ""
    # divergence provenance: the first chunk (tree path + chunk index)
    # whose per-host digests forked — only digest_divergence sets these
    chunk: str | None = None
    chunk_index: int | None = None
    alert_schema: str = ALERT_SCHEMA

    def as_dict(self) -> dict:
        return {k: v for k, v in asdict(self).items() if v is not None}


@dataclass
class WatchConfig:
    """Rule thresholds. Defaults are intentionally lenient — the happy
    path of every existing drill must stay alert-free; drills that
    *inject* a failure are what should trip them."""

    # round rules
    stall_ratio_max: float = 0.5        # sum(stall_us)/1e6 vs round_s
    abort_rate_window: int = 3          # consecutive aborts => critical
    # heartbeat rules
    max_step_skew: int = 0              # 0 = disabled (lockstep barriers
    #                                     make persistent skew visible as
    #                                     stalls; enable for async loops)
    # wall-clock skew rule: a host's heartbeat ``wt`` vs the coordinator's
    # own clock at receipt (0 = disabled). Re-arming: recovers when the
    # host's clock comes back inside the limit.
    max_clock_skew_s: float = 0.0
    # uvm fault/eviction spike rule (per-second rate over the heartbeat
    # series; 0 disables — oversubscribed runs set their own budget)
    fault_rate_max: float = 0.0
    fault_metrics: tuple = ("uvm_faults", "uvm_evictions")
    # leak-trend rule: sample every interval, alert when the count grew
    # monotonically across the whole window by more than the allowance
    leak_sample_every_s: float = 2.0
    leak_window: int = 5
    fd_leak_allowance: int = 8
    shm_leak_allowance: int = 4
    # digest divergence needs at least this many reporting hosts
    divergence_min_hosts: int = 2


class Watchdog:
    """Evaluates :class:`WatchConfig` rules over the coordinator's feed."""

    def __init__(
        self,
        cfg: WatchConfig | None = None,
        *,
        on_alert: Callable[[Alert], None] | None = None,
        sampler: Callable[[], dict] | None = None,
    ):
        self.cfg = cfg or WatchConfig()
        self.on_alert = on_alert
        # default sampler excludes obs-owned fds (trace shards, journal):
        # a traced run must not trip the fd-leak rule just by tracing
        self._sampler = sampler or leakcheck.watchdog_sample
        self.alerts: list[Alert] = []
        self._steps: dict[int, int] = {}         # host -> last heartbeat step
        self._skew_alerted: set[int] = set()
        self._clock_alerted: set[int] = set()
        self._consecutive_aborts = 0
        self._abort_rate_alerted = False
        self._fault_last: dict[tuple[int, str], tuple[float, float]] = {}
        self._leak = leakcheck.PeriodicAudit(
            interval_s=self.cfg.leak_sample_every_s,
            window=self.cfg.leak_window,
            sampler=self._sampler,
        )
        self._leak_alerted: set[str] = set()
        self._digests: dict[int, dict[int, str]] = {}  # step -> host -> digest
        self._diverged_steps: set[int] = set()
        # divergences detected but held back for a determinable culprit
        self._pending_divergence: set[int] = set()
        # per-chunk provenance: step -> host -> {path: [chunk digests]}
        self._chunks: dict[int, dict[int, dict[str, list[int]]]] = {}
        # last unanimously-agreed digest per (path, chunk index), recorded
        # at committed rounds — lets the divergence alert name the culprit
        # host exactly instead of guessing by minority vote
        self._chunk_baseline: dict[tuple[str, int], int] = {}

    # -- emission ----------------------------------------------------------

    def _emit(self, alert: Alert) -> Alert:
        self.alerts.append(alert)
        if self.on_alert is not None:
            self.on_alert(alert)
        return alert

    @property
    def critical(self) -> list[Alert]:
        return [a for a in self.alerts if a.severity == SEV_CRITICAL]

    def kinds(self) -> set[str]:
        return {a.kind for a in self.alerts}

    # -- heartbeat-path rules ---------------------------------------------

    def on_heartbeat(self, host: int, step: int,
                     wt: float | None = None) -> None:
        self._steps[int(host)] = int(step)
        if self.cfg.max_clock_skew_s > 0 and wt is not None:
            h = int(host)
            skew = abs(float(wt) - time.time())
            if skew > self.cfg.max_clock_skew_s:
                if h not in self._clock_alerted:
                    self._clock_alerted.add(h)
                    self._emit(Alert(
                        "clock_skew", SEV_WARNING, host=h, step=int(step),
                        value=round(skew, 3),
                        limit=self.cfg.max_clock_skew_s,
                        message=f"host {h} heartbeat wall clock is "
                                f"{skew:.1f}s off the coordinator's",
                    ))
            else:
                self._clock_alerted.discard(h)  # re-arm once back in sync
        if self.cfg.max_step_skew <= 0 or len(self._steps) < 2:
            return
        front = max(self._steps.values())
        for h, s in self._steps.items():
            lag = front - s
            if lag > self.cfg.max_step_skew and h not in self._skew_alerted:
                self._skew_alerted.add(h)
                self._emit(Alert(
                    "heartbeat_skew", SEV_WARNING, host=h, step=s,
                    value=float(lag), limit=float(self.cfg.max_step_skew),
                    message=f"host {h} at step {s} lags front-runner "
                            f"at {front}",
                ))
            elif lag <= self.cfg.max_step_skew:
                self._skew_alerted.discard(h)  # re-arm once caught up

    def on_metric_point(self, host: int, metric: str, t: float,
                        value: float) -> None:
        """Rate rules over piggybacked series (uvm faults/evictions)."""
        if self.cfg.fault_rate_max <= 0:
            return
        if metric not in self.cfg.fault_metrics:
            return
        key = (int(host), metric)
        prev = self._fault_last.get(key)
        self._fault_last[key] = (t, value)
        if prev is None:
            return
        dt = t - prev[0]
        if dt <= 0:
            return
        rate = (value - prev[1]) / dt
        if rate > self.cfg.fault_rate_max:
            self._emit(Alert(
                "fault_rate", SEV_WARNING, host=int(host),
                value=round(rate, 1), limit=self.cfg.fault_rate_max,
                message=f"{metric} rate {rate:.0f}/s on host {host}",
            ))

    def tick(self, now: float | None = None) -> dict | None:
        """Periodic (coordinator event-loop tick): leak-trend sampling.

        Returns the leakcheck sample taken this tick (None when the
        interval has not elapsed) so the caller can publish the raw
        fd//dev/shm counts as live metric series — the soak verdict's
        leak-trend check reads those series, not just the alerts.
        """
        s = self._leak.maybe_sample(now)
        if s is None:
            return None
        for kind, count_key, allowance in (
            ("fd_leak_trend", "fd", self.cfg.fd_leak_allowance),
            ("shm_leak_trend", "shm", self.cfg.shm_leak_allowance),
        ):
            growth = self._leak.trend(count_key)
            if growth is None:
                continue
            if growth > allowance and kind not in self._leak_alerted:
                self._leak_alerted.add(kind)
                self._emit(Alert(
                    kind, SEV_WARNING, value=float(growth),
                    limit=float(allowance),
                    message=f"{count_key} count grew by {growth} over "
                            f"{self._leak.window} samples",
                ))
            elif growth is not None and growth <= allowance:
                self._leak_alerted.discard(kind)  # re-arm after recovery
        return s

    # -- round-path rules --------------------------------------------------

    def on_persist_done(self, host: int, step: int,
                        state_digest: str | None,
                        chunk_digests: dict[str, list[int]] | None = None,
                        ) -> None:
        """Cross-worker divergence: every host acking the same round must
        hold the same (replicated, lockstep) state.

        When the ack also carries per-chunk ``chunk_digests`` (full-state
        fused digests, comparable across hosts), a divergence alert names
        the first chunk that forked and the culprit host instead of just
        reporting that the whole-state digests differ."""
        if not state_digest:
            return
        step = int(step)
        if chunk_digests:
            self._chunks.setdefault(step, {})[int(host)] = chunk_digests
        per_round = self._digests.setdefault(step, {})
        per_round[int(host)] = state_digest
        if (
            len(per_round) >= self.cfg.divergence_min_hosts
            and len(set(per_round.values())) > 1
            and step not in self._diverged_steps
        ):
            chunk, index, culprit = self._first_divergent_chunk(step)
            if (chunk is not None and culprit is None
                    and step not in self._pending_divergence):
                # provenance is flowing but the culprit is still ambiguous
                # (e.g. a 1-vs-1 split with more acks on the way): hold the
                # alert until a later ack breaks the tie or the round
                # settles — divergence itself is already certain, only the
                # attribution improves by waiting
                self._pending_divergence.add(step)
                return
            self._pending_divergence.discard(step)
            self._emit_divergence(step)

    def _emit_divergence(self, step: int) -> None:
        per_round = self._digests.get(step) or {}
        self._diverged_steps.add(step)
        chunk, index, culprit = self._first_divergent_chunk(step)
        msg = (f"hosts disagree on state at step {step}: "
               f"{sorted(set(per_round.values()))}")
        if chunk is not None:
            who = (f"host {culprit}" if culprit is not None
                   else "an unidentified host")
            msg = (f"hosts disagree on state at step {step}: first "
                   f"divergent chunk {chunk}[{index}] forked at step "
                   f"{step} on {who}")
        self._emit(Alert(
            "digest_divergence", SEV_CRITICAL, step=step,
            host=culprit,
            value=float(len(set(per_round.values()))),
            chunk=chunk, chunk_index=index,
            message=msg,
        ))

    def _first_divergent_chunk(
        self, step: int,
    ) -> tuple[str | None, int | None, int | None]:
        """First (sorted path, lowest index) chunk whose digests differ
        across the hosts that reported tables for ``step``, plus the
        culprit host: the one off the committed baseline when one exists,
        else the minority digest's host (None on an unbreakable tie)."""
        tables = self._chunks.get(step) or {}
        if len(tables) < 2:
            return None, None, None
        paths = sorted(set().union(*(t.keys() for t in tables.values())))
        for path in paths:
            per_host = {h: t[path] for h, t in tables.items() if path in t}
            if len(per_host) < 2:
                continue
            n = min(len(v) for v in per_host.values())
            for i in range(n):
                vals = {h: v[i] for h, v in per_host.items()}
                if len(set(vals.values())) <= 1:
                    continue
                base = self._chunk_baseline.get((path, i))
                if base is not None:
                    # trust the baseline only when exactly one host left
                    # it: training legitimately moves every live chunk
                    # off the last committed digest, so "off baseline"
                    # alone cannot separate culprit from victim
                    off = sorted(h for h, d in vals.items() if d != base)
                    if len(off) == 1:
                        return path, i, off[0]
                # blame the minority digest, if there is one
                counts: dict[int, list[int]] = {}
                for h, d in vals.items():
                    counts.setdefault(d, []).append(h)
                minority = sorted(counts.values(), key=len)
                if len(minority) > 1 and len(minority[0]) < len(minority[1]):
                    return path, i, sorted(minority[0])[0]
                return path, i, None
        return None, None, None

    def on_round(self, rec: dict) -> None:
        """One round record (RoundRecord.as_dict() shape), at decision."""
        step = rec.get("step")
        if step is not None and int(step) in self._pending_divergence:
            # the round settled with the culprit still ambiguous: emit the
            # held divergence now, with whatever provenance arrived
            self._pending_divergence.discard(int(step))
            self._emit_divergence(int(step))
        if rec.get("status") == "aborted":
            self._consecutive_aborts += 1
            self._emit(Alert(
                "round_abort", SEV_WARNING, step=step,
                message=str(rec.get("reason", "")),
            ))
            if (
                self._consecutive_aborts >= self.cfg.abort_rate_window
                and not self._abort_rate_alerted
            ):
                self._abort_rate_alerted = True
                self._emit(Alert(
                    "abort_rate", SEV_CRITICAL, step=step,
                    value=float(self._consecutive_aborts),
                    limit=float(self.cfg.abort_rate_window),
                    message=f"{self._consecutive_aborts} consecutive "
                            f"aborted rounds",
                ))
            return
        self._consecutive_aborts = 0
        self._abort_rate_alerted = False
        if step is not None:  # committed: the round's digest set is settled
            self._digests.pop(int(step), None)
            tables = self._chunks.pop(int(step), None)
            if tables and int(step) not in self._diverged_steps:
                # all hosts agreed this round: their chunk digests become
                # the baseline future divergences are judged against
                for path in set().union(*(t.keys() for t in tables.values())):
                    cols = [t[path] for t in tables.values() if path in t]
                    for i, d in enumerate(cols[0]):
                        if all(len(c) > i and c[i] == d for c in cols):
                            self._chunk_baseline[(path, i)] = d
        round_s = float(rec.get("round_s") or 0.0)
        stall_s = float(rec.get("stall_us") or 0.0) / 1e6
        if round_s > 0 and stall_s / round_s > self.cfg.stall_ratio_max:
            self._emit(Alert(
                "stall_ratio", SEV_WARNING, step=step,
                value=round(stall_s / round_s, 3),
                limit=self.cfg.stall_ratio_max,
                message=f"sync stall {stall_s:.3f}s vs round "
                        f"{round_s:.3f}s",
            ))
        stragglers = rec.get("stragglers") or []
        for h in stragglers:
            self._emit(Alert(
                "straggler", SEV_WARNING, host=int(h), step=step,
                message=f"host {h} persist duration is an outlier",
            ))

    # -- membership rules --------------------------------------------------

    def on_death(self, host: int, reason: str) -> None:
        self._steps.pop(int(host), None)
        self._emit(Alert(
            "worker_death", SEV_WARNING, host=int(host),
            message=reason,
        ))

    def on_proxy_host_death(self, name: str, worker: int) -> None:
        self._emit(Alert(
            "proxy_host_death", SEV_WARNING, host=int(worker),
            message=f"proxy endpoint {name!r} reported dead by worker "
                    f"{worker}",
        ))
