from repro.optim.optimizers import (
    Optimizer,
    adamw,
    adafactor,
    q8adam,
    get_optimizer,
    global_norm,
    clip_by_global_norm,
)
from repro.optim.schedule import warmup_cosine

__all__ = [
    "Optimizer",
    "adamw",
    "adafactor",
    "q8adam",
    "get_optimizer",
    "global_norm",
    "clip_by_global_norm",
    "warmup_cosine",
]
