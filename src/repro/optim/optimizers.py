"""Optimizers (self-contained; optax is not available offline).

Three state-memory design points (DESIGN §6 — required to *fit* the ≥100B
configs on a 16 GiB/chip pod):

  - adamw      : m, v in f32            (10 bytes/param with bf16 params)
  - adafactor  : factored second moment (~2 bytes/param + O(rows+cols))
  - q8adam     : m, v int8 + per-block f32 scales (~4.03 bytes/param)

All optimizer states are dict pytrees of arrays — checkpointable by the
CRUM core like everything else, and shardable with the same rules as their
parameters (ZeRO-style when FSDP is on).
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Any, Callable

import jax
import jax.numpy as jnp
import numpy as np


@dataclass(frozen=True)
class Optimizer:
    init: Callable[[Any], Any]
    update: Callable[[Any, Any, Any, jax.Array], tuple[Any, Any]]
    """update(grads, state, params, step) -> (new_params, new_state)"""


def global_norm(tree: Any) -> jax.Array:
    leaves = jax.tree.leaves(tree)
    return jnp.sqrt(
        sum(jnp.sum(jnp.square(x.astype(jnp.float32))) for x in leaves)
    )


def clip_by_global_norm(tree: Any, max_norm: float) -> tuple[Any, jax.Array]:
    norm = global_norm(tree)
    scale = jnp.minimum(1.0, max_norm / (norm + 1e-9))
    return jax.tree.map(lambda x: (x.astype(jnp.float32) * scale).astype(x.dtype), tree), norm


# ---------------------------------------------------------------------------
# AdamW
# ---------------------------------------------------------------------------

def adamw(
    lr: float | Callable = 1e-3,
    *,
    b1: float = 0.9,
    b2: float = 0.95,
    eps: float = 1e-8,
    weight_decay: float = 0.01,
    max_grad_norm: float = 1.0,
) -> Optimizer:
    lr_fn = lr if callable(lr) else (lambda _: lr)

    def init(params):
        return {
            "m": jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32), params),
            "v": jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32), params),
        }

    def update(grads, state, params, step):
        grads, gnorm = clip_by_global_norm(grads, max_grad_norm)
        t = step.astype(jnp.float32) + 1.0
        lr_t = lr_fn(step)
        bc1 = 1.0 - b1**t
        bc2 = 1.0 - b2**t

        def upd(p, g, m, v):
            g = g.astype(jnp.float32)
            m_new = b1 * m + (1 - b1) * g
            v_new = b2 * v + (1 - b2) * g * g
            u = (m_new / bc1) / (jnp.sqrt(v_new / bc2) + eps)
            u = u + weight_decay * p.astype(jnp.float32)
            return (p.astype(jnp.float32) - lr_t * u).astype(p.dtype), m_new, v_new

        flat = jax.tree.map(upd, params, grads, state["m"], state["v"])
        new_params = jax.tree.map(lambda t3: t3[0], flat, is_leaf=lambda x: isinstance(x, tuple))
        new_m = jax.tree.map(lambda t3: t3[1], flat, is_leaf=lambda x: isinstance(x, tuple))
        new_v = jax.tree.map(lambda t3: t3[2], flat, is_leaf=lambda x: isinstance(x, tuple))
        return new_params, {"m": new_m, "v": new_v}

    return Optimizer(init=init, update=update)


# ---------------------------------------------------------------------------
# Adafactor (factored second moments, no momentum)
# ---------------------------------------------------------------------------

def adafactor(
    lr: float | Callable = 1e-3,
    *,
    eps: float = 1e-30,
    weight_decay: float = 0.0,
    max_grad_norm: float = 1.0,
    decay: float = 0.8,
) -> Optimizer:
    lr_fn = lr if callable(lr) else (lambda _: lr)

    def _factored(shape) -> bool:
        return len(shape) >= 2 and shape[-1] > 1 and shape[-2] > 1

    def init(params):
        def per_leaf(p):
            if _factored(p.shape):
                return {
                    "vr": jnp.zeros(p.shape[:-1], jnp.float32),
                    "vc": jnp.zeros(p.shape[:-2] + p.shape[-1:], jnp.float32),
                }
            return {"v": jnp.zeros(p.shape, jnp.float32)}

        return {"f": jax.tree.map(per_leaf, params)}

    def update(grads, state, params, step):
        grads, _ = clip_by_global_norm(grads, max_grad_norm)
        t = step.astype(jnp.float32) + 1.0
        beta = 1.0 - t ** (-decay)
        lr_t = lr_fn(step)

        def upd(p, g, s):
            g = g.astype(jnp.float32)
            g2 = g * g + eps
            if "vr" in s:
                vr = beta * s["vr"] + (1 - beta) * g2.mean(axis=-1)
                vc = beta * s["vc"] + (1 - beta) * g2.mean(axis=-2)
                denom = (
                    vr[..., None]
                    * vc[..., None, :]
                    / jnp.clip(vr.mean(axis=-1)[..., None, None], 1e-30)
                )
                u = g / jnp.sqrt(denom + eps)
                s_new = {"vr": vr, "vc": vc}
            else:
                v = beta * s["v"] + (1 - beta) * g2
                u = g / jnp.sqrt(v + eps)
                s_new = {"v": v}
            # update-norm clipping (adafactor's d=1.0 rule, simplified)
            rms = jnp.sqrt(jnp.mean(u * u) + 1e-30)
            u = u / jnp.maximum(1.0, rms)
            u = u + weight_decay * p.astype(jnp.float32)
            return (p.astype(jnp.float32) - lr_t * u).astype(p.dtype), s_new

        pairs = jax.tree.map(
            upd, params, grads, state["f"],
            is_leaf=lambda x: isinstance(x, dict) and ("vr" in x or "v" in x),
        )
        is_pair = lambda x: isinstance(x, tuple)
        new_params = jax.tree.map(lambda t2: t2[0], pairs, is_leaf=is_pair)
        new_f = jax.tree.map(lambda t2: t2[1], pairs, is_leaf=is_pair)
        return new_params, {"f": new_f}

    return Optimizer(init=init, update=update)


# ---------------------------------------------------------------------------
# 8-bit Adam (block-quantized moments)
# ---------------------------------------------------------------------------

_Q8_BLOCK = 256


def _q8_encode(x: jax.Array) -> dict:
    flat = x.reshape(-1)
    n = flat.shape[0]
    pad = (-n) % _Q8_BLOCK
    if pad:
        flat = jnp.concatenate([flat, jnp.zeros((pad,), flat.dtype)])
    blocks = flat.reshape(-1, _Q8_BLOCK)
    scale = jnp.max(jnp.abs(blocks), axis=1) / 127.0 + 1e-12
    q = jnp.clip(jnp.round(blocks / scale[:, None]), -127, 127).astype(jnp.int8)
    return {"q": q, "s": scale.astype(jnp.float32)}


def _q8_decode(enc: dict, shape) -> jax.Array:
    x = (enc["q"].astype(jnp.float32) * enc["s"][:, None]).reshape(-1)
    n = int(np.prod(shape, dtype=np.int64)) if shape else 1
    return x[:n].reshape(shape)


def q8adam(
    lr: float | Callable = 1e-3,
    *,
    b1: float = 0.9,
    b2: float = 0.95,
    eps: float = 1e-8,
    weight_decay: float = 0.01,
    max_grad_norm: float = 1.0,
) -> Optimizer:
    """Quantized-state Adam: ~3 bytes/param of optimizer state.

    m: int8 blocks + per-block f32 scales (symmetric linear quantization is
    fine for the first moment). v: bf16 — the second moment spans too many
    decades for linear int8 (small entries snap to 0 and the rsqrt update
    explodes; observed divergence), while bf16's f32-range exponent keeps
    the ratio error at ~0.4%.
    """
    lr_fn = lr if callable(lr) else (lambda _: lr)

    def init(params):
        return {
            "m": jax.tree.map(
                lambda p: _q8_encode(jnp.zeros(p.shape, jnp.float32)), params
            ),
            "v": jax.tree.map(
                lambda p: jnp.zeros(p.shape, jnp.bfloat16), params
            ),
        }

    def update(grads, state, params, step):
        grads, _ = clip_by_global_norm(grads, max_grad_norm)
        t = step.astype(jnp.float32) + 1.0
        lr_t = lr_fn(step)
        bc1, bc2 = 1.0 - b1**t, 1.0 - b2**t

        def upd(p, g, m_enc, v_bf):
            g = g.astype(jnp.float32)
            m = b1 * _q8_decode(m_enc, p.shape) + (1 - b1) * g
            v = b2 * v_bf.astype(jnp.float32) + (1 - b2) * g * g
            u = (m / bc1) / (jnp.sqrt(v / bc2) + eps) + weight_decay * p.astype(jnp.float32)
            return (
                (p.astype(jnp.float32) - lr_t * u).astype(p.dtype),
                _q8_encode(m),
                v.astype(jnp.bfloat16),
            )

        is_enc = lambda x: isinstance(x, dict) and "q" in x
        triples = jax.tree.map(
            upd, params, grads, state["m"], state["v"], is_leaf=is_enc
        )
        is_tri = lambda x: isinstance(x, tuple)
        new_params = jax.tree.map(lambda t3: t3[0], triples, is_leaf=is_tri)
        new_m = jax.tree.map(lambda t3: t3[1], triples, is_leaf=is_tri)
        new_v = jax.tree.map(lambda t3: t3[2], triples, is_leaf=is_tri)
        return new_params, {"m": new_m, "v": new_v}

    return Optimizer(init=init, update=update)


def get_optimizer(name: str, lr: float | Callable = 1e-3, **kw) -> Optimizer:
    if name == "adamw":
        return adamw(lr, **kw)
    if name == "adafactor":
        return adafactor(lr, **kw)
    if name == "q8adam":
        return q8adam(lr, **kw)
    raise KeyError(f"unknown optimizer {name!r}")
