"""Device-proxy subsystem (paper §3): compute in a restartable proxy process.

The application process stays "device-clean" — it never owns device state,
only a host mirror — while a separate proxy process executes the pipelined
step stream. Every state-creating call is appended to a durable API log,
so a killed proxy is respawned and replayed to the last synced step with
bit-identical results, and restart re-creates device state by replaying
the logged allocations and pushing the data back (RestoreManager's
``restore_into_proxy``).
"""
from repro.proxy.api_log import ApiLog, iter_records
from repro.proxy.client import DeviceProxy
from repro.proxy.programs import (
    StepProgram,
    list_step_programs,
    make_program,
    register_step_program,
)
from repro.proxy.protocol import ProxyDiedError, ProxyServiceConfig
from repro.proxy.segments import (
    PrivateTable,
    SegmentTable,
    SharedSegment,
    StateTable,
    default_segment_dir,
)
from repro.proxy.supervisor import ProxyRunner

__all__ = [
    "ApiLog", "iter_records",
    "DeviceProxy", "ProxyDiedError", "ProxyServiceConfig",
    "StateTable", "PrivateTable",
    "SegmentTable", "SharedSegment", "default_segment_dir",
    "StepProgram", "make_program", "register_step_program",
    "list_step_programs",
    "ProxyRunner",
]
