"""The durable API log — CRUM §3.4 / CRAC's replayable call record.

Every state-creating proxy call the application issues (program
construction, register/alloc, upload, step) is appended here *before* it
is sent, so the log is always a superset of what the proxy has executed.
Restart = replay: a fresh proxy gets the PROGRAM and REGISTER calls
re-issued, the last synced snapshot pushed back through the segments
(UPLOAD), and every STEP after the last SYNC re-executed — deterministic
step programs make the result bit-identical to the uninterrupted run.

Records are u32-length-prefixed msgpack maps (the coordinator protocol's
framing, applied to a file) with a ``call`` discriminator::

    {"call": "program",    "spec": {...}}
    {"call": "register",   "layout": {...}, "chunk_bytes": int, "workdir": str}
    {"call": "upload",     "step": int, "paths": [..] | None}   None = all
    {"call": "step",       "step": int}
    {"call": "sync_begin", "epoch": int, "step": int}
    {"call": "sync",       "step": int, "digest": str, "epoch": int?}

SYNC records are write-side only (the proxy never reads them): they mark
the replay low-water line — everything at or before the last synced step
is already captured in the segments' bytes.

Pipelined epoch syncs split into two records because issue and ack are no
longer the same moment: ``sync_begin`` is appended when the SYNC{epoch}
frame is *issued* (so its position marks the step boundary inside the
pipelined call stream), and the ``sync`` ack record — appended only once
SYNCED{epoch} arrived and the mirror was captured — is what makes that
boundary a replay watermark. An issued-but-unacked epoch sync is NOT a
watermark (the mirror never saw its image); replay re-executes the steps
before it and re-issues the SYNC at the same position, so the application
can still collect the ack after a kill.
"""
from __future__ import annotations

import os
import struct
from typing import Any, Iterator

import msgpack

from repro.obs import metrics as obs_metrics

_LEN = struct.Struct("<I")
MAX_RECORD = 64 << 20  # a single log record this large is a bug


class ApiLog:
    """Append-only call log; survives proxy death (and fsync makes it
    survive host power loss, the same knob the checkpointer exposes)."""

    def __init__(self, path: str, *, truncate: bool = False, fsync: bool = False):
        self.path = path
        self.fsync = fsync
        d = os.path.dirname(path)
        if d:
            os.makedirs(d, exist_ok=True)
        self._f = open(path, "wb" if truncate else "ab")

    def append(self, record: dict[str, Any]) -> None:
        data = msgpack.packb(record, use_bin_type=True)
        if len(data) > MAX_RECORD:
            raise ValueError(f"API log record too large ({len(data)} bytes)")
        self._f.write(_LEN.pack(len(data)) + data)
        self._f.flush()
        if self.fsync:
            os.fsync(self._f.fileno())
        obs_metrics.REGISTRY.inc("apilog_records_total")
        obs_metrics.REGISTRY.inc("apilog_bytes_total", len(data))

    def close(self) -> None:
        if not self._f.closed:
            self._f.close()

    # -- read side -------------------------------------------------------------
    def records(self) -> list[dict[str, Any]]:
        return list(iter_records(self.path))

    def last_synced_step(self) -> int:
        """The replay low-water line: newest SYNC record's step (0 if none)."""
        last = 0
        for rec in iter_records(self.path):
            if rec.get("call") == "sync":
                last = int(rec["step"])
        return last

    def replay_plan(self) -> tuple[dict | None, dict | None, list[int]]:
        """(program_spec, register_record, steps_to_replay).

        The step-only view of :meth:`replay_actions` — kept for callers
        that predate pipelined epoch syncs and only re-execute STEPs.
        """
        program, register, actions = self.replay_actions()
        return program, register, [a[1] for a in actions if a[0] == "step"]

    def replay_actions(
        self,
    ) -> tuple[dict | None, dict | None, list[tuple]]:
        """(program_spec, register_record, ordered replay actions).

        Actions are the calls a fresh proxy must re-execute, in pipeline
        order, on top of the pushed mirror: ``("step", n)`` and
        ``("sync", epoch, step)`` (an issued-but-unacked epoch sync that
        must be re-issued at the same boundary so its SYNCED{epoch} can
        still be collected).

        Watermarks are *positional*: an upload or a legacy (un-epoched)
        sync record captures the device state at that point — everything
        before it is in the mirror. An epoch sync's ack record instead
        clears up to *its own sync_begin position*: the mirror holds the
        epoch-boundary image, so steps issued while that sync was in
        flight (logged after the begin, executed after the boundary) still
        replay.
        """
        program = register = None
        actions: list[tuple] = []
        for rec in iter_records(self.path):
            call = rec.get("call")
            if call == "program":
                program = rec.get("spec")
            elif call == "register":
                register = rec
                actions = []
            elif call == "upload":
                actions = []  # snapshot watermark: earlier calls captured
            elif call == "step":
                actions.append(("step", int(rec["step"])))
            elif call == "sync_begin":
                actions.append(
                    ("sync", int(rec["epoch"]), int(rec.get("step", 0)))
                )
            elif call == "sync":
                epoch = rec.get("epoch")
                if epoch is None:
                    actions = []  # legacy barrier sync: positional watermark
                    continue
                for i, a in enumerate(actions):
                    if a[0] == "sync" and a[1] == int(epoch):
                        del actions[: i + 1]
                        break
        return program, register, actions


def iter_records(path: str) -> Iterator[dict[str, Any]]:
    """Stream records; a torn tail (crash mid-append) ends iteration
    cleanly — every fully-written record before it is still replayable."""
    if not os.path.exists(path):
        return
    with open(path, "rb") as f:
        while True:
            hdr = f.read(_LEN.size)
            if len(hdr) < _LEN.size:
                return
            (n,) = _LEN.unpack(hdr)
            if n > MAX_RECORD:
                return  # corrupt length: treat as torn tail
            data = f.read(n)
            if len(data) < n:
                return
            yield msgpack.unpackb(data, raw=False, strict_map_key=False)
