"""The durable API log — CRUM §3.4 / CRAC's replayable call record.

Every state-creating proxy call the application issues (program
construction, register/alloc, upload, step) is appended here *before* it
is sent, so the log is always a superset of what the proxy has executed.
Restart = replay: a fresh proxy gets the PROGRAM and REGISTER calls
re-issued, the last synced snapshot pushed back through the segments
(UPLOAD), and every STEP after the last SYNC re-executed — deterministic
step programs make the result bit-identical to the uninterrupted run.

Records are u32-length-prefixed msgpack maps (the coordinator protocol's
framing, applied to a file) with a ``call`` discriminator::

    {"call": "program",  "spec": {...}}
    {"call": "register", "layout": {...}, "chunk_bytes": int, "workdir": str}
    {"call": "upload",   "step": int, "paths": [..] | None}   None = all
    {"call": "step",     "step": int}
    {"call": "sync",     "step": int, "digest": str}

SYNC records are write-side only (the proxy never reads them): they mark
the replay low-water line — everything at or before the last synced step
is already captured in the segments' bytes.
"""
from __future__ import annotations

import os
import struct
from typing import Any, Iterator

import msgpack

_LEN = struct.Struct("<I")
MAX_RECORD = 64 << 20  # a single log record this large is a bug


class ApiLog:
    """Append-only call log; survives proxy death (and fsync makes it
    survive host power loss, the same knob the checkpointer exposes)."""

    def __init__(self, path: str, *, truncate: bool = False, fsync: bool = False):
        self.path = path
        self.fsync = fsync
        d = os.path.dirname(path)
        if d:
            os.makedirs(d, exist_ok=True)
        self._f = open(path, "wb" if truncate else "ab")

    def append(self, record: dict[str, Any]) -> None:
        data = msgpack.packb(record, use_bin_type=True)
        if len(data) > MAX_RECORD:
            raise ValueError(f"API log record too large ({len(data)} bytes)")
        self._f.write(_LEN.pack(len(data)) + data)
        self._f.flush()
        if self.fsync:
            os.fsync(self._f.fileno())

    def close(self) -> None:
        if not self._f.closed:
            self._f.close()

    # -- read side -------------------------------------------------------------
    def records(self) -> list[dict[str, Any]]:
        return list(iter_records(self.path))

    def last_synced_step(self) -> int:
        """The replay low-water line: newest SYNC record's step (0 if none)."""
        last = 0
        for rec in iter_records(self.path):
            if rec.get("call") == "sync":
                last = int(rec["step"])
        return last

    def replay_plan(self) -> tuple[dict | None, dict | None, list[int]]:
        """(program_spec, register_record, steps_to_replay).

        Everything a fresh proxy needs: the program, the allocation table,
        and the step calls to re-execute on top of the pushed snapshot.
        The watermark is *positional*: a sync OR upload record captures the
        device state at that point (the segments/mirror hold it), so only
        step calls appearing after the latest such record are replayed —
        an upload (e.g. a restore pushed onto a live runner) supersedes
        steps issued before it.
        """
        program = register = None
        steps: list[int] = []
        for rec in iter_records(self.path):
            call = rec.get("call")
            if call == "program":
                program = rec.get("spec")
            elif call == "register":
                register = rec
                steps = []
            elif call in ("sync", "upload"):
                steps = []  # snapshot watermark: earlier steps are captured
            elif call == "step":
                steps.append(int(rec["step"]))
        return program, register, steps


def iter_records(path: str) -> Iterator[dict[str, Any]]:
    """Stream records; a torn tail (crash mid-append) ends iteration
    cleanly — every fully-written record before it is still replayable."""
    if not os.path.exists(path):
        return
    with open(path, "rb") as f:
        while True:
            hdr = f.read(_LEN.size)
            if len(hdr) < _LEN.size:
                return
            (n,) = _LEN.unpack(hdr)
            if n > MAX_RECORD:
                return  # corrupt length: treat as torn tail
            data = f.read(n)
            if len(data) < n:
                return
            yield msgpack.unpackb(data, raw=False, strict_map_key=False)
