"""DeviceProxy — the application-side handle on ONE proxy incarnation.

Transport-only: spawns the proxy process (multiprocessing *spawn*, safe
with an initialized JAX in the parent), accepts its loopback connection,
and speaks the protocol. Pipelining lives here — ``step()`` is
fire-and-forget with an auto-flush watermark so the app runs ahead of the
proxy exactly like ``core/drain.py`` describes the device pipeline — but
*durability and replay do not*: the API log and respawn policy belong to
``ProxyRunner`` (supervisor.py), so a dead incarnation is simply dropped
and a new DeviceProxy attached to the same segments.

Every transport failure raises :class:`ProxyDiedError`; callers that can
replay (the runner) catch it, everyone else propagates it.
"""
from __future__ import annotations

import multiprocessing as mp
import socket
import time
from typing import Any

from repro.proxy.protocol import (
    MSG_ERR,
    MSG_FLUSH,
    MSG_FLUSHED,
    MSG_OK,
    MSG_PROGRAM,
    MSG_REGISTER,
    MSG_SHUTDOWN,
    MSG_STEP,
    MSG_SYNC,
    MSG_SYNCED,
    MSG_UPLOAD,
    Connection,
    ProxyDiedError,
    ProxyServiceConfig,
)
from repro.proxy.service import proxy_entry


class DeviceProxy:
    def __init__(
        self,
        *,
        mp_context: str = "spawn",
        start_timeout_s: float = 120.0,
        op_timeout_s: float = 120.0,
        max_pipeline: int = 64,
        jax_platforms: str | None = "cpu",
        name: str = "crum-proxy",
    ):
        self.ctx = mp.get_context(mp_context)
        self.start_timeout_s = start_timeout_s
        self.op_timeout_s = op_timeout_s
        self.max_pipeline = int(max_pipeline)
        self.jax_platforms = jax_platforms
        self.name = name
        self.proc: mp.Process | None = None
        self.conn: Connection | None = None
        self.inflight = 0  # STEP frames sent since the last barrier
        self._seq = 0

    # -- lifecycle ---------------------------------------------------------------
    def start(self) -> "DeviceProxy":
        listener = socket.socket(socket.AF_INET, socket.SOCK_STREAM)
        listener.setsockopt(socket.SOL_SOCKET, socket.SO_REUSEADDR, 1)
        listener.bind(("127.0.0.1", 0))
        listener.listen(1)
        host, port = listener.getsockname()
        cfg = ProxyServiceConfig(
            host=host, port=port, jax_platforms=self.jax_platforms
        )
        self.proc = self.ctx.Process(
            target=proxy_entry, args=(cfg,), name=self.name, daemon=True
        )
        self.proc.start()
        listener.settimeout(self.start_timeout_s)
        try:
            sock, _ = listener.accept()
        except socket.timeout:
            raise ProxyDiedError(
                f"proxy did not connect within {self.start_timeout_s}s"
            ) from None
        finally:
            listener.close()
        sock.setsockopt(socket.IPPROTO_TCP, socket.TCP_NODELAY, 1)
        self.conn = Connection(sock)
        self.conn.settimeout(1.0)
        return self

    @property
    def pid(self) -> int | None:
        return self.proc.pid if self.proc is not None else None

    def alive(self) -> bool:
        return self.proc is not None and self.proc.is_alive()

    def kill(self) -> None:
        """Hard-kill the incarnation (failure drills: SIGKILL mid-pipeline)."""
        if self.proc is not None and self.proc.is_alive():
            self.proc.kill()
            self.proc.join(timeout=10)

    def close(self, *, graceful: bool = True) -> None:
        if self.conn is not None:
            if graceful and self.alive():
                try:
                    self.conn.send(MSG_SHUTDOWN)
                except OSError:
                    pass
            self.conn.close()
            self.conn = None
        if self.proc is not None:
            self.proc.join(timeout=10)
            if self.proc.is_alive():
                self.proc.kill()
                self.proc.join(timeout=10)
            self.proc = None

    # -- transport helpers --------------------------------------------------------
    def _send(self, mtype: str, **fields: Any) -> None:
        if self.conn is None:
            raise ProxyDiedError("proxy connection is closed")
        try:
            self.conn.send(mtype, **fields)
        except OSError as e:
            raise ProxyDiedError(f"send({mtype}) failed: {e}") from e

    def _recv_reply(self, want: str, *, timeout: float | None = None) -> dict:
        deadline = time.monotonic() + (timeout or self.op_timeout_s)
        while True:
            if time.monotonic() > deadline:
                raise ProxyDiedError(
                    f"no {want} reply within {timeout or self.op_timeout_s}s "
                    f"(proxy {'alive' if self.alive() else 'dead'})"
                )
            try:
                msg = self.conn.recv()
            except (socket.timeout, TimeoutError):
                if not self.alive():
                    raise ProxyDiedError(
                        f"proxy died while waiting for {want}"
                    ) from None
                continue
            except OSError as e:
                raise ProxyDiedError(f"recv failed: {e}") from e
            if msg is None:
                raise ProxyDiedError(f"proxy EOF while waiting for {want}")
            mtype = msg.get("type")
            if mtype == MSG_ERR:
                raise RuntimeError(
                    f"proxy call {msg.get('op')} failed: {msg.get('error')}"
                )
            if mtype == want:
                return msg
            # stale frame from before a died-and-replayed call: drop it

    def _call(self, mtype: str, *, reply: str = MSG_OK, **fields: Any) -> dict:
        self._send(mtype, **fields)
        return self._recv_reply(reply)

    # -- the proxied API -----------------------------------------------------------
    def send_program(self, spec: dict) -> None:
        self._call(MSG_PROGRAM, spec=spec)

    def register(
        self,
        workdir: str,
        layout: dict,
        *,
        chunk_bytes: int,
        device_capacity_bytes: int | None = None,
        page_bytes: int | None = None,
        eviction_policy: str = "lru",
    ) -> None:
        fields: dict[str, Any] = dict(
            workdir=workdir, layout=layout, chunk_bytes=chunk_bytes
        )
        if device_capacity_bytes is not None:
            # the proxy hosts its device state in a ManagedSpace: a state
            # larger than this budget pages under the proxy's own arena
            fields.update(
                device_capacity_bytes=int(device_capacity_bytes),
                page_bytes=page_bytes,
                eviction_policy=eviction_policy,
            )
        self._call(MSG_REGISTER, **fields)
        self.inflight = 0

    def upload(
        self,
        *,
        step: int,
        paths: list[str] | None = None,
        chunks: dict[str, list[int]] | None = None,
    ) -> dict:
        """Full upload (``paths``/None) or chunk-delta (``chunks``: only
        those segment chunk ranges are ingested)."""
        return self._call(MSG_UPLOAD, step=step, paths=paths, chunks=chunks)

    def step(self, step: int) -> None:
        """Pipelined: returns as soon as the frame is written. Auto-flushes
        at the watermark so the app never runs unboundedly ahead."""
        self._send(MSG_STEP, step=int(step))
        self.inflight += 1
        if self.inflight >= self.max_pipeline:
            self.flush()

    def flush(self) -> dict:
        """Pipeline barrier: the proxy has executed everything sent so far."""
        self._seq += 1
        self._send(MSG_FLUSH, seq=self._seq)
        msg = self._recv_reply(MSG_FLUSHED)
        self.inflight = 0
        return msg

    def sync(self, *, timeout: float | None = None) -> dict:
        """Flush + device->segments sync; returns the SYNCED frame."""
        self._send(MSG_SYNC)
        msg = self._recv_reply(MSG_SYNCED, timeout=timeout)
        self.inflight = 0
        return msg
