"""DeviceProxy — the application-side handle on ONE proxy incarnation.

Transport-only: brings up the proxy process and speaks the protocol. Two
placement modes:

  local (default)   spawn the proxy process (multiprocessing *spawn*, safe
                    with an initialized JAX in the parent) and accept its
                    loopback connection.
  endpoint=(h, p)   connect OUT to a proxy-host daemon
                    (``repro.remote.host``) that serves the proxy session
                    remotely — no child process exists here, and liveness
                    is the connection itself.

Pipelining lives here — ``step()`` is fire-and-forget with an auto-flush
watermark so the app runs ahead of the proxy exactly like ``core/drain.py``
describes the device pipeline — but *durability and replay do not*: the
API log and respawn policy belong to ``ProxyRunner`` (supervisor.py), so a
dead incarnation is simply dropped and a new DeviceProxy attached to the
same data plane.

Every transport failure raises :class:`ProxyDiedError` — and closes the
socket first, so a dropped incarnation never leaks its fd; callers that
can replay (the runner) catch it, everyone else propagates it.
"""
from __future__ import annotations

import multiprocessing as mp
import socket
import time
from typing import Any, Callable

from repro.obs import trace as obs_trace
from repro.proxy.protocol import (
    MSG_CHUNKS,
    MSG_ERR,
    MSG_FLUSH,
    MSG_FLUSHED,
    MSG_OK,
    MSG_PROGRAM,
    MSG_REGISTER,
    MSG_SHUTDOWN,
    MSG_STEP,
    MSG_SYNC,
    MSG_SYNCED,
    MSG_UPLOAD,
    Connection,
    ProxyDiedError,
    ProxyServiceConfig,
    connect,
)
from repro.proxy.service import proxy_entry


class DeviceProxy:
    def __init__(
        self,
        *,
        endpoint: tuple[str, int] | None = None,
        mp_context: str = "spawn",
        start_timeout_s: float = 120.0,
        op_timeout_s: float = 120.0,
        max_pipeline: int = 64,
        jax_platforms: str | None = "cpu",
        name: str = "crum-proxy",
    ):
        self.endpoint = tuple(endpoint) if endpoint is not None else None
        self.ctx = mp.get_context(mp_context)
        self.start_timeout_s = start_timeout_s
        self.op_timeout_s = op_timeout_s
        self.max_pipeline = int(max_pipeline)
        self.jax_platforms = jax_platforms
        self.name = name
        self.proc: mp.Process | None = None
        self.conn: Connection | None = None
        self.inflight = 0  # STEP frames sent since the last barrier
        self._seq = 0
        # streamed transport: CHUNKS frames arriving ahead of a SYNCED
        # reply are handed here (the runner wires its transport's ingest)
        self.on_data: Callable[[dict], None] | None = None
        # pipelined epoch SYNCs: SYNCED{epoch} frames that arrive while we
        # are waiting for something else are parked here until collected —
        # the asynchronous half of the non-barrier sync path
        self._synced: dict[int, dict] = {}
        # inflight watermark at each epoch's SYNC frame: once SYNCED{epoch}
        # arrives, everything sent before that SYNC has executed
        self._sync_marks: dict[int, int] = {}

    # -- lifecycle ---------------------------------------------------------------
    def start(self) -> "DeviceProxy":
        if self.endpoint is not None:
            try:
                self.conn = connect(self.endpoint, timeout=self.start_timeout_s)
            except OSError as e:
                raise ProxyDiedError(
                    f"proxy endpoint {self.endpoint} unreachable: {e}"
                ) from e
            self.conn.settimeout(1.0)
            return self
        listener = socket.socket(socket.AF_INET, socket.SOCK_STREAM)
        listener.setsockopt(socket.SOL_SOCKET, socket.SO_REUSEADDR, 1)
        listener.bind(("127.0.0.1", 0))
        listener.listen(1)
        host, port = listener.getsockname()
        tr = obs_trace.get()
        cfg = ProxyServiceConfig(
            host=host, port=port, jax_platforms=self.jax_platforms,
            obs_dir=tr.obs_dir if tr is not None else None,
            obs_run=tr.run_id if tr is not None else None,
        )
        self.proc = self.ctx.Process(
            target=proxy_entry, args=(cfg,), name=self.name, daemon=True
        )
        self.proc.start()
        listener.settimeout(self.start_timeout_s)
        try:
            sock, _ = listener.accept()
        except socket.timeout:
            # the spawned child never connected: reap it, don't leak it
            self.proc.kill()
            self.proc.join(timeout=10)
            self.proc = None
            raise ProxyDiedError(
                f"proxy did not connect within {self.start_timeout_s}s"
            ) from None
        finally:
            listener.close()
        sock.setsockopt(socket.IPPROTO_TCP, socket.TCP_NODELAY, 1)
        self.conn = Connection(sock)
        self.conn.settimeout(1.0)
        return self

    @property
    def pid(self) -> int | None:
        return self.proc.pid if self.proc is not None else None

    def alive(self) -> bool:
        if self.endpoint is not None:
            return self.conn is not None
        return self.proc is not None and self.proc.is_alive()

    def kill(self) -> None:
        """Hard-kill the incarnation (failure drills: SIGKILL mid-pipeline).

        Endpoint mode has no local process to signal; the connection is
        severed instead (the drill for a *remote* proxy host is to SIGKILL
        the daemon itself)."""
        if self.proc is not None and self.proc.is_alive():
            self.proc.kill()
            self.proc.join(timeout=10)
        elif self.endpoint is not None and self.conn is not None:
            self.conn.close()
            self.conn = None

    def close(self, *, graceful: bool = True) -> None:
        if self.conn is not None:
            if graceful and self.alive():
                try:
                    self.conn.send(MSG_SHUTDOWN)
                except OSError:
                    pass
            self.conn.close()
            self.conn = None
        if self.proc is not None:
            self.proc.join(timeout=10)
            if self.proc.is_alive():
                self.proc.kill()
                self.proc.join(timeout=10)
            self.proc = None

    # -- transport helpers --------------------------------------------------------
    def _die(self, why: str, cause: BaseException | None = None) -> "ProxyDiedError":
        """Close the socket (resource hygiene: every death branch releases
        its fd) and build the error for the caller to raise."""
        if self.conn is not None:
            self.conn.close()
            self.conn = None
        obs_trace.instant("proxy.died", why=why)
        err = ProxyDiedError(why)
        err.__cause__ = cause
        return err

    def _send(self, mtype: str, **fields: Any) -> None:
        if self.conn is None:
            raise ProxyDiedError("proxy connection is closed")
        try:
            self.conn.send(mtype, **fields)
        except OSError as e:
            raise self._die(f"send({mtype}) failed: {e}", e)

    def _recv_reply(self, want: str, *, timeout: float | None = None) -> dict:
        deadline = time.monotonic() + (timeout or self.op_timeout_s)
        while True:
            if time.monotonic() > deadline:
                raise self._die(
                    f"no {want} reply within {timeout or self.op_timeout_s}s "
                    f"(proxy {'alive' if self.alive() else 'dead'})"
                )
            if self.conn is None:
                raise ProxyDiedError("proxy connection is closed")
            try:
                msg = self.conn.recv()
            except (socket.timeout, TimeoutError):
                if not self.alive():
                    raise self._die(f"proxy died while waiting for {want}")
                continue
            except OSError as e:
                raise self._die(f"recv failed: {e}", e)
            if msg is None:
                raise self._die(f"proxy EOF while waiting for {want}")
            mtype = msg.get("type")
            if mtype == MSG_CHUNKS and self.on_data is not None:
                # streamed-transport payload ahead of its SYNCED
                self.on_data(msg)
                continue
            if mtype == MSG_ERR:
                raise RuntimeError(
                    f"proxy call {msg.get('op')} failed: {msg.get('error')}"
                )
            if mtype == MSG_SYNCED and msg.get("epoch") is not None:
                # a pipelined epoch sync completed while we waited for
                # something else: park it for collect_synced() — an epoch
                # SYNCED never answers a barrier sync
                self._synced[int(msg["epoch"])] = msg
                continue
            if mtype == want:
                return msg
            # stale frame from before a died-and-replayed call: drop it

    def _call(self, mtype: str, *, reply: str = MSG_OK, **fields: Any) -> dict:
        self._send(mtype, **fields)
        return self._recv_reply(reply)

    # -- the proxied API -----------------------------------------------------------
    def send_program(self, spec: dict) -> None:
        self._call(MSG_PROGRAM, spec=spec)

    def register(self, **fields: Any) -> None:
        """REGISTER with the transport/layout/paging fields the runner's
        transport and config assembled (see protocol docstring)."""
        self._call(MSG_REGISTER, **fields)
        self.inflight = 0

    def upload(
        self,
        *,
        step: int,
        paths: list[str] | None = None,
        chunks: dict[str, list[int]] | None = None,
        payload_frames: list[dict] | None = None,
        ctx: dict | None = None,
    ) -> dict:
        """Full upload (``paths``/None) or chunk-delta (``chunks``: only
        those chunk ranges are ingested). ``payload_frames`` (streamed
        transport) are sent immediately after the UPLOAD frame."""
        n_frames = len(payload_frames) if payload_frames is not None else 0
        if ctx is None:  # untraced frames stay byte-identical
            self._send(
                MSG_UPLOAD, step=step, paths=paths, chunks=chunks,
                n_frames=n_frames,
            )
        else:
            self._send(
                MSG_UPLOAD, step=step, paths=paths, chunks=chunks,
                n_frames=n_frames, ctx=ctx,
            )
        for frame in payload_frames or ():
            self._send(MSG_CHUNKS, **frame)
        return self._recv_reply(MSG_OK)

    def step(self, step: int, *, ctx: dict | None = None) -> None:
        """Pipelined: returns as soon as the frame is written. Auto-flushes
        at the watermark so the app never runs unboundedly ahead. ``ctx``
        (optional causal context) names the span the service's handler
        will emit for this frame."""
        if ctx is None:  # untraced frames stay byte-identical
            self._send(MSG_STEP, step=int(step))
        else:
            self._send(MSG_STEP, step=int(step), ctx=ctx)
        self.inflight += 1
        if self.inflight >= self.max_pipeline:
            self.flush()

    def flush(self) -> dict:
        """Pipeline barrier: the proxy has executed everything sent so far."""
        self._seq += 1
        self._send(MSG_FLUSH, seq=self._seq)
        msg = self._recv_reply(MSG_FLUSHED)
        self.inflight = 0
        return msg

    def sync(self, *, timeout: float | None = None) -> dict:
        """Flush + device->data-plane sync; returns the SYNCED frame. On
        the streamed transport the payload CHUNKS frames are handed to
        ``on_data`` before this returns."""
        self._send(MSG_SYNC)
        msg = self._recv_reply(MSG_SYNCED, timeout=timeout)
        self.inflight = 0
        return msg

    # -- pipelined epoch sync -----------------------------------------------------
    def sync_begin(self, epoch: int, *, ctx: dict | None = None) -> None:
        """Issue SYNC{epoch} fire-and-forget: the proxy executes it in
        pipeline order (after everything sent so far), and the matching
        SYNCED{epoch} is collected later — the app keeps stepping instead
        of stalling on the boundary."""
        if ctx is None:  # untraced frames stay byte-identical
            self._send(MSG_SYNC, epoch=int(epoch))
        else:
            self._send(MSG_SYNC, epoch=int(epoch), ctx=ctx)
        self._sync_marks[int(epoch)] = self.inflight

    def poll_synced(self, epoch: int) -> dict | None:
        """Non-blocking: the parked SYNCED{epoch} if it has arrived (or
        arrives within a sub-millisecond drain of the socket), else None."""
        epoch = int(epoch)
        if epoch not in self._synced and self.conn is not None:
            old = self.conn.sock.gettimeout()
            try:
                self.conn.settimeout(0.0005)
                while epoch not in self._synced:
                    try:
                        msg = self.conn.recv()
                    except (socket.timeout, TimeoutError):
                        break
                    except OSError as e:
                        raise self._die(f"recv failed: {e}", e)
                    if msg is None:
                        raise self._die("proxy EOF while polling SYNCED")
                    self._absorb(msg)
            finally:
                if self.conn is not None:
                    self.conn.settimeout(old)
        if epoch not in self._synced:
            return None
        return self._take_synced(epoch)

    def collect_synced(self, epoch: int, *, timeout: float | None = None) -> dict:
        """Block until SYNCED{epoch} arrives and return it."""
        epoch = int(epoch)
        deadline = time.monotonic() + (timeout or self.op_timeout_s)
        while epoch not in self._synced:
            if time.monotonic() > deadline:
                raise self._die(
                    f"no SYNCED(epoch={epoch}) within "
                    f"{timeout or self.op_timeout_s}s "
                    f"(proxy {'alive' if self.alive() else 'dead'})"
                )
            if self.conn is None:
                raise ProxyDiedError("proxy connection is closed")
            try:
                msg = self.conn.recv()
            except (socket.timeout, TimeoutError):
                if not self.alive():
                    raise self._die(
                        f"proxy died while waiting for SYNCED(epoch={epoch})"
                    )
                continue
            except OSError as e:
                raise self._die(f"recv failed: {e}", e)
            if msg is None:
                raise self._die(
                    f"proxy EOF while waiting for SYNCED(epoch={epoch})"
                )
            self._absorb(msg)
        return self._take_synced(epoch)

    def _absorb(self, msg: dict) -> None:
        """Route one frame received outside a _recv_reply() wait."""
        mtype = msg.get("type")
        if mtype == MSG_CHUNKS and self.on_data is not None:
            self.on_data(msg)
        elif mtype == MSG_SYNCED and msg.get("epoch") is not None:
            self._synced[int(msg["epoch"])] = msg
        elif mtype == MSG_ERR:
            raise RuntimeError(
                f"proxy call {msg.get('op')} failed: {msg.get('error')}"
            )
        # anything else (stale FLUSHED/OK from a replayed call): drop

    def _take_synced(self, epoch: int) -> dict:
        msg = self._synced.pop(epoch)
        # everything sent before that SYNC frame has now executed
        mark = self._sync_marks.pop(epoch, 0)
        self.inflight = max(0, self.inflight - mark)
        return msg
