"""Step programs — the replayable compute the proxy executes.

CRUM's proxy does not receive closures from the application; it receives
*API calls*. A step program is the analogue: a named factory plus a
msgpack-able kwargs dict, reconstructible inside any proxy incarnation
(spawned processes share no closures) and inside replay. Determinism is
the contract: ``step(state, n)`` must be a pure function of (state, n) —
batches are derived from the step number, never streamed — so replaying
the API log into a fresh proxy reproduces device state bit-identically.

Built-ins:

    numpy_sgd   momentum-SGD-shaped numpy update (fast; tests/benchmarks)
    jax_tiny    jitted 2-layer transformer (the coord worker's jax loop)
    train_arch  a real config from repro.configs (launch/train.py --device-runner proxy)

The cluster worker's inline loops delegate their device math here too, so
inline and proxied execution share one definition of "a step".
"""
from __future__ import annotations

import time
from typing import Any, Callable

import numpy as np


class StepProgram:
    """Protocol: deterministic device-state transition, replayable by spec."""

    def init_state(self) -> Any:
        raise NotImplementedError

    def step(self, device_state: Any, step: int) -> tuple[Any, dict]:
        """(new_device_state, metrics) — pure in (device_state, step)."""
        raise NotImplementedError

    def step_with_digests(
        self, device_state: Any, step: int, chunk_bytes: int
    ) -> tuple[Any, dict, dict[str, list[int]]]:
        """Step, then emit per-chunk digests of the new state as a fused
        final pass: (new_state, metrics, {path: [u64 digest, ...]}).

        The proxy service calls this (instead of :meth:`step`) when the
        runner registered with ``fused_digests=True``, and hands the
        digests of the *last* step before a SYNC to
        ``ShadowStateManager.sync(device_digests=...)`` — the boundary
        digest scan disappears because the step already paid for it (on
        TPU as one extra Pallas pass over state that is already hot).
        Programs with a cheaper in-step hash can override; this default
        composes :meth:`step` with ``kernels.ops.tree_chunk_digests``.
        """
        from repro.kernels.ops import tree_chunk_digests

        new_state, metrics = self.step(device_state, step)
        return new_state, metrics, tree_chunk_digests(new_state, chunk_bytes)

    def on_restore(self, device_state: Any) -> Any:
        """Adapt a freshly-restored (numpy) state for this program."""
        return device_state

    def state_nbytes(self) -> int:
        """Total bytes of :meth:`init_state` WITHOUT materializing it where
        possible (the app sizes a proxy's --device-capacity percentage from
        this; allocating a giant state app-side would defeat the
        device-clean split). Fallback: build one and measure."""
        import numpy as np

        from repro.utils.tree import flatten_with_paths

        flat, _ = flatten_with_paths(self.init_state())
        return sum(int(np.asarray(l).nbytes) for l in flat.values())


_PROGRAMS: dict[str, Callable[..., StepProgram]] = {}


def register_step_program(
    name: str, factory: Callable[..., StepProgram], *, replace: bool = False
) -> None:
    if name in _PROGRAMS and not replace:
        raise ValueError(f"step program {name!r} already registered")
    _PROGRAMS[name] = factory


def list_step_programs() -> list[str]:
    return sorted(_PROGRAMS)


def make_program(spec: dict[str, Any]) -> StepProgram:
    """Build a program from its spec: {"name": ..., **kwargs}."""
    spec = dict(spec)
    name = spec.pop("name", None)
    try:
        factory = _PROGRAMS[name]
    except KeyError:
        raise KeyError(
            f"unknown step program {name!r}; have {sorted(_PROGRAMS)}"
        ) from None
    return factory(**spec)


# -- built-ins -----------------------------------------------------------------

class NumpySGD(StepProgram):
    """Deterministic momentum-SGD-shaped update (the coord numpy loop)."""

    def __init__(self, *, rows: int = 16, width: int = 64, seed: int = 0,
                 step_time_s: float = 0.0):
        self.rows, self.width, self.seed = int(rows), int(width), int(seed)
        self.step_time_s = float(step_time_s)

    def init_state(self):
        rng = np.random.default_rng(self.seed)
        shape = (self.rows, self.width)
        return {
            "w": rng.standard_normal(shape).astype(np.float32),
            "m": np.zeros(shape, np.float32),
        }

    def step(self, d, step):
        g = np.sin(d["w"] * 0.05 + np.float32(step) * 0.001, dtype=np.float32)
        m = (0.9 * d["m"] + g).astype(np.float32)
        w = (d["w"] - 0.01 * m).astype(np.float32)
        if self.step_time_s:
            time.sleep(self.step_time_s)
        return {"w": w, "m": m}, {"w_norm": float(np.linalg.norm(w))}

    def state_nbytes(self) -> int:
        return 2 * self.rows * self.width * 4  # w + m, float32


class JaxTiny(StepProgram):
    """A real jitted train step over a small dense transformer."""

    def __init__(self, *, width: int = 64, seed: int = 0, batch: int = 2,
                 seq: int = 32):
        import jax

        from repro.models import ModelConfig, build
        from repro.optim import get_optimizer

        self.jax = jax
        self.seed, self.batch, self.seq = int(seed), int(batch), int(seq)
        mc = ModelConfig(
            name="proxy-tiny", family="dense", num_layers=2,
            d_model=width, vocab_size=256, num_heads=4, num_kv_heads=2,
            head_dim=max(width // 4, 8), d_ff=2 * width,
            param_dtype="float32", compute_dtype="float32",
        )
        self.model = build(mc)
        self.opt = get_optimizer("adamw", 1e-3)
        self.vocab = mc.vocab_size

        @jax.jit
        def step_fn(dstate, batch):
            (l, _), g = jax.value_and_grad(self.model.loss, has_aux=True)(
                dstate["params"], batch
            )
            p2, o2 = self.opt.update(
                g, dstate["opt"], dstate["params"], dstate["step"]
            )
            return {"params": p2, "opt": o2, "step": dstate["step"] + 1}, l

        self.step_fn = step_fn

    def _batch(self, step: int):
        # deterministic in (seed, step): identical across incarnations and
        # after replay — no iterator state to persist or re-push
        k = self.jax.random.fold_in(self.jax.random.key(self.seed), step)
        toks = self.jax.random.randint(k, (self.batch, self.seq), 0, self.vocab)
        return {"inputs": toks, "targets": toks}

    def init_state(self):
        import jax.numpy as jnp

        params = self.model.init(self.jax.random.key(self.seed))
        return {
            "params": params,
            "opt": self.opt.init(params),
            "step": jnp.zeros((), jnp.int32),
        }

    def step(self, d, step):
        d2, loss = self.step_fn(d, self._batch(step))
        return d2, {"loss": float(loss)}

    def on_restore(self, d):
        import jax.numpy as jnp

        return self.jax.tree.map(jnp.asarray, d)

    def state_nbytes(self) -> int:
        return _abstract_state_nbytes(self.jax, self.init_state)


class TrainArch(StepProgram):
    """A real architecture from ``repro.configs``, deterministic synthetic
    batches — what ``launch/train.py --device-runner proxy`` ships to its
    proxy instead of a closure."""

    def __init__(self, *, arch: str, smoke: bool = True, batch: int = 8,
                 seq: int = 128, lr: float = 3e-4, total_steps: int = 100,
                 seed: int = 0):
        import jax

        from repro.configs import get_config
        from repro.models import build
        from repro.optim import get_optimizer, warmup_cosine

        self.jax = jax
        self.batch, self.seq, self.seed = int(batch), int(seq), int(seed)
        self.cfg = get_config(arch, smoke=smoke)
        self.model = build(self.cfg)
        self.opt = get_optimizer(
            self.cfg.optimizer, warmup_cosine(lr, 10, total_steps)
        )
        self.vocab = self.cfg.vocab_size

        @jax.jit
        def step_fn(dstate, b):
            (l, _), g = jax.value_and_grad(self.model.loss, has_aux=True)(
                dstate["params"], b
            )
            p2, o2 = self.opt.update(
                g, dstate["opt"], dstate["params"], dstate["step"]
            )
            return {"params": p2, "opt": o2, "step": dstate["step"] + 1}, l

        self.step_fn = step_fn

    def _batch(self, step: int):
        k = self.jax.random.fold_in(self.jax.random.key(self.seed), step)
        toks = self.jax.random.randint(k, (self.batch, self.seq), 0, self.vocab)
        return {"inputs": toks, "targets": toks}

    def init_state(self):
        import jax.numpy as jnp

        params = self.model.init(self.jax.random.key(self.seed))
        return {
            "params": params,
            "opt": self.opt.init(params),
            "step": jnp.zeros((), jnp.int32),
        }

    def step(self, d, step):
        d2, loss = self.step_fn(d, self._batch(step))
        return d2, {"loss": float(loss)}

    def on_restore(self, d):
        import jax.numpy as jnp

        return self.jax.tree.map(jnp.asarray, d)

    def state_nbytes(self) -> int:
        return _abstract_state_nbytes(self.jax, self.init_state)


class DecodeArch(StepProgram):
    """Greedy batched decode as a replayable step program — the *serving*
    workload proxied (``launch/serve.py --device-runner proxy``).

    Device state is ``{params, cache, toks}``: ``toks`` is the (B, P+G)
    token buffer holding the deterministic synthetic prompt in its first P
    positions; step ``n`` feeds ``toks[:, n-1]`` through one decode step
    and writes the argmax token at position ``n`` when that position is in
    the generated region. Pure in (state, n), so a proxy death mid-decode
    replays to bit-identical tokens — and a SYNC after decoding moves only
    the chunks decode actually dirtied (cache/toks, never the params),
    which is what makes serving over the *streamed* transport cheap.
    """

    def __init__(self, *, arch: str, smoke: bool = True, batch: int = 2,
                 prompt_len: int = 32, gen: int = 16, seed: int = 0):
        import jax
        import jax.numpy as jnp

        from repro.configs import get_config
        from repro.models import build

        self.jax = jax
        self.cfg = get_config(arch, smoke=smoke)
        if self.cfg.frontend not in (None, "none", "text"):
            raise ValueError(
                f"decode_arch proxies text decode; arch {arch!r} has "
                f"frontend {self.cfg.frontend!r}"
            )
        self.model = build(self.cfg)
        if self.model.decode is None or self.model.init_cache is None:
            raise ValueError(f"arch {arch!r} has no decode path")
        self.batch, self.seed = int(batch), int(seed)
        self.prompt_len, self.gen = int(prompt_len), int(gen)
        self.total = self.prompt_len + self.gen
        P, total = self.prompt_len, self.total

        @jax.jit
        def step_fn(d, n):
            tok = jax.lax.dynamic_slice_in_dim(d["toks"], n - 1, 1, 1)[:, 0]
            logits, cache = self.model.decode(d["params"], d["cache"], tok)
            nxt = jnp.argmax(logits, axis=-1).astype(jnp.int32)
            pos = jnp.minimum(n, total - 1)
            cur = jax.lax.dynamic_slice_in_dim(d["toks"], pos, 1, 1)[:, 0]
            val = jnp.where((n >= P) & (n < total), nxt, cur)
            toks = jax.lax.dynamic_update_slice(
                d["toks"], val[:, None], (0, pos)
            )
            return (
                {"params": d["params"], "cache": cache, "toks": toks},
                nxt[0].astype(jnp.float32),
            )

        self.step_fn = step_fn

    def prompt(self):
        import numpy as np

        rng = np.random.default_rng(self.seed)
        return rng.integers(
            0, self.cfg.vocab_size, (self.batch, self.prompt_len)
        ).astype(np.int32)

    def init_state(self):
        import jax.numpy as jnp
        import numpy as np

        toks = np.zeros((self.batch, self.total), np.int32)
        toks[:, : self.prompt_len] = self.prompt()
        return {
            "params": self.model.init(self.jax.random.key(self.seed)),
            "cache": self.model.init_cache(self.batch, self.total),
            "toks": jnp.asarray(toks),
        }

    def step(self, d, step):
        import jax.numpy as jnp

        d2, tok0 = self.step_fn(d, jnp.asarray(int(step), jnp.int32))
        return d2, {"tok0": float(tok0)}

    def on_restore(self, d):
        import jax.numpy as jnp

        return self.jax.tree.map(jnp.asarray, d)

    def state_nbytes(self) -> int:
        return _abstract_state_nbytes(self.jax, self.init_state)


def _abstract_state_nbytes(jax, init_fn) -> int:
    """Size a jax init under eval_shape: shapes/dtypes only, no buffers."""
    import numpy as np

    shapes = jax.eval_shape(init_fn)
    return sum(
        int(np.prod(l.shape, dtype=np.int64)) * np.dtype(l.dtype).itemsize
        for l in jax.tree.leaves(shapes)
    )


register_step_program("numpy_sgd", NumpySGD)
register_step_program("jax_tiny", JaxTiny)
register_step_program("train_arch", TrainArch)
register_step_program("decode_arch", DecodeArch)
