"""Device-proxy wire protocol (paper §3: application <-> proxy process).

CRUM's application process is "device-clean": it never owns device state;
every device API call is forwarded to the proxy. Here the control plane is
u32-length-prefixed msgpack frames over loopback TCP — the exact framing of
``repro.coord.protocol`` (``Connection``/``send_frame``/``recv_frame`` are
re-exported from there) — while the data plane is file-backed MAP_SHARED
mmap segments (``repro.proxy.segments``): step inputs/outputs never pickle
through the pipe, only tiny control frames do.

When tracing is enabled, REGISTER/STEP/SYNC/UPLOAD (and streamed CHUNKS)
frames may carry an optional ``ctx`` field — ``{"trace", "span",
"parent"}``, the causal trace context (repro.obs.trace) under which the
proxy-side service emits its execution span, so a merged trace links the
app's round tree to the proxy work it caused (repro.obs.critpath). The
field is absent when tracing is off; the untraced frames are
byte-identical.

Application -> proxy::

    PROGRAM   {spec}                 construct the step program (replayable)
    REGISTER  {layout, chunk_bytes,  attach the data plane; init state.
               transport?,           ``transport`` is ``"segment"`` (shared
               workdir?,             MAP_SHARED files, local zero-copy —
               device_capacity_bytes?, needs ``workdir``) or ``"stream"``
               page_bytes?,          (payloads travel as CHUNKS frames over
               eviction_policy?,     this connection — the remote form).
               promote_threshold?}   with a capacity the proxy hosts its
                                     device state in a ManagedSpace (UVM
                                     paging under a hard budget)
    UPLOAD    {paths, step, chunks?, ingest data-plane bytes into device
               n_frames?}            state. ``chunks`` ({path: [chunk
                                     indices]}) is the delta form: only
                                     those chunk ranges move — bytes on
                                     the wire scale with dirty chunks, not
                                     state size. Streamed transport: the
                                     payload follows as exactly
                                     ``n_frames`` CHUNKS frames
    CHUNKS    {codec, items, data}   one data-plane frame (streamed
                                     transport): ``items`` is a list of
                                     [path, chunk_index, raw_len] and
                                     ``data`` their concatenated bytes,
                                     optionally zstd-compressed per frame
    STEP      {step}                 run one train step — pipelined, NO reply
    FLUSH     {seq}                  pipeline barrier (control-plane only)
    SYNC      {epoch?}               device state -> data plane at this
                                     point in the pipeline. With ``epoch``
                                     the call is *pipelined like STEP*: no
                                     barrier, the app keeps issuing STEPs
                                     and matches the SYNCED{epoch} ack
                                     asynchronously. Without it: the
                                     legacy blocking barrier.
    SHUTDOWN  {}                     clean exit

Proxy -> application::

    OK        {op, ...}              ack for PROGRAM/REGISTER/UPLOAD
    ERR       {op, error}            the call failed; proxy stays up
    FLUSHED   {seq, step}            pipeline empty up to ``seq``
    CHUNKS    {codec, items, data}   streamed transport: dirty-chunk
                                     payload of the in-progress SYNC (sent
                                     before its SYNCED)
    SYNCED    {step, digest, metrics, chunks_synced, bytes_synced,
               epoch?, phase_us?, wire_bytes?, paging?}
                                     ``epoch`` echoes the SYNC's epoch;
                                     ``phase_us`` breaks the window down
                                     ({step, digest, sync} microseconds)
                                     for the pipeline observability path

STEP carrying no reply is the proxying economy the paper measures in
Fig. 4: the app runs ahead of the proxy exactly like JAX's async dispatch
runs ahead of the device (see ``core/drain.py``); SYNC is the flush. An
epoch-tagged SYNC extends the same economy to the sync boundary itself:
the proxy still executes it in pipeline order (so the image is exactly
the step-boundary state), but the app overlaps the drain+digest+fetch
work with its next steps instead of stalling on the ack.
"""
from __future__ import annotations

from dataclasses import dataclass

from repro.coord.protocol import (  # noqa: F401  (re-exported framing)
    Connection,
    connect,
    recv_frame,
    send_frame,
)

MSG_PROGRAM = "PROGRAM"
MSG_REGISTER = "REGISTER"
MSG_UPLOAD = "UPLOAD"
MSG_CHUNKS = "CHUNKS"
MSG_STEP = "STEP"
MSG_FLUSH = "FLUSH"
MSG_SYNC = "SYNC"
MSG_SHUTDOWN = "SHUTDOWN"

MSG_OK = "OK"
MSG_ERR = "ERR"
MSG_FLUSHED = "FLUSHED"
MSG_SYNCED = "SYNCED"


class ProxyDiedError(RuntimeError):
    """The proxy process is gone (EOF/broken pipe/timeout past liveness)."""


@dataclass
class ProxyServiceConfig:
    """Everything a fresh proxy incarnation needs to come up and connect.

    Deliberately minimal: program/layout/data arrive as *replayed API
    calls* over the connection, never as spawn arguments — that is what
    makes a respawned proxy reconstructible from the API log alone.
    """

    host: str
    port: int
    jax_platforms: str | None = "cpu"
    sock_timeout_s: float = 1.0
    # observability (not part of the replayable state — a respawn works
    # with or without it): where to write this incarnation's trace shard.
    # Normally inherited via CRUM_OBS_DIR; explicit here for spawn paths
    # whose environment is scrubbed.
    obs_dir: str | None = None
    obs_run: str | None = None
