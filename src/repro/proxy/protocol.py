"""Device-proxy wire protocol (paper §3: application <-> proxy process).

CRUM's application process is "device-clean": it never owns device state;
every device API call is forwarded to the proxy. Here the control plane is
u32-length-prefixed msgpack frames over loopback TCP — the exact framing of
``repro.coord.protocol`` (``Connection``/``send_frame``/``recv_frame`` are
re-exported from there) — while the data plane is file-backed MAP_SHARED
mmap segments (``repro.proxy.segments``): step inputs/outputs never pickle
through the pipe, only tiny control frames do.

Application -> proxy::

    PROGRAM   {spec}                 construct the step program (replayable)
    REGISTER  {layout, chunk_bytes,  attach data-plane segments; init state.
               device_capacity_bytes?, page_bytes?, eviction_policy?}
                                     with a capacity the proxy hosts its
                                     device state in a ManagedSpace (UVM
                                     paging under a hard budget)
    UPLOAD    {paths, step, chunks?} ingest segment bytes into device state.
                                     ``chunks`` ({path: [chunk indices]})
                                     is the delta form: only those chunk
                                     ranges are read from the segments —
                                     bytes-on-wire scales with dirty
                                     chunks, not state size
    STEP      {step}                 run one train step — pipelined, NO reply
    FLUSH     {seq}                  pipeline barrier (control-plane only)
    SYNC      {}                     flush + write device state to segments
    SHUTDOWN  {}                     clean exit

Proxy -> application::

    OK        {op, ...}              ack for PROGRAM/REGISTER/UPLOAD
    ERR       {op, error}            the call failed; proxy stays up
    FLUSHED   {seq, step}            pipeline empty up to ``seq``
    SYNCED    {step, digest, metrics, chunks_synced, bytes_synced, paging?}

STEP carrying no reply is the proxying economy the paper measures in
Fig. 4: the app runs ahead of the proxy exactly like JAX's async dispatch
runs ahead of the device (see ``core/drain.py``); SYNC is the flush.
"""
from __future__ import annotations

from dataclasses import dataclass

from repro.coord.protocol import (  # noqa: F401  (re-exported framing)
    Connection,
    connect,
    recv_frame,
    send_frame,
)

MSG_PROGRAM = "PROGRAM"
MSG_REGISTER = "REGISTER"
MSG_UPLOAD = "UPLOAD"
MSG_STEP = "STEP"
MSG_FLUSH = "FLUSH"
MSG_SYNC = "SYNC"
MSG_SHUTDOWN = "SHUTDOWN"

MSG_OK = "OK"
MSG_ERR = "ERR"
MSG_FLUSHED = "FLUSHED"
MSG_SYNCED = "SYNCED"


class ProxyDiedError(RuntimeError):
    """The proxy process is gone (EOF/broken pipe/timeout past liveness)."""


@dataclass
class ProxyServiceConfig:
    """Everything a fresh proxy incarnation needs to come up and connect.

    Deliberately minimal: program/layout/data arrive as *replayed API
    calls* over the connection, never as spawn arguments — that is what
    makes a respawned proxy reconstructible from the API log alone.
    """

    host: str
    port: int
    jax_platforms: str | None = "cpu"
    sock_timeout_s: float = 1.0
