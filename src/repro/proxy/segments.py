"""Data plane: per-leaf byte tables shared (or streamed) app <-> proxy.

The control pipe carries only tiny msgpack frames; bulk state crosses
process boundaries through a :class:`StateTable` — the allocation table
(``layout``: path -> byte size, shape, dtype) plus one byte buffer per
device-state leaf. Two concrete tables exist:

``SegmentTable``
    file-backed MAP_SHARED mmap segments (preferring ``/dev/shm`` so the
    pages are RAM-backed), mapped by both the application and the proxy —
    the same split CRUM makes between its proxy RPC channel and the UVM
    pages both sides can touch. Because the files outlive any one proxy
    incarnation, a respawned *local* proxy attaches the same pages and
    replay's data push is a segment read, not a transfer.

``PrivateTable``
    plain process-private numpy buffers with the identical read/write API.
    This is each side's terminal of the *streamed* transport
    (``repro.remote.transport``): a remote proxy cannot map the app's
    ``/dev/shm``, so UPLOAD/SYNC payloads travel as chunk frames over the
    TCP connection and land in a private table on the far side.

Either table hands ``factory`` to a ``ShadowStateManager(segment_factory=
...)`` so shadow buffers ARE the table's buffers.
"""
from __future__ import annotations

import mmap
import os
import shutil
import tempfile
from typing import Any

import ml_dtypes  # noqa: F401  (registers bfloat16 & friends with numpy)
import numpy as np

from repro.utils.tree import flatten_with_paths, unflatten_from_paths


def default_segment_dir(prefix: str = "crum-proxy-") -> str:
    """A fresh directory for segment files, RAM-backed when possible."""
    base = "/dev/shm" if os.path.isdir("/dev/shm") and os.access(
        "/dev/shm", os.W_OK
    ) else None
    return tempfile.mkdtemp(prefix=prefix, dir=base)


class SharedSegment:
    """One MAP_SHARED mapping of one segment file."""

    def __init__(self, path: str, nbytes: int, *, create: bool):
        self.path = path
        self.nbytes = int(nbytes)
        flags = os.O_RDWR | (os.O_CREAT if create else 0)
        fd = os.open(path, flags, 0o600)
        try:
            if create and os.fstat(fd).st_size != self.nbytes:
                os.ftruncate(fd, self.nbytes)
            if self.nbytes > 0:
                self._mm = mmap.mmap(fd, self.nbytes, mmap.MAP_SHARED)
            else:  # zero-length leaves still need a (trivial) buffer
                self._mm = None
        finally:
            os.close(fd)  # the mapping keeps the pages; the fd is done

    def view(self) -> np.ndarray:
        if self._mm is None:
            return np.empty(0, np.uint8)
        return np.frombuffer(self._mm, dtype=np.uint8, count=self.nbytes)

    def close(self) -> None:
        if self._mm is not None:
            try:
                self._mm.close()
            except BufferError:  # a numpy view is still alive; GC frees it
                pass
            self._mm = None


class StateTable:
    """Layout + chunk/state access over one byte buffer per pytree leaf.

    The application side *creates* it from a state pytree (recording the
    treedef so synced state can be rebuilt); the proxy side *attaches* to
    an existing layout. Storage is subclass-provided via :meth:`view`.
    """

    kind = "?"

    def __init__(self, workdir: str | None = None):
        self.workdir = workdir
        self.layout: dict[str, dict[str, Any]] = {}
        self._treedef = None
        # cumulative bytes this side has written INTO the table — the
        # data-plane half of "bytes on the wire" (the wire-level delta
        # tests assert it scales with dirty chunks, not state size)
        self.bytes_written = 0

    # -- storage (subclass) ----------------------------------------------------
    def view(self, path: str) -> np.ndarray:
        """The u8 byte buffer backing one leaf."""
        raise NotImplementedError

    def _alloc(self, path: str, fname: str, nbytes: int) -> np.ndarray:
        """Create storage for one leaf; returns its u8 view."""
        raise NotImplementedError

    # -- application side ------------------------------------------------------
    @classmethod
    def create(cls, state: Any, **kw) -> "StateTable":
        """Allocate one buffer per leaf and fill it with the leaf bytes."""
        t = cls(**kw)
        flat, treedef = flatten_with_paths(state)
        t._treedef = treedef
        for i, (path, leaf) in enumerate(flat.items()):
            arr = np.asarray(leaf)
            fname = f"seg-{i:04d}.bin"
            t.layout[path] = {
                "file": fname,
                "nbytes": int(arr.nbytes),
                "shape": list(arr.shape),
                "dtype": arr.dtype.name,
            }
            buf = t._alloc(path, fname, arr.nbytes)
            if arr.nbytes:
                buf[:] = np.ascontiguousarray(arr).reshape(-1).view(np.uint8)
                t.bytes_written += int(arr.nbytes)
        return t

    def write_state(self, state: Any) -> int:
        """Overwrite buffer content with ``state``'s bytes; returns bytes."""
        flat, _ = flatten_with_paths(state)
        total = 0
        for path, leaf in flat.items():
            spec = self.layout.get(path)
            if spec is None:
                raise KeyError(f"leaf {path!r} not in table layout")
            arr = np.asarray(leaf)
            if int(arr.nbytes) != spec["nbytes"]:
                raise ValueError(
                    f"leaf {path!r} is {arr.nbytes}B, buffer is "
                    f"{spec['nbytes']}B — re-register for shape changes"
                )
            if arr.nbytes:
                self.view(path)[:] = (
                    np.ascontiguousarray(arr).reshape(-1).view(np.uint8)
                )
            total += int(arr.nbytes)
        self.bytes_written += total
        return total

    def write_chunks(
        self, state: Any, chunks: dict[str, list[int]], chunk_bytes: int
    ) -> int:
        """Overwrite only the given chunk byte-ranges of each leaf's
        buffer — the delta half of a chunk-delta UPLOAD. Returns bytes
        actually written (what crossed the data plane)."""
        flat, _ = flatten_with_paths(state)
        cb = int(chunk_bytes)
        total = 0
        for path, idxs in chunks.items():
            spec = self.layout.get(path)
            if spec is None:
                raise KeyError(f"leaf {path!r} not in table layout")
            arr = np.asarray(flat[path])
            if int(arr.nbytes) != spec["nbytes"]:
                raise ValueError(
                    f"leaf {path!r} is {arr.nbytes}B, buffer is "
                    f"{spec['nbytes']}B — re-register for shape changes"
                )
            if not idxs or not arr.nbytes:
                continue
            raw = np.ascontiguousarray(arr).reshape(-1).view(np.uint8)
            view = self.view(path)
            for i in idxs:
                lo, hi = i * cb, min(int(arr.nbytes), (i + 1) * cb)
                if i < 0 or lo >= hi:
                    raise IndexError(f"chunk {i} outside leaf {path!r}")
                view[lo:hi] = raw[lo:hi]
                total += hi - lo
        self.bytes_written += total
        return total

    def write_range(self, path: str, lo: int, data: np.ndarray) -> int:
        """Splice raw bytes at offset ``lo`` of one leaf's buffer — the
        receive half of a streamed chunk frame. Returns bytes written."""
        spec = self.layout.get(path)
        if spec is None:
            raise KeyError(f"leaf {path!r} not in table layout")
        data = np.frombuffer(data, np.uint8) if isinstance(
            data, (bytes, bytearray, memoryview)
        ) else np.ascontiguousarray(data).reshape(-1).view(np.uint8)
        hi = lo + data.nbytes
        if lo < 0 or hi > spec["nbytes"]:
            raise ValueError(
                f"range [{lo}, {hi}) outside leaf {path!r} "
                f"({spec['nbytes']}B)"
            )
        if data.nbytes:
            self.view(path)[lo:hi] = data
            self.bytes_written += int(data.nbytes)
        return int(data.nbytes)

    def chunk_bytes_of(self, path: str, index: int, chunk_bytes: int) -> np.ndarray:
        """The current bytes of one chunk (a buffer view, zero-copy)."""
        nbytes = self.layout[path]["nbytes"]
        lo, hi = index * chunk_bytes, min(nbytes, (index + 1) * chunk_bytes)
        if index < 0 or lo >= hi:
            raise IndexError(f"chunk {index} outside leaf {path!r}")
        return self.view(path)[lo:hi]

    def all_chunks(self, chunk_bytes: int) -> dict[str, list[int]]:
        """{path: every chunk index} — the full-state chunk map."""
        cb = int(chunk_bytes)
        return {
            p: list(range(-(-s["nbytes"] // cb))) if s["nbytes"] else []
            for p, s in self.layout.items()
        }

    def read_state(self) -> Any:
        """Rebuild the state pytree from current buffer content (copies)."""
        if self._treedef is None:
            raise RuntimeError("read_state() needs the creating side's treedef")
        leaves = {}
        for path, spec in self.layout.items():
            arr = self.view(path).copy().view(np.dtype(spec["dtype"]))
            leaves[path] = arr.reshape(tuple(spec["shape"]))
        return unflatten_from_paths(self._treedef, leaves)

    # -- proxy side ------------------------------------------------------------
    @classmethod
    def attach(cls, layout: dict[str, dict], **kw) -> "StateTable":
        t = cls(**kw)
        t.layout = {p: dict(s) for p, s in layout.items()}
        return t

    # -- both sides ------------------------------------------------------------
    def factory(self, key: tuple[str, int], nbytes: int) -> np.ndarray:
        """``ShadowStateManager.segment_factory`` adapter (shard 0 only —
        proxy device state is host-local, one stream per leaf)."""
        path, ordinal = key
        if ordinal != 0:
            raise ValueError("proxy state tables are single-shard (ordinal 0)")
        spec = self.layout[path]
        if int(nbytes) != spec["nbytes"]:
            raise ValueError(
                f"shadow stream {key} wants {nbytes}B, buffer holds "
                f"{spec['nbytes']}B"
            )
        return self.view(path)

    def total_bytes(self) -> int:
        return sum(s["nbytes"] for s in self.layout.values())

    def close(self, *, unlink: bool = False) -> None:
        pass


class SegmentTable(StateTable):
    """File-backed MAP_SHARED segments — the zero-copy local data plane."""

    kind = "segment"

    def __init__(self, workdir: str | None = None):
        owns = workdir is None
        super().__init__(workdir or default_segment_dir())
        self._segments: dict[str, SharedSegment] = {}
        self._owns_dir = owns

    def _alloc(self, path: str, fname: str, nbytes: int) -> np.ndarray:
        seg = SharedSegment(
            os.path.join(self.workdir, fname), nbytes, create=True
        )
        self._segments[path] = seg
        return seg.view()

    @classmethod
    def attach(cls, workdir: str, layout: dict[str, dict]) -> "SegmentTable":
        return super().attach(layout, workdir=workdir)

    def view(self, path: str) -> np.ndarray:
        seg = self._segments.get(path)
        if seg is None:
            spec = self.layout[path]
            seg = SharedSegment(
                os.path.join(self.workdir, spec["file"]),
                spec["nbytes"],
                create=False,
            )
            self._segments[path] = seg
        return seg.view()

    def close(self, *, unlink: bool = False) -> None:
        for seg in self._segments.values():
            seg.close()
        self._segments.clear()
        if unlink:
            if self._owns_dir:
                shutil.rmtree(self.workdir, ignore_errors=True)
            else:
                for spec in self.layout.values():
                    try:
                        os.unlink(os.path.join(self.workdir, spec["file"]))
                    except OSError:
                        pass


class PrivateTable(StateTable):
    """Process-private buffers — each side's terminal of the streamed
    transport. Nothing is shared: bytes arrive/leave as chunk frames."""

    kind = "private"

    def __init__(self, workdir: str | None = None):
        super().__init__(workdir)
        self._buffers: dict[str, np.ndarray] = {}

    def _alloc(self, path: str, fname: str, nbytes: int) -> np.ndarray:
        buf = np.zeros(nbytes, np.uint8)
        self._buffers[path] = buf
        return buf

    def view(self, path: str) -> np.ndarray:
        buf = self._buffers.get(path)
        if buf is None:
            buf = np.zeros(self.layout[path]["nbytes"], np.uint8)
            self._buffers[path] = buf
        return buf

    def close(self, *, unlink: bool = False) -> None:
        self._buffers.clear()
