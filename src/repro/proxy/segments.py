"""Data plane: file-backed MAP_SHARED mmap segments shared app <-> proxy.

The control pipe carries only tiny msgpack frames; bulk state crosses
process boundaries through these segments, the same split CRUM makes
between its proxy RPC channel and the UVM pages both sides can touch.
Segments are plain files (preferring ``/dev/shm`` so the pages are
RAM-backed) mapped MAP_SHARED by both the application and the proxy — and,
because the files outlive any one proxy incarnation, a respawned proxy
attaches the *same* pages and replay's data push is a segment read, not a
network transfer.

One segment per device-state leaf. The ``layout`` dict (sent in REGISTER
and recorded in the API log) is the allocation table: path -> file name,
byte size, shape, dtype.
"""
from __future__ import annotations

import mmap
import os
import shutil
import tempfile
from typing import Any

import ml_dtypes  # noqa: F401  (registers bfloat16 & friends with numpy)
import numpy as np

from repro.utils.tree import flatten_with_paths, unflatten_from_paths


def default_segment_dir(prefix: str = "crum-proxy-") -> str:
    """A fresh directory for segment files, RAM-backed when possible."""
    base = "/dev/shm" if os.path.isdir("/dev/shm") and os.access(
        "/dev/shm", os.W_OK
    ) else None
    return tempfile.mkdtemp(prefix=prefix, dir=base)


class SharedSegment:
    """One MAP_SHARED mapping of one segment file."""

    def __init__(self, path: str, nbytes: int, *, create: bool):
        self.path = path
        self.nbytes = int(nbytes)
        flags = os.O_RDWR | (os.O_CREAT if create else 0)
        fd = os.open(path, flags, 0o600)
        try:
            if create and os.fstat(fd).st_size != self.nbytes:
                os.ftruncate(fd, self.nbytes)
            if self.nbytes > 0:
                self._mm = mmap.mmap(fd, self.nbytes, mmap.MAP_SHARED)
            else:  # zero-length leaves still need a (trivial) buffer
                self._mm = None
        finally:
            os.close(fd)  # the mapping keeps the pages; the fd is done

    def view(self) -> np.ndarray:
        if self._mm is None:
            return np.empty(0, np.uint8)
        return np.frombuffer(self._mm, dtype=np.uint8, count=self.nbytes)

    def close(self) -> None:
        if self._mm is not None:
            try:
                self._mm.close()
            except BufferError:  # a numpy view is still alive; GC frees it
                pass
            self._mm = None


class SegmentTable:
    """The full segment set for one registered device state.

    The application side *creates* it from a state pytree (recording the
    treedef so synced state can be rebuilt); the proxy side *attaches* to
    an existing layout. Either side hands ``factory`` to a
    ``ShadowStateManager(segment_factory=...)`` so shadow buffers ARE the
    shared segments.
    """

    def __init__(self, workdir: str):
        self.workdir = workdir
        self.layout: dict[str, dict[str, Any]] = {}
        self._segments: dict[str, SharedSegment] = {}
        self._treedef = None
        self._owns_dir = False
        # cumulative bytes this side has written INTO the segments — the
        # data-plane half of "bytes on the wire" (the wire-level delta
        # tests assert it scales with dirty chunks, not state size)
        self.bytes_written = 0

    # -- application side ------------------------------------------------------
    @classmethod
    def create(cls, state: Any, *, workdir: str | None = None) -> "SegmentTable":
        """Allocate one segment per leaf and fill it with the leaf bytes."""
        t = cls(workdir or default_segment_dir())
        t._owns_dir = workdir is None
        flat, treedef = flatten_with_paths(state)
        t._treedef = treedef
        for i, (path, leaf) in enumerate(flat.items()):
            arr = np.asarray(leaf)
            fname = f"seg-{i:04d}.bin"
            t.layout[path] = {
                "file": fname,
                "nbytes": int(arr.nbytes),
                "shape": list(arr.shape),
                "dtype": arr.dtype.name,
            }
            seg = SharedSegment(
                os.path.join(t.workdir, fname), arr.nbytes, create=True
            )
            t._segments[path] = seg
            if arr.nbytes:
                seg.view()[:] = np.ascontiguousarray(arr).reshape(-1).view(np.uint8)
                t.bytes_written += int(arr.nbytes)
        return t

    def write_state(self, state: Any) -> int:
        """Overwrite segment content with ``state``'s bytes; returns bytes."""
        flat, _ = flatten_with_paths(state)
        total = 0
        for path, leaf in flat.items():
            spec = self.layout.get(path)
            if spec is None:
                raise KeyError(f"leaf {path!r} not in segment layout")
            arr = np.asarray(leaf)
            if int(arr.nbytes) != spec["nbytes"]:
                raise ValueError(
                    f"leaf {path!r} is {arr.nbytes}B, segment is "
                    f"{spec['nbytes']}B — re-register for shape changes"
                )
            if arr.nbytes:
                self.view(path)[:] = (
                    np.ascontiguousarray(arr).reshape(-1).view(np.uint8)
                )
            total += int(arr.nbytes)
        self.bytes_written += total
        return total

    def write_chunks(
        self, state: Any, chunks: dict[str, list[int]], chunk_bytes: int
    ) -> int:
        """Overwrite only the given chunk byte-ranges of each leaf's
        segment — the delta half of a chunk-delta UPLOAD. Returns bytes
        actually written (what crossed the data plane)."""
        flat, _ = flatten_with_paths(state)
        cb = int(chunk_bytes)
        total = 0
        for path, idxs in chunks.items():
            spec = self.layout.get(path)
            if spec is None:
                raise KeyError(f"leaf {path!r} not in segment layout")
            arr = np.asarray(flat[path])
            if int(arr.nbytes) != spec["nbytes"]:
                raise ValueError(
                    f"leaf {path!r} is {arr.nbytes}B, segment is "
                    f"{spec['nbytes']}B — re-register for shape changes"
                )
            if not idxs or not arr.nbytes:
                continue
            raw = np.ascontiguousarray(arr).reshape(-1).view(np.uint8)
            view = self.view(path)
            for i in idxs:
                lo, hi = i * cb, min(int(arr.nbytes), (i + 1) * cb)
                if i < 0 or lo >= hi:
                    raise IndexError(f"chunk {i} outside leaf {path!r}")
                view[lo:hi] = raw[lo:hi]
                total += hi - lo
        self.bytes_written += total
        return total

    def read_state(self) -> Any:
        """Rebuild the state pytree from current segment content (copies)."""
        if self._treedef is None:
            raise RuntimeError("read_state() needs the creating side's treedef")
        leaves = {}
        for path, spec in self.layout.items():
            arr = self.view(path).copy().view(np.dtype(spec["dtype"]))
            leaves[path] = arr.reshape(tuple(spec["shape"]))
        return unflatten_from_paths(self._treedef, leaves)

    # -- proxy side ------------------------------------------------------------
    @classmethod
    def attach(cls, workdir: str, layout: dict[str, dict]) -> "SegmentTable":
        t = cls(workdir)
        t.layout = {p: dict(s) for p, s in layout.items()}
        return t

    # -- both sides ------------------------------------------------------------
    def view(self, path: str) -> np.ndarray:
        seg = self._segments.get(path)
        if seg is None:
            spec = self.layout[path]
            seg = SharedSegment(
                os.path.join(self.workdir, spec["file"]),
                spec["nbytes"],
                create=False,
            )
            self._segments[path] = seg
        return seg.view()

    def factory(self, key: tuple[str, int], nbytes: int) -> np.ndarray:
        """``ShadowStateManager.segment_factory`` adapter (shard 0 only —
        proxy device state is host-local, one stream per leaf)."""
        path, ordinal = key
        if ordinal != 0:
            raise ValueError("proxy segments are single-shard (ordinal 0)")
        spec = self.layout[path]
        if int(nbytes) != spec["nbytes"]:
            raise ValueError(
                f"shadow stream {key} wants {nbytes}B, segment holds "
                f"{spec['nbytes']}B"
            )
        return self.view(path)

    def total_bytes(self) -> int:
        return sum(s["nbytes"] for s in self.layout.values())

    def close(self, *, unlink: bool = False) -> None:
        for seg in self._segments.values():
            seg.close()
        self._segments.clear()
        if unlink:
            if self._owns_dir:
                shutil.rmtree(self.workdir, ignore_errors=True)
            else:
                for spec in self.layout.values():
                    try:
                        os.unlink(os.path.join(self.workdir, spec["file"]))
                    except OSError:
                        pass
