"""The proxy process: owns device state, executes forwarded API calls.

This is the paper's proxy half of the split: the application process stays
device-clean (checkpointable with ordinary host-memory tools) while this
process holds the "device" (the step program's state) and executes the
pipelined call stream. The shadow machinery is reused in reverse: a
``ShadowStateManager`` whose buffers ARE the data-plane table gives

  - ``sync``:   device -> table, digest-gated so unchanged chunks never
                recopy (the paper's read-fault economy on the data plane),
  - ``upload``: table -> device, HOST_DIRTY chunks only — the replay
                data-push primitive after a respawn or restore.

The data plane itself is a transport decision made at REGISTER time
(``repro.remote.transport``): ``segment`` attaches the app's MAP_SHARED
files (local, zero-copy); ``stream`` keeps a private table and moves
UPLOAD/SYNC payloads as CHUNKS frames on this very connection — which is
what lets this service run on a *different host* than its application
(``repro.remote.host`` serves accepted connections with this same class).

The service exits on EOF (application gone), SHUTDOWN, or a SIGKILL drill;
it keeps no durable state of its own — everything needed to rebuild it
lives in the application's API log plus the application-side mirror.
"""
from __future__ import annotations

import os
import socket
import time
from typing import Any

from repro.obs import metrics as obs_metrics
from repro.obs import trace as obs_trace
from repro.proxy.protocol import (
    MSG_ERR,
    MSG_CHUNKS,
    MSG_FLUSH,
    MSG_FLUSHED,
    MSG_OK,
    MSG_PROGRAM,
    MSG_REGISTER,
    MSG_SHUTDOWN,
    MSG_STEP,
    MSG_SYNC,
    MSG_SYNCED,
    MSG_UPLOAD,
    ProxyServiceConfig,
    connect,
)


def proxy_entry(cfg: ProxyServiceConfig) -> int:
    """Process entry point (multiprocessing spawn target, local mode)."""
    if cfg.jax_platforms:
        os.environ.setdefault("JAX_PLATFORMS", cfg.jax_platforms)
    if cfg.obs_dir:
        obs_trace.enable(cfg.obs_dir, "proxy", run_id=cfg.obs_run,
                         set_env=False)
    else:
        obs_trace.enable_from_env("proxy")
    conn = connect((cfg.host, cfg.port), timeout=60.0)
    conn.settimeout(cfg.sock_timeout_s)
    service = ProxyService(conn)
    try:
        service.serve()
    finally:
        conn.close()
        obs_metrics.dump_if_enabled("proxy")
    return 0


class ProxyService:
    """One proxy session over one connection (process- or thread-hosted)."""

    def __init__(self, conn):
        self.conn = conn
        self.program = None
        self.table = None            # data-plane StateTable (segment/private)
        self.transport = "segment"
        self.shadow = None
        self.dstate: Any = None
        # managed-memory mode (REGISTER with device_capacity_bytes): the
        # device state lives in a ManagedSpace under a hard frame budget —
        # the proxy can host a state larger than its "device" memory, and
        # sync pushes page deltas instead of digest-scanning every leaf
        self.space = None
        self._space_sync_tick = -1
        self.last_step = 0
        self.last_metrics: dict = {}
        # fused digesting (REGISTER fused_digests=True): every STEP ends
        # with a chunk-digest pass over the new state, so the SYNC boundary
        # compares ready-made hashes instead of re-scanning the state
        self.fused_digests = False
        self._last_digests: dict[str, list[int]] | None = None
        # trained zstd dictionary for streamed CHUNKS frames (REGISTER zdict)
        self._zdict: bytes | None = None
        # per-window phase accounting, reset at every SYNC: how the wall
        # time between two sync boundaries split between stepping and
        # boundary work (reported in SYNCED phase_us)
        self._win_step_us = 0.0
        self._win_steps = 0
        # incarnation number (REGISTER obs field): tags every step/sync
        # span so a merged trace separates replayed work from first runs
        self._obs_inc = 0

    def serve(self) -> None:
        while True:
            try:
                msg = self.conn.recv()
            except (socket.timeout, TimeoutError):
                continue
            except (OSError, ValueError):
                return  # connection torn down under us (daemon shutdown)
            if msg is None:  # application died or closed: this incarnation ends
                return
            if not self._dispatch(msg):
                return

    def _dispatch(self, msg: dict) -> bool:
        mtype = msg.get("type")
        try:
            if mtype == MSG_PROGRAM:
                self._on_program(msg)
            elif mtype == MSG_REGISTER:
                self._on_register(msg)
            elif mtype == MSG_UPLOAD:
                self._on_upload(msg)
            elif mtype == MSG_STEP:
                # pipelined: no reply — the app is already issuing the next call
                self._on_step(msg)
            elif mtype == MSG_FLUSH:
                self.conn.send(MSG_FLUSHED, seq=msg.get("seq", 0),
                               step=self.last_step)
            elif mtype == MSG_SYNC:
                self._on_sync(msg)
            elif mtype == MSG_SHUTDOWN:
                return False
            else:
                self.conn.send(MSG_ERR, op=str(mtype), error="unknown message")
        except Exception as e:  # surface per-call failures, stay alive
            if mtype == MSG_STEP:
                raise  # a failed step poisons the pipeline: die loudly
            self.conn.send(
                MSG_ERR, op=str(mtype), error=f"{type(e).__name__}: {e}"
            )
        return True

    def _step_fn(self, dstate: Any, step: int) -> tuple[Any, dict]:
        """One step, with the fused digest pass when registered for it."""
        if self.fused_digests:
            dstate, metrics, self._last_digests = self.program.step_with_digests(
                dstate, step, self.shadow.chunk_bytes
            )
            return dstate, metrics
        return self.program.step(dstate, step)

    def _on_step(self, msg: dict) -> None:
        t0 = time.perf_counter()
        if self.space is not None:
            # device access through the pager: fault the working
            # set in under the budget, write-allocate results back
            dstate = self.space.read_state()
            dstate, self.last_metrics = self._step_fn(dstate, int(msg["step"]))
            self.space.write_state(dstate)
        else:
            self.dstate, self.last_metrics = self._step_fn(
                self.dstate, int(msg["step"])
            )
        self.last_step = int(msg["step"])
        self._win_step_us += (time.perf_counter() - t0) * 1e6
        self._win_steps += 1
        tr = obs_trace.get()
        if tr is not None:
            # the frame's ctx names THIS span (sender minted the child id):
            # the step lands in the round tree under the app's window span
            tr.complete("proxy.step", t0, step=self.last_step,
                        inc=self._obs_inc,
                        **obs_trace.ctx_args(msg.get("ctx")))

    # -- state-creating calls (the replayed ones) ------------------------------
    def _on_program(self, msg: dict) -> None:
        from repro.proxy.programs import make_program

        self.program = make_program(msg["spec"])
        self.conn.send(MSG_OK, op=MSG_PROGRAM)

    def _on_register(self, msg: dict) -> None:
        from repro.core.shadow import ShadowStateManager
        from repro.remote.transport import make_proxy_table

        obs = msg.get("obs") or {}
        self._obs_inc = int(obs.get("inc") or 0)
        if obs.get("dir"):
            # a thread-hosted session (remote daemon) may serve a run it
            # was not spawned by — the REGISTER frame carries the obs dir
            obs_trace.enable(obs["dir"], "proxy", run_id=obs.get("run"),
                             set_env=False)
        if obs.get("ctx"):
            # re-attach marker: a respawned incarnation registering under
            # an open round shows up *inside* that round's causal tree
            obs_trace.instant("proxy.register", inc=self._obs_inc,
                              **obs_trace.ctx_args(obs["ctx"]))
        self.transport = msg.get("transport", "segment")
        self.table = make_proxy_table(msg)
        self.fused_digests = bool(msg.get("fused_digests"))
        self._last_digests = None
        zd = msg.get("zdict")
        self._zdict = bytes(zd) if zd else None
        self.shadow = ShadowStateManager(
            chunk_bytes=int(msg.get("chunk_bytes", 1 << 20)),
            digest_on_device=False,
            segment_factory=self.table.factory,
        )
        # the program defines the structure; uploads overwrite the content
        init = self.program.init_state()
        capacity = msg.get("device_capacity_bytes")
        if capacity:
            from repro.uvm import DEFAULT_PAGE_BYTES, ManagedSpace

            self.space = ManagedSpace(
                int(capacity),
                page_bytes=int(msg.get("page_bytes") or DEFAULT_PAGE_BYTES),
                eviction_policy=msg.get("eviction_policy") or "lru",
                promote_threshold=int(msg.get("promote_threshold") or 0),
                promote_window=int(msg.get("promote_window") or 0),
            )
            self.space.register(init)
            self._space_sync_tick = -1
            self.dstate = None  # authoritative bytes live in the space
            self.shadow.register(self.space.peek_state())
        else:
            self.space = None
            self.dstate = init
            self.shadow.register(self.dstate)
        self.last_step = 0
        self.conn.send(MSG_OK, op=MSG_REGISTER)

    def _device_view(self) -> Any:
        """The device state as a host pytree (coherent, no migrations)."""
        return self.space.peek_state() if self.space is not None else self.dstate

    def _on_upload(self, msg: dict) -> None:
        t0 = time.perf_counter()
        # streamed transport: the payload follows the UPLOAD frame as
        # exactly n_frames CHUNKS frames — land them in the table first,
        # then ingest from the table exactly like the segment path
        n_frames = int(msg.get("n_frames") or 0)
        if n_frames:
            from repro.remote.transport import recv_chunk_frames

            recv_chunk_frames(
                self.conn, n_frames, self.table, self.shadow.chunk_bytes,
                dict_bytes=self._zdict,
            )
        # a host push changed device bytes outside any step: digests the
        # last step emitted no longer describe the state
        self._last_digests = None
        chunks = msg.get("chunks")
        if self.space is not None and chunks is not None:
            self._delta_upload_into_space(msg, chunks)
            tr = obs_trace.get()
            if tr is not None:
                tr.complete("proxy.upload", t0, step=self.last_step,
                            inc=self._obs_inc, delta=True,
                            **obs_trace.ctx_args(msg.get("ctx")))
            return
        state = self._device_view()
        if chunks is not None:
            # delta form: only the listed chunk ranges are stale
            for p, idxs in chunks.items():
                self.shadow.mark_host_chunks(p, [int(i) for i in idxs])
        else:
            paths = msg.get("paths")
            if paths is None:
                from repro.utils.tree import flatten_with_paths

                paths = list(flatten_with_paths(state)[0])
            for p in paths:
                self.shadow.mark_host_write(p)
        state, stats = self.shadow.upload(state)
        state = self.program.on_restore(state)
        if self.space is not None:
            self.space.load_state(state)
        else:
            self.dstate = state
        self.last_step = int(msg.get("step", self.last_step))
        self.conn.send(
            MSG_OK,
            op=MSG_UPLOAD,
            bytes_uploaded=stats.bytes_uploaded,
            chunks_uploaded=stats.chunks_uploaded,
        )
        tr = obs_trace.get()
        if tr is not None:
            tr.complete("proxy.upload", t0, step=self.last_step,
                        inc=self._obs_inc,
                        bytes_uploaded=stats.bytes_uploaded,
                        **obs_trace.ctx_args(msg.get("ctx")))

    def _delta_upload_into_space(self, msg: dict, chunks: dict) -> None:
        """Chunk-delta upload into a paged device: splice ONLY the uploaded
        byte ranges into the managed space, so untouched pages keep their
        write history and the next page-delta SYNC stays a delta.

        No ``on_restore`` here: a delta targets a live, already-adapted
        state and is bytes-identical by construction (the full-upload path
        keeps the adaptation hook).
        """
        from repro.utils.tree import flatten_with_paths

        import numpy as np

        cb = self.shadow.chunk_bytes
        touched = {}
        for p, idxs in chunks.items():
            self.shadow.mark_host_chunks(p, [int(i) for i in idxs])
            # a flat {full-path: leaf} dict flattens back to the same path
            # strings, so the shadow finds its streams
            touched[p] = self.space.peek_leaf(p)
        patched, stats = self.shadow.upload(touched)
        flat, _ = flatten_with_paths(patched)
        for p, leaf in flat.items():
            raw = np.ascontiguousarray(np.asarray(leaf)).reshape(-1).view(np.uint8)
            nbytes = raw.nbytes
            for i in sorted(int(i) for i in chunks[p]):
                lo, hi = i * cb, min(nbytes, (i + 1) * cb)
                self.space.load_range(p, lo, raw[lo:hi])
        self.last_step = int(msg.get("step", self.last_step))
        self.conn.send(
            MSG_OK,
            op=MSG_UPLOAD,
            bytes_uploaded=stats.bytes_uploaded,
            chunks_uploaded=stats.chunks_uploaded,
        )

    def _on_sync(self, msg: dict | None = None) -> None:
        from repro.utils.tree import tree_digest

        t0 = time.perf_counter()
        ctx = (msg or {}).get("ctx")
        epoch = (msg or {}).get("epoch")
        # fused digests describe the state after the last executed step —
        # exactly the boundary this (pipeline-ordered) SYNC captures
        device_digests = self._last_digests if self.fused_digests else None
        fields: dict[str, Any] = {}
        if self.space is not None:
            # page-delta sync: mark exactly the chunks written since the
            # last SYNC (the space's write-tick history), captured before
            # the peek so nothing can fall between
            tick = self.space.tick()
            marks = self.space.dirty_chunk_marks_since(
                self._space_sync_tick, self.shadow.chunk_bytes
            )
            state = self.space.peek_state()
            self.shadow.mark_device_step(marks)
            stats = self.shadow.sync(state, device_digests=device_digests)
            self._space_sync_tick = tick
            fields["paging"] = self.space.stats_dict()
        else:
            state = self.dstate
            self.shadow.mark_device_step()
            stats = self.shadow.sync(state, device_digests=device_digests)
        if self.transport == "stream":
            # the app side cannot see this table: ship exactly the chunks
            # this sync materialized as CHUNKS frames ahead of the SYNCED —
            # steady-state wire bytes scale with dirty chunks
            from repro.remote.transport import encode_chunk_frames

            changed = {
                path: idxs
                for (path, ordinal), idxs in stats.changed.items()
                if ordinal == 0 and idxs
            }
            t_wire = time.perf_counter()
            wctx = obs_trace.child_span(ctx)
            frames, raw, wire = encode_chunk_frames(
                self.table, changed, self.shadow.chunk_bytes,
                dict_bytes=self._zdict, ctx=wctx,
            )
            for frame in frames:
                self.conn.send(MSG_CHUNKS, **frame)
            tr = obs_trace.get()
            if tr is not None:
                # the wire/codec phase as its own span under this sync:
                # chunk gather + (zstd) encode + framed sends
                tr.complete("proxy.wire", t_wire, frames=len(frames),
                            wire_bytes=wire, raw_bytes=raw,
                            **obs_trace.ctx_args(wctx))
            fields["wire_bytes"] = wire
            fields["raw_bytes"] = raw
        if epoch is not None:
            fields["epoch"] = int(epoch)
        # divergence provenance: the per-chunk digest table of the synced
        # state (fused digests when the step emitted them, else the shadow
        # scan's) rides the ack — size-capped so a pathological chunk
        # count cannot blow the control-frame limit
        digest_table = (
            self._last_digests
            if self.fused_digests and self._last_digests is not None
            else self.shadow.digest_table()
        )
        if digest_table and sum(map(len, digest_table.values())) <= 65536:
            fields["chunk_digests"] = {
                p: [int(d) for d in v] for p, v in digest_table.items()
            }
        fields["phase_us"] = {
            "step": round(self._win_step_us, 1),
            "steps": self._win_steps,
            "digest": round(stats.digest_us, 1),
            "fetch": round(stats.fetch_us, 1),
            "sync": round((time.perf_counter() - t0) * 1e6, 1),
            "prehashed_chunks": stats.chunks_prehashed,
        }
        self._win_step_us = 0.0
        self._win_steps = 0
        self.conn.send(
            MSG_SYNCED,
            step=self.last_step,
            digest=tree_digest(state),
            metrics={k: float(v) for k, v in (self.last_metrics or {}).items()},
            chunks_synced=stats.chunks_fetched,
            bytes_synced=stats.bytes_fetched,
            **fields,
        )
        tr = obs_trace.get()
        if tr is not None:
            tr.complete(
                "proxy.sync", t0, step=self.last_step,
                inc=self._obs_inc,
                epoch=fields.get("epoch"),
                chunks_synced=stats.chunks_fetched,
                bytes_synced=stats.bytes_fetched,
                **obs_trace.ctx_args(ctx),
            )
            paging = fields.get("paging")
            if paging:
                tr.counter("uvm", **{
                    k: v for k, v in paging.items()
                    if isinstance(v, (int, float)) and not isinstance(v, bool)
                })


# Backwards-compatible alias (pre-remote name)
_ProxyService = ProxyService
