"""ProxyRunner — supervised, restartable proxied execution.

The process-level half of the proxy subsystem (modeled on
``coord/supervisor.py``): owns the durable API log, the data-plane
transport (``repro.remote.transport``: shared segments locally, streamed
chunk frames cross-host), and the current :class:`DeviceProxy`
incarnation. Any transport failure is treated as proxy death and answered
with the paper's restart protocol, mid-training:

    1. spend one unit of the restart budget (``core.failure.RestartBudget``),
    2. bring up a fresh proxy — respawn locally, or ask the
       ``endpoint_provider`` for a (possibly different) proxy host when the
       placement layer owns the decision (jittered backoff between
       attempts so a crash-looping endpoint is not hammered),
    3. replay the API log: PROGRAM, REGISTER, then push the last synced
       snapshot back through the transport (UPLOAD — served by
       ``ShadowStateManager.upload`` on the proxy side),
    4. re-issue every logged STEP after the last SYNC.

Deterministic step programs make the recovered state bit-identical to an
uninterrupted run, so training simply continues — even when the new
incarnation lives on a different machine than the dead one.

Torn-sync hazard (CRAC's "streams in flight"): a SIGKILL mid-SYNC can
leave data-plane bytes mixed between two steps (segments half-written, or
only some streamed CHUNKS frames applied), so the transport table alone is
not a safe replay source. The runner therefore keeps a host-side mirror of
the last *acknowledged* sync (``sync_state()`` returns it to the caller
anyway — checkpointing needs the copy) and rewrites the table from that
mirror before the replay UPLOAD.
"""
from __future__ import annotations

import os
import random
import time
from typing import Any, Callable

import numpy as np

from repro.core.failure import RestartBudget
from repro.obs import metrics as obs_metrics
from repro.obs import trace as obs_trace
from repro.proxy.api_log import ApiLog
from repro.proxy.client import DeviceProxy
from repro.proxy.protocol import ProxyDiedError

# NOTE: repro.remote.transport is imported lazily (start()): it builds on
# repro.proxy.segments, so a module-level import here would cycle through
# the package __init__ while remote.transport itself is mid-import.


class ProxyRunner:
    """The trainer-facing device runner for ``device_runner="proxy"``."""

    def __init__(
        self,
        program_spec: dict[str, Any],
        *,
        workdir: str | None = None,
        log_path: str | None = None,
        chunk_bytes: int = 1 << 20,
        transport: str = "segment",
        compress: bool | None = None,
        train_dict: bool = False,
        fused_digests: bool = False,
        endpoint_provider: Callable[..., tuple[str, int]] | None = None,
        device_capacity_bytes: int | None = None,
        page_bytes: int | None = None,
        eviction_policy: str = "lru",
        promote_threshold: int = 0,
        promote_window: int = 0,
        max_restarts: int = 3,
        max_pipeline: int = 64,
        sync_timeout_s: float = 120.0,
        op_timeout_s: float = 120.0,
        mp_context: str = "spawn",
        jax_platforms: str | None = "cpu",
        fsync_log: bool = False,
        respawn_backoff_s: float = 0.05,
    ):
        self.program_spec = dict(program_spec)
        self.chunk_bytes = int(chunk_bytes)
        self.transport_kind = transport
        self.compress = compress
        # stream transport: train a zstd dictionary on the initial state's
        # chunks and ship it in REGISTER — small-chunk frames compress
        # against shared context instead of starting cold every time
        self.train_dict = bool(train_dict)
        # fused digesting: every proxied STEP ends with a chunk-digest
        # pass, so SYNC boundaries compare ready-made hashes (no scan)
        self.fused_digests = bool(fused_digests)
        # placement seam: when set, incarnations connect OUT to whatever
        # endpoint the provider names (provider(failed=True) after a death
        # reports the loss and may return a different host — the
        # reschedule-and-replay path). None = spawn a local child process.
        self.endpoint_provider = endpoint_provider
        # UVM mode: the proxy hosts its device state in a ManagedSpace with
        # this hard budget — states larger than "device" memory page
        self.device_capacity_bytes = (
            int(device_capacity_bytes) if device_capacity_bytes else None
        )
        self.page_bytes = page_bytes
        self.eviction_policy = eviction_policy
        self.promote_threshold = int(promote_threshold)
        self.promote_window = int(promote_window)
        self.sync_timeout_s = sync_timeout_s
        self._proxy_opts = dict(
            mp_context=mp_context,
            max_pipeline=max_pipeline,
            op_timeout_s=op_timeout_s,
            jax_platforms=jax_platforms,
        )
        self.budget = RestartBudget(max_restarts, what="device proxy")
        self.respawn_backoff_s = float(respawn_backoff_s)
        self.transport = None  # ChunkTransport, created by start()
        self._explicit_workdir = workdir
        self._log_path = log_path
        self._owned_log_dir: str | None = None
        self._fsync_log = fsync_log
        self.log: ApiLog | None = None
        self.proxy: DeviceProxy | None = None
        self.started = False
        self.last_synced_step = 0
        self.last_digest: str | None = None
        # pipelined epoch syncs: monotonically increasing epoch counter and
        # the (at most one) issued-but-unacked epoch:
        #   epoch -> (boundary step, _steps_since_sync at issue time)
        # Serialized on purpose: the data-plane table is rewritten by every
        # SYNC, so the mirror of epoch N must be captured before epoch N+1
        # is allowed to touch the table.
        self._sync_epoch = 0
        self._pending_epochs: dict[int, tuple[int, int]] = {}
        self._last_issued_step = 0
        self._last_state: Any = None  # host mirror of the last acked sync
        # STEP frames issued since the last acked sync/upload: while any
        # are outstanding the proxy's device state has moved PAST the
        # mirror, so a chunk-delta push diffed against the mirror would
        # under-upload — push() falls back to a full upload then
        self._steps_since_sync = 0
        self.recoveries: list[dict[str, Any]] = []
        # causal trace context installed by the trainer for the current
        # checkpoint window (worker._ProxyLoop.set_ctx). While set, every
        # outgoing STEP/SYNC/UPLOAD/REGISTER frame carries a fresh child
        # context so the proxy's spans join the round's causal tree; None
        # (tracing off, or no round in flight) keeps frames byte-identical
        # to the pre-ctx wire format.
        self.trace_ctx: dict | None = None

    def _frame_ctx(self) -> dict | None:
        """A child context for one outgoing frame (None when untraced)."""
        if self.trace_ctx is None:
            return None
        return obs_trace.child_span(self.trace_ctx)

    # -- lifecycle ---------------------------------------------------------------
    def start(self, device_state: Any = None, *, base_step: int = 0) -> Any:
        """Bring up the proxy and create device state in it.

        ``device_state=None`` asks the program for a fresh init (built
        app-side too — both sides share the registry, so the layout is
        known without a round-trip). A restored state (the RestoreManager
        proxy path) is pushed as-is. Returns the host mirror of the state.
        """
        if self.started:
            raise RuntimeError("ProxyRunner already started; use push()")
        from repro.remote.transport import default_log_dir, make_transport

        if device_state is None:
            from repro.proxy.programs import make_program

            device_state = make_program(self.program_spec).init_state()
        self.transport = make_transport(
            self.transport_kind,
            device_state,
            self.chunk_bytes,
            workdir=self._explicit_workdir,
            compress=self.compress,
            train_dict=self.train_dict,
        )
        log_path = self._log_path
        if log_path is None:
            log_dir = self.transport.table.workdir or self._explicit_workdir
            if log_dir is None:
                log_dir = self._owned_log_dir = default_log_dir()
            log_path = os.path.join(log_dir, "API_LOG.bin")
        self.log = ApiLog(log_path, truncate=True, fsync=self._fsync_log)
        self.log.append({"call": "program", "spec": self.program_spec})
        self.log.append({
            "call": "register",
            **self.transport.register_fields(),
            "chunk_bytes": self.chunk_bytes,
            "device_capacity_bytes": self.device_capacity_bytes,
            "page_bytes": self.page_bytes,
            "eviction_policy": self.eviction_policy,
            "promote_threshold": self.promote_threshold,
            "promote_window": self.promote_window,
            "fused_digests": self.fused_digests,
        })
        self.log.append({"call": "upload", "step": int(base_step), "paths": None})
        self.last_synced_step = int(base_step)
        self._last_issued_step = int(base_step)
        self._last_state = self.transport.read_state()
        self._steps_since_sync = 0
        self._spawn_and_replay(upload_only=True)
        self.started = True
        return self._last_state

    def push(self, device_state: Any) -> dict[str, Any]:
        """Overwrite proxy device state (restore path on a live runner).

        Delta-aware: when the last acked sync mirror is structurally
        compatible with ``device_state``, only the chunk ranges whose bytes
        differ are rewritten into the data plane and named in the UPLOAD
        frame — bytes on the wire scale with dirty chunks, not state size.
        Returns the proxy's UPLOAD ack ({bytes_uploaded, chunks_uploaded}).
        """
        self._require_started()
        # an UPLOAD record is a positional watermark that clears everything
        # before it from the replay tail — collect any in-flight epoch sync
        # first so its ack (and mirror) are not silently dropped
        self._drain_pending()
        chunks = (
            self._chunk_delta(device_state)
            if self._steps_since_sync == 0 else None
        )
        self.transport.stage(device_state, chunks)
        self._last_state = self.transport.read_state()
        self.log.append({
            "call": "upload", "step": self.last_synced_step, "paths": None,
            "chunks": chunks,
        })
        try:
            reply = self.proxy.upload(
                step=self.last_synced_step,
                chunks=chunks,
                payload_frames=self.transport.payload_frames(chunks),
                ctx=self._frame_ctx(),
            )
        except ProxyDiedError:
            # recovery rewrites the data plane from the (already updated)
            # mirror and replays a FULL upload — the pushed state lands
            self._recover()
            return {"op": "UPLOAD", "replayed": True}
        self._steps_since_sync = 0  # device == mirror again
        return reply

    def _chunk_delta(self, new_state: Any) -> dict[str, list[int]] | None:
        """{path: chunk indices} whose bytes differ from the last acked
        sync mirror; None when no mirror (or the tree changed shape) and a
        full rewrite is required."""
        if self._last_state is None:
            return None
        from repro.utils.tree import flatten_with_paths

        old, _ = flatten_with_paths(self._last_state)
        new, _ = flatten_with_paths(new_state)
        if old.keys() != new.keys():
            return None
        cb = self.chunk_bytes
        delta: dict[str, list[int]] = {}
        for path, leaf in new.items():
            a = np.ascontiguousarray(np.asarray(old[path]))
            b = np.ascontiguousarray(np.asarray(leaf))
            if a.nbytes != b.nbytes or a.dtype != b.dtype:
                return None
            if a.nbytes == 0:
                continue
            diff = np.flatnonzero(
                a.reshape(-1).view(np.uint8) != b.reshape(-1).view(np.uint8)
            )
            if diff.size:
                delta[path] = np.unique(diff // cb).tolist()
        return delta

    def close(self) -> None:
        if self.proxy is not None:
            self.proxy.close()
            self.proxy = None
        if self.log is not None:
            self.log.close()
        if self.transport is not None:
            self.transport.close(unlink=True)
            self.transport = None
        if self._owned_log_dir is not None:
            import shutil

            shutil.rmtree(self._owned_log_dir, ignore_errors=True)
            self._owned_log_dir = None
        self.started = False

    # -- the pipelined call stream -------------------------------------------------
    def step(self, step: int) -> None:
        """Forward one train step; returns immediately (pipelined)."""
        self._require_started()
        self.log.append({"call": "step", "step": int(step)})
        self._steps_since_sync += 1
        self._last_issued_step = int(step)
        try:
            self.proxy.step(int(step), ctx=self._frame_ctx())
        except ProxyDiedError:
            self._recover()  # the log already holds this step: replay runs it

    def drain(self) -> None:
        """Pipeline barrier (``core.drain.drain(runner=...)`` hook)."""
        self._require_started()
        try:
            self.proxy.flush()
        except ProxyDiedError:
            self._recover()

    def sync_state(self) -> tuple[Any, dict[str, Any]]:
        """Blocking sync: issue an epoch SYNC and immediately collect it.

        The compat barrier — ``sync_begin()`` + ``sync_collect()`` with no
        overlap in between. The returned state is a host-side copy (safe to
        checkpoint, safe to keep as the recovery mirror). ``info`` carries
        the proxy's step, state digest, per-sync transfer stats and last
        step metrics.
        """
        return self.sync_collect(self.sync_begin())

    def sync_begin(self) -> int:
        """Issue a pipelined SYNC at the current step boundary; returns its
        epoch. The caller keeps stepping and later matches the ack with
        ``sync_poll``/``sync_collect`` — the proxy still executes the sync
        in pipeline order, so the captured image is exactly the state at
        this boundary."""
        self._require_started()
        self._drain_pending()  # serialize: one in-flight epoch at a time
        self._sync_epoch += 1
        epoch = self._sync_epoch
        self.log.append({
            "call": "sync_begin",
            "epoch": epoch,
            "step": self._last_issued_step,
        })
        self._pending_epochs[epoch] = (
            self._last_issued_step, self._steps_since_sync,
        )
        try:
            self.proxy.sync_begin(epoch, ctx=self._frame_ctx())
        except ProxyDiedError:
            self._recover()  # replay re-issues this SYNC at its boundary
        return epoch

    def sync_poll(self, epoch: int) -> tuple[Any, dict[str, Any]] | None:
        """Non-blocking: (state, info) if SYNCED{epoch} has arrived, else
        None. Proxy death during the poll triggers recovery (which re-issues
        the pending sync) and reports None — poll again later."""
        self._require_started()
        try:
            msg = self.proxy.poll_synced(epoch)
        except ProxyDiedError:
            self._recover()
            return None
        if msg is None:
            return None
        return self._finish_sync(epoch, msg, stall_us=0.0)

    def sync_collect(
        self, epoch: int, *, timeout: float | None = None
    ) -> tuple[Any, dict[str, Any]]:
        """Block until SYNCED{epoch} arrives; returns (state, info). The
        blocked wall time is reported as ``info["stall_us"]`` — the number
        the pipelined trainer drives toward zero."""
        self._require_started()
        t0 = time.perf_counter()
        while True:
            try:
                msg = self.proxy.collect_synced(
                    epoch, timeout=timeout or self.sync_timeout_s
                )
                break
            except ProxyDiedError:
                self._recover()  # replay re-issued the SYNC: collect again
        stall_us = (time.perf_counter() - t0) * 1e6
        return self._finish_sync(epoch, msg, stall_us=stall_us)

    def _drain_pending(self) -> None:
        for epoch in sorted(self._pending_epochs):
            self.sync_collect(epoch)

    def _finish_sync(
        self, epoch: int, msg: dict[str, Any], *, stall_us: float
    ) -> tuple[Any, dict[str, Any]]:
        """SYNCED{epoch} arrived: capture the mirror, make the boundary a
        replay watermark (the ack record), rebase the stale-step counter."""
        boundary, steps_at_begin = self._pending_epochs.pop(epoch)
        self.last_synced_step = int(msg.get("step", boundary))
        self.last_digest = msg.get("digest")
        self.log.append({
            "call": "sync",
            "step": self.last_synced_step,
            "digest": self.last_digest,
            "epoch": epoch,
        })
        self._last_state = self.transport.read_state()
        # steps issued while this sync was in flight are PAST the mirror
        self._steps_since_sync = max(
            0, self._steps_since_sync - steps_at_begin
        )
        info = {
            "step": self.last_synced_step,
            "digest": self.last_digest,
            "epoch": epoch,
            "stall_us": stall_us,
            "metrics": msg.get("metrics", {}),
            "chunks_synced": msg.get("chunks_synced", 0),
            "bytes_synced": msg.get("bytes_synced", 0),
            "restarts": self.budget.count,
            "transport": self.transport.stats(),
        }
        for key in (
            "wire_bytes", "raw_bytes", "paging", "phase_us", "chunk_digests",
        ):
            if key in msg:
                info[key] = msg[key]
        # one registry absorbs the whole SYNCED summary — paging counters,
        # wire counters and phase breakdown ride the frame they always rode
        obs_metrics.absorb_sync_info(info)
        tr = obs_trace.get()
        if tr is not None and stall_us:
            # backdated span: the boundary stalled [now - stall_us, now]
            tr.complete(
                "app.sync_stall",
                time.perf_counter() - stall_us / 1e6,
                epoch=epoch,
                step=self.last_synced_step,
                **obs_trace.ctx_args(self._frame_ctx()),
            )
        return self._last_state, info

    # -- failure drills ------------------------------------------------------------
    def kill(self) -> int | None:
        """SIGKILL the current incarnation (drills/benchmarks); returns pid."""
        pid = self.proxy.pid if self.proxy else None
        if self.proxy is not None:
            self.proxy.kill()
        return pid

    @property
    def restarts(self) -> int:
        return self.budget.count

    @property
    def segments(self):
        """The data-plane table (historical name kept for callers/tests)."""
        return self.transport.table if self.transport is not None else None

    # -- respawn + replay ------------------------------------------------------------
    def _require_started(self) -> None:
        if not self.started or self.proxy is None:
            raise RuntimeError("ProxyRunner is not started")

    def _next_endpoint(self, *, failed: bool) -> tuple[str, int] | None:
        if self.endpoint_provider is None:
            return None
        return self.endpoint_provider(failed=failed)

    def _spawn_and_replay(
        self, *, upload_only: bool = False, failed: bool = False
    ) -> list[int]:
        """Bring up a fresh incarnation from the API log (+ the mirror);
        returns the step numbers replayed."""
        endpoint = self._next_endpoint(failed=failed)
        self.proxy = DeviceProxy(endpoint=endpoint, **self._proxy_opts).start()
        self.proxy.on_data = self.transport.on_chunks
        self.proxy.send_program(self.program_spec)
        # correlation IDs ride the REGISTER frame: the service tags its
        # step/sync spans with this incarnation number, so a merged trace
        # separates pre-kill execution from post-respawn replay (and a
        # remote daemon learns the obs dir for runs it was not spawned by)
        tr = obs_trace.get()
        self.proxy.register(
            **self.transport.register_fields(),
            chunk_bytes=self.chunk_bytes,
            device_capacity_bytes=self.device_capacity_bytes,
            page_bytes=self.page_bytes,
            eviction_policy=self.eviction_policy,
            promote_threshold=self.promote_threshold,
            promote_window=self.promote_window,
            fused_digests=self.fused_digests,
            obs={
                "inc": self.budget.count,
                "run": tr.run_id if tr is not None else None,
                "dir": tr.obs_dir if tr is not None else None,
                # re-attach marker: a respawned incarnation registers under
                # the *current* round's context, so its spans (including
                # the replayed frames below) join the retried round's tree
                # instead of floating free
                "ctx": self._frame_ctx(),
            },
        )
        self.proxy.upload(
            step=self.last_synced_step,
            payload_frames=self.transport.payload_frames(None),
            ctx=self._frame_ctx(),
        )
        if upload_only:
            return []
        _prog, _reg, actions = self.log.replay_actions()
        steps = []
        for a in actions:
            if a[0] == "step":
                self.proxy.step(a[1], ctx=self._frame_ctx())
                steps.append(a[1])
            else:  # ("sync", epoch, step): unacked epoch sync — re-issue at
                # the same boundary so its SYNCED{epoch} is still collectable
                self.proxy.sync_begin(a[1], ctx=self._frame_ctx())
        return steps

    def _recover(self) -> None:
        """The kill-replay path: bring up a fresh incarnation (possibly on
        a different endpoint), rewrite the data plane from the last acked
        sync, replay logged steps past it. A fresh incarnation dying
        *during* the replay spends more budget and retries — with a
        jittered backoff so a flapping endpoint is not hammered — rather
        than aborting while budget remains."""
        t0 = time.perf_counter()
        attempt = 0
        tr = obs_trace.get()
        if tr is not None:
            tr.begin("proxy.respawn", resumed_from=self.last_synced_step)
        try:
            steps = self._recover_loop(attempt)
        finally:
            if tr is not None:
                tr.end("proxy.respawn")
        obs_metrics.REGISTRY.inc("proxy_restarts")
        if tr is not None:
            tr.instant("proxy.replayed", steps=len(steps),
                       inc=self.budget.count,
                       resumed_from=self.last_synced_step)
        # the fresh incarnation re-executed exactly the steps past the
        # last watermark: the mirror is stale by that many steps again
        self._steps_since_sync = len(steps)
        self.recoveries.append({
            "recovery_s": time.perf_counter() - t0,
            "replayed_steps": len(steps),
            "resumed_from_step": self.last_synced_step,
            "endpoint": getattr(self.proxy, "endpoint", None),
        })

    def _recover_loop(self, attempt: int) -> list[int]:
        while True:
            self.budget.spend(f"last synced step {self.last_synced_step}")
            old = self.proxy
            self.proxy = None
            if old is not None:
                old.close(graceful=False)
            if attempt and self.respawn_backoff_s:
                # full jitter, exponentially widening, capped at ~2s: avoid
                # thundering back onto an endpoint that just died under load
                time.sleep(random.uniform(
                    0.0, min(self.respawn_backoff_s * (2 ** attempt), 2.0)
                ))
            attempt += 1
            # a SIGKILL mid-SYNC may have torn the data-plane bytes (half-
            # written segments, or only some streamed frames applied):
            # restore them from the host mirror before the replay upload
            if self._last_state is not None:
                self.transport.stage(self._last_state, None)
            try:
                return self._spawn_and_replay(failed=True)
            except ProxyDiedError:
                # the fresh incarnation died too: release its socket (and
                # local process, if any) before the next attempt
                if self.proxy is not None:
                    self.proxy.close(graceful=False)
                    self.proxy = None
                continue
