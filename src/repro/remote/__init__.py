"""Remote device proxies: cross-host data plane + placement.

CRUM's headline scenario is hybrid CUDA/MPI computation across nodes; CRAC
shows the proxy split surviving a host boundary once device state travels
over an explicit transport. This package is that seam:

``transport``
    the :class:`ChunkTransport` axis — shared-segment (local, zero-copy)
    vs streamed (length-prefixed dirty-chunk frames over the msgpack TCP
    connection, optional per-frame zstd).

``placement``
    which proxy host serves which worker: the coordinator's
    PROXY_ENDPOINT handshake, least-loaded assignment, and
    reschedule-onto-a-survivor when a proxy host dies.

``host``
    the proxy-host daemon: a process that serves proxy sessions for any
    number of remote applications over TCP.
"""
from repro.remote.transport import (
    ChunkTransport,
    SegmentChunkTransport,
    StreamChunkTransport,
    make_transport,
)
from repro.remote.placement import (
    CoordEndpointProvider,
    PlacementMap,
    ProxyEndpoint,
    request_proxy_endpoint,
)
from repro.remote.host import ProxyHostConfig, ProxyHostHandle

__all__ = [
    "ChunkTransport",
    "SegmentChunkTransport",
    "StreamChunkTransport",
    "make_transport",
    "CoordEndpointProvider",
    "PlacementMap",
    "ProxyEndpoint",
    "request_proxy_endpoint",
    "ProxyHostConfig",
    "ProxyHostHandle",
]
