"""The proxy-host daemon: serves device-proxy sessions over TCP.

One daemon process per (simulated) machine. It listens on a port and, for
every accepted connection, runs a full :class:`~repro.proxy.service.
ProxyService` session on a thread — the same service class a locally
spawned proxy runs, now reachable from any host. Applications connect via
``DeviceProxy(endpoint=(addr, port))``; which application lands on which
daemon is the placement layer's decision (``repro.remote.placement``).

Killing the daemon (SIGKILL — the cross-host failure drill) severs every
session it hosts at once: each affected worker sees ProxyDiedError, asks
the coordinator for a survivor, and replays its API log there.

Standalone use (e.g. for ``launch/serve.py --proxy-endpoint``)::

    PYTHONPATH=src python -m repro.remote.host --port 7070
"""
from __future__ import annotations

import argparse
import multiprocessing as mp
import os
import socket
import sys
import threading
from dataclasses import dataclass


@dataclass
class ProxyHostConfig:
    bind: str = "127.0.0.1"
    port: int = 0                       # 0: OS-assigned (reported via queue)
    jax_platforms: str | None = "cpu"
    sock_timeout_s: float = 1.0


def serve_forever(cfg: ProxyHostConfig, port_q=None, on_bound=None) -> None:
    """Bind, report the chosen port, serve sessions until killed.

    ``on_bound(port)`` runs after the listener exists — registration with
    a coordinator belongs there, never before the bind (an endpoint must
    not be advertised while nothing is accepting on it).
    """
    if cfg.jax_platforms:
        os.environ.setdefault("JAX_PLATFORMS", cfg.jax_platforms)
    from repro.coord.protocol import Connection
    from repro.obs import trace as obs_trace
    from repro.proxy.service import ProxyService

    listener = socket.socket(socket.AF_INET, socket.SOCK_STREAM)
    listener.setsockopt(socket.SOL_SOCKET, socket.SO_REUSEADDR, 1)
    listener.bind((cfg.bind, cfg.port))
    listener.listen(64)
    port = listener.getsockname()[1]
    obs_trace.enable_from_env(f"proxyhost-{port}")
    if port_q is not None:
        port_q.put(port)
    else:
        print(f"[proxy-host] serving on {cfg.bind}:{port}", flush=True)
    if on_bound is not None:
        on_bound(port)

    def session(sock: socket.socket) -> None:
        sock.setsockopt(socket.IPPROTO_TCP, socket.TCP_NODELAY, 1)
        conn = Connection(sock)
        conn.settimeout(cfg.sock_timeout_s)
        obs_trace.instant("host.session_open", port=port)
        try:
            ProxyService(conn).serve()
        finally:
            conn.close()
            obs_trace.instant("host.session_close", port=port)

    while True:
        try:
            sock, _ = listener.accept()
        except OSError:
            return
        threading.Thread(
            target=session, args=(sock,), name="proxy-session", daemon=True
        ).start()


def proxy_host_entry(cfg: ProxyHostConfig, port_q) -> int:
    """multiprocessing spawn target."""
    serve_forever(cfg, port_q)
    return 0


class ProxyHostHandle:
    """Launcher-side handle on one daemon process."""

    def __init__(
        self,
        name: str,
        *,
        bind: str = "127.0.0.1",
        mp_context: str = "spawn",
        start_timeout_s: float = 120.0,
    ):
        self.name = name
        self.cfg = ProxyHostConfig(bind=bind)
        self.ctx = mp.get_context(mp_context)
        self.start_timeout_s = start_timeout_s
        self.proc: mp.Process | None = None
        self.port: int | None = None

    def start(self) -> "ProxyHostHandle":
        q = self.ctx.Queue()
        self.proc = self.ctx.Process(
            target=proxy_host_entry, args=(self.cfg, q),
            name=f"crum-proxy-host-{self.name}", daemon=True,
        )
        self.proc.start()
        try:
            self.port = int(q.get(timeout=self.start_timeout_s))
        except Exception:
            self.terminate()
            raise RuntimeError(
                f"proxy host {self.name} did not report a port within "
                f"{self.start_timeout_s}s"
            ) from None
        return self

    @property
    def addr(self) -> tuple[str, int]:
        assert self.port is not None, "call start() first"
        return self.cfg.bind, self.port

    @property
    def pid(self) -> int | None:
        return self.proc.pid if self.proc is not None else None

    def alive(self) -> bool:
        return self.proc is not None and self.proc.is_alive()

    def kill(self) -> None:
        """SIGKILL — the proxy-host failure drill. Every session dies."""
        if self.proc is not None and self.proc.is_alive():
            self.proc.kill()
            self.proc.join(timeout=10)

    def terminate(self) -> None:
        if self.proc is not None:
            if self.proc.is_alive():
                self.proc.terminate()
            self.proc.join(timeout=10)
            if self.proc.is_alive():
                self.proc.kill()
                self.proc.join(timeout=10)
            self.proc = None


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--bind", default="127.0.0.1")
    ap.add_argument("--port", type=int, default=0,
                    help="listen port (0 = OS-assigned, printed at startup)")
    ap.add_argument("--coord", default=None, metavar="HOST:PORT",
                    help="register this endpoint with a cluster coordinator")
    ap.add_argument("--name", default=None,
                    help="endpoint name for registration (default host:port)")
    args = ap.parse_args(argv)

    cfg = ProxyHostConfig(bind=args.bind, port=args.port)
    on_bound = None
    if args.coord:
        from repro.remote.placement import register_proxy_endpoint
        from repro.remote.transport import endpoint_arg

        coord_addr = endpoint_arg(args.coord)

        def on_bound(port: int) -> None:
            # register only once the listener is live: advertising an
            # endpoint nothing accepts on would hand workers a
            # connection-refused assignment
            name = args.name or f"{cfg.bind}:{port}"
            register_proxy_endpoint(
                coord_addr, name=name, addr=cfg.bind, port=port
            )
            print(f"[proxy-host] registered as {name!r}", flush=True)

    serve_forever(cfg, on_bound=on_bound)
    return 0


if __name__ == "__main__":
    sys.exit(main())
