"""Proxy placement: which proxy host serves which worker.

The cluster coordinator owns a :class:`PlacementMap`. Proxy-host daemons
(or the launcher on their behalf) *register* endpoints; workers *acquire*
an assignment over a short-lived side-channel connection speaking the
coordinator's PROXY_ENDPOINT handshake:

    -> {type: PROXY_ENDPOINT, op: "register", name, addr, port}
    <- {type: PROXY_ENDPOINT, op: "registered", name}

    -> {type: PROXY_ENDPOINT, op: "acquire", worker, failed?, exclude?}
    <- {type: PROXY_ENDPOINT, name, addr, port}         # assignment
    <- {type: PROXY_ENDPOINT, error: "no live proxy endpoints"}

``failed`` names an endpoint the worker just watched die: the coordinator
marks it dead (every other worker on it will be reassigned too) and
answers with a survivor — the reschedule half of CRAC's restart protocol.
The side channel is deliberately NOT the worker's main coordinator
connection: a reassignment mid-round must never steal DRAIN/COMMIT frames
from the barrier loop.

Assignment is sticky + least-loaded: a worker keeps its endpoint while it
lives; fresh or rescheduled workers land on the live endpoint currently
serving the fewest workers.
"""
from __future__ import annotations

import socket
import time
from collections import Counter
from dataclasses import dataclass, field

from repro.coord.protocol import MSG_PROXY_ENDPOINT, connect

# NOTE: repro.proxy.protocol is imported lazily inside CoordEndpointProvider
# — this module sits on the coordinator's import path, and proxy.protocol
# re-exports the coord framing (importing it here would be circular).


@dataclass
class ProxyEndpoint:
    name: str
    addr: str
    port: int
    alive: bool = True


@dataclass
class PlacementMap:
    """Endpoint registry + worker->endpoint assignment (coordinator-owned)."""

    endpoints: dict[str, ProxyEndpoint] = field(default_factory=dict)
    assignment: dict[int, str] = field(default_factory=dict)
    #: every assignment ever made, in order — the audit trail tests and the
    #: cluster report consume ("did the reschedule actually happen?")
    history: list[tuple[int, str]] = field(default_factory=list)

    def register(self, name: str, addr: str, port: int) -> ProxyEndpoint:
        ep = ProxyEndpoint(str(name), str(addr), int(port))
        self.endpoints[ep.name] = ep
        return ep

    def report_dead(self, name: str) -> None:
        ep = self.endpoints.get(name)
        if ep is not None:
            ep.alive = False

    def live(self) -> list[ProxyEndpoint]:
        return [e for e in self.endpoints.values() if e.alive]

    def loads(self) -> Counter:
        """{endpoint name: workers currently assigned to it}."""
        return Counter(
            n for n in self.assignment.values()
            if n in self.endpoints and self.endpoints[n].alive
        )

    def assign(
        self, worker: int, *, exclude: tuple[str, ...] = ()
    ) -> ProxyEndpoint | None:
        """Sticky assignment; falls over to the least-loaded live survivor.

        When NO live endpoint remains outside ``exclude``, dead-marked ones
        are offered as a last resort: "dead" can be a transient verdict (a
        sync timeout under load reports a healthy daemon dead), and trying
        a possibly-alive endpoint beats failing the worker outright — the
        runner's restart budget bounds the retries either way. Returns
        None only when every registered endpoint is excluded.
        """
        worker = int(worker)
        cur = self.endpoints.get(self.assignment.get(worker, ""))
        if cur is not None and cur.alive and cur.name not in exclude:
            return cur
        loads = self.loads()
        candidates = [e for e in self.live() if e.name not in exclude]
        if not candidates:
            candidates = [
                e for e in self.endpoints.values() if e.name not in exclude
            ]
        if not candidates:
            return None
        ep = min(candidates, key=lambda e: (loads[e.name], e.name))
        self.assignment[worker] = ep.name
        self.history.append((worker, ep.name))
        return ep


# -- the worker-side handshake --------------------------------------------------

def _exchange(
    coord_addr: tuple[str, int], timeout_s: float, **fields
) -> dict:
    """One PROXY_ENDPOINT request/reply over a fresh side-channel
    connection (shared by acquire and register — the timeout/EOF/match
    semantics must never drift between them)."""
    conn = connect(coord_addr, timeout=timeout_s)
    try:
        conn.settimeout(1.0)
        conn.send(MSG_PROXY_ENDPOINT, **fields)
        deadline = time.monotonic() + timeout_s
        while True:
            if time.monotonic() > deadline:
                raise TimeoutError(
                    f"coordinator did not answer PROXY_ENDPOINT "
                    f"{fields.get('op')}"
                )
            try:
                msg = conn.recv()
            except (socket.timeout, TimeoutError):
                continue
            if msg is None:
                raise ConnectionError(
                    "coordinator closed the PROXY_ENDPOINT side channel"
                )
            if msg.get("type") == MSG_PROXY_ENDPOINT:
                return msg
    finally:
        conn.close()


def request_proxy_endpoint(
    coord_addr: tuple[str, int],
    *,
    worker: int,
    failed: str | None = None,
    exclude: tuple[str, ...] = (),
    timeout_s: float = 30.0,
) -> dict | None:
    """Acquire (or re-acquire after a death) a proxy endpoint assignment.

    Returns the assignment dict ({name, addr, port}) or None when the
    coordinator has no endpoint to offer.
    """
    msg = _exchange(
        coord_addr, timeout_s,
        op="acquire", worker=int(worker), failed=failed,
        exclude=list(exclude),
    )
    if msg.get("error") or not msg.get("addr"):
        return None
    return {"name": msg["name"], "addr": msg["addr"], "port": int(msg["port"])}


def register_proxy_endpoint(
    coord_addr: tuple[str, int],
    *,
    name: str,
    addr: str,
    port: int,
    timeout_s: float = 30.0,
) -> None:
    """Announce one proxy-host endpoint to the coordinator (the daemon- or
    launcher-side half of the handshake)."""
    _exchange(
        coord_addr, timeout_s,
        op="register", name=name, addr=addr, port=int(port),
    )


class CoordEndpointProvider:
    """``ProxyRunner.endpoint_provider`` backed by the coordinator.

    ``provider(failed=False)`` acquires this worker's assignment;
    ``provider(failed=True)`` reports the current endpoint dead, excludes
    it, and acquires a survivor — the runner then replays the API log
    against the new host. Only the *most recently failed* endpoint is
    excluded (not every endpoint that ever failed): a "death" can be a
    transient verdict, and with the coordinator's last-resort fallback a
    flagged-but-healthy daemon stays reachable instead of being shut out
    of the pool forever. Raises :class:`ProxyDiedError` when the
    coordinator has nothing to offer (the runner's restart budget turns
    that into a surfaced failure instead of a hang).
    """

    def __init__(
        self,
        coord_addr: tuple[str, int],
        worker: int,
        *,
        timeout_s: float = 30.0,
    ):
        self.coord_addr = tuple(coord_addr)
        self.worker = int(worker)
        self.timeout_s = timeout_s
        self.current: str | None = None
        self.last_failed: str | None = None

    def __call__(self, *, failed: bool = False) -> tuple[str, int]:
        from repro.proxy.protocol import ProxyDiedError

        report = None
        if failed and self.current is not None:
            report = self.last_failed = self.current
            self.current = None
        exclude = (self.last_failed,) if self.last_failed else ()
        try:
            got = request_proxy_endpoint(
                self.coord_addr,
                worker=self.worker,
                failed=report,
                exclude=exclude,
                timeout_s=self.timeout_s,
            )
        except (OSError, TimeoutError, ConnectionError) as e:
            raise ProxyDiedError(
                f"coordinator unreachable for proxy placement: {e}"
            ) from e
        if got is None:
            raise ProxyDiedError(
                f"no proxy endpoint available (excluded {exclude})"
            )
        self.current = got["name"]
        return got["addr"], got["port"]
