"""ChunkTransport — how device-state bytes cross the app/proxy boundary.

The proxy control plane (``repro.proxy.protocol``) is already
location-transparent: tiny msgpack frames over TCP. What pins a proxy to
the application's machine is the *data* plane — file-backed MAP_SHARED
segments both processes mmap. This module abstracts that into a transport
axis:

``segment``
    the existing local path: bulk bytes move through a shared
    :class:`~repro.proxy.segments.SegmentTable`; UPLOAD/SYNC control
    frames carry no payload. Zero-copy, but both ends must share a
    filesystem (same host).

``stream``
    the cross-host path: UPLOAD/SYNC payloads travel as length-prefixed
    CHUNKS frames *on the control connection itself*, each frame a batch
    of ``[path, chunk_index, raw_len]`` entries plus their concatenated
    bytes (optionally zstd-compressed per frame). Both ends keep a
    :class:`~repro.proxy.segments.PrivateTable` as their local terminal.
    Steady-state wire bytes scale with *dirty chunks* (PR 4's chunk-delta
    machinery decides what is dirty), not with state size.

The application side drives a :class:`ChunkTransport`; the proxy side uses
the module-level helpers (:func:`make_proxy_table`,
:func:`recv_chunk_frames`, :func:`encode_chunk_frames`) from inside the
service dispatch loop.
"""
from __future__ import annotations

import os
import tempfile
from typing import Any

import numpy as np

from repro.proxy.segments import PrivateTable, SegmentTable, StateTable

# payload batching target per CHUNKS frame — far under protocol.MAX_FRAME,
# large enough that framing overhead stays negligible
FRAME_PAYLOAD_BYTES = 1 << 20

TRANSPORTS = ("segment", "stream")


def _zstd():
    try:
        import zstandard

        return zstandard
    except ImportError:
        return None


def encode_chunk_frames(
    table: StateTable,
    chunks: dict[str, list[int]],
    chunk_bytes: int,
    *,
    compress: bool | None = None,
) -> tuple[list[dict], int, int]:
    """Pack the given chunks' current table bytes into CHUNKS frame dicts.

    Returns (frames, raw_bytes, wire_bytes): ``raw_bytes`` is the payload
    before compression, ``wire_bytes`` what actually rides the connection.
    ``compress=None`` auto-enables zstd when the package is importable —
    the receiving side decodes per the frame's ``codec`` field, so both
    ends must have it (they share this codebase's environment).
    """
    zstd = _zstd() if compress in (None, True) else None
    if compress is True and zstd is None:
        raise RuntimeError("compress=True but zstandard is not installed")
    cctx = zstd.ZstdCompressor(level=1) if zstd is not None else None

    frames: list[dict] = []
    items: list[list] = []
    parts: list[bytes] = []
    pending = 0
    raw_total = wire_total = 0

    def flush() -> None:
        nonlocal items, parts, pending, wire_total
        if not items:
            return
        data = b"".join(parts)
        codec = "raw"
        if cctx is not None:
            packed = cctx.compress(data)
            if len(packed) < len(data):
                data, codec = packed, "zstd"
        frames.append({"codec": codec, "items": items, "data": data})
        wire_total += len(data)
        items, parts, pending = [], [], 0

    for path in sorted(chunks):
        for i in sorted(int(x) for x in chunks[path]):
            piece = table.chunk_bytes_of(path, i, chunk_bytes)
            n = int(piece.nbytes)
            items.append([path, i, n])
            parts.append(piece.tobytes())
            pending += n
            raw_total += n
            if pending >= FRAME_PAYLOAD_BYTES:
                flush()
    flush()
    return frames, raw_total, wire_total


def apply_chunk_frame(
    table: StateTable, msg: dict, chunk_bytes: int
) -> tuple[int, int]:
    """Splice one CHUNKS frame's payload into the table.

    Returns (raw_bytes, wire_bytes) applied.
    """
    data = msg["data"]
    wire = len(data)
    if msg.get("codec") == "zstd":
        zstd = _zstd()
        if zstd is None:
            raise RuntimeError(
                "received a zstd CHUNKS frame but zstandard is not installed"
            )
        data = zstd.ZstdDecompressor().decompress(data)
    off = 0
    cb = int(chunk_bytes)
    for path, index, raw_len in msg["items"]:
        table.write_range(path, int(index) * cb, data[off : off + int(raw_len)])
        off += int(raw_len)
    if off != len(data):
        raise ValueError(
            f"CHUNKS frame payload is {len(data)}B but items claim {off}B"
        )
    return off, wire


def recv_chunk_frames(conn, n_frames: int, table: StateTable, chunk_bytes: int) -> int:
    """Consume exactly ``n_frames`` CHUNKS frames from ``conn`` into the
    table (the proxy side of a streamed UPLOAD). Returns raw bytes applied.
    Raises ``ConnectionError`` on EOF mid-payload (torn upload: the caller
    dies and the app-side runner replays)."""
    import socket

    from repro.proxy.protocol import MSG_CHUNKS

    total = 0
    for _ in range(int(n_frames)):
        while True:
            try:
                msg = conn.recv()
                break
            except (socket.timeout, TimeoutError):
                continue
        if msg is None:
            raise ConnectionError("EOF mid-UPLOAD payload")
        if msg.get("type") != MSG_CHUNKS:
            raise ValueError(
                f"expected CHUNKS payload frame, got {msg.get('type')!r}"
            )
        raw, _ = apply_chunk_frame(table, msg, chunk_bytes)
        total += raw
    return total


def make_proxy_table(msg: dict) -> StateTable:
    """The proxy-side table for a REGISTER frame's transport fields."""
    kind = msg.get("transport", "segment")
    if kind == "stream":
        return PrivateTable.attach(msg["layout"])
    if kind == "segment":
        return SegmentTable.attach(msg["workdir"], msg["layout"])
    raise ValueError(f"unknown transport {kind!r}; have {TRANSPORTS}")


class ChunkTransport:
    """Application-side data plane for one registered device state.

    Owns the app's :class:`StateTable` (the mirror the runner reads back
    after SYNC) and knows how to move bytes toward the proxy (``stage`` +
    ``payload_frames``) and how to ingest the proxy's SYNC payload
    (``on_chunks``). Wire counters separate payload that rode the TCP
    connection (``wire_tx``/``wire_rx``) from bytes written into a shared
    data plane (``table.bytes_written`` covers both sides' view of that).
    """

    kind = "?"

    def __init__(self, table: StateTable, chunk_bytes: int):
        self.table = table
        self.chunk_bytes = int(chunk_bytes)
        self.wire_tx = 0      # payload bytes sent on the connection
        self.wire_rx = 0      # payload bytes received on the connection
        self.raw_tx = 0       # pre-compression payload bytes sent
        self.raw_rx = 0

    # -- app -> proxy -----------------------------------------------------------
    def stage(self, state: Any, chunks: dict[str, list[int]] | None) -> int:
        """Write ``state`` (or just ``chunks`` of it) into the mirror table."""
        if chunks is None:
            return self.table.write_state(state)
        return self.table.write_chunks(state, chunks, self.chunk_bytes)

    def payload_frames(
        self, chunks: dict[str, list[int]] | None
    ) -> list[dict] | None:
        """CHUNKS frames to send right after the UPLOAD control frame
        (None: the data plane is shared, nothing rides the wire)."""
        return None

    # -- proxy -> app -----------------------------------------------------------
    def on_chunks(self, msg: dict) -> None:
        """A CHUNKS frame arrived ahead of SYNCED (streamed transport)."""
        raise RuntimeError(
            f"{self.kind} transport does not expect CHUNKS frames"
        )

    def read_state(self) -> Any:
        return self.table.read_state()

    # -- plumbing ---------------------------------------------------------------
    def register_fields(self) -> dict:
        """Transport fields for REGISTER (and the API log's register record)."""
        raise NotImplementedError

    def stats(self) -> dict:
        return {
            "transport": self.kind,
            "wire_tx": self.wire_tx,
            "wire_rx": self.wire_rx,
            "raw_tx": self.raw_tx,
            "raw_rx": self.raw_rx,
            "data_plane_bytes": self.table.bytes_written,
        }

    def close(self, *, unlink: bool = False) -> None:
        self.table.close(unlink=unlink)


class SegmentChunkTransport(ChunkTransport):
    """Local zero-copy transport over shared MAP_SHARED segments."""

    kind = "segment"

    def register_fields(self) -> dict:
        return {
            "transport": "segment",
            "workdir": self.table.workdir,
            "layout": self.table.layout,
        }


class StreamChunkTransport(ChunkTransport):
    """Cross-host transport: payloads as CHUNKS frames on the connection."""

    kind = "stream"

    def __init__(self, table: StateTable, chunk_bytes: int, *,
                 compress: bool | None = None):
        super().__init__(table, chunk_bytes)
        self.compress = compress

    def payload_frames(
        self, chunks: dict[str, list[int]] | None
    ) -> list[dict]:
        if chunks is None:
            chunks = self.table.all_chunks(self.chunk_bytes)
        frames, raw, wire = encode_chunk_frames(
            self.table, chunks, self.chunk_bytes, compress=self.compress
        )
        self.raw_tx += raw
        self.wire_tx += wire
        return frames

    def on_chunks(self, msg: dict) -> None:
        raw, wire = apply_chunk_frame(self.table, msg, self.chunk_bytes)
        self.raw_rx += raw
        self.wire_rx += wire

    def register_fields(self) -> dict:
        return {"transport": "stream", "layout": self.table.layout}


def make_transport(
    kind: str,
    state: Any,
    chunk_bytes: int,
    *,
    workdir: str | None = None,
    compress: bool | None = None,
) -> ChunkTransport:
    """Application-side factory: build the table from ``state`` and wrap it."""
    if kind == "segment":
        return SegmentChunkTransport(
            SegmentTable.create(state, workdir=workdir), chunk_bytes
        )
    if kind == "stream":
        return StreamChunkTransport(
            PrivateTable.create(state, workdir=workdir),
            chunk_bytes,
            compress=compress,
        )
    raise ValueError(f"unknown transport {kind!r}; have {TRANSPORTS}")


def default_log_dir(prefix: str = "crum-proxy-log-") -> str:
    """A directory for the API log when no segment workdir exists (the
    streamed transport has no files of its own)."""
    return tempfile.mkdtemp(prefix=prefix)


def endpoint_arg(value: str) -> tuple[str, int]:
    """Parse a ``host:port`` CLI argument."""
    host, _, port = value.rpartition(":")
    if not host or not port.isdigit():
        raise ValueError(f"expected host:port, got {value!r}")
    return host, int(port)
