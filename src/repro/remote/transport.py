"""ChunkTransport — how device-state bytes cross the app/proxy boundary.

The proxy control plane (``repro.proxy.protocol``) is already
location-transparent: tiny msgpack frames over TCP. What pins a proxy to
the application's machine is the *data* plane — file-backed MAP_SHARED
segments both processes mmap. This module abstracts that into a transport
axis:

``segment``
    the existing local path: bulk bytes move through a shared
    :class:`~repro.proxy.segments.SegmentTable`; UPLOAD/SYNC control
    frames carry no payload. Zero-copy, but both ends must share a
    filesystem (same host).

``stream``
    the cross-host path: UPLOAD/SYNC payloads travel as length-prefixed
    CHUNKS frames *on the control connection itself*, each frame a batch
    of ``[path, chunk_index, raw_len]`` entries plus their concatenated
    bytes (optionally zstd-compressed per frame). Both ends keep a
    :class:`~repro.proxy.segments.PrivateTable` as their local terminal.
    Steady-state wire bytes scale with *dirty chunks* (PR 4's chunk-delta
    machinery decides what is dirty), not with state size.

The application side drives a :class:`ChunkTransport`; the proxy side uses
the module-level helpers (:func:`make_proxy_table`,
:func:`recv_chunk_frames`, :func:`encode_chunk_frames`) from inside the
service dispatch loop.
"""
from __future__ import annotations

import os
import tempfile
from typing import Any

import numpy as np

from repro.proxy.segments import PrivateTable, SegmentTable, StateTable

# payload batching target per CHUNKS frame — far under protocol.MAX_FRAME,
# large enough that framing overhead stays negligible
FRAME_PAYLOAD_BYTES = 1 << 20

TRANSPORTS = ("segment", "stream")


def _zstd():
    try:
        import zstandard

        return zstandard
    except ImportError:
        return None


def train_chunk_dict(
    table: StateTable,
    chunk_bytes: int,
    *,
    dict_bytes: int = 16 << 10,
    max_samples: int = 2048,
) -> bytes | None:
    """Train a zstd dictionary on the table's current chunk population.

    Small-chunk regimes (many tiny leaves, sub-kilobyte dirty ranges) give
    a cold per-frame compressor almost nothing to work with; a trained
    dictionary ships the shared context once, in REGISTER, and every later
    CHUNKS frame compresses against it. Returns the dictionary bytes, or
    None when zstandard is unavailable or the samples are too small/too
    uniform to train on (callers fall back to plain per-frame zstd).
    """
    zstd = _zstd()
    if zstd is None:
        return None
    samples = []
    for path, idx in table.all_chunks(chunk_bytes).items():
        for i in idx:
            samples.append(table.chunk_bytes_of(path, i, chunk_bytes).tobytes())
            if len(samples) >= max_samples:
                break
        if len(samples) >= max_samples:
            break
    try:
        return zstd.train_dictionary(int(dict_bytes), samples).as_bytes()
    except Exception:
        return None  # too few/too small samples — not an error, just no dict


def encode_chunk_frames(
    table: StateTable,
    chunks: dict[str, list[int]],
    chunk_bytes: int,
    *,
    compress: bool | None = None,
    dict_bytes: bytes | None = None,
    ctx: dict | None = None,
) -> tuple[list[dict], int, int]:
    """Pack the given chunks' current table bytes into CHUNKS frame dicts.

    Coalescing: entries accumulate across leaves until ~FRAME_PAYLOAD_BYTES
    of payload, so many small dirty chunks ride one frame instead of one
    frame each. Returns (frames, raw_bytes, wire_bytes): ``raw_bytes`` is
    the payload before compression, ``wire_bytes`` what actually rides the
    connection. ``compress=None`` auto-enables zstd when the package is
    importable — the receiving side decodes per the frame's ``codec``
    field, so both ends must have it (they share this codebase's
    environment). ``dict_bytes`` (a trained dictionary both ends hold, see
    :func:`train_chunk_dict`) switches the codec to ``zstd-dict``.
    ``ctx`` (optional causal context, ``obs.trace``) is stamped on every
    frame so a data-plane stream is attributable to the SYNC/UPLOAD span
    that produced it; None (tracing off) keeps frames byte-identical.
    """
    zstd = _zstd() if compress in (None, True) else None
    if compress is True and zstd is None:
        raise RuntimeError("compress=True but zstandard is not installed")
    cctx = None
    codec_name = "zstd"
    if zstd is not None:
        if dict_bytes:
            cctx = zstd.ZstdCompressor(
                level=1, dict_data=zstd.ZstdCompressionDict(dict_bytes)
            )
            codec_name = "zstd-dict"
        else:
            cctx = zstd.ZstdCompressor(level=1)

    frames: list[dict] = []
    items: list[list] = []
    parts: list[bytes] = []
    pending = 0
    raw_total = wire_total = 0

    def flush() -> None:
        nonlocal items, parts, pending, wire_total
        if not items:
            return
        data = b"".join(parts)
        codec = "raw"
        if cctx is not None:
            packed = cctx.compress(data)
            if len(packed) < len(data):
                data, codec = packed, codec_name
        frame = {"codec": codec, "items": items, "data": data}
        if ctx is not None:
            frame["ctx"] = ctx
        frames.append(frame)
        wire_total += len(data)
        items, parts, pending = [], [], 0

    for path in sorted(chunks):
        for i in sorted(int(x) for x in chunks[path]):
            piece = table.chunk_bytes_of(path, i, chunk_bytes)
            n = int(piece.nbytes)
            items.append([path, i, n])
            parts.append(piece.tobytes())
            pending += n
            raw_total += n
            if pending >= FRAME_PAYLOAD_BYTES:
                flush()
    flush()
    return frames, raw_total, wire_total


def apply_chunk_frame(
    table: StateTable, msg: dict, chunk_bytes: int, *,
    dict_bytes: bytes | None = None,
) -> tuple[int, int]:
    """Splice one CHUNKS frame's payload into the table.

    Returns (raw_bytes, wire_bytes) applied.
    """
    data = msg["data"]
    wire = len(data)
    codec = msg.get("codec")
    if codec in ("zstd", "zstd-dict"):
        zstd = _zstd()
        if zstd is None:
            raise RuntimeError(
                "received a zstd CHUNKS frame but zstandard is not installed"
            )
        if codec == "zstd-dict":
            if not dict_bytes:
                raise RuntimeError(
                    "received a zstd-dict CHUNKS frame but no trained "
                    "dictionary was registered on this end"
                )
            dctx = zstd.ZstdDecompressor(
                dict_data=zstd.ZstdCompressionDict(dict_bytes)
            )
        else:
            dctx = zstd.ZstdDecompressor()
        data = dctx.decompress(data)
    off = 0
    cb = int(chunk_bytes)
    for path, index, raw_len in msg["items"]:
        table.write_range(path, int(index) * cb, data[off : off + int(raw_len)])
        off += int(raw_len)
    if off != len(data):
        raise ValueError(
            f"CHUNKS frame payload is {len(data)}B but items claim {off}B"
        )
    return off, wire


def recv_chunk_frames(
    conn, n_frames: int, table: StateTable, chunk_bytes: int, *,
    dict_bytes: bytes | None = None,
) -> int:
    """Consume exactly ``n_frames`` CHUNKS frames from ``conn`` into the
    table (the proxy side of a streamed UPLOAD). Returns raw bytes applied.
    Raises ``ConnectionError`` on EOF mid-payload (torn upload: the caller
    dies and the app-side runner replays)."""
    import socket

    from repro.proxy.protocol import MSG_CHUNKS

    total = 0
    for _ in range(int(n_frames)):
        while True:
            try:
                msg = conn.recv()
                break
            except (socket.timeout, TimeoutError):
                continue
        if msg is None:
            raise ConnectionError("EOF mid-UPLOAD payload")
        if msg.get("type") != MSG_CHUNKS:
            raise ValueError(
                f"expected CHUNKS payload frame, got {msg.get('type')!r}"
            )
        raw, _ = apply_chunk_frame(table, msg, chunk_bytes, dict_bytes=dict_bytes)
        total += raw
    return total


def make_proxy_table(msg: dict) -> StateTable:
    """The proxy-side table for a REGISTER frame's transport fields."""
    kind = msg.get("transport", "segment")
    if kind == "stream":
        return PrivateTable.attach(msg["layout"])
    if kind == "segment":
        return SegmentTable.attach(msg["workdir"], msg["layout"])
    raise ValueError(f"unknown transport {kind!r}; have {TRANSPORTS}")


class ChunkTransport:
    """Application-side data plane for one registered device state.

    Owns the app's :class:`StateTable` (the mirror the runner reads back
    after SYNC) and knows how to move bytes toward the proxy (``stage`` +
    ``payload_frames``) and how to ingest the proxy's SYNC payload
    (``on_chunks``). Wire counters separate payload that rode the TCP
    connection (``wire_tx``/``wire_rx``) from bytes written into a shared
    data plane (``table.bytes_written`` covers both sides' view of that).
    """

    kind = "?"

    def __init__(self, table: StateTable, chunk_bytes: int):
        self.table = table
        self.chunk_bytes = int(chunk_bytes)
        self.wire_tx = 0      # payload bytes sent on the connection
        self.wire_rx = 0      # payload bytes received on the connection
        self.raw_tx = 0       # pre-compression payload bytes sent
        self.raw_rx = 0
        self.frames_tx = 0    # CHUNKS frames sent (proves coalescing:
        self.frames_rx = 0    # many dirty chunks, few frames)
        self.chunks_tx = 0
        self.chunks_rx = 0

    # -- app -> proxy -----------------------------------------------------------
    def stage(self, state: Any, chunks: dict[str, list[int]] | None) -> int:
        """Write ``state`` (or just ``chunks`` of it) into the mirror table."""
        if chunks is None:
            return self.table.write_state(state)
        return self.table.write_chunks(state, chunks, self.chunk_bytes)

    def payload_frames(
        self, chunks: dict[str, list[int]] | None
    ) -> list[dict] | None:
        """CHUNKS frames to send right after the UPLOAD control frame
        (None: the data plane is shared, nothing rides the wire)."""
        return None

    # -- proxy -> app -----------------------------------------------------------
    def on_chunks(self, msg: dict) -> None:
        """A CHUNKS frame arrived ahead of SYNCED (streamed transport)."""
        raise RuntimeError(
            f"{self.kind} transport does not expect CHUNKS frames"
        )

    def read_state(self) -> Any:
        return self.table.read_state()

    # -- plumbing ---------------------------------------------------------------
    def register_fields(self) -> dict:
        """Transport fields for REGISTER (and the API log's register record)."""
        raise NotImplementedError

    def stats(self) -> dict:
        return {
            "transport": self.kind,
            "wire_tx": self.wire_tx,
            "wire_rx": self.wire_rx,
            "raw_tx": self.raw_tx,
            "raw_rx": self.raw_rx,
            "frames_tx": self.frames_tx,
            "frames_rx": self.frames_rx,
            "chunks_tx": self.chunks_tx,
            "chunks_rx": self.chunks_rx,
            "data_plane_bytes": self.table.bytes_written,
        }

    def canonical_stats(self) -> dict:
        """Registry-form counters: the one snake_case scheme every layer
        emits through (``transport_<metric>``; see repro.obs.metrics)."""
        return {
            f"transport_{k}": v
            for k, v in self.stats().items()
            if isinstance(v, (int, float)) and not isinstance(v, bool)
        }

    def close(self, *, unlink: bool = False) -> None:
        self.table.close(unlink=unlink)


class SegmentChunkTransport(ChunkTransport):
    """Local zero-copy transport over shared MAP_SHARED segments."""

    kind = "segment"

    def register_fields(self) -> dict:
        return {
            "transport": "segment",
            "workdir": self.table.workdir,
            "layout": self.table.layout,
        }


class StreamChunkTransport(ChunkTransport):
    """Cross-host transport: payloads as CHUNKS frames on the connection."""

    kind = "stream"

    def __init__(self, table: StateTable, chunk_bytes: int, *,
                 compress: bool | None = None,
                 zdict: bytes | None = None):
        super().__init__(table, chunk_bytes)
        self.compress = compress
        # trained zstd dictionary shared with the proxy via REGISTER; both
        # directions' CHUNKS frames compress against it (codec zstd-dict)
        self.zdict = zdict

    def payload_frames(
        self, chunks: dict[str, list[int]] | None
    ) -> list[dict]:
        if chunks is None:
            chunks = self.table.all_chunks(self.chunk_bytes)
        frames, raw, wire = encode_chunk_frames(
            self.table, chunks, self.chunk_bytes, compress=self.compress,
            dict_bytes=self.zdict,
        )
        self.raw_tx += raw
        self.wire_tx += wire
        self.frames_tx += len(frames)
        self.chunks_tx += sum(len(f["items"]) for f in frames)
        return frames

    def on_chunks(self, msg: dict) -> None:
        raw, wire = apply_chunk_frame(
            self.table, msg, self.chunk_bytes, dict_bytes=self.zdict
        )
        self.raw_rx += raw
        self.wire_rx += wire
        self.frames_rx += 1
        self.chunks_rx += len(msg["items"])

    def register_fields(self) -> dict:
        fields = {"transport": "stream", "layout": self.table.layout}
        if self.zdict:
            fields["zdict"] = self.zdict
        return fields


def make_transport(
    kind: str,
    state: Any,
    chunk_bytes: int,
    *,
    workdir: str | None = None,
    compress: bool | None = None,
    train_dict: bool = False,
) -> ChunkTransport:
    """Application-side factory: build the table from ``state`` and wrap it.

    ``train_dict=True`` (stream only) trains a zstd dictionary on the
    initial state's chunks and ships it to the proxy in REGISTER.
    """
    if kind == "segment":
        return SegmentChunkTransport(
            SegmentTable.create(state, workdir=workdir), chunk_bytes
        )
    if kind == "stream":
        table = PrivateTable.create(state, workdir=workdir)
        zdict = (
            train_chunk_dict(table, chunk_bytes) if train_dict else None
        )
        return StreamChunkTransport(
            table, chunk_bytes, compress=compress, zdict=zdict,
        )
    raise ValueError(f"unknown transport {kind!r}; have {TRANSPORTS}")


def default_log_dir(prefix: str = "crum-proxy-log-") -> str:
    """A directory for the API log when no segment workdir exists (the
    streamed transport has no files of its own)."""
    return tempfile.mkdtemp(prefix=prefix)


def endpoint_arg(value: str) -> tuple[str, int]:
    """Parse a ``host:port`` CLI argument."""
    host, _, port = value.rpartition(":")
    if not host or not port.isdigit():
        raise ValueError(f"expected host:port, got {value!r}")
    return host, int(port)
