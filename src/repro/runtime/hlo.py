"""Roofline analysis from compiled XLA artifacts (no hardware needed).

Three terms per (arch × shape × mesh), per the methodology in the brief:

    compute    = HLO_FLOPs / (chips × peak_FLOP/s)
    memory     = HLO_bytes / (chips × HBM_bw)
    collective = collective_bytes / (chips × link_bw)

``cost_analysis()`` supplies FLOPs/bytes (whole-program, i.e. summed over
devices for SPMD — divided by chip count here). Collective bytes are
parsed from the optimized HLO text: the summed result sizes of all-gather /
all-reduce / reduce-scatter / all-to-all / collective-permute ops, weighted
by ring-algorithm factors (all-reduce ≈ 2x its payload on a ring).

Hardware model (TPU v5e-class, from the brief):
    197 TFLOP/s bf16 per chip, 819 GB/s HBM, ~50 GB/s/link ICI.
"""
from __future__ import annotations

import re
from dataclasses import dataclass, field

import numpy as np

PEAK_FLOPS = 197e12        # bf16 / chip
HBM_BW = 819e9             # bytes/s / chip
ICI_BW = 50e9              # bytes/s / link

_DTYPE_BYTES = {
    "pred": 1, "s4": 1, "u4": 1, "s8": 1, "u8": 1,
    "s16": 2, "u16": 2, "f16": 2, "bf16": 2,
    "s32": 4, "u32": 4, "f32": 4,
    "s64": 8, "u64": 8, "f64": 8, "c64": 8, "c128": 16,
}

# result-bytes multipliers approximating ring-algorithm wire traffic
_COLLECTIVE_FACTORS = {
    "all-gather": 1.0,
    "all-reduce": 2.0,
    "reduce-scatter": 1.0,
    "all-to-all": 1.0,
    "collective-permute": 1.0,
}

_SHAPE_RE = re.compile(r"\b(pred|[sufbc]\d+|bf16)\[([0-9,]*)\]")
_OP_RE = re.compile(
    r"^\s*(?:ROOT\s+)?%?[\w.\-]+\s*=\s*(.+?)\s+"
    r"(all-gather|all-reduce|reduce-scatter|all-to-all|collective-permute)"
    r"(?:-start|-done)?\(",
)


def _shape_bytes(shape_str: str) -> int:
    """Sum byte sizes of every tensor literal in an HLO type string."""
    total = 0
    for dt, dims in _SHAPE_RE.findall(shape_str):
        if dt not in _DTYPE_BYTES:
            continue
        n = 1
        if dims:
            for d in dims.split(","):
                n *= int(d)
        total += n * _DTYPE_BYTES[dt]
    return total


@dataclass
class CollectiveStats:
    bytes_by_kind: dict[str, int] = field(default_factory=dict)
    count_by_kind: dict[str, int] = field(default_factory=dict)
    weighted_bytes: float = 0.0

    @property
    def total_bytes(self) -> int:
        return sum(self.bytes_by_kind.values())


def parse_collectives(hlo_text: str) -> CollectiveStats:
    """Sum result sizes of collective ops in (optimized) HLO text.

    ``-done`` ops are skipped so async (start/done) pairs count once.
    """
    stats = CollectiveStats()
    for line in hlo_text.splitlines():
        if "-done(" in line:
            continue
        m = _OP_RE.match(line)
        if not m:
            continue
        result_type, kind = m.group(1), m.group(2)
        b = _shape_bytes(result_type)
        if kind == "all-gather" and "-start" in line:
            pass  # result of start op includes the full gathered buffer
        stats.bytes_by_kind[kind] = stats.bytes_by_kind.get(kind, 0) + b
        stats.count_by_kind[kind] = stats.count_by_kind.get(kind, 0) + 1
        stats.weighted_bytes += b * _COLLECTIVE_FACTORS[kind]
    return stats


@dataclass
class Roofline:
    flops: float               # whole-program HLO FLOPs
    hbm_bytes: float           # whole-program bytes accessed
    collective_bytes: float    # weighted wire bytes
    chips: int
    compute_s: float = 0.0
    memory_s: float = 0.0
    collective_s: float = 0.0
    bottleneck: str = ""
    model_flops: float = 0.0   # analytic 6ND / 2ND
    useful_ratio: float = 0.0  # model_flops / HLO flops

    def finalize(self) -> "Roofline":
        self.compute_s = self.flops / (self.chips * PEAK_FLOPS)
        self.memory_s = self.hbm_bytes / (self.chips * HBM_BW)
        self.collective_s = self.collective_bytes / (self.chips * ICI_BW)
        terms = {
            "compute": self.compute_s,
            "memory": self.memory_s,
            "collective": self.collective_s,
        }
        self.bottleneck = max(terms, key=terms.get)
        if self.flops:
            self.useful_ratio = self.model_flops / self.flops
        return self

    def step_time_bound_s(self) -> float:
        return max(self.compute_s, self.memory_s, self.collective_s)

    def as_dict(self) -> dict:
        return {
            "flops": self.flops,
            "hbm_bytes": self.hbm_bytes,
            "collective_bytes": self.collective_bytes,
            "chips": self.chips,
            "compute_s": self.compute_s,
            "memory_s": self.memory_s,
            "collective_s": self.collective_s,
            "bottleneck": self.bottleneck,
            "model_flops": self.model_flops,
            "useful_ratio": self.useful_ratio,
        }


def cost_of(compiled) -> dict:
    """Normalize compiled.cost_analysis() across jax versions."""
    ca = compiled.cost_analysis()
    if isinstance(ca, (list, tuple)):
        ca = ca[0]
    return dict(ca)


def analyze(
    compiled,
    *,
    chips: int,
    model_flops: float = 0.0,
    hlo_text: str | None = None,
) -> Roofline:
    ca = cost_of(compiled)
    flops = float(ca.get("flops", 0.0))
    hbm = float(ca.get("bytes accessed", 0.0))
    text = hlo_text if hlo_text is not None else compiled.as_text()
    coll = parse_collectives(text)
    return Roofline(
        flops=flops,
        hbm_bytes=hbm,
        collective_bytes=coll.weighted_bytes,
        chips=chips,
        model_flops=model_flops,
    ).finalize()


def memory_summary(compiled) -> dict:
    """Per-device memory from compiled.memory_analysis() (best effort)."""
    try:
        ma = compiled.memory_analysis()
    except Exception:
        return {}
    out = {}
    for k in (
        "argument_size_in_bytes",
        "output_size_in_bytes",
        "temp_size_in_bytes",
        "alias_size_in_bytes",
        "generated_code_size_in_bytes",
    ):
        v = getattr(ma, k, None)
        if v is not None:
            out[k] = int(v)
    if out:
        # live bytes: args + outputs + temps - aliased (donated) buffers
        out["total_bytes"] = (
            out.get("argument_size_in_bytes", 0)
            + out.get("output_size_in_bytes", 0)
            + out.get("temp_size_in_bytes", 0)
            - out.get("alias_size_in_bytes", 0)
        )
    return out


def model_flops_train(n_params: int, tokens: int) -> float:
    return 6.0 * n_params * tokens


def model_flops_forward(n_params: int, tokens: int) -> float:
    return 2.0 * n_params * tokens
