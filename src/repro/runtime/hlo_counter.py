"""Loop-aware cost extraction from optimized HLO text.

``compiled.cost_analysis()`` visits every op exactly once — a scan-based
model (layers, microbatches, attention blocks) under-reports FLOPs,
bytes and collectives by the loop trip counts. This module re-derives the
three roofline inputs directly from ``compiled.as_text()``:

  - splits the module into computations,
  - counts per-computation dot FLOPs (2 * prod(out) * contraction size),
    fusion I/O bytes, and collective payload bytes,
  - multiplies while-loop bodies by their ``known_trip_count`` (annotated
    by XLA for counted loops; falls back to 1 with a warning flag),
  - counts ``conditional`` branches at the cost of the *most expensive*
    branch (upper bound; hybrid archs apply their shared block this way),
  - counts async collective start/done pairs once.

All shapes in SPMD HLO are partition-local, so totals are per-device;
callers multiply by chip count for whole-program numbers.
"""
from __future__ import annotations

import re
from dataclasses import dataclass, field

_DTYPE_BYTES = {
    "pred": 1, "s2": 1, "u2": 1, "s4": 1, "u4": 1, "s8": 1, "u8": 1,
    "f8e4m3fn": 1, "f8e5m2": 1,
    "s16": 2, "u16": 2, "f16": 2, "bf16": 2,
    "s32": 4, "u32": 4, "f32": 4,
    "s64": 8, "u64": 8, "f64": 8, "c64": 8, "c128": 16,
}

_COLLECTIVE_FACTORS = {
    "all-gather": 1.0,
    "all-reduce": 2.0,
    "reduce-scatter": 1.0,
    "all-to-all": 1.0,
    "collective-permute": 1.0,
}

_SHAPE_RE = re.compile(r"\b([a-z]\d+(?:e\d+m\d+(?:fn)?)?|pred)\[([0-9,]*)\]")
_COMP_HDR_RE = re.compile(r"^(?:ENTRY\s+)?%?([\w.\-]+)\s*\(.*\)\s*->\s*\S.*\{\s*$")
# type group is lazy `.+?`: tuple types contain `/*index=N*/` comments (with
# '='!) and nested brackets; the first `word(` after whitespace is the opcode
_OP_RE = re.compile(r"^\s*(?:ROOT\s+)?%([\w.\-]+)\s*=\s*(.+?)\s+([\w\-]+)\((.*?)\)(.*)$")
_TRIP_RE = re.compile(r'"known_trip_count":\{"n":"(\d+)"\}')
_CALLS_RE = re.compile(r"calls=%?([\w.\-]+)")
_BODY_RE = re.compile(r"body=%?([\w.\-]+)")
_COND_BRANCHES_RE = re.compile(r"(?:branch_computations|true_computation|false_computation)=\{?%?([\w.\-,%\s]+)\}?")
_LHS_CDIMS_RE = re.compile(r"lhs_contracting_dims=\{([0-9,]*)\}")


def _type_bytes(type_str: str) -> int:
    total = 0
    for dt, dims in _SHAPE_RE.findall(type_str):
        if dt not in _DTYPE_BYTES:
            continue
        n = 1
        if dims:
            for d in dims.split(","):
                n *= int(d)
        total += n * _DTYPE_BYTES[dt]
    return total


def _shape_dims(type_str: str) -> list[int]:
    m = _SHAPE_RE.search(type_str)
    if not m:
        return []
    dims = m.group(2)
    return [int(d) for d in dims.split(",")] if dims else []


@dataclass
class Cost:
    flops: float = 0.0
    hbm_bytes: float = 0.0
    collective_bytes: float = 0.0           # wire-weighted
    coll_by_kind: dict = field(default_factory=dict)
    coll_counts: dict = field(default_factory=dict)
    unknown_trip_loops: int = 0

    def add(self, other: "Cost", times: float = 1.0) -> None:
        self.flops += other.flops * times
        self.hbm_bytes += other.hbm_bytes * times
        self.collective_bytes += other.collective_bytes * times
        for k, v in other.coll_by_kind.items():
            self.coll_by_kind[k] = self.coll_by_kind.get(k, 0) + v * times
        for k, v in other.coll_counts.items():
            self.coll_counts[k] = self.coll_counts.get(k, 0) + v * times
        self.unknown_trip_loops += other.unknown_trip_loops


@dataclass
class _Op:
    name: str
    type_str: str
    opcode: str
    operands: list[str]
    attrs: str


def _parse_computations(text: str) -> dict[str, list[_Op]]:
    comps: dict[str, list[_Op]] = {}
    cur: list[_Op] | None = None
    for raw in text.splitlines():
        line = raw.rstrip()
        hdr = _COMP_HDR_RE.match(line.strip())
        if hdr and line.strip().endswith("{"):
            cur = comps.setdefault(hdr.group(1), [])
            continue
        if line.strip() == "}":
            cur = None
            continue
        if cur is None:
            continue
        m = _OP_RE.match(line)
        if not m:
            continue
        name, type_str, opcode, args, attrs = m.groups()
        operands = [a.strip().lstrip("%") for a in args.split(",") if a.strip().startswith("%")]
        cur.append(_Op(name, type_str, opcode, operands, attrs))
    return comps


class HloCostModel:
    def __init__(self, text: str):
        self.comps = _parse_computations(text)
        # op names are only unique WITHIN a computation (%param_0.1 etc.
        # repeat across fused computations) — resolve types per-comp first
        self.types_by_comp: dict[str, dict[str, str]] = {}
        self.types: dict[str, str] = {}
        for cname, ops in self.comps.items():
            tmap = self.types_by_comp.setdefault(cname, {})
            for op in ops:
                tmap[op.name] = op.type_str
                self.types[op.name] = op.type_str
        self._memo: dict[str, Cost] = {}
        self.entry = self._find_entry(text)

    def _type_of(self, comp: str, name: str) -> str:
        t = self.types_by_comp.get(comp, {}).get(name)
        return t if t is not None else self.types.get(name, "")

    @staticmethod
    def _find_entry(text: str) -> str:
        m = re.search(r"^ENTRY\s+%?([\w.\-]+)", text, re.M)
        if not m:
            raise ValueError("no ENTRY computation found")
        return m.group(1)

    # -- per-op costs ---------------------------------------------------------
    def _dot_flops(self, op: _Op, comp: str) -> float:
        out_dims = _shape_dims(op.type_str)
        lhs_type = self._type_of(comp, op.operands[0]) if op.operands else ""
        lhs_dims = _shape_dims(lhs_type)
        m = _LHS_CDIMS_RE.search(op.attrs)
        contract = 1
        if m and m.group(1):
            for d in m.group(1).split(","):
                i = int(d)
                if i < len(lhs_dims):
                    contract *= lhs_dims[i]
        n_out = 1
        for d in out_dims:
            n_out *= d
        return 2.0 * n_out * contract

    def _op_bytes(self, op: _Op, comp: str) -> float:
        if op.opcode in ("parameter", "constant", "get-tuple-element", "bitcast",
                         "tuple", "after-all"):
            return 0.0
        total = float(_type_bytes(op.type_str))
        for o in op.operands:
            total += _type_bytes(self._type_of(comp, o))
        return total

    def _fusion_bytes(self, op: _Op, called: str, comp: str) -> float:
        """HBM traffic of a fusion, slice-aware.

        A fusion that merely dynamic-slices / gathers from a big operand
        reads only the slice; one whose root dynamic-update-slices into a
        big (aliased, in-place) buffer writes only the update. Counting
        full buffers per loop iteration overstated HBM traffic ~80x on
        scan-heavy models.
        """
        ops = self.comps.get(called)
        if ops is None:
            return self._op_bytes(op, comp)
        try:
            consumers: dict[str, list[_Op]] = {}
            root = ops[-1] if ops else None
            for o in ops:
                if o.opcode == "parameter":
                    continue
                for src in o.operands:
                    consumers.setdefault(src, []).append(o)
            # XLA prints parameters in index order -> positional operand map
            params_in_order = [o for o in ops if o.opcode == "parameter"]
            total = 0.0
            # result: if the fusion root is a DUS, the write is update-sized
            if root is not None and root.opcode == "dynamic-update-slice" and len(root.operands) >= 2:
                total += _type_bytes(self._type_of(called, root.operands[1]))
            else:
                total += _type_bytes(op.type_str)
            for i, operand in enumerate(op.operands):
                full = _type_bytes(self._type_of(comp, operand))
                if i < len(params_in_order):
                    pname = params_in_order[i].name
                    use = consumers.get(pname, [])
                    if use and all(
                        u.opcode in ("dynamic-slice", "gather", "dynamic-update-slice")
                        for u in use
                    ):
                        sliced = 0
                        for u in use:
                            if u.opcode == "dynamic-update-slice":
                                sliced += _type_bytes(
                                    self._type_of(called, u.operands[1])
                                ) if len(u.operands) >= 2 else full
                            else:
                                sliced += _type_bytes(u.type_str)
                        total += min(full, sliced)
                        continue
                total += full
            return total
        except Exception:
            return self._op_bytes(op, comp)

    # -- computation traversal ----------------------------------------------------
    def cost_of(self, comp_name: str) -> Cost:
        if comp_name in self._memo:
            return self._memo[comp_name]
        self._memo[comp_name] = Cost()  # cycle guard
        c = Cost()
        for op in self.comps.get(comp_name, []):
            kind = op.opcode.replace("-start", "")
            if op.opcode == "dot":
                c.flops += self._dot_flops(op, comp_name)
                c.hbm_bytes += self._op_bytes(op, comp_name)
            elif kind in _COLLECTIVE_FACTORS and not op.opcode.endswith("-done"):
                b = _type_bytes(op.type_str)
                c.collective_bytes += b * _COLLECTIVE_FACTORS[kind]
                c.coll_by_kind[kind] = c.coll_by_kind.get(kind, 0) + b
                c.coll_counts[kind] = c.coll_counts.get(kind, 0) + 1
                c.hbm_bytes += self._op_bytes(op, comp_name)
            elif op.opcode == "fusion":
                m = _CALLS_RE.search(op.attrs)
                if m:
                    c.hbm_bytes += self._fusion_bytes(op, m.group(1), comp_name)
                    sub = self.cost_of(m.group(1))
                    c.flops += sub.flops
                    c.collective_bytes += sub.collective_bytes
                else:
                    c.hbm_bytes += self._op_bytes(op, comp_name)
            elif op.opcode == "while":
                body = _BODY_RE.search(op.attrs)
                trip_m = _TRIP_RE.search(op.attrs)
                trip = int(trip_m.group(1)) if trip_m else 1
                if not trip_m:
                    c.unknown_trip_loops += 1
                if body:
                    c.add(self.cost_of(body.group(1)), times=trip)
            elif op.opcode == "conditional":
                branches = re.findall(r"%([\w.\-]+)", op.attrs)
                branch_costs = [
                    self.cost_of(b) for b in branches if b in self.comps
                ]
                if branch_costs:
                    best = max(branch_costs, key=lambda x: x.flops + x.hbm_bytes)
                    c.add(best)
                c.hbm_bytes += self._op_bytes(op, comp_name)
            elif op.opcode in ("call", "async-start"):
                for target in _CALLS_RE.findall(op.attrs) + re.findall(
                    r"to_apply=%?([\w.\-]+)", op.attrs
                ):
                    if target in self.comps:
                        c.add(self.cost_of(target))
            elif op.opcode in ("dynamic-slice", "gather"):
                # read the slice, not the buffer
                c.hbm_bytes += 2.0 * _type_bytes(op.type_str)
            elif op.opcode == "dynamic-update-slice":
                upd = (
                    _type_bytes(self._type_of(comp_name, op.operands[1]))
                    if len(op.operands) >= 2
                    else _type_bytes(op.type_str)
                )
                c.hbm_bytes += 2.0 * upd
            elif op.opcode in ("custom-call", "convolution", "reduce", "sort",
                               "scatter", "copy", "transpose", "reshape",
                               "broadcast", "iota", "convert", "select",
                               "compare", "add", "multiply", "subtract",
                               "divide", "exponential", "pad", "slice",
                               "concatenate", "reduce-window", "rng",
                               "dynamic-reshape", "clamp", "maximum", "minimum"):
                c.hbm_bytes += self._op_bytes(op, comp_name)
        self._memo[comp_name] = c
        return c

    def total(self) -> Cost:
        return self.cost_of(self.entry)


def loop_aware_cost(compiled_text: str) -> Cost:
    return HloCostModel(compiled_text).total()
