"""Sharding rules: param/activation PartitionSpecs per architecture family.

Mesh axes (launch/mesh.py): single-pod ("data", "model") = (16, 16);
multi-pod ("pod", "data", "model") = (2, 16, 16). The pod axis extends
data parallelism across the DCN (gradient all-reduce is the only
cross-pod collective; checkpoint I/O is per-host by construction).

Param rules are (regex over path) -> logical spec, resolved bottom-up per
leaf; FSDP additionally shards the first replicated non-trivial dim over
("pod","data"). GQA archs whose kv_heads don't divide the model axis
replicate KV projections and shard the *head_dim* of the KV cache instead
(DESIGN §4).
"""
from __future__ import annotations

import re
from dataclasses import dataclass
from typing import Any

import jax
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.models.config import ModelConfig
from repro.utils.tree import flatten_with_paths, map_with_paths


# ---------------------------------------------------------------------------
# activation constraint helper (no-op outside a mesh context)
# ---------------------------------------------------------------------------

def _current_mesh_names() -> tuple[str, ...] | None:
    try:
        m = jax.sharding.get_abstract_mesh()
        if m is not None and not m.empty:
            return tuple(m.axis_names)
    except Exception:
        pass
    try:
        from jax._src.mesh import thread_resources

        pm = thread_resources.env.physical_mesh
        if not pm.empty:
            return tuple(pm.axis_names)
    except Exception:
        pass
    return None


def _filter_axes(spec: tuple, names: tuple[str, ...]) -> tuple:
    out = []
    for a in spec:
        if a is None:
            out.append(None)
        elif isinstance(a, str):
            out.append(a if a in names else None)
        elif isinstance(a, (tuple, list)):
            kept = tuple(s for s in a if s in names)
            out.append(kept if kept else None)
        else:
            out.append(None)
    return tuple(out)


def fit_spec(mesh: Mesh, spec: P, shape: tuple[int, ...]) -> P:
    """Drop spec axes that over-index or don't divide the dim (replicate)."""
    if len(spec) > len(shape):
        spec = P(*tuple(spec)[: len(shape)])
    out = []
    for dim, ax in zip(shape, tuple(spec) + (None,) * (len(shape) - len(spec))):
        if ax is None:
            out.append(None)
            continue
        axes = ax if isinstance(ax, tuple) else (ax,)
        if any(a not in mesh.axis_names for a in axes):
            out.append(None)
            continue
        n = int(np.prod([mesh.shape[a] for a in axes], dtype=np.int64))
        out.append(ax if (n and dim % n == 0) else None)
    return P(*out)


def constrain(x: jax.Array, spec: tuple) -> jax.Array:
    """with_sharding_constraint that degrades to a no-op without a mesh."""
    names = _current_mesh_names()
    if not names:
        return x
    clean = _filter_axes(spec, names)
    if all(a is None for a in clean):
        return x
    return jax.lax.with_sharding_constraint(x, P(*clean))


# ---------------------------------------------------------------------------
# parameter rules
# ---------------------------------------------------------------------------

@dataclass(frozen=True)
class ShardingRules:
    """Resolved rule set for one (config, mesh) pair."""

    cfg: ModelConfig
    mesh: Mesh
    fsdp: bool = True

    @property
    def data_axes(self) -> tuple[str, ...]:
        """Axes carrying the batch. Without tensor parallelism the "model"
        axis joins them (DP over the full mesh)."""
        names = ("pod", "data") if self.cfg.tensor_parallel else ("pod", "data", "model")
        return tuple(a for a in names if a in self.mesh.axis_names)

    @property
    def model_axis(self) -> str | None:
        if not self.cfg.tensor_parallel:
            return None
        return "model" if "model" in self.mesh.axis_names else None

    def _model_size(self) -> int:
        return self.mesh.shape["model"] if self.model_axis else 1

    # -- core decisions -------------------------------------------------------
    def kv_heads_shardable(self) -> bool:
        return self.cfg.num_kv_heads % max(self._model_size(), 1) == 0

    def ssm_heads_shardable(self) -> bool:
        return (
            self.cfg.ssm_heads % max(self._model_size(), 1) == 0
            and self.cfg.ssm_heads > 0
        )

    def _fsdp_axis(self, dim: int) -> Any:
        """Axis group for FSDP-sharding a dim, or None if not divisible."""
        if not self.fsdp:
            return None
        n = int(np.prod([self.mesh.shape[a] for a in self.data_axes], dtype=np.int64))
        if n > 1 and dim % n == 0:
            return self.data_axes if len(self.data_axes) > 1 else self.data_axes[0]
        return None

    def param_rules(self) -> list[tuple[str, Any]]:
        """(regex, spec-maker) pairs; first match wins.

        spec-maker is a callable (shape) -> PartitionSpec so FSDP can check
        divisibility per-leaf.
        """
        model = self.model_axis
        cfg = self.cfg
        if cfg.attn_over_model:
            attn_model = None   # attention runs batch-parallel over model
        else:
            attn_model = model
        kv_model = attn_model if self.kv_heads_shardable() else None
        ssm_model = model if self.ssm_heads_shardable() else None

        L = "LAYER"  # sentinel: stacked-layer axis — never sharded, never FSDP'd

        def _clean(ax):
            return [None if a == L else a for a in ax]

        def s(*axes):
            return lambda shape: P(*_clean(list(axes[: len(shape)])))

        def fsdp_last(*axes):
            # FSDP: shard the first unsharded (non-layer) dim over data axes
            def mk(shape):
                ax = list(axes[: len(shape)])
                for i, a in enumerate(ax):
                    if a is None and shape[i] > 1:
                        f = self._fsdp_axis(shape[i])
                        if f is not None:
                            ax[i] = f
                            break
                return P(*_clean(ax))

            return mk
        rules: list[tuple[str, Any]] = [
            # embeddings / lm head: vocab over model, d_model over fsdp
            (r".*(embed|lm_head|codebook_embed|codebook_head).*", fsdp_last(model, None)),
            # attention projections
            (r".*attn/wq$", fsdp_last(L, None, attn_model)),
            (r".*attn/wk$", fsdp_last(L, None, kv_model)),
            (r".*attn/wv$", fsdp_last(L, None, kv_model)),
            (r".*attn/wo$", fsdp_last(L, attn_model, None)),
            (r".*attn/b(q)$", s(L, model)),
            (r".*attn/b(k|v)$", s(L, kv_model)),
            # shared attention block (hybrid): no leading L
            (r".*shared/attn/wq$", fsdp_last(None, model)),
            (r".*shared/attn/w(k|v)$", fsdp_last(None, kv_model)),
            (r".*shared/attn/wo$", fsdp_last(model, None)),
            (r".*shared/mlp/w(i|g)$", fsdp_last(None, model)),
            (r".*shared/mlp/wo$", fsdp_last(model, None)),
            # dense MLP
            (r".*mlp/w(i|g)$", fsdp_last(L, None, model)),
            (r".*mlp/wo$", fsdp_last(L, model, None)),
            # MoE: experts over model; expert matrices fsdp over D
            (r".*moe/router$", s(L, None, None)),
            (r".*moe/w(i|g)$", fsdp_last(L, model, None, None)),
            (r".*moe/wo$", fsdp_last(L, model, None, None)),
            (r".*moe/dense/w(i|g)$", fsdp_last(L, None, model)),
            (r".*moe/dense/wo$", fsdp_last(L, model, None)),
            # mamba2: per-segment projections shard on their own dims
            (r".*ssm/w_(z|x)$", fsdp_last(L, None, ssm_model)),
            (r".*ssm/w_(B|C)$", fsdp_last(L, None, None)),
            (r".*ssm/w_dt$", fsdp_last(L, None, ssm_model)),
            (r".*ssm/w_out$", fsdp_last(L, ssm_model, None)),
            (r".*ssm/conv_x$", s(L, None, ssm_model)),
            (r".*ssm/conv_(B|C)$", s(L, None, None)),
            (r".*ssm/conv_xb$", s(L, ssm_model)),
            (r".*ssm/(conv_Bb|conv_Cb|norm_w)$", s(L, None)),
            (r".*ssm/(A_log|D|dt_bias)$", s(L, None)),
            # vision stub projection
            (r".*vision_proj$", fsdp_last(None, model)),
            # norms & everything else: replicated
            (r".*", s(L, None, None, None, None)),
        ]
        return rules

    # -- public API ---------------------------------------------------------------
    def spec_for(self, path: str, shape: tuple[int, ...]) -> P:
        for pat, mk in self.param_rules():
            if re.fullmatch(pat, path):
                return mk(shape)
        return P()

    def params_specs(self, params_shape: Any) -> Any:
        return map_with_paths(
            lambda p, leaf: fit_spec(
                self.mesh, self.spec_for(p, tuple(leaf.shape)), tuple(leaf.shape)
            ),
            params_shape,
        )

    def params_shardings(self, params_shape: Any) -> Any:
        return jax.tree.map(
            lambda spec: NamedSharding(self.mesh, spec),
            self.params_specs(params_shape),
            is_leaf=lambda x: isinstance(x, P),
        )

    # -- activations / batch / cache ----------------------------------------------
    def batch_spec(self) -> P:
        return P(self.data_axes if len(self.data_axes) > 1 else self.data_axes[0])

    def batch_sharding_for(self, leaf_shape: tuple[int, ...]) -> NamedSharding:
        n = int(np.prod([self.mesh.shape[a] for a in self.data_axes], dtype=np.int64))
        if leaf_shape and leaf_shape[0] % n == 0:
            first = self.data_axes if len(self.data_axes) > 1 else self.data_axes[0]
            spec = [first] + [None] * (len(leaf_shape) - 1)
            return NamedSharding(self.mesh, P(*spec))
        return NamedSharding(self.mesh, P())

    def cache_spec(self) -> P:
        """KV cache (L, B, Hkv, S, Dh).

        Batch shards over "data" only (serve batches rarely divide the full
        DP group — an unshardable axis would replicate the entire cache:
        observed 1.3 TiB/device on musicgen decode_32k). The model axis
        takes kv-heads when divisible, else head_dim (partial-sum attention
        scores, one small all-reduce per step) — this applies even for
        tensor_parallel=False archs, where weights replicate over "model"
        but the cache must still shard.
        """
        model = "model" if "model" in self.mesh.axis_names else None
        data = "data" if "data" in self.mesh.axis_names else None
        n_kv = self.cfg.num_kv_heads
        msize = self.mesh.shape.get("model", 1) if model else 1
        if n_kv and msize > 1 and n_kv % msize == 0:
            return P(None, data, model, None, None)
        if self.cfg.head_dim and msize > 1 and self.cfg.head_dim % msize == 0:
            return P(None, data, None, None, model)
        return P(None, data, None, None, None)

    def decode_batch_axes(self) -> tuple[str, ...]:
        """Token batch for decode: data axes only (see cache_spec)."""
        return tuple(a for a in ("pod", "data") if a in self.mesh.axis_names)

    def ssm_state_spec(self) -> P:
        """SSM decode state (L, B, H, P, N): batch over data only (see
        cache_spec); heads over model when divisible."""
        data = "data" if "data" in self.mesh.axis_names else None
        model = "model" if "model" in self.mesh.axis_names else None
        msize = self.mesh.shape.get("model", 1) if model else 1
        h = self.cfg.ssm_heads
        model = model if (h and msize > 1 and h % msize == 0) else None
        return P(None, data, model, None, None)

    def opt_state_specs(self, params_shape: Any) -> Any:
        """Optimizer moments mirror param specs (ZeRO via fsdp=True)."""
        return self.params_specs(params_shape)
