"""Step builders: jitted train / prefill / decode functions with shardings.

``make_train_step`` assembles loss -> grad -> (optional microbatch
accumulation) -> optimizer into one jitted function with explicit
in/out shardings from the ShardingRules. Gradient accumulation runs as a
``lax.scan`` over microbatch slices with f32 accumulators; the per-
microbatch reduce-scatter of grads overlaps the next microbatch's compute
under XLA's latency-hiding scheduler (§Perf lever).
"""
from __future__ import annotations

from typing import Any

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.models.zoo import Model
from repro.optim import Optimizer, global_norm
from repro.runtime.sharding import ShardingRules, fit_spec
from repro.utils.tree import map_with_paths


def make_train_state_specs(model: Model, rules: ShardingRules, optimizer: Optimizer):
    """Abstract shapes + PartitionSpecs for {"params", "opt", "step"}."""
    params_shape = jax.eval_shape(lambda: model.init(jax.random.key(0)))
    opt_shape = jax.eval_shape(lambda: optimizer.init(params_shape))
    p_spec = rules.params_specs(params_shape)

    def spec_for_opt(path: str, leaf) -> P:
        # moments mirror the param sharding: strip the m/v/f prefix and any
        # quantization/factoring suffix, then apply the param rule; leaves
        # whose rank changed (q8 blocks, factored rows/cols) fall back to
        # replication via fit_spec.
        inner = path
        for prefix in ("m/", "v/", "f/"):
            if inner.startswith(prefix):
                inner = inner[len(prefix):]
                break
        for suffix in ("/q", "/s", "/vr", "/vc", "/v"):
            if inner.endswith(suffix):
                inner = inner[: -len(suffix)]
                break
        spec = rules.spec_for(inner, tuple(leaf.shape))
        return fit_spec(rules.mesh, spec, tuple(leaf.shape))

    o_spec = map_with_paths(spec_for_opt, opt_shape)
    return params_shape, opt_shape, p_spec, o_spec


def _split_microbatches(batch: Any, n: int) -> Any:
    return jax.tree.map(
        lambda x: x.reshape((n, x.shape[0] // n) + x.shape[1:]), batch
    )


def make_train_step(
    model: Model,
    rules: ShardingRules,
    optimizer: Optimizer,
    *,
    microbatches: int | None = None,
    donate: bool = True,
):
    """Returns (jitted_step, state_shardings, batch_shardings_fn)."""
    mb = microbatches or model.cfg.microbatches
    mesh = rules.mesh
    params_shape, opt_shape, p_spec, o_spec = make_train_state_specs(
        model, rules, optimizer
    )

    def to_sharding(spec_tree):
        return jax.tree.map(
            lambda s: NamedSharding(mesh, s),
            spec_tree,
            is_leaf=lambda x: isinstance(x, P),
        )

    state_shardings = {
        "params": to_sharding(p_spec),
        "opt": to_sharding(o_spec),
        "step": NamedSharding(mesh, P()),
    }

    def batch_shardings(batch_shape: Any):
        return jax.tree.map(
            lambda l: rules.batch_sharding_for(tuple(l.shape)), batch_shape
        )

    def step_fn(state, batch):
        params, opt, step = state["params"], state["opt"], state["step"]
        if mb > 1:
            micro = _split_microbatches(batch, mb)

            acc_dt = jnp.dtype(model.cfg.accum_dtype)

            def accum(carry, mb_batch):
                g_acc, l_acc = carry
                (l, _), g = jax.value_and_grad(model.loss, has_aux=True)(
                    params, mb_batch
                )
                g = jax.tree.map(lambda a, b: a + b.astype(acc_dt), g_acc, g)
                return (g, l_acc + l), None

            g0 = jax.tree.map(lambda p: jnp.zeros(p.shape, acc_dt), params)
            (grads, loss_sum), _ = jax.lax.scan(accum, (g0, 0.0), micro)
            # keep the accumulation dtype: optimizers upcast per-leaf inside
            # their update (a tree-wide f32 cast doubled peak grad memory)
            grads = jax.tree.map(lambda g: g / mb, grads)
            metrics = {"loss": loss_sum / mb}
        else:
            (loss, metrics), grads = jax.value_and_grad(model.loss, has_aux=True)(
                params, batch
            )
            metrics = dict(metrics)
            metrics["loss"] = loss
        new_params, new_opt = optimizer.update(grads, opt, params, step)
        metrics["grad_norm"] = global_norm(grads)
        return {"params": new_params, "opt": new_opt, "step": step + 1}, metrics

    jitted = jax.jit(
        step_fn,
        in_shardings=(state_shardings, None),
        out_shardings=(state_shardings, None),
        donate_argnums=(0,) if donate else (),
    )
    return jitted, state_shardings, batch_shardings


def make_prefill_step(model: Model, rules: ShardingRules, cache_len: int):
    params_shape = jax.eval_shape(lambda: model.init(jax.random.key(0)))
    p_shard = rules.params_shardings(params_shape)

    def fn(params, batch):
        return model.prefill(params, batch, cache_len)

    return jax.jit(fn, in_shardings=(p_shard, None)), p_shard


def make_decode_step(model: Model, rules: ShardingRules, *, donate_cache: bool = True):
    """serve_step: (params, cache, tokens) -> (logits, cache)."""
    mesh = rules.mesh
    params_shape = jax.eval_shape(lambda: model.init(jax.random.key(0)))
    p_shard = rules.params_shardings(params_shape)

    def cache_shardings(cache_shape: Any):
        def per_leaf(path: str, leaf):
            shape = tuple(leaf.shape)
            name = path.split("/")[-1]
            if name in ("k", "v") and leaf.ndim == 5:
                spec = rules.cache_spec()
            elif path.startswith("ssm") and leaf.ndim == 5:
                spec = rules.ssm_state_spec()
            elif path.startswith("ssm") and leaf.ndim >= 2:
                batch_ax = (
                    rules.data_axes if len(rules.data_axes) > 1 else rules.data_axes[0]
                )
                spec = P(None, batch_ax)
            else:
                spec = P()
            return NamedSharding(mesh, fit_spec(mesh, spec, shape))

        return map_with_paths(per_leaf, cache_shape)

    def token_sharding(tok_shape) -> NamedSharding:
        axes = rules.decode_batch_axes()
        shape = tuple(tok_shape.shape)
        first = axes if len(axes) > 1 else (axes[0] if axes else None)
        spec = P(*([first] + [None] * (len(shape) - 1))) if shape else P()
        return NamedSharding(mesh, fit_spec(mesh, spec, shape))

    def fn(params, cache, tokens):
        return model.decode(params, cache, tokens)

    jitted = jax.jit(
        fn,
        in_shardings=(p_shard, None, None),
        donate_argnums=(1,) if donate_cache else (),
    )
    return jitted, p_shard, cache_shardings, token_sharding
