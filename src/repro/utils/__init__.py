from repro.utils.tree import (
    flatten_with_paths,
    unflatten_from_paths,
    path_str,
    tree_equal,
    map_with_paths,
)
from repro.utils.timing import Timer, Timings

__all__ = [
    "flatten_with_paths",
    "unflatten_from_paths",
    "path_str",
    "tree_equal",
    "map_with_paths",
    "Timer",
    "Timings",
]
