"""Optional-dependency shims for the test suite.

``hypothesis`` is an optional dev dependency (the ``[test]`` extra). Test
modules that mix property-based and plain tests import the decorators via

    from repro.utils.testing import given, settings, st

so that when hypothesis is absent only the property tests skip, instead of
the whole module erroring at collection.
"""
from __future__ import annotations

try:
    from hypothesis import given, settings, strategies as st

    HAVE_HYPOTHESIS = True
except ImportError:
    HAVE_HYPOTHESIS = False

    class _AnyStrategy:
        """Stand-in for ``hypothesis.strategies``: every attribute is a
        callable returning None (the stubs are never executed)."""

        def __getattr__(self, name: str):
            return lambda *a, **k: None

    st = _AnyStrategy()

    def settings(*args, **kwargs):
        if args and callable(args[0]):  # used as a bare decorator
            return args[0]
        return lambda fn: fn

    def given(*args, **kwargs):
        def deco(fn):
            import pytest

            def stub():
                pytest.skip("hypothesis not installed (pip install .[test])")

            stub.__name__ = fn.__name__
            stub.__doc__ = fn.__doc__
            return stub

        return deco
