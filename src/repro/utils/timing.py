"""Lightweight wall-clock instrumentation.

The paper reports drain time, transfer time, blocking checkpoint time and
total persist time separately (§4.2–4.5); every CRUM phase here is timed so
benchmarks can reproduce those splits.
"""
from __future__ import annotations

import time
from collections import defaultdict
from contextlib import contextmanager
from dataclasses import dataclass, field


@dataclass
class Timings:
    """Accumulates named durations (seconds)."""

    totals: dict[str, float] = field(default_factory=lambda: defaultdict(float))
    counts: dict[str, int] = field(default_factory=lambda: defaultdict(int))

    def add(self, name: str, seconds: float) -> None:
        self.totals[name] += seconds
        self.counts[name] += 1

    def mean(self, name: str) -> float:
        c = self.counts.get(name, 0)
        return self.totals[name] / c if c else 0.0

    def summary(self) -> dict[str, dict[str, float]]:
        return {
            k: {"total_s": self.totals[k], "count": self.counts[k], "mean_s": self.mean(k)}
            for k in sorted(self.totals)
        }

    @contextmanager
    def measure(self, name: str):
        t0 = time.perf_counter()
        try:
            yield
        finally:
            self.add(name, time.perf_counter() - t0)


class Timer:
    """Context manager returning elapsed seconds via ``.elapsed``."""

    def __enter__(self) -> "Timer":
        self._t0 = time.perf_counter()
        self.elapsed = 0.0
        return self

    def __exit__(self, *exc) -> None:
        self.elapsed = time.perf_counter() - self._t0
