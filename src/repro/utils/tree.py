"""Pytree path utilities.

Checkpoint state is addressed by *path strings* — stable, human-readable keys
derived from the pytree structure (e.g. ``params/layers/attn/wq``). All
checkpoint formats key chunks by (path, global offset), never by flatten
order, so adding/removing leaves does not invalidate unrelated chunks.
"""
from __future__ import annotations

import hashlib
from typing import Any, Callable

import jax
import numpy as np
from jax.tree_util import (
    DictKey,
    FlattenedIndexKey,
    GetAttrKey,
    SequenceKey,
    tree_flatten_with_path,
    tree_unflatten,
)


def path_str(path: tuple) -> str:
    """Render a jax key-path as a stable '/'-joined string."""
    parts = []
    for k in path:
        if isinstance(k, DictKey):
            parts.append(str(k.key))
        elif isinstance(k, SequenceKey):
            parts.append(str(k.idx))
        elif isinstance(k, GetAttrKey):
            parts.append(str(k.name))
        elif isinstance(k, FlattenedIndexKey):
            parts.append(str(k.key))
        else:  # pragma: no cover - future key types
            parts.append(str(k))
    return "/".join(parts)


def flatten_with_paths(tree: Any) -> tuple[dict[str, Any], Any]:
    """Flatten ``tree`` to an ordered {path_str: leaf} dict + treedef."""
    leaves, treedef = tree_flatten_with_path(tree)
    out: dict[str, Any] = {}
    for path, leaf in leaves:
        key = path_str(path)
        if key in out:
            raise ValueError(f"duplicate path key {key!r} in pytree")
        out[key] = leaf
    return out, treedef


def unflatten_from_paths(treedef: Any, flat: dict[str, Any]) -> Any:
    """Inverse of :func:`flatten_with_paths` for the same treedef."""
    # tree_unflatten consumes leaves in flatten order; re-derive that order
    # from the treedef itself so dict insertion order never matters.
    dummy = tree_unflatten(treedef, list(range(treedef.num_leaves)))
    keyed, _ = tree_flatten_with_path(dummy)
    ordered = []
    for path, _ in keyed:
        key = path_str(path)
        if key not in flat:
            raise KeyError(f"missing leaf {key!r} during unflatten")
        ordered.append(flat[key])
    return tree_unflatten(treedef, ordered)


def map_with_paths(fn: Callable[[str, Any], Any], tree: Any) -> Any:
    """tree_map where ``fn`` receives (path_str, leaf)."""
    return jax.tree_util.tree_map_with_path(
        lambda p, x: fn(path_str(p), x), tree
    )


def tree_digest(tree: Any) -> str:
    """Order-stable content hash of a pytree of arrays.

    Used for lockstep-convergence assertions (cluster workers) and for the
    device proxy's bit-identical replay guarantee: two states digest equal
    iff every leaf's bytes are equal, independent of dict insertion order.
    """
    flat, _ = flatten_with_paths(tree)
    h = hashlib.sha256()
    for path in sorted(flat):
        h.update(path.encode())
        h.update(np.ascontiguousarray(np.asarray(flat[path])).tobytes())
    return h.hexdigest()[:16]


def tree_equal(a: Any, b: Any) -> bool:
    """Structural + bitwise equality of two pytrees of arrays."""
    fa, da = flatten_with_paths(a)
    fb, db = flatten_with_paths(b)
    if da != db or fa.keys() != fb.keys():
        return False
    for k in fa:
        x, y = np.asarray(fa[k]), np.asarray(fb[k])
        if x.shape != y.shape or x.dtype != y.dtype:
            return False
        if x.dtype == np.dtype(object):  # pragma: no cover
            if not (x == y).all():
                return False
        elif not np.array_equal(
            x.view(np.uint8) if x.dtype.kind == "f" else x,
            y.view(np.uint8) if y.dtype.kind == "f" else y,
        ):
            return False
    return True
