"""Managed-memory (UVM) paging subsystem — CRUM's actual substrate.

The paper checkpoints CUDA *unified memory*: allocations whose pages
migrate between host and device on demand, letting the working set exceed
device memory. This package models that layer explicitly — page-granular
residency and dirty bits (``pagetable``), fault-driven migration with
bounded device frames and pluggable eviction (``pager``), memadvise/
prefetch hints (``advice``), and the pytree-facing facade with a hard
``device_capacity_bytes`` budget (``space``). The checkpoint stack reads
dirty history from here (page-delta sync instead of whole-leaf digest
scans) and the device proxy routes step/sync/upload through it so a proxy
can host state larger than its device budget.
"""
from repro.uvm.advice import Advice, PrefetchStream
from repro.uvm.pagetable import PageTable, PageTableError, Residency
from repro.uvm.pager import (
    ClockPolicy,
    DeviceArena,
    EvictionPolicy,
    LRUPolicy,
    Pager,
    PagingStats,
    make_eviction_policy,
)
from repro.uvm.space import (
    DEFAULT_PAGE_BYTES,
    ManagedSpace,
    SpaceDirtySource,
)

EVICTION_POLICIES = ("lru", "clock")

__all__ = [
    "Advice", "PrefetchStream",
    "PageTable", "PageTableError", "Residency",
    "ClockPolicy", "DeviceArena", "EvictionPolicy", "LRUPolicy",
    "Pager", "PagingStats", "make_eviction_policy",
    "DEFAULT_PAGE_BYTES", "ManagedSpace", "SpaceDirtySource",
    "EVICTION_POLICIES",
]
