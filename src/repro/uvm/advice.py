"""cudaMemAdvise / cudaMemPrefetchAsync-style hints for managed regions.

UVM's performance story (UVMBench; CRUM §2) is dominated by whether the
application tells the driver what it knows:

    READ_MOSTLY         read faults *duplicate* the page (residency BOTH):
                        the host keeps a valid copy, so a later host read —
                        e.g. the checkpoint sync — costs no migration. A
                        write collapses the duplication (pager.fault_in).
    PREFERRED_HOST      evict these pages first; the device copy is a
                        transient.
    PREFERRED_DEVICE    evict these pages last; hot working set.

``PrefetchStream`` is the cudaMemPrefetchAsync analogue: enqueued ranges
migrate in batches ahead of the faults that would otherwise pay the
latency, counted as prefetches (not faults) in the paging stats.
"""
from __future__ import annotations

import enum
from dataclasses import dataclass, field


class Advice(enum.IntFlag):
    NONE = 0
    READ_MOSTLY = 1
    PREFERRED_HOST = 2
    PREFERRED_DEVICE = 4


@dataclass
class PrefetchStream:
    """An ordered queue of (path, lo_page, hi_page) prefetch requests.

    ``enqueue`` records intent; ``drain(space)`` issues the migrations in
    ``batch_pages``-sized slices so a huge prefetch cannot monopolize the
    arena (each batch may evict the previous one under oversubscription —
    exactly the self-defeating prefetch the benchmark can demonstrate).
    """

    batch_pages: int = 64
    _queue: list[tuple[str, int, int]] = field(default_factory=list)

    def enqueue(self, path: str, lo_page: int = 0, hi_page: int | None = None) -> None:
        self._queue.append((path, int(lo_page), -1 if hi_page is None else int(hi_page)))

    def __len__(self) -> int:
        return len(self._queue)

    def drain(self, space) -> int:
        """Issue everything queued against ``space``; returns pages moved."""
        moved = 0
        queue, self._queue = self._queue, []
        for path, lo, hi in queue:
            table = space.table(path)
            hi = table.n_pages if hi < 0 else min(hi, table.n_pages)
            for batch_lo in range(lo, hi, self.batch_pages):
                batch_hi = min(hi, batch_lo + self.batch_pages)
                moved += space.prefetch_pages(path, batch_lo, batch_hi)
        return moved
