"""Fault-driven host<->device migration under a hard frame budget.

The device is modeled honestly as a bounded frame arena: ``capacity_bytes``
divided into page frames, each holding the *actual bytes* of whichever page
is resident. A device access to a non-resident page is a fault: the pager
allocates a frame (evicting a victim when the arena is full — writing the
victim back to the host backing store first if its device copy is newer)
and migrates the page's bytes h2d. This makes oversubscription real: a
working set larger than the arena physically cannot be resident at once,
and every byte a policy decision saves or wastes is counted.

Eviction policies (``cudaMemAdvise`` §: UVM's LRU vs the Volta+ access
counters):

    lru     strict least-recently-used over resident frames
    clock   access-counter clock (second chance): a frame touched since the
            hand last passed gets its reference bit cleared and is skipped
            once; cold frames are evicted on first encounter

Pages advised PREFERRED_HOST are evicted preferentially; PREFERRED_DEVICE
pages are passed over while any unadvised victim exists.
"""
from __future__ import annotations

from collections import OrderedDict
from dataclasses import dataclass, field
from typing import Callable

import numpy as np

from repro.uvm.pagetable import PageTable, Residency


@dataclass
class PagingStats:
    """Counters the benchmarks and round logs report."""

    faults_read: int = 0
    faults_write: int = 0
    hits: int = 0               # device accesses to already-resident pages
    prefetches: int = 0         # pages migrated ahead of a fault
    evictions: int = 0
    writebacks: int = 0         # evictions that had to copy d2h first
    invalidations: int = 0      # frames dropped by load/overwrite (no copy)
    h2d_bytes: int = 0
    d2h_bytes: int = 0
    resident_high_water: int = 0  # peak resident bytes
    # access-counter promotion (Volta-style): a cold read is served
    # *remotely* (device reads host memory over the bus, no migration)
    # until the page's access count within the window crosses the
    # threshold — then it is promoted to a device frame
    remote_reads: int = 0
    remote_read_bytes: int = 0
    promotions: int = 0         # migrations triggered by crossing the threshold

    @property
    def faults(self) -> int:
        return self.faults_read + self.faults_write

    def as_dict(self) -> dict:
        d = {k: int(getattr(self, k)) for k in (
            "faults_read", "faults_write", "hits", "prefetches", "evictions",
            "writebacks", "invalidations", "h2d_bytes", "d2h_bytes",
            "resident_high_water", "remote_reads", "remote_read_bytes",
            "promotions",
        )}
        d["faults"] = self.faults
        return d

    def canonical(self) -> dict:
        """Registry-form counters: the one snake_case scheme every layer
        emits through (``uvm_<metric>``; see repro.obs.metrics)."""
        return {f"uvm_{k}": v for k, v in self.as_dict().items()}


class EvictionPolicy:
    """Victim selection over device frames. Frames are identified by index
    into the arena; the pager reports inserts/accesses/releases."""

    name = "?"

    def note_insert(self, fid: int) -> None:
        raise NotImplementedError

    def note_access(self, fid: int) -> None:
        raise NotImplementedError

    def forget(self, fid: int) -> None:
        raise NotImplementedError

    def pick_victim(self, eligible: Callable[[int], bool]) -> int | None:
        """A frame id with ``eligible(fid)`` true, or None if none is."""
        raise NotImplementedError


class LRUPolicy(EvictionPolicy):
    """Strict LRU: evict the least recently accessed eligible frame."""

    name = "lru"

    def __init__(self):
        self._order: OrderedDict[int, None] = OrderedDict()

    def note_insert(self, fid: int) -> None:
        self._order[fid] = None
        self._order.move_to_end(fid)

    def note_access(self, fid: int) -> None:
        if fid in self._order:
            self._order.move_to_end(fid)

    def forget(self, fid: int) -> None:
        self._order.pop(fid, None)

    def pick_victim(self, eligible: Callable[[int], bool]) -> int | None:
        for fid in self._order:  # oldest first
            if eligible(fid):
                return fid
        return None


class ClockPolicy(EvictionPolicy):
    """Access-counter clock (second chance). Referenced frames survive one
    pass of the hand; a frame untouched between passes is evicted."""

    name = "clock"

    def __init__(self, n_frames: int):
        self.ref = np.zeros(n_frames, np.bool_)
        self.live = np.zeros(n_frames, np.bool_)
        self._hand = 0

    def note_insert(self, fid: int) -> None:
        self.live[fid] = True
        self.ref[fid] = True

    def note_access(self, fid: int) -> None:
        self.ref[fid] = True

    def forget(self, fid: int) -> None:
        self.live[fid] = False
        self.ref[fid] = False

    def pick_victim(self, eligible: Callable[[int], bool]) -> int | None:
        n = len(self.live)
        # two full sweeps: the first may only clear reference bits
        for _ in range(2 * n):
            fid = self._hand
            self._hand = (self._hand + 1) % n
            if not self.live[fid] or not eligible(fid):
                continue
            if self.ref[fid]:
                self.ref[fid] = False  # second chance
                continue
            return fid
        # everything referenced+eligible was given its chance: fall back to
        # the first eligible frame so eviction always terminates
        for fid in range(n):
            if self.live[fid] and eligible(fid):
                return fid
        return None


def make_eviction_policy(name: str, n_frames: int) -> EvictionPolicy:
    if name == "lru":
        return LRUPolicy()
    if name == "clock":
        return ClockPolicy(n_frames)
    raise ValueError(f"unknown eviction policy {name!r}; have ['clock', 'lru']")


class DeviceArena:
    """The simulated device memory: ``n_frames`` page-sized byte frames."""

    def __init__(self, capacity_bytes: int, page_bytes: int):
        if capacity_bytes < page_bytes:
            raise ValueError(
                f"device capacity {capacity_bytes}B is smaller than one page "
                f"({page_bytes}B) — nothing could ever be resident"
            )
        self.page_bytes = int(page_bytes)
        self.n_frames = int(capacity_bytes) // self.page_bytes
        self.frames = np.zeros((self.n_frames, self.page_bytes), np.uint8)
        self.owner: list[tuple[PageTable, int] | None] = [None] * self.n_frames
        self.free: list[int] = list(range(self.n_frames - 1, -1, -1))

    @property
    def resident_frames(self) -> int:
        return self.n_frames - len(self.free)


@dataclass
class Pager:
    """The fault/evict/write-back state machine over one arena.

    ``host_of`` maps a PageTable to its host backing bytes (u8 view) —
    supplied by the ManagedSpace that owns the regions.
    """

    arena: DeviceArena
    policy: EvictionPolicy
    host_of: Callable[[PageTable], np.ndarray]
    stats: PagingStats = field(default_factory=PagingStats)
    _pinned: set = field(default_factory=set)

    # -- faulting ---------------------------------------------------------------
    def fault_in(
        self,
        table: PageTable,
        pages,
        *,
        write: bool,
        tick: int,
        prefetch: bool = False,
        overwrite: bool = False,
        pin: bool = False,
        read_mostly: bool = False,
    ) -> None:
        """Make ``pages`` device-resident; count faults/hits/migrations.

        ``overwrite`` is the write-allocate fast path: the caller is about
        to overwrite the whole page, so the stale h2d copy is skipped.
        ``pin`` keeps the faulted frames ineligible for eviction until
        :meth:`unpin_all` — used while a windowed reader copies them out.
        """
        host = None  # lazy: only touched when a migration actually happens
        for p in (int(x) for x in np.atleast_1d(pages)):
            res = table.residency[p]
            if res != Residency.HOST:
                fid = int(table.frame[p])
                if not prefetch:
                    self.stats.hits += 1
                self.policy.note_access(fid)
                if write and res == Residency.BOTH:
                    # a write collapses read-mostly duplication: the host
                    # copy is stale from here until write-back
                    table.residency[p] = Residency.DEVICE
            else:
                fid = self._take_frame()
                self.arena.owner[fid] = (table, p)
                table.frame[p] = fid
                n = table.page_nbytes(p)
                if not (write and overwrite):
                    if host is None:
                        host = self.host_of(table)
                    lo, hi = table.page_span(p)
                    self.arena.frames[fid, : hi - lo] = host[lo:hi]
                    self.stats.h2d_bytes += n
                if prefetch:
                    self.stats.prefetches += 1
                elif write:
                    self.stats.faults_write += 1
                else:
                    self.stats.faults_read += 1
                table.residency[p] = (
                    Residency.BOTH
                    if (not write and read_mostly)
                    else Residency.DEVICE
                )
                self.policy.note_insert(fid)
                self.stats.resident_high_water = max(
                    self.stats.resident_high_water,
                    self.arena.resident_frames * self.arena.page_bytes,
                )
            if write:
                table.wb_dirty[p] = True
                table.write_tick[p] = tick
            table.access_tick[p] = tick
            table.access_count[p] += 1
            if pin:
                self._pinned.add(int(table.frame[p]))

    def unpin_all(self) -> None:
        self._pinned.clear()

    # -- eviction ---------------------------------------------------------------
    def _take_frame(self) -> int:
        if self.arena.free:
            return self.arena.free.pop()
        fid = self._pick_victim()
        if fid is None:
            raise RuntimeError(
                "device arena exhausted with every frame pinned — shrink the "
                "fault window or raise device_capacity_bytes"
            )
        self.evict(fid)
        return self.arena.free.pop()

    def _pick_victim(self) -> int | None:
        from repro.uvm.advice import Advice

        def unpinned(fid: int) -> bool:
            return fid not in self._pinned

        # eviction preference: advised-host pages first, unadvised next,
        # advised-device pages only when nothing else remains
        def advised_host(fid: int) -> bool:
            if not unpinned(fid):
                return False
            owner = self.arena.owner[fid]
            return owner is not None and bool(
                owner[0].advice & Advice.PREFERRED_HOST
            )

        def not_device_preferred(fid: int) -> bool:
            if not unpinned(fid):
                return False
            owner = self.arena.owner[fid]
            return owner is None or not bool(
                owner[0].advice & Advice.PREFERRED_DEVICE
            )

        for eligible in (advised_host, not_device_preferred, unpinned):
            fid = self.policy.pick_victim(eligible)
            if fid is not None:
                return fid
        return None

    def evict(self, fid: int) -> None:
        """Release one frame. A dirty page is ALWAYS written back first —
        the invariant the property tests pin down."""
        owner = self.arena.owner[fid]
        if owner is None:
            return
        table, p = owner
        if table.wb_dirty[p]:
            lo, hi = table.page_span(p)
            self.host_of(table)[lo:hi] = self.arena.frames[fid, : hi - lo]
            table.wb_dirty[p] = False
            self.stats.writebacks += 1
            self.stats.d2h_bytes += hi - lo
        table.residency[p] = Residency.HOST
        table.frame[p] = -1
        self.policy.forget(fid)
        self.arena.owner[fid] = None
        self.arena.free.append(fid)
        self.stats.evictions += 1

    def evict_table(self, table: PageTable) -> None:
        """Write back and release every frame ``table`` holds."""
        for p in table.device_pages():
            self.evict(int(table.frame[p]))

    def invalidate_page(self, table: PageTable, page: int) -> None:
        """Drop one page's frame WITHOUT write-back — only valid when the
        caller is about to overwrite that page's host backing (load /
        restore): the device copy is superseded, not lost."""
        if table.residency[page] == Residency.HOST:
            return
        fid = int(table.frame[page])
        table.wb_dirty[page] = False
        table.residency[page] = Residency.HOST
        table.frame[page] = -1
        self.policy.forget(fid)
        self.arena.owner[fid] = None
        self.arena.free.append(fid)
        self.stats.invalidations += 1

    def invalidate_table(self, table: PageTable) -> None:
        """Whole-region :meth:`invalidate_page` (load_state/re-register)."""
        for p in table.device_pages():
            self.invalidate_page(table, int(p))
