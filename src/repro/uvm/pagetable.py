"""Page table — per-page residency, dirty and access bits for one region.

CRUM operates on CUDA's managed (UVM) address space: every allocation is a
run of pages that migrate between host and device on demand, and the
checkpointer's unit of work is the page, not the allocation. This module is
that bookkeeping layer, one :class:`PageTable` per managed region (= one
pytree leaf):

    residency   HOST / DEVICE / BOTH      (BOTH = read-mostly duplication:
                                           both copies valid, host readable
                                           without a migration)
    wb_dirty    device copy is newer than the host backing page; an eviction
                MUST write it back (the driver's dirty bit)
    write_tick  monotonic tick of the last write fault — the page-granular
                dirty *history* the checkpoint sync consumes ("which pages
                changed since tick T?"), deliberately never cleared by
                eviction: write-back makes host bytes current but the page
                is still dirty relative to an older checkpoint.
    access_*    LRU / access-counter inputs for the eviction policies.

All bits are numpy arrays so range operations (fault a window, query a
dirty epoch) are vectorized; the per-page state machine itself lives in
``pager.py``.
"""
from __future__ import annotations

import enum

import numpy as np


class Residency(enum.IntEnum):
    HOST = 0     # only the host backing page is valid
    DEVICE = 1   # page lives in a device frame; host copy stale iff wb_dirty
    BOTH = 2     # duplicated (cudaMemAdviseSetReadMostly): both copies valid


class PageTableError(RuntimeError):
    """An operation violated the page-table state machine."""


class PageTable:
    """Residency/dirty/access bits for one contiguous byte region."""

    __slots__ = (
        "path", "nbytes", "page_bytes", "n_pages",
        "residency", "frame", "wb_dirty",
        "write_tick", "access_tick", "access_count",
        "advice",
    )

    def __init__(self, path: str, nbytes: int, page_bytes: int):
        if page_bytes <= 0:
            raise ValueError(f"page_bytes must be positive, got {page_bytes}")
        self.path = path
        self.nbytes = int(nbytes)
        self.page_bytes = int(page_bytes)
        self.n_pages = max(1, -(-self.nbytes // self.page_bytes))
        n = self.n_pages
        self.residency = np.full(n, Residency.HOST, np.int8)
        self.frame = np.full(n, -1, np.int64)       # device frame id or -1
        self.wb_dirty = np.zeros(n, np.bool_)       # needs write-back
        self.write_tick = np.zeros(n, np.int64)     # last write-fault tick
        self.access_tick = np.zeros(n, np.int64)    # last access tick (LRU)
        self.access_count = np.zeros(n, np.int64)   # faults+hits (counters)
        self.advice = 0                             # advice.Advice flags

    # -- geometry --------------------------------------------------------------
    def page_nbytes(self, page: int) -> int:
        """Valid bytes in ``page`` (the tail page may be partial)."""
        lo = page * self.page_bytes
        return max(0, min(self.nbytes, lo + self.page_bytes) - lo)

    def page_span(self, page: int) -> tuple[int, int]:
        lo = page * self.page_bytes
        return lo, min(self.nbytes, lo + self.page_bytes)

    def pages_for_range(self, lo: int, hi: int) -> tuple[int, int]:
        """[lo_page, hi_page) covering byte range [lo, hi)."""
        if not 0 <= lo <= hi <= max(self.nbytes, 1):
            raise ValueError(
                f"byte range [{lo}, {hi}) outside region of {self.nbytes}B"
            )
        if lo == hi:
            return 0, 0
        return lo // self.page_bytes, -(-hi // self.page_bytes)

    # -- queries ---------------------------------------------------------------
    def device_pages(self) -> np.ndarray:
        """Indices of pages holding a device frame (DEVICE or BOTH)."""
        return np.flatnonzero(self.residency != Residency.HOST)

    def device_bytes(self) -> int:
        pages = self.device_pages()
        if pages.size == 0:
            return 0
        full = int(pages.size) * self.page_bytes
        if pages[-1] == self.n_pages - 1:
            full -= self.page_bytes - self.page_nbytes(self.n_pages - 1)
        return full

    def dirty_pages_since(self, tick: int) -> np.ndarray:
        """Pages written strictly after ``tick`` (checkpoint dirty epoch)."""
        return np.flatnonzero(self.write_tick > tick)

    # -- verification (tests / property checks) --------------------------------
    def check_invariants(self) -> None:
        """Raise PageTableError on any inconsistent per-page state."""
        host = self.residency == Residency.HOST
        if np.any(self.frame[host] != -1):
            raise PageTableError(f"{self.path}: HOST page holds a frame")
        if np.any(self.wb_dirty[host]):
            raise PageTableError(
                f"{self.path}: HOST page marked write-back dirty "
                "(a dirty page was dropped without write-back)"
            )
        if np.any(self.frame[~host] < 0):
            raise PageTableError(f"{self.path}: resident page without a frame")
        both = self.residency == Residency.BOTH
        if np.any(self.wb_dirty[both]):
            raise PageTableError(
                f"{self.path}: duplicated (BOTH) page cannot be dirty — a "
                "write must collapse the duplication first"
            )
