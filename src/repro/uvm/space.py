"""ManagedSpace — the managed (UVM) address space backing a pytree.

The public face of the paging subsystem. One space owns:

  - a host backing buffer per pytree leaf (the managed allocation),
  - one :class:`PageTable` per leaf (residency / dirty / access bits),
  - one :class:`DeviceArena` bounded by ``device_capacity_bytes`` — the
    hard budget that makes oversubscription mean something,
  - the :class:`Pager` that migrates pages on fault and writes dirty
    victims back on eviction.

Access model (matching managed-memory semantics, not mirroring them):

    read_leaf / read_state    device access: faults every touched page in
                              (windowed, pinned, budget-respecting) and
                              returns the assembled array — what a kernel
                              sees.
    write_leaf / write_state  device write access: write-allocates frames
                              (no stale h2d copy), marks wb_dirty and
                              stamps the page's write_tick.
    peek_leaf / peek_state    coherent host read WITHOUT migration (the
                              cudaMemcpy-from-managed path): host backing
                              overlaid with any newer device frames. The
                              checkpoint sync reads through this.
    load_leaf / load_state    host overwrite (restore/upload): device
                              frames are invalidated (superseded, not
                              dropped), all pages become epoch-dirty.

Dirty history is tick-based, not a single clearable bit: every write
stamps ``write_tick``; ``dirty_chunk_marks_since(tick)`` answers "which
checkpoint chunks changed after T?" for any T, so multiple shadow buffers
(the forked checkpointer's double buffering) can each diff against their
own last-sync tick without stepping on each other.
"""
from __future__ import annotations

from typing import Any, Iterator

import numpy as np

from repro.uvm.advice import Advice
from repro.uvm.pagetable import PageTable, Residency
from repro.uvm.pager import (
    DeviceArena,
    Pager,
    PagingStats,
    make_eviction_policy,
)
from repro.utils.tree import flatten_with_paths, unflatten_from_paths

DEFAULT_PAGE_BYTES = 64 << 10  # 64 KiB — x86 UVM's effective fault granule


class _Region:
    __slots__ = ("path", "shape", "dtype", "host", "table")

    def __init__(self, path: str, arr: np.ndarray, page_bytes: int):
        self.path = path
        self.shape = tuple(arr.shape)
        self.dtype = arr.dtype
        self.host = np.ascontiguousarray(arr).reshape(-1).view(np.uint8).copy()
        self.table = PageTable(path, self.host.nbytes, page_bytes)


class ManagedSpace:
    def __init__(
        self,
        device_capacity_bytes: int,
        *,
        page_bytes: int = DEFAULT_PAGE_BYTES,
        eviction_policy: str = "lru",
        fault_window_pages: int = 32,
        promote_threshold: int = 0,
        promote_window: int = 0,
    ):
        self.device_capacity_bytes = int(device_capacity_bytes)
        self.page_bytes = int(page_bytes)
        self.policy_name = eviction_policy
        # access-counter promotion (Volta-style): with threshold N > 1, a
        # HOST page *read* is served remotely (no migration) until it has
        # been read N times within ``promote_window`` ticks — only then is
        # it promoted to a device frame. 0/1 = classic first-touch
        # migration. Writes always migrate (write-allocate).
        self.promote_threshold = int(promote_threshold)
        self.promote_window = int(promote_window)
        self.arena = DeviceArena(self.device_capacity_bytes, self.page_bytes)
        self.pager = Pager(
            arena=self.arena,
            policy=make_eviction_policy(eviction_policy, self.arena.n_frames),
            host_of=self._host_of,
        )
        # windowed access: pages pinned per window so faulting page k+1
        # cannot evict page k before its bytes are copied out
        self.fault_window = max(1, min(int(fault_window_pages), self.arena.n_frames))
        self._regions: dict[str, _Region] = {}
        self._treedef = None
        self._tick = 0

    # -- plumbing ---------------------------------------------------------------
    def _host_of(self, table: PageTable) -> np.ndarray:
        return self._regions[table.path].host

    def table(self, path: str) -> PageTable:
        return self._regions[path].table

    def paths(self) -> list[str]:
        return list(self._regions)

    @property
    def stats(self) -> PagingStats:
        return self.pager.stats

    def stats_dict(self) -> dict:
        d = self.pager.stats.as_dict()
        d.update(
            device_capacity_bytes=self.device_capacity_bytes,
            page_bytes=self.page_bytes,
            policy=self.policy_name,
            promote_threshold=self.promote_threshold,
            resident_bytes=self.device_bytes_resident(),
            total_bytes=self.total_bytes(),
        )
        return d

    def tick(self) -> int:
        """Current write clock; writes after a reader captures this value
        are guaranteed a strictly larger ``write_tick``."""
        return self._tick

    def total_bytes(self) -> int:
        return sum(r.host.nbytes for r in self._regions.values())

    def device_bytes_resident(self) -> int:
        return self.arena.resident_frames * self.page_bytes

    def oversubscription_ratio(self) -> float:
        cap = self.device_capacity_bytes
        return (self.total_bytes() / cap) if cap else float("inf")

    # -- registration -----------------------------------------------------------
    def register(self, state: Any) -> None:
        """Back every leaf of ``state`` with a managed region.

        Content starts HOST-resident (pages migrate on first device
        access) and epoch-dirty relative to any tick before registration,
        so a checkpoint consumer that has never synced sees everything.
        """
        flat, treedef = flatten_with_paths(state)
        if self.arena.resident_frames:
            for r in self._regions.values():
                self.pager.invalidate_table(r.table)
        self._regions = {
            path: _Region(path, np.asarray(leaf), self.page_bytes)
            for path, leaf in flat.items()
        }
        self._treedef = treedef
        # registration replaces ALL content: stamp every page at a fresh
        # tick so consumers holding a pre-registration watermark see
        # everything dirty (the tick clock itself survives re-registration)
        self._tick += 1
        for r in self._regions.values():
            r.table.write_tick[:] = self._tick

    # -- device access (faulting) ----------------------------------------------
    def _windows(self, lo_page: int, hi_page: int) -> Iterator[tuple[int, int]]:
        for w_lo in range(lo_page, hi_page, self.fault_window):
            yield w_lo, min(hi_page, w_lo + self.fault_window)

    def _split_promotion(
        self, table: PageTable, pages: np.ndarray
    ) -> tuple[np.ndarray, np.ndarray]:
        """(migrate, remote) page split under the promotion threshold.

        Resident pages always go to ``migrate`` (they're hits); HOST pages
        whose windowed access count is still below the threshold are served
        remotely — the count advances here, so the Nth read promotes.
        """
        host = table.residency[pages] == Residency.HOST
        if not host.any():
            return pages, pages[:0]
        cold = pages[host]
        if self.promote_window:
            stale = self._tick - table.access_tick[cold] > self.promote_window
            table.access_count[cold[stale]] = 0
        # counting THIS access: crossing the threshold promotes now
        promote = table.access_count[cold] + 1 >= self.promote_threshold
        remote = cold[~promote]
        table.access_count[remote] += 1
        table.access_tick[remote] = self._tick
        self.pager.stats.promotions += int(promote.sum())
        return np.concatenate([pages[~host], cold[promote]]), remote

    def read_range(self, path: str, lo: int, hi: int) -> np.ndarray:
        """Device read of byte range [lo, hi): fault in, return the bytes.

        With ``promote_threshold`` > 1, cold (HOST) pages below the
        threshold are read *remotely* — bytes served from host backing
        with no migration, the Volta access-counter behaviour — so a
        once-touched page never costs a frame or an eviction.
        """
        region = self._regions[path]
        table = region.table
        out = np.empty(hi - lo, np.uint8)
        p_lo, p_hi = table.pages_for_range(lo, hi)
        read_mostly = bool(table.advice & Advice.READ_MOSTLY)
        if self.promote_threshold > 1:
            # access epoch: promotion windows are tick-based, so reads
            # must advance the clock (writes already do)
            self._tick += 1
        for w_lo, w_hi in self._windows(p_lo, p_hi):
            pages = np.arange(w_lo, w_hi)
            if self.promote_threshold > 1:
                pages, remote = self._split_promotion(table, pages)
            else:
                remote = pages[:0]
            if pages.size:
                self.pager.fault_in(
                    table, pages, write=False, tick=self._tick,
                    pin=True, read_mostly=read_mostly,
                )
            for p in pages:
                s_lo, s_hi = table.page_span(int(p))
                c_lo, c_hi = max(s_lo, lo), min(s_hi, hi)
                if c_lo < c_hi:
                    fid = int(table.frame[p])
                    out[c_lo - lo : c_hi - lo] = self.arena.frames[
                        fid, c_lo - s_lo : c_hi - s_lo
                    ]
            for p in remote:
                s_lo, s_hi = table.page_span(int(p))
                c_lo, c_hi = max(s_lo, lo), min(s_hi, hi)
                if c_lo < c_hi:
                    out[c_lo - lo : c_hi - lo] = region.host[c_lo:c_hi]
                    self.pager.stats.remote_reads += 1
                    self.pager.stats.remote_read_bytes += c_hi - c_lo
            self.pager.unpin_all()
        return out

    def write_range(self, path: str, lo: int, data: np.ndarray) -> None:
        """Device write at byte offset ``lo``: write-allocate + dirty."""
        region = self._regions[path]
        table = region.table
        data = np.ascontiguousarray(data).reshape(-1).view(np.uint8)
        hi = lo + data.nbytes
        if hi > region.host.nbytes:
            raise ValueError(
                f"write of {data.nbytes}B at {lo} overruns {path!r} "
                f"({region.host.nbytes}B)"
            )
        if data.nbytes == 0:
            return
        self._tick += 1
        p_lo, p_hi = table.pages_for_range(lo, hi)
        for w_lo, w_hi in self._windows(p_lo, p_hi):
            pages = np.arange(w_lo, w_hi)
            for p in pages:
                s_lo, s_hi = table.page_span(int(p))
                full_overwrite = lo <= s_lo and hi >= s_hi
                self.pager.fault_in(
                    table, [p], write=True, tick=self._tick,
                    overwrite=full_overwrite, pin=True,
                )
                c_lo, c_hi = max(s_lo, lo), min(s_hi, hi)
                fid = int(table.frame[p])
                self.arena.frames[fid, c_lo - s_lo : c_hi - s_lo] = data[
                    c_lo - lo : c_hi - lo
                ]
            self.pager.unpin_all()

    def read_leaf(self, path: str) -> np.ndarray:
        region = self._regions[path]
        raw = self.read_range(path, 0, region.host.nbytes)
        return raw.view(region.dtype).reshape(region.shape)

    def write_leaf(self, path: str, arr: Any) -> None:
        region = self._regions[path]
        arr = np.asarray(arr)
        if arr.nbytes != region.host.nbytes or arr.dtype != region.dtype:
            raise ValueError(
                f"leaf {path!r} is {region.host.nbytes}B {region.dtype}; "
                f"got {arr.nbytes}B {arr.dtype} — re-register for reshapes"
            )
        self.write_range(path, 0, arr)

    def read_state(self) -> Any:
        """Fault the whole tree in (device access) and assemble it."""
        leaves = {p: self.read_leaf(p) for p in self._regions}
        return unflatten_from_paths(self._treedef, leaves)

    def write_state(self, state: Any) -> None:
        flat, _ = flatten_with_paths(state)
        for path, leaf in flat.items():
            self.write_leaf(path, leaf)

    # -- coherent host access (no migration) -------------------------------------
    def peek_range(self, path: str, lo: int, hi: int) -> np.ndarray:
        """Coherent host read without migration: backing bytes overlaid
        with device frames that are newer (wb_dirty)."""
        region = self._regions[path]
        table = region.table
        out = region.host[lo:hi].copy()
        dirty = np.flatnonzero(table.wb_dirty)
        for p in dirty:
            s_lo, s_hi = table.page_span(int(p))
            c_lo, c_hi = max(s_lo, lo), min(s_hi, hi)
            if c_lo < c_hi:
                fid = int(table.frame[p])
                out[c_lo - lo : c_hi - lo] = self.arena.frames[
                    fid, c_lo - s_lo : c_hi - s_lo
                ]
        return out

    def peek_leaf(self, path: str) -> np.ndarray:
        region = self._regions[path]
        raw = self.peek_range(path, 0, region.host.nbytes)
        return raw.view(region.dtype).reshape(region.shape)

    def peek_state(self) -> Any:
        leaves = {p: self.peek_leaf(p) for p in self._regions}
        return unflatten_from_paths(self._treedef, leaves)

    # -- host overwrite (restore / upload) ---------------------------------------
    def load_range(self, path: str, lo: int, data: np.ndarray) -> None:
        """Host overwrite of byte range [lo, lo+len): the targeted form of
        :meth:`load_leaf` a chunk-delta upload uses, so only the touched
        pages become epoch-dirty. Fully-covered resident pages are
        invalidated (superseded); partially-covered ones are evicted first
        (write-back) so their untouched bytes survive the splice."""
        region = self._regions[path]
        table = region.table
        data = np.ascontiguousarray(data).reshape(-1).view(np.uint8)
        hi = lo + data.nbytes
        if hi > region.host.nbytes:
            raise ValueError(
                f"load of {data.nbytes}B at {lo} overruns {path!r} "
                f"({region.host.nbytes}B)"
            )
        if data.nbytes == 0:
            return
        p_lo, p_hi = table.pages_for_range(lo, hi)
        for p in range(p_lo, p_hi):
            if table.residency[p] == Residency.HOST:
                continue
            s_lo, s_hi = table.page_span(p)
            if lo <= s_lo and hi >= s_hi:
                self.pager.invalidate_page(table, p)
            else:
                self.pager.evict(int(table.frame[p]))
        region.host[lo:hi] = data
        self._tick += 1
        table.write_tick[p_lo:p_hi] = self._tick

    def load_leaf(self, path: str, arr: Any) -> None:
        """Overwrite the host backing; device frames are superseded."""
        region = self._regions[path]
        arr = np.asarray(arr)
        if arr.nbytes != region.host.nbytes:
            raise ValueError(
                f"load of {arr.nbytes}B into {path!r} ({region.host.nbytes}B)"
            )
        self.pager.invalidate_table(region.table)
        if arr.nbytes:
            region.host[:] = np.ascontiguousarray(arr).reshape(-1).view(np.uint8)
        self._tick += 1
        region.table.write_tick[:] = self._tick

    def load_state(self, state: Any) -> None:
        flat, _ = flatten_with_paths(state)
        for path, leaf in flat.items():
            self.load_leaf(path, leaf)

    # -- hints -------------------------------------------------------------------
    def advise(self, path: str, advice: Advice) -> None:
        self._regions[path].table.advice = int(advice)

    def prefetch_pages(self, path: str, lo_page: int, hi_page: int) -> int:
        """Migrate [lo_page, hi_page) h2d ahead of access; returns pages moved."""
        table = self._regions[path].table
        hi_page = min(hi_page, table.n_pages)
        pages = np.arange(lo_page, hi_page)
        pages = pages[table.residency[pages] == Residency.HOST]
        if pages.size:
            self.pager.fault_in(
                table, pages, write=False, tick=self._tick, prefetch=True,
                read_mostly=bool(table.advice & Advice.READ_MOSTLY),
            )
        return int(pages.size)

    def prefetch(self, path: str, lo_page: int = 0, hi_page: int | None = None) -> int:
        table = self._regions[path].table
        return self.prefetch_pages(
            path, lo_page, table.n_pages if hi_page is None else hi_page
        )

    # -- checkpoint integration ----------------------------------------------------
    def dirty_pages_since(self, path: str, tick: int) -> np.ndarray:
        return self._regions[path].table.dirty_pages_since(tick)

    def dirty_chunk_marks_since(
        self, tick: int, chunk_bytes: int
    ) -> dict[str, list[int]]:
        """{path: sorted chunk indices} dirtied strictly after ``tick``.

        Every registered path appears (clean -> empty list): the shadow
        treats absence as "unknown, be conservative", presence as an
        authoritative page-granular answer.
        """
        out: dict[str, list[int]] = {}
        cb = int(chunk_bytes)
        for path, region in self._regions.items():
            table = region.table
            pages = table.dirty_pages_since(tick)
            if pages.size == 0:
                out[path] = []
                continue
            chunks: set[int] = set()
            for p in pages:
                lo, hi = table.page_span(int(p))
                chunks.update(range(lo // cb, (max(hi, lo + 1) - 1) // cb + 1))
            out[path] = sorted(chunks)
        return out

    def as_dirty_source(self, prefix: str = "") -> "SpaceDirtySource":
        return SpaceDirtySource(self, prefix)

    # -- verification ---------------------------------------------------------------
    def check_invariants(self) -> None:
        resident = 0
        for region in self._regions.values():
            region.table.check_invariants()
            resident += region.table.device_pages().size
        if resident != self.arena.resident_frames:
            raise RuntimeError(
                f"frame accounting skew: tables hold {resident}, arena says "
                f"{self.arena.resident_frames}"
            )
        if resident * self.page_bytes > self.device_capacity_bytes:
            raise RuntimeError("device budget exceeded")


class SpaceDirtySource:
    """Adapter: a ManagedSpace as a ForkedCheckpointer ``dirty_source``.

    ``prefix`` maps space-local leaf paths to the checkpointed pytree's
    paths (the trainer registers ``state['device']``, so its leaves appear
    under ``device/`` in the full state).
    """

    def __init__(self, space: ManagedSpace, prefix: str = ""):
        self.space = space
        self.prefix = prefix

    def tick(self) -> int:
        return self.space.tick()

    def dirty_chunk_marks_since(
        self, tick: int, chunk_bytes: int
    ) -> dict[str, list[int]]:
        marks = self.space.dirty_chunk_marks_since(tick, chunk_bytes)
        return {self.prefix + p: v for p, v in marks.items()}
