"""Fault arming: sentinels, expiry, host filtering, the disk-full shim."""
import errno
import json
import os
import time

import pytest

from repro.chaos import faults


def test_disabled_without_env(monkeypatch, tmp_path):
    monkeypatch.delenv(faults.CHAOS_ENV, raising=False)
    assert faults.chaos_dir() is None
    assert faults.active("disk_full") is None
    with pytest.raises(RuntimeError):
        faults.arm("disk_full", quota_bytes=1)
    # the shim is a no-op: no env, no exception, no file access
    faults.check_disk_quota(0, 10**9, 10**9)


def test_arm_active_disarm(tmp_path):
    d = str(tmp_path)
    path = faults.arm("clock_skew", directory=d, host=1, skew_s=60.0)
    assert os.path.exists(path)
    assert faults.active("clock_skew", directory=d) == \
        {"host": 1, "skew_s": 60.0}
    # host filter: a host-targeted sentinel matches only that host
    assert faults.active("clock_skew", host=1, directory=d) is not None
    assert faults.active("clock_skew", host=0, directory=d) is None
    faults.disarm("clock_skew", directory=d)
    assert faults.active("clock_skew", directory=d) is None
    faults.disarm("clock_skew", directory=d)  # idempotent


def test_self_expiry(tmp_path):
    d = str(tmp_path)
    faults.arm("disk_full", directory=d, duration_s=0.05, quota_bytes=1)
    assert faults.active("disk_full", directory=d) is not None
    time.sleep(0.08)
    assert faults.active("disk_full", directory=d) is None


def test_torn_sentinel_is_inactive(tmp_path):
    d = str(tmp_path)
    with open(os.path.join(d, "disk_full.json"), "w") as f:
        f.write('{"kind": "disk_full", "par')  # torn mid-write
    assert faults.active("disk_full", directory=d) is None


def test_disk_quota_shim(monkeypatch, tmp_path):
    d = str(tmp_path)
    monkeypatch.setenv(faults.CHAOS_ENV, d)
    faults.arm("disk_full", directory=d, host=0, quota_bytes=100)
    faults.check_disk_quota(0, 50, 50)  # exactly at quota: fine
    with pytest.raises(OSError) as ei:
        faults.check_disk_quota(0, 51, 50)
    assert ei.value.errno == errno.ENOSPC
    # another host is unaffected by a host-targeted quota
    faults.check_disk_quota(1, 10**9, 0)


def test_store_writer_hits_quota(monkeypatch, tmp_path):
    """End to end through the real write path: ChunkStore.Writer.append
    raises ENOSPC mid-stream while the fault is armed, and the same
    append succeeds after disarm (abort-not-corrupt's retry path)."""
    from repro.checkpoint.store import ChunkStore

    d = str(tmp_path / "chaos")
    os.makedirs(d)
    monkeypatch.setenv(faults.CHAOS_ENV, d)
    store = ChunkStore(str(tmp_path / "ckpt"))
    faults.arm("disk_full", directory=d, host=0, quota_bytes=1)
    w = store.writer(2, 0)
    with pytest.raises(OSError) as ei:
        w.append(b"x" * 4096, "none", index=0, digest=1)
    assert ei.value.errno == errno.ENOSPC
    w.close(fsync=False)
    faults.disarm("disk_full", directory=d)
    w2 = store.writer(2, 0)
    rec = w2.append(b"x" * 4096, "none", index=0, digest=1)
    w2.close(fsync=False)
    assert store.read_chunk(rec) == b"x" * 4096


def test_arm_is_atomic_replace(tmp_path):
    d = str(tmp_path)
    faults.arm("disk_full", directory=d, quota_bytes=1)
    faults.arm("disk_full", directory=d, quota_bytes=2)
    with open(os.path.join(d, "disk_full.json")) as f:
        doc = json.load(f)
    assert doc["params"]["quota_bytes"] == 2
    assert not [n for n in os.listdir(d) if ".tmp." in n]
