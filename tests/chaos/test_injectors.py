"""Per-injector cluster drills: every fault produces exactly the
evidence its journal line promised, and the run still converges.

Each drill runs a real (small) cluster with the injection engine wired
through ``run_cluster(chaos=...)``, then closes the loop with the soak
verdict: the injection must be evidenced and every alert explained.
Marked ``integration`` (spawns OS processes)."""
import json
import os
import threading
import time

import pytest

from repro.coord.supervisor import run_cluster

pytestmark = pytest.mark.integration


def _chaos_hook(run_dir, chaos_dir, fire):
    """Adapter: run ``fire(engine, handles)`` on a thread once up."""
    from repro.chaos.injectors import InjectionEngine

    def hook(handles):
        eng = InjectionEngine(
            handles, os.path.join(run_dir, "INJECT_LOG.jsonl"),
            chaos_dir=chaos_dir,
        )
        th = threading.Thread(target=fire, args=(eng, handles),
                              daemon=True)
        th.start()

        class _Ctl:
            def stop(self):
                th.join(timeout=30)
                eng.stop()

        return _Ctl()

    return hook


def _wait_first_commit(handles, timeout=60.0):
    deadline = time.monotonic() + timeout
    while time.monotonic() < deadline:
        if handles.coordinator.committed_rounds():
            return True
        if handles.coordinator.done.is_set():
            return False
        time.sleep(0.05)
    return False


def _verdict(run_dir):
    from repro.obs.soak import verdict

    return verdict(run_dir)


def test_torn_frame_is_eof_not_poison(tmp_path):
    """A valid length prefix + partial payload + hangup must be treated
    as a dead stranger: the coordinator keeps committing rounds."""
    run_dir = str(tmp_path)

    def fire(eng, handles):
        assert _wait_first_commit(handles)
        eng.torn_frame()

    report = run_cluster(
        root=os.path.join(run_dir, "ckpt"), n_hosts=2, total_steps=6,
        # the probe's evidence is a commit *after* it fires: keep the
        # steps slow enough that rounds are still landing post-probe
        ckpt_every=2, backend="thread", loop="numpy", step_time_s=0.2,
        deadline_s=180.0, chaos=_chaos_hook(run_dir, None, fire),
    )
    assert report.latest_committed == 6
    assert report.lockstep()
    assert report.alerts == []  # the probe must not trip anything
    doc = _verdict(run_dir)
    assert doc["n_injections"] == 1
    assert doc["checks"]["all_injections_evidenced"], doc["injections"]
    assert doc["checks"]["no_unexplained_alerts"]
    assert doc["pass"], doc["checks"]


def test_disk_full_aborts_then_commits(tmp_path, monkeypatch):
    """ENOSPC mid-persist aborts the round (abort-not-corrupt); once the
    quota window expires the retried round commits cleanly."""
    from repro.chaos.faults import CHAOS_ENV

    run_dir = str(tmp_path)
    chaos_dir = os.path.join(run_dir, "chaos")
    os.makedirs(chaos_dir)
    monkeypatch.setenv(CHAOS_ENV, chaos_dir)

    def fire(eng, handles):
        eng.disk_full(host=0, quota_bytes=1, duration_s=2.5)

    report = run_cluster(
        root=os.path.join(run_dir, "ckpt"), n_hosts=2, total_steps=6,
        ckpt_every=2, backend="thread", loop="numpy", step_time_s=0.05,
        deadline_s=180.0, chaos=_chaos_hook(run_dir, chaos_dir, fire),
    )
    aborted = [r for r in report.aborted if "persist" in r.reason]
    assert aborted, f"no persist abort: {report.rounds}"
    assert "host 0" in aborted[0].reason
    assert report.latest_committed == 6      # the retry committed
    assert report.lockstep()
    assert report.restarts == {0: 0, 1: 0}   # a full disk kills nobody
    doc = _verdict(run_dir)
    assert doc["checks"]["all_injections_evidenced"], doc["injections"]
    assert doc["checks"]["no_unexplained_alerts"], doc["alerts"]
    assert doc["checks"]["converged"]
    assert doc["pass"], doc


def test_clock_skew_alert_fires_and_is_explained(tmp_path, monkeypatch):
    """An armed skew shim pushes the heartbeat wall clock out; the
    watchdog's clock_skew rule names the host; the verdict explains it."""
    from repro.chaos.faults import CHAOS_ENV
    from repro.obs.watch import WatchConfig

    run_dir = str(tmp_path)
    chaos_dir = os.path.join(run_dir, "chaos")
    os.makedirs(chaos_dir)
    monkeypatch.setenv(CHAOS_ENV, chaos_dir)

    def fire(eng, handles):
        eng.clock_skew(host=1, skew_s=120.0, duration_s=2.0)

    report = run_cluster(
        root=os.path.join(run_dir, "ckpt"), n_hosts=2, total_steps=30,
        ckpt_every=10, backend="thread", loop="numpy", step_time_s=0.1,
        deadline_s=180.0, watch_cfg=WatchConfig(max_clock_skew_s=10.0),
        chaos=_chaos_hook(run_dir, chaos_dir, fire),
    )
    skews = [a for a in report.alerts if a["kind"] == "clock_skew"]
    assert skews and skews[0]["host"] == 1
    assert report.lockstep()
    doc = _verdict(run_dir)
    assert doc["checks"]["all_injections_evidenced"], doc["injections"]
    assert doc["checks"]["no_unexplained_alerts"], doc["alerts"]
    assert doc["pass"], doc


def test_partition_reschedules_onto_survivor(tmp_path):
    """A SIGSTOPped proxy host looks exactly like a network partition;
    the worker's op timeout detects it and the coordinator reschedules
    the proxy onto the survivor."""
    run_dir = str(tmp_path)

    def fire(eng, handles):
        assert _wait_first_commit(handles)
        # partition the daemon actually serving worker 0
        name = handles.coordinator.placement.history[0][1]
        index = next(i for i, d in enumerate(handles.daemons)
                     if d.name == name)
        eng.partition(index, window_s=30.0)

    report = run_cluster(
        root=os.path.join(run_dir, "ckpt"), n_hosts=1, total_steps=9,
        ckpt_every=3, backend="thread", loop="numpy", step_time_s=0.25,
        device_runner="proxy", proxy_hosts=2, persist_timeout_s=3.0,
        deadline_s=240.0, chaos=_chaos_hook(run_dir, None, fire),
    )
    # the worker was re-placed: two placements, second on the survivor
    assert len(report.proxy_placements) >= 2
    first, second = report.proxy_placements[0], report.proxy_placements[-1]
    assert first[0] == second[0] == 0 and first[1] != second[1]
    assert report.latest_committed == 9
    assert report.lockstep()
    doc = _verdict(run_dir)
    assert doc["checks"]["all_injections_evidenced"], doc["injections"]
    assert doc["checks"]["no_unexplained_alerts"], doc["alerts"]
    assert doc["pass"], doc


def test_inject_log_is_written_before_the_fault(tmp_path):
    """The journal-first discipline: the INJECT_LOG line (with its
    expected-evidence spec) exists even when the fault itself no-ops."""
    from repro.chaos.injectors import ClusterHandles, InjectionEngine

    class _NoProcs:
        procs: dict = {}

    eng = InjectionEngine(
        ClusterHandles(coordinator=None, supervisor=_NoProcs(),
                       daemons=[], root=str(tmp_path)),
        os.path.join(str(tmp_path), "INJECT_LOG.jsonl"),
        chaos_dir=str(tmp_path / "chaos"),
    )
    doc = eng.kill_worker(0)          # host 0 does not exist: fault no-ops
    eng.journal.close()
    assert doc["seq"] == 1
    with open(os.path.join(str(tmp_path), "INJECT_LOG.jsonl")) as f:
        [line] = [json.loads(x) for x in f]
    assert line["schema"] == "crum-inject/1"
    assert line["event"] == "inject"
    assert line["kind"] == "kill_worker"
    assert line["expect"]["any"]
    assert "worker_death" in line["expect"]["explains"]
