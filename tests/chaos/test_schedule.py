"""Seeded chaos schedules: determinism, safety caps, shape validation."""
import pytest

from repro.chaos.schedule import build_schedule


def test_same_seed_same_plan():
    kw = dict(duration_s=120.0, n_hosts=3, n_proxy_hosts=3)
    a = build_schedule(seed=42, **kw)
    b = build_schedule(seed=42, **kw)
    assert a == b
    assert a, "a two-minute soak must plan at least one injection"


def test_different_seed_different_plan():
    kw = dict(duration_s=120.0, n_hosts=3, n_proxy_hosts=3)
    plans = {tuple((p.kind, p.offset_s) for p in
             build_schedule(seed=s, **kw)) for s in range(6)}
    assert len(plans) > 1


def test_worker_kill_cap_respected():
    plan = build_schedule(seed=1, duration_s=600.0, n_hosts=2,
                          kinds=("kill_worker",),
                          max_worker_kills_per_host=1)
    kills: dict[int, int] = {}
    for p in plan:
        kills[p.params["host"]] = kills.get(p.params["host"], 0) + 1
    assert kills and max(kills.values()) <= 1


def test_proxy_host_kills_leave_a_survivor():
    plan = build_schedule(seed=3, duration_s=600.0, n_hosts=2,
                          n_proxy_hosts=3,
                          kinds=("kill_proxy_host", "partition"))
    killed = {p.params["index"] for p in plan
              if p.kind == "kill_proxy_host"}
    assert len(killed) <= 2  # of 3: always one survivor
    # a partitioned daemon is never one already killed earlier
    dead: set[int] = set()
    for p in plan:
        if p.kind == "partition":
            assert p.params["index"] not in dead
        elif p.kind == "kill_proxy_host":
            dead.add(p.params["index"])


def test_proxy_kinds_need_daemons():
    with pytest.raises(ValueError):
        build_schedule(seed=0, duration_s=60.0, n_hosts=2,
                       n_proxy_hosts=0, kinds=("partition",))


def test_tail_is_fault_free():
    plan = build_schedule(seed=5, duration_s=90.0, n_hosts=2,
                          n_proxy_hosts=2)
    assert plan
    # the last third of the run is reserved for convergence
    assert max(p.offset_s for p in plan) < 90.0 - 20.0
