"""Durability (directory fsync at commit) + GC-race tolerance on reads."""
import os

import numpy as np
import pytest

from repro.checkpoint.manifest import (
    Manifest,
    commit_manifest,
    committed_steps,
    fsync_dir,
    latest_committed_step,
    load_manifest,
    load_manifest_if_committed,
    step_dir,
)
from repro.checkpoint.store import ChunkStore
from repro.core.restore import RestoreManager


def _commit_step(root, step):
    commit_manifest(root, Manifest(step=step), durable=True)


# -- durability ---------------------------------------------------------------

def test_commit_fsyncs_step_dir_and_root(tmp_path, monkeypatch):
    """The commit point must flush directory entries, not just file bytes:
    a rename that only lives in the directory cache can vanish on power
    failure, leaving a COMMIT whose payloads were never durably linked."""
    root = str(tmp_path / "ck")
    os.makedirs(root)
    synced_dirs = []
    real_fsync = os.fsync

    def spy_fsync(fd):
        try:
            import stat

            if stat.S_ISDIR(os.fstat(fd).st_mode):
                synced_dirs.append(fd)
        except OSError:
            pass
        return real_fsync(fd)

    monkeypatch.setattr(os, "fsync", spy_fsync)
    commit_manifest(root, Manifest(step=3), durable=True)
    # step dir (payloads + MANIFEST + COMMIT renames) and root (step dir entry)
    assert len(synced_dirs) >= 2


def test_commit_durable_false_skips_dir_fsync(tmp_path, monkeypatch):
    root = str(tmp_path / "ck")
    os.makedirs(root)
    opened_dirs = []
    real_open = os.open

    def spy_open(path, flags, *a, **k):
        if os.path.isdir(path):
            opened_dirs.append(path)
        return real_open(path, flags, *a, **k)

    monkeypatch.setattr(os, "open", spy_open)
    commit_manifest(root, Manifest(step=3), durable=False)
    assert opened_dirs == []


def test_fsync_dir_tolerates_missing_dir(tmp_path):
    fsync_dir(str(tmp_path / "never-existed"))  # must not raise


# -- GC races -----------------------------------------------------------------

def test_committed_steps_tolerates_vanishing_root(tmp_path):
    assert committed_steps(str(tmp_path / "nope")) == []
    # a *file* where the root should be is also a clean "nothing"
    f = tmp_path / "afile"
    f.write_text("x")
    assert committed_steps(str(f)) == []


def test_committed_steps_tolerates_ghost_entries(tmp_path, monkeypatch):
    """A step dir listed by listdir can be GC'd before the COMMIT probe."""
    root = str(tmp_path / "ck")
    os.makedirs(root)
    _commit_step(root, 1)
    real_listdir = os.listdir

    def ghost_listdir(path):
        names = real_listdir(path)
        if os.path.abspath(path) == os.path.abspath(root):
            names = names + ["step_00000099"]  # listed, then GC'd
        return names

    monkeypatch.setattr(os, "listdir", ghost_listdir)
    assert committed_steps(root) == [1]
    assert latest_committed_step(root) == 1


def test_load_manifest_if_committed_none_on_gc(tmp_path):
    root = str(tmp_path / "ck")
    os.makedirs(root)
    _commit_step(root, 1)
    assert load_manifest_if_committed(root, 1).step == 1
    assert load_manifest_if_committed(root, 2) is None
    # GC between is_committed and the read: simulated by removing the dir
    import shutil

    shutil.rmtree(step_dir(root, 1))
    assert load_manifest_if_committed(root, 1) is None


def test_restore_survives_gc_of_newest_step(tmp_path, monkeypatch):
    """latest_committed_step picks N, GC deletes N before the manifest
    read: restore must fall back to the surviving step, not crash."""
    root = str(tmp_path / "ck")
    store = ChunkStore(root)
    rng = np.random.default_rng(0)
    from repro.core.forked import ForkedCheckpointer

    ck = ForkedCheckpointer(store, chunk_bytes=1 << 8, digest_on_device=False)
    state = {"w": rng.standard_normal(32).astype(np.float32)}
    ck.save_async(1, state).wait(60)
    ck.save_async(2, state).wait(60)
    ck.close()

    import repro.core.restore as restore_mod

    real_load = restore_mod.load_manifest
    calls = {"n": 0}

    def racing_load(root_, step):
        calls["n"] += 1
        if calls["n"] == 1 and step == 2:
            # concurrent GC wins the race for the newest step
            import shutil

            shutil.rmtree(step_dir(root_, 2))
            raise FileNotFoundError(f"step {step} GC'd mid-read")
        return real_load(root_, step)

    monkeypatch.setattr(restore_mod, "load_manifest", racing_load)
    restored, manifest = RestoreManager(store).restore()
    assert manifest.step == 1
    np.testing.assert_array_equal(restored["w"], state["w"])


def test_restore_explicit_step_still_raises(tmp_path):
    """Only the auto-picked path retries; an explicit step the caller
    asked for propagates its FileNotFoundError."""
    root = str(tmp_path / "ck")
    os.makedirs(root)
    _commit_step(root, 1)
    with pytest.raises(FileNotFoundError):
        RestoreManager(ChunkStore(root)).restore(step=7)


def test_trainer_gc_tolerates_concurrent_collection(tmp_path, monkeypatch):
    """Another process GCs a step between the scan and the manifest read:
    the trainer's GC planner skips it instead of crashing."""
    import jax.numpy as jnp

    from repro.core import CheckpointedTrainer, CheckpointPolicy

    trainer = CheckpointedTrainer(
        lambda s, b: (s, {}),
        store_root=str(tmp_path / "gc"),
        policy=CheckpointPolicy(interval_steps=1, keep_last=1),
        chunk_bytes=1 << 8, incremental=False,
    )
    state = {"device": {"w": jnp.zeros((8,), jnp.float32)},
             "host": {"step": np.int64(0)}}
    trainer.checkpointer.save_async(1, state).wait(60)
    trainer.checkpointer.save_async(2, state).wait(60)

    import repro.checkpoint.manifest as manifest_mod

    real = manifest_mod.load_manifest_if_committed
    import repro.core.trainer as trainer_mod  # noqa: F401 (import target)

    def racing(root, step):
        if step == 1:
            import shutil

            d = step_dir(root, step)
            if os.path.isdir(d):
                shutil.rmtree(d)
            return None
        return real(root, step)

    monkeypatch.setattr(manifest_mod, "load_manifest_if_committed", racing)
    trainer._gc()  # must not raise
    assert committed_steps(str(tmp_path / "gc")) == [2]
    trainer.checkpointer.close()
